//! BENCH ABL-SCALE — the paper's workload envelope.
//!
//! §2: "a typical PERMANOVA invocation uses a distance matrix between 1k²
//! and 100k² elements, and computes the pseudo-F partial statistic on
//! between 1k and 1M permutations."  This bench measures host throughput
//! across matrix sizes (elements/s must stay ~flat once out of cache) and
//! sweeps the model across the paper's full envelope.
//!
//! Run: `cargo bench --bench ablation_scaling`

use permanova_apu::bench::Bencher;
use permanova_apu::dmat::DistanceMatrix;
use permanova_apu::permanova::{sw_permutations, Grouping, SwAlgorithm};
use permanova_apu::report::Table;
use permanova_apu::simulator::{predict, DeviceConfig, Mi300a, Workload};

fn main() {
    println!("host: matrix-size scaling of Algorithm 2 (tiled, all threads)\n");
    let mut b = Bencher { warmup: 1, min_reps: 3, max_reps: 5, ..Default::default() };
    let mut t = Table::new(&["n", "perms", "median s", "Melem/s"]);
    for n in [256usize, 512, 1024, 2048] {
        // Keep total work ~constant so every row runs in similar time.
        let perms = (2048 * 2048 / (n * n) * 8).clamp(2, 512);
        let mat = DistanceMatrix::random_euclidean(n, 8, 1);
        let grouping = Grouping::balanced(n, 8).unwrap();
        let m = b.run(&format!("n{n}"), || {
            sw_permutations(&mat, &grouping, 3, perms, SwAlgorithm::Tiled { tile: 512 }, 0)
        });
        let elems = (n * (n - 1) / 2) as f64 * perms as f64;
        t.row(&[
            n.to_string(),
            perms.to_string(),
            format!("{:.4}", m.median),
            format!("{:.1}", elems / m.median / 1e6),
        ]);
    }
    println!("{}", t.render());

    println!("model: MI300A predictions across the paper's envelope");
    println!("(rows: matrix edge; cols: permutations; cells: GPU-brute s / CPU-tiled-SMT s)\n");
    let machine = Mi300a::default();
    let ns = [1_000usize, 5_000, 25_145, 100_000];
    let ps = [1_000usize, 3_999, 100_000, 1_000_000];
    let mut mt = Table::new(&["n \\ perms", "1k", "3999", "100k", "1M"]);
    for n in ns {
        let mut row = vec![n.to_string()];
        for p in ps {
            let w = Workload { n_dims: n, n_perms: p, n_groups: 8 };
            let gpu = predict(&machine, &w, SwAlgorithm::Brute, DeviceConfig::Gpu);
            let cpu = predict(
                &machine,
                &w,
                SwAlgorithm::Tiled { tile: 512 },
                DeviceConfig::Cpu { smt: true },
            );
            row.push(format!("{:.0}/{:.0}", gpu.seconds, cpu.seconds));
        }
        mt.row(&row);
    }
    println!("{}", mt.render());
    println!("(the GPU advantage holds across the whole envelope; at n=100k, 1M perms the");
    println!(" run is ~days on CPU vs ~hours on GPU — the paper's motivation for offload)");
}
