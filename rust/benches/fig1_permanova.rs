//! BENCH FIG1 — the paper's Figure 1: PERMANOVA execution time by
//! algorithm and resource.
//!
//! Part A: the calibrated MI300A model at the paper's exact workload
//! (25145², 3999 perms) — the six bars of Figure 1.
//! Part B: the same algorithm axis *measured* on this host at reduced
//! scale, confirming the CPU-side orderings on real silicon.
//!
//! Run: `cargo bench --bench fig1_permanova`

use permanova_apu::bench::Bencher;
use permanova_apu::dmat::DistanceMatrix;
use permanova_apu::permanova::{sw_permutations, Grouping, SwAlgorithm};
use permanova_apu::report::Table;
use permanova_apu::simulator::{fig1_rows, render_fig1, Mi300a, Workload};

fn main() {
    println!("================================================================");
    println!("FIG1.A  simulated MI300A, paper workload (25145^2, 3999 perms)");
    println!("================================================================\n");
    let rows = fig1_rows(&Mi300a::default(), &Workload::paper());
    println!("{}", render_fig1(&rows));

    let mut t = Table::new(&["configuration", "seconds", "bound", "achieved GB/s"]);
    for r in &rows {
        t.row(&[
            r.label.clone(),
            format!("{:.2}", r.seconds),
            format!("{:?}", r.bound),
            format!("{:.0}", r.prediction.achieved_bw_gbs),
        ]);
    }
    println!("{}", t.render());

    println!("================================================================");
    println!("FIG1.B  host-measured, reduced scale (CPU-side orderings)");
    println!("================================================================\n");
    // The tiling win needs the paper's regime: the grouping row (4n bytes)
    // must exceed L1d.  n = 16384 -> 64 KiB per row, comfortably past it.
    let n = 16384;
    let k = 8;
    let perms = 4;
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let half = (cores / 2).max(1);
    println!("n={n}, perms={perms}, host threads: {half} (noSMT analog) / {cores} (SMT analog)\n");

    // Timing only depends on the access pattern, not values: a zero matrix
    // keeps setup fast at this n (the numerics benches cover correctness).
    let mat = DistanceMatrix::zeros(n);
    let grouping = Grouping::balanced(n, k).unwrap();
    let mut b = Bencher { warmup: 1, min_reps: 3, max_reps: 6, ..Default::default() };

    let configs: Vec<(&str, SwAlgorithm, usize)> = vec![
        ("CPU brute force (no SMT)", SwAlgorithm::Brute, half),
        ("CPU brute force (SMT)", SwAlgorithm::Brute, cores),
        ("CPU tiled (no SMT)", SwAlgorithm::Tiled { tile: 512 }, half),
        ("CPU tiled (SMT)", SwAlgorithm::Tiled { tile: 512 }, cores),
        ("CPU flat/SIMD (SMT)", SwAlgorithm::Flat, cores),
    ];
    let mut out = Table::new(&["configuration", "median s", "best s", "perms/s"]);
    let mut medians = Vec::new();
    for (label, algo, threads) in configs {
        let m = b.run(label, || sw_permutations(&mat, &grouping, 3, perms, algo, threads));
        out.row(&[
            label.to_string(),
            format!("{:.4}", m.median),
            format!("{:.4}", m.best),
            format!("{:.1}", perms as f64 / m.median),
        ]);
        medians.push((label, m.median));
    }
    println!("{}", out.render());

    let get = |l: &str| medians.iter().find(|(n, _)| *n == l).unwrap().1;
    println!("paper-claim checks (host):");
    println!(
        "  tiled beats brute (no SMT): {}",
        get("CPU tiled (no SMT)") < get("CPU brute force (no SMT)")
    );
    println!(
        "  tiled beats brute (SMT):    {}",
        get("CPU tiled (SMT)") < get("CPU brute force (SMT)")
    );
    if cores > 1 {
        println!(
            "  SMT helps brute:            {}",
            get("CPU brute force (SMT)") < get("CPU brute force (no SMT)")
        );
    } else {
        println!("  SMT helps brute:            (skipped: single-core host)");
    }
}
