//! BENCH XLA-KERN — the three-layer stack's serving cost (our extension).
//!
//! For each AOT-compiled kernel variant: compile time, batch latency and
//! permutation throughput through the PJRT runtime, vs the native Rust
//! kernels on identical inputs.  This is the "is the AOT stack paying its
//! way" table recorded in EXPERIMENTS.md §XLA-KERN.
//!
//! Requires `make artifacts` (skips gracefully otherwise).
//!
//! Run: `cargo bench --bench kernel_xla`

use std::time::Instant;

use permanova_apu::bench::Bencher;
use permanova_apu::dmat::{CondensedMatrix, DistanceMatrix};
use permanova_apu::permanova::{sw_batch, Grouping, SwAlgorithm};
use permanova_apu::report::Table;
use permanova_apu::rng::PermutationPlan;
use permanova_apu::runtime::{artifacts_dir_for_tests, XlaRuntime};

fn main() {
    let dir = artifacts_dir_for_tests();
    if !dir.join("manifest.json").exists() {
        println!("SKIP: no artifacts at {dir:?} (run `make artifacts`)");
        return;
    }
    let rt = match XlaRuntime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP: {e}");
            return;
        }
    };
    println!("platform: {}, artifacts: {}\n", rt.platform(), rt.manifest().artifacts().len());

    let n = 256;
    let k = 8;
    let mat = DistanceMatrix::random_euclidean(n, 16, 9);
    let grouping = Grouping::balanced(n, k).unwrap();
    let plan = PermutationPlan::new(grouping.labels().to_vec(), 21, 1024);

    let mut b = Bencher { warmup: 1, min_reps: 3, max_reps: 8, ..Default::default() };
    let mut t = Table::new(&[
        "kernel", "compile s", "batch", "batch latency s", "perms/s",
    ]);

    for kernel in ["bruteforce", "tiled", "matmul", "ref"] {
        let Some(_) = rt.manifest().best_fit(kernel, n) else { continue };
        let t0 = Instant::now();
        let sess = rt.session(kernel, mat.data(), n, &grouping).unwrap();
        let compile = t0.elapsed().as_secs_f64();
        let cap = sess.batch_capacity();
        let rows = plan.batch(0, cap);
        let m = b.run(kernel, || sess.run_batch(&rows, cap).unwrap());
        t.row(&[
            format!("xla/{kernel}"),
            format!("{compile:.2}"),
            cap.to_string(),
            format!("{:.4}", m.median),
            format!("{:.0}", cap as f64 / m.median),
        ]);
    }

    // Native baselines on the same inputs (batch = 32 to match artifacts;
    // packed once, like the engine does).
    let tri = CondensedMatrix::from_dense(&mat);
    let cap = 32;
    let rows = plan.batch(0, cap);
    for (name, algo) in [
        ("native/brute", SwAlgorithm::Brute),
        ("native/tiled512", SwAlgorithm::Tiled { tile: 512 }),
        ("native/flat", SwAlgorithm::Flat),
    ] {
        let m = b.run(name, || {
            sw_batch(&tri, &rows, cap, grouping.inv_sizes(), algo, 0)
        });
        t.row(&[
            name.to_string(),
            "-".into(),
            cap.to_string(),
            format!("{:.4}", m.median),
            format!("{:.0}", cap as f64 / m.median),
        ]);
    }
    println!("{}", t.render());
    println!("(interpret-mode Pallas lowers to scalar-ish HLO loops on CPU — the native");
    println!(" kernels win on this backend; on a real TPU the matmul variant rides the MXU.");
    println!(" The bench exists to keep the serving path honest, not to crown a winner.)");
}
