//! BENCH A2 — the paper's Appendix A2: STREAM memory bandwidth.
//!
//! Host-measured STREAM (Copy/Scale/Add/Triad), then the simulated MI300A
//! CPU and GPU tables side-by-side with the paper's printed values.
//!
//! Run: `cargo bench --bench a2_stream`

use permanova_apu::report::Table;
use permanova_apu::simulator::{paper_a2_reference, simulate_stream, Mi300a, StreamDevice};
use permanova_apu::stream::run_stream;

fn main() {
    println!("================================================================");
    println!("A2.host  STREAM on this machine");
    println!("================================================================\n");
    let r = run_stream(30_000_000, 6, 0);
    println!(
        "array = {} doubles x3 ({} MiB total), {} threads, best of {}",
        r.array_len,
        r.array_len * 8 * 3 >> 20,
        r.threads,
        r.reps - 1
    );
    println!("{}", r.format_table());
    println!(
        "{} (max rel err {:.2e})\n",
        if r.validated { "Solution Validates" } else { "VALIDATION FAILED" },
        r.max_rel_err
    );

    println!("================================================================");
    println!("A2.sim  simulated MI300A vs the paper's printed values");
    println!("================================================================\n");
    let m = Mi300a::default();
    for (dev, title) in [
        (StreamDevice::Cpu, "CPU cores, 48 threads (stream.large.exe)"),
        (StreamDevice::Gpu, "GPU cores (stream.amd_apu.exe, HSA_XNACK=1)"),
    ] {
        println!("-- {title} --");
        let sim = simulate_stream(&m, dev, 1_000_000_000);
        let mut t = Table::new(&["Function", "model MB/s", "paper MB/s", "delta"]);
        for (res, (_, paper)) in sim.iter().zip(paper_a2_reference(dev)) {
            t.row(&[
                format!("{}:", res.kernel.name()),
                format!("{:.1}", res.best_rate_mbs),
                format!("{paper:.1}"),
                format!("{:+.2}%", (res.best_rate_mbs / paper - 1.0) * 100.0),
            ]);
        }
        println!("{}", t.render());
    }
    let cpu = simulate_stream(&m, StreamDevice::Cpu, 1 << 20);
    let gpu = simulate_stream(&m, StreamDevice::Gpu, 1 << 20);
    println!(
        "headline asymmetry: GPU/CPU Triad = {:.1}x on identical HBM (paper: ~15x)",
        gpu[3].best_rate_mbs / cpu[3].best_rate_mbs
    );
}
