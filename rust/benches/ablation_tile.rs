//! BENCH ABL-TILE — the paper's TILE constant, swept.
//!
//! §2 of the paper hand-tiles the loops with a fixed TILE; this ablation
//! measures the tile-size sensitivity of Algorithm 2 on the host (where
//! L1d residency of the grouping slice is the mechanism) and prints the
//! model's predicted MI300A sensitivity (where only the small line-waste
//! term moves — the model says tile choice is second-order for traffic,
//! first-order for the CPU issue rate).
//!
//! Run: `cargo bench --bench ablation_tile`

use permanova_apu::bench::Bencher;
use permanova_apu::dmat::DistanceMatrix;
use permanova_apu::permanova::{sw_permutations, Grouping, SwAlgorithm};
use permanova_apu::report::Table;
use permanova_apu::simulator::{cpu_traffic, predict, DeviceConfig, Mi300a, Workload};

fn main() {
    let n = 2048;
    let k = 8;
    let perms = 16;
    let tiles = [32usize, 64, 128, 256, 512, 1024, 2048];

    println!("host: n={n}, perms={perms}, Algorithm 2 tile sweep\n");
    let mat = DistanceMatrix::random_euclidean(n, 16, 5);
    let grouping = Grouping::balanced(n, k).unwrap();
    let mut b = Bencher { warmup: 1, min_reps: 3, max_reps: 6, ..Default::default() };

    let brute = b.run("brute (reference)", || {
        sw_permutations(&mat, &grouping, 3, perms, SwAlgorithm::Brute, 0)
    });

    let mut t = Table::new(&["tile", "median s", "vs brute", "model HBM bytes @paper-scale"]);
    let mut best: Option<(usize, f64)> = None;
    let w = Workload::paper();
    for tile in tiles {
        let m = b.run(&format!("tiled{tile}"), || {
            sw_permutations(&mat, &grouping, 3, perms, SwAlgorithm::Tiled { tile }, 0)
        });
        let traffic = cpu_traffic(&w, SwAlgorithm::Tiled { tile });
        t.row(&[
            tile.to_string(),
            format!("{:.4}", m.median),
            format!("{:.2}x", brute.median / m.median),
            format!("{}", traffic.hbm_bytes),
        ]);
        if best.map(|(_, bt)| m.median < bt).unwrap_or(true) {
            best = Some((tile, m.median));
        }
    }
    println!("{}", t.render());
    let (bt, bs) = best.unwrap();
    println!(
        "best host tile: {bt} ({:.4}s median, {:.2}x over brute)\n",
        bs,
        brute.median / bs
    );

    println!("model: predicted MI300A CPU (SMT) seconds at paper scale per tile");
    let machine = Mi300a::default();
    let mut tm = Table::new(&["tile", "predicted s", "bound"]);
    for tile in tiles {
        let p = predict(&machine, &w, SwAlgorithm::Tiled { tile }, DeviceConfig::Cpu { smt: true });
        tm.row(&[tile.to_string(), format!("{:.2}", p.seconds), format!("{:?}", p.bound)]);
    }
    println!("{}", tm.render());
    println!("(model: tile only moves the line-waste term once memory-bound — matching the");
    println!(" paper's experience that the exact TILE mattered less than tiling at all)");
}
