//! BENCH ABL-SMT — "the significant benefit of SMT was a pleasant
//! surprise" (paper §1/§3).
//!
//! Host: thread-count sweep for brute vs tiled (on a multi-core host the
//! 2x-threads point is the SMT analog; on this container it degenerates
//! gracefully and says so).  Model: the SMT on/off delta for every
//! algorithm class at paper scale, with the bound explaining *why* SMT
//! helps (stall-bound loops) or doesn't (throughput-bound flat kernel).
//!
//! Run: `cargo bench --bench ablation_smt`

use permanova_apu::bench::Bencher;
use permanova_apu::dmat::DistanceMatrix;
use permanova_apu::permanova::{sw_permutations, Grouping, SwAlgorithm};
use permanova_apu::report::Table;
use permanova_apu::simulator::{predict, DeviceConfig, Mi300a, Workload};

fn main() {
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let n = 1536;
    let perms = 16;
    println!("host: thread sweep, n={n}, perms={perms}, {cores} hw threads available\n");

    let mat = DistanceMatrix::random_euclidean(n, 16, 3);
    let grouping = Grouping::balanced(n, 8).unwrap();
    let mut b = Bencher { warmup: 1, min_reps: 3, max_reps: 5, ..Default::default() };

    let mut threads_list: Vec<usize> = vec![1];
    let mut th = 2;
    while th <= 2 * cores {
        threads_list.push(th);
        th *= 2;
    }

    let mut t = Table::new(&["threads", "brute s", "tiled s", "brute speedup", "tiled speedup"]);
    let mut base: Option<(f64, f64)> = None;
    for &threads in &threads_list {
        let mb = b.run(&format!("brute t{threads}"), || {
            sw_permutations(&mat, &grouping, 3, perms, SwAlgorithm::Brute, threads)
        });
        let mt = b.run(&format!("tiled t{threads}"), || {
            sw_permutations(&mat, &grouping, 3, perms, SwAlgorithm::Tiled { tile: 512 }, threads)
        });
        let (b0, t0) = *base.get_or_insert((mb.median, mt.median));
        t.row(&[
            threads.to_string(),
            format!("{:.4}", mb.median),
            format!("{:.4}", mt.median),
            format!("{:.2}x", b0 / mb.median),
            format!("{:.2}x", t0 / mt.median),
        ]);
    }
    println!("{}", t.render());
    if cores == 1 {
        println!("(single-core container: oversubscription shows no gain, as expected;");
        println!(" the SMT effect is carried by the model below)\n");
    }

    println!("model: MI300A SMT on/off at paper scale (25145^2, 3999 perms)\n");
    let machine = Mi300a::default();
    let w = Workload::paper();
    let mut mt = Table::new(&["algorithm", "no SMT s", "SMT s", "SMT gain", "bound (SMT)"]);
    for (name, algo) in [
        ("brute", SwAlgorithm::Brute),
        ("tiled512", SwAlgorithm::Tiled { tile: 512 }),
        ("flat/SIMD", SwAlgorithm::Flat),
    ] {
        let off = predict(&machine, &w, algo, DeviceConfig::Cpu { smt: false });
        let on = predict(&machine, &w, algo, DeviceConfig::Cpu { smt: true });
        mt.row(&[
            name.to_string(),
            format!("{:.2}", off.seconds),
            format!("{:.2}", on.seconds),
            format!("{:.2}x", off.seconds / on.seconds),
            format!("{:?}", on.bound),
        ]);
    }
    println!("{}", mt.render());
    println!("(SMT pays most for the stall-bound brute loop; the memory-bound tiled kernel");
    println!(" still gains because SMT raises achievable bandwidth 150 -> 209 GB/s — the");
    println!(" paper's 'pleasant surprise' has two separate mechanisms)");
}
