//! Cross-backend conformance: for one fixed seed, every backend draws the
//! *same* permutation stream and must reproduce the same statistics.
//!
//! Two tiers of agreement, matching what the arithmetic can actually
//! guarantee:
//!
//! * **Oracle tier** — every backend's full F-distribution matches the f64
//!   brute-force oracle to f32-reduction tolerance, and all backends agree
//!   on the p-value exactly.
//! * **Bitwise tier** — backends that execute the same f32 operation
//!   sequence are bitwise identical: `native-batch` ≡ `native-brute` at
//!   every tested block size (the batched engine's defining contract), and
//!   `simulator` ≡ `native-flat` (both run the flat kernel).

use permanova_apu::backend::execute;
use permanova_apu::config::{DataSource, RunConfig};
use permanova_apu::permanova::{fstat_from_sw, st_of, sw_brute_f64};
use permanova_apu::report::RunReport;
use permanova_apu::rng::PermutationPlan;

const N: usize = 56;
const K: usize = 4;
const N_PERMS: usize = 149;
const SEED: u64 = 0xC0FFEE;

fn cfg(backend: &str, perm_block: usize) -> RunConfig {
    RunConfig {
        data: DataSource::Synthetic { n_dims: N, n_groups: K },
        backend: backend.to_string(),
        n_perms: N_PERMS,
        seed: SEED,
        threads: 2,
        perm_block,
        ..Default::default()
    }
}

fn run(backend: &str, perm_block: usize) -> RunReport {
    let c = cfg(backend, perm_block);
    let (mat, grouping) = permanova_apu::coordinator::load_data(&c).unwrap();
    execute(&c, &mat, &grouping).unwrap()
}

/// The f64 oracle F-distribution for the fixture, straight from the plan.
fn oracle() -> Vec<f64> {
    let c = cfg("native-brute", 0);
    let (mat, grouping) = permanova_apu::coordinator::load_data(&c).unwrap();
    let s_t = st_of(&mat);
    let plan = PermutationPlan::new(grouping.labels().to_vec(), SEED, N_PERMS + 1);
    let mut row = vec![0u32; N];
    (0..N_PERMS + 1)
        .map(|i| {
            plan.fill(i, &mut row);
            let sw = sw_brute_f64(mat.data(), N, &row, grouping.inv_sizes());
            fstat_from_sw(sw, s_t, N, K)
        })
        .collect()
}

#[test]
fn every_backend_matches_the_f64_oracle() {
    let want = oracle();
    let runs: Vec<(String, RunReport)> = [
        ("native".to_string(), 0usize),
        ("native-brute".to_string(), 0),
        ("native-tiled".to_string(), 0),
        ("native-flat".to_string(), 0),
        ("native-batch".to_string(), 1),
        ("native-batch".to_string(), 8),
        ("native-batch".to_string(), 64),
        ("simulator".to_string(), 0),
        ("simulator-gpu".to_string(), 0),
    ]
    .into_iter()
    .map(|(name, block)| {
        let label = if block > 0 { format!("{name}/b{block}") } else { name.clone() };
        (label, run(&name, block))
    })
    .collect();

    for (label, r) in &runs {
        assert_eq!(r.f_perms.len(), N_PERMS, "{label}");
        let rel = (r.f_obs - want[0]).abs() / want[0].abs().max(1e-12);
        assert!(rel < 5e-4, "{label}: f_obs {} vs oracle {}", r.f_obs, want[0]);
        for (i, (got, oracle_f)) in r.f_perms.iter().zip(&want[1..]).enumerate() {
            let rel = (got - oracle_f).abs() / oracle_f.abs().max(1e-12);
            assert!(rel < 5e-4, "{label} perm {i}: {got} vs {oracle_f}");
        }
    }

    // Identical permutation stream + well-separated statistics => every
    // backend lands on the identical p-value.
    let (label0, r0) = &runs[0];
    for (label, r) in &runs[1..] {
        assert_eq!(r.p_value, r0.p_value, "{label} vs {label0}");
    }
}

#[test]
fn native_batch_is_bitwise_identical_to_brute_at_all_block_sizes() {
    let brute = run("native-brute", 0);
    assert_eq!(brute.perm_block, 0);
    for block in [1usize, 8, 64] {
        let batch = run("native-batch", block);
        assert_eq!(batch.backend, "native-batch");
        assert_eq!(batch.perm_block, block, "report records the resolved block");
        assert_eq!(
            batch.f_obs.to_bits(),
            brute.f_obs.to_bits(),
            "block={block}: f_obs {} vs {}",
            batch.f_obs,
            brute.f_obs
        );
        assert_eq!(batch.f_perms.len(), brute.f_perms.len());
        for (i, (b, s)) in batch.f_perms.iter().zip(&brute.f_perms).enumerate() {
            assert_eq!(b.to_bits(), s.to_bits(), "block={block} perm {i}: {b} vs {s}");
        }
        assert_eq!(batch.p_value, brute.p_value);
    }
}

#[test]
fn simulator_is_bitwise_identical_to_native_flat() {
    let flat = run("native-flat", 0);
    let sim = run("simulator", 0);
    assert_eq!(flat.f_obs.to_bits(), sim.f_obs.to_bits());
    for (a, b) in flat.f_perms.iter().zip(&sim.f_perms) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // The simulator additionally reports modelled MI300A time.
    assert!(sim.per_device.iter().map(|d| d.simulated_secs).sum::<f64>() > 0.0);
}
