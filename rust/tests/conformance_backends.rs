//! Cross-backend × cross-method conformance: for one fixed seed, every
//! backend draws the *same* permutation stream and must reproduce the same
//! statistics — for **every** method the engine routes, not just
//! PERMANOVA's pseudo-F.
//!
//! Tiers of agreement, matching what the arithmetic can actually
//! guarantee:
//!
//! * **Oracle tier (PERMANOVA)** — every backend's full F-distribution
//!   matches the f64 brute-force oracle to f32-reduction tolerance, and
//!   all backends agree on the p-value exactly.
//! * **Exact tier (ANOSIM / PERMDISP / pairwise)** — the generic methods
//!   compute in f64 with one shared statistic implementation, so every
//!   backend must match the legacy standalone oracle functions
//!   (`anosim`, `permdisp`, `pairwise_permanova`) **exactly**, across
//!   shard / worker / SMT / block settings.
//! * **Bitwise tier** — backends that execute the same operation sequence
//!   are bitwise identical *per method*: `native-batch` ≡ `native-brute`
//!   at every tested block size, and `simulator` ≡ `native-flat`.

use permanova_apu::backend::execute;
use permanova_apu::config::{DataSource, RunConfig};
use permanova_apu::permanova::{
    anosim, fstat_from_sw, pairwise_permanova, permdisp, st_of, sw_brute_f64_dense, Method,
    PermanovaOpts, SwAlgorithm,
};
use permanova_apu::report::AnalysisReport;
use permanova_apu::rng::PermutationPlan;

const N: usize = 56;
const K: usize = 4;
const N_PERMS: usize = 149;
const SEED: u64 = 0xC0FFEE;

/// Every backend the conformance sweep covers (xla needs artifacts and is
/// covered by its own gated tests).
const BACKENDS: [&str; 7] = [
    "native",
    "native-brute",
    "native-tiled",
    "native-flat",
    "native-batch",
    "simulator",
    "simulator-gpu",
];

fn cfg(backend: &str, method: Method, perm_block: usize) -> RunConfig {
    RunConfig {
        data: DataSource::Synthetic { n_dims: N, n_groups: K },
        backend: backend.to_string(),
        method,
        n_perms: N_PERMS,
        seed: SEED,
        threads: 2,
        perm_block,
        ..Default::default()
    }
}

fn run(backend: &str, method: Method, perm_block: usize) -> AnalysisReport {
    let c = cfg(backend, method, perm_block);
    let (mat, grouping) = permanova_apu::coordinator::load_data_dense(&c).unwrap();
    execute(&c, &mat, &grouping).unwrap()
}

/// The f64 oracle F-distribution for the fixture, straight from the plan.
fn permanova_oracle() -> Vec<f64> {
    let c = cfg("native-brute", Method::Permanova, 0);
    let (mat, grouping) = permanova_apu::coordinator::load_data_dense(&c).unwrap();
    let s_t = st_of(&mat);
    let plan = PermutationPlan::new(grouping.labels().to_vec(), SEED, N_PERMS + 1);
    let mut row = vec![0u32; N];
    (0..N_PERMS + 1)
        .map(|i| {
            plan.fill(i, &mut row);
            let sw = sw_brute_f64_dense(mat.data(), N, &row, grouping.inv_sizes());
            fstat_from_sw(sw, s_t, N, K)
        })
        .collect()
}

#[test]
fn every_backend_matches_the_f64_oracle() {
    let want = permanova_oracle();
    let runs: Vec<(String, AnalysisReport)> = [
        ("native".to_string(), 0usize),
        ("native-brute".to_string(), 0),
        ("native-tiled".to_string(), 0),
        ("native-flat".to_string(), 0),
        ("native-batch".to_string(), 1),
        ("native-batch".to_string(), 8),
        ("native-batch".to_string(), 64),
        ("simulator".to_string(), 0),
        ("simulator-gpu".to_string(), 0),
    ]
    .into_iter()
    .map(|(name, block)| {
        let label = if block > 0 { format!("{name}/b{block}") } else { name.clone() };
        (label, run(&name, Method::Permanova, block))
    })
    .collect();

    for (label, r) in &runs {
        assert_eq!(r.f_perms.len(), N_PERMS, "{label}");
        let rel = (r.f_obs - want[0]).abs() / want[0].abs().max(1e-12);
        assert!(rel < 5e-4, "{label}: f_obs {} vs oracle {}", r.f_obs, want[0]);
        for (i, (got, oracle_f)) in r.f_perms.iter().zip(&want[1..]).enumerate() {
            let rel = (got - oracle_f).abs() / oracle_f.abs().max(1e-12);
            assert!(rel < 5e-4, "{label} perm {i}: {got} vs {oracle_f}");
        }
    }

    // Identical permutation stream + well-separated statistics => every
    // backend lands on the identical p-value.
    let (label0, r0) = &runs[0];
    for (label, r) in &runs[1..] {
        assert_eq!(r.p_value, r0.p_value, "{label} vs {label0}");
    }
}

#[test]
fn anosim_matches_its_legacy_oracle_on_every_backend() {
    let c = cfg("native", Method::Anosim, 0);
    let (mat, grouping) = permanova_apu::coordinator::load_data_dense(&c).unwrap();
    let oracle = anosim(&mat, &grouping, N_PERMS, SEED).unwrap();
    for backend in BACKENDS {
        for block in [0usize, 1, 8, 64] {
            if block > 0 && backend != "native-batch" {
                continue;
            }
            let r = run(backend, Method::Anosim, block);
            let label = format!("{backend}/b{block}");
            assert_eq!(r.method, Method::Anosim, "{label}");
            // Same f64 statistic implementation end to end: exact equality.
            assert_eq!(r.f_obs, oracle.r_obs, "{label}");
            assert_eq!(r.p_value, oracle.p_value, "{label}");
            assert!((-1.0..=1.0).contains(&r.f_obs), "{label}: R = {}", r.f_obs);
        }
    }
}

#[test]
fn permdisp_matches_its_legacy_oracle_on_every_backend() {
    let c = cfg("native", Method::Permdisp, 0);
    let (mat, grouping) = permanova_apu::coordinator::load_data_dense(&c).unwrap();
    let oracle = permdisp(&mat, &grouping, N_PERMS, SEED).unwrap();
    for backend in BACKENDS {
        for block in [0usize, 1, 8, 64] {
            if block > 0 && backend != "native-batch" {
                continue;
            }
            let r = run(backend, Method::Permdisp, block);
            let label = format!("{backend}/b{block}");
            assert_eq!(r.f_obs, oracle.f_obs, "{label}");
            assert_eq!(r.p_value, oracle.p_value, "{label}");
            assert_eq!(r.group_dispersions, oracle.group_dispersions, "{label}");
        }
    }
}

#[test]
fn pairwise_matches_its_legacy_oracle_on_every_backend_kernel_modulo() {
    let c = cfg("native-brute", Method::PairwisePermanova, 0);
    let (mat, grouping) = permanova_apu::coordinator::load_data_dense(&c).unwrap();
    // The legacy sweep runs the f32 brute kernel per pair — the same f32
    // op sequence `native-brute` executes, so agreement is exact.
    let oracle = pairwise_permanova(
        &mat,
        &grouping,
        N_PERMS,
        &PermanovaOpts { algo: SwAlgorithm::Brute, seed: SEED, threads: 2, keep_f_perms: false },
    )
    .unwrap();
    let r = run("native-brute", Method::PairwisePermanova, 0);
    assert_eq!(r.runs.len(), oracle.entries.len());
    assert_eq!(r.pairs.len(), oracle.n_comparisons);
    for ((pair, run), want) in r.pairs.iter().zip(&r.runs).zip(&oracle.entries) {
        let label = format!("pair ({}, {})", pair.group_a, pair.group_b);
        assert_eq!((pair.group_a, pair.group_b), (want.group_a, want.group_b), "{label}");
        assert_eq!(pair.n, want.n, "{label}");
        assert_eq!(run.f_obs.to_bits(), want.f_obs.to_bits(), "{label}");
        assert_eq!(run.p_value, want.p_value, "{label}");
        assert_eq!(pair.p_adjusted, want.p_adjusted, "{label}");
    }
    // Every backend agrees with the oracle on the per-pair p-values (the
    // f32 kernels differ only in reduction order, far below the separation
    // between distinct F values in the null distribution).
    for backend in BACKENDS {
        let r = run(backend, Method::PairwisePermanova, 0);
        for (run, want) in r.runs.iter().zip(&oracle.entries) {
            assert_eq!(run.p_value, want.p_value, "{backend}");
        }
        for (pair, want) in r.pairs.iter().zip(&oracle.entries) {
            assert_eq!(pair.p_adjusted, want.p_adjusted, "{backend}");
        }
    }
}

#[test]
fn exact_oracle_agreement_survives_scheduling_knobs() {
    // The acceptance contract: per-method p-values (and the f64 statistics
    // themselves) agree with the oracle across shard / worker / SMT /
    // block settings.
    let c = cfg("native-batch", Method::Anosim, 0);
    let (mat, grouping) = permanova_apu::coordinator::load_data_dense(&c).unwrap();
    let a_oracle = anosim(&mat, &grouping, N_PERMS, SEED).unwrap();
    let d_oracle = permdisp(&mat, &grouping, N_PERMS, SEED).unwrap();
    for (shard_size, threads, smt) in
        [(1usize, 1usize, false), (7, 3, true), (64, 2, false), (0, 0, true)]
    {
        for block in [1usize, 8, 64] {
            let mut ca = cfg("native-batch", Method::Anosim, block);
            ca.shard_size = shard_size;
            ca.threads = threads;
            ca.smt_oversubscribe = smt;
            let ra = execute(&ca, &mat, &grouping).unwrap();
            assert_eq!(
                ra.f_obs, a_oracle.r_obs,
                "anosim shard={shard_size} threads={threads} smt={smt} block={block}"
            );
            assert_eq!(ra.p_value, a_oracle.p_value);

            let mut cd = cfg("native-batch", Method::Permdisp, block);
            cd.shard_size = shard_size;
            cd.threads = threads;
            cd.smt_oversubscribe = smt;
            let rd = execute(&cd, &mat, &grouping).unwrap();
            assert_eq!(rd.f_obs, d_oracle.f_obs);
            assert_eq!(rd.p_value, d_oracle.p_value);
        }
    }
}

#[test]
fn native_batch_is_bitwise_identical_to_brute_at_all_block_sizes_per_method() {
    for method in [Method::Permanova, Method::Anosim, Method::Permdisp] {
        let brute = run("native-brute", method, 0);
        assert_eq!(brute.perm_block, 0);
        for block in [1usize, 8, 64] {
            let batch = run("native-batch", method, block);
            assert_eq!(batch.backend, "native-batch");
            assert_eq!(batch.perm_block, block, "report records the resolved block");
            assert_eq!(
                batch.f_obs.to_bits(),
                brute.f_obs.to_bits(),
                "{method:?} block={block}: f_obs {} vs {}",
                batch.f_obs,
                brute.f_obs
            );
            assert_eq!(batch.f_perms.len(), brute.f_perms.len());
            for (i, (b, s)) in batch.f_perms.iter().zip(&brute.f_perms).enumerate() {
                assert_eq!(
                    b.to_bits(),
                    s.to_bits(),
                    "{method:?} block={block} perm {i}: {b} vs {s}"
                );
            }
            assert_eq!(batch.p_value, brute.p_value);
        }
    }
}

#[test]
fn simulator_is_bitwise_identical_to_native_flat_per_method() {
    for method in [Method::Permanova, Method::Anosim, Method::Permdisp] {
        let flat = run("native-flat", method, 0);
        let sim = run("simulator", method, 0);
        assert_eq!(flat.f_obs.to_bits(), sim.f_obs.to_bits(), "{method:?}");
        for (a, b) in flat.f_perms.iter().zip(&sim.f_perms) {
            assert_eq!(a.to_bits(), b.to_bits(), "{method:?}");
        }
        // The simulator additionally reports modelled MI300A time, but
        // only for PERMANOVA — the model is calibrated for the f32 d²
        // stream; ANOSIM's f64 rank stream and PERMDISP's O(n) loop are
        // outside its regime and report none.
        let modelled: f64 = sim.per_device.iter().map(|d| d.simulated_secs).sum();
        if method == Method::Permanova {
            assert!(modelled > 0.0, "{method:?}");
        } else {
            assert_eq!(modelled, 0.0, "{method:?}");
        }
    }
}
