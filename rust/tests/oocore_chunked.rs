//! Out-of-core conformance: a PERMANOVA run under a `max_resident_bytes`
//! budget — triangle spilled to a chunk file, kernels sweeping it
//! chunk-major — must be **bitwise identical** to the uncapped resident
//! run on every backend, across shard / SMT / permutation-block settings.
//!
//! This is a stronger claim than cross-backend agreement (backends differ
//! in f32 reduction order and agree only to tolerance): the chunked
//! drivers replay each backend's *own* operation sequence — per-lane
//! ascending row order with a carried accumulator — so capped ≡ uncapped
//! holds per algorithm, bit for bit, while the run pages `chunks_paged ≥
//! 1` windows through a residency that never exceeds the budget.

use std::sync::Arc;

use permanova_apu::config::{DataSource, RunConfig};
use permanova_apu::dmat::{
    file_backed_from, random_euclidean_condensed, read_pdm_storage, CondensedMatrix,
    DistanceMatrix,
};
use permanova_apu::permanova::{Method, SwAlgorithm};
use permanova_apu::report::AnalysisReport;
use permanova_apu::request::AnalysisRequest;
use permanova_apu::Error;

const N: usize = 56;
const K: usize = 4;
const N_PERMS: usize = 99;
const SEED: u64 = 0xBEEF;
/// Packed triangle: 56*55/2 * 4 = 6160 bytes; this budget forces several
/// paging cycles per sweep.
const BUDGET: u64 = 1000;

fn cfg(backend: &str, cap: u64) -> RunConfig {
    RunConfig {
        data: DataSource::Synthetic { n_dims: N, n_groups: K },
        backend: backend.to_string(),
        n_perms: N_PERMS,
        seed: SEED,
        threads: 2,
        max_resident_bytes: cap,
        ..Default::default()
    }
}

fn run(cfg: &RunConfig) -> AnalysisReport {
    AnalysisRequest::new(cfg).run().unwrap()
}

fn assert_bitwise(capped: &AnalysisReport, uncapped: &AnalysisReport, tag: &str) {
    assert_eq!(capped.f_obs.to_bits(), uncapped.f_obs.to_bits(), "{tag}: f_obs");
    assert_eq!(capped.p_value.to_bits(), uncapped.p_value.to_bits(), "{tag}: p_value");
    assert_eq!(capped.f_perms.len(), uncapped.f_perms.len(), "{tag}: perm count");
    for (i, (a, b)) in capped.f_perms.iter().zip(&uncapped.f_perms).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: f_perms[{i}]");
    }
}

/// The acceptance criterion: every backend, capped ≡ uncapped bitwise,
/// with the capped run visibly paging.
#[test]
fn capped_runs_are_bitwise_identical_per_backend() {
    for backend in
        ["native", "native-brute", "native-tiled", "native-flat", "native-batch", "simulator",
         "simulator-gpu"]
    {
        let uncapped = run(&cfg(backend, 0));
        let capped = run(&cfg(backend, BUDGET));
        assert_bitwise(&capped, &uncapped, backend);
        assert!(uncapped.oocore.is_none(), "{backend}: uncapped reports carry no oocore section");
        let oo = capped.oocore.as_ref().unwrap_or_else(|| panic!("{backend}: capped run must report paging"));
        assert_eq!(oo.resident_cap, BUDGET, "{backend}");
        assert!(oo.chunks_paged >= 1, "{backend}: paged {} chunks", oo.chunks_paged);
        assert!(oo.bytes_paged > 0, "{backend}");
    }
}

/// The budget interacts with every scheduler knob: shards, SMT
/// oversubscription, and the batched engine's block width must not break
/// the bitwise tie (each lane still sweeps rows in ascending order with a
/// carried accumulator).
#[test]
fn capped_runs_survive_scheduler_knobs() {
    for (threads, shard_size, smt) in [(1, 0, false), (3, 7, false), (2, 16, true)] {
        let mk = |cap: u64| RunConfig {
            threads,
            shard_size,
            smt_oversubscribe: smt,
            ..cfg("native-flat", cap)
        };
        let tag = format!("t{threads}/s{shard_size}/smt{smt}");
        assert_bitwise(&run(&mk(BUDGET)), &run(&mk(0)), &tag);
    }
    for perm_block in [1, 8, 64] {
        let mk = |cap: u64| RunConfig { perm_block, ..cfg("native-batch", cap) };
        let tag = format!("block{perm_block}");
        let capped = run(&mk(BUDGET));
        assert_bitwise(&capped, &run(&mk(0)), &tag);
        assert!(capped.oocore.as_ref().unwrap().chunks_paged >= 1, "{tag}");
    }
}

/// Explicit kernel algorithms: brute, flat, and tiled (whose chunk plan
/// must align to tile stripes) all hold the tie.
#[test]
fn capped_runs_hold_across_kernel_algorithms() {
    for algo in [SwAlgorithm::Brute, SwAlgorithm::Flat, SwAlgorithm::Tiled { tile: 8 }] {
        let mk = |cap: u64| RunConfig { algo, ..cfg("native", cap) };
        assert_bitwise(&run(&mk(BUDGET)), &run(&mk(0)), &format!("{algo:?}"));
    }
}

/// Ingest-spill round-trip: a PDM file streamed through the budgeted sink
/// spills to a chunk file whose replayed stream is bitwise the resident
/// triangle — the spill path changes residency, never values.
#[test]
fn ingest_spill_roundtrips_bitwise() {
    let dir = std::env::temp_dir().join("permanova_apu_oocore_ingest_test");
    std::fs::create_dir_all(&dir).unwrap();
    let mpath = dir.join("m.pdm");
    let mat = DistanceMatrix::random_euclidean(48, 6, 3);
    mat.write_binary(&mpath).unwrap();
    let oracle = CondensedMatrix::from_dense(&mat);

    let storage = read_pdm_storage(&mpath, 1e-4, 700).unwrap();
    let file = storage.as_file().expect("48*47/2*4 = 4512 bytes > 700 must spill");
    assert!(file.resident_bytes() <= 700 + file.n() * 8, "honest residency");
    let mut replayed = Vec::new();
    for (r0, r1) in file.chunk_plan(1) {
        replayed.extend_from_slice(file.load_chunk(r0, r1).unwrap().values());
    }
    assert_eq!(replayed, oracle.values(), "spilled stream ≡ from_dense oracle");
    assert!(file.chunks_paged() >= 2, "the replay actually paged");
}

/// A corrupted chunk file is rejected at load with a checksum error, not
/// silently analyzed.
#[test]
fn corrupt_chunk_files_fail_the_checksum() {
    let tri = random_euclidean_condensed(32, 4, 9);
    let storage = file_backed_from(&tri, 300).unwrap();
    let file = storage.as_file().unwrap();
    let path = file.path().to_path_buf();
    // Flip one byte in the value region (past the 20-byte header).
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[40] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();
    let err = (0..32)
        .zip(1..33)
        .find_map(|(r0, r1)| file.load_chunk(r0, r1).err())
        .expect("some chunk must fail its checksum");
    assert!(err.to_string().contains("checksum"), "{err}");
}

/// Methods that need the whole triangle resident fail loudly under a
/// budget, naming the knob — never a silent dense materialization.
#[test]
fn whole_triangle_methods_fail_loudly_under_budget() {
    for method in [Method::Anosim, Method::Permdisp, Method::PairwisePermanova] {
        let c = RunConfig { method, ..cfg("native", BUDGET) };
        match AnalysisRequest::new(&c).run() {
            Err(Error::Config(m)) => {
                assert!(m.contains("--max-resident-bytes"), "{method:?}: {m}");
            }
            Ok(_) => panic!("{method:?} must not run file-backed"),
            Err(e) => panic!("{method:?}: want Error::Config, got {e:?}"),
        }
        // The same method under no cap (or a roomy one) still runs.
        let roomy = RunConfig { method, max_resident_bytes: 1 << 20, ..cfg("native", 0) };
        AnalysisRequest::new(&roomy).run().unwrap();
    }
}

/// The capped report's JSON carries the oocore section; the uncapped
/// report's JSON is byte-identical to the pre-out-of-core schema (no new
/// key leaks into cap-free runs — the store's verbatim-replay contract).
#[test]
fn report_json_gains_oocore_only_when_capped() {
    let uncapped = run(&cfg("native-flat", 0)).to_json().to_string();
    assert!(!uncapped.contains("oocore"), "{uncapped}");
    let capped = run(&cfg("native-flat", BUDGET));
    let doc = capped.to_json();
    let oo = doc.get("oocore").expect("capped report JSON carries oocore");
    assert_eq!(oo.req_usize("resident_cap").unwrap() as u64, BUDGET);
    assert!(oo.req_usize("chunks_paged").unwrap() >= 1);
    let rendered = capped.render();
    assert!(rendered.contains("paging"), "{rendered}");
}

/// The scratch chunk file is removed when the storage drops — budgeted
/// runs leave nothing behind in the scratch directory.
#[test]
fn scratch_files_are_cleaned_up_on_drop() {
    let tri = random_euclidean_condensed(24, 4, 5);
    let storage = file_backed_from(&tri, 200).unwrap();
    let path = storage.as_file().unwrap().path().to_path_buf();
    assert!(path.exists());
    // Clone shares the same Arc'd file; dropping the last handle deletes.
    let clone = storage.clone();
    drop(storage);
    assert!(path.exists(), "file survives while a handle lives");
    drop(clone);
    assert!(!path.exists(), "last drop removes the scratch file");
}

/// Sub-range batches (what shards execute) line up with the full capped
/// sweep — paging is per-batch, results are position-independent.
#[test]
fn capped_equals_uncapped_through_the_cache_path() {
    use permanova_apu::service::DatasetCache;
    let cache = DatasetCache::new(4);
    let capped_cfg = cfg("native-flat", BUDGET);
    let (warm1, h1) =
        AnalysisRequest::new(&capped_cfg).via_cache(&cache).run_traced().unwrap();
    let (warm2, h2) =
        AnalysisRequest::new(&capped_cfg).via_cache(&cache).run_traced().unwrap();
    assert!(!h1 && h2, "second capped lookup hits the file-backed entry");
    assert_bitwise(&warm1, &warm2, "warm capped");
    assert_bitwise(&warm1, &run(&cfg("native-flat", 0)), "capped via cache vs uncapped cold");
    let paging = cache.oocore_paging();
    assert_eq!(paging.file_backed, 1);
    assert!(paging.chunks_paged >= 2, "both jobs paged through the shared handle");
}

/// `file_backed_from` itself: the spill helper's file replays the source
/// triangle bitwise (the oracle the kernel tests build on).
#[test]
fn file_backed_from_replays_bitwise() {
    let tri = random_euclidean_condensed(41, 5, 7);
    let storage = file_backed_from(&tri, 512).unwrap();
    let file = storage.as_file().unwrap();
    assert_eq!(file.n(), 41);
    assert_eq!(file.count(), 41 * 40 / 2);
    let mut replayed = Vec::new();
    for (r0, r1) in file.chunk_plan(1) {
        replayed.extend_from_slice(file.load_chunk(r0, r1).unwrap().values());
    }
    assert_eq!(replayed, tri.values());
    let _ = Arc::new(tri); // keep the resident copy alive past the replay
}
