//! Fault-injection campaign: every injection point fires at least once
//! and its containment holds — the store degrades loudly instead of
//! dying, scratch reads re-materialize once before surfacing, a
//! panicking job poisons only its own response, dropped connections are
//! survived by both the daemon and the retrying client, and every
//! response that succeeds under faults is **bitwise identical** to the
//! fault-free run.
//!
//! The fault plan is process-global (`inject::install`), so every test
//! serializes on a file-local mutex and clears the plan before
//! releasing it.  Job ids are namespaced per test so an `@id=` trigger
//! armed by one test can never match another test's jobs.

use std::io::Write as _;
use std::net::TcpStream;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use permanova_apu::config::{DataSource, RunConfig};
use permanova_apu::coordinator::load_storage;
use permanova_apu::dmat::{file_backed_from, random_euclidean_condensed};
use permanova_apu::inject::{self, FaultPlan};
use permanova_apu::jsonio::Json;
use permanova_apu::service::{
    client_exchange, client_exchange_retrying, envelope_v1, parse_jobs, run_jobs, Daemon,
    DaemonConfig, DatasetCache, RetryPolicy,
};
use permanova_apu::store::{ResultStore, StoreConfig, DEGRADE_AFTER};

/// Serializes tests that arm the process-global fault plan.  Poison is
/// tolerated (a failed test must not cascade) and any plan a panicking
/// test left armed is cleared on acquire.
static GUARD: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    let g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
    inject::clear();
    g
}

fn arm(spec: &str) {
    inject::install(FaultPlan::parse(spec).expect("valid fault spec"));
}

/// A fresh scratch directory under the system temp dir.
fn scratch(case: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("permanova_apu_fault_{case}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// `count` small analysis jobs in the v1 envelope, ids `<ns>-0..`.
fn job_lines(ns: &str, count: usize) -> Vec<String> {
    (0..count)
        .map(|i| {
            let payload = Json::obj(vec![
                ("method", Json::str("permanova")),
                ("backend", Json::str("native-flat")),
                ("n_perms", Json::num(19.0)),
                ("seed", Json::num((40 + i) as f64)),
                (
                    "data",
                    Json::obj(vec![
                        ("source", Json::str("synthetic")),
                        ("n_dims", Json::num(24.0)),
                        ("n_groups", Json::num(2.0)),
                        ("seed", Json::num(7.0)),
                    ]),
                ),
            ]);
            envelope_v1(Some(&format!("{ns}-{i}")), payload).to_string()
        })
        .collect()
}

/// Deterministic projection of a response for bitwise comparison
/// (drops timing fields; keeps ids, errors, and the full report).
fn comparable(response: &Json) -> String {
    let mut keep = Vec::new();
    for key in ["id", "ok", "dataset", "error", "report", "note"] {
        if let Some(v) = response.get(key) {
            keep.push((key, v.clone()));
        }
    }
    Json::obj(keep).to_string()
}

/// An out-of-core run config: 56 objects at a 1000-byte residency
/// budget forces the file-backed triangle (56·55/2 · 4 B = 6160 B).
fn oocore_cfg() -> RunConfig {
    RunConfig {
        data: DataSource::Synthetic { n_dims: 56, n_groups: 4 },
        max_resident_bytes: 1000,
        ..RunConfig::default()
    }
}

// ---------------------------------------------------------------------
// store.wal.write — degraded mode
// ---------------------------------------------------------------------

#[test]
fn wal_write_faults_latch_loud_read_only_degradation() {
    let _g = lock();
    let dir = scratch("wal_latch");
    let store = ResultStore::open(StoreConfig::new(dir)).unwrap();

    // First put succeeds, the next DEGRADE_AFTER consecutive puts hit an
    // injected WAL error (consults 2..=4) and latch the store.
    arm("store.wal.write:err@2,store.wal.write:err@3,store.wal.write:err@4");
    store.put("k1", b"v1").unwrap();
    for i in 0..DEGRADE_AFTER {
        let before_latch = i + 1 < DEGRADE_AFTER;
        let err = store.put(&format!("fail-{i}"), b"x").unwrap_err();
        assert!(
            err.to_string().contains("injected fault: store.wal.write:err"),
            "unexpected error: {err}"
        );
        assert_eq!(store.is_degraded(), !before_latch, "latch after exactly {DEGRADE_AFTER}");
    }

    // Degraded: puts become silent no-ops, gets keep serving what made
    // it in, and the latch never releases — even with the fault gone.
    store.put("k5", b"v5").unwrap();
    assert_eq!(store.get("k5"), None, "degraded puts must not write");
    assert_eq!(store.get("k1").as_deref(), Some(b"v1".as_slice()));
    inject::clear();
    store.put("k6", b"v6").unwrap();
    assert!(store.is_degraded(), "degradation is latched until restart");
    assert_eq!(store.get("k6"), None);

    let stats = store.stats();
    assert_eq!(stats.put_errors, DEGRADE_AFTER);
    assert!(stats.degraded);
}

#[test]
fn store_degrades_but_analyses_stay_bitwise_identical() {
    let _g = lock();
    let jobs_text = job_lines("wal", 4).join("\n");
    let jobs = parse_jobs(&jobs_text).unwrap();

    // Fault-free, store-free reference.
    let baseline = run_jobs(&jobs, &DatasetCache::new(4), 2);
    assert!(baseline.responses.iter().all(|r| r.opt_bool("ok").unwrap() == Some(true)));

    // Every WAL append fails: the store degrades after DEGRADE_AFTER
    // puts, but the analyses themselves never notice.
    let dir = scratch("wal_bitwise");
    let store = Arc::new(ResultStore::open(StoreConfig::new(dir)).unwrap());
    arm("store.wal.write:err@p=1/7");
    let cache = DatasetCache::with_store(4, Arc::clone(&store));
    let under_fault = run_jobs(&jobs, &cache, 2);
    inject::clear();

    assert!(store.is_degraded(), "persistent WAL failure must latch degraded mode");
    assert!(store.stats().put_errors >= DEGRADE_AFTER);
    for (a, b) in baseline.responses.iter().zip(&under_fault.responses) {
        assert_eq!(comparable(a), comparable(b), "responses must not change under store faults");
    }
}

// ---------------------------------------------------------------------
// store.sst.write — contained flush
// ---------------------------------------------------------------------

#[test]
fn sstable_write_fault_contains_the_flush_and_the_next_drain_succeeds() {
    let _g = lock();
    let dir = scratch("sst_flush");
    let store = ResultStore::open(StoreConfig::new(dir)).unwrap();
    store.put("a", b"1").unwrap();
    store.put("b", b"2").unwrap();

    // The first SSTable write fails: drain errors, but the memtable
    // entries are WAL-durable and reinserted, so gets keep serving and
    // a later drain (fault exhausted — @1 fires once) lands them.
    arm("store.sst.write:err@1");
    let err = store.drain().unwrap_err();
    assert!(
        err.to_string().contains("injected fault: store.sst.write:err"),
        "unexpected error: {err}"
    );
    assert_eq!(store.get("a").as_deref(), Some(b"1".as_slice()));
    assert_eq!(store.get("b").as_deref(), Some(b"2".as_slice()));

    store.drain().unwrap();
    assert_eq!(store.get("a").as_deref(), Some(b"1".as_slice()));
    inject::clear();
}

// ---------------------------------------------------------------------
// scratch.read — one re-materialization, bitwise identical values
// ---------------------------------------------------------------------

#[test]
fn scratch_corruption_rematerializes_once_and_values_stay_bitwise() {
    let _g = lock();
    let cfg = oocore_cfg();
    let (storage, _grouping) = load_storage(&cfg).unwrap();
    let ft = storage.as_file().expect("budget forces the file-backed triangle");
    let (r0, r1) = ft.chunk_plan(1)[0];
    let clean: Vec<u32> =
        ft.load_chunk(r0, r1).unwrap().values().iter().map(|v| v.to_bits()).collect();
    assert_eq!(ft.rebuilds(), 0);

    // One injected checksum mismatch: load_chunk re-materializes the
    // spill file from the run config and retries — same bits, no error.
    arm("scratch.read:corrupt@1");
    let recovered: Vec<u32> =
        ft.load_chunk(r0, r1).unwrap().values().iter().map(|v| v.to_bits()).collect();
    inject::clear();

    assert_eq!(ft.rebuilds(), 1, "exactly one re-materialization");
    assert_eq!(clean, recovered, "recovered chunk must be bitwise identical");
}

#[test]
fn scratch_read_double_failure_names_both_attempts() {
    let _g = lock();

    // Hook installed (coordinator path) but the disk never recovers:
    // the rebuild's own reads fail too, and the surfaced error says so.
    let cfg = oocore_cfg();
    let (storage, _grouping) = load_storage(&cfg).unwrap();
    let ft = storage.as_file().unwrap();
    let (r0, r1) = ft.chunk_plan(1)[0];
    arm("scratch.read:err@p=1/3");
    let err = ft.load_chunk(r0, r1).unwrap_err().to_string();
    inject::clear();
    assert!(
        err.contains("re-materialization from the source failed too"),
        "error must say the rebuild was attempted: {err}"
    );
    assert!(err.contains("injected fault: scratch.read:err"), "error must name the cause: {err}");

    // No hook (raw file_backed_from): the first error passes through
    // untouched — no rebuild is claimed that never happened.
    let tri = random_euclidean_condensed(24, 8, 5);
    let storage = file_backed_from(&tri, 500).unwrap();
    let ft = storage.as_file().unwrap();
    let (r0, r1) = ft.chunk_plan(1)[0];
    arm("scratch.read:err@1");
    let err = ft.load_chunk(r0, r1).unwrap_err().to_string();
    inject::clear();
    assert!(err.contains("injected fault: scratch.read:err"), "unexpected error: {err}");
    assert!(!err.contains("re-materializ"), "hookless reads must not claim a rebuild: {err}");
    assert_eq!(ft.rebuilds(), 0);
}

// ---------------------------------------------------------------------
// job.exec — panic containment, batch ≡ daemon
// ---------------------------------------------------------------------

#[test]
fn panicking_job_is_contained_and_daemon_matches_batch_bitwise() {
    let _g = lock();
    let lines = job_lines("panic", 3);
    let jobs = parse_jobs(&lines.join("\n")).unwrap();

    // `@id=` fires on every consult with that id, so the same plan
    // covers the batch run and the daemon run below.
    arm("job.exec:panic@id=panic-1");
    let batch = run_jobs(&jobs, &DatasetCache::new(4), 2);
    assert_eq!(batch.summary.failed, 1);
    let poisoned = &batch.responses[1];
    assert_eq!(poisoned.opt_bool("ok").unwrap(), Some(false));
    let err = poisoned.req_str("error").unwrap();
    assert!(err.contains("job panicked"), "panic must be named: {err}");
    assert!(err.contains("injected fault: job.exec:panic"), "cause must survive: {err}");
    for i in [0usize, 2] {
        assert_eq!(batch.responses[i].opt_bool("ok").unwrap(), Some(true), "job {i} unharmed");
    }

    // The daemon survives the same panic and answers identically.
    let daemon =
        Daemon::spawn(DaemonConfig { workers: 1, ..DaemonConfig::default() }).unwrap();
    let addr = daemon.addr();
    let responses = client_exchange(&addr, &lines).unwrap();
    daemon.shutdown();
    let summary = daemon.join().unwrap();
    inject::clear();

    assert_eq!(summary.admitted, 3);
    assert_eq!(summary.completed, 2);
    assert_eq!(summary.failed, 1);
    for (b, d) in batch.responses.iter().zip(&responses) {
        assert_eq!(comparable(b), comparable(d), "daemon must match the batch bitwise");
    }
}

// ---------------------------------------------------------------------
// wire.accept — dropped connections, retrying client
// ---------------------------------------------------------------------

#[test]
fn dropped_accept_is_survived_and_the_retrying_client_recovers() {
    let _g = lock();
    arm("wire.accept:drop@1");
    let daemon =
        Daemon::spawn(DaemonConfig { workers: 1, ..DaemonConfig::default() }).unwrap();
    let addr = daemon.addr();

    // The first connection is dropped at accept; the client sees the
    // socket close after 0 responses, backs off, reconnects, and the
    // second attempt answers everything.
    let lines = job_lines("drop", 2);
    let policy = RetryPolicy { retries: 3, budget_ms: 30_000 };
    let responses = client_exchange_retrying(&addr, &lines, policy).unwrap();
    assert_eq!(responses.len(), 2);
    assert!(responses.iter().all(|r| r.opt_bool("ok").unwrap() == Some(true)));

    daemon.shutdown();
    let summary = daemon.join().unwrap();
    inject::clear();
    assert_eq!(summary.connections, 1, "a dropped accept must not count as a connection");
    assert_eq!(summary.admitted, 2);
    assert_eq!(summary.completed, 2);
}

// ---------------------------------------------------------------------
// connection hygiene — mid-pipeline drops and drains (satellite 4)
// ---------------------------------------------------------------------

/// One wire frame: `<len>\n<payload>\n`.
fn frame(payload: &str) -> Vec<u8> {
    format!("{}\n{}\n", payload.len(), payload).into_bytes()
}

#[test]
fn mid_pipeline_connection_drop_is_reaped_and_counters_reconcile() {
    let _g = lock();
    let daemon =
        Daemon::spawn(DaemonConfig { workers: 1, ..DaemonConfig::default() }).unwrap();
    let addr = daemon.addr();

    // Two complete frames, then a frame that promises 999 bytes and
    // delivers 3 before the socket drops mid-pipeline.
    let lines = job_lines("midpipe", 2);
    {
        let mut s = TcpStream::connect(addr).unwrap();
        for line in &lines {
            s.write_all(&frame(line)).unwrap();
        }
        s.write_all(b"999\nabc").unwrap();
        s.flush().unwrap();
    } // dropped here

    // The daemon must keep serving: poll stats over fresh connections
    // until both admitted jobs finished and every past connection is
    // accounted for (the stats connection itself is the one live one).
    let stats_req =
        envelope_v1(Some("stats"), Json::obj(vec![("op", Json::str("stats"))])).to_string();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let got = client_exchange(&addr, &[stats_req.clone()]).unwrap();
        let s = got[0].get("stats").expect("stats body");
        let connections = s.req_usize("connections").unwrap();
        let closed = s.req_usize("connections_closed").unwrap();
        let reaped = s.req_usize("connections_reaped").unwrap();
        let done = s.req_usize("completed").unwrap() + s.req_usize("failed").unwrap();
        if done == 2 && connections == closed + reaped + 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "counters never reconciled: connections={connections} closed={closed} \
             reaped={reaped} done={done}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    daemon.shutdown();
    let summary = daemon.join().unwrap();
    assert_eq!(summary.admitted, 2, "frames read before the drop are admitted");
    assert_eq!(summary.completed + summary.failed, 2);
}

#[test]
fn drain_is_not_held_hostage_by_an_idle_connection() {
    let _g = lock();
    let daemon =
        Daemon::spawn(DaemonConfig { workers: 1, ..DaemonConfig::default() }).unwrap();
    let addr = daemon.addr();

    // An idle connection that never sends a byte must not stall the
    // drain: quiet connections are reaped as soon as draining starts.
    let idle = TcpStream::connect(addr).unwrap();
    std::thread::sleep(Duration::from_millis(300));

    let started = Instant::now();
    daemon.shutdown();
    let summary = daemon.join().unwrap();
    assert!(
        started.elapsed() < Duration::from_secs(5),
        "drain must not wait for idle connections ({:?})",
        started.elapsed()
    );
    assert_eq!(summary.connections, 1);
    assert_eq!(summary.admitted, 0);
    drop(idle);
}
