//! Ingestion conformance suite: every data source streams straight into
//! the packed triangle — no dense `n*n` staging copy — and the streamed
//! result is **bitwise identical** to the old dense-then-pack path.
//!
//! Three pillars:
//!
//! 1. **Equivalence** — TSV / `.pdm` / synthetic sources loaded through
//!    the streaming `load_data` equal `CondensedMatrix::from_dense` of
//!    the test-only dense oracle (`load_data_dense`), bit for bit, and
//!    a warm `DatasetCache` serves the very same packed buffer.
//! 2. **Malformed input** — asymmetry beyond `data_tol`, negative
//!    entries, NaN/inf, ragged rows, non-zero diagonals and empty files
//!    each fail loudly *before any job runs*, naming the file and the
//!    offending entry, on both the `run` and `serve --jobs` paths; a bad
//!    file in a batch must not poison later jobs.
//! 3. **Memory accounting** — a cached dataset's footprint is exactly
//!    the condensed buffer plus its row-offset table (nothing dense),
//!    LRU eviction order is unchanged, and the bench validator rejects
//!    any cell whose resident footprint includes dense bytes.

use std::path::PathBuf;
use std::time::Duration;

use permanova_apu::bench::{run_sweep, validate_bench_json, Bencher, SweepGrid};
use permanova_apu::config::{DataSource, RunConfig};
use permanova_apu::coordinator::{load_data, load_data_dense, run_config, run_config_cached};
use permanova_apu::dmat::{
    read_pdm_condensed, read_tsv_condensed, CondensedMatrix, DistanceMatrix,
};
use permanova_apu::error::Error;
use permanova_apu::jsonio::Json;
use permanova_apu::service::{parse_jobs, run_jobs, DatasetCache};

/// A fresh scratch directory per test (tests run in parallel).
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("permanova_apu_ingest_suite_{name}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write `n` alternating two-group labels next to a matrix file.
fn write_labels(dir: &PathBuf, name: &str, n: usize) -> String {
    let path = dir.join(name);
    let labels: Vec<String> = (0..n).map(|i| format!("g{}", i % 2)).collect();
    std::fs::write(&path, labels.join("\n")).unwrap();
    path.display().to_string()
}

fn file_cfg(data: DataSource) -> RunConfig {
    RunConfig { data, n_perms: 9, seed: 7, ..Default::default() }
}

fn bits(tri: &CondensedMatrix) -> Vec<u32> {
    tri.values().iter().map(|v| v.to_bits()).collect()
}

// -------------------------------------------------------------------------
// 1. Streamed ≡ dense-then-pack, bitwise
// -------------------------------------------------------------------------

/// TSV and `.pdm` files round-tripped through the streaming loader equal
/// `CondensedMatrix::from_dense` of the dense oracle loader, bit for bit.
#[test]
fn streamed_file_sources_equal_the_dense_oracle_bitwise() {
    let dir = scratch("equiv");
    for n in [3usize, 17, 64] {
        let mat = DistanceMatrix::random_euclidean(n, 6, 0xC0FFEE ^ n as u64);
        let tsv = dir.join(format!("m{n}.tsv"));
        let pdm = dir.join(format!("m{n}.pdm"));
        mat.write_tsv(&tsv, None).unwrap();
        mat.write_binary(&pdm).unwrap();
        let labels = write_labels(&dir, &format!("l{n}.txt"), n);

        for data in [
            DataSource::Tsv { path: tsv.display().to_string(), labels_path: labels.clone() },
            DataSource::Pdm { path: pdm.display().to_string(), labels_path: labels.clone() },
        ] {
            let cfg = file_cfg(data);
            let (streamed, grouping) = load_data(&cfg).unwrap();
            let (dense, dense_grouping) = load_data_dense(&cfg).unwrap();
            let oracle = CondensedMatrix::from_dense(&dense);
            assert_eq!(streamed.n(), n);
            assert_eq!(bits(&streamed), bits(&oracle), "n={n} {:?}", cfg.data);
            assert_eq!(grouping.labels(), dense_grouping.labels(), "n={n}");
        }
    }
}

/// The n = 2 edge (below PERMANOVA's n >= 3 floor, so `load_data`
/// rejects it): the raw streaming readers still match the oracle — the
/// packed layout has no small-n special case.
#[test]
fn n2_edge_matches_through_the_raw_readers() {
    let dir = scratch("n2");
    let mat = DistanceMatrix::random_euclidean(2, 4, 5);
    let tsv = dir.join("m2.tsv");
    let pdm = dir.join("m2.pdm");
    mat.write_tsv(&tsv, None).unwrap();
    mat.write_binary(&pdm).unwrap();
    let oracle = CondensedMatrix::from_dense(&mat);
    let (from_tsv, ids) = read_tsv_condensed(&tsv, 1e-6).unwrap();
    assert_eq!(ids.len(), 2);
    assert_eq!(bits(&from_tsv), bits(&oracle));
    let from_pdm = read_pdm_condensed(&pdm, 1e-6).unwrap();
    assert_eq!(bits(&from_pdm), bits(&oracle));

    // ... while the config path refuses to analyze it, loudly.
    let labels = write_labels(&dir, "l2.txt", 2);
    let cfg = file_cfg(DataSource::Tsv {
        path: tsv.display().to_string(),
        labels_path: labels,
    });
    let e = load_data(&cfg).unwrap_err();
    match e {
        Error::Config(m) => assert!(m.contains("at least 3 objects"), "{m}"),
        other => panic!("want Error::Config, got {other:?}"),
    }
}

/// Synthetic sources: the packed generator and the UniFrac pipeline equal
/// the dense loader bit for bit (the generator consumes the RNG in the
/// identical order; the UniFrac dense matrix is packed transiently).
#[test]
fn synthetic_sources_match_the_dense_loader_bitwise() {
    let synth = RunConfig {
        data: DataSource::Synthetic { n_dims: 33, n_groups: 3 },
        n_perms: 9,
        seed: 13,
        ..Default::default()
    };
    let unifrac = RunConfig {
        data: DataSource::SyntheticUnifrac { n_taxa: 64, n_samples: 24, n_groups: 3 },
        n_perms: 9,
        seed: 13,
        ..Default::default()
    };
    for cfg in [synth, unifrac] {
        let (streamed, grouping) = load_data(&cfg).unwrap();
        let (dense, dense_grouping) = load_data_dense(&cfg).unwrap();
        assert_eq!(
            bits(&streamed),
            bits(&CondensedMatrix::from_dense(&dense)),
            "{:?}",
            cfg.data
        );
        assert_eq!(grouping.labels(), dense_grouping.labels());
    }
}

/// Warm cache ≡ cold, for a file-sourced dataset: the cached packed
/// buffer is the same allocation across hits, and the analysis it serves
/// is bitwise identical to the cold single-shot path.
#[test]
fn warm_cache_serves_the_same_packed_triangle_bitwise() {
    let dir = scratch("warm");
    let n = 20usize;
    let mat = DistanceMatrix::random_euclidean(n, 5, 77);
    let tsv = dir.join("m.tsv");
    mat.write_tsv(&tsv, None).unwrap();
    let labels = write_labels(&dir, "l.txt", n);
    let cfg = file_cfg(DataSource::Tsv {
        path: tsv.display().to_string(),
        labels_path: labels,
    });

    let cache = DatasetCache::new(2);
    let (ds0, hit0) = cache.get_or_load(&cfg).unwrap();
    let (ds1, hit1) = cache.get_or_load(&cfg).unwrap();
    assert!(!hit0 && hit1);
    assert!(
        std::sync::Arc::ptr_eq(ds0.tri(), ds1.tri()),
        "a warm hit must serve the same packed allocation, not a reload"
    );
    let (oracle, _) = load_data(&cfg).unwrap();
    assert_eq!(bits(ds0.tri()), bits(&oracle));

    let cold = run_config(&cfg).unwrap();
    let (warm, hit) = run_config_cached(&cfg, &cache).unwrap();
    assert!(hit, "dataset is already resident");
    assert_eq!(cold.f_obs.to_bits(), warm.f_obs.to_bits());
    assert_eq!(cold.p_value, warm.p_value);
    assert_eq!(cold.f_perms.len(), warm.f_perms.len());
    for (a, b) in cold.f_perms.iter().zip(&warm.f_perms) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

// -------------------------------------------------------------------------
// 2. Malformed input: loud, early, file + entry named
// -------------------------------------------------------------------------

/// A 12-object matrix with one specific defect planted, written as TSV.
fn write_bad_tsv(dir: &PathBuf, name: &str, plant: impl FnOnce(&mut DistanceMatrix)) -> String {
    let mut mat = DistanceMatrix::random_euclidean(12, 4, 3);
    plant(&mut mat);
    let path = dir.join(name);
    mat.write_tsv(&path, None).unwrap();
    path.display().to_string()
}

/// Every malformed-matrix class fails `run` with [`Error::Config`] naming
/// the file and the offending entry — never a silent analysis.
#[test]
fn malformed_matrices_fail_the_run_path_naming_file_and_entry() {
    let dir = scratch("bad_run");
    let labels = write_labels(&dir, "l.txt", 12);

    let cases: Vec<(String, &str)> = vec![
        (
            // Asymmetric beyond data_tol: upper (0,1) nudged, mirror kept.
            write_bad_tsv(&dir, "asym.tsv", |m| m.data_mut()[1] += 0.25),
            "asymmetry at (0,1)",
        ),
        (
            write_bad_tsv(&dir, "neg.tsv", |m| m.set_sym(0, 2, -0.5)),
            "negative distance at (0,2)",
        ),
        (
            write_bad_tsv(&dir, "nan.tsv", |m| m.set_sym(0, 3, f32::NAN)),
            "non-finite distance at (0,3)",
        ),
        (
            write_bad_tsv(&dir, "inf.tsv", |m| m.set_sym(1, 4, f32::INFINITY)),
            "non-finite distance at (1,4)",
        ),
        (
            write_bad_tsv(&dir, "diag.tsv", |m| m.data_mut()[5 * 12 + 5] = 0.75),
            "diagonal entry (5,5)",
        ),
    ];
    for (path, want) in &cases {
        let cfg = file_cfg(DataSource::Tsv { path: path.clone(), labels_path: labels.clone() });
        match run_config(&cfg).unwrap_err() {
            Error::Config(m) => {
                assert!(m.contains(path.as_str()), "{want}: error must name the file: {m}");
                assert!(m.contains(want), "want {want:?} in {m}");
            }
            other => panic!("{want}: want Error::Config, got {other:?}"),
        }
    }

    // Ragged row and empty file: structural TSV defects, same loud path.
    let ragged = dir.join("ragged.tsv");
    std::fs::write(&ragged, "\ta\tb\tc\na\t0\t1\t2\nb\t1\t0\nc\t2\t1.5\t0\n").unwrap();
    let cfg = file_cfg(DataSource::Tsv {
        path: ragged.display().to_string(),
        labels_path: labels.clone(),
    });
    match run_config(&cfg).unwrap_err() {
        Error::Config(m) => {
            assert!(m.contains("ragged"), "{m}");
            assert!(m.contains("row 1"), "must name the offending row: {m}");
        }
        other => panic!("ragged: want Error::Config, got {other:?}"),
    }
    let empty = dir.join("empty.tsv");
    std::fs::write(&empty, "").unwrap();
    let cfg = file_cfg(DataSource::Tsv {
        path: empty.display().to_string(),
        labels_path: labels.clone(),
    });
    match run_config(&cfg).unwrap_err() {
        Error::Config(m) => assert!(m.contains("empty file"), "{m}"),
        other => panic!("empty: want Error::Config, got {other:?}"),
    }

    // The same defects through the binary reader: identical entry naming.
    let mut asym = DistanceMatrix::random_euclidean(12, 4, 3);
    asym.data_mut()[1] += 0.25;
    let pdm = dir.join("asym.pdm");
    asym.write_binary(&pdm).unwrap();
    let cfg = file_cfg(DataSource::Pdm {
        path: pdm.display().to_string(),
        labels_path: labels.clone(),
    });
    match run_config(&cfg).unwrap_err() {
        Error::Config(m) => {
            assert!(m.contains("asymmetry at (0,1)"), "{m}");
            assert!(m.contains("tol"), "must point at the tolerance knob: {m}");
        }
        other => panic!("pdm asym: want Error::Config, got {other:?}"),
    }
    // An empty .pdm is an IO-level truncation: still loud, still names
    // the file (the path rides on the io error itself).
    let empty_pdm = dir.join("empty.pdm");
    std::fs::write(&empty_pdm, "").unwrap();
    let cfg = file_cfg(DataSource::Pdm {
        path: empty_pdm.display().to_string(),
        labels_path: labels.clone(),
    });
    let e = run_config(&cfg).unwrap_err().to_string();
    assert!(e.contains("empty.pdm"), "{e}");

    // Asymmetry *within* the tolerance is not a defect: the same file
    // loads once the knob is raised — the error message's suggested fix
    // actually works.
    let mut cfg = file_cfg(DataSource::Tsv { path: cases[0].0.clone(), labels_path: labels });
    cfg.data_tol = 0.5;
    let report = run_config(&cfg).unwrap();
    assert_eq!(report.n, 12);
}

/// The `serve --jobs` path: a malformed matrix fails its own job with the
/// same file-and-entry-naming error, and does **not** poison the jobs
/// that follow it in the batch.
#[test]
fn bad_file_in_a_batch_fails_alone_and_names_the_entry() {
    let dir = scratch("bad_batch");
    let n = 12usize;
    let good_mat = DistanceMatrix::random_euclidean(n, 4, 9);
    let good = dir.join("good.tsv");
    good_mat.write_tsv(&good, None).unwrap();
    let bad = write_bad_tsv(&dir, "asym.tsv", |m| m.data_mut()[1] += 0.25);
    let labels = write_labels(&dir, "l.txt", n);

    let line = |id: &str, path: &str| {
        format!(
            r#"{{"id": "{id}", "n_perms": 9, "seed": 3, "data": {{"source": "tsv", "path": "{path}", "labels": "{labels}"}}}}"#
        )
    };
    let text = [
        line("good-before", &good.display().to_string()),
        line("bad", &bad),
        line("good-after", &good.display().to_string()),
    ]
    .join("\n");
    let jobs = parse_jobs(&text).unwrap();
    let cache = DatasetCache::new(4);
    let batch = run_jobs(&jobs, &cache, 2);

    assert_eq!(batch.summary.jobs, 3);
    assert_eq!(batch.summary.failed, 1, "only the malformed job fails");

    let ok = |r: &Json| matches!(r.get("ok"), Some(Json::Bool(true)));
    assert!(ok(&batch.responses[0]));
    assert!(!ok(&batch.responses[1]));
    assert!(ok(&batch.responses[2]), "a bad file must not poison later jobs");

    let err = batch.responses[1].get("error").and_then(|v| v.as_str()).unwrap();
    assert!(err.contains("asym.tsv"), "{err}");
    assert!(err.contains("asymmetry at (0,1)"), "{err}");

    // The good dataset was loaded once and reused across the bad job.
    let cache_tag = |r: &Json| r.get("cache").and_then(|v| v.as_str()).unwrap().to_string();
    assert_eq!(cache_tag(&batch.responses[0]), "miss");
    assert_eq!(cache_tag(&batch.responses[2]), "hit");

    // And the post-failure job's statistics equal its cold single shot.
    let cold = run_config(&jobs[2].cfg).unwrap().to_json();
    let report = batch.responses[2].get("report").unwrap();
    let f = |doc: &Json, key: &str| doc.get(key).and_then(|v| v.as_f64()).unwrap();
    assert_eq!(f(report, "f_obs").to_bits(), f(&cold, "f_obs").to_bits());
    assert_eq!(f(report, "p_value"), f(&cold, "p_value"));
}

// -------------------------------------------------------------------------
// 3. Memory accounting: packed-only residency
// -------------------------------------------------------------------------

/// A cached dataset's accounted footprint is exactly the condensed buffer
/// plus its row-offset table — and LRU eviction order is unchanged by the
/// dense-free load path.
#[test]
fn cache_accounts_packed_bytes_only_and_keeps_lru_order() {
    let dir = scratch("accounting");
    let mut cfgs = Vec::new();
    for n in [12usize, 16, 20] {
        let mat = DistanceMatrix::random_euclidean(n, 4, n as u64);
        let tsv = dir.join(format!("m{n}.tsv"));
        mat.write_tsv(&tsv, None).unwrap();
        let labels = write_labels(&dir, &format!("l{n}.txt"), n);
        cfgs.push(file_cfg(DataSource::Tsv {
            path: tsv.display().to_string(),
            labels_path: labels,
        }));
    }
    let packed_footprint = |n: usize| n * (n - 1) / 2 * 4 + (n + 1) * 8;

    let cache = DatasetCache::new(2);
    let (ds12, _) = cache.get_or_load(&cfgs[0]).unwrap();
    assert_eq!(ds12.nbytes(), packed_footprint(12), "condensed values + offsets, nothing dense");
    assert_eq!(ds12.nbytes(), ds12.tri().resident_bytes());
    cache.get_or_load(&cfgs[1]).unwrap();

    // Touch n=12 so n=16 becomes the LRU victim, then load n=20.
    let (_, hit) = cache.get_or_load(&cfgs[0]).unwrap();
    assert!(hit);
    cache.get_or_load(&cfgs[2]).unwrap();
    assert!(cache.contains(&cfgs[0]), "recently-touched dataset survives");
    assert!(!cache.contains(&cfgs[1]), "least-recently-used dataset is the victim");
    assert!(cache.contains(&cfgs[2]));

    // Total residency is exactly the two survivors' packed footprints.
    assert_eq!(cache.resident_bytes(), packed_footprint(12) + packed_footprint(20));
}

/// The bench validator is the CI tripwire: a cell whose resident
/// footprint quietly re-includes the dense bytes is rejected.
#[test]
fn bench_validator_rejects_dense_inclusive_footprints() {
    let grid = SweepGrid {
        backends: vec!["native-brute".into()],
        n_grid: vec![24],
        perm_grid: vec![9],
        n_groups: 2,
        bencher: Bencher {
            warmup: 0,
            min_reps: 1,
            max_reps: 1,
            max_time: Duration::from_secs(1),
        },
        quick: true,
        throughput_jobs: 2,
        latency_clients: vec![],
        ..Default::default()
    };
    let good = run_sweep(&grid).unwrap().json;
    validate_bench_json(&good).unwrap();

    let mut bad = good.clone();
    if let Json::Obj(m) = &mut bad {
        let mut entries = m.get("entries").unwrap().as_arr().unwrap().to_vec();
        if let Json::Obj(e) = &mut entries[0] {
            let resident = e.get("resident_bytes").and_then(Json::as_f64).unwrap();
            let dense = e.get("dense_bytes").and_then(Json::as_f64).unwrap();
            e.insert("resident_bytes".into(), Json::num(resident + dense));
        }
        m.insert("entries".into(), Json::Arr(entries));
    }
    let e = validate_bench_json(&bad).unwrap_err().to_string();
    assert!(e.contains("resident_bytes"), "{e}");
    assert!(e.contains("dense copy"), "the rejection should say why: {e}");
}
