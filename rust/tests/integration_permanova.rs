//! Integration: PERMANOVA statistics across kernels, threads and scales.
//!
//! These are the cross-module invariants a downstream user relies on —
//! property-test style (seeded sweeps; the offline crate set has no
//! proptest, so cases are enumerated deterministically).

use permanova_apu::dmat::{CondensedMatrix, DistanceMatrix};
use permanova_apu::permanova::{
    fstat_from_sw, permanova, st_of, sw_brute_f64, sw_of, sw_one, Grouping, PermanovaOpts,
    SwAlgorithm,
};
use permanova_apu::rng::{shuffle, PermutationPlan, Xoshiro256pp};

fn random_grouping(n: usize, k: usize, seed: u64) -> Grouping {
    let mut labels: Vec<u32> = (0..n).map(|i| (i % k) as u32).collect();
    let mut rng = Xoshiro256pp::new(seed);
    shuffle(&mut rng, &mut labels);
    Grouping::new(labels).unwrap()
}

/// Property: s_W + s_A == s_T for every algorithm, every labelling.
#[test]
fn decomposition_identity_sweep() {
    for seed in 0..12u64 {
        let n = 20 + (seed as usize * 13) % 90;
        let k = 2 + (seed as usize) % 5;
        let mat = DistanceMatrix::random_euclidean(n, 6, seed);
        let grouping = random_grouping(n, k, seed ^ 0xF00);
        let s_t = st_of(&mat);
        for algo in [
            SwAlgorithm::Brute,
            SwAlgorithm::Flat,
            SwAlgorithm::Tiled { tile: 7 },
            SwAlgorithm::Tiled { tile: 64 },
        ] {
            let s_w = sw_of(algo, &mat, &grouping) as f64;
            let f = fstat_from_sw(s_w, s_t, n, k);
            // Reconstruct s_A from F and check the decomposition closes.
            let s_a = f * (k as f64 - 1.0) * s_w / (n as f64 - k as f64);
            assert!(
                ((s_w + s_a) - s_t).abs() / s_t < 1e-4,
                "seed {seed} algo {algo:?}: {s_w} + {s_a} != {s_t}"
            );
        }
    }
}

/// Property: relabelling groups bijectively (and permuting inv_sizes to
/// match) leaves s_W unchanged.
#[test]
fn label_bijection_invariance_sweep() {
    for seed in 0..8u64 {
        let n = 30 + (seed as usize * 7) % 40;
        let k = 3 + (seed as usize) % 3;
        let mat = DistanceMatrix::random_euclidean(n, 5, seed);
        let grouping = random_grouping(n, k, seed);
        let tri = CondensedMatrix::from_dense(&mat);
        let base = sw_brute_f64(tri.view(), grouping.labels(), grouping.inv_sizes());

        // Build the relabelling perm: g -> (g + 1) % k.
        let relabel: Vec<u32> = grouping.labels().iter().map(|&g| (g + 1) % k as u32).collect();
        let mut inv_re = vec![0.0f32; k];
        for g in 0..k {
            inv_re[(g + 1) % k] = grouping.inv_sizes()[g];
        }
        let re = sw_brute_f64(tri.view(), &relabel, &inv_re);
        assert!((base - re).abs() / base < 1e-10, "seed {seed}");
    }
}

/// Property: consistently permuting objects (matrix rows+cols AND labels)
/// leaves the statistic unchanged — PERMANOVA is object-order blind.
#[test]
fn object_permutation_invariance_sweep() {
    for seed in 0..8u64 {
        let n = 24 + (seed as usize * 5) % 30;
        let k = 2 + (seed as usize) % 4;
        let mat = DistanceMatrix::random_euclidean(n, 4, seed);
        let grouping = random_grouping(n, k, seed);

        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Xoshiro256pp::new(seed ^ 0xBEEF);
        // Fisher-Yates over the order vector.
        for i in (1..n).rev() {
            let j = rng.gen_range((i + 1) as u32) as usize;
            order.swap(i, j);
        }
        let mut pm = DistanceMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                let d = mat.get(order[i], order[j]);
                pm.data_mut()[i * n + j] = d;
            }
        }
        let plabels: Vec<u32> = order.iter().map(|&o| grouping.labels()[o]).collect();

        let a = sw_brute_f64(
            CondensedMatrix::from_dense(&mat).view(),
            grouping.labels(),
            grouping.inv_sizes(),
        );
        let b = sw_brute_f64(
            CondensedMatrix::from_dense(&pm).view(),
            &plabels,
            grouping.inv_sizes(),
        );
        assert!((a - b).abs() / a < 1e-10, "seed {seed}");
    }
}

/// Property: all kernel formulations agree to f32 tolerance on odd shapes
/// (primes, tile-straddling sizes) and extreme tiles.
#[test]
fn kernel_agreement_odd_shapes() {
    for &n in &[5usize, 17, 63, 65, 127, 251] {
        let k = 2 + n % 3;
        let mat = DistanceMatrix::random_euclidean(n, 3, n as u64);
        let grouping = random_grouping(n, k, n as u64);
        let tri = CondensedMatrix::from_dense(&mat);
        let oracle = sw_brute_f64(tri.view(), grouping.labels(), grouping.inv_sizes());
        for algo in [
            SwAlgorithm::Brute,
            SwAlgorithm::Flat,
            SwAlgorithm::Tiled { tile: 1 },
            SwAlgorithm::Tiled { tile: n },
            SwAlgorithm::Tiled { tile: n + 1 },
            SwAlgorithm::Tiled { tile: 1 << 20 },
        ] {
            let got = sw_one(algo, tri.view(), grouping.labels(), grouping.inv_sizes()) as f64;
            assert!(
                (got - oracle).abs() / oracle.max(1e-12) < 1e-4,
                "n={n} {algo:?}: {got} vs {oracle}"
            );
        }
    }
}

/// skbio-pinned case: perfectly separated two-group data must give the
/// theoretical maximum significance p = 1/(P+1) and a huge F.
#[test]
fn separated_groups_extreme_statistics() {
    let n = 30;
    let mut mat = DistanceMatrix::zeros(n);
    for i in 0..n {
        for j in (i + 1)..n {
            let same = (i < n / 2) == (j < n / 2);
            mat.set_sym(i, j, if same { 0.01 } else { 1.0 });
        }
    }
    let labels: Vec<u32> = (0..n).map(|i| (i >= n / 2) as u32).collect();
    let grouping = Grouping::new(labels).unwrap();
    let res = permanova(&mat, &grouping, 999, &PermanovaOpts::default()).unwrap();
    assert!(res.f_obs > 1000.0, "F = {}", res.f_obs);
    assert!((res.p_value - 0.001).abs() < 1e-9, "p = {}", res.p_value);
}

/// Under the null (no structure), the p-value must be approximately
/// uniform: across many datasets its mean sits near 0.5.
#[test]
fn null_pvalues_roughly_uniform() {
    let mut ps = Vec::new();
    for seed in 0..20u64 {
        let n = 30;
        let mat = DistanceMatrix::random_euclidean(n, 10, seed * 31 + 5);
        let grouping = random_grouping(n, 3, seed * 17 + 1);
        let res = permanova(
            &mat,
            &grouping,
            199,
            &PermanovaOpts { seed: seed ^ 0xAB, ..Default::default() },
        )
        .unwrap();
        ps.push(res.p_value);
    }
    let mean = ps.iter().sum::<f64>() / ps.len() as f64;
    assert!(
        (0.3..0.7).contains(&mean),
        "null p-values not uniform-ish: mean {mean}, ps {ps:?}"
    );
    // And none of them can be "significant at 0.001" by luck with 199 perms.
    assert!(ps.iter().all(|&p| p >= 0.005), "{ps:?}");
}

/// Thread count and batch decomposition never change results (bitwise for
/// a fixed algorithm).
#[test]
fn threading_determinism_large() {
    let n = 150;
    let mat = DistanceMatrix::random_euclidean(n, 8, 2);
    let tri = CondensedMatrix::from_dense(&mat);
    let grouping = random_grouping(n, 5, 9);
    let plan = PermutationPlan::new(grouping.labels().to_vec(), 33, 301);
    let single = permanova_apu::permanova::sw_plan_range(
        &tri,
        &plan,
        0,
        301,
        grouping.inv_sizes(),
        SwAlgorithm::Tiled { tile: 32 },
        1,
    );
    for threads in [2, 4, 7] {
        let multi = permanova_apu::permanova::sw_plan_range(
            &tri,
            &plan,
            0,
            301,
            grouping.inv_sizes(),
            SwAlgorithm::Tiled { tile: 32 },
            threads,
        );
        assert_eq!(single, multi, "threads {threads}");
    }
}

/// Statistical power: planted effects of decreasing strength — stronger
/// effects must never be less significant.
#[test]
fn monotone_effect_size() {
    let n = 48;
    let k = 2;
    let mut last_f = f64::INFINITY;
    for (i, within) in [0.2f32, 0.5, 0.8].iter().enumerate() {
        let mat = DistanceMatrix::planted_blocks(n, k, *within, 1.0, 7 + i as u64);
        let grouping = Grouping::new((0..n).map(|i| (i % k) as u32).collect()).unwrap();
        let res = permanova(&mat, &grouping, 99, &PermanovaOpts::default()).unwrap();
        assert!(
            res.f_obs < last_f,
            "weaker effect (within={within}) should not raise F: {} vs {last_f}",
            res.f_obs
        );
        last_f = res.f_obs;
    }
}
