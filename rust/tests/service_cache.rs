//! Cache-correctness suite for the shared-dataset service layer.
//!
//! The service contract under test:
//!
//! 1. **Warm ≡ cold, bitwise** — a run served from the `DatasetCache`
//!    (reused matrix + reused `StatKernel` prelude, shared scheduler pool)
//!    produces bit-identical statistics to the cold single-shot path, for
//!    every method × backend;
//! 2. **LRU bounds memory** — residency never exceeds capacity, eviction
//!    is least-recently-used;
//! 3. **The identity permutation is counted exactly once** in the
//!    `(1 + ge) / (1 + N)` p-value denominator, on both the legacy oracle
//!    and engine paths (a regression guard: the cache refactor must not
//!    double-serve plan index 0).

use permanova_apu::backend::shard::with_shared_pool;
use permanova_apu::config::{DataSource, RunConfig};
use permanova_apu::coordinator::{load_data_dense, run_config, run_config_cached};
use permanova_apu::permanova::{permanova, Method, PermanovaOpts, SwAlgorithm};
use permanova_apu::service::{parse_jobs, run_jobs, DatasetCache};

/// Every backend that needs no external artifacts (xla is exercised by its
/// own artifact-gated suites).
const BACKENDS: [&str; 7] = [
    "native",
    "native-brute",
    "native-tiled",
    "native-flat",
    "native-batch",
    "simulator",
    "simulator-gpu",
];

fn cfg(backend: &str, method: Method) -> RunConfig {
    RunConfig {
        data: DataSource::Synthetic { n_dims: 36, n_groups: 3 },
        n_perms: 49,
        seed: 11,
        method,
        backend: backend.to_string(),
        ..Default::default()
    }
}

#[test]
fn warm_cache_is_bitwise_identical_to_cold_for_every_method_and_backend() {
    for backend in BACKENDS {
        for method in Method::ALL {
            let c = cfg(backend, method);
            let cold = run_config(&c).expect("cold run");
            let cache = DatasetCache::new(4);
            let (first, hit0) = run_config_cached(&c, &cache).expect("first cached run");
            assert!(!hit0, "{backend}/{method:?}: first lookup must load");
            let (warm, hit1) = run_config_cached(&c, &cache).expect("warm run");
            assert!(hit1, "{backend}/{method:?}: second lookup must hit");
            for candidate in [&first, &warm] {
                assert_eq!(cold.runs.len(), candidate.runs.len(), "{backend}/{method:?}");
                for (a, b) in cold.runs.iter().zip(&candidate.runs) {
                    assert_eq!(
                        a.f_obs.to_bits(),
                        b.f_obs.to_bits(),
                        "{backend}/{method:?}: f_obs differs"
                    );
                    assert_eq!(a.p_value, b.p_value, "{backend}/{method:?}");
                    assert_eq!(a.s_t.to_bits(), b.s_t.to_bits(), "{backend}/{method:?}");
                    assert_eq!(a.f_perms.len(), b.f_perms.len());
                    for (i, (x, y)) in a.f_perms.iter().zip(&b.f_perms).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "{backend}/{method:?}: perm {i} differs"
                        );
                    }
                }
                assert_eq!(cold.group_dispersions, candidate.group_dispersions);
                for (p, q) in cold.pairs.iter().zip(&candidate.pairs) {
                    assert_eq!(p.p_adjusted, q.p_adjusted, "{backend}/{method:?}");
                }
            }
        }
    }
}

#[test]
fn shared_pool_execution_is_bitwise_identical_too() {
    // The "one pool per batch" scheduler must not perturb results either:
    // the same cached run inside and outside a shared pool, multi-threaded.
    let mut c = cfg("native-batch", Method::Anosim);
    c.threads = 3;
    c.shard_size = 7;
    let cold = run_config(&c).unwrap();
    let cache = DatasetCache::new(2);
    let pooled = with_shared_pool(3, |pool| {
        let r = run_config_cached(&c, &cache).unwrap().0;
        assert!(pool.jobs_dispatched() > 0, "the sharded loop must route via the pool");
        r
    });
    assert_eq!(cold.f_obs.to_bits(), pooled.f_obs.to_bits());
    assert_eq!(cold.p_value, pooled.p_value);
    for (x, y) in cold.f_perms.iter().zip(&pooled.f_perms) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

#[test]
fn lru_eviction_bounds_memory_across_runs() {
    let cache = DatasetCache::new(2);
    let sizes = [30usize, 36, 42];
    let mut per_dataset_bytes = Vec::new();
    for n in sizes {
        let mut c = cfg("native-brute", Method::Permanova);
        c.data = DataSource::Synthetic { n_dims: n, n_groups: 3 };
        let (r, hit) = run_config_cached(&c, &cache).unwrap();
        assert!(!hit);
        assert_eq!(r.n, n);
        // Dense-free ingestion: each cached dataset holds only the packed
        // triangle (values + row-offset table), never the n² copy.
        per_dataset_bytes.push(n * (n - 1) / 2 * 4 + (n + 1) * 8);
        assert!(cache.len() <= 2, "capacity is a hard residency bound");
    }
    // The oldest dataset (n=30) was evicted; the two recent ones remain.
    let mut c30 = cfg("native-brute", Method::Permanova);
    c30.data = DataSource::Synthetic { n_dims: 30, n_groups: 3 };
    assert!(!cache.contains(&c30), "LRU victim evicted");
    let mut c42 = cfg("native-brute", Method::Permanova);
    c42.data = DataSource::Synthetic { n_dims: 42, n_groups: 3 };
    assert!(cache.contains(&c42));
    // Resident bytes stay below the sum of all three datasets — and are
    // *exactly* the packed residency of the two survivors (n=36, n=42):
    // any dense copy sneaking back into the footprint breaks the equality.
    let total: usize = per_dataset_bytes.iter().sum();
    assert!(
        cache.resident_bytes() < total,
        "resident {} vs unbounded {total}",
        cache.resident_bytes()
    );
    assert_eq!(
        cache.resident_bytes(),
        per_dataset_bytes[1] + per_dataset_bytes[2],
        "packed-only residency of the surviving datasets"
    );
    let stats = cache.stats();
    assert_eq!((stats.misses, stats.entries), (3, 2));
}

#[test]
fn identity_permutation_counted_exactly_once_in_the_denominator() {
    let n_perms = 99usize;
    let c = RunConfig {
        data: DataSource::Synthetic { n_dims: 30, n_groups: 3 },
        n_perms,
        seed: 23,
        ..Default::default()
    };

    // Engine path (cold).
    let engine = run_config(&c).unwrap();
    assert_eq!(
        engine.f_perms.len(),
        n_perms,
        "the observed labelling (plan index 0) must not sit in f_perms"
    );
    let ge = engine.f_perms.iter().filter(|&&f| f >= engine.f_obs).count();
    let expect = (1.0 + ge as f64) / (1.0 + n_perms as f64);
    assert_eq!(engine.p_value, expect, "(1+ge)/(1+N) with the identity counted once");
    // A p-value of exactly 1/(1+N) is reachable only when no permutation
    // ties or beats the observed — the identity contributes the single +1.
    assert!(engine.p_value >= 1.0 / (1.0 + n_perms as f64));

    // Legacy oracle path (dense loader: the free function wants n×n).
    let (mat, grouping) = load_data_dense(&c).unwrap();
    let legacy = permanova(
        &mat,
        &grouping,
        n_perms,
        &PermanovaOpts { algo: SwAlgorithm::Brute, seed: 23, threads: 1, keep_f_perms: true },
    )
    .unwrap();
    let lp = legacy.f_perms.as_ref().unwrap();
    assert_eq!(lp.len(), n_perms);
    let lge = lp.iter().filter(|&&f| f >= legacy.f_obs).count();
    assert_eq!(legacy.p_value, (1.0 + lge as f64) / (1.0 + n_perms as f64));
    assert_eq!(legacy.p_value, engine.p_value, "both paths agree on the same plan");

    // Warm service path: identical denominator behaviour.
    let cache = DatasetCache::new(2);
    run_config_cached(&c, &cache).unwrap();
    let (warm, hit) = run_config_cached(&c, &cache).unwrap();
    assert!(hit);
    assert_eq!(warm.f_perms.len(), n_perms);
    assert_eq!(warm.p_value, engine.p_value);
}

#[test]
fn serve_batch_matches_cold_single_shots_bitwise() {
    // A heterogeneous JSONL batch (methods × backends over one dataset):
    // every response's statistics must equal the cold run of the same job.
    let text = r#"
        {"id": "f", "n_perms": 29, "seed": 5, "data": {"source": "synthetic", "n_dims": 30, "n_groups": 3, "seed": 9}}
        {"id": "r", "method": "anosim", "backend": "native-batch", "n_perms": 29, "seed": 6, "data": {"source": "synthetic", "n_dims": 30, "n_groups": 3, "seed": 9}}
        {"id": "d", "method": "permdisp", "backend": "native-flat", "n_perms": 29, "seed": 7, "data": {"source": "synthetic", "n_dims": 30, "n_groups": 3, "seed": 9}}
        {"id": "p", "method": "pairwise", "n_perms": 29, "seed": 8, "data": {"source": "synthetic", "n_dims": 30, "n_groups": 3, "seed": 9}}
    "#;
    let jobs = parse_jobs(text).unwrap();
    let cache = DatasetCache::new(4);
    let batch = run_jobs(&jobs, &cache, 2);
    assert_eq!(batch.summary.failed, 0);
    assert_eq!(batch.summary.cache.misses, 1, "one dataset, loaded once");
    assert_eq!(batch.summary.cache.hits, 3);
    for (job, resp) in jobs.iter().zip(&batch.responses) {
        let cold = run_config(&job.cfg).unwrap();
        let report = resp.get("report").expect("ok response carries a report");
        // Compare through the serialized form: same f_obs/p_value fields.
        let cold_json = cold.to_json();
        if job.cfg.method == Method::PairwisePermanova {
            assert_eq!(
                report.req_arr("pairs").unwrap().len(),
                cold_json.req_arr("pairs").unwrap().len()
            );
        } else {
            let f = |doc: &permanova_apu::jsonio::Json, key: &str| {
                doc.get(key).and_then(|v| v.as_f64()).unwrap()
            };
            assert_eq!(
                f(report, "f_obs").to_bits(),
                f(&cold_json, "f_obs").to_bits(),
                "{}",
                job.id
            );
            assert_eq!(f(report, "p_value"), f(&cold_json, "p_value"), "{}", job.id);
        }
    }
}
