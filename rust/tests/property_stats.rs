//! Property tests for the statistics core.
//!
//! The offline crate set has no proptest/quickcheck, so properties are
//! checked over seeded random case families generated with the crate's own
//! RNG — deterministic, but broad enough to catch structural mistakes:
//!
//! * pseudo-F is invariant under a whole-matrix row/column permutation
//!   applied together with the matching label permutation — and so are
//!   ANOSIM's R (the pair-rank multiset is permutation-invariant) and
//!   PERMDISP's F (distances-to-centroid are coordinate-free);
//! * ANOSIM's R always lies in `[-1, 1]` and permutation p-values always
//!   lie in `(0, 1]`, for every method through every backend;
//! * degenerate groupings are rejected, and the near-degenerate
//!   perfectly-separated case yields exactly the F the f64 oracle predicts.

use permanova_apu::backend::execute;
use permanova_apu::config::{DataSource, RunConfig};
use permanova_apu::dmat::DistanceMatrix;
use permanova_apu::permanova::{
    anosim, fstat_from_sw, permanova, permdisp, pvalue, st_of, sw_brute_f64_dense, Grouping, Method,
    PermanovaOpts, SwAlgorithm,
};
use permanova_apu::rng::{shuffle, Xoshiro256pp};

/// Apply object permutation `sigma` to matrix and labels together:
/// object `i` of the permuted problem is object `sigma[i]` of the original.
fn permuted(mat: &DistanceMatrix, labels: &[u32], sigma: &[usize]) -> (DistanceMatrix, Vec<u32>) {
    let n = mat.n();
    let mut out = DistanceMatrix::zeros(n);
    for i in 0..n {
        for j in 0..n {
            let v = mat.get(sigma[i], sigma[j]);
            out.data_mut()[i * n + j] = v;
        }
    }
    let l = sigma.iter().map(|&s| labels[s]).collect();
    (out, l)
}

fn oracle_f(mat: &DistanceMatrix, labels: &[u32], inv: &[f32], k: usize) -> f64 {
    let n = mat.n();
    let sw = sw_brute_f64_dense(mat.data(), n, labels, inv);
    fstat_from_sw(sw, st_of(mat), n, k)
}

#[test]
fn pseudo_f_is_invariant_under_joint_relabelling() {
    for (n, k, seed) in [(20usize, 2usize, 1u64), (33, 3, 2), (48, 4, 3), (61, 5, 4)] {
        let mat = DistanceMatrix::random_euclidean(n, 6, seed);
        let grouping = Grouping::balanced(n, k).unwrap();
        let f_base = oracle_f(&mat, grouping.labels(), grouping.inv_sizes(), k);

        let mut rng = Xoshiro256pp::new(seed ^ 0xFACE);
        for round in 0..5 {
            let mut sigma: Vec<usize> = (0..n).collect();
            shuffle(&mut rng, &mut sigma);
            let (pm, pl) = permuted(&mat, grouping.labels(), &sigma);
            let f_perm = oracle_f(&pm, &pl, grouping.inv_sizes(), k);
            // The sums are re-associated under the permutation, so the f64
            // values match to accumulation tolerance, not bitwise.
            let rel = (f_perm - f_base).abs() / f_base.abs().max(1e-12);
            assert!(
                rel < 1e-9,
                "n={n} k={k} round={round}: F {f_perm} vs {f_base} (rel {rel})"
            );
        }
    }
}

#[test]
fn anosim_r_is_invariant_under_joint_relabelling() {
    for (n, k, seed) in [(20usize, 2usize, 1u64), (33, 3, 2), (48, 4, 3)] {
        let mat = DistanceMatrix::random_euclidean(n, 6, seed);
        let grouping = Grouping::balanced(n, k).unwrap();
        let base = anosim(&mat, &grouping, 9, 1).unwrap().r_obs;

        let mut rng = Xoshiro256pp::new(seed ^ 0xFACE);
        for round in 0..4 {
            let mut sigma: Vec<usize> = (0..n).collect();
            shuffle(&mut rng, &mut sigma);
            let (pm, pl) = permuted(&mat, grouping.labels(), &sigma);
            let pg = Grouping::new(pl).unwrap();
            let got = anosim(&pm, &pg, 9, 1).unwrap().r_obs;
            // Each pair keeps its distance (hence its mid-rank); only the
            // f64 summation order changes.
            let diff = (got - base).abs();
            assert!(diff < 1e-9, "n={n} k={k} round={round}: R {got} vs {base}");
        }
    }
}

#[test]
fn permdisp_f_is_invariant_under_joint_relabelling() {
    // PCoA re-derives the embedding per matrix, so invariance holds to
    // eigensolver tolerance, not bitwise.
    for (n, k, seed) in [(24usize, 2usize, 5u64), (30, 3, 6)] {
        let mat = DistanceMatrix::random_euclidean(n, 5, seed);
        let grouping = Grouping::balanced(n, k).unwrap();
        let base = permdisp(&mat, &grouping, 9, 1).unwrap().f_obs;

        let mut rng = Xoshiro256pp::new(seed ^ 0xFACE);
        for round in 0..3 {
            let mut sigma: Vec<usize> = (0..n).collect();
            shuffle(&mut rng, &mut sigma);
            let (pm, pl) = permuted(&mat, grouping.labels(), &sigma);
            let pg = Grouping::new(pl).unwrap();
            let got = permdisp(&pm, &pg, 9, 1).unwrap().f_obs;
            let rel = (got - base).abs() / base.abs().max(1e-12);
            assert!(rel < 1e-5, "n={n} k={k} round={round}: F {got} vs {base} (rel {rel})");
        }
    }
}

#[test]
fn anosim_r_bounded_and_p_in_unit_interval_across_backends() {
    for backend in
        ["native", "native-brute", "native-tiled", "native-flat", "native-batch", "simulator"]
    {
        for seed in [3u64, 7, 11] {
            let cfg = RunConfig {
                data: DataSource::Synthetic { n_dims: 26, n_groups: 3 },
                backend: backend.to_string(),
                method: Method::Anosim,
                n_perms: 29,
                seed,
                threads: 2,
                ..Default::default()
            };
            let mat = DistanceMatrix::random_euclidean(26, 5, seed ^ 0xB0);
            let grouping = Grouping::balanced(26, 3).unwrap();
            let r = execute(&cfg, &mat, &grouping).unwrap();
            assert!(
                (-1.0..=1.0).contains(&r.f_obs),
                "{backend} seed={seed}: R = {}",
                r.f_obs
            );
            assert!(r.p_value > 0.0 && r.p_value <= 1.0, "{backend}: p = {}", r.p_value);
        }
    }
}

#[test]
fn every_method_p_in_unit_interval_across_backends() {
    let mat = DistanceMatrix::random_euclidean(28, 5, 11);
    let grouping = Grouping::balanced(28, 4).unwrap();
    for backend in ["native-brute", "native-flat", "native-batch", "simulator"] {
        for method in Method::ALL {
            let cfg = RunConfig {
                data: DataSource::Synthetic { n_dims: 28, n_groups: 4 },
                backend: backend.to_string(),
                method,
                n_perms: 29,
                seed: 5,
                threads: 2,
                ..Default::default()
            };
            let r = execute(&cfg, &mat, &grouping).unwrap();
            assert!(
                r.p_value > 0.0 && r.p_value <= 1.0,
                "{backend}/{method:?}: p = {}",
                r.p_value
            );
            for run in &r.runs {
                assert!(run.p_value > 0.0 && run.p_value <= 1.0, "{backend}/{method:?}");
            }
        }
    }
}

#[test]
fn p_values_always_lie_in_unit_interval() {
    // Through the low-level API, across kernels and data shapes...
    for (n, k, seed) in [(16usize, 2usize, 7u64), (30, 3, 8), (45, 5, 9)] {
        let mat = DistanceMatrix::random_euclidean(n, 5, seed);
        let grouping = Grouping::balanced(n, k).unwrap();
        for algo in [SwAlgorithm::Brute, SwAlgorithm::Flat, SwAlgorithm::Tiled { tile: 16 }] {
            let res = permanova(
                &mat,
                &grouping,
                39,
                &PermanovaOpts { algo, seed, threads: 2, keep_f_perms: false },
            )
            .unwrap();
            assert!(
                res.p_value > 0.0 && res.p_value <= 1.0,
                "{algo:?} n={n}: p = {}",
                res.p_value
            );
        }
    }
    // ...through every registered native/simulator backend...
    for backend in
        ["native", "native-brute", "native-tiled", "native-flat", "native-batch", "simulator"]
    {
        let cfg = RunConfig {
            data: DataSource::Synthetic { n_dims: 28, n_groups: 4 },
            backend: backend.to_string(),
            n_perms: 29,
            seed: 5,
            threads: 2,
            ..Default::default()
        };
        let mat = DistanceMatrix::random_euclidean(28, 5, 11);
        let grouping = Grouping::balanced(28, 4).unwrap();
        let r = execute(&cfg, &mat, &grouping).unwrap();
        assert!(r.p_value > 0.0 && r.p_value <= 1.0, "{backend}: p = {}", r.p_value);
    }
    // ...and at the pvalue() edges themselves.
    assert_eq!(pvalue(f64::INFINITY, &[1.0, 2.0, 3.0]), 0.25); // above all: 1/(1+3)
    assert_eq!(pvalue(f64::NEG_INFINITY, &[1.0, 2.0, 3.0]), 1.0); // below all
    assert_eq!(pvalue(0.0, &[]), 1.0); // no permutations: p = 1
}

#[test]
fn degenerate_groupings_are_rejected() {
    // All objects in one group: k = 1, no between-group variance to test.
    assert!(Grouping::new(vec![0; 10]).is_err());
    // Every object its own group: n = k, no within-group degrees of freedom.
    assert!(Grouping::new((0..8).collect()).is_err());
    // Empty labelling.
    assert!(Grouping::new(vec![]).is_err());
    // Non-dense labels (group 1 empty).
    assert!(Grouping::new(vec![0, 0, 2, 2, 2]).is_err());
}

#[test]
fn perfect_separation_yields_the_oracle_degenerate_f() {
    // Within-group distances all zero, cross-group all one: s_W = 0, so the
    // F statistic degenerates to +inf — and the f64 oracle must agree.
    let n = 12;
    let k = 3;
    let grouping = Grouping::balanced(n, k).unwrap();
    let mut mat = DistanceMatrix::zeros(n);
    for i in 0..n {
        for j in (i + 1)..n {
            if grouping.labels()[i] != grouping.labels()[j] {
                mat.set_sym(i, j, 1.0);
            }
        }
    }
    let sw_oracle = sw_brute_f64_dense(mat.data(), n, grouping.labels(), grouping.inv_sizes());
    assert_eq!(sw_oracle, 0.0, "perfect separation has zero within-group sum");
    let f_oracle = fstat_from_sw(sw_oracle, st_of(&mat), n, k);
    assert!(f_oracle.is_infinite() && f_oracle > 0.0, "oracle F = {f_oracle}");

    let res = permanova(
        &mat,
        &grouping,
        49,
        &PermanovaOpts { algo: SwAlgorithm::Brute, seed: 3, threads: 1, keep_f_perms: true },
    )
    .unwrap();
    assert!(
        res.f_obs.is_infinite() && res.f_obs > 0.0,
        "observed F must match the oracle's degenerate value, got {}",
        res.f_obs
    );
    // A shuffled labelling reproduces s_W = 0 only if it preserves the
    // exact partition, so nearly all permuted F values are finite and the
    // p-value is (1 + #partition-preserving draws) / (P + 1).
    let ties = res
        .f_perms
        .as_ref()
        .unwrap()
        .iter()
        .filter(|f| f.is_infinite())
        .count();
    assert!(ties < 5, "implausibly many partition-preserving shuffles: {ties}");
    assert!(
        (res.p_value - (1.0 + ties as f64) / 50.0).abs() < 1e-12,
        "p = {} with {ties} ties",
        res.p_value
    );
}
