//! The daemon's concurrency-edition correctness contract, pinned over a
//! real loopback TCP socket:
//!
//! * **Bitwise identity** — N concurrent pipelined clients receive
//!   `report` payloads byte-identical to the one-shot file-batch path
//!   (`run_jobs`) for the same requests, and each connection's responses
//!   come back in request order.  (`elapsed_secs` and the `cache`
//!   hit/miss tag are execution provenance — they legitimately differ
//!   across concurrency editions — so the comparison pins the `report`
//!   object, the `id`, `ok` and `dataset` fields.)
//! * **Bounded admission** — a queue of depth 1 under a pipelined flood
//!   sheds with `retry_after` (load-shedding, not OOM), the shed
//!   responses still arrive in order, the stats counters add up, and the
//!   daemon drains cleanly afterwards.

use std::collections::BTreeMap;

use permanova_apu::jsonio::Json;
use permanova_apu::service::{
    client_exchange, envelope_v1, parse_jobs, run_jobs, Daemon, DaemonConfig, DatasetCache,
};

/// A mixed-method batch over one shared dataset plus one distinct
/// dataset, in the legacy-free v1 envelope shape.
fn request_lines() -> Vec<String> {
    let mut lines = Vec::new();
    let combos: [(&str, &str, u64); 4] = [
        ("permanova", "native-flat", 11),
        ("anosim", "native-brute", 12),
        ("permdisp", "native-brute", 13),
        ("pairwise", "native-batch", 14),
    ];
    for (i, (method, backend, seed)) in combos.iter().enumerate() {
        let payload = Json::obj(vec![
            ("method", Json::str(*method)),
            ("backend", Json::str(*backend)),
            ("n_perms", Json::num(19.0)),
            ("seed", Json::str(seed.to_string())),
            (
                "data",
                Json::obj(vec![
                    ("source", Json::str("synthetic")),
                    ("n_dims", Json::num(24.0)),
                    ("n_groups", Json::num(2.0)),
                    // Jobs 0..2 share a dataset; job 3 loads its own.
                    ("seed", Json::num(if i < 3 { 7.0 } else { 8.0 })),
                ]),
            ),
        ]);
        lines.push(envelope_v1(Some(&format!("job-{i}")), payload).to_string());
    }
    lines
}

/// The fields of a response that must be identical across execution
/// editions (one-shot batch vs concurrent daemon): identity, success and
/// the full analysis report.  `elapsed_secs`/`cache` are provenance.
fn comparable(response: &Json) -> String {
    let mut keep = Vec::new();
    for key in ["id", "ok", "dataset", "error", "report", "note"] {
        if let Some(v) = response.get(key) {
            keep.push((key, v.clone()));
        }
    }
    Json::obj(keep).to_string()
}

#[test]
fn concurrent_pipelined_clients_match_the_file_batch_bitwise() {
    // Reference: the one-shot file-batch path over the same requests.
    let jobs_text = request_lines().join("\n");
    let jobs = parse_jobs(&jobs_text).unwrap();
    let cache = DatasetCache::new(4);
    let batch = run_jobs(&jobs, &cache, 2);
    let reference: BTreeMap<String, String> = batch
        .responses
        .iter()
        .map(|r| (r.req_str("id").unwrap().to_string(), comparable(r)))
        .collect();
    assert_eq!(reference.len(), 4);
    assert!(batch.responses.iter().all(|r| r.opt_bool("ok").unwrap() == Some(true)));

    let daemon = Daemon::spawn(DaemonConfig {
        workers: 2,
        cache_capacity: 4,
        queue_depth: 64,
        ..DaemonConfig::default()
    })
    .unwrap();
    let addr = daemon.addr();

    // N concurrent clients, each pipelining the full request list in a
    // different rotation (so the executor interleaves datasets), twice —
    // the second pass exercises the warm cache edition.
    const CLIENTS: usize = 4;
    let all_responses: Vec<Vec<Json>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                scope.spawn(move || {
                    let mut requests = request_lines();
                    requests.rotate_left(c % requests.len());
                    requests.extend(request_lines());
                    client_exchange(&addr, &requests).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (c, responses) in all_responses.iter().enumerate() {
        assert_eq!(responses.len(), 8, "client {c}: one response per request");
        // Per-connection ordering: responses correlate to requests by
        // position — ids must match the (rotated) request order exactly.
        let mut expected: Vec<String> =
            (0..4).map(|i| format!("job-{}", (i + c) % 4)).collect();
        expected.extend((0..4).map(|i| format!("job-{i}")));
        for (response, want_id) in responses.iter().zip(&expected) {
            assert_eq!(response.req_str("id").unwrap(), want_id, "client {c} order");
            assert_eq!(
                &comparable(response),
                reference.get(want_id).unwrap(),
                "client {c}, {want_id}: daemon response diverges from the file batch"
            );
        }
    }

    daemon.shutdown();
    let summary = daemon.join().unwrap();
    assert_eq!(summary.connections, CLIENTS);
    assert_eq!(summary.completed, CLIENTS * 8);
    assert_eq!(summary.failed, 0);
    assert_eq!(summary.rejected, 0, "queue depth 64 never sheds this load");
}

#[test]
fn bounded_admission_sheds_with_retry_after_and_drains_cleanly() {
    let daemon = Daemon::spawn(DaemonConfig {
        workers: 1,
        cache_capacity: 2,
        queue_depth: 1,
        retry_after_secs: 0.25,
        ..DaemonConfig::default()
    })
    .unwrap();
    let addr = daemon.addr();

    // Flood: one pipelined connection pushes far more work than a
    // depth-1 queue holds.  Each job carries a few hundred microseconds
    // of permutation work (n = 64, 199 perms), so the executor lags the
    // reader (which only parses) and the queue must overflow.
    let flood: Vec<String> = (0..48)
        .map(|i| {
            let payload = Json::obj(vec![
                ("n_perms", Json::num(199.0)),
                ("seed", Json::str((100 + i).to_string())),
                (
                    "data",
                    Json::obj(vec![
                        ("source", Json::str("synthetic")),
                        ("n_dims", Json::num(64.0)),
                        ("n_groups", Json::num(4.0)),
                        ("seed", Json::num(7.0)),
                    ]),
                ),
            ]);
            envelope_v1(Some(&format!("flood-{i}")), payload).to_string()
        })
        .collect();
    let responses = client_exchange(&addr, &flood).unwrap();
    assert_eq!(responses.len(), flood.len());

    let mut ok = 0usize;
    let mut shed = 0usize;
    for (i, response) in responses.iter().enumerate() {
        // Ordering holds even when rejections finish instantly while
        // earlier admitted jobs are still computing.
        assert_eq!(response.req_str("id").unwrap(), format!("flood-{i}"));
        if response.opt_bool("ok").unwrap() == Some(true) {
            ok += 1;
            assert!(response.get("report").is_some());
        } else {
            let retry = response
                .get("retry_after")
                .and_then(Json::as_f64)
                .expect("failed flood responses must carry retry_after");
            assert_eq!(retry, 0.25, "the configured hint is pinned");
            let error = response.req_str("error").unwrap();
            assert!(error.starts_with("server busy"), "{error}");
            shed += 1;
        }
    }
    assert_eq!(ok + shed, flood.len());
    assert!(ok >= 1, "the executor makes progress under flood");
    assert!(shed >= 1, "a depth-1 queue must shed a pipelined flood");

    // Stats over the wire agree with the observed split.
    let stats_req = envelope_v1(
        Some("stats"),
        Json::obj(vec![("op", Json::str("stats"))]),
    )
    .to_string();
    let stats = &client_exchange(&addr, &[stats_req]).unwrap()[0];
    let s = stats.get("stats").expect("stats body");
    assert_eq!(s.req_usize("completed").unwrap() + s.req_usize("failed").unwrap(), ok);
    assert_eq!(s.req_usize("rejected").unwrap(), shed);
    assert_eq!(s.req_usize("queue_capacity").unwrap(), 1);
    let hit_rate = s.get("cache").unwrap().get("hit_rate").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&hit_rate));

    // Graceful drain via the shutdown op: the daemon acknowledges, stops
    // accepting, finishes admitted work and joins.
    let bye_req = envelope_v1(
        Some("bye"),
        Json::obj(vec![("op", Json::str("shutdown"))]),
    )
    .to_string();
    let bye = &client_exchange(&addr, &[bye_req]).unwrap()[0];
    assert_eq!(bye.opt_bool("ok").unwrap(), Some(true));
    assert_eq!(bye.opt_bool("draining").unwrap(), Some(true));
    let summary = daemon.join().unwrap();
    assert_eq!(summary.completed + summary.failed, ok);
    assert_eq!(summary.rejected, shed);
}

#[test]
fn malformed_and_legacy_requests_get_correlated_responses() {
    let daemon = Daemon::spawn(DaemonConfig {
        workers: 1,
        cache_capacity: 2,
        ..DaemonConfig::default()
    })
    .unwrap();
    let addr = daemon.addr();

    let requests = vec![
        // Unsupported version: rejected with the id echoed back.
        r#"{"v": 99, "id": "future", "request": {"n_perms": 9}}"#.to_string(),
        // Field-path naming: the bad field is spelled request.n_perm.
        r#"{"v": 1, "id": "typo", "request": {"n_perm": 9}}"#.to_string(),
        // Legacy v0 still computes, with the deprecation note attached.
        concat!(
            r#"{"id": "legacy", "n_perms": 9, "#,
            r#""data": {"source": "synthetic", "n_dims": 24, "n_groups": 2}}"#
        )
        .to_string(),
    ];
    let responses = client_exchange(&addr, &requests).unwrap();
    assert_eq!(responses.len(), 3);

    assert_eq!(responses[0].req_str("id").unwrap(), "future");
    assert_eq!(responses[0].opt_bool("ok").unwrap(), Some(false));
    assert!(responses[0].req_str("error").unwrap().contains("unsupported envelope version"));

    assert_eq!(responses[1].req_str("id").unwrap(), "typo");
    let error = responses[1].req_str("error").unwrap();
    assert!(error.contains("request.n_perm"), "exact field path named: {error}");

    assert_eq!(responses[2].req_str("id").unwrap(), "legacy");
    assert_eq!(responses[2].opt_bool("ok").unwrap(), Some(true));
    assert!(
        responses[2].req_str("note").unwrap().contains("deprecated"),
        "v0 responses carry the deprecation note"
    );

    daemon.shutdown();
    daemon.join().unwrap();
}
