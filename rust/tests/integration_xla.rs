//! Integration: the three-layer AOT stack (Pallas -> HLO -> PJRT) against
//! the native oracle, across every artifact the manifest ships.
//!
//! All tests skip silently when `make artifacts` hasn't run (clean
//! checkout); CI runs them after the artifacts step.

use permanova_apu::dmat::DistanceMatrix;
use permanova_apu::permanova::{fstat_from_sw, st_of, sw_brute_f64_dense, Grouping};
use permanova_apu::rng::PermutationPlan;
use permanova_apu::runtime::{artifacts_dir_for_tests, XlaRuntime};

fn runtime() -> Option<XlaRuntime> {
    let dir = artifacts_dir_for_tests();
    if !dir.join("manifest.json").exists() {
        eprintln!("skip: no artifacts at {dir:?}");
        return None;
    }
    match XlaRuntime::new(dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            // Stub build (no `pjrt` feature): artifacts exist but there is
            // no client — skip rather than fail.
            eprintln!("skip: {e}");
            None
        }
    }
}

/// Every artifact in the manifest compiles and matches the native oracle
/// at its exact lowered shape.
#[test]
fn every_artifact_parity() {
    let Some(rt) = runtime() else { return };
    let metas: Vec<_> = rt.manifest().artifacts().to_vec();
    for meta in metas {
        let n = meta.n_dims;
        let k = meta.n_groups;
        let b = meta.batch.min(8); // keep runtime modest
        let mat = DistanceMatrix::random_euclidean(n, 8, meta.n_dims as u64);
        let grouping = Grouping::balanced(n, k).unwrap();
        let plan = PermutationPlan::new(grouping.labels().to_vec(), 3, b);
        let rows = plan.batch(0, b);

        let sess = rt
            .session(&meta.kernel, mat.data(), n, &grouping)
            .unwrap_or_else(|e| panic!("{}: {e}", meta.name));
        let out = sess.run_batch(&rows, b).unwrap_or_else(|e| panic!("{}: {e}", meta.name));

        let s_t = st_of(&mat);
        for r in 0..b {
            let want =
                sw_brute_f64_dense(mat.data(), n, &rows[r * n..(r + 1) * n], grouping.inv_sizes());
            let got = out.s_w[r] as f64;
            let rel = (got - want).abs() / want.max(1e-9);
            assert!(rel < 2e-4, "{} row {r}: sw rel err {rel}", meta.name);
            let want_f = fstat_from_sw(want, s_t, n, k);
            let rel_f = (out.f_stats[r] - want_f).abs() / want_f.abs().max(1e-9);
            assert!(rel_f < 2e-3, "{} row {r}: f rel err {rel_f}", meta.name);
        }
    }
}

/// Sessions are reusable across many batches with consistent results
/// (device-resident matrix is not corrupted by subsequent uploads).
#[test]
fn session_reuse_many_batches() {
    let Some(rt) = runtime() else { return };
    let n = 64;
    let mat = DistanceMatrix::random_euclidean(n, 4, 5);
    let grouping = Grouping::balanced(n, 4).unwrap();
    let sess = rt.session("matmul", mat.data(), n, &grouping).unwrap();
    let plan = PermutationPlan::new(grouping.labels().to_vec(), 9, 64);

    let mut first_batch_f = None;
    for round in 0..4 {
        let rows = plan.batch(0, 16);
        let out = sess.run_batch(&rows, 16).unwrap();
        match &first_batch_f {
            None => first_batch_f = Some(out.f_stats.clone()),
            Some(want) => {
                assert_eq!(&out.f_stats, want, "round {round}: drift across re-execution")
            }
        }
    }
}

/// Mixed-size serving: one runtime, several problems, interleaved — the
/// executable cache and padding must not cross-contaminate.
#[test]
fn interleaved_sessions_different_problems() {
    let Some(rt) = runtime() else { return };
    let mk = |n: usize, k: usize, seed: u64| {
        let mat = DistanceMatrix::random_euclidean(n, 6, seed);
        let grouping = Grouping::balanced(n, k).unwrap();
        (mat, grouping)
    };
    let (mat_a, grp_a) = mk(64, 4, 1);
    let (mat_b, grp_b) = mk(200, 8, 2); // pads into the 256 artifact
    let sess_a = rt.session("bruteforce", mat_a.data(), 64, &grp_a).unwrap();
    let sess_b = rt.session("bruteforce", mat_b.data(), 200, &grp_b).unwrap();
    assert_eq!(sess_b.meta().n_dims, 256);

    let plan_a = PermutationPlan::new(grp_a.labels().to_vec(), 4, 8);
    let plan_b = PermutationPlan::new(grp_b.labels().to_vec(), 4, 8);
    for _ in 0..3 {
        let ra = sess_a.run_batch(&plan_a.batch(0, 4), 4).unwrap();
        let rb = sess_b.run_batch(&plan_b.batch(0, 4), 4).unwrap();
        let wa = sw_brute_f64_dense(mat_a.data(), 64, plan_a.base(), grp_a.inv_sizes());
        let wb = sw_brute_f64_dense(mat_b.data(), 200, plan_b.base(), grp_b.inv_sizes());
        assert!(((ra.s_w[0] as f64) - wa).abs() / wa < 1e-4);
        assert!(((rb.s_w[0] as f64) - wb).abs() / wb < 1e-4);
    }
}

/// The kernels must agree with EACH OTHER through the XLA path (not just
/// with the oracle): same inputs, same outputs across variants.
#[test]
fn xla_kernel_cross_agreement() {
    let Some(rt) = runtime() else { return };
    let n = 256;
    let mat = DistanceMatrix::random_euclidean(n, 12, 31);
    let grouping = Grouping::balanced(n, 8).unwrap();
    let plan = PermutationPlan::new(grouping.labels().to_vec(), 8, 8);
    let rows = plan.batch(0, 8);

    let mut outputs = Vec::new();
    for kernel in ["bruteforce", "tiled", "matmul", "ref"] {
        if rt.manifest().best_fit(kernel, n).is_none() {
            continue;
        }
        let sess = rt.session(kernel, mat.data(), n, &grouping).unwrap();
        outputs.push((kernel, sess.run_batch(&rows, 8).unwrap()));
    }
    assert!(outputs.len() >= 3);
    let (k0, base) = &outputs[0];
    for (k, out) in &outputs[1..] {
        for r in 0..8 {
            let rel = ((out.s_w[r] - base.s_w[r]) / base.s_w[r].max(1e-9)).abs();
            assert!(rel < 2e-4, "{k} vs {k0} row {r}: rel {rel}");
        }
    }
}

/// Concurrent native devices + a local XLA device through the coordinator:
/// the heterogeneous path end-to-end.
#[test]
fn coordinator_heterogeneous_with_xla() {
    let Some(rt) = runtime() else { return };
    use permanova_apu::coordinator::{run_coordinated, Device, NativeCpuDevice, XlaDevice};
    use permanova_apu::permanova::SwAlgorithm;

    let n = 64;
    let mat = DistanceMatrix::random_euclidean(n, 8, 17);
    let grouping = Grouping::balanced(n, 4).unwrap();

    let session = rt.session("matmul", mat.data(), n, &grouping).unwrap();
    let local: Vec<Box<dyn Device + '_>> = vec![Box::new(XlaDevice::new(session))];
    let send: Vec<Box<dyn Device + Send>> =
        vec![Box::new(NativeCpuDevice::new(SwAlgorithm::Flat, 1))];

    let hetero = run_coordinated(&mat, &grouping, 150, 5, send, local).unwrap();

    let native_only: Vec<Box<dyn Device + Send>> =
        vec![Box::new(NativeCpuDevice::new(SwAlgorithm::Brute, 1))];
    let pure = run_coordinated(&mat, &grouping, 150, 5, native_only, vec![]).unwrap();

    assert!((hetero.f_obs - pure.f_obs).abs() / pure.f_obs.abs().max(1e-12) < 1e-3);
    assert_eq!(hetero.p_value, pure.p_value);
    let covered: usize = hetero.per_device.iter().map(|d| d.perms).sum();
    assert_eq!(covered, 151);
}
