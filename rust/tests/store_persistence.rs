//! Durable-store persistence conformance: warm-across-restart results are
//! the cold run's bytes verbatim, a crash-torn WAL tail is tolerated,
//! compaction never loses a lookup, the store-disabled path is untouched,
//! and evicted triangles round-trip through the spill directory.
//!
//! Everything here goes through the public surface (`ResultStore`,
//! `DatasetCache::with_store`, `service::run_jobs`) except the compaction
//! test, which drives the exported `Lsm` directly to force table churn
//! with a tiny flush threshold.

use std::path::PathBuf;
use std::sync::Arc;

use permanova_apu::config::{DataSource, RunConfig};
use permanova_apu::jsonio::Json;
use permanova_apu::permanova::Method;
use permanova_apu::service::{run_jobs, validate_responses, DatasetCache, JobRequest};
use permanova_apu::store::{
    fnv64_bytes, Lsm, LsmConfig, ResultStore, StoreConfig, MAX_TABLES,
};

/// Fresh scratch directory under the system temp root.  Removed up front
/// so a previous run's state can never satisfy this run's assertions.
fn scratch(case: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("permanova_apu_store_persist_{case}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn synth_cfg(method: Method, backend: &str, seed: u64) -> RunConfig {
    RunConfig {
        data: DataSource::Synthetic { n_dims: 24, n_groups: 2 },
        data_seed: Some(7),
        n_perms: 19,
        seed,
        method,
        backend: backend.into(),
        ..Default::default()
    }
}

/// One job per method × backend — the same grid `daemon_loopback` pins.
fn job_grid() -> Vec<JobRequest> {
    vec![
        JobRequest::new("permanova", synth_cfg(Method::Permanova, "native-flat", 11)),
        JobRequest::new("anosim", synth_cfg(Method::Anosim, "native-brute", 12)),
        JobRequest::new("permdisp", synth_cfg(Method::Permdisp, "native-brute", 13)),
        JobRequest::new("pairwise", synth_cfg(Method::PairwisePermanova, "native-batch", 14)),
    ]
}

fn field<'a>(resp: &'a Json, key: &str) -> Option<&'a Json> {
    resp.get(key)
}

fn str_field(resp: &Json, key: &str) -> Option<String> {
    field(resp, key).and_then(Json::as_str).map(str::to_string)
}

#[test]
fn warm_across_restart_returns_cold_bytes_verbatim() {
    let dir = scratch("restart");
    let jobs = job_grid();

    // Cold process: every job misses the store, executes, and writes its
    // serialized report back.
    let store = Arc::new(ResultStore::open(StoreConfig::new(&dir)).unwrap());
    let cache = DatasetCache::with_store(4, store.clone());
    let cold = run_jobs(&jobs, &cache, 0);
    assert_eq!(cold.summary.failed, 0, "cold batch must be clean");
    let mut cold_reports = Vec::new();
    for resp in &cold.responses {
        assert_eq!(field(resp, "ok").and_then(Json::as_bool), Some(true));
        assert_eq!(str_field(resp, "store").as_deref(), Some("miss"), "cold run misses");
        cold_reports.push(field(resp, "report").expect("cold report").to_string());
    }
    let puts = store.stats().puts;
    assert_eq!(puts, jobs.len() as u64, "one durable put per job");
    store.drain().unwrap();
    drop(cache);
    drop(store);

    // "Restart": a brand-new handle over the same directory, empty
    // in-memory cache.  Every response must be served from the store and
    // carry the cold run's report bytes verbatim — including the original
    // run's timings and backend provenance, because a store hit never
    // re-executes.
    let store = Arc::new(ResultStore::open(StoreConfig::new(&dir)).unwrap());
    let cache = DatasetCache::with_store(4, store.clone());
    let warm = run_jobs(&jobs, &cache, 0);
    assert_eq!(warm.summary.failed, 0);
    for (resp, cold_report) in warm.responses.iter().zip(&cold_reports) {
        assert_eq!(str_field(resp, "cache").as_deref(), Some("store"));
        assert_eq!(str_field(resp, "store").as_deref(), Some("hit"));
        let warm_report = field(resp, "report").expect("warm report").to_string();
        assert_eq!(&warm_report, cold_report, "store hit must be bitwise the cold bytes");
    }
    let stats = store.stats();
    assert_eq!(stats.hits, jobs.len() as u64, "every warm job hit the store");
    assert_eq!(stats.puts, 0, "a hit writes nothing");
    validate_responses(&warm.to_jsonl()).unwrap();

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wal_replay_recovers_fsynced_entries_and_ignores_a_torn_tail() {
    let dir = scratch("torn_wal");

    // Two fsynced puts, then a simulated crash: no drain, so both live
    // only in the WAL.
    let store = ResultStore::open(StoreConfig::new(&dir)).unwrap();
    store.put("alpha", b"first value").unwrap();
    store.put("beta", b"second value").unwrap();
    drop(store);

    // Hand-append a torn record — a crash mid-append leaves a prefix of
    // `[u32 len][u64 fnv64(payload)][payload]` on disk.
    let wal_path = dir.join("wal.log");
    let key = b"gamma";
    let val = b"never landed";
    let mut payload = Vec::new();
    payload.extend_from_slice(&(key.len() as u32).to_le_bytes());
    payload.extend_from_slice(key);
    payload.extend_from_slice(&(val.len() as u32).to_le_bytes());
    payload.extend_from_slice(val);
    let mut record = Vec::new();
    record.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    record.extend_from_slice(&fnv64_bytes(&payload).to_le_bytes());
    record.extend_from_slice(&payload);
    let mut raw = std::fs::read(&wal_path).unwrap();
    raw.extend_from_slice(&record[..record.len() - 5]);
    std::fs::write(&wal_path, &raw).unwrap();

    // Replay: the fsynced entries survive, the torn one is dropped.
    let store = ResultStore::open(StoreConfig::new(&dir)).unwrap();
    assert_eq!(store.get("alpha").as_deref(), Some(b"first value".as_slice()));
    assert_eq!(store.get("beta").as_deref(), Some(b"second value".as_slice()));
    assert_eq!(store.get("gamma"), None, "torn record must not replay");

    // Open truncated the torn tail back to the last intact boundary, so
    // the log is immediately appendable again and the new entry persists.
    store.put("gamma", b"landed this time").unwrap();
    drop(store);
    let store = ResultStore::open(StoreConfig::new(&dir)).unwrap();
    assert_eq!(store.get("alpha").as_deref(), Some(b"first value".as_slice()));
    assert_eq!(store.get("gamma").as_deref(), Some(b"landed this time".as_slice()));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn compaction_preserves_every_lookup_and_the_latest_version_wins() {
    let dir = scratch("compaction");

    // A tiny flush threshold turns nearly every put into a table flush,
    // so the tree must compact (tables are capped at MAX_TABLES).
    let mut lsm = Lsm::open(LsmConfig {
        dir: dir.clone(),
        capacity_bytes: 0,
        flush_bytes: 64,
    })
    .unwrap();
    for i in 0..40u32 {
        lsm.put(&format!("key-{i:03}"), format!("value-{i}").as_bytes()).unwrap();
    }
    // Overwrite a few keys so shadowed versions exist across tables.
    for i in (0..40u32).step_by(7) {
        lsm.put(&format!("key-{i:03}"), format!("rewrite-{i}").as_bytes()).unwrap();
    }
    let stats = lsm.stats();
    assert!(stats.compactions >= 1, "forced churn must have compacted: {stats:?}");
    assert!(stats.segments <= MAX_TABLES, "table count stays bounded: {stats:?}");

    let check = |lsm: &mut Lsm| {
        for i in 0..40u32 {
            let want = if i % 7 == 0 { format!("rewrite-{i}") } else { format!("value-{i}") };
            let got = lsm.get(&format!("key-{i:03}")).unwrap();
            assert_eq!(got.as_deref(), Some(want.as_bytes()), "key-{i:03}");
        }
    };
    check(&mut lsm);

    // Survives a clean shutdown + reopen too.
    lsm.drain().unwrap();
    drop(lsm);
    let mut lsm = Lsm::open(LsmConfig {
        dir: dir.clone(),
        capacity_bytes: 0,
        flush_bytes: 64,
    })
    .unwrap();
    check(&mut lsm);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_disabled_path_is_unchanged() {
    // A plain cache (no store tier) must produce responses with no
    // `store` field at all — byte-compatible with the pre-store schema —
    // and they must still validate.
    let jobs = job_grid();
    let cache = DatasetCache::new(4);
    let out = run_jobs(&jobs, &cache, 0);
    assert_eq!(out.summary.failed, 0);
    for resp in &out.responses {
        assert_eq!(field(resp, "ok").and_then(Json::as_bool), Some(true));
        assert!(field(resp, "store").is_none(), "no store tier, no store field: {resp}");
        let cache_tag = str_field(resp, "cache").unwrap();
        assert!(
            cache_tag == "hit" || cache_tag == "miss",
            "store-less cache tag is hit/miss only, got {cache_tag}"
        );
    }
    validate_responses(&out.to_jsonl()).unwrap();
}

#[test]
fn evicted_triangle_spills_and_reloads_fresh_but_bitwise_equal() {
    let dir = scratch("spill_reload");
    let store = Arc::new(ResultStore::open(StoreConfig::new(&dir)).unwrap());
    let cache = DatasetCache::with_store(1, store.clone());

    let cfg_a = synth_cfg(Method::Permanova, "native-flat", 11);
    let cfg_b = RunConfig { data_seed: Some(8), ..cfg_a.clone() };

    let (a_first, hit) = cache.get_or_load(&cfg_a).unwrap();
    assert!(!hit, "first load misses");
    let original_values: Vec<f32> = a_first.tri().values().to_vec();
    let original_labels: Vec<u32> = a_first.grouping.labels().to_vec();

    // Loading a second dataset through a capacity-1 cache evicts the
    // first, which must park as a spill segment.
    let (_b, _) = cache.get_or_load(&cfg_b).unwrap();
    assert!(store.stats().spill.spilled >= 1, "eviction spilled the triangle");

    // Reloading A is a memory miss served from the segment: a fresh
    // allocation (the evicted Arc is gone) holding bitwise-identical
    // values and the same grouping.
    let (a_again, hit) = cache.get_or_load(&cfg_a).unwrap();
    assert!(!hit, "evicted dataset is a memory miss");
    assert!(
        !Arc::ptr_eq(a_first.tri(), a_again.tri()),
        "reload must be a fresh allocation, not the evicted Arc"
    );
    assert_eq!(a_again.tri().values(), original_values.as_slice(), "values bitwise equal");
    assert_eq!(a_again.grouping.labels(), original_labels.as_slice(), "grouping preserved");
    assert!(store.stats().spill.reloaded >= 1, "served from the spill segment");

    let _ = std::fs::remove_dir_all(&dir);
}
