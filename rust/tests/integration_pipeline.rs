//! Integration: the full microbiome pipeline (tree -> table -> UniFrac ->
//! PERMANOVA) and the UniFrac metric's mathematical properties at scale.

use permanova_apu::config::{DataSource, RunConfig};
use permanova_apu::coordinator::{load_data_dense, run_config, run_on_backend};
use permanova_apu::permanova::{Grouping, SwAlgorithm};
use permanova_apu::rng::{shuffle, Xoshiro256pp};
use permanova_apu::unifrac::{generate, newick, unweighted_unifrac, SynthParams};

/// UniFrac over a generated community is a valid distance matrix and
/// satisfies the triangle inequality (sampled).
#[test]
fn unifrac_metric_properties() {
    let ds = generate(&SynthParams {
        n_taxa: 200,
        n_samples: 50,
        n_envs: 4,
        seed: 3,
        ..Default::default()
    })
    .unwrap();
    let m = unweighted_unifrac(&ds.tree, &ds.table, 0).unwrap();
    m.validate(1e-6).unwrap();
    let n = m.n();
    // Range [0, 1].
    for v in m.data() {
        assert!((0.0..=1.0 + 1e-6).contains(v));
    }
    // Triangle inequality, sampled systematically.
    let mut rng = Xoshiro256pp::new(1);
    for _ in 0..2000 {
        let i = rng.gen_range(n as u32) as usize;
        let j = rng.gen_range(n as u32) as usize;
        let l = rng.gen_range(n as u32) as usize;
        assert!(
            m.get(i, j) <= m.get(i, l) + m.get(l, j) + 1e-5,
            "triangle violated at ({i},{j},{l})"
        );
    }
}

/// The pipeline detects planted environments and clears shuffled controls,
/// deterministically by seed.
#[test]
fn pipeline_signal_and_null() {
    let ds = generate(&SynthParams {
        n_taxa: 256,
        n_samples: 60,
        n_envs: 3,
        seed: 11,
        ..Default::default()
    })
    .unwrap();
    let mat = unweighted_unifrac(&ds.tree, &ds.table, 0).unwrap();

    let cfg = RunConfig {
        n_perms: 199,
        algo: SwAlgorithm::Tiled { tile: 128 },
        ..Default::default()
    };
    let signal = run_on_backend(&cfg, &mat, &ds.grouping).unwrap();
    assert!(signal.p_value <= 0.01, "planted environments: p = {}", signal.p_value);

    let mut labels = ds.grouping.labels().to_vec();
    let mut rng = Xoshiro256pp::new(5);
    shuffle(&mut rng, &mut labels);
    let null_grouping = Grouping::new(labels).unwrap();
    let null = run_on_backend(&cfg, &mat, &null_grouping).unwrap();
    assert!(null.p_value > 0.05, "shuffled control: p = {}", null.p_value);
}

/// The config-driven path produces the identical report to the manual
/// pipeline (load_data_dense is deterministic in the seed).
#[test]
fn config_driven_pipeline_deterministic() {
    let cfg = RunConfig {
        data: DataSource::SyntheticUnifrac { n_taxa: 96, n_samples: 28, n_groups: 2 },
        n_perms: 49,
        seed: 77,
        ..Default::default()
    };
    let a = run_config(&cfg).unwrap();
    let b = run_config(&cfg).unwrap();
    assert_eq!(a.f_obs, b.f_obs);
    assert_eq!(a.p_value, b.p_value);

    // load_data_dense + run_on_backend == run_config.
    let (mat, grouping) = load_data_dense(&cfg).unwrap();
    let c = run_on_backend(&cfg, &mat, &grouping).unwrap();
    assert_eq!(a.f_obs, c.f_obs);
}

/// A real-world-shaped Newick file (quoted names, comments, scientific
/// notation) flows through the whole pipeline.
#[test]
fn newick_to_permanova_roundtrip() {
    // 8 leaves, two clades.
    let nwk = "[16S placement] (('taxon A':0.12,'taxon B':0.08)cladeL:0.3,\
               (tC:1.1e-1,(tD:0.05,tE:0.07):0.02)cladeR:0.25,(tF:0.2,(tG:0.3,tH:0.1):0.15):0.2);";
    let tree = newick::parse(nwk).unwrap();
    assert_eq!(tree.leaves().len(), 8);

    // 12 samples: half live in cladeL+tC, half in cladeR's tail.
    let features: Vec<String> = tree
        .leaves()
        .iter()
        .map(|&l| tree.name(l).to_string())
        .collect();
    let samples: Vec<String> = (0..12).map(|i| format!("s{i}")).collect();
    let mut counts = vec![0u32; features.len() * 12];
    for s in 0..12 {
        for (fi, fname) in features.iter().enumerate() {
            let left_pool = fname.contains('A') || fname.contains('B') || fname == "tC";
            let present = if s % 2 == 0 { left_pool } else { !left_pool };
            if present {
                counts[fi * 12 + s] = 1 + (s as u32 % 3);
            }
        }
    }
    let table = permanova_apu::unifrac::OtuTable::new(features, samples, counts).unwrap();
    let mat = unweighted_unifrac(&tree, &table, 1).unwrap();
    mat.validate(1e-6).unwrap();

    let grouping = Grouping::new((0..12).map(|i| (i % 2) as u32).collect()).unwrap();
    let cfg = RunConfig { n_perms: 99, ..Default::default() };
    let r = run_on_backend(&cfg, &mat, &grouping).unwrap();
    assert!(r.p_value <= 0.05, "clade-split communities must separate: p = {}", r.p_value);
}

/// Backends agree end-to-end on UniFrac input (native vs simulated; XLA
/// covered in integration_xla).
#[test]
fn backends_agree_on_pipeline_data() {
    let cfg = RunConfig {
        data: DataSource::SyntheticUnifrac { n_taxa: 80, n_samples: 24, n_groups: 2 },
        n_perms: 59,
        seed: 13,
        ..Default::default()
    };
    let (mat, grouping) = load_data_dense(&cfg).unwrap();
    let nat = run_on_backend(&cfg, &mat, &grouping).unwrap();
    let sim = run_on_backend(
        &RunConfig { backend: "simulator".to_string(), ..cfg.clone() },
        &mat,
        &grouping,
    )
    .unwrap();
    assert!((nat.f_obs - sim.f_obs).abs() / nat.f_obs.abs().max(1e-12) < 1e-4);
    assert_eq!(nat.p_value, sim.p_value);
}

/// Bigger-than-one-stripe sample counts (>64) run threaded and stay valid.
#[test]
fn unifrac_multithreaded_multistripe() {
    let ds = generate(&SynthParams {
        n_taxa: 128,
        n_samples: 130, // 3 stripes
        n_envs: 2,
        seed: 21,
        ..Default::default()
    })
    .unwrap();
    let m1 = unweighted_unifrac(&ds.tree, &ds.table, 1).unwrap();
    let m4 = unweighted_unifrac(&ds.tree, &ds.table, 4).unwrap();
    assert_eq!(m1, m4, "thread count must not change UniFrac");
    m1.validate(1e-6).unwrap();
}
