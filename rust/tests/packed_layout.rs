//! Packed-vs-dense conformance: the packed upper-triangle layout is the
//! canonical kernel operand, and it must be **bitwise invisible** in every
//! statistic the engine produces.
//!
//! Three tiers:
//!
//! * **Kernel tier** — every packed f32/f64 kernel formulation equals its
//!   dense seed (`*_dense`) bit for bit, on awkward shapes and tiles.
//! * **Engine tier** — every method × backend × shard/SMT/`perm_block`
//!   combination reproduces the *dense seed pipeline* (dense kernels run
//!   by hand over the same permutation plan) bit for bit.
//! * **Storage tier** — dense ↔ condensed round-trips exactly, and packed
//!   rows are the dense rows' tails (the property the bitwise tiers rest
//!   on), at ≤ half the dense footprint.

use permanova_apu::backend::execute;
use permanova_apu::config::{DataSource, RunConfig};
use permanova_apu::dmat::{CondensedMatrix, DistanceMatrix};
use permanova_apu::permanova::{
    fstat_from_sw, st_of, st_of_condensed, sw_brute_f64, sw_brute_f64_dense, sw_one,
    sw_one_dense, Grouping, Method, StatKernel, SwAlgorithm,
};
use permanova_apu::rng::PermutationPlan;

const N: usize = 52;
const K: usize = 4;
const N_PERMS: usize = 99;
const SEED: u64 = 0xFACADE;

fn fixture() -> (DistanceMatrix, Grouping) {
    let cfg = cfg("native", Method::Permanova, 0);
    // The dense oracle loader: this suite compares packed kernels against
    // their dense seeds, so it needs the n×n matrix in hand.
    permanova_apu::coordinator::load_data_dense(&cfg).unwrap()
}

fn cfg(backend: &str, method: Method, perm_block: usize) -> RunConfig {
    RunConfig {
        data: DataSource::Synthetic { n_dims: N, n_groups: K },
        backend: backend.to_string(),
        method,
        n_perms: N_PERMS,
        seed: SEED,
        threads: 2,
        perm_block,
        ..Default::default()
    }
}

// -------------------------------------------------------------------------
// Storage tier
// -------------------------------------------------------------------------

/// Property sweep: dense → condensed → dense is exact, rows are dense row
/// tails, and the packed footprint is ≤ half the dense one.
#[test]
fn dense_condensed_roundtrip_property() {
    for (n, seed) in [(3usize, 1u64), (4, 2), (9, 3), (33, 4), (64, 5), (101, 6)] {
        let mat = DistanceMatrix::random_euclidean(n, 5, seed);
        let tri = CondensedMatrix::from_dense(&mat);
        assert_eq!(tri.n(), n);
        assert_eq!(tri.values().len(), n * (n - 1) / 2);
        // Round-trip is exact (f32 equality, not approximate).
        assert_eq!(tri.to_dense(), mat, "n={n}");
        // Packed values are the dense to_condensed vector.
        assert_eq!(tri.values(), mat.to_condensed().as_slice(), "n={n}");
        // Rows are dense row tails, bit for bit.
        for i in 0..n {
            assert_eq!(tri.row(i), &mat.row(i)[i + 1..], "n={n} row {i}");
        }
        // Symmetric random access agrees with the dense matrix.
        for (i, j) in [(0usize, n - 1), (n / 2, n / 3), (n - 1, 0)] {
            assert_eq!(tri.get(i, j), mat.get(i, j), "n={n} ({i},{j})");
        }
        // The whole point: ≤ half the bytes.
        assert!(tri.nbytes() * 2 <= mat.nbytes(), "n={n}");
    }
}

// -------------------------------------------------------------------------
// Kernel tier
// -------------------------------------------------------------------------

/// Every f32 formulation and the f64 oracle: packed ≡ dense seed, bitwise,
/// across shapes that straddle tiles and SIMD lanes.
#[test]
fn packed_kernels_match_dense_seeds_bitwise() {
    for (n, k, seed) in [(5usize, 2usize, 1u64), (17, 3, 2), (52, 4, 3), (97, 5, 4)] {
        let mat = DistanceMatrix::random_euclidean(n, 6, seed);
        let tri = CondensedMatrix::from_dense(&mat);
        let grouping = Grouping::balanced(n, k).unwrap();
        let (labels, inv) = (grouping.labels(), grouping.inv_sizes());
        for algo in [
            SwAlgorithm::Brute,
            SwAlgorithm::Flat,
            SwAlgorithm::Tiled { tile: 1 },
            SwAlgorithm::Tiled { tile: 7 },
            SwAlgorithm::Tiled { tile: 512 },
        ] {
            let packed = sw_one(algo, tri.view(), labels, inv);
            let dense = sw_one_dense(algo, mat.data(), n, labels, inv);
            assert_eq!(packed.to_bits(), dense.to_bits(), "n={n} {algo:?}");
        }
        let packed = sw_brute_f64(tri.view(), labels, inv);
        let dense = sw_brute_f64_dense(mat.data(), n, labels, inv);
        assert_eq!(packed.to_bits(), dense.to_bits(), "n={n} f64 oracle");
        // The s_T prelude too (it feeds every recorded pseudo-F).
        assert_eq!(st_of(&mat).to_bits(), st_of_condensed(&tri).to_bits(), "n={n} s_T");
    }
}

/// The ANOSIM prelude built from the packed buffer equals the one built
/// from `to_condensed` — same values, same order, identical mid-ranks.
#[test]
fn anosim_rank_prelude_is_layout_invariant() {
    let (mat, grouping) = fixture();
    let kernel = StatKernel::prepare(Method::Anosim, &mat, &grouping).unwrap();
    let row = grouping.labels().to_vec();
    let r = kernel.eval_labels(&grouping, &row);
    let legacy = permanova_apu::permanova::anosim(&mat, &grouping, 9, 1).unwrap();
    assert_eq!(r.to_bits(), legacy.r_obs.to_bits());
}

// -------------------------------------------------------------------------
// Engine tier
// -------------------------------------------------------------------------

/// The dense seed pipeline for one backend's f32 formulation: run the
/// dense kernel by hand over the same permutation plan.
fn dense_seed_fstats(
    mat: &DistanceMatrix,
    grouping: &Grouping,
    algo: SwAlgorithm,
) -> Vec<f64> {
    let n = mat.n();
    let s_t = st_of(mat);
    let plan = PermutationPlan::new(grouping.labels().to_vec(), SEED, N_PERMS + 1);
    let mut row = vec![0u32; n];
    (0..N_PERMS + 1)
        .map(|i| {
            plan.fill(i, &mut row);
            let sw = sw_one_dense(algo, mat.data(), n, &row, grouping.inv_sizes()) as f64;
            fstat_from_sw(sw, s_t, n, grouping.k())
        })
        .collect()
}

/// PERMANOVA through every packed backend ≡ the dense seed kernels, bit
/// for bit, across shard / SMT / worker / `perm_block` sweeps.
#[test]
fn permanova_backends_match_dense_seed_kernels_bitwise() {
    let (mat, grouping) = fixture();
    // (backend, the dense formulation it must reproduce)
    let cases: [(&str, SwAlgorithm); 5] = [
        ("native-brute", SwAlgorithm::Brute),
        ("native-flat", SwAlgorithm::Flat),
        ("native-tiled", SwAlgorithm::Tiled { tile: 512 }),
        ("native-batch", SwAlgorithm::Brute), // SoA lanes ≡ scalar brute
        ("simulator", SwAlgorithm::Flat),     // exact numerics via flat
    ];
    for (backend, algo) in cases {
        let want = dense_seed_fstats(&mat, &grouping, algo);
        for perm_block in [0usize, 1, 8, 64] {
            if perm_block > 0 && backend != "native-batch" {
                continue;
            }
            for (shard_size, threads, smt) in
                [(0usize, 2usize, false), (7, 3, true), (64, 1, false)]
            {
                let mut c = cfg(backend, Method::Permanova, perm_block);
                c.shard_size = shard_size;
                c.threads = threads;
                c.smt_oversubscribe = smt;
                let r = execute(&c, &mat, &grouping).unwrap();
                let label =
                    format!("{backend}/b{perm_block} shard={shard_size} t={threads} smt={smt}");
                assert_eq!(r.f_obs.to_bits(), want[0].to_bits(), "{label}");
                for (i, (got, seed_f)) in r.f_perms.iter().zip(&want[1..]).enumerate() {
                    assert_eq!(
                        got.to_bits(),
                        seed_f.to_bits(),
                        "{label} perm {i}: {got} vs {seed_f}"
                    );
                }
            }
        }
    }
}

/// ANOSIM and PERMDISP never touched the f32 matrix stream per
/// permutation, but their preludes now flow through the shared packed
/// buffer — the statistics must still match the legacy oracles exactly on
/// every backend and scheduling knob.
#[test]
fn generic_methods_unperturbed_by_the_packed_preludes() {
    let (mat, grouping) = fixture();
    let a_oracle = permanova_apu::permanova::anosim(&mat, &grouping, N_PERMS, SEED).unwrap();
    let d_oracle = permanova_apu::permanova::permdisp(&mat, &grouping, N_PERMS, SEED).unwrap();
    for backend in ["native", "native-batch", "simulator"] {
        for perm_block in [0usize, 1, 8, 64] {
            if perm_block > 0 && backend != "native-batch" {
                continue;
            }
            let ra = execute(&cfg(backend, Method::Anosim, perm_block), &mat, &grouping).unwrap();
            assert_eq!(ra.f_obs.to_bits(), a_oracle.r_obs.to_bits(), "{backend}/b{perm_block}");
            assert_eq!(ra.p_value, a_oracle.p_value);
            let rd =
                execute(&cfg(backend, Method::Permdisp, perm_block), &mat, &grouping).unwrap();
            assert_eq!(rd.f_obs.to_bits(), d_oracle.f_obs.to_bits(), "{backend}/b{perm_block}");
            assert_eq!(rd.p_value, d_oracle.p_value);
        }
    }
}

/// Warm (cached prelude, shared packed buffer) ≡ cold, bit for bit — the
/// service-path acceptance of the layout change.
#[test]
fn warm_shared_packed_equals_cold_bitwise() {
    use permanova_apu::backend::execute_prepared;
    use std::sync::Arc;
    let (mat, grouping) = fixture();
    let tri = Arc::new(CondensedMatrix::from_dense(&mat));
    for backend in ["native-brute", "native-batch", "simulator"] {
        for method in [Method::Permanova, Method::Anosim, Method::Permdisp] {
            let c = cfg(backend, method, 0);
            let kernel = StatKernel::prepare(method, &mat, &grouping).unwrap();
            let cold = execute(&c, &mat, &grouping).unwrap();
            let warm = execute_prepared(&c, &tri, &grouping, Some(&kernel)).unwrap();
            assert_eq!(cold.f_obs.to_bits(), warm.f_obs.to_bits(), "{backend} {method:?}");
            for (a, b) in cold.f_perms.iter().zip(&warm.f_perms) {
                assert_eq!(a.to_bits(), b.to_bits(), "{backend} {method:?}");
            }
        }
    }
}
