//! Determinism and report-serialization contracts.
//!
//! * For a fixed seed, results are identical across shard sizes, worker
//!   counts and SMT oversubscription — for the batched engine the block
//!   width is a fourth axis that must also be invisible.
//! * The extended `RunReport` JSON (including the new `perm_block` field)
//!   round-trips against a golden file, so the machine-readable schema
//!   downstream tooling consumes cannot drift silently.

use permanova_apu::backend::execute;
use permanova_apu::config::{DataSource, RunConfig};
use permanova_apu::jsonio::Json;
use permanova_apu::permanova::Method;
use permanova_apu::report::{DeviceStats, RunReport};

fn cfg(backend: &str) -> RunConfig {
    RunConfig {
        data: DataSource::Synthetic { n_dims: 36, n_groups: 3 },
        backend: backend.to_string(),
        n_perms: 59,
        seed: 0xD15C,
        ..Default::default()
    }
}

#[test]
fn identical_results_across_scheduling_configs() {
    for backend in ["native-batch", "native-flat", "native-brute"] {
        for method in [Method::Permanova, Method::Anosim, Method::Permdisp] {
            let mut base_cfg = cfg(backend);
            base_cfg.method = method;
            let (mat, grouping) = permanova_apu::coordinator::load_data_dense(&base_cfg).unwrap();
            let mut base = base_cfg.clone();
            base.threads = 1;
            base.shard_size = 1;
            let want = execute(&base, &mat, &grouping).unwrap();
            // shard size × worker count × SMT oversubscription all vary;
            // none may change a single output bit — for any method.
            for (shard_size, threads, smt) in [
                (1usize, 2usize, false),
                (5, 3, false),
                (64, 2, true),
                (7, 4, true),
                (0, 0, false), // fully automatic
                (0, 0, true),
            ] {
                let mut c = base_cfg.clone();
                c.shard_size = shard_size;
                c.threads = threads;
                c.smt_oversubscribe = smt;
                let r = execute(&c, &mat, &grouping).unwrap();
                assert_eq!(
                    want.f_obs.to_bits(),
                    r.f_obs.to_bits(),
                    "{backend}/{method:?} shard={shard_size} threads={threads} smt={smt}"
                );
                assert_eq!(want.f_perms, r.f_perms, "{backend}/{method:?} shard={shard_size}");
                assert_eq!(want.p_value, r.p_value);
            }
        }
    }
}

#[test]
fn block_width_is_invisible_alongside_scheduling() {
    // perm_block composes with the scheduler axes: sweep all of them
    // together for the batched engine.
    let base_cfg = cfg("native-batch");
    let (mat, grouping) = permanova_apu::coordinator::load_data_dense(&base_cfg).unwrap();
    let want = execute(&base_cfg, &mat, &grouping).unwrap();
    for block in [1usize, 3, 8, 64] {
        for (shard_size, threads, smt) in [(1usize, 1usize, false), (7, 3, true), (0, 2, false)] {
            let mut c = base_cfg.clone();
            c.perm_block = block;
            c.shard_size = shard_size;
            c.threads = threads;
            c.smt_oversubscribe = smt;
            let r = execute(&c, &mat, &grouping).unwrap();
            assert_eq!(want.f_perms, r.f_perms, "block={block} shard={shard_size} smt={smt}");
            assert_eq!(want.f_obs.to_bits(), r.f_obs.to_bits());
            // The report records the width actually used (clamped to the
            // 60 permutations of this fixture).
            assert_eq!(r.perm_block, block.min(60), "effective block width");
        }
    }
}

#[test]
fn same_seed_same_results_different_seed_different_draw() {
    let base_cfg = cfg("native-batch");
    let (mat, grouping) = permanova_apu::coordinator::load_data_dense(&base_cfg).unwrap();
    let a = execute(&base_cfg, &mat, &grouping).unwrap();
    let b = execute(&base_cfg, &mat, &grouping).unwrap();
    assert_eq!(a.f_perms, b.f_perms, "repeat runs are bitwise reproducible");
    let mut other = base_cfg.clone();
    other.seed ^= 1;
    let c = execute(&other, &mat, &grouping).unwrap();
    assert_ne!(a.f_perms, c.f_perms, "a different seed draws different permutations");
}

/// Fixed report whose every numeric field is exactly representable, so the
/// golden comparison is deterministic.
fn sample_report() -> RunReport {
    RunReport {
        f_obs: 2.5,
        p_value: 0.25,
        n_perms: 99,
        n: 40,
        k: 4,
        s_t: 10.0,
        elapsed_secs: 0.5,
        method: "permanova".into(),
        backend: "native-batch".into(),
        kernel: "brute-block".into(),
        perm_block: 64,
        per_device: vec![DeviceStats {
            device: "native-batch/b64".into(),
            batches: 2,
            perms: 100,
            busy_secs: 0.125,
            simulated_secs: 0.0,
        }],
        oocore: None,
        f_perms: vec![1.0; 99],
    }
}

#[test]
fn run_report_json_matches_the_golden_file() {
    let doc = sample_report().to_json();
    let golden_text = include_str!("golden/run_report.json");
    let mut golden = Json::parse(golden_text).unwrap();
    // The crate version is stamped into every report; pin the golden to
    // whatever this build reports so version bumps don't rot the fixture.
    if let Json::Obj(m) = &mut golden {
        m.insert("version".into(), Json::str(permanova_apu::VERSION));
    }
    assert_eq!(
        golden, doc,
        "RunReport JSON schema drifted — update rust/tests/golden/run_report.json deliberately"
    );
}

#[test]
fn run_report_json_roundtrips_through_both_serializers() {
    let doc = sample_report().to_json();
    for text in [doc.to_string(), doc.to_string_pretty()] {
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed, doc);
        assert_eq!(parsed.req_usize("perm_block").unwrap(), 64);
        assert_eq!(parsed.req_str("backend").unwrap(), "native-batch");
        assert_eq!(parsed.req_arr("devices").unwrap().len(), 1);
    }
}

#[test]
fn live_report_json_carries_perm_block_and_kernel() {
    let mut c = cfg("native-batch");
    c.n_perms = 99; // total 100 > the default block, so no clamping
    let (mat, grouping) = permanova_apu::coordinator::load_data_dense(&c).unwrap();
    let r = execute(&c, &mat, &grouping).unwrap();
    let doc = r.to_json();
    let parsed = Json::parse(&doc.to_string_pretty()).unwrap();
    assert_eq!(
        parsed.req_usize("perm_block").unwrap(),
        permanova_apu::permanova::DEFAULT_PERM_BLOCK
    );
    assert_eq!(parsed.req_str("method").unwrap(), "permanova");
    assert_eq!(parsed.req_str("backend").unwrap(), "native-batch");
    assert_eq!(parsed.req_str("algo").unwrap(), "brute-block");
}

#[test]
fn live_report_json_is_method_tagged() {
    let mut c = cfg("native-flat");
    c.method = Method::Anosim;
    let (mat, grouping) = permanova_apu::coordinator::load_data_dense(&c).unwrap();
    let r = execute(&c, &mat, &grouping).unwrap();
    let parsed = Json::parse(&r.to_json().to_string_pretty()).unwrap();
    assert_eq!(parsed.req_str("method").unwrap(), "anosim");
    assert_eq!(parsed.req_str("algo").unwrap(), "rank-r");

    c.method = Method::PairwisePermanova;
    let r = execute(&c, &mat, &grouping).unwrap();
    let parsed = Json::parse(&r.to_json().to_string_pretty()).unwrap();
    assert_eq!(parsed.req_str("method").unwrap(), "pairwise");
    assert_eq!(parsed.req_usize("n_comparisons").unwrap(), 3);
    assert_eq!(parsed.req_arr("pairs").unwrap().len(), 3);
}
