//! Integration: the unified `Backend` execution engine.
//!
//! The repo's core claim — brute, tiled and flat are *formulations of the
//! same statistic* — is only testable if all of them run through one
//! schedulable path.  These tests drive the name-keyed registry end-to-end
//! and pin the cross-backend equivalence against the f64 oracle.

use permanova_apu::backend::{execute, known_backends, Registry};
use permanova_apu::config::{DataSource, RunConfig};
use permanova_apu::dmat::DistanceMatrix;
use permanova_apu::permanova::{
    fstat_from_sw, st_of, sw_brute_f64_dense, Grouping, Method, SwAlgorithm, DEFAULT_TILE,
};
use permanova_apu::rng::PermutationPlan;

fn cfg(backend: &str, n: usize, k: usize, n_perms: usize) -> RunConfig {
    RunConfig {
        data: DataSource::Synthetic { n_dims: n, n_groups: k },
        backend: backend.to_string(),
        n_perms,
        seed: 2024,
        threads: 2,
        ..Default::default()
    }
}

/// `SwAlgorithm::parse` / `name` round-trip, including the tiled family
/// and the rejection cases the config layer depends on.
#[test]
fn algorithm_name_parse_roundtrips() {
    for algo in [
        SwAlgorithm::Brute,
        SwAlgorithm::Flat,
        SwAlgorithm::Tiled { tile: 1 },
        SwAlgorithm::Tiled { tile: 37 },
        SwAlgorithm::Tiled { tile: 128 },
        SwAlgorithm::Tiled { tile: 512 },
        SwAlgorithm::Tiled { tile: 4096 },
    ] {
        assert_eq!(SwAlgorithm::parse(&algo.name()), Some(algo), "{algo:?}");
    }
    // The canonical spellings.
    assert_eq!(SwAlgorithm::parse("tiled512"), Some(SwAlgorithm::Tiled { tile: 512 }));
    assert_eq!(SwAlgorithm::Tiled { tile: 512 }.name(), "tiled512");
    // Bare "tiled" uses the paper-informed default.
    assert_eq!(SwAlgorithm::parse("tiled"), Some(SwAlgorithm::Tiled { tile: DEFAULT_TILE }));
    // Rejections: zero tile, garbage suffixes, unknown names.
    assert_eq!(SwAlgorithm::parse("tiled0"), None);
    assert_eq!(SwAlgorithm::parse("tiled-8"), None);
    assert_eq!(SwAlgorithm::parse("tiledx"), None);
    assert_eq!(SwAlgorithm::parse("TILED"), None);
    assert_eq!(SwAlgorithm::parse(""), None);
    assert_eq!(SwAlgorithm::parse("bogus"), None);
}

/// Every native formulation plus the simulator, through the same `Backend`
/// trait, must produce identical F statistics (f64 oracle tolerance) and
/// the identical p-value on the same plan.
#[test]
fn cross_backend_equivalence_against_f64_oracle() {
    let n = 60;
    let k = 4;
    let n_perms = 99;
    let c = cfg("native-brute", n, k, n_perms);
    let (mat, grouping) = permanova_apu::coordinator::load_data_dense(&c).unwrap();

    // The f64 oracle distribution, straight from the permutation plan.
    let s_t = st_of(&mat);
    let plan = PermutationPlan::new(grouping.labels().to_vec(), c.seed, n_perms + 1);
    let mut row = vec![0u32; n];
    let oracle: Vec<f64> = (0..n_perms + 1)
        .map(|i| {
            plan.fill(i, &mut row);
            let sw = sw_brute_f64_dense(mat.data(), n, &row, grouping.inv_sizes());
            fstat_from_sw(sw, s_t, n, k)
        })
        .collect();

    let mut reports = Vec::new();
    for name in ["native-brute", "native-tiled", "native-flat", "native-batch", "simulator"] {
        let r = execute(&cfg(name, n, k, n_perms), &mat, &grouping).unwrap();
        assert_eq!(r.backend, name, "report must record the producing backend");
        assert_eq!(r.f_perms.len(), n_perms);

        // Observed statistic and full distribution vs the oracle.
        let rel = (r.f_obs - oracle[0]).abs() / oracle[0].abs().max(1e-12);
        assert!(rel < 5e-4, "{name}: f_obs {} vs oracle {}", r.f_obs, oracle[0]);
        for (i, (got, want)) in r.f_perms.iter().zip(&oracle[1..]).enumerate() {
            let rel = (got - want).abs() / want.abs().max(1e-12);
            assert!(rel < 5e-4, "{name} perm {i}: {got} vs {want}");
        }
        reports.push((name, r));
    }

    // All backends agree with each other on the p-value exactly, and on F
    // to f32-reduction tolerance.
    let (name0, r0) = &reports[0];
    for (name, r) in &reports[1..] {
        assert_eq!(r.p_value, r0.p_value, "{name} vs {name0}");
        let rel = (r.f_obs - r0.f_obs).abs() / r0.f_obs.abs().max(1e-12);
        assert!(rel < 1e-4, "{name} vs {name0}: {} vs {}", r.f_obs, r0.f_obs);
    }

    // The simulator computes with the flat kernel: bitwise-identical to
    // the native-flat backend, plus a modelled-time annotation.
    let flat = &reports.iter().find(|(n, _)| *n == "native-flat").unwrap().1;
    let sim = &reports.iter().find(|(n, _)| *n == "simulator").unwrap().1;
    assert_eq!(flat.f_obs, sim.f_obs);
    assert_eq!(flat.f_perms, sim.f_perms);
    assert!(sim.per_device.iter().map(|d| d.simulated_secs).sum::<f64>() > 0.0);

    // The batched engine executes the brute kernel's exact f32 op sequence:
    // bitwise-identical to native-brute, and the report records its block.
    let brute = &reports.iter().find(|(n, _)| *n == "native-brute").unwrap().1;
    let batch = &reports.iter().find(|(n, _)| *n == "native-batch").unwrap().1;
    assert_eq!(brute.f_obs, batch.f_obs);
    assert_eq!(brute.f_perms, batch.f_perms);
    assert_eq!(batch.perm_block, permanova_apu::permanova::DEFAULT_PERM_BLOCK);
    assert_eq!(brute.perm_block, 0);
}

/// The acceptance contract of the statistic-generic redesign: all four
/// methods run through every registered backend via `backend::execute`
/// (`xla` excepted here — it cannot open without AOT artifacts and is
/// covered by its own gated tests).
#[test]
fn every_method_runs_through_every_registered_backend() {
    let c0 = cfg("native", 30, 3, 19);
    let (mat, grouping) = permanova_apu::coordinator::load_data_dense(&c0).unwrap();
    for backend in known_backends() {
        if backend == "xla" {
            continue;
        }
        for method in Method::ALL {
            let mut c = cfg(&backend, 30, 3, 19);
            c.method = method;
            let r = execute(&c, &mat, &grouping)
                .unwrap_or_else(|e| panic!("{backend}/{method:?}: {e}"));
            assert_eq!(r.method, method);
            assert!(r.p_value > 0.0 && r.p_value <= 1.0, "{backend}/{method:?}");
            let want_runs =
                if method == Method::PairwisePermanova { 3 } else { 1 };
            assert_eq!(r.runs.len(), want_runs, "{backend}/{method:?}");
        }
    }
}

/// Typo'd backend names come back with a did-you-mean suggestion.
#[test]
fn unknown_backend_suggests_nearest() {
    let e = cfg("native-batched", 24, 2, 9).validate().unwrap_err().to_string();
    assert!(e.contains("did you mean \"native-batch\"?"), "{e}");
}

/// The registry is the single source of backend names: configs validate
/// against it and unknown names fail with the known set in the message.
#[test]
fn registry_governs_config_validation() {
    let names = known_backends();
    for required in [
        "native",
        "native-brute",
        "native-tiled",
        "native-flat",
        "native-batch",
        "simulator",
        "xla",
    ] {
        assert!(names.iter().any(|n| n == required), "registry missing {required}");
    }
    assert!(cfg("native-tiled", 24, 2, 9).validate().is_ok());
    let err = cfg("warp-drive", 24, 2, 9).validate().unwrap_err().to_string();
    assert!(err.contains("warp-drive") && err.contains("simulator"), "{err}");

    let registry = Registry::with_defaults();
    assert!(registry.create("warp-drive", &cfg("native", 24, 2, 9)).is_err());
}

/// Scheduling knobs (threads, shard size, SMT oversubscription) never
/// change statistics — the determinism contract of the shard scheduler,
/// observed through the public engine.
#[test]
fn scheduling_is_statistically_invisible() {
    let base_cfg = cfg("native-tiled", 48, 3, 49);
    let (mat, grouping) = permanova_apu::coordinator::load_data_dense(&base_cfg).unwrap();
    let base = execute(&base_cfg, &mat, &grouping).unwrap();
    for (threads, shard, smt) in [(1usize, 1usize, false), (4, 7, false), (3, 1000, true)] {
        let mut c = base_cfg.clone();
        c.threads = threads;
        c.shard_size = shard;
        c.smt_oversubscribe = smt;
        let r = execute(&c, &mat, &grouping).unwrap();
        assert_eq!(base.f_obs, r.f_obs);
        assert_eq!(base.f_perms, r.f_perms);
        assert_eq!(base.p_value, r.p_value);
    }
}

/// Planted structure must be significant through every native backend —
/// an end-to-end sanity check that the engine feeds real data through.
#[test]
fn planted_structure_detected_by_all_backends() {
    let n = 45;
    let k = 3;
    let mat = DistanceMatrix::planted_blocks(n, k, 0.2, 1.0, 11);
    let grouping = Grouping::balanced(n, k).unwrap();
    for name in ["native-brute", "native-tiled", "native-flat", "native-batch", "simulator"] {
        let r = execute(&cfg(name, n, k, 199), &mat, &grouping).unwrap();
        assert!(r.p_value <= 0.01, "{name}: p = {}", r.p_value);
        assert!(r.f_obs > 10.0, "{name}: F = {}", r.f_obs);
    }
}
