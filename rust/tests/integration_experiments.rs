//! Integration: the experiment surface (Figure 1, Appendix A1/A2) through
//! the public API — the assertions EXPERIMENTS.md's claims rest on.

use permanova_apu::cli::{dispatch, Args};
use permanova_apu::simulator::{
    fig1_rows, paper_a2_reference, simulate_stream, Mi300a, NodeTopology, StreamDevice, Workload,
};

fn cli(v: &[&str]) -> String {
    dispatch(&Args::parse(&v.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()).unwrap()
}

/// FIG1: the complete claim set of the paper's one figure, via public API.
#[test]
fn fig1_claims() {
    let rows = fig1_rows(&Mi300a::default(), &Workload::paper());
    let by = |label: &str| rows.iter().find(|r| r.label == label).unwrap().seconds;

    let cpu_brute = by("CPU brute force (no SMT)");
    let cpu_brute_smt = by("CPU brute force (SMT)");
    let cpu_tiled = by("CPU tiled (no SMT)");
    let cpu_tiled_smt = by("CPU tiled (SMT)");
    let gpu_brute = by("GPU brute force");
    let gpu_tiled = by("GPU tiled");

    // §3: "the GPU implementation is over 6x faster" (vs brute non-SMT CPU).
    let headline = cpu_brute / gpu_brute;
    assert!(headline > 6.0, "headline speedup {headline:.2}");

    // §3: "the more flexible nature of the CPU [...] claw back some of that
    // advantage [...] especially noticeable when paired with SMT".
    assert!(cpu_tiled < cpu_brute);
    assert!(cpu_tiled_smt < cpu_tiled);
    assert!(cpu_brute_smt < cpu_brute);
    let clawed = cpu_brute / cpu_tiled_smt;
    assert!(clawed > 1.5, "tiled+SMT claws back {clawed:.2}x");
    // ... but does not overturn the GPU win:
    assert!(gpu_brute < cpu_tiled_smt);

    // §2: "any attempt to tile the [GPU] algorithm resulted in drastically
    // slower execution".
    assert!(gpu_tiled / gpu_brute > 3.0);
}

/// A2: simulated STREAM matches every printed number within 2%.
#[test]
fn a2_claims() {
    let m = Mi300a::default();
    for dev in [StreamDevice::Cpu, StreamDevice::Gpu] {
        let sim = simulate_stream(&m, dev, 1_000_000_000);
        for (kernel, want) in paper_a2_reference(dev) {
            let got = sim.iter().find(|r| r.kernel == kernel).unwrap().best_rate_mbs;
            assert!(((got - want) / want).abs() < 0.02, "{dev:?} {kernel:?}");
        }
    }
    // "GPU cores report approximately 3.0 TB/s, while the CPU cores report
    // approximately 0.2 TB/s".
    let cpu = simulate_stream(&m, StreamDevice::Cpu, 1 << 20)[3].best_rate_mbs;
    let gpu = simulate_stream(&m, StreamDevice::Gpu, 1 << 20)[3].best_rate_mbs;
    assert!((cpu / 1e6 - 0.2).abs() < 0.05, "CPU ~0.2 TB/s, got {cpu}");
    assert!((gpu / 1e6 - 3.0).abs() < 0.3, "GPU ~3.0 TB/s, got {gpu}");
}

/// A1: the topology module reproduces the printed lscpu facts and the
/// paper's exact pinning line.
#[test]
fn a1_claims() {
    let t = NodeTopology::cosmos_node();
    assert_eq!(t.logical_cpus(), 192);
    assert_eq!(t.cpuset_for_apu(0, true), "0-23,96-119"); // the taskset line
    let render = t.render();
    for needle in [
        "CPU(s):               192",
        "Thread(s) per core:   2",
        "Core(s) per socket:   24",
        "Socket(s):            4",
        "L3:                   384 MiB (12 instances)",
        "NUMA node(s):         4",
    ] {
        assert!(render.contains(needle), "missing {needle:?}");
    }
}

/// The experiment CLIs run end-to-end and carry their key numbers.
#[test]
fn experiment_clis() {
    let fig1 = cli(&["fig1"]);
    assert!(fig1.contains("GPU brute vs CPU brute (no SMT):"));

    let sim = cli(&["simulate"]);
    assert!(sim.contains("CPU tiled (SMT)"));
    assert!(sim.contains("Memory"));

    let topo = cli(&["simulate", "--topology"]);
    assert!(topo.contains("0-23,96-119"));

    let a2 = cli(&["stream", "--simulate"]);
    assert!(a2.contains("Triad:"));
    // Every simulated-vs-paper delta under 2%.
    for line in a2.lines().filter(|l| l.contains('%')) {
        let pct: f64 = line
            .rsplit_once(|c| c == '+' || c == '-')
            .and_then(|(_, p)| p.trim_end_matches('%').parse().ok())
            .unwrap_or(0.0);
        assert!(pct.abs() < 2.0, "delta too large: {line}");
    }
}

/// Workload arithmetic: the paper's §2 envelope quantities.
#[test]
fn workload_envelope() {
    // "a distance matrix between 1k^2 and 100k^2 elements, and [...]
    // between 1k and 1M permutations"
    let small = Workload { n_dims: 1_000, n_perms: 1_000, n_groups: 4 };
    let large = Workload { n_dims: 100_000, n_perms: 1_000_000, n_groups: 4 };
    assert_eq!(small.matrix_bytes(), 4_000_000);
    assert_eq!(large.matrix_bytes(), 40_000_000_000);
    // The paper's own point: ~2.5 GB matrix, ~5 TB of streaming at 3999 perms.
    let paper = Workload::paper();
    let gb = paper.matrix_bytes() as f64 / 1e9;
    assert!((2.4..2.7).contains(&gb), "matrix {gb} GB");
}
