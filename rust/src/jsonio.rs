//! Minimal JSON: parse + serialize.
//!
//! The offline crate set has no `serde` facade, and the library needs JSON
//! in exactly two places — reading `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and writing machine-readable run/bench reports.
//! A few hundred lines of recursive-descent parser cover both, with real
//! error positions.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.  Numbers are kept as f64 (the manifest only carries small
/// ints and floats); object keys are sorted (BTreeMap) so serialization is
/// deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing content after document"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors (manifest reading convenience) ----

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric content, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Number as usize (rejects negatives / fractions, and values at or
    /// above the serializer's conservative 9.0e15 bound — just under
    /// 2^53, where f64 stops representing integers exactly; a huge float
    /// must not silently saturate to `usize::MAX`).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < 9.0e15 => Some(*x as usize),
            _ => None,
        }
    }

    /// Array items, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Boolean content, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Number as u64 (rejects negatives and fractions).  Numbers are f64
    /// internally, so big values are rejected rather than silently
    /// rounded; the cutoff is the serializer's conservative 9.0e15 bound
    /// (just under 2^53) — pass big seeds as strings (see [`opt_u64`]).
    ///
    /// [`opt_u64`]: Self::opt_u64
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < 9.0e15 => Some(*x as u64),
            _ => None,
        }
    }

    /// Required typed field accessors with contextual errors.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| Error::parse("json", key.to_string(), "missing/not a string"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| {
                Error::parse("json", key.to_string(), "missing/not a non-negative integer")
            })
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::parse("json", key.to_string(), "missing/not an array"))
    }

    /// Optional typed accessors: `Ok(None)` when the key is absent, `Err`
    /// when it is present with the wrong type — so a mistyped field in a
    /// job request fails loudly instead of silently taking the default.
    pub fn opt_str(&self, key: &str) -> Result<Option<&str>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .as_str()
                .map(Some)
                .ok_or_else(|| Error::parse("json", key.to_string(), "not a string")),
        }
    }

    pub fn opt_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v.as_usize().map(Some).ok_or_else(|| {
                Error::parse("json", key.to_string(), "not a non-negative integer")
            }),
        }
    }

    /// Optional u64: accepts a JSON number (< 2^53) or a decimal string
    /// (full 64-bit range — how bench records seeds).
    pub fn opt_u64(&self, key: &str) -> Result<Option<u64>> {
        match self.get(key) {
            None => Ok(None),
            Some(Json::Str(s)) => s.parse::<u64>().map(Some).map_err(|_| {
                Error::parse("json", key.to_string(), format!("{s:?} is not a u64"))
            }),
            Some(v) => v
                .as_u64()
                .map(Some)
                .ok_or_else(|| Error::parse("json", key.to_string(), "not a u64")),
        }
    }

    pub fn opt_bool(&self, key: &str) -> Result<Option<bool>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .as_bool()
                .map(Some)
                .ok_or_else(|| Error::parse("json", key.to_string(), "not a boolean")),
        }
    }

    // ---- builders (report writing convenience) ----

    /// Object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// String value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Number value.
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> Error {
        // Compute line/col for the error message.
        let mut line = 1usize;
        let mut col = 1usize;
        for &c in &self.b[..self.pos.min(self.b.len())] {
            if c == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Error::parse("json", format!("line {line} col {col}"), msg)
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (the manifest never leaves ASCII).
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| self.err(format!("bad number {text:?}: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}, "f": -0.25}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "c"
        );
        assert_eq!(v.get("f").unwrap().as_f64().unwrap(), -0.25);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let doc = r#"{"name":"x","vals":[1,2.5,true,null],"nested":{"k":"v \"q\""}}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("line1\nline2\t\"quoted\" \\ \u{1}".into());
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn errors_have_positions() {
        let err = Json::parse("{\n  \"a\": }").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(Json::parse("").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} extra").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 512, "s": "x", "a": [1], "neg": -1, "fr": 1.5}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 512);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_arr("a").unwrap().len(), 1);
        assert!(v.req_usize("neg").is_err());
        assert!(v.req_usize("fr").is_err());
        assert!(v.req_usize("missing").is_err());
        assert!(v.req_str("n").is_err());
        // Regression: above 2^53 a float is not an exact integer — reject
        // instead of silently saturating.
        let huge = Json::obj(vec![("x", Json::Num(1.0e300))]);
        assert!(huge.req_usize("x").is_err());
    }

    #[test]
    fn optional_accessors_distinguish_absent_from_mistyped() {
        let v = Json::parse(
            r#"{"s": "x", "n": 7, "b": true, "seed_str": "18446744073709551615", "f": 1.5}"#,
        )
        .unwrap();
        assert_eq!(v.opt_str("s").unwrap(), Some("x"));
        assert_eq!(v.opt_str("missing").unwrap(), None);
        assert!(v.opt_str("n").is_err(), "present but mistyped is an error");
        assert_eq!(v.opt_usize("n").unwrap(), Some(7));
        assert!(v.opt_usize("f").is_err());
        assert_eq!(v.opt_bool("b").unwrap(), Some(true));
        assert!(v.opt_bool("s").is_err());
        assert_eq!(v.opt_u64("n").unwrap(), Some(7));
        // Strings carry the full 64-bit range (bench-style seeds).
        assert_eq!(v.opt_u64("seed_str").unwrap(), Some(u64::MAX));
        assert!(v.opt_u64("s").is_err());
        assert_eq!(v.opt_u64("absent").unwrap(), None);
        // 2^53-and-above numbers are rejected, not rounded.
        let big = Json::obj(vec![("x", Json::num(9.1e15))]);
        assert!(big.opt_u64("x").is_err());
    }

    #[test]
    fn builders() {
        let v = Json::obj(vec![
            ("x", Json::num(3)),
            ("y", Json::str("z")),
            ("l", Json::Arr(vec![Json::Bool(false)])),
        ]);
        let p = Json::parse(&v.to_string()).unwrap();
        assert_eq!(p, v);
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::num(3).to_string(), "3");
        assert_eq!(Json::num(3.5).to_string(), "3.5");
    }

    #[test]
    fn manifest_shaped_document() {
        let doc = r#"{
          "version": 1,
          "interchange": "hlo-text",
          "artifacts": [
            {"name": "matmul_n64_b16_k4", "file": "matmul_n64_b16_k4.hlo.txt",
             "kernel": "matmul", "n_dims": 64, "batch": 16, "n_groups": 4,
             "inputs": [{"name": "mat", "shape": [64, 64], "dtype": "f32"}],
             "outputs": [{"name": "f_stats", "shape": [16], "dtype": "f32"}]}
          ]
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.req_usize("version").unwrap(), 1);
        let arts = v.req_arr("artifacts").unwrap();
        assert_eq!(arts[0].req_usize("n_dims").unwrap(), 64);
        assert_eq!(
            arts[0].req_arr("inputs").unwrap()[0].req_str("dtype").unwrap(),
            "f32"
        );
    }
}
