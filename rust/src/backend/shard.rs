//! The sharded permutation scheduler: one thread-pool implementation for
//! every execution path in the crate.
//!
//! Before this module existed, `permanova/batch.rs`, the coordinator's
//! scheduler and the STREAM benchmark each hand-rolled their own
//! `std::thread::scope` pool (atomic cursor + raw output pointers,
//! duplicated three times).  All of that now lives here:
//!
//! * [`ShardSpec`] — the scheduling knobs: shard size, worker count, and the
//!   paper's SMT-style 2-threads-per-worker oversubscription toggle (the
//!   Figure 1 ablation is "same cores, 1 vs 2 threads per core");
//! * [`ShardCursor`] — the work-stealing claim primitive (disjoint
//!   `[start, end)` ranges from a shared atomic cursor);
//! * [`run_sharded`] / [`run_sharded_with`] — fill a disjoint output slice
//!   per shard, with optional per-worker scratch state (the only `unsafe`
//!   in the permutation hot path lives in this function);
//! * [`with_static_pool`] — the persistent, barrier-synchronized,
//!   statically-partitioned pool STREAM needs (timed regions must exclude
//!   thread spawn, as OpenMP's do);
//! * [`with_shared_pool`] / [`SharedPool`] — the service layer's persistent
//!   work-crew: one set of worker threads serving *every* sharded run
//!   dispatched inside its scope, so a batch of engine jobs shares one
//!   pool instead of spawning one per call.  While a shared pool is active
//!   on the dispatching thread, [`run_sharded_with`] routes through it
//!   transparently — backends need no changes.
//!
//! Determinism contract: results never depend on the shard size, worker
//! count, SMT setting or whether a shared pool served the run — every
//! output index is computed independently.  The tests at the bottom pin
//! that contract.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Barrier, Condvar, Mutex};

/// Scheduling knobs for one sharded run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Permutations per shard; 0 picks a size that gives each thread ~8
    /// claims (big enough to amortize the atomic, small enough to balance).
    pub shard_size: usize,
    /// Worker slots; 0 = all available hardware threads.
    pub workers: usize,
    /// SMT-style oversubscription: spawn 2 threads per worker slot.  This
    /// mirrors the paper's SMT ablation ("same cores, 1 vs 2 threads per
    /// core") when `workers` is pinned to a physical-core count.
    pub smt: bool,
}

impl Default for ShardSpec {
    fn default() -> Self {
        ShardSpec { shard_size: 0, workers: 0, smt: false }
    }
}

impl ShardSpec {
    /// Spec with an explicit worker count (0 = all available), no
    /// oversubscription, automatic shard size.
    pub fn with_workers(workers: usize) -> Self {
        ShardSpec { workers, ..Default::default() }
    }

    /// Number of OS threads this spec resolves to.
    pub fn threads(&self) -> usize {
        let slots = if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        };
        if self.smt {
            slots * 2
        } else {
            slots
        }
    }

    /// Shard size for `total` items on `threads` threads.
    pub fn shard_for(&self, total: usize, threads: usize) -> usize {
        if self.shard_size > 0 {
            self.shard_size
        } else {
            (total / (threads.max(1) * 8)).max(1)
        }
    }

    /// Spec whose resolved shard size is rounded **up** to a whole multiple
    /// of `block`.  Block-granular engines form their blocks inside shards,
    /// so without this the auto shard size (`total / (threads · 8)`) would
    /// silently clip every block below the requested width — e.g. 100
    /// permutations on 4 threads auto-shards at 3, degenerating a 64-lane
    /// block to 3 lanes.  Rounding up guarantees every non-tail block is
    /// full-width while keeping work-stealing granularity as close to the
    /// spec's intent as possible.
    pub fn aligned_to_block(&self, total: usize, block: usize) -> ShardSpec {
        let block = block.max(1);
        let threads = self.threads().min(total.max(1)).max(1);
        let shard = self.shard_for(total, threads).div_ceil(block) * block;
        ShardSpec { shard_size: shard, ..*self }
    }
}

/// One claimed range of work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    pub start: usize,
    pub end: usize,
}

impl Shard {
    /// Items in the shard.
    pub fn len(&self) -> usize {
        self.end - self.start
    }
}

/// Work-stealing cursor over `[0, total)`: every [`claim`](Self::claim)
/// returns a disjoint range (or `None` when the work is exhausted), so fast
/// workers naturally take more shards.
#[derive(Debug)]
pub struct ShardCursor {
    next: AtomicUsize,
    total: usize,
}

impl ShardCursor {
    /// Cursor over `[0, total)`.
    pub fn new(total: usize) -> Self {
        ShardCursor { next: AtomicUsize::new(0), total }
    }

    /// Claim the next shard of at most `size` items.
    pub fn claim(&self, size: usize) -> Option<Shard> {
        let size = size.max(1);
        let start = self.next.fetch_add(size, Ordering::Relaxed);
        if start >= self.total {
            return None;
        }
        Some(Shard { start, end: (start + size).min(self.total) })
    }

    /// Total items the cursor covers.
    pub fn total(&self) -> usize {
        self.total
    }
}

/// Raw pointer wrapper so scoped workers can write disjoint output ranges.
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

/// Fill `out` (length `total`) by sharding `[0, total)` across the spec's
/// threads.  `fill(state, start, slice)` writes the results for plan
/// indices `[start, start + slice.len())` into `slice`; `init` builds one
/// scratch state per worker (e.g. a label-row buffer), so the hot loop
/// allocates nothing.
///
/// Single-threaded specs (or trivially small runs) execute inline with no
/// thread spawn at all.
pub fn run_sharded_with<T, S, G, F>(spec: &ShardSpec, out: &mut [T], init: G, fill: F)
where
    T: Send,
    G: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &mut [T]) + Sync,
{
    let total = out.len();
    if total == 0 {
        return;
    }
    let threads = spec.threads().min(total).max(1);
    if threads <= 1 {
        let mut state = init();
        fill(&mut state, 0, out);
        return;
    }
    let shard = spec.shard_for(total, threads);
    let cursor = ShardCursor::new(total);
    let base = SendPtr(out.as_mut_ptr());

    let worker = |t: usize| {
        // Cap participation at the spec's thread count: a pool wider than
        // the request leaves its extra workers idle, so the `threads` knob
        // keeps bounding parallelism.  (Results are t-independent either
        // way — the cursor hands out disjoint ranges.)
        if t >= threads {
            return;
        }
        let base = &base;
        let mut state = init();
        while let Some(sh) = cursor.claim(shard) {
            // SAFETY: `claim` hands out disjoint [start, end) ranges
            // within `out`, which outlives this call; no other code
            // touches `out` while the workers run.
            let slice =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(sh.start), sh.len()) };
            fill(&mut state, sh.start, slice);
        }
    };

    // A shared pool registered on this thread serves the run with its
    // persistent workers; otherwise spawn a scoped crew for just this call.
    if let Some(pool) = SharedPool::current() {
        pool.run(&worker);
        return;
    }
    std::thread::scope(|s| {
        for t in 0..threads {
            let worker = &worker;
            s.spawn(move || worker(t));
        }
    });
}

/// The out-of-core chunk-sweep pass: for each `(r0, r1)` row range in
/// `plan`, load the chunk **once** (serially, on the driver thread) and
/// have the full shard × SMT crew sweep it across every output lane before
/// the next chunk is paged in.  This is the loop inversion that bounds
/// residency — per batch, each chunk crosses the disk exactly once, and the
/// sweep inside a chunk is the ordinary [`run_sharded_with`] schedule.
///
/// `fill(state, chunk, r0, r1, start, slice)` must *accumulate* into
/// `slice` (carried across chunks; the caller zeroes `out` once), with rows
/// ascending per lane — that is what keeps the concatenated chunk sweeps
/// bitwise identical to a resident whole-triangle sweep.  A `load` error
/// aborts the pass with output lanes mid-accumulation; callers propagate
/// the error and discard `out`.
pub fn run_chunk_sweep<T, S, C, E, L, G, F>(
    spec: &ShardSpec,
    out: &mut [T],
    plan: &[(usize, usize)],
    mut load: L,
    init: G,
    fill: F,
) -> std::result::Result<(), E>
where
    T: Send,
    C: Sync,
    L: FnMut(usize, usize) -> std::result::Result<C, E>,
    G: Fn() -> S + Sync,
    F: Fn(&mut S, &C, usize, usize, usize, &mut [T]) + Sync,
{
    if out.is_empty() {
        return Ok(());
    }
    for &(r0, r1) in plan {
        let chunk = load(r0, r1)?;
        run_sharded_with(spec, out, &init, |state, start, slice| {
            fill(state, &chunk, r0, r1, start, slice)
        });
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// The shared work-crew: one persistent pool for a whole batch of jobs.
// ---------------------------------------------------------------------------

/// The job a [`SharedPool`] is currently running, type-erased through a
/// thin-pointer trampoline (no fat-pointer lifetime juggling): `call`
/// invokes the borrowed closure behind `data` with a worker index.
#[derive(Clone, Copy)]
struct PoolJob {
    call: Option<unsafe fn(*const (), usize)>,
    data: *const (),
}

// SAFETY: the pointer is only dereferenced between the dispatch barriers,
// while `SharedPool::run` keeps the closure borrowed on the driver thread.
unsafe impl Send for PoolJob {}

unsafe fn pool_trampoline<F: Fn(usize) + Sync>(data: *const (), t: usize) {
    // SAFETY: `data` was cast from `&F` in `SharedPool::run`, which blocks
    // until every worker is done with it.
    unsafe { (*(data as *const F))(t) }
}

/// Handle to a running shared worker crew (see [`with_shared_pool`]).
///
/// While registered as the dispatching thread's ambient pool, every
/// [`run_sharded_with`] / [`run_sharded`] call routes through it — so a
/// batch of engine jobs reuses one set of threads instead of spawning a
/// scoped crew per call.
pub struct SharedPool<'env> {
    threads: usize,
    barrier: &'env Barrier,
    job: &'env Mutex<PoolJob>,
    dispatched: &'env AtomicUsize,
    /// The first worker panic message of the current job, if any (the
    /// panic is caught so the worker still reaches its barrier; `run`
    /// re-raises it with this message, so containment layers above —
    /// the daemon's per-job catch — can report the real cause).
    panicked: &'env Mutex<Option<String>>,
}

thread_local! {
    /// The shared pool ambient on this thread (null = none).  Stored as a
    /// type-erased raw pointer; only valid inside the registering
    /// [`with_shared_pool`] driver's dynamic extent.
    static AMBIENT_POOL: Cell<*const ()> = const { Cell::new(std::ptr::null()) };
}

/// Restores the previously ambient pool when dropped.
struct AmbientGuard(*const ());

impl Drop for AmbientGuard {
    fn drop(&mut self) {
        AMBIENT_POOL.with(|c| c.set(self.0));
    }
}

/// Releases a shared pool's workers into shutdown when dropped — on the
/// normal path *and* when the driver panics, so an unwinding driver can't
/// leave the crew parked at the barrier and deadlock the scope join.
struct ShutdownGuard<'a> {
    job: &'a Mutex<PoolJob>,
    barrier: &'a Barrier,
}

impl Drop for ShutdownGuard<'_> {
    fn drop(&mut self) {
        *self.job.lock().unwrap() = PoolJob { call: None, data: std::ptr::null() };
        self.barrier.wait();
    }
}

impl<'env> SharedPool<'env> {
    /// Worker threads in the crew.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Jobs dispatched through the pool so far (sharded runs served).
    pub fn jobs_dispatched(&self) -> usize {
        self.dispatched.load(Ordering::Relaxed)
    }

    /// Run `work(t)` on every worker `t in 0..threads` and wait for all of
    /// them.  The closure is borrowed only for the duration of this call.
    ///
    /// A panic inside `work` on any worker is caught there (so every
    /// worker still reaches the join barrier — no deadlock) and re-raised
    /// here on the dispatching thread, matching the scoped-crew path's
    /// panic-at-join behaviour.  The re-raise carries the first worker's
    /// panic message, so a containment layer above (the daemon catching
    /// per job) can name the real cause in its `ok:false` response.
    pub fn run<F: Fn(usize) + Sync>(&self, work: &F) {
        *self.job.lock().unwrap() =
            PoolJob { call: Some(pool_trampoline::<F>), data: work as *const F as *const () };
        self.dispatched.fetch_add(1, Ordering::Relaxed);
        self.barrier.wait(); // release the workers
        self.barrier.wait(); // join the workers
        if let Some(msg) = self.panicked.lock().unwrap().take() {
            panic!("a shared-pool worker panicked while running a dispatched job: {msg}");
        }
    }

    /// The pool ambient on the calling thread, if any.
    fn current<'a>() -> Option<&'a SharedPool<'a>> {
        AMBIENT_POOL.with(|c| {
            let p = c.get();
            if p.is_null() {
                None
            } else {
                // SAFETY: non-null only inside `with_shared_pool`'s driver
                // extent, where the handle (and everything it borrows) is
                // alive on this thread's call stack.
                Some(unsafe { &*(p as *const SharedPool<'a>) })
            }
        })
    }
}

/// Spawn a persistent crew of `workers` threads (0 = all available), make
/// it the calling thread's **ambient** pool, and run `driver`.  Every
/// sharded run the driver performs — directly or deep inside
/// `backend::run_batch` — is served by this one crew; the pool tears down
/// when the driver returns, passing its value through.
///
/// This is the "one scheduler pool per batch, not per call" seam the
/// service layer leans on: thread spawn is paid once per batch, and the
/// scheduling knobs of each individual job still apply (a job wanting
/// fewer threads leaves the extra workers idle for that job).
pub fn with_shared_pool<R>(workers: usize, driver: impl FnOnce(&SharedPool<'_>) -> R) -> R {
    let threads = if workers > 0 {
        workers
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    let barrier = Barrier::new(threads + 1);
    let job = Mutex::new(PoolJob { call: None, data: std::ptr::null() });
    let dispatched = AtomicUsize::new(0);
    let panicked: Mutex<Option<String>> = Mutex::new(None);
    std::thread::scope(|s| {
        for t in 0..threads {
            let barrier = &barrier;
            let job = &job;
            let panicked = &panicked;
            s.spawn(move || loop {
                barrier.wait(); // wait for a dispatch (or shutdown)
                let slot = *job.lock().unwrap();
                match slot.call {
                    None => break,
                    // SAFETY: `run` keeps the closure alive until the
                    // second barrier below.  Catch a job panic so this
                    // worker still reaches that barrier — `run` re-raises
                    // it on the dispatching thread.
                    Some(call) => {
                        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || unsafe { call(slot.data, t) },
                        ));
                        if let Err(payload) = r {
                            let msg = payload
                                .downcast_ref::<&str>()
                                .map(|s| s.to_string())
                                .or_else(|| payload.downcast_ref::<String>().cloned())
                                .unwrap_or_else(|| "non-string panic payload".to_string());
                            // First panic wins; later ones raced it and
                            // would only overwrite the root cause.
                            let mut slot = panicked.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some(msg);
                            }
                        }
                    }
                }
                barrier.wait(); // job done
            });
        }
        let pool = SharedPool {
            threads,
            barrier: &barrier,
            job: &job,
            dispatched: &dispatched,
            panicked: &panicked,
        };
        let prev = AMBIENT_POOL.with(|c| c.replace(&pool as *const SharedPool<'_> as *const ()));
        // Drop runs in reverse declaration order, so an unwinding driver
        // first de-registers the ambient pool (`guard`), then releases the
        // crew into shutdown (`shutdown`) — no deadlock at the scope join.
        let shutdown = ShutdownGuard { job: &job, barrier: &barrier };
        let guard = AmbientGuard(prev);
        let out = driver(&pool);
        drop(guard); // de-register before tearing the crew down
        drop(shutdown); // release the workers into shutdown
        out
    })
}

/// Iterate `[start, start + len)` in consecutive blocks of at most `block`
/// items, calling `f(block_start, block_len)` for each.  This is how
/// block-granular backends (e.g. the batched brute engine's permutation
/// blocks) subdivide a scheduler shard: the cursor hands out shards, each
/// worker walks its shard block-by-block, and because every output index is
/// still computed independently the shard × block × SMT composition keeps
/// the scheduler's determinism contract.
pub fn for_each_block(start: usize, len: usize, block: usize, mut f: impl FnMut(usize, usize)) {
    let block = block.max(1);
    let mut off = 0;
    while off < len {
        let b = block.min(len - off);
        f(start + off, b);
        off += b;
    }
}

/// Stateless convenience over [`run_sharded_with`].
pub fn run_sharded<T, F>(spec: &ShardSpec, out: &mut [T], fill: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    run_sharded_with(spec, out, || (), |_, start, slice| fill(start, slice));
}

/// Job id meaning "shut down" inside [`with_static_pool`].
const POOL_QUIT: usize = usize::MAX;

/// Handle for dispatching jobs into a running static pool.
pub struct StaticPool<'a> {
    barrier: &'a Barrier,
    job: &'a AtomicUsize,
}

impl StaticPool<'_> {
    /// Run job `id` on every worker (each covers its static partition) and
    /// wait for all of them to finish.  The two barrier crossings bracket
    /// exactly the workers' compute, so a caller can time around this call
    /// without including thread spawn.
    pub fn run(&self, id: usize) {
        assert!(id != POOL_QUIT, "job id reserved for shutdown");
        self.job.store(id, Ordering::Release);
        self.barrier.wait(); // release workers
        self.barrier.wait(); // join workers
    }
}

/// Persistent, statically-partitioned worker pool (the STREAM shape).
///
/// Spawns `threads` workers, each owning the static range
/// `[total*t/threads, total*(t+1)/threads)`; `kernel(job, lo, hi)` runs one
/// job on one partition.  `driver` receives a [`StaticPool`] handle to
/// dispatch jobs; when it returns, the pool shuts down and its value is
/// passed through.
pub fn with_static_pool<F, D, R>(threads: usize, total: usize, kernel: &F, driver: D) -> R
where
    F: Fn(usize, usize, usize) + Sync,
    D: FnOnce(&StaticPool<'_>) -> R,
{
    let threads = threads.max(1);
    let barrier = Barrier::new(threads + 1);
    let job = AtomicUsize::new(POOL_QUIT - 1); // arbitrary non-quit idle value
    std::thread::scope(|s| {
        for t in 0..threads {
            let barrier = &barrier;
            let job = &job;
            let lo = total * t / threads;
            let hi = total * (t + 1) / threads;
            s.spawn(move || loop {
                barrier.wait(); // wait for a job
                let id = job.load(Ordering::Acquire);
                if id == POOL_QUIT {
                    break;
                }
                kernel(id, lo, hi);
                barrier.wait(); // job done
            });
        }
        let pool = StaticPool { barrier: &barrier, job: &job };
        let out = driver(&pool);
        job.store(POOL_QUIT, Ordering::Release);
        barrier.wait();
        out
    })
}

/// Bounded admission queue in front of a shared pool — the daemon's
/// load-shedding seam.
///
/// Producers (connection readers) call [`try_push`](Self::try_push):
/// admission is **non-blocking** and a full queue hands the item straight
/// back, so the caller can answer `retry_after` instead of buffering
/// without bound.  One consumer (the executor thread, running inside
/// [`with_shared_pool`]) calls [`pop`](Self::pop), which blocks until work
/// arrives and returns `None` once the queue is closed *and* drained —
/// exactly the graceful-drain order shutdown needs: close first (new work
/// sheds), then finish what was already admitted.
///
/// Memory stays bounded by construction: at most `capacity` items are
/// ever resident, and the admitted/rejected counters feed the daemon's
/// `stats` response.
pub struct AdmissionQueue<T> {
    inner: Mutex<AdmissionInner<T>>,
    ready: Condvar,
    capacity: usize,
    admitted: AtomicUsize,
    rejected: AtomicUsize,
}

struct AdmissionInner<T> {
    queue: VecDeque<T>,
    closed: bool,
}

impl<T> AdmissionQueue<T> {
    /// A queue admitting at most `capacity` (floor 1) queued items.
    pub fn new(capacity: usize) -> AdmissionQueue<T> {
        let capacity = capacity.max(1);
        AdmissionQueue {
            inner: Mutex::new(AdmissionInner {
                queue: VecDeque::with_capacity(capacity),
                closed: false,
            }),
            ready: Condvar::new(),
            capacity,
            admitted: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
        }
    }

    /// Maximum queued items.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Currently queued (admitted, not yet popped) items.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Items ever admitted.
    pub fn admitted(&self) -> usize {
        self.admitted.load(Ordering::Relaxed)
    }

    /// Items shed at admission (queue full or closed).
    pub fn rejected(&self) -> usize {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Non-blocking admission: `Ok(())` when the item was queued, else the
    /// item comes straight back (`Err`) because the queue is full
    /// (load-shed) or closed (draining).
    pub fn try_push(&self, item: T) -> std::result::Result<(), T> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed || inner.queue.len() >= self.capacity {
            drop(inner);
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(item);
        }
        inner.queue.push_back(item);
        drop(inner);
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.ready.notify_one();
        Ok(())
    }

    /// Blocking FIFO pop: waits for an item, `None` once the queue is
    /// closed and fully drained.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.queue.pop_front() {
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.ready.wait(inner).unwrap();
        }
    }

    /// Stop admitting: later pushes shed, already-admitted items still
    /// drain through [`pop`](Self::pop).
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.ready.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_thread_resolution() {
        assert_eq!(ShardSpec::with_workers(3).threads(), 3);
        assert_eq!(ShardSpec { workers: 3, smt: true, shard_size: 0 }.threads(), 6);
        assert!(ShardSpec::default().threads() >= 1);
    }

    #[test]
    fn spec_shard_sizing() {
        let auto = ShardSpec::default();
        assert_eq!(auto.shard_for(1000, 4), 31); // 1000 / 32
        assert_eq!(auto.shard_for(3, 8), 1); // floor at 1
        let fixed = ShardSpec { shard_size: 17, ..Default::default() };
        assert_eq!(fixed.shard_for(1000, 4), 17);
    }

    #[test]
    fn chunk_sweep_accumulates_each_chunk_once_per_lane() {
        // Each "chunk" contributes its row-range width; after the sweep,
        // every lane must hold the total width exactly once, regardless of
        // shard geometry — and the loader must run once per planned chunk.
        let plan = [(0usize, 3usize), (3, 7), (7, 20)];
        for spec in [
            ShardSpec::with_workers(1),
            ShardSpec { shard_size: 5, workers: 3, smt: false },
            ShardSpec { shard_size: 3, workers: 2, smt: true },
        ] {
            let mut out = vec![0u64; 33];
            let mut loads = 0usize;
            run_chunk_sweep(
                &spec,
                &mut out,
                &plan,
                |r0, r1| {
                    loads += 1;
                    Ok::<usize, ()>(r1 - r0)
                },
                || (),
                |_, width, _r0, _r1, _start, slice| {
                    for o in slice.iter_mut() {
                        *o += *width as u64;
                    }
                },
            )
            .unwrap();
            assert_eq!(loads, plan.len(), "one disk read per chunk per batch");
            assert!(out.iter().all(|&v| v == 20), "spec={spec:?} out={out:?}");
        }
    }

    #[test]
    fn chunk_sweep_propagates_load_errors_and_skips_empty_output() {
        let mut out = vec![0u8; 4];
        let err = run_chunk_sweep(
            &ShardSpec::with_workers(2),
            &mut out,
            &[(0, 2), (2, 4)],
            |r0, _| if r0 == 2 { Err("boom") } else { Ok(0usize) },
            || (),
            |_, _, _, _, _, _: &mut [u8]| {},
        );
        assert_eq!(err, Err("boom"));

        let mut empty: Vec<u8> = Vec::new();
        let mut loads = 0;
        run_chunk_sweep(
            &ShardSpec::default(),
            &mut empty,
            &[(0, 2)],
            |_, _| {
                loads += 1;
                Ok::<usize, ()>(0)
            },
            || (),
            |_, _, _, _, _, _: &mut [u8]| {},
        )
        .unwrap();
        assert_eq!(loads, 0, "empty output pages nothing");
    }

    #[test]
    fn cursor_covers_exactly_once() {
        let c = ShardCursor::new(103);
        let mut seen = vec![false; 103];
        while let Some(sh) = c.claim(7) {
            for i in sh.start..sh.end {
                assert!(!seen[i], "index {i} claimed twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "coverage hole");
        assert!(c.claim(7).is_none(), "exhausted cursor stays exhausted");
    }

    #[test]
    fn cursor_zero_size_claims_one() {
        let c = ShardCursor::new(2);
        assert_eq!(c.claim(0), Some(Shard { start: 0, end: 1 }));
    }

    #[test]
    fn aligned_shard_size_is_a_block_multiple() {
        // Auto sizing for 100 items on 4 workers picks 3-item shards, which
        // would clip a 64-lane block; alignment floors it at one full block.
        assert_eq!(ShardSpec::with_workers(4).aligned_to_block(100, 64).shard_size, 64);
        // Whatever the host's auto sizing, the result is a block multiple.
        let a = ShardSpec::default().aligned_to_block(1000, 64);
        assert!(a.shard_size >= 64 && a.shard_size % 64 == 0, "{}", a.shard_size);
        // Explicit shard sizes are rounded up, never down.
        let exp = ShardSpec { shard_size: 100, workers: 2, smt: false }.aligned_to_block(1000, 8);
        assert_eq!(exp.shard_size, 104);
        // Block 1 (or 0) keeps the spec's own sizing.
        let keep = ShardSpec { shard_size: 7, workers: 2, smt: false }.aligned_to_block(100, 1);
        assert_eq!(keep.shard_size, 7);
        // Worker/SMT knobs pass through untouched.
        let s = ShardSpec { shard_size: 0, workers: 3, smt: true }.aligned_to_block(64, 16);
        assert_eq!((s.workers, s.smt), (3, true));
    }

    #[test]
    fn blocks_tile_a_range_exactly() {
        for (start, len, block) in [(0, 10, 3), (7, 23, 8), (5, 4, 100), (0, 0, 4)] {
            let mut covered = Vec::new();
            let mut calls = 0usize;
            for_each_block(start, len, block, |lo, b| {
                assert!((1..=block).contains(&b), "block len {b}");
                for i in lo..lo + b {
                    covered.push(i);
                }
                calls += 1;
            });
            let want: Vec<usize> = (start..start + len).collect();
            assert_eq!(covered, want, "start={start} len={len} block={block}");
            assert_eq!(calls, len.div_ceil(block), "full blocks plus one remainder");
        }
    }

    #[test]
    fn zero_block_size_claims_one_at_a_time() {
        let mut calls = 0;
        for_each_block(0, 3, 0, |_, b| {
            assert_eq!(b, 1);
            calls += 1;
        });
        assert_eq!(calls, 3);
    }

    #[test]
    fn run_sharded_fills_every_slot() {
        for workers in [1usize, 2, 3, 8] {
            let spec = ShardSpec { shard_size: 5, workers, smt: false };
            let mut out = vec![0usize; 237];
            run_sharded(&spec, &mut out, |start, slice| {
                for (i, v) in slice.iter_mut().enumerate() {
                    *v = (start + i) * 3;
                }
            });
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, i * 3, "workers={workers} slot {i}");
            }
        }
    }

    #[test]
    fn run_sharded_with_per_worker_state() {
        let spec = ShardSpec { shard_size: 4, workers: 4, smt: true };
        let mut out = vec![0u64; 100];
        run_sharded_with(
            &spec,
            &mut out,
            || vec![0u8; 16], // scratch: exists per worker, never shared
            |scratch, start, slice| {
                scratch[0] = scratch[0].wrapping_add(1);
                for (i, v) in slice.iter_mut().enumerate() {
                    *v = (start + i) as u64 + 1;
                }
            },
        );
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64 + 1));
    }

    #[test]
    fn run_sharded_empty_and_tiny() {
        let mut empty: Vec<u32> = Vec::new();
        run_sharded(&ShardSpec::default(), &mut empty, |_, _| panic!("no work"));
        let mut one = vec![0u32; 1];
        run_sharded(&ShardSpec::with_workers(8), &mut one, |start, s| {
            assert_eq!(start, 0);
            s[0] = 9;
        });
        assert_eq!(one, vec![9]);
    }

    #[test]
    fn sharding_is_deterministic_across_specs() {
        let compute = |spec: &ShardSpec| {
            let mut out = vec![0.0f32; 333];
            run_sharded(spec, &mut out, |start, slice| {
                for (i, v) in slice.iter_mut().enumerate() {
                    let x = (start + i) as f32;
                    *v = x.sqrt() * 1.5;
                }
            });
            out
        };
        let base = compute(&ShardSpec::with_workers(1));
        for spec in [
            ShardSpec::with_workers(2),
            ShardSpec { shard_size: 1, workers: 7, smt: false },
            ShardSpec { shard_size: 100, workers: 3, smt: true },
            ShardSpec::default(),
        ] {
            assert_eq!(base, compute(&spec), "{spec:?}");
        }
    }

    #[test]
    fn shared_pool_serves_sharded_runs_unchanged() {
        let compute = || {
            let mut out = vec![0.0f32; 333];
            run_sharded(
                &ShardSpec { shard_size: 10, workers: 4, smt: false },
                &mut out,
                |start, slice| {
                    for (i, v) in slice.iter_mut().enumerate() {
                        let x = (start + i) as f32;
                        *v = x.sqrt() * 1.5;
                    }
                },
            );
            out
        };
        let base = compute();
        with_shared_pool(3, |pool| {
            assert_eq!(pool.threads(), 3);
            assert_eq!(pool.jobs_dispatched(), 0);
            for round in 1..=4 {
                assert_eq!(base, compute(), "round {round}");
                assert_eq!(pool.jobs_dispatched(), round, "one dispatch per sharded run");
            }
        });
        // The guard de-registers the pool: runs after the scope still work.
        assert_eq!(base, compute());
    }

    #[test]
    fn shared_pool_skips_single_threaded_runs() {
        with_shared_pool(2, |pool| {
            let mut out = vec![0u32; 50];
            run_sharded(&ShardSpec::with_workers(1), &mut out, |start, slice| {
                for (i, v) in slice.iter_mut().enumerate() {
                    *v = (start + i) as u32;
                }
            });
            assert!(out.iter().enumerate().all(|(i, &v)| v == i as u32));
            assert_eq!(pool.jobs_dispatched(), 0, "inline runs bypass the pool");
        });
    }

    #[test]
    fn shared_pool_caps_participation_at_the_spec() {
        // A pool wider than the request must leave extra workers idle: the
        // dispatched closure sees worker indices up to the pool width, and
        // run_sharded's worker returns early for t >= spec threads.  Here we
        // drive `run` directly and count participants.
        with_shared_pool(4, |pool| {
            let seen = AtomicUsize::new(0);
            pool.run(&|t| {
                assert!(t < 4);
                seen.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(seen.load(Ordering::Relaxed), 4, "every worker runs the job once");
        });
    }

    #[test]
    fn shared_pool_propagates_worker_panics() {
        // A panicking job must surface on the dispatching thread (like the
        // scoped-crew path's panic-at-join), never deadlock the barrier.
        let caught = std::panic::catch_unwind(|| {
            with_shared_pool(2, |pool| {
                pool.run(&|t| {
                    if t == 0 {
                        panic!("boom");
                    }
                });
            })
        });
        assert!(caught.is_err(), "worker panic must surface");
        // The pool after a poisoned run is torn down cleanly; a fresh one
        // still works.
        with_shared_pool(2, |pool| {
            pool.run(&|_| {});
            assert_eq!(pool.jobs_dispatched(), 1);
        });
    }

    #[test]
    fn shared_pool_returns_driver_value_and_nests_runs() {
        let out = with_shared_pool(2, |_pool| {
            let mut v = vec![0usize; 64];
            run_sharded(&ShardSpec::with_workers(2), &mut v, |start, slice| {
                for (i, s) in slice.iter_mut().enumerate() {
                    *s = start + i;
                }
            });
            v.iter().sum::<usize>()
        });
        assert_eq!(out, 63 * 64 / 2);
    }

    #[test]
    fn static_pool_runs_jobs_on_partitions() {
        let n = 97;
        let mut data = vec![0u32; n];
        let ptr = SendPtr(data.as_mut_ptr());
        let kernel = |job: usize, lo: usize, hi: usize| {
            // SAFETY: each worker owns a disjoint [lo, hi) partition.
            let slice = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(lo), hi - lo) };
            for v in slice.iter_mut() {
                *v += 1 + job as u32;
            }
        };
        with_static_pool(3, n, &kernel, |pool| {
            pool.run(0); // +1 everywhere
            pool.run(4); // +5 everywhere
        });
        assert!(data.iter().all(|&v| v == 6), "{data:?}");
    }

    #[test]
    fn static_pool_returns_driver_value() {
        let out = with_static_pool(2, 10, &|_, _, _| {}, |pool| {
            pool.run(1);
            42usize
        });
        assert_eq!(out, 42);
    }

    #[test]
    fn admission_queue_sheds_on_full_and_counts() {
        let q = AdmissionQueue::new(2);
        assert_eq!(q.capacity(), 2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        // Full: the item comes straight back, memory stays bounded.
        assert_eq!(q.try_push(3), Err(3));
        assert_eq!(q.depth(), 2);
        assert_eq!((q.admitted(), q.rejected()), (2, 1));
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(4).is_ok(), "popping frees a slot");
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(4));
    }

    #[test]
    fn admission_queue_close_drains_then_ends() {
        let q = AdmissionQueue::new(4);
        q.try_push(7).unwrap();
        q.close();
        assert_eq!(q.try_push(8), Err(8), "closed queues shed new work");
        assert_eq!(q.pop(), Some(7), "admitted work still drains");
        assert_eq!(q.pop(), None, "drained + closed ends the consumer loop");
    }

    #[test]
    fn admission_queue_wakes_blocked_consumer() {
        let q = AdmissionQueue::new(1);
        std::thread::scope(|s| {
            let consumer = s.spawn(|| {
                let first = q.pop();
                let end = q.pop();
                (first, end)
            });
            // Zero-capacity floor is 1, so this admission succeeds even
            // before the consumer drains.
            while q.try_push(9).is_err() {
                std::thread::yield_now();
            }
            q.close();
            let (first, end) = consumer.join().unwrap();
            assert_eq!(first, Some(9));
            assert_eq!(end, None);
        });
    }
}
