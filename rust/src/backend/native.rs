//! Native CPU backend: the paper's kernel formulations on this host's
//! cores, scheduled by the shared shard scheduler.
//!
//! PERMANOVA batches run the backend's f32 formulation (`sw_one` with this
//! instance's [`SwAlgorithm`]) over the **packed triangle** carried by the
//! prelude ([`BatchPlan::condensed`]) — half the dense footprint per
//! sweep, bit-identical statistics; every other method delegates to the
//! generic f64 [`eval_plan_range`] loop through the same scheduler, so
//! shard / worker / SMT knobs behave identically across methods.

use std::time::Instant;

use super::shard::run_sharded_with;
use super::{Backend, BatchPlan, BatchResult, Caps};
use crate::config::RunConfig;
use crate::dmat::TriangleStorage;
use crate::error::Result;
use crate::permanova::{
    eval_plan_range, fstat_from_sw, sw_one, sw_plan_range_chunked, StatKernel, SwAlgorithm,
    DEFAULT_TILE,
};

/// Native Rust kernels (brute / tiled / flat) on host threads.
pub struct NativeBackend {
    algo: SwAlgorithm,
    /// Registry name this instance was created under.
    name: String,
}

impl NativeBackend {
    /// Backend for a fixed kernel formulation.
    pub fn new(algo: SwAlgorithm) -> Self {
        NativeBackend { name: format!("native-{}", algo.name()), algo }
    }

    /// The kernel formulation this backend evaluates.
    pub fn algo(&self) -> SwAlgorithm {
        self.algo
    }

    fn named(algo: SwAlgorithm, name: &str) -> Self {
        NativeBackend { algo, name: name.to_string() }
    }
}

impl Backend for NativeBackend {
    fn run_batch(&self, plan: &BatchPlan<'_>) -> Result<BatchResult> {
        let t0 = Instant::now();
        let n = plan.n();
        let k = plan.grouping.k();
        let stats = match plan.stat {
            // PERMANOVA: this backend's f32 kernel formulation over the
            // prelude's packed triangle (the canonical operand).  A
            // file-backed triangle runs the *same* formulation through the
            // chunk-major sweep — bitwise identical, paged residency.
            StatKernel::Permanova(pk) => {
                let algo = self.algo;
                let s_w = match &pk.storage {
                    TriangleStorage::Resident(packed) => {
                        let tri = packed.view();
                        let mut s_w = vec![0.0f32; plan.rows];
                        run_sharded_with(
                            &plan.shard,
                            &mut s_w,
                            || vec![0u32; n], // per-worker scratch label row
                            |row, start, slice| {
                                for (i, out) in slice.iter_mut().enumerate() {
                                    plan.perms.fill(plan.start + start + i, row);
                                    *out = sw_one(algo, tri, row, plan.grouping.inv_sizes());
                                }
                            },
                        );
                        s_w
                    }
                    TriangleStorage::FileBacked(file) => sw_plan_range_chunked(
                        file,
                        plan.perms,
                        plan.start,
                        plan.rows,
                        plan.grouping.inv_sizes(),
                        algo,
                        &plan.shard,
                    )?,
                };
                s_w.iter().map(|&sw| fstat_from_sw(sw as f64, pk.s_t, n, k)).collect()
            }
            // ANOSIM / PERMDISP: the generic f64 loop, same scheduler.
            stat => {
                eval_plan_range(stat, plan.grouping, plan.perms, plan.start, plan.rows, &plan.shard)
            }
        };
        Ok(BatchResult {
            start: plan.start,
            stats,
            elapsed_secs: t0.elapsed().as_secs_f64(),
            modelled_secs: None,
            backend: self.name.clone(),
        })
    }

    fn capabilities(&self) -> Caps {
        Caps {
            name: self.name.clone(),
            kernel: self.algo.name(),
            max_batch: None,
            threaded: true,
            modelled_time: false,
            perm_block: None,
        }
    }
}

/// `native`: kernel taken from the run configuration.
pub fn factory_from_config(cfg: &RunConfig) -> Result<Box<dyn Backend>> {
    Ok(Box::new(NativeBackend::named(cfg.algo, "native")))
}

/// `native-brute`: Algorithm 1.
pub fn factory_brute(_cfg: &RunConfig) -> Result<Box<dyn Backend>> {
    Ok(Box::new(NativeBackend::named(SwAlgorithm::Brute, "native-brute")))
}

/// `native-tiled`: Algorithm 2 with the paper-informed default tile.
pub fn factory_tiled(cfg: &RunConfig) -> Result<Box<dyn Backend>> {
    let tile = match cfg.algo {
        SwAlgorithm::Tiled { tile } => tile,
        _ => DEFAULT_TILE,
    };
    Ok(Box::new(NativeBackend::named(SwAlgorithm::Tiled { tile }, "native-tiled")))
}

/// `native-flat`: Algorithm 3's branchless/SIMD shape.
pub fn factory_flat(_cfg: &RunConfig) -> Result<Box<dyn Backend>> {
    Ok(Box::new(NativeBackend::named(SwAlgorithm::Flat, "native-flat")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ShardSpec;
    use crate::dmat::DistanceMatrix;
    use crate::permanova::{anosim, st_of, sw_brute_f64_dense, Grouping, Method};
    use crate::rng::PermutationPlan;

    fn plan_fixture(
        n: usize,
        k: usize,
        count: usize,
    ) -> (DistanceMatrix, Grouping, PermutationPlan) {
        let mat = DistanceMatrix::random_euclidean(n, 6, 3);
        let grouping = Grouping::balanced(n, k).unwrap();
        let perms = PermutationPlan::new(grouping.labels().to_vec(), 11, count);
        (mat, grouping, perms)
    }

    #[test]
    fn batch_matches_f64_oracle() {
        let (mat, grouping, perms) = plan_fixture(48, 4, 20);
        let s_t = st_of(&mat);
        let stat = StatKernel::prepare(Method::Permanova, &mat, &grouping).unwrap();
        let plan = BatchPlan {
            grouping: &grouping,
            perms: &perms,
            start: 0,
            rows: 20,
            stat: &stat,
            shard: ShardSpec::with_workers(3),
        };
        let b = NativeBackend::new(SwAlgorithm::Flat);
        let r = b.run_batch(&plan).unwrap();
        assert_eq!(r.stats.len(), 20);
        let mut row = vec![0u32; 48];
        for i in 0..20 {
            perms.fill(i, &mut row);
            let sw = sw_brute_f64_dense(mat.data(), 48, &row, grouping.inv_sizes());
            let want = fstat_from_sw(sw, s_t, 48, 4);
            let rel = (r.stats[i] - want).abs() / want.abs().max(1e-12);
            assert!(rel < 5e-4, "row {i}: {} vs {want}", r.stats[i]);
        }
    }

    #[test]
    fn anosim_batch_matches_the_oracle_wrapper() {
        // The generic method path: run_batch with an ANOSIM kernel must
        // reproduce the legacy wrapper's statistics exactly (same f64 ops).
        let (mat, grouping, perms) = plan_fixture(30, 3, 20);
        let stat = StatKernel::prepare(Method::Anosim, &mat, &grouping).unwrap();
        let plan = BatchPlan {
            grouping: &grouping,
            perms: &perms,
            start: 0,
            rows: 20,
            stat: &stat,
            shard: ShardSpec::with_workers(3),
        };
        let r = NativeBackend::new(SwAlgorithm::Tiled { tile: 64 }).run_batch(&plan).unwrap();
        assert_eq!(r.stats.len(), 20);
        let legacy = anosim(&mat, &grouping, 19, 11).unwrap();
        assert_eq!(r.stats[0], legacy.r_obs, "index 0 is the observed labelling");
        for (i, s) in r.stats.iter().enumerate() {
            assert!((-1.0..=1.0).contains(s), "perm {i}: R = {s}");
        }
    }

    #[test]
    fn sub_range_batches_line_up() {
        let (mat, grouping, perms) = plan_fixture(32, 4, 30);
        let stat = StatKernel::prepare(Method::Permanova, &mat, &grouping).unwrap();
        let b = NativeBackend::new(SwAlgorithm::Brute);
        let mk = |start: usize, rows: usize| BatchPlan {
            grouping: &grouping,
            perms: &perms,
            start,
            rows,
            stat: &stat,
            shard: ShardSpec::with_workers(2),
        };
        let full = b.run_batch(&mk(0, 30)).unwrap();
        let head = b.run_batch(&mk(0, 11)).unwrap();
        let tail = b.run_batch(&mk(11, 19)).unwrap();
        assert_eq!(&full.stats[..11], &head.stats[..]);
        assert_eq!(&full.stats[11..], &tail.stats[..]);
    }

    #[test]
    fn capabilities_name_tracks_registry_entry() {
        let cfg = RunConfig::default();
        let caps = factory_tiled(&cfg).unwrap().capabilities();
        assert_eq!(caps.name, "native-tiled");
        assert_eq!(caps.kernel, "tiled512");
        assert!(caps.threaded);
        assert!(!caps.modelled_time);
    }
}
