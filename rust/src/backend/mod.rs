//! The unified execution engine: one `Backend` trait for every way this
//! crate can compute a permutation-test batch.
//!
//! The paper's comparison only means something if the kernel formulations
//! (and the three compute substrates — native CPU, XLA/PJRT, simulated
//! MI300A) run through **one** schedulable path with the data path held
//! fixed.  That seam is this module — and since the permute-relabel-reduce
//! loop is the same for ANOSIM and PERMDISP, the engine is generic over
//! the *statistic* ([`Method`] / [`StatKernel`]), not hardwired to
//! PERMANOVA's pseudo-F:
//!
//! * [`Backend`] — `run_batch(&BatchPlan) -> BatchResult` plus
//!   [`capabilities`](Backend::capabilities);
//! * [`BatchPlan`] / [`BatchResult`] — the shared job and output shapes
//!   (seekable permutation plan + prepared [`StatKernel`] in — including
//!   the packed-triangle kernel operand, see [`BatchPlan::condensed`] —
//!   one statistic per permutation out);
//! * [`Registry`] — name-keyed factories (`--backend native-tiled`,
//!   `--backend simulator`, ...), the hook future backends plug into;
//! * [`execute`] — the config-driven entry: prepare the method's kernel,
//!   create the backend, run it, aggregate a method-tagged
//!   [`AnalysisReport`].  [`Method::PairwisePermanova`] fans out as one
//!   scheduled job per group pair.
//!
//! Scheduling (shard size, worker count, SMT oversubscription) is owned by
//! [`shard`] and threaded through every backend via [`BatchPlan::shard`].

pub mod shard;

mod batch;
mod native;
mod sim;
mod xla;

pub use batch::BatchedBruteBackend;
pub use native::NativeBackend;
pub use shard::{AdmissionQueue, ShardCursor, ShardSpec};
pub use sim::SimulatorBackend;
pub use xla::XlaBackend;

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::config::RunConfig;
use crate::dmat::{CondensedMatrix, DistanceMatrix, TriangleStorage};
use crate::error::{Error, Result};
use crate::permanova::{
    pairwise_seed, pairwise_subproblem_condensed, pvalue, Grouping, Method, StatKernel,
};
use crate::report::{AnalysisReport, DeviceStats, OocoreStats, PairSummary, RunReport};
use crate::rng::PermutationPlan;

/// One batch of permutation work, shared read-only with the backend.
///
/// Indices `[start, start + rows)` of `perms` are to be evaluated;
/// index 0 of the plan is always the observed labelling.
///
/// The plan is **dense-free**: the prepared [`StatKernel`] carries each
/// method's packed operand (PERMANOVA's condensed triangle, ANOSIM's rank
/// vector, PERMDISP's distance vector) and the grouping carries the
/// problem edge [`n`](Self::n) — no dense matrix exists for a backend to
/// reach for.  The one substrate that needs a dense staging buffer (XLA's
/// AOT artifacts take an `n×n` input) mirrors it on demand from the
/// triangle inside its own `run_batch`.
pub struct BatchPlan<'a> {
    pub grouping: &'a Grouping,
    pub perms: &'a PermutationPlan,
    /// First plan index of this batch.
    pub start: usize,
    /// Number of permutations to evaluate.
    pub rows: usize,
    /// The prepared statistic: which method to evaluate plus its
    /// permutation-invariant prelude (PERMANOVA's `s_T` and packed
    /// triangle, ANOSIM's condensed ranks, PERMDISP's
    /// distances-to-centroid).
    pub stat: &'a StatKernel,
    /// Scheduling knobs for whatever internal parallelism the backend has.
    pub shard: ShardSpec,
}

impl<'a> BatchPlan<'a> {
    /// Full-run plan over every index of `perms`.
    pub fn full(
        grouping: &'a Grouping,
        perms: &'a PermutationPlan,
        stat: &'a StatKernel,
        shard: ShardSpec,
    ) -> Self {
        BatchPlan { grouping, perms, start: 0, rows: perms.count, stat, shard }
    }

    /// Problem edge (object count) — what `plan.mat.n()` used to spell.
    #[inline]
    pub fn n(&self) -> usize {
        self.grouping.n()
    }

    /// The **packed triangle** this plan's f32 PERMANOVA kernels sweep,
    /// when the prelude carries one (`None` for ANOSIM/PERMDISP, whose
    /// operands are the f64 rank / distance vectors).  Backends bind the
    /// same buffer through their `StatKernel::Permanova(pk)` match arm;
    /// this accessor is the plan-level spelling for callers outside that
    /// match (diagnostics, tests).
    pub fn condensed(&self) -> Option<&crate::dmat::CondensedMatrix> {
        self.stat.packed().map(|p| p.as_ref())
    }
}

/// One batch of output.
#[derive(Clone, Debug)]
pub struct BatchResult {
    /// First plan index the batch covered.
    pub start: usize,
    /// The method statistic per permutation, in plan order (pseudo-F for
    /// PERMANOVA, R for ANOSIM, ANOVA F for PERMDISP).
    pub stats: Vec<f64>,
    /// Wall-clock the backend spent.
    pub elapsed_secs: f64,
    /// Modelled MI300A seconds (simulator backends only).
    pub modelled_secs: Option<f64>,
    /// Display name of the producing backend.
    pub backend: String,
}

/// Static description of what a backend can do.
#[derive(Clone, Debug)]
pub struct Caps {
    /// Registry name (what `--backend` selects and run reports record).
    pub name: String,
    /// Kernel formulation it evaluates (an [`SwAlgorithm`] name, or an XLA
    /// kernel variant).
    pub kernel: String,
    /// Preferred rows per internal sub-batch (None = unlimited).
    pub max_batch: Option<usize>,
    /// Whether the backend parallelizes internally via the shard scheduler.
    pub threaded: bool,
    /// Whether [`BatchResult::modelled_secs`] is populated.
    pub modelled_time: bool,
    /// Permutations evaluated per matrix sweep, for block-batched engines
    /// (None for one-permutation-per-sweep backends).  Recorded in the run
    /// report's `perm_block` field.
    pub perm_block: Option<usize>,
}

/// A compute substrate that can evaluate permutation batches.
///
/// Implementations must handle **every** [`StatKernel`] variant: they keep
/// formulation-specific fast paths for `StatKernel::Permanova` (the
/// paper's f32 kernels, the SoA block engine, the XLA artifacts) and
/// delegate the other methods to the generic
/// [`eval_plan_range`](crate::permanova::eval_plan_range) /
/// [`eval_plan_range_blocked`](crate::permanova::eval_plan_range_blocked)
/// loops, which run through the same shard scheduler.
pub trait Backend {
    /// Evaluate one batch.  Implementations must honour the plan's shard
    /// spec for internal parallelism and return exactly `plan.rows`
    /// statistics in plan order.
    fn run_batch(&self, plan: &BatchPlan<'_>) -> Result<BatchResult>;

    /// Static capabilities (also the source of the report's backend name).
    fn capabilities(&self) -> Caps;
}

/// Factory signature: build a backend from a run configuration.
pub type BackendFactory = fn(&RunConfig) -> Result<Box<dyn Backend>>;

/// Name-keyed backend registry.
pub struct Registry {
    factories: BTreeMap<&'static str, BackendFactory>,
}

impl Registry {
    /// Registry with every built-in backend:
    ///
    /// | name            | substrate                                     |
    /// |-----------------|-----------------------------------------------|
    /// | `native`        | native CPU kernels, algorithm from the config |
    /// | `native-brute`  | native CPU, Algorithm 1 (brute force)         |
    /// | `native-tiled`  | native CPU, Algorithm 2 (cache-tiled)         |
    /// | `native-flat`   | native CPU, Algorithm 3 shape (SIMD/flat)     |
    /// | `native-batch`  | native CPU, Algorithm 1 batched: one matrix   |
    /// |                 | sweep per `perm_block` permutations (the      |
    /// |                 | paper's GPU-winning access pattern)           |
    /// | `simulator`     | exact numerics + modelled MI300A CPU time     |
    /// | `simulator-gpu` | exact numerics + modelled MI300A GPU time     |
    /// | `simulated`     | alias of `simulator` (legacy config name)     |
    /// | `xla`           | AOT artifacts via the PJRT runtime            |
    pub fn with_defaults() -> Registry {
        let mut factories: BTreeMap<&'static str, BackendFactory> = BTreeMap::new();
        factories.insert("native", native::factory_from_config);
        factories.insert("native-brute", native::factory_brute);
        factories.insert("native-tiled", native::factory_tiled);
        factories.insert("native-flat", native::factory_flat);
        factories.insert("native-batch", batch::factory);
        factories.insert("simulator", sim::factory_cpu);
        factories.insert("simulated", sim::factory_cpu);
        factories.insert("simulator-gpu", sim::factory_gpu);
        factories.insert("xla", xla::factory);
        Registry { factories }
    }

    /// All registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.factories.keys().map(|k| k.to_string()).collect()
    }

    /// Whether `name` is registered.
    pub fn contains(&self, name: &str) -> bool {
        self.factories.contains_key(name)
    }

    /// Instantiate backend `name` for a configuration.
    pub fn create(&self, name: &str, cfg: &RunConfig) -> Result<Box<dyn Backend>> {
        match self.factories.get(name) {
            Some(f) => f(cfg),
            None => Err(Error::UnknownBackend { name: name.to_string(), known: self.names() }),
        }
    }
}

/// The names the default registry knows (for usage/help text).
pub fn known_backends() -> Vec<String> {
    Registry::with_defaults().names()
}

/// Instantiate the backend a config selects.
pub fn create_backend(cfg: &RunConfig) -> Result<Box<dyn Backend>> {
    Registry::with_defaults().create(&cfg.backend, cfg)
}

/// Deprecated facade: prefer
/// [`AnalysisRequest::new(cfg).with_data(mat, grouping).run()`](crate::request::AnalysisRequest).
///
/// Config-driven permutation test through the `Backend` trait: prepare
/// the method's [`StatKernel`], run the whole batch on the selected
/// backend, aggregate a method-tagged [`AnalysisReport`].
///
/// [`Method::PairwisePermanova`] fans out as one scheduled PERMANOVA job
/// per group pair (independent per-pair seeds via
/// [`pairwise_seed`](crate::permanova::pairwise_seed), Bonferroni-adjusted
/// p-values), every pair going through the same backend and scheduler.
pub fn execute(
    cfg: &RunConfig,
    mat: &DistanceMatrix,
    grouping: &Grouping,
) -> Result<AnalysisReport> {
    crate::request::AnalysisRequest::new(cfg).with_data(mat, grouping).run()
}

/// The engine-seam core below [`AnalysisRequest`](crate::request::AnalysisRequest):
/// [`execute`] with an optionally **pre-prepared** statistic prelude — the
/// seam the service layer's `DatasetCache` reuses kernels through.
/// Callers outside the engine should go through the builder.
///
/// Dense-free: the problem arrives as the packed triangle `tri` (the only
/// resident copy on every ingest path) and the engine prepares preludes
/// with [`StatKernel::prepare_packed`].
///
/// When `prelude` is `Some`, it must be the [`StatKernel`] prepared for
/// exactly this `(cfg.method, tri, grouping)` problem (checked via
/// [`StatKernel::check_problem`]); the engine then skips the per-call
/// precomputation.  Reuse is bitwise-neutral: the prelude carries the same
/// values `StatKernel::prepare_packed` would recompute, so warm-cache
/// results are bit-identical to cold ones.  [`Method::PairwisePermanova`]
/// prepares one kernel per group-pair sub-problem *below* this seam, so it
/// rejects a caller-supplied prelude.
pub fn execute_prepared(
    cfg: &RunConfig,
    tri: &Arc<CondensedMatrix>,
    grouping: &Grouping,
    prelude: Option<&StatKernel>,
) -> Result<AnalysisReport> {
    execute_storage(cfg, &TriangleStorage::Resident(Arc::clone(tri)), grouping, prelude)
}

/// [`execute_prepared`] generalized over **triangle storage** — the
/// out-of-core-aware engine core.  Resident storage behaves exactly as the
/// classic path (bit for bit).  File-backed storage runs PERMANOVA through
/// each backend's chunk-major sweep under the residency budget, with the
/// job's paging activity (chunks and bytes read) recorded in the run
/// report; methods and backends that fundamentally need the whole triangle
/// resident (ANOSIM's rank sort, PERMDISP's PCoA, pairwise sub-triangle
/// extraction, XLA's dense staging) fail loudly with an
/// [`Error::Config`] naming `--max-resident-bytes`.
pub fn execute_storage(
    cfg: &RunConfig,
    storage: &TriangleStorage,
    grouping: &Grouping,
    prelude: Option<&StatKernel>,
) -> Result<AnalysisReport> {
    if grouping.n() != storage.n() {
        return Err(Error::InvalidInput(format!(
            "grouping n = {} vs matrix n = {}",
            grouping.n(),
            storage.n()
        )));
    }
    if cfg.n_perms == 0 {
        return Err(Error::InvalidInput("n_perms must be >= 1".into()));
    }
    // Validate a caller-supplied prelude before paying for backend
    // construction (opening e.g. the XLA runtime reads artifacts).
    if let Some(kernel) = prelude {
        if cfg.method == Method::PairwisePermanova {
            return Err(Error::InvalidInput(
                "pairwise PERMANOVA prepares one kernel per pair; pass no prelude".into(),
            ));
        }
        if kernel.method() != cfg.method {
            return Err(Error::InvalidInput(format!(
                "prelude prepared for {:?}, run requests {:?}",
                kernel.method(),
                cfg.method
            )));
        }
        kernel.check_problem(storage.n(), grouping)?;
    }
    // One backend instance serves every scheduled job of this call — for
    // pairwise that is k(k−1)/2 jobs, and re-opening e.g. the XLA runtime
    // per pair would re-read the artifacts each time.
    let backend = create_backend(cfg)?;
    match cfg.method {
        Method::PairwisePermanova => {
            // Per-pair sub-triangles are extracted from the resident
            // buffer; under a residency cap that buffer does not exist.
            let Some(tri) = storage.as_resident() else {
                return Err(Error::Config(
                    "pairwise PERMANOVA extracts per-pair sub-triangles from the \
                     resident buffer, but the dataset is file-backed under \
                     --max-resident-bytes; raise the budget (or drop the cap) to \
                     run this method"
                        .into(),
                ));
            };
            let k = grouping.k() as u32;
            let n_comparisons = (k as usize) * (k as usize - 1) / 2;
            let mut runs = Vec::with_capacity(n_comparisons);
            let mut pairs = Vec::with_capacity(n_comparisons);
            for a in 0..k {
                for b in (a + 1)..k {
                    let (sub, sub_grouping) =
                        pairwise_subproblem_condensed(tri, grouping, a, b)?;
                    let sub_n = sub.n();
                    let (run, _) = run_single(
                        cfg,
                        backend.as_ref(),
                        &TriangleStorage::Resident(Arc::new(sub)),
                        &sub_grouping,
                        Method::Permanova,
                        pairwise_seed(cfg.seed, a, b),
                        None,
                    )?;
                    pairs.push(PairSummary {
                        group_a: a,
                        group_b: b,
                        n: sub_n,
                        p_adjusted: (run.p_value * n_comparisons as f64).min(1.0),
                    });
                    runs.push(run);
                }
            }
            Ok(AnalysisReport {
                method: Method::PairwisePermanova,
                n: storage.n(),
                k: grouping.k(),
                runs,
                pairs,
                group_dispersions: vec![],
            })
        }
        method => {
            let (run, group_dispersions) =
                run_single(cfg, backend.as_ref(), storage, grouping, method, cfg.seed, prelude)?;
            Ok(AnalysisReport {
                method,
                n: storage.n(),
                k: grouping.k(),
                runs: vec![run],
                pairs: vec![],
                group_dispersions,
            })
        }
    }
}

/// One scheduled engine job: prepare the kernel (or reuse the caller's
/// prelude), run the full plan on the given backend, aggregate one
/// [`RunReport`].  Returns the PERMDISP group dispersions alongside (empty
/// for the other methods).
fn run_single(
    cfg: &RunConfig,
    backend: &dyn Backend,
    storage: &TriangleStorage,
    grouping: &Grouping,
    method: Method,
    seed: u64,
    prelude: Option<&StatKernel>,
) -> Result<(RunReport, Vec<f64>)> {
    let caps = backend.capabilities();

    // Snapshot the paging counters so the report records this *job's*
    // paging delta (prelude `s_T` pass + permutation sweep), not the
    // file's lifetime totals.
    let paged_before = storage.paging().unwrap_or((0, 0));

    // Reuse the caller's prepared kernel when given (validated by
    // `execute_storage`); otherwise prepare one for this job.
    let prepared;
    let stat: &StatKernel = match prelude {
        Some(k) => k,
        None => {
            prepared = StatKernel::prepare_storage(method, storage, grouping)?;
            &prepared
        }
    };
    let group_dispersions = stat.group_dispersions().to_vec();
    let total = cfg.n_perms + 1; // index 0 = observed labelling
    let perms = PermutationPlan::new(grouping.labels().to_vec(), seed, total);
    let shard = cfg.shard_spec();
    let t0 = Instant::now();

    let plan = BatchPlan::full(grouping, &perms, stat, shard);
    let batch = backend.run_batch(&plan)?;
    if batch.stats.len() != total {
        return Err(Error::Coordinator(format!(
            "backend {} returned {} statistics for {total} permutations",
            caps.name,
            batch.stats.len()
        )));
    }

    let f_obs = batch.stats[0];
    let f_perms = batch.stats[1..].to_vec();
    // File-backed jobs record their paging activity; resident jobs record
    // nothing (keeping uncapped report serialization byte-stable).
    let oocore = storage.as_file().map(|f| {
        let (chunks, bytes) = storage.paging().unwrap_or((0, 0));
        OocoreStats {
            resident_cap: f.budget_bytes(),
            chunks_paged: chunks.saturating_sub(paged_before.0),
            bytes_paged: bytes.saturating_sub(paged_before.1),
        }
    });
    let report = RunReport {
        f_obs,
        p_value: pvalue(f_obs, &f_perms),
        n_perms: cfg.n_perms,
        n: storage.n(),
        k: grouping.k(),
        s_t: stat.s_t(),
        elapsed_secs: t0.elapsed().as_secs_f64(),
        method: method.name().to_string(),
        backend: caps.name,
        // PERMANOVA jobs record the backend's f32 formulation (pairwise
        // reaches here as per-pair Permanova jobs); the generic methods
        // record their statistic kernel, which is the same on every
        // backend (and bit-identical — the conformance contract).
        kernel: match method {
            Method::Permanova => caps.kernel,
            _ => stat.kernel_label().to_string(),
        },
        // Record the width actually used: the engine clamps the block to
        // the permutation count (see sw_plan_range_blocked).
        perm_block: caps.perm_block.map(|b| b.min(total)).unwrap_or(0),
        per_device: vec![DeviceStats {
            device: batch.backend,
            batches: 1,
            perms: total,
            busy_secs: batch.elapsed_secs,
            simulated_secs: batch.modelled_secs.unwrap_or(0.0),
        }],
        oocore,
        f_perms,
    };
    Ok((report, group_dispersions))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DataSource;
    use crate::permanova::SwAlgorithm;

    fn fixture(n: usize, k: usize) -> (DistanceMatrix, Grouping) {
        (DistanceMatrix::random_euclidean(n, 6, 4), Grouping::balanced(n, k).unwrap())
    }

    fn cfg(backend: &str) -> RunConfig {
        RunConfig {
            data: DataSource::Synthetic { n_dims: 40, n_groups: 4 },
            backend: backend.to_string(),
            n_perms: 60,
            seed: 9,
            ..Default::default()
        }
    }

    #[test]
    fn registry_knows_the_builtins() {
        let r = Registry::with_defaults();
        for name in [
            "native",
            "native-brute",
            "native-tiled",
            "native-flat",
            "native-batch",
            "simulator",
            "xla",
        ] {
            assert!(r.contains(name), "missing {name}");
        }
        assert!(!r.contains("cuda"));
        let e = match r.create("cuda", &cfg("cuda")) {
            Err(e) => e,
            Ok(_) => panic!("created an unknown backend"),
        };
        assert!(e.to_string().contains("cuda"));
        assert!(e.to_string().contains("native-tiled"), "error lists known names: {e}");
    }

    #[test]
    fn batch_plan_exposes_the_packed_operand() {
        use crate::rng::PermutationPlan;
        let (mat, grouping) = fixture(24, 2);
        let perms = PermutationPlan::new(grouping.labels().to_vec(), 1, 4);
        let pk = StatKernel::prepare(Method::Permanova, &mat, &grouping).unwrap();
        let plan = BatchPlan::full(&grouping, &perms, &pk, ShardSpec::default());
        assert_eq!(plan.n(), 24, "plan edge comes from the grouping");
        let tri = plan.condensed().expect("PERMANOVA plans carry the packed triangle");
        assert_eq!(tri.n(), 24);
        assert_eq!(tri.values(), mat.to_condensed().as_slice());
        let ak = StatKernel::prepare(Method::Anosim, &mat, &grouping).unwrap();
        let plan = BatchPlan::full(&grouping, &perms, &ak, ShardSpec::default());
        assert!(plan.condensed().is_none(), "rank plans have no f32 stream");
    }

    #[test]
    fn execute_records_backend_name() {
        let (mat, grouping) = fixture(40, 4);
        for name in ["native-tiled", "native-brute", "simulator"] {
            let r = execute(&cfg(name), &mat, &grouping).unwrap();
            assert_eq!(r.backend, name);
            assert_eq!(r.f_perms.len(), 60);
            assert!(r.p_value > 0.0 && r.p_value <= 1.0);
            assert_eq!(r.perm_block, 0, "{name} is not block-batched");
        }
    }

    #[test]
    fn execute_records_effective_perm_block() {
        let (mat, grouping) = fixture(40, 4);
        let mut c = cfg("native-batch");
        c.n_perms = 199; // total 200 > any tested block width
        c.perm_block = 8;
        let r = execute(&c, &mat, &grouping).unwrap();
        assert_eq!(r.backend, "native-batch");
        assert_eq!(r.kernel, "brute-block");
        assert_eq!(r.perm_block, 8);
        c.perm_block = 0; // auto: the paper-informed default
        let r = execute(&c, &mat, &grouping).unwrap();
        assert_eq!(r.perm_block, crate::permanova::DEFAULT_PERM_BLOCK);
        // Wider than the work: the report records the clamped width.
        c.n_perms = 9;
        c.perm_block = 64;
        let r = execute(&c, &mat, &grouping).unwrap();
        assert_eq!(r.perm_block, 10, "64 lanes requested, only 10 permutations exist");
    }

    #[test]
    fn execute_matches_direct_permanova() {
        use crate::permanova::{permanova, PermanovaOpts};
        let (mat, grouping) = fixture(40, 4);
        let c = cfg("native-brute");
        let r = execute(&c, &mat, &grouping).unwrap();
        let direct = permanova(
            &mat,
            &grouping,
            60,
            &PermanovaOpts {
                algo: SwAlgorithm::Brute,
                seed: 9,
                threads: 1,
                keep_f_perms: true,
            },
        )
        .unwrap();
        assert!((r.f_obs - direct.f_obs).abs() < 1e-9);
        assert_eq!(r.p_value, direct.p_value);
        for (a, b) in r.f_perms.iter().zip(direct.f_perms.as_ref().unwrap()) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn execute_routes_every_method() {
        let (mat, grouping) = fixture(36, 3);
        let mut c = cfg("native-flat");
        c.n_perms = 49;
        for method in Method::ALL {
            c.method = method;
            let r = execute(&c, &mat, &grouping).unwrap();
            assert_eq!(r.method, method, "report is method-tagged");
            assert_eq!((r.n, r.k), (36, 3));
            assert!(r.p_value > 0.0 && r.p_value <= 1.0, "{method:?}: p = {}", r.p_value);
            match method {
                Method::Permanova => {
                    assert_eq!(r.runs.len(), 1);
                    assert_eq!(r.primary().method, "permanova");
                }
                Method::Anosim => {
                    assert!((-1.0..=1.0).contains(&r.f_obs), "R = {}", r.f_obs);
                    assert_eq!(r.primary().kernel, "rank-r");
                    assert_eq!(r.s_t, 0.0, "rank statistic has no s_T");
                }
                Method::Permdisp => {
                    assert_eq!(r.group_dispersions.len(), 3);
                    assert_eq!(r.primary().kernel, "centroid-anova");
                }
                Method::PairwisePermanova => {
                    assert_eq!(r.runs.len(), 3, "3 groups -> 3 pairs");
                    assert_eq!(r.pairs.len(), 3);
                    for (pair, run) in r.pairs.iter().zip(&r.runs) {
                        assert_eq!(run.method, "permanova", "per-pair jobs are PERMANOVA");
                        assert!(pair.p_adjusted >= run.p_value);
                        assert!(pair.p_adjusted <= 1.0);
                        assert_eq!(pair.n, 24, "two balanced groups of 12");
                    }
                }
            }
        }
    }

    #[test]
    fn execute_prepared_is_bitwise_identical_to_cold() {
        let (mat, grouping) = fixture(36, 3);
        let tri = Arc::new(CondensedMatrix::from_dense(&mat));
        for backend in ["native-brute", "native-batch", "simulator"] {
            let mut c = cfg(backend);
            c.n_perms = 49;
            for method in [Method::Permanova, Method::Anosim, Method::Permdisp] {
                c.method = method;
                let kernel = StatKernel::prepare(method, &mat, &grouping).unwrap();
                let cold = execute(&c, &mat, &grouping).unwrap();
                let warm = execute_prepared(&c, &tri, &grouping, Some(&kernel)).unwrap();
                assert_eq!(cold.f_obs.to_bits(), warm.f_obs.to_bits(), "{backend} {method:?}");
                assert_eq!(cold.p_value, warm.p_value);
                for (a, b) in cold.f_perms.iter().zip(&warm.f_perms) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{backend} {method:?}");
                }
            }
        }
    }

    #[test]
    fn execute_prepared_rejects_mismatched_preludes() {
        let (mat, grouping) = fixture(36, 3);
        let tri = Arc::new(CondensedMatrix::from_dense(&mat));
        let c = cfg("native-brute");
        // Wrong method for the config.
        let anosim = StatKernel::prepare(Method::Anosim, &mat, &grouping).unwrap();
        assert!(execute_prepared(&c, &tri, &grouping, Some(&anosim)).is_err());
        // Right method, wrong problem size.
        let (other, other_g) = fixture(40, 4);
        let foreign = StatKernel::prepare(Method::Permanova, &other, &other_g).unwrap();
        assert!(execute_prepared(&c, &tri, &grouping, Some(&foreign)).is_err());
        // Pairwise never takes a caller prelude.
        let mut pw = cfg("native-brute");
        pw.method = Method::PairwisePermanova;
        let perma = StatKernel::prepare(Method::Permanova, &mat, &grouping).unwrap();
        assert!(execute_prepared(&pw, &tri, &grouping, Some(&perma)).is_err());
    }

    #[test]
    fn pairwise_jobs_draw_independent_seed_streams() {
        let (mat, grouping) = fixture(36, 3);
        let mut c = cfg("native-brute");
        c.method = Method::PairwisePermanova;
        let r = execute(&c, &mat, &grouping).unwrap();
        // Distinct pairs must not share a permutation stream.
        assert_ne!(r.runs[0].f_perms, r.runs[1].f_perms);
        // ... and the whole fan-out is seed-reproducible.
        let again = execute(&c, &mat, &grouping).unwrap();
        for (x, y) in r.runs.iter().zip(&again.runs) {
            assert_eq!(x.f_perms, y.f_perms);
            assert_eq!(x.p_value, y.p_value);
        }
    }

    #[test]
    fn execute_rejects_mismatch_and_zero_perms() {
        let (mat, _) = fixture(40, 4);
        let g_bad = Grouping::balanced(30, 3).unwrap();
        assert!(execute(&cfg("native"), &mat, &g_bad).is_err());
        let (mat, grouping) = fixture(24, 2);
        let mut c = cfg("native");
        c.n_perms = 0;
        assert!(execute(&c, &mat, &grouping).is_err());
    }

    #[test]
    fn shard_spec_does_not_change_results() {
        let (mat, grouping) = fixture(36, 3);
        let base = execute(&cfg("native-flat"), &mat, &grouping).unwrap();
        for (shard_size, threads, smt) in [(1usize, 1usize, false), (7, 3, true), (500, 2, false)]
        {
            let mut c = cfg("native-flat");
            c.shard_size = shard_size;
            c.threads = threads;
            c.smt_oversubscribe = smt;
            let r = execute(&c, &mat, &grouping).unwrap();
            assert_eq!(base.f_obs, r.f_obs);
            assert_eq!(base.p_value, r.p_value);
            assert_eq!(base.f_perms, r.f_perms);
        }
    }

    #[test]
    fn xla_backend_errors_cleanly_without_artifacts() {
        let (mat, grouping) = fixture(24, 2);
        let mut c = cfg("xla");
        c.artifacts_dir = "/nonexistent/artifacts".into();
        assert!(execute(&c, &mat, &grouping).is_err());
    }
}
