//! Simulator backend: exact numerics natively, modelled MI300A wall-clock
//! alongside — the hardware-substitution substrate as a [`Backend`].
//!
//! Method routing: PERMANOVA numerics use the fast flat kernel over the
//! prelude's packed triangle (bitwise identical to `native-flat`); ANOSIM
//! and PERMDISP use the generic f64 loop (bitwise identical to every other
//! backend's generic path).  The MI300A time model is calibrated for the
//! paper's f32 d² stream, so only PERMANOVA batches report modelled time —
//! ANOSIM streams f64 ranks (double the bytes per element) and PERMDISP's
//! per-permutation loop is O(n); pricing either with the f32-kernel model
//! would be fiction, so their batches report none.  Since PR 5 the
//! byte-traffic model prices the **packed** layout (what the engine
//! actually streams); `simulator::traffic` keeps the dense formulas on a
//! layout axis for comparison.

use std::time::Instant;

use super::shard::run_sharded_with;
use super::{Backend, BatchPlan, BatchResult, Caps};
use crate::config::RunConfig;
use crate::dmat::TriangleStorage;
use crate::error::Result;
use crate::permanova::{
    eval_plan_range, fstat_from_sw, sw_one, sw_plan_range_chunked, StatKernel, SwAlgorithm,
};
use crate::simulator::{predict, DeviceConfig, Mi300a, Workload};

/// The calibrated MI300A model as an execution backend.
///
/// Numerics are always computed exactly (with the fast flat kernel, like
/// the coordinator's `SimulatedDevice` did); the *modelled* time is the
/// prediction for running the configured algorithm on the configured
/// MI300A device, reported via [`BatchResult::modelled_secs`].
pub struct SimulatorBackend {
    machine: Mi300a,
    /// Algorithm the *model* prices (numerics always use the flat kernel).
    algo: SwAlgorithm,
    device: DeviceConfig,
    name: String,
}

impl SimulatorBackend {
    pub fn new(machine: Mi300a, algo: SwAlgorithm, device: DeviceConfig, name: &str) -> Self {
        SimulatorBackend { machine, algo, device, name: name.to_string() }
    }
}

impl Backend for SimulatorBackend {
    fn run_batch(&self, plan: &BatchPlan<'_>) -> Result<BatchResult> {
        let t0 = Instant::now();
        let n = plan.n();
        let k = plan.grouping.k();
        let stats: Vec<f64> = match plan.stat {
            StatKernel::Permanova(pk) => {
                // Numerics always use the flat kernel; a file-backed
                // triangle runs the same flat kernel chunk-major (bitwise
                // identical — the modelled time is unaffected either way).
                let s_w = match &pk.storage {
                    TriangleStorage::Resident(packed) => {
                        let tri = packed.view();
                        let mut s_w = vec![0.0f32; plan.rows];
                        run_sharded_with(
                            &plan.shard,
                            &mut s_w,
                            || vec![0u32; n],
                            |row, start, slice| {
                                let inv = plan.grouping.inv_sizes();
                                for (i, out) in slice.iter_mut().enumerate() {
                                    plan.perms.fill(plan.start + start + i, row);
                                    *out = sw_one(SwAlgorithm::Flat, tri, row, inv);
                                }
                            },
                        );
                        s_w
                    }
                    TriangleStorage::FileBacked(file) => sw_plan_range_chunked(
                        file,
                        plan.perms,
                        plan.start,
                        plan.rows,
                        plan.grouping.inv_sizes(),
                        SwAlgorithm::Flat,
                        &plan.shard,
                    )?,
                };
                s_w.iter().map(|&sw| fstat_from_sw(sw as f64, pk.s_t, n, k)).collect()
            }
            stat => {
                eval_plan_range(stat, plan.grouping, plan.perms, plan.start, plan.rows, &plan.shard)
            }
        };
        // Only PERMANOVA is inside the calibrated model's regime (the f32
        // d² stream the paper measured); see the module docs.
        let modelled_secs = match plan.stat {
            StatKernel::Permanova(_) => {
                let w = Workload { n_dims: n, n_perms: plan.rows, n_groups: k };
                Some(predict(&self.machine, &w, self.algo, self.device).seconds)
            }
            _ => None,
        };
        // The device tag names what actually ran: the priced algorithm for
        // PERMANOVA, the generic statistic kernel otherwise.
        let evaluated = match plan.stat {
            StatKernel::Permanova(_) => self.algo.name(),
            stat => stat.kernel_label().to_string(),
        };
        Ok(BatchResult {
            start: plan.start,
            stats,
            elapsed_secs: t0.elapsed().as_secs_f64(),
            modelled_secs,
            backend: format!("sim-mi300a/{}/{evaluated}", self.device.name()),
        })
    }

    fn capabilities(&self) -> Caps {
        Caps {
            name: self.name.clone(),
            kernel: self.algo.name(),
            max_batch: None,
            threaded: true,
            modelled_time: true,
            perm_block: None,
        }
    }
}

/// `simulator` (and legacy `simulated`): MI300A CPU cores, SMT per config.
pub fn factory_cpu(cfg: &RunConfig) -> Result<Box<dyn Backend>> {
    Ok(Box::new(SimulatorBackend::new(
        Mi300a::default(),
        cfg.algo,
        DeviceConfig::Cpu { smt: cfg.smt },
        "simulator",
    )))
}

/// `simulator-gpu`: MI300A GPU compute units.
pub fn factory_gpu(cfg: &RunConfig) -> Result<Box<dyn Backend>> {
    Ok(Box::new(SimulatorBackend::new(
        Mi300a::default(),
        cfg.algo,
        DeviceConfig::Gpu,
        "simulator-gpu",
    )))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BatchPlan, NativeBackend, ShardSpec};
    use crate::dmat::DistanceMatrix;
    use crate::permanova::{Grouping, Method};
    use crate::rng::PermutationPlan;

    #[test]
    fn exact_numerics_modelled_time() {
        let mat = DistanceMatrix::random_euclidean(32, 4, 7);
        let grouping = Grouping::balanced(32, 4).unwrap();
        let perms = PermutationPlan::new(grouping.labels().to_vec(), 5, 12);
        let stat = StatKernel::prepare(Method::Permanova, &mat, &grouping).unwrap();
        let plan = BatchPlan {
            grouping: &grouping,
            perms: &perms,
            start: 0,
            rows: 12,
            stat: &stat,
            shard: ShardSpec::with_workers(2),
        };
        let sim = SimulatorBackend::new(
            Mi300a::default(),
            SwAlgorithm::Brute,
            DeviceConfig::Gpu,
            "simulator-gpu",
        );
        let native = NativeBackend::new(SwAlgorithm::Flat);
        let rs = sim.run_batch(&plan).unwrap();
        let rn = native.run_batch(&plan).unwrap();
        // Identical kernel + identical plan => bitwise-identical statistics.
        assert_eq!(rs.stats, rn.stats);
        assert!(rs.modelled_secs.unwrap() > 0.0);
        assert!(rn.modelled_secs.is_none());
        assert!(sim.capabilities().modelled_time);
    }

    #[test]
    fn method_routing_models_only_the_calibrated_regime() {
        let mat = DistanceMatrix::random_euclidean(30, 4, 9);
        let grouping = Grouping::balanced(30, 3).unwrap();
        let perms = PermutationPlan::new(grouping.labels().to_vec(), 5, 10);
        let sim = SimulatorBackend::new(
            Mi300a::default(),
            SwAlgorithm::Brute,
            DeviceConfig::Cpu { smt: true },
            "simulator",
        );
        let native = NativeBackend::new(SwAlgorithm::Flat);
        for (method, modelled) in
            [(Method::Anosim, false), (Method::Permdisp, false), (Method::Permanova, true)]
        {
            let stat = StatKernel::prepare(method, &mat, &grouping).unwrap();
            let plan = BatchPlan::full(&grouping, &perms, &stat, ShardSpec::with_workers(2));
            let rs = sim.run_batch(&plan).unwrap();
            let rn = native.run_batch(&plan).unwrap();
            assert_eq!(rs.stats, rn.stats, "{method:?}: simulator numerics are exact");
            assert_eq!(
                rs.modelled_secs.is_some(),
                modelled,
                "{method:?}: modelled time only inside the f32-calibrated regime"
            );
            // Provenance names the statistic actually evaluated.
            if method == Method::Anosim {
                assert!(rs.backend.ends_with("/rank-r"), "{}", rs.backend);
            }
        }
    }

    #[test]
    fn gpu_model_prices_brute_below_tiled() {
        // The paper's negative result must survive the backend port.
        let cfg = RunConfig::default();
        let mk = |algo| {
            SimulatorBackend::new(Mi300a::default(), algo, DeviceConfig::Gpu, "simulator-gpu")
        };
        let mat = DistanceMatrix::random_euclidean(24, 2, 1);
        let grouping = Grouping::balanced(24, 2).unwrap();
        let perms = PermutationPlan::new(grouping.labels().to_vec(), 1, 4);
        let stat = StatKernel::prepare(Method::Permanova, &mat, &grouping).unwrap();
        let plan = BatchPlan::full(&grouping, &perms, &stat, cfg.shard_spec());
        let brute = mk(SwAlgorithm::Brute).run_batch(&plan).unwrap();
        let tiled = mk(SwAlgorithm::Tiled { tile: 512 }).run_batch(&plan).unwrap();
        assert!(tiled.modelled_secs.unwrap() > brute.modelled_secs.unwrap());
    }
}
