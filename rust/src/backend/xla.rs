//! XLA backend: the AOT/PJRT runtime behind the unified [`Backend`] trait.
//!
//! Wraps [`XlaRuntime`]: one compiled session per `run_batch` call (the
//! matrix is staged device-resident once per call, then permutation-row
//! sub-batches stream through at the artifact's lowered batch size).
//! Construction fails cleanly when the artifacts are missing or the crate
//! was built without the `pjrt` feature — callers see one typed error, not
//! a panic.

use std::time::Instant;

use super::{Backend, BatchPlan, BatchResult, Caps};
use crate::config::RunConfig;
use crate::error::{Error, Result};
use crate::runtime::XlaRuntime;

/// AOT-compiled XLA kernels via PJRT.
pub struct XlaBackend {
    runtime: XlaRuntime,
    kernel: String,
}

impl XlaBackend {
    /// Open the runtime at `artifacts_dir`, preferring `kernel`
    /// (bruteforce | tiled | matmul | ref).
    pub fn new(artifacts_dir: &str, kernel: &str) -> Result<Self> {
        let runtime = XlaRuntime::new(artifacts_dir)?;
        Ok(XlaBackend { runtime, kernel: kernel.to_string() })
    }
}

impl Backend for XlaBackend {
    fn run_batch(&self, plan: &BatchPlan<'_>) -> Result<BatchResult> {
        let t0 = Instant::now();
        let n = plan.mat.n();
        let session = self.runtime.session(&self.kernel, plan.mat.data(), n, plan.grouping)?;
        let cap = session.batch_capacity().max(1);

        let mut f_stats = Vec::with_capacity(plan.rows);
        let mut start = plan.start;
        let end = plan.start + plan.rows;
        while start < end {
            let rows = cap.min(end - start);
            let labels = plan.perms.batch(start, rows);
            let out = session.run_batch(&labels, rows)?;
            if out.f_stats.len() != rows {
                return Err(Error::Xla(format!(
                    "session returned {} stats for {rows} rows",
                    out.f_stats.len()
                )));
            }
            f_stats.extend(out.f_stats);
            start += rows;
        }
        Ok(BatchResult {
            start: plan.start,
            f_stats,
            elapsed_secs: t0.elapsed().as_secs_f64(),
            modelled_secs: None,
            backend: format!("xla/{}", self.kernel),
        })
    }

    fn capabilities(&self) -> Caps {
        Caps {
            name: "xla".to_string(),
            kernel: self.kernel.clone(),
            max_batch: self
                .runtime
                .manifest()
                .by_kernel(&self.kernel)
                .iter()
                .map(|a| a.batch)
                .max(),
            threaded: false,
            modelled_time: false,
            perm_block: None,
        }
    }
}

/// `xla`: artifacts directory and kernel variant from the config.
pub fn factory(cfg: &RunConfig) -> Result<Box<dyn Backend>> {
    Ok(Box::new(XlaBackend::new(&cfg.artifacts_dir, &cfg.xla_kernel)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ShardSpec;
    use crate::dmat::DistanceMatrix;
    use crate::permanova::{fstat_from_sw, st_of, sw_brute_f64, Grouping};
    use crate::rng::PermutationPlan;

    #[test]
    fn missing_artifacts_is_a_clean_error() {
        let e = match XlaBackend::new("/definitely/not/a/dir", "matmul") {
            Err(e) => e,
            Ok(_) => panic!("runtime without artifacts must not open"),
        };
        let s = e.to_string();
        assert!(s.contains("manifest.json") || s.contains("xla"), "{s}");
    }

    /// Full parity run, only when `make artifacts` has produced artifacts
    /// AND the crate was built with a working PJRT client.
    #[test]
    fn xla_backend_matches_oracle_if_available() {
        let dir = crate::runtime::artifacts_dir_for_tests();
        if !dir.join("manifest.json").exists() {
            eprintln!("skip: no artifacts at {dir:?}");
            return;
        }
        let Ok(backend) = XlaBackend::new(dir.to_str().unwrap(), "matmul") else {
            eprintln!("skip: PJRT runtime unavailable in this build");
            return;
        };
        let n = 64;
        let mat = DistanceMatrix::random_euclidean(n, 8, 2);
        let grouping = Grouping::balanced(n, 4).unwrap();
        let perms = PermutationPlan::new(grouping.labels().to_vec(), 3, 40);
        let s_t = st_of(&mat);
        let plan = BatchPlan::full(&mat, &grouping, &perms, s_t, ShardSpec::default());
        let r = backend.run_batch(&plan).unwrap();
        assert_eq!(r.f_stats.len(), 40);
        let mut row = vec![0u32; n];
        for i in 0..40 {
            perms.fill(i, &mut row);
            let sw = sw_brute_f64(mat.data(), n, &row, grouping.inv_sizes());
            let want = fstat_from_sw(sw, s_t, n, 4);
            let rel = (r.f_stats[i] - want).abs() / want.abs().max(1e-9);
            assert!(rel < 2e-3, "row {i}: {} vs {want}", r.f_stats[i]);
        }
    }
}
