//! XLA backend: the AOT/PJRT runtime behind the unified [`Backend`] trait.
//!
//! Wraps [`XlaRuntime`]: one compiled session per `run_batch` call (the
//! matrix is staged device-resident once per call, then permutation-row
//! sub-batches stream through at the artifact's lowered batch size).
//! Construction fails cleanly when the artifacts are missing or the crate
//! was built without the `pjrt` feature — callers see one typed error, not
//! a panic.
//!
//! Method routing: the AOT artifacts lower only the PERMANOVA s_W graph,
//! so PERMANOVA batches run on the device while ANOSIM / PERMDISP batches
//! evaluate host-side through the generic [`eval_plan_range`] loop (same
//! shard scheduler, same bit-exact statistics as every other backend's
//! generic path) — one backend name, every method served.
//!
//! Layout note: the compiled artifacts take the **dense** `n*n` matrix as
//! a graph input (the lowered HLO's contract).  Since the dense-free
//! ingestion refactor nothing upstream holds a dense copy anymore, so this
//! backend mirrors one **on demand** from the prelude's packed triangle
//! (`to_dense()`), stages it device-resident for the session, and drops it
//! when the batch returns — an explicit, transient staging buffer at the
//! one call site that needs it, not a resident layout.  The host-side
//! generic methods stream their own packed preludes like every other
//! backend.

use std::time::Instant;

use super::{Backend, BatchPlan, BatchResult, Caps};
use crate::config::RunConfig;
use crate::error::{Error, Result};
use crate::permanova::{eval_plan_range, StatKernel};
use crate::runtime::XlaRuntime;

/// AOT-compiled XLA kernels via PJRT.
pub struct XlaBackend {
    runtime: XlaRuntime,
    kernel: String,
}

impl XlaBackend {
    /// Open the runtime at `artifacts_dir`, preferring `kernel`
    /// (bruteforce | tiled | matmul | ref).
    pub fn new(artifacts_dir: &str, kernel: &str) -> Result<Self> {
        let runtime = XlaRuntime::new(artifacts_dir)?;
        Ok(XlaBackend { runtime, kernel: kernel.to_string() })
    }
}

impl Backend for XlaBackend {
    fn run_batch(&self, plan: &BatchPlan<'_>) -> Result<BatchResult> {
        let t0 = Instant::now();
        let n = plan.n();

        // Only the PERMANOVA s_W graph is lowered to artifacts; the other
        // methods evaluate host-side through the generic scheduler loop.
        let StatKernel::Permanova(pk) = plan.stat else {
            let stats = eval_plan_range(
                plan.stat,
                plan.grouping,
                plan.perms,
                plan.start,
                plan.rows,
                &plan.shard,
            );
            return Ok(BatchResult {
                start: plan.start,
                stats,
                elapsed_secs: t0.elapsed().as_secs_f64(),
                modelled_secs: None,
                backend: format!("xla/{}+host", plan.stat.kernel_label()),
            });
        };

        // The lowered HLO takes the dense n×n matrix: mirror it on demand
        // from the packed triangle, stage it, and let it drop with this
        // scope — the transient dense boundary, not a resident copy.  A
        // file-backed triangle cannot be mirrored densely without blowing
        // the residency budget, so it fails loudly instead of silently
        // materializing n² bytes.
        let Some(packed) = pk.storage.as_resident() else {
            return Err(Error::Config(
                "the XLA backend stages the dense n×n matrix device-side, which a \
                 file-backed triangle under --max-resident-bytes cannot provide; \
                 raise the budget (or drop the cap) to run this backend"
                    .into(),
            ));
        };
        let staged = packed.to_dense();
        let session = self.runtime.session(&self.kernel, staged.data(), n, plan.grouping)?;
        let cap = session.batch_capacity().max(1);

        let mut stats = Vec::with_capacity(plan.rows);
        let mut start = plan.start;
        let end = plan.start + plan.rows;
        while start < end {
            let rows = cap.min(end - start);
            let labels = plan.perms.batch(start, rows);
            let out = session.run_batch(&labels, rows)?;
            if out.f_stats.len() != rows {
                return Err(Error::Xla(format!(
                    "session returned {} stats for {rows} rows",
                    out.f_stats.len()
                )));
            }
            stats.extend(out.f_stats);
            start += rows;
        }
        Ok(BatchResult {
            start: plan.start,
            stats,
            elapsed_secs: t0.elapsed().as_secs_f64(),
            modelled_secs: None,
            backend: format!("xla/{}", self.kernel),
        })
    }

    fn capabilities(&self) -> Caps {
        Caps {
            name: "xla".to_string(),
            kernel: self.kernel.clone(),
            max_batch: self
                .runtime
                .manifest()
                .by_kernel(&self.kernel)
                .iter()
                .map(|a| a.batch)
                .max(),
            threaded: false,
            modelled_time: false,
            perm_block: None,
        }
    }
}

/// `xla`: artifacts directory and kernel variant from the config.
pub fn factory(cfg: &RunConfig) -> Result<Box<dyn Backend>> {
    Ok(Box::new(XlaBackend::new(&cfg.artifacts_dir, &cfg.xla_kernel)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ShardSpec;
    use crate::dmat::DistanceMatrix;
    use crate::permanova::{fstat_from_sw, st_of, sw_brute_f64_dense, Grouping, Method};
    use crate::rng::PermutationPlan;

    #[test]
    fn missing_artifacts_is_a_clean_error() {
        let e = match XlaBackend::new("/definitely/not/a/dir", "matmul") {
            Err(e) => e,
            Ok(_) => panic!("runtime without artifacts must not open"),
        };
        let s = e.to_string();
        assert!(s.contains("manifest.json") || s.contains("xla"), "{s}");
    }

    /// Full parity run, only when `make artifacts` has produced artifacts
    /// AND the crate was built with a working PJRT client.
    #[test]
    fn xla_backend_matches_oracle_if_available() {
        let dir = crate::runtime::artifacts_dir_for_tests();
        if !dir.join("manifest.json").exists() {
            eprintln!("skip: no artifacts at {dir:?}");
            return;
        }
        let Ok(backend) = XlaBackend::new(dir.to_str().unwrap(), "matmul") else {
            eprintln!("skip: PJRT runtime unavailable in this build");
            return;
        };
        let n = 64;
        let mat = DistanceMatrix::random_euclidean(n, 8, 2);
        let grouping = Grouping::balanced(n, 4).unwrap();
        let perms = PermutationPlan::new(grouping.labels().to_vec(), 3, 40);
        let s_t = st_of(&mat);
        let stat = StatKernel::prepare(Method::Permanova, &mat, &grouping).unwrap();
        let plan = BatchPlan::full(&grouping, &perms, &stat, ShardSpec::default());
        let r = backend.run_batch(&plan).unwrap();
        assert_eq!(r.stats.len(), 40);
        let mut row = vec![0u32; n];
        for i in 0..40 {
            perms.fill(i, &mut row);
            let sw = sw_brute_f64_dense(mat.data(), n, &row, grouping.inv_sizes());
            let want = fstat_from_sw(sw, s_t, n, 4);
            let rel = (r.stats[i] - want).abs() / want.abs().max(1e-9);
            assert!(rel < 2e-3, "row {i}: {} vs {want}", r.stats[i]);
        }
    }

    /// The host-fallback methods need no artifacts to *evaluate*, but the
    /// backend still refuses to open without them — one construction
    /// contract for every method.  With artifacts present, ANOSIM batches
    /// must match the generic path bit-for-bit.
    #[test]
    fn xla_backend_serves_anosim_host_side_if_available() {
        let dir = crate::runtime::artifacts_dir_for_tests();
        if !dir.join("manifest.json").exists() {
            eprintln!("skip: no artifacts at {dir:?}");
            return;
        }
        let Ok(backend) = XlaBackend::new(dir.to_str().unwrap(), "matmul") else {
            eprintln!("skip: PJRT runtime unavailable in this build");
            return;
        };
        let n = 64;
        let mat = DistanceMatrix::random_euclidean(n, 8, 2);
        let grouping = Grouping::balanced(n, 4).unwrap();
        let perms = PermutationPlan::new(grouping.labels().to_vec(), 3, 20);
        let stat = StatKernel::prepare(Method::Anosim, &mat, &grouping).unwrap();
        let plan = BatchPlan::full(&grouping, &perms, &stat, ShardSpec::default());
        let r = backend.run_batch(&plan).unwrap();
        let want = eval_plan_range(&stat, &grouping, &perms, 0, 20, &ShardSpec::default());
        assert_eq!(r.stats, want);
        assert!(r.backend.contains("+host"), "{}", r.backend);
    }
}
