//! Batched brute-force backend: the paper's GPU-winning
//! one-sweep-many-permutations access pattern as a native [`Backend`].
//!
//! The MI300A result this repo reproduces is that the GPU cores prefer the
//! *brute-force* formulation — because the GPU streams the n² matrix out of
//! shared HBM once per pass and amortizes it across many concurrent
//! permutation lanes, where the CPU formulations re-stream it per
//! permutation.  `native-batch` models exactly that schedule on host
//! threads: each scheduler shard is walked in blocks of `perm_block`
//! permutations, the block's labels are transposed into a
//! structure-of-arrays layout, and [`sw_brute_block`]
//! (`crate::permanova::sw_brute_block`) makes one sweep over the distance
//! matrix per block.
//!
//! Numerics contract: every lane executes the scalar brute kernel's exact
//! f32 operation sequence, so `native-batch` is **bitwise identical** to
//! `native-brute` at every block size, shard size, worker count and SMT
//! setting — the cross-backend conformance tests pin this.
//!
//! The contract extends per method: ANOSIM batches run the SoA rank-sweep
//! block kernel and PERMDISP batches the per-lane scalar statistic (via
//! [`eval_plan_range_blocked`]), both of which execute the scalar f64
//! operation sequence per lane — so `native-batch` stays bit-identical to
//! `native-brute` for *every* method at every block width.

use std::time::Instant;

use super::{Backend, BatchPlan, BatchResult, Caps};
use crate::config::RunConfig;
use crate::dmat::TriangleStorage;
use crate::error::Result;
use crate::permanova::{
    eval_plan_range_blocked, fstat_from_sw, resolve_perm_block, sw_plan_range_blocked,
    sw_plan_range_blocked_chunked, StatKernel,
};

/// Algorithm 1 evaluated `perm_block` permutations per matrix sweep.
pub struct BatchedBruteBackend {
    perm_block: usize,
}

impl BatchedBruteBackend {
    /// Backend with the given block width (0 = the paper-informed default).
    pub fn new(perm_block: usize) -> Self {
        BatchedBruteBackend { perm_block: resolve_perm_block(perm_block) }
    }

    /// The resolved permutations-per-sweep block width.
    pub fn perm_block(&self) -> usize {
        self.perm_block
    }
}

impl Backend for BatchedBruteBackend {
    fn run_batch(&self, plan: &BatchPlan<'_>) -> Result<BatchResult> {
        let t0 = Instant::now();
        let n = plan.n();
        let k = plan.grouping.k();
        let stats = match plan.stat {
            // PERMANOVA: the f32 SoA brute-block engine over the packed
            // triangle — one half-footprint sweep per `perm_block` lanes.
            // File-backed storage runs the same engine chunk-major: one
            // *disk* read per chunk per batch, same bits per lane.
            StatKernel::Permanova(pk) => {
                let s_w = match &pk.storage {
                    TriangleStorage::Resident(packed) => sw_plan_range_blocked(
                        packed,
                        plan.perms,
                        plan.start,
                        plan.rows,
                        plan.grouping.inv_sizes(),
                        self.perm_block,
                        &plan.shard,
                    ),
                    TriangleStorage::FileBacked(file) => sw_plan_range_blocked_chunked(
                        file,
                        plan.perms,
                        plan.start,
                        plan.rows,
                        plan.grouping.inv_sizes(),
                        self.perm_block,
                        &plan.shard,
                    )?,
                };
                s_w.iter().map(|&sw| fstat_from_sw(sw as f64, pk.s_t, n, k)).collect()
            }
            // ANOSIM / PERMDISP: the generic blocked walk (SoA rank sweep
            // for ANOSIM, per-lane scalar for PERMDISP).
            stat => eval_plan_range_blocked(
                stat,
                plan.grouping,
                plan.perms,
                plan.start,
                plan.rows,
                self.perm_block,
                &plan.shard,
            ),
        };
        Ok(BatchResult {
            start: plan.start,
            stats,
            elapsed_secs: t0.elapsed().as_secs_f64(),
            modelled_secs: None,
            // Device tag carries the width actually used for this batch.
            backend: format!("native-batch/b{}", self.perm_block.min(plan.rows.max(1))),
        })
    }

    fn capabilities(&self) -> Caps {
        Caps {
            name: "native-batch".to_string(),
            kernel: "brute-block".to_string(),
            max_batch: Some(self.perm_block),
            threaded: true,
            modelled_time: false,
            perm_block: Some(self.perm_block),
        }
    }
}

/// `native-batch`: block width from the config's `perm_block` knob.
pub fn factory(cfg: &RunConfig) -> Result<Box<dyn Backend>> {
    Ok(Box::new(BatchedBruteBackend::new(cfg.perm_block)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{NativeBackend, ShardSpec};
    use crate::dmat::DistanceMatrix;
    use crate::permanova::{Grouping, Method, SwAlgorithm, DEFAULT_PERM_BLOCK};
    use crate::rng::PermutationPlan;

    fn plan_fixture(
        n: usize,
        k: usize,
        count: usize,
    ) -> (DistanceMatrix, Grouping, PermutationPlan) {
        let mat = DistanceMatrix::random_euclidean(n, 6, 17);
        let grouping = Grouping::balanced(n, k).unwrap();
        let perms = PermutationPlan::new(grouping.labels().to_vec(), 23, count);
        (mat, grouping, perms)
    }

    #[test]
    fn bitwise_identical_to_native_brute_across_blocks_and_shards() {
        let (mat, grouping, perms) = plan_fixture(44, 4, 50);
        // The contract holds per method, not just for pseudo-F.
        for method in [Method::Permanova, Method::Anosim, Method::Permdisp] {
            let stat = StatKernel::prepare(method, &mat, &grouping).unwrap();
            let mk = |shard: ShardSpec| BatchPlan {
                grouping: &grouping,
                perms: &perms,
                start: 0,
                rows: 50,
                stat: &stat,
                shard,
            };
            let brute = NativeBackend::new(SwAlgorithm::Brute)
                .run_batch(&mk(ShardSpec::with_workers(1)))
                .unwrap();
            for block in [1usize, 8, 64] {
                for shard in [
                    ShardSpec::with_workers(1),
                    ShardSpec { shard_size: 7, workers: 3, smt: false },
                    ShardSpec { shard_size: 16, workers: 2, smt: true },
                ] {
                    let b = BatchedBruteBackend::new(block);
                    let r = b.run_batch(&mk(shard)).unwrap();
                    assert_eq!(r.stats.len(), 50);
                    for (i, (got, want)) in r.stats.iter().zip(&brute.stats).enumerate() {
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "{method:?} block={block} shard={shard:?} perm {i}: {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sub_range_batches_line_up() {
        let (mat, grouping, perms) = plan_fixture(30, 3, 40);
        let stat = StatKernel::prepare(Method::Permanova, &mat, &grouping).unwrap();
        let b = BatchedBruteBackend::new(8);
        let mk = |start: usize, rows: usize| BatchPlan {
            grouping: &grouping,
            perms: &perms,
            start,
            rows,
            stat: &stat,
            shard: ShardSpec::with_workers(2),
        };
        let full = b.run_batch(&mk(0, 40)).unwrap();
        let head = b.run_batch(&mk(0, 13)).unwrap();
        let tail = b.run_batch(&mk(13, 27)).unwrap();
        assert_eq!(&full.stats[..13], &head.stats[..]);
        assert_eq!(&full.stats[13..], &tail.stats[..]);
    }

    #[test]
    fn capabilities_record_block_width() {
        let caps = BatchedBruteBackend::new(32).capabilities();
        assert_eq!(caps.name, "native-batch");
        assert_eq!(caps.kernel, "brute-block");
        assert_eq!(caps.perm_block, Some(32));
        assert_eq!(caps.max_batch, Some(32));
        assert!(caps.threaded);
        assert!(!caps.modelled_time);
        // 0 resolves to the default.
        assert_eq!(
            BatchedBruteBackend::new(0).capabilities().perm_block,
            Some(DEFAULT_PERM_BLOCK)
        );
    }

    #[test]
    fn factory_reads_the_config_knob() {
        let cfg = RunConfig { perm_block: 16, ..Default::default() };
        let be = factory(&cfg).unwrap();
        assert_eq!(be.capabilities().perm_block, Some(16));
        assert_eq!(
            factory(&RunConfig::default()).unwrap().capabilities().perm_block,
            Some(DEFAULT_PERM_BLOCK)
        );
    }
}
