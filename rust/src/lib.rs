//! # permanova-apu
//!
//! A production-shaped reproduction of *"Comparing CPU and GPU compute of
//! PERMANOVA on MI300A"* (Igor Sfiligoi, PEARC'25): PERMANOVA — the
//! permutation test microbiome studies run over distance matrices — with
//! the paper's three kernel formulations (brute force, cache-tiled,
//! device-reshaped), a device coordinator that schedules permutation batches
//! across native CPU kernels, AOT-compiled XLA kernels (PJRT), and a
//! calibrated MI300A CPU/GPU performance model that regenerates the paper's
//! Figure 1 and Appendix A2 without the hardware.
//!
//! ## Layering (see DESIGN.md)
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`), AOT-lowered to HLO
//!   text at build time.
//! * **L2** — the JAX PERMANOVA batch graph (`python/compile/model.py`).
//! * **L3** — this crate: substrates ([`rng`], [`dmat`], [`unifrac`],
//!   [`stream`], [`simulator`], [`bench`]), the statistics core
//!   ([`permanova`]: the PERMANOVA kernels plus the statistic-generic
//!   `Method`/`StatKernel` seam covering ANOSIM, PERMDISP and pairwise
//!   PERMANOVA), the XLA runtime ([`runtime`]), the unified [`backend`]
//!   execution engine (the `Backend` trait, its name-keyed registry and
//!   the sharded permutation scheduler — generic over the statistic), the
//!   heterogeneous [`coordinator`], the shared-dataset [`service`]
//!   layer (dataset cache + multi-job batch driver behind the `serve`
//!   subcommand), and the durable result [`store`] (a crash-safe LSM
//!   cache under the service layer, so warm state survives restarts),
//!   plus reporting and the CLI.
//!
//! Python never runs on the request path: `make artifacts` lowers the JAX
//! graph once, and the binary only loads `artifacts/*.hlo.txt`.
//!
//! ## Quickstart
//!
//! ```no_run
//! use permanova_apu::dmat::DistanceMatrix;
//! use permanova_apu::permanova::{permanova, Grouping, PermanovaOpts};
//!
//! let mat = DistanceMatrix::random_euclidean(64, 8, 42);
//! let grouping = Grouping::balanced(64, 4).unwrap();
//! let res = permanova(&mat, &grouping, 999, &PermanovaOpts::default()).unwrap();
//! println!("F = {:.4}, p = {:.4}", res.f_obs, res.p_value);
//! ```

pub mod backend;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dmat;
pub mod error;
pub mod inject;
pub mod jsonio;
pub mod permanova;
pub mod report;
pub mod request;
pub mod rng;
pub mod runtime;
pub mod service;
pub mod simulator;
pub mod store;
pub mod stream;
pub mod unifrac;

pub use error::{Error, Result};
pub use request::AnalysisRequest;

/// Crate version, surfaced by the CLI and embedded in run reports.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Default location of the AOT artifacts directory, relative to the repo
/// root (overridable everywhere via `--artifacts` / config).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";
