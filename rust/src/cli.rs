//! Command-line interface: flag parsing and subcommand implementations.
//!
//! Hand-rolled (the offline crate set has no clap): `<command> [--flag
//! value]...` with every command returning its report as a `String` so the
//! whole surface is unit-testable without capturing stdout.
//!
//! Commands:
//!   run              permutation test on synthetic/file data; --method
//!                    selects permanova|anosim|permdisp|pairwise;
//!                    --repeat N runs warm through the dataset cache
//!   serve            JSONL job batch through the shared-dataset service
//!                    (one DatasetCache + one scheduler pool per batch);
//!                    --listen ADDR runs the long-lived TCP daemon instead
//!   client           speak to a running daemon: pipelined --jobs FILE,
//!                    --stats, --shutdown over length-prefixed JSONL
//!   bench            sweep backends × methods over n/perm grids ->
//!                    BENCH_PERMANOVA.json (incl. cold/warm throughput)
//!   backends         list registered backends + capabilities
//!                    (also reachable as `--list-backends`)
//!   pipeline         E2E: synthetic community -> UniFrac -> PERMANOVA
//!   fig1             regenerate the paper's Figure 1 (simulated MI300A)
//!   stream           STREAM bandwidth: measured host + simulated MI300A (A2)
//!   simulate         performance-model predictions / node topology (A1)
//!   artifacts-check  verify + smoke-run the AOT artifacts
//!   version          print version

use std::collections::BTreeMap;

use crate::config::{DataSource, RunConfig, TomlDoc};
use crate::error::{Error, Result};
use crate::permanova::{Method, SwAlgorithm};
use crate::request::AnalysisRequest;
use crate::report::{bar_chart, Table};
use crate::simulator::{
    fig1_rows, paper_a2_reference, render_fig1, simulate_stream, Mi300a, NodeTopology,
    StreamDevice, Workload,
};
use crate::stream::run_stream;

/// Parsed command line: subcommand + `--key value` flags (bare `--key`
/// becomes `"true"`).
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse raw args (excluding argv[0]).
    pub fn parse(raw: &[String]) -> Result<Args> {
        let mut it = raw.iter().peekable();
        let command = it
            .next()
            .cloned()
            .ok_or_else(|| Error::Config("no command (try `help`)".into()))?;
        if command.starts_with("--") && command != "--help" && command != "--list-backends" {
            return Err(Error::Config(format!(
                "expected a command before flags, got {command:?}"
            )));
        }
        let mut flags = BTreeMap::new();
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| Error::Config(format!("expected --flag, got {tok:?}")))?;
            if key.is_empty() {
                return Err(Error::Config("empty flag name".into()));
            }
            let value = match it.peek() {
                Some(next) if !next.starts_with("--") => it.next().unwrap().clone(),
                _ => "true".to_string(),
            };
            flags.insert(key.to_string(), value);
        }
        Ok(Args { command, flags })
    }

    pub fn str_flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize_flag(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::Config(format!("--{key} {v:?}: {e}"))),
        }
    }

    pub fn u64_flag(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::Config(format!("--{key} {v:?}: {e}"))),
        }
    }

    /// Boolean flag: absent = `false`, bare `--flag` = `true`, explicit
    /// literals `true/1/yes` / `false/0/no` as written.  Anything else is
    /// a config error — `--smt-oversubscribe ture` must not silently run
    /// with the feature off.
    pub fn bool_flag(&self, key: &str) -> Result<bool> {
        match self.flags.get(key).map(|s| s.as_str()) {
            None => Ok(false),
            Some("true" | "1" | "yes") => Ok(true),
            Some("false" | "0" | "no") => Ok(false),
            Some(other) => Err(Error::Config(format!(
                "--{key} expects a boolean (true/1/yes or false/0/no), got {other:?}"
            ))),
        }
    }

    /// Whether a flag was given at all.
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

/// Top-level dispatch; returns the text to print.
pub fn dispatch(args: &Args) -> Result<String> {
    match args.command.as_str() {
        "run" => cmd_run(args),
        "serve" => cmd_serve(args),
        "client" => cmd_client(args),
        "bench" => cmd_bench(args),
        "backends" | "--list-backends" => cmd_backends(args),
        "pipeline" => cmd_pipeline(args),
        "fig1" => cmd_fig1(args),
        "stream" => cmd_stream(args),
        "simulate" => cmd_simulate(args),
        "artifacts-check" => cmd_artifacts_check(args),
        "version" => Ok(format!("permanova-apu {}", crate::VERSION)),
        "help" | "--help" | "-h" => Ok(usage()),
        other => Err(Error::Config(format!("unknown command {other:?} (try `help`)"))),
    }
}

/// Usage text.
pub fn usage() -> String {
    let mut s = String::from("permanova-apu — PERMANOVA on APU-class hardware\n\nCommands:\n");
    for (cmd, desc) in [
        ("run", "permutation test: --method permanova|anosim|permdisp|pairwise --n-dims N --n-groups K --n-perms P --algo brute|tiled|flat --backend NAME --perm-block B --threads T --shard-size S --smt-oversubscribe --seed S --data-seed D --data-tol T --max-resident-bytes B (0 = unbounded; smaller datasets spill to disk and run chunk-major, bitwise identical) --repeat N [--store-dir DIR [--store-capacity-bytes B] | --no-store] --json out.json --config file.toml | --pdm file --labels file (file input is validated on load); legacy oracle-path companions (bypass the backend engine): --pairwise --anosim --permdisp"),
        ("serve", "JSONL job batch through the shared-dataset service: --jobs FILE [--out FILE] [--cache-capacity N] [--threads T]; --listen HOST:PORT runs the TCP daemon instead (adds --queue-depth N; SIGTERM/ctrl-C drains); --store-dir DIR attaches the durable result store (crash-safe; warm state survives restarts; --store-capacity-bytes B bounds it, --no-store disables); --fault-plan SPEC arms deterministic fault injection for chaos drills (e.g. store.wal.write:err@3,scratch.read:corrupt@2 — see DESIGN.md §2.13); --check FILE validates a response document"),
        ("client", "speak to a running daemon: --addr HOST:PORT with any of --jobs FILE (pipelined v1/legacy requests), --stats, --shutdown; --retries N reconnects-and-resumes dropped exchanges and re-asks shed requests with capped jittered backoff (honoring retry_after; --retry-budget-ms MS caps the total); prints one JSONL response per request; exits non-zero when any job fails"),
        ("bench", "backend x method sweep -> BENCH_PERMANOVA.json: --quick | --backends a,b --methods permanova,anosim --n-dims 128,256 --n-perms 499 --n-groups K --perm-block B --threads T --shard-size S --smt-oversubscribe --throughput-jobs J --latency-clients 1,4 (0 disables) --out FILE; --check FILE validates an existing document"),
        ("backends", "list registered backends with their capabilities (alias: --list-backends)"),
        ("pipeline", "end-to-end: community -> UniFrac -> PERMANOVA: --taxa --samples --groups --n-perms --metric unweighted|weighted --anosim"),
        ("fig1", "regenerate Figure 1: --n-dims --n-perms (defaults: the paper's 25145/3999)"),
        ("stream", "STREAM bandwidth: --len --reps --threads; --simulate for the MI300A A2 tables"),
        ("simulate", "model predictions: --n-dims --n-perms; --topology for the Appendix A1 node"),
        ("artifacts-check", "verify AOT artifacts: --dir artifacts"),
        ("version", "print version"),
    ] {
        s.push_str(&format!("  {cmd:<16} {desc}\n"));
    }
    s.push_str(&format!("\nBackends: {}\n", crate::backend::known_backends().join(", ")));
    s.push_str(&format!(
        "Methods:  {} (any method on any backend)\n",
        Method::ALL.map(|m| m.name()).join(", ")
    ));
    s
}

/// `backends` / `--list-backends`: one row per registry entry with its
/// static capabilities, so users can discover valid `--backend` /
/// `--method` combinations without reading the source.
fn cmd_backends(args: &Args) -> Result<String> {
    let registry = crate::backend::Registry::with_defaults();
    let mut cfg = RunConfig::default();
    if let Some(d) = args.str_flag("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    let mut t = Table::new(&["backend", "kernel", "block", "threaded", "modelled time", "status"]);
    let mut notes = Vec::new();
    for name in registry.names() {
        match registry.create(&name, &cfg) {
            Ok(b) => {
                let caps = b.capabilities();
                t.row(&[
                    name.clone(),
                    caps.kernel,
                    caps.perm_block.map_or("-".to_string(), |b| b.to_string()),
                    if caps.threaded { "yes" } else { "no" }.to_string(),
                    if caps.modelled_time { "yes" } else { "no" }.to_string(),
                    "ok".to_string(),
                ]);
            }
            Err(e) => {
                // Typically `xla` without artifacts/PJRT: list it anyway so
                // the name stays discoverable, and say why it won't open.
                t.row(&[
                    name.clone(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "unavailable".to_string(),
                ]);
                notes.push(format!("  {name}: {e}"));
            }
        }
    }
    let mut out = t.render();
    if !notes.is_empty() {
        out.push_str(&format!("unavailable backends:\n{}\n", notes.join("\n")));
    }
    out.push_str(&format!(
        "methods: {} — every method runs on every backend (--method NAME)\n",
        Method::ALL.map(|m| m.name()).join(", ")
    ));
    Ok(out)
}

/// Resolve the durable-store settings: the `[store]` config section (when
/// `--config` is given), overridden by `--store-dir` /
/// `--store-capacity-bytes`, with `--no-store` winning over everything.
fn store_settings_from_args(args: &Args) -> Result<crate::config::StoreSettings> {
    let mut s = if let Some(path) = args.str_flag("config") {
        crate::config::StoreSettings::from_toml(&TomlDoc::load(path)?)?
    } else {
        crate::config::StoreSettings::default()
    };
    if let Some(dir) = args.str_flag("store-dir") {
        s.dir = Some(dir.to_string());
    }
    s.capacity_bytes = args.u64_flag("store-capacity-bytes", s.capacity_bytes)?;
    if args.bool_flag("no-store")? {
        s.enabled = false;
    }
    Ok(s)
}

/// Resolve and arm the deterministic fault-injection plan: the `[fault]`
/// config section (when `--config` is given), overridden by
/// `--fault-plan SPEC`.  Returns the armed spec for the startup notice,
/// `None` when no plan was requested (the common case — injection stays
/// a single relaxed atomic load at every seam).
fn install_fault_plan_from_args(args: &Args) -> Result<Option<String>> {
    let mut spec = if let Some(path) = args.str_flag("config") {
        crate::config::FaultSettings::from_toml(&TomlDoc::load(path)?)?.plan
    } else {
        None
    };
    if let Some(s) = args.str_flag("fault-plan") {
        spec = Some(s.to_string());
    }
    match spec {
        Some(s) => {
            crate::inject::install(crate::inject::FaultPlan::parse(&s)?);
            Ok(Some(s))
        }
        None => Ok(None),
    }
}

/// Open the resolved durable store, if one is enabled (`None` = run
/// store-free, exactly as before the store existed).
fn open_store_from_args(
    args: &Args,
) -> Result<Option<std::sync::Arc<crate::store::ResultStore>>> {
    let s = store_settings_from_args(args)?;
    if !s.enabled {
        return Ok(None);
    }
    let Some(dir) = s.dir else { return Ok(None) };
    let mut sc = crate::store::StoreConfig::new(dir);
    sc.capacity_bytes = s.capacity_bytes;
    Ok(Some(std::sync::Arc::new(crate::store::ResultStore::open(sc)?)))
}

fn config_from_args(args: &Args) -> Result<RunConfig> {
    let mut cfg = if let Some(path) = args.str_flag("config") {
        RunConfig::from_toml(&TomlDoc::load(path)?)?
    } else {
        RunConfig::default()
    };
    if let (Some(pdm), Some(labels)) = (args.str_flag("pdm"), args.str_flag("labels")) {
        cfg.data = DataSource::Pdm { path: pdm.to_string(), labels_path: labels.to_string() };
    } else if args.has_flag("n-dims") || args.has_flag("n-groups") {
        let (dn, dk) = match cfg.data {
            DataSource::Synthetic { n_dims, n_groups } => (n_dims, n_groups),
            _ => (256, 8),
        };
        cfg.data = DataSource::Synthetic {
            n_dims: args.usize_flag("n-dims", dn)?,
            n_groups: args.usize_flag("n-groups", dk)?,
        };
    }
    cfg.n_perms = args.usize_flag("n-perms", cfg.n_perms)?;
    cfg.seed = args.u64_flag("seed", cfg.seed)?;
    cfg.threads = args.usize_flag("threads", cfg.threads)?;
    cfg.shard_size = args.usize_flag("shard-size", cfg.shard_size)?;
    cfg.perm_block = args.usize_flag("perm-block", cfg.perm_block)?;
    cfg.max_resident_bytes = args.u64_flag("max-resident-bytes", cfg.max_resident_bytes)?;
    if args.has_flag("smt-oversubscribe") {
        cfg.smt_oversubscribe = args.bool_flag("smt-oversubscribe")?;
    }
    if args.has_flag("data-seed") {
        cfg.data_seed = Some(args.u64_flag("data-seed", 0)?);
    }
    if let Some(v) = args.str_flag("data-tol") {
        cfg.data_tol = v
            .parse()
            .map_err(|e| Error::Config(format!("--data-tol {v:?}: {e}")))?;
    }
    if let Some(a) = args.str_flag("algo") {
        cfg.algo = SwAlgorithm::parse(a)
            .ok_or_else(|| Error::Config(format!("unknown --algo {a:?}")))?;
    }
    if let Some(m) = args.str_flag("method") {
        cfg.method = Method::parse(m)
            .ok_or_else(|| Error::Config(format!("unknown --method {m:?}")))?;
    }
    if let Some(b) = args.str_flag("backend") {
        cfg.backend = b.to_string();
    }
    if let Some(d) = args.str_flag("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    if let Some(k) = args.str_flag("xla-kernel") {
        cfg.xla_kernel = k.to_string();
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<String> {
    let cfg = config_from_args(args)?;

    // `--repeat N`: run the same configuration N times through the service
    // layer — the dataset and prelude are loaded once, every iteration
    // reuses them (bitwise-identical results), and the sharded loops share
    // one scheduler pool.  The cold-vs-warm wall clocks land in the table.
    let repeat = args.usize_flag("repeat", 1)?;
    if repeat > 1 {
        // The repeat path renders its own table and nothing else; reject
        // flags it would otherwise silently ignore.
        for flag in ["json", "pairwise", "anosim", "permdisp"] {
            if args.has_flag(flag) {
                return Err(Error::Config(format!(
                    "--repeat does not combine with --{flag} (run them as separate invocations)"
                )));
            }
        }
        return cmd_run_repeated(&cfg, repeat, open_store_from_args(args)?);
    }
    // The durable store only pays off across repeated/served analyses; on
    // a one-shot run the flags would be silently inert — reject instead.
    for flag in ["store-dir", "store-capacity-bytes", "no-store"] {
        if args.has_flag(flag) {
            return Err(Error::Config(format!(
                "--{flag} needs --repeat N (or the serve subcommand) — a single run never \
                 revisits the store"
            )));
        }
    }
    let r = AnalysisRequest::new(&cfg).run()?;
    // The report carries the kernel the backend actually evaluated
    // (`Caps::kernel`), so rendering needs no config-side label.
    let mut out = r.render();

    // Legacy companion flags: append the *oracle-path* results (the
    // standalone free functions, single-threaded, engine bypassed).  The
    // engine-scheduled spelling of the same tests is `--method
    // anosim|permdisp|pairwise`; the conformance suite pins that the two
    // paths agree exactly, which is why both stay.
    if args.bool_flag("pairwise")? {
        use crate::coordinator::load_data;
        use crate::permanova::{pairwise_permanova, PermanovaOpts};
        let (tri, grouping) = load_data(&cfg)?;
        // The oracle free functions keep their dense signature; mirror a
        // transient copy from the packed triangle for this render only.
        let mat = tri.to_dense();
        let pw = pairwise_permanova(
            &mat,
            &grouping,
            cfg.n_perms,
            &PermanovaOpts {
                algo: cfg.algo,
                threads: cfg.threads,
                seed: cfg.seed,
                keep_f_perms: false,
            },
        )?;
        let mut t = Table::new(&["pair", "n", "pseudo-F", "p", "p (Bonferroni)"]);
        for e in &pw.entries {
            t.row(&[
                format!("{} vs {}", e.group_a, e.group_b),
                e.n.to_string(),
                format!("{:.4}", e.f_obs),
                format!("{:.4}", e.p_value),
                format!("{:.4}", e.p_adjusted),
            ]);
        }
        out.push_str(&format!("\npairwise ({} comparisons):\n{}", pw.n_comparisons, t.render()));
    }

    // Companion tests (the full skbio-style workflow).
    if args.bool_flag("anosim")? || args.bool_flag("permdisp")? {
        use crate::coordinator::load_data;
        let (tri, grouping) = load_data(&cfg)?;
        let mat = tri.to_dense(); // transient oracle staging, as above

        if args.bool_flag("anosim")? {
            let a = crate::permanova::anosim(&mat, &grouping, cfg.n_perms, cfg.seed)?;
            out.push_str(&format!("ANOSIM:   R = {:.4}, p = {:.4}\n", a.r_obs, a.p_value));
        }
        if args.bool_flag("permdisp")? {
            let d = crate::permanova::permdisp(&mat, &grouping, cfg.n_perms, cfg.seed)?;
            out.push_str(&format!(
                "PERMDISP: F = {:.4}, p = {:.4} (dispersions: {})\n",
                d.f_obs,
                d.p_value,
                d.group_dispersions
                    .iter()
                    .map(|x| format!("{x:.3}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            ));
        }
    }

    // Machine-readable export (the backend name rides along in the JSON).
    if let Some(path) = args.str_flag("json") {
        let doc = r.to_json();
        std::fs::write(path, doc.to_string_pretty())
            .map_err(|e| Error::io(path, e))?;
        out.push_str(&format!("wrote {path}\n"));
    }
    Ok(out)
}

/// `run --repeat N`: the same configuration N times through the service
/// layer (one shared pool, one cached dataset + prelude), with the
/// cold-vs-warm wall clocks tabled per iteration.  With `--store-dir`,
/// iterations go through the durable tier instead: results persist across
/// process restarts, and a re-run over the same store directory answers
/// from disk without recomputing.
fn cmd_run_repeated(
    cfg: &RunConfig,
    repeat: usize,
    store: Option<std::sync::Arc<crate::store::ResultStore>>,
) -> Result<String> {
    use crate::backend::shard::with_shared_pool;
    use crate::report::AnalysisReport;
    use crate::service::DatasetCache;
    use std::time::Instant;

    if let Some(store) = store {
        return cmd_run_repeated_stored(cfg, repeat, store);
    }
    let cache = DatasetCache::new(2);
    let mut t = Table::new(&["iteration", "cache", "wall s"]);
    let mut first: Option<AnalysisReport> = None;
    with_shared_pool(cfg.threads, |_pool| -> Result<()> {
        for i in 1..=repeat {
            let t0 = Instant::now();
            let (r, hit) = AnalysisRequest::new(cfg).via_cache(&cache).run_traced()?;
            t.row(&[
                format!("iter-{i}"),
                if hit { "hit" } else { "miss" }.to_string(),
                format!("{:.4}", t0.elapsed().as_secs_f64()),
            ]);
            // Every iteration is bitwise-identical (same seed, same data);
            // render the first and table the rest.
            if first.is_none() {
                first = Some(r);
            }
        }
        Ok(())
    })?;
    let stats = cache.stats();
    let mut out = first.expect("repeat >= 2 ran at least once").render();
    out.push_str(&format!("\nrepeat x{repeat} (warm iterations reuse the cached dataset):\n"));
    out.push_str(&t.render());
    out.push_str(&format!(
        "cache: {} hits / {} misses ({:.0}% hit rate)\n",
        stats.hits,
        stats.misses,
        100.0 * stats.hit_rate()
    ));
    Ok(out)
}

/// The store-backed edition of `run --repeat`: every iteration goes
/// through [`execute_job`](crate::service::execute_job) — the same durable
/// lookup/insert path the daemon uses — so a second invocation over the
/// same `--store-dir` answers every iteration from disk.
fn cmd_run_repeated_stored(
    cfg: &RunConfig,
    repeat: usize,
    store: std::sync::Arc<crate::store::ResultStore>,
) -> Result<String> {
    use crate::backend::shard::with_shared_pool;
    use crate::jsonio::Json;
    use crate::service::{execute_job, DatasetCache, JobRequest};
    use std::time::Instant;

    let cache = DatasetCache::with_store(2, std::sync::Arc::clone(&store));
    let job = JobRequest::new("repeat", cfg.clone());
    let mut t = Table::new(&["iteration", "cache", "store", "wall s"]);
    let mut first: Option<Json> = None;
    with_shared_pool(cfg.threads, |_pool| -> Result<()> {
        for i in 1..=repeat {
            let t0 = Instant::now();
            let (resp, ok) = execute_job(&job, &cache);
            if !ok {
                let msg =
                    resp.get("error").and_then(Json::as_str).unwrap_or("job failed").to_string();
                return Err(Error::Config(msg));
            }
            t.row(&[
                format!("iter-{i}"),
                resp.req_str("cache")?.to_string(),
                resp.req_str("store")?.to_string(),
                format!("{:.4}", t0.elapsed().as_secs_f64()),
            ]);
            if first.is_none() {
                first = Some(resp);
            }
        }
        Ok(())
    })?;
    // Flush the memtable so even an abrupt exit after this point leaves
    // nothing to replay (every put was already WAL-durable regardless).
    store.drain()?;
    let s = store.stats();
    let first = first.expect("repeat >= 2 ran at least once");
    let report = first.get("report").ok_or_else(|| Error::Config("response without report".into()))?;
    let mut out = format!(
        "{} on {}: F = {}, p = {}\n",
        report.req_str("method")?,
        report.req_str("backend")?,
        report.get("f_obs").and_then(Json::as_f64).unwrap_or(f64::NAN),
        report.get("p_value").and_then(Json::as_f64).unwrap_or(f64::NAN),
    );
    out.push_str(&format!("\nrepeat x{repeat} through the durable store:\n"));
    out.push_str(&t.render());
    out.push_str(&format!(
        "store: {} hits / {} misses / {} puts, {} segments, {} bytes on disk\n",
        s.hits, s.misses, s.puts, s.segments, s.disk_bytes
    ));
    Ok(out)
}

/// `serve`: execute a JSONL job batch through the shared-dataset service
/// layer, run the long-lived TCP daemon (`--listen`), or (`--check`)
/// validate a response document.
fn cmd_serve(args: &Args) -> Result<String> {
    use crate::service::{parse_jobs, run_jobs, validate_responses, DatasetCache};

    if let Some(path) = args.str_flag("check") {
        let text = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
        let (ok, failed) = validate_responses(&text)?;
        return Ok(format!("responses ok: {path} ({ok} ok, {failed} failed)\n"));
    }

    // Chaos drills arm the plan for the daemon and the file batch alike
    // (the one-shot `run` path deliberately has no injection knob).
    if let Some(spec) = install_fault_plan_from_args(args)? {
        eprintln!("fault injection ARMED: {spec} (chaos drill — not for production)");
    }

    if let Some(addr) = args.str_flag("listen") {
        return cmd_serve_daemon(args, addr);
    }

    let jobs_path = args
        .str_flag("jobs")
        .ok_or_else(|| Error::Config("serve needs --jobs FILE (or --check FILE)".into()))?;
    let text = std::fs::read_to_string(jobs_path).map_err(|e| Error::io(jobs_path, e))?;
    let jobs = parse_jobs(&text)?;
    let capacity = args.usize_flag("cache-capacity", 8)?;
    let store = open_store_from_args(args)?;
    let cache = match &store {
        Some(s) => DatasetCache::with_store(capacity, std::sync::Arc::clone(s)),
        None => DatasetCache::new(capacity),
    };
    let workers = args.usize_flag("threads", 0)?;
    let batch = run_jobs(&jobs, &cache, workers);
    if let Some(s) = &store {
        // Flush the memtable into a sorted table; every result was
        // already WAL-fsynced, so a failed drain is only a lost
        // optimization, never lost data.
        let _ = s.drain();
    }

    match args.str_flag("out") {
        // File output: responses to disk, summary (with the cache
        // counters) to the console.
        Some(path) => {
            std::fs::write(path, batch.to_jsonl()).map_err(|e| Error::io(path, e))?;
            Ok(format!(
                "wrote {path} ({} responses)\n{}",
                batch.responses.len(),
                batch.summary.render()
            ))
        }
        // Stdout output stays pure JSONL so it can be piped; the summary
        // is available by re-running with --out.
        None => Ok(batch.to_jsonl()),
    }
}

/// `serve --listen`: the long-lived TCP daemon.  Blocks until SIGTERM,
/// ctrl-C or a client `shutdown` request drains it, then reports the
/// final accounting.
fn cmd_serve_daemon(args: &Args, addr: &str) -> Result<String> {
    use crate::service::{install_signal_handlers, Daemon, DaemonConfig};

    let store = store_settings_from_args(args)?;
    let cfg = DaemonConfig {
        addr: addr.to_string(),
        workers: args.usize_flag("threads", 0)?,
        cache_capacity: args.usize_flag("cache-capacity", 8)?,
        queue_depth: args.usize_flag("queue-depth", 64)?,
        store_dir: if store.enabled { store.dir.map(Into::into) } else { None },
        store_capacity_bytes: store.capacity_bytes,
        ..DaemonConfig::default()
    };
    install_signal_handlers();
    let daemon = Daemon::spawn(cfg)?;
    // Announce the bound address immediately (port 0 lets the OS pick);
    // everything after this line blocks until drain completes.
    println!("listening on {} (SIGTERM, ctrl-C or a shutdown request drains)", daemon.addr());
    let summary = daemon.join()?;
    Ok(format!("daemon drained\n{}", summary.render()))
}

/// `client`: speak the versioned envelope protocol to a running daemon.
/// Requests (any mix of a pipelined `--jobs` file, `--stats` and
/// `--shutdown`) go out in one connection; responses print as JSONL in
/// request order.
fn cmd_client(args: &Args) -> Result<String> {
    use crate::jsonio::Json;
    use crate::service::{client_exchange_retrying, envelope_v1, RetryPolicy};

    let addr = args
        .str_flag("addr")
        .ok_or_else(|| Error::Config("client needs --addr HOST:PORT".into()))?;
    let addr: std::net::SocketAddr = addr
        .parse()
        .map_err(|_| Error::Config(format!("--addr {addr:?} is not an ip:port address")))?;
    let mut requests: Vec<String> = Vec::new();
    if let Some(path) = args.str_flag("jobs") {
        // Job lines go out as-is: v1 envelopes pass through, legacy bare
        // jobs reach the daemon as implicit v0 (its responses carry the
        // deprecation note).
        let text = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
        for line in text.lines().map(str::trim).filter(|l| !l.is_empty()) {
            requests.push(line.to_string());
        }
    }
    // Everything queued so far is a job; --stats / --shutdown are
    // appended after, so `take(job_count)` below scopes the failure
    // check to the actual analysis responses.
    let job_count = requests.len();
    if args.bool_flag("stats")? {
        let payload = Json::obj(vec![("op", Json::str("stats"))]);
        requests.push(envelope_v1(Some("stats"), payload).to_string());
    }
    if args.bool_flag("shutdown")? {
        let payload = Json::obj(vec![("op", Json::str("shutdown"))]);
        requests.push(envelope_v1(Some("shutdown"), payload).to_string());
    }
    if requests.is_empty() {
        return Err(Error::Config(
            "client needs at least one of --jobs FILE, --stats, --shutdown".into(),
        ));
    }
    // --retries 0 (the default) is byte-for-byte the old single-shot
    // exchange; anything higher adds reconnect-and-resume plus shed
    // retries with capped, jittered backoff.
    let policy = RetryPolicy {
        retries: args.usize_flag("retries", 0)?,
        budget_ms: args.u64_flag("retry-budget-ms", 0)?,
    };
    let responses = client_exchange_retrying(&addr, &requests, policy)?;
    let mut out = String::new();
    for r in &responses {
        out.push_str(&r.to_string());
        out.push('\n');
    }
    // A failed job must fail the invocation: scripts drive `client
    // --jobs` and a zero exit on an `ok:false` response silently drops
    // results.  The responses still reach stdout for pipelines; the
    // failure count goes to stderr via the dispatch error path.
    let failed = responses
        .iter()
        .take(job_count)
        .filter(|r| r.get("ok").and_then(Json::as_bool) == Some(false))
        .count();
    if failed > 0 {
        print!("{out}");
        return Err(Error::Config(format!("{failed} of {job_count} jobs failed")));
    }
    Ok(out)
}

/// Parse a `--flag a,b,c` comma-separated usize list.
fn parse_usize_csv(flag: &str, v: &str) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    for part in v.split(',') {
        let t = part.trim();
        if t.is_empty() {
            continue;
        }
        out.push(
            t.parse()
                .map_err(|e| Error::Config(format!("--{flag} {t:?}: {e}")))?,
        );
    }
    if out.is_empty() {
        return Err(Error::Config(format!("--{flag}: empty list")));
    }
    Ok(out)
}

/// `bench`: sweep backends over n/permutation grids and write the repo's
/// performance record, or (`--check`) validate an existing one.
fn cmd_bench(args: &Args) -> Result<String> {
    use crate::bench::{run_sweep, validate_bench_json, SweepGrid};

    // Validation mode: parse + schema-check an existing document.
    if let Some(path) = args.str_flag("check") {
        let text = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
        let doc = crate::jsonio::Json::parse(&text)?;
        let n = validate_bench_json(&doc)?;
        return Ok(format!("bench json ok: {path} ({n} entries)\n"));
    }

    let mut grid = if args.bool_flag("quick")? {
        SweepGrid::quick()
    } else {
        SweepGrid::default()
    };
    if let Some(b) = args.str_flag("backends") {
        grid.backends = b
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
    }
    // `--methods a,b` adds the method axis; `--method a` is the
    // single-method convenience spelling.
    if let Some(m) = args.str_flag("methods").or_else(|| args.str_flag("method")) {
        grid.methods = m
            .split(',')
            .map(|s| s.trim())
            .filter(|s| !s.is_empty())
            .map(|s| {
                Method::parse(s).ok_or_else(|| Error::Config(format!("unknown method {s:?}")))
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(v) = args.str_flag("n-dims") {
        grid.n_grid = parse_usize_csv("n-dims", v)?;
    }
    if let Some(v) = args.str_flag("n-perms") {
        grid.perm_grid = parse_usize_csv("n-perms", v)?;
    }
    grid.n_groups = args.usize_flag("n-groups", grid.n_groups)?;
    grid.base.seed = args.u64_flag("seed", grid.base.seed)?;
    grid.base.threads = args.usize_flag("threads", grid.base.threads)?;
    grid.base.shard_size = args.usize_flag("shard-size", grid.base.shard_size)?;
    grid.base.perm_block = args.usize_flag("perm-block", grid.base.perm_block)?;
    grid.throughput_jobs = args.usize_flag("throughput-jobs", grid.throughput_jobs)?;
    if let Some(v) = args.str_flag("latency-clients") {
        // `--latency-clients 0` disables the daemon latency axis (mirrors
        // `--throughput-jobs 0`); any other list is client counts.
        grid.latency_clients =
            if v.trim() == "0" { Vec::new() } else { parse_usize_csv("latency-clients", v)? };
    }
    if args.has_flag("smt-oversubscribe") {
        grid.base.smt_oversubscribe = args.bool_flag("smt-oversubscribe")?;
    }

    let sweep = run_sweep(&grid)?;
    let out_path = args.str_flag("out").unwrap_or("BENCH_PERMANOVA.json");
    std::fs::write(out_path, sweep.json.to_string_pretty())
        .map_err(|e| Error::io(out_path, e))?;
    Ok(format!("{}wrote {out_path} ({} entries)\n", sweep.table, sweep.entries))
}

fn cmd_pipeline(args: &Args) -> Result<String> {
    use crate::unifrac::{generate, unweighted_unifrac, weighted_unifrac, SynthParams};

    let mut cfg = config_from_args(args)?;
    let n_taxa = args.usize_flag("taxa", 256)?;
    let n_samples = args.usize_flag("samples", 64)?;
    let n_groups = args.usize_flag("groups", 4)?;
    cfg.data = DataSource::SyntheticUnifrac { n_taxa, n_samples, n_groups };
    cfg.validate()?;

    let metric = args.str_flag("metric").unwrap_or("unweighted");
    let ds = generate(&SynthParams {
        n_taxa,
        n_samples,
        n_envs: n_groups,
        seed: cfg.seed ^ 0xDA7A,
        ..Default::default()
    })?;
    let mat = match metric {
        "unweighted" => unweighted_unifrac(&ds.tree, &ds.table, cfg.threads)?,
        "weighted" => weighted_unifrac(&ds.tree, &ds.table, cfg.threads)?,
        other => return Err(Error::Config(format!("unknown --metric {other:?}"))),
    };
    let r = AnalysisRequest::new(&cfg).with_data(&mat, &ds.grouping).run()?;

    let mut out = format!("UniFrac ({metric}) -> PERMANOVA pipeline\n");
    out.push_str(&r.render());
    if args.bool_flag("anosim")? {
        // The cross-check runs through the same backend engine as the
        // primary statistic, so --backend/--shard-size/--smt-oversubscribe/
        // --perm-block apply to it too and the printed numbers match
        // `--method anosim` exactly (the conformance suite pins that the
        // engine path equals the legacy oracle bit-for-bit).
        let cross = RunConfig { method: Method::Anosim, ..cfg.clone() };
        let a = AnalysisRequest::new(&cross).with_data(&mat, &ds.grouping).run()?;
        out.push_str(&format!(
            "ANOSIM: R = {:.4}, p = {:.4} (cross-check statistic, backend={})\n",
            a.f_obs, a.p_value, a.backend
        ));
    }
    out.push_str(&format!(
        "verdict: group effect is {} at alpha=0.05\n",
        if r.p_value <= 0.05 { "SIGNIFICANT" } else { "not significant" }
    ));
    Ok(out)
}

fn cmd_fig1(args: &Args) -> Result<String> {
    let w = Workload {
        n_dims: args.usize_flag("n-dims", 25145)?,
        n_perms: args.usize_flag("n-perms", 3999)?,
        n_groups: args.usize_flag("n-groups", 8)?,
    };
    let rows = fig1_rows(&Mi300a::default(), &w);
    Ok(render_fig1(&rows))
}

fn cmd_stream(args: &Args) -> Result<String> {
    if args.bool_flag("simulate")? {
        let m = Mi300a::default();
        let len = args.usize_flag("len", 1_000_000_000)?;
        let mut out = String::new();
        for (dev, title) in [
            (StreamDevice::Cpu, "CPU cores (stream.large.exe, 48 threads)"),
            (StreamDevice::Gpu, "GPU cores (stream.amd_apu.exe, HSA_XNACK=1)"),
        ] {
            out.push_str(&format!("== simulated MI300A {title} ==\n"));
            let mut t = Table::new(&["Function", "Best Rate MB/s", "paper MB/s", "delta"]);
            let sim = simulate_stream(&m, dev, len);
            for (res, (_, paper)) in sim.iter().zip(paper_a2_reference(dev)) {
                t.row(&[
                    format!("{}:", res.kernel.name()),
                    format!("{:.1}", res.best_rate_mbs),
                    format!("{paper:.1}"),
                    format!("{:+.1}%", (res.best_rate_mbs / paper - 1.0) * 100.0),
                ]);
            }
            out.push_str(&t.render());
        }
        Ok(out)
    } else {
        let len = args.usize_flag("len", 20_000_000)?;
        let reps = args.usize_flag("reps", 10)?.max(2);
        let threads = args.usize_flag("threads", 0)?;
        let r = run_stream(len, reps, threads);
        let mut out = format!(
            "STREAM (host): array {} doubles, {} reps, {} threads\n",
            r.array_len, r.reps, r.threads
        );
        out.push_str(&r.format_table());
        out.push_str(if r.validated { "Solution Validates\n" } else { "VALIDATION FAILED\n" });
        Ok(out)
    }
}

fn cmd_simulate(args: &Args) -> Result<String> {
    if args.bool_flag("topology")? {
        return Ok(NodeTopology::cosmos_node().render());
    }
    let w = Workload {
        n_dims: args.usize_flag("n-dims", 25145)?,
        n_perms: args.usize_flag("n-perms", 3999)?,
        n_groups: args.usize_flag("n-groups", 8)?,
    };
    let rows = fig1_rows(&Mi300a::default(), &w);
    let mut t = Table::new(&["configuration", "seconds", "bound", "HBM traffic", "achieved GB/s"]);
    for r in &rows {
        t.row(&[
            r.label.clone(),
            format!("{:.2}", r.seconds),
            format!("{:?}", r.bound),
            crate::report::format_bytes(r.prediction.hbm_bytes),
            format!("{:.0}", r.prediction.achieved_bw_gbs),
        ]);
    }
    let items: Vec<(String, f64)> = rows.iter().map(|r| (r.label.clone(), r.seconds)).collect();
    Ok(format!(
        "{}\n{}",
        t.render(),
        bar_chart("predicted execution time (s, lower is better)", &items, "s", 48)
    ))
}

fn cmd_artifacts_check(args: &Args) -> Result<String> {
    use crate::runtime::XlaRuntime;
    let dir = args.str_flag("dir").unwrap_or(crate::DEFAULT_ARTIFACTS_DIR);
    let rt = XlaRuntime::new(dir)?;
    rt.manifest().verify_files()?;
    let mut out = format!(
        "artifacts ok: {} modules on {}\n",
        rt.manifest().artifacts().len(),
        rt.platform()
    );
    // Smoke-run the smallest artifact of each kernel.
    let kernels: std::collections::BTreeSet<String> =
        rt.manifest().artifacts().iter().map(|a| a.kernel.clone()).collect();
    for kernel in kernels {
        let metas = rt.manifest().by_kernel(&kernel);
        let meta = metas.iter().min_by_key(|a| a.n_dims).unwrap();
        let n = meta.n_dims;
        let mat = crate::dmat::DistanceMatrix::random_euclidean(n, 4, 7);
        let grouping = crate::permanova::Grouping::balanced(n, meta.n_groups)?;
        let sess = rt.session(&kernel, mat.data(), n, &grouping)?;
        let plan = crate::rng::PermutationPlan::new(grouping.labels().to_vec(), 3, 2);
        let rows = plan.batch(0, 2);
        let res = sess.run_batch(&rows, 2)?;
        let want = crate::permanova::sw_brute_f64_dense(
            mat.data(),
            n,
            plan.base(),
            grouping.inv_sizes(),
        );
        let got = res.s_w[0] as f64;
        let ok = (got - want).abs() / want.max(1e-9) < 1e-3;
        out.push_str(&format!(
            "  {kernel:<12} {} n={n} b={} ... {}\n",
            meta.name,
            meta.batch,
            if ok { "numerics OK" } else { "NUMERICS MISMATCH" }
        ));
        if !ok {
            return Err(Error::Artifact(format!("{kernel}: s_w {got} vs native {want}")));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(&v.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parse_command_and_flags() {
        let a = args(&["run", "--n-dims", "64", "--backend", "native", "--verbose"]);
        assert_eq!(a.command, "run");
        assert_eq!(a.usize_flag("n-dims", 0).unwrap(), 64);
        assert_eq!(a.str_flag("backend"), Some("native"));
        assert!(a.bool_flag("verbose").unwrap());
        assert!(!a.bool_flag("quiet").unwrap());
        assert_eq!(a.usize_flag("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bool_flags_accept_literals_and_reject_typos() {
        let a = args(&["run", "--a", "true", "--b", "1", "--c", "yes", "--d", "false", "--e",
            "0", "--f", "no", "--bare"]);
        for key in ["a", "b", "c", "bare"] {
            assert!(a.bool_flag(key).unwrap(), "{key}");
        }
        for key in ["d", "e", "f", "absent"] {
            assert!(!a.bool_flag(key).unwrap(), "{key}");
        }
        // The satellite bug: a typo'd literal must be a config error, not
        // a silent `false`.
        let bad = args(&["run", "--smt-oversubscribe", "ture"]);
        let e = bad.bool_flag("smt-oversubscribe").unwrap_err().to_string();
        assert!(e.contains("ture") && e.contains("smt-oversubscribe"), "{e}");
        // ... end to end through a command.
        assert!(dispatch(&args(&[
            "run", "--n-dims", "24", "--n-groups", "2", "--n-perms", "9",
            "--smt-oversubscribe", "ture",
        ]))
        .is_err());
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Args::parse(&[]).is_err());
        assert!(Args::parse(&["--flag-first".to_string()]).is_err());
        let a = args(&["run", "--n-dims", "notanumber"]);
        assert!(a.usize_flag("n-dims", 0).is_err());
    }

    #[test]
    fn version_and_help() {
        assert!(dispatch(&args(&["version"])).unwrap().contains(crate::VERSION));
        let help = dispatch(&args(&["help"])).unwrap();
        for cmd in [
            "run", "serve", "client", "bench", "backends", "fig1", "stream", "simulate",
            "artifacts-check",
        ] {
            assert!(help.contains(cmd));
        }
        assert!(help.contains("native-batch"), "registry names surface in help: {help}");
        assert!(help.contains("permdisp"), "method names surface in help: {help}");
        assert!(dispatch(&args(&["frobnicate"])).is_err());
    }

    #[test]
    fn run_native_small() {
        let out = dispatch(&args(&[
            "run", "--n-dims", "32", "--n-groups", "4", "--n-perms", "29", "--algo", "flat",
            "--threads", "2",
        ]))
        .unwrap();
        assert!(out.contains("pseudo-F"));
        assert!(out.contains("p-value"));
        assert!(out.contains("backend=native"));
        assert!(out.contains("algo=flat"));
    }

    #[test]
    fn run_selects_registry_backends() {
        // The acceptance path: the same `Backend` trait serves both names,
        // and the report records which backend produced the run.
        let tiled = dispatch(&args(&[
            "run", "--n-dims", "30", "--n-groups", "3", "--n-perms", "19", "--backend",
            "native-tiled",
        ]))
        .unwrap();
        assert!(tiled.contains("backend=native-tiled"), "{tiled}");

        let sim = dispatch(&args(&[
            "run", "--n-dims", "30", "--n-groups", "3", "--n-perms", "19", "--backend",
            "simulator",
        ]))
        .unwrap();
        assert!(sim.contains("backend=simulator"), "{sim}");
        assert!(sim.contains("sim-mi300a/"), "{sim}");
    }

    #[test]
    fn run_rejects_bad_flags() {
        assert!(dispatch(&args(&["run", "--algo", "quantum"])).is_err());
        assert!(dispatch(&args(&["run", "--backend", "cuda"])).is_err());
        assert!(dispatch(&args(&["run", "--n-perms", "0"])).is_err());
        assert!(dispatch(&args(&["run", "--method", "kruskal"])).is_err());
        assert!(dispatch(&args(&["run", "--data-tol", "loose"])).is_err());
        assert!(dispatch(&args(&["run", "--data-tol", "-0.5"])).is_err());
    }

    #[test]
    fn data_tol_gates_file_input_end_to_end() {
        let dir = std::env::temp_dir().join("permanova_apu_cli_tol_test");
        let (mpath, lpath) = crate::dmat::write_asymmetric_pdm_fixture(&dir);
        let base =
            ["run", "--pdm", mpath.as_str(), "--labels", lpath.as_str(), "--n-perms", "9"];
        let e = dispatch(&args(&base)).unwrap_err().to_string();
        assert!(e.contains("tol"), "rejection names the knob: {e}");
        let mut loose: Vec<&str> = base.to_vec();
        loose.extend(["--data-tol", "1.0"]);
        assert!(dispatch(&args(&loose)).unwrap().contains("pseudo-F"));
    }

    #[test]
    fn run_selects_methods() {
        let base = ["run", "--n-dims", "30", "--n-groups", "3", "--n-perms", "19"];
        let with = |m: &str| {
            let mut v: Vec<&str> = base.to_vec();
            v.extend(["--method", m]);
            dispatch(&args(&v)).unwrap()
        };
        let anosim = with("anosim");
        assert!(anosim.starts_with("ANOSIM"), "{anosim}");
        assert!(anosim.contains("R        ="), "{anosim}");
        let permdisp = with("permdisp");
        assert!(permdisp.starts_with("PERMDISP"), "{permdisp}");
        assert!(permdisp.contains("dispersions:"), "{permdisp}");
        let pairwise = with("pairwise");
        assert!(pairwise.starts_with("PAIRWISE-PERMANOVA"), "{pairwise}");
        assert!(pairwise.contains("0 vs 1"), "{pairwise}");
        assert!(pairwise.contains("p (Bonferroni)"), "{pairwise}");
    }

    #[test]
    fn run_method_json_is_method_tagged() {
        let dir = std::env::temp_dir().join("permanova_apu_cli_method_test");
        std::fs::create_dir_all(&dir).unwrap();
        let jpath = dir.join("anosim.json");
        dispatch(&args(&[
            "run", "--n-dims", "24", "--n-groups", "2", "--n-perms", "19", "--method",
            "anosim", "--backend", "native-batch", "--json", jpath.to_str().unwrap(),
        ]))
        .unwrap();
        let doc = crate::jsonio::Json::parse(&std::fs::read_to_string(&jpath).unwrap()).unwrap();
        assert_eq!(doc.req_str("method").unwrap(), "anosim");
        assert_eq!(doc.req_str("algo").unwrap(), "rank-r");
        assert_eq!(doc.req_str("backend").unwrap(), "native-batch");

        let ppath = dir.join("pairwise.json");
        dispatch(&args(&[
            "run", "--n-dims", "30", "--n-groups", "3", "--n-perms", "19", "--method",
            "pairwise", "--json", ppath.to_str().unwrap(),
        ]))
        .unwrap();
        let doc = crate::jsonio::Json::parse(&std::fs::read_to_string(&ppath).unwrap()).unwrap();
        assert_eq!(doc.req_str("method").unwrap(), "pairwise");
        assert_eq!(doc.req_arr("pairs").unwrap().len(), 3);
    }

    #[test]
    fn backends_listing_shows_caps() {
        for cmd in ["backends", "--list-backends"] {
            let out = dispatch(&args(&[cmd])).unwrap();
            for name in ["native-brute", "native-tiled", "native-batch", "simulator", "xla"] {
                assert!(out.contains(name), "{cmd}: missing {name} in {out}");
            }
            assert!(out.contains("kernel"), "{out}");
            assert!(out.contains("threaded"), "{out}");
            assert!(out.contains("modelled time"), "{out}");
            assert!(out.contains("brute-block"), "native-batch kernel listed: {out}");
            assert!(out.contains("methods: permanova, anosim, permdisp, pairwise"), "{out}");
        }
    }

    #[test]
    fn run_native_batch_with_block() {
        let out = dispatch(&args(&[
            "run", "--n-dims", "30", "--n-groups", "3", "--n-perms", "19", "--backend",
            "native-batch", "--perm-block", "8",
        ]))
        .unwrap();
        assert!(out.contains("backend=native-batch"), "{out}");
        assert!(out.contains("block=8"), "{out}");
    }

    #[test]
    fn bench_quick_writes_and_validates() {
        let dir = std::env::temp_dir().join("permanova_apu_cli_bench_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out_path = dir.join("BENCH_PERMANOVA.json");
        let out = dispatch(&args(&[
            "bench",
            "--quick",
            "--backends",
            "native-brute,native-batch",
            "--n-dims",
            "24",
            "--n-perms",
            "9",
            "--n-groups",
            "2",
            "--out",
            out_path.to_str().unwrap(),
        ]))
        .unwrap();
        assert!(out.contains("wrote"), "{out}");
        assert!(out.contains("native-batch"), "{out}");

        let check = dispatch(&args(&["bench", "--check", out_path.to_str().unwrap()])).unwrap();
        assert!(check.contains("bench json ok"), "{check}");
        assert!(check.contains("2 entries"), "{check}");
    }

    #[test]
    fn bench_sweeps_the_method_axis() {
        let dir = std::env::temp_dir().join("permanova_apu_cli_bench_method_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out_path = dir.join("BENCH_METHODS.json");
        let out = dispatch(&args(&[
            "bench",
            "--quick",
            "--backends",
            "native-brute,native-batch",
            "--methods",
            "permanova,anosim",
            "--n-dims",
            "24",
            "--n-perms",
            "9",
            "--n-groups",
            "2",
            "--out",
            out_path.to_str().unwrap(),
        ]))
        .unwrap();
        assert!(out.contains("wrote"), "{out}");
        assert!(out.contains("anosim"), "method column in the table: {out}");
        let check = dispatch(&args(&["bench", "--check", out_path.to_str().unwrap()])).unwrap();
        assert!(check.contains("4 entries"), "2 backends x 2 methods: {check}");
        // `--method` (singular) is accepted as the single-method spelling.
        assert!(dispatch(&args(&[
            "bench", "--quick", "--backends", "native-brute", "--method", "anosim", "--n-dims",
            "24", "--n-perms", "9", "--n-groups", "2", "--out",
            dir.join("one.json").to_str().unwrap(),
        ]))
        .is_ok());
    }

    #[test]
    fn bench_rejects_bad_input() {
        assert!(dispatch(&args(&["bench", "--backends", "warp-drive"])).is_err());
        assert!(dispatch(&args(&["bench", "--n-dims", "not-a-number"])).is_err());
        assert!(dispatch(&args(&["bench", "--methods", "kruskal"])).is_err());

        let dir = std::env::temp_dir().join("permanova_apu_cli_bench_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.json");
        std::fs::write(&bad, "{\"schema\": \"wrong\"}").unwrap();
        assert!(dispatch(&args(&["bench", "--check", bad.to_str().unwrap()])).is_err());
        assert!(dispatch(&args(&["bench", "--check", "/definitely/missing.json"])).is_err());
    }

    #[test]
    fn shard_flags_parse_and_run() {
        let out = dispatch(&args(&[
            "run", "--n-dims", "24", "--n-groups", "2", "--n-perms", "9", "--threads", "2",
            "--shard-size", "4", "--smt-oversubscribe",
        ]))
        .unwrap();
        assert!(out.contains("pseudo-F"));
    }

    #[test]
    fn fig1_small_workload() {
        let out = dispatch(&args(&["fig1", "--n-dims", "2048", "--n-perms", "100"])).unwrap();
        assert!(out.contains("GPU brute force"));
        assert!(out.contains("x faster"));
    }

    #[test]
    fn stream_simulated_matches_paper_labels() {
        let out = dispatch(&args(&["stream", "--simulate"])).unwrap();
        assert!(out.contains("Triad:"));
        assert!(out.contains("paper MB/s"));
        assert!(out.contains("stream.amd_apu.exe"));
    }

    #[test]
    fn stream_host_tiny() {
        let out = dispatch(&args(&[
            "stream", "--len", "100000", "--reps", "2", "--threads", "2",
        ]))
        .unwrap();
        assert!(out.contains("Solution Validates"), "{out}");
    }

    #[test]
    fn simulate_topology_and_predictions() {
        let topo = dispatch(&args(&["simulate", "--topology"])).unwrap();
        assert!(topo.contains("MI300A"));
        let pred = dispatch(&args(&["simulate", "--n-dims", "4096", "--n-perms", "500"])).unwrap();
        assert!(pred.contains("configuration"));
        assert!(pred.contains("Memory") || pred.contains("Compute"));
    }

    #[test]
    fn pipeline_small() {
        let out = dispatch(&args(&[
            "pipeline", "--taxa", "64", "--samples", "20", "--groups", "2", "--n-perms", "39",
        ]))
        .unwrap();
        assert!(out.contains("UniFrac (unweighted) -> PERMANOVA"));
        assert!(out.contains("verdict"));
    }

    #[test]
    fn pipeline_weighted_with_anosim() {
        let out = dispatch(&args(&[
            "pipeline", "--taxa", "64", "--samples", "20", "--groups", "2", "--n-perms", "39",
            "--metric", "weighted", "--anosim",
        ]))
        .unwrap();
        assert!(out.contains("UniFrac (weighted) -> PERMANOVA"));
        assert!(out.contains("ANOSIM: R ="));
        assert!(dispatch(&args(&["pipeline", "--metric", "cosine"])).is_err());
    }

    #[test]
    fn artifacts_check_if_present() {
        let dir = crate::runtime::artifacts_dir_for_tests();
        if dir.join("manifest.json").exists() {
            match dispatch(&args(&["artifacts-check", "--dir", dir.to_str().unwrap()])) {
                Ok(out) => assert!(out.contains("numerics OK"), "{out}"),
                Err(crate::error::Error::Xla(m)) => {
                    eprintln!("skipping artifacts-check: {m}")
                }
                Err(e) => panic!("{e}"),
            }
        }
    }

    #[test]
    fn run_companion_tests() {
        let out = dispatch(&args(&[
            "run", "--n-dims", "24", "--n-groups", "2", "--n-perms", "19", "--anosim",
            "--permdisp",
        ]))
        .unwrap();
        assert!(out.contains("ANOSIM:   R ="));
        assert!(out.contains("PERMDISP: F ="));
    }

    #[test]
    fn run_pairwise_and_json() {
        let dir = std::env::temp_dir().join("permanova_apu_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let jpath = dir.join("report.json");
        let out = dispatch(&args(&[
            "run", "--n-dims", "30", "--n-groups", "3", "--n-perms", "19", "--pairwise",
            "--json", jpath.to_str().unwrap(),
        ]))
        .unwrap();
        assert!(out.contains("pairwise (3 comparisons)"));
        assert!(out.contains("0 vs 1"));
        let doc = crate::jsonio::Json::parse(&std::fs::read_to_string(&jpath).unwrap()).unwrap();
        assert_eq!(doc.req_usize("n_perms").unwrap(), 19);
        assert!(doc.get("f_obs").unwrap().as_f64().is_some());
        assert_eq!(doc.req_arr("devices").unwrap().len(), 1);
    }

    #[test]
    fn serve_runs_a_jsonl_batch_end_to_end() {
        let dir = std::env::temp_dir().join("permanova_apu_cli_serve_test");
        std::fs::create_dir_all(&dir).unwrap();
        let jobs = dir.join("jobs.jsonl");
        std::fs::write(
            &jobs,
            concat!(
                r#"{"id": "a", "n_perms": 19, "seed": 3, "data": {"source": "synthetic", "n_dims": 24, "n_groups": 2, "seed": 7}}"#,
                "\n",
                r#"{"id": "b", "method": "anosim", "backend": "native-batch", "n_perms": 19, "seed": 4, "data": {"source": "synthetic", "n_dims": 24, "n_groups": 2, "seed": 7}}"#,
                "\n",
            ),
        )
        .unwrap();

        // Stdout mode: pure JSONL, ordered.
        let out =
            dispatch(&args(&["serve", "--jobs", jobs.to_str().unwrap(), "--threads", "2"]))
                .unwrap();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = crate::jsonio::Json::parse(lines[0]).unwrap();
        let second = crate::jsonio::Json::parse(lines[1]).unwrap();
        assert_eq!(first.req_str("id").unwrap(), "a");
        assert_eq!(first.req_str("cache").unwrap(), "miss");
        assert_eq!(second.req_str("id").unwrap(), "b");
        assert_eq!(second.req_str("cache").unwrap(), "hit", "same dataset key");
        assert_eq!(second.get("report").unwrap().req_str("method").unwrap(), "anosim");
        assert_eq!(
            second.get("report").unwrap().req_str("backend").unwrap(),
            "native-batch"
        );

        // File mode: responses to disk + summary with cache counters.
        let resp = dir.join("responses.jsonl");
        let summary = dispatch(&args(&[
            "serve", "--jobs", jobs.to_str().unwrap(), "--out", resp.to_str().unwrap(),
        ]))
        .unwrap();
        assert!(summary.contains("wrote"), "{summary}");
        assert!(summary.contains("1 hits / 1 misses"), "{summary}");
        // --check validates the written document.
        let check =
            dispatch(&args(&["serve", "--check", resp.to_str().unwrap()])).unwrap();
        assert!(check.contains("2 ok, 0 failed"), "{check}");

        // Errors: no --jobs, missing file, invalid responses.
        assert!(dispatch(&args(&["serve"])).is_err());
        assert!(dispatch(&args(&["serve", "--jobs", "/definitely/missing.jsonl"])).is_err());
        let bad = dir.join("bad.jsonl");
        std::fs::write(&bad, "{\"id\": \"x\"}\n").unwrap();
        assert!(dispatch(&args(&["serve", "--check", bad.to_str().unwrap()])).is_err());
    }

    #[test]
    fn client_talks_to_an_in_process_daemon() {
        use crate::service::{Daemon, DaemonConfig};
        let daemon = Daemon::spawn(DaemonConfig {
            workers: 1,
            cache_capacity: 2,
            queue_depth: 4,
            ..DaemonConfig::default()
        })
        .unwrap();
        let addr = daemon.addr().to_string();

        let dir = std::env::temp_dir().join("permanova_apu_cli_client_test");
        std::fs::create_dir_all(&dir).unwrap();
        let jobs = dir.join("jobs.jsonl");
        std::fs::write(
            &jobs,
            concat!(
                r#"{"v": 1, "id": "j1", "request": {"n_perms": 19, "seed": 3, "data": {"source": "synthetic", "n_dims": 24, "n_groups": 2, "seed": 7}}}"#,
                "\n",
                r#"{"id": "old", "n_perms": 19, "seed": 3, "data": {"source": "synthetic", "n_dims": 24, "n_groups": 2, "seed": 7}}"#,
                "\n",
            ),
        )
        .unwrap();

        let out = dispatch(&args(&[
            "client", "--addr", &addr, "--jobs", jobs.to_str().unwrap(), "--stats",
        ]))
        .unwrap();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 3, "{out}");
        let first = crate::jsonio::Json::parse(lines[0]).unwrap();
        assert_eq!(first.req_str("id").unwrap(), "j1");
        assert_eq!(first.opt_bool("ok").unwrap(), Some(true), "{out}");
        let second = crate::jsonio::Json::parse(lines[1]).unwrap();
        assert_eq!(second.req_str("id").unwrap(), "old");
        assert!(second.get("note").is_some(), "legacy v0 carries the deprecation note");
        let stats = crate::jsonio::Json::parse(lines[2]).unwrap();
        assert_eq!(stats.req_str("id").unwrap(), "stats");
        assert!(stats.get("stats").unwrap().get("cache").is_some(), "{out}");

        let bye = dispatch(&args(&["client", "--addr", &addr, "--shutdown"])).unwrap();
        assert!(bye.contains("\"draining\":true"), "{bye}");
        let summary = daemon.join().unwrap();
        assert_eq!(summary.completed, 2);

        // Errors: no --addr, no request flags, unparseable address.
        assert!(dispatch(&args(&["client", "--stats"])).is_err());
        assert!(dispatch(&args(&["client", "--addr", &addr])).is_err());
        assert!(dispatch(&args(&["client", "--addr", "nonsense", "--stats"])).is_err());
    }

    #[test]
    fn run_repeat_reuses_the_cached_dataset() {
        let out = dispatch(&args(&[
            "run", "--n-dims", "24", "--n-groups", "2", "--n-perms", "19", "--repeat", "3",
        ]))
        .unwrap();
        assert!(out.contains("pseudo-F"), "{out}");
        assert!(out.contains("repeat x3"), "{out}");
        assert!(out.contains("iter-1"), "{out}");
        assert!(out.contains("miss"), "first iteration loads: {out}");
        assert!(out.contains("hit"), "later iterations reuse: {out}");
        assert!(out.contains("2 hits / 1 misses"), "{out}");
        // Flags the repeat path cannot honour are rejected, not ignored.
        assert!(dispatch(&args(&[
            "run", "--n-dims", "24", "--n-groups", "2", "--n-perms", "9", "--repeat", "2",
            "--json", "out.json",
        ]))
        .is_err());
        assert!(dispatch(&args(&[
            "run", "--n-dims", "24", "--n-groups", "2", "--n-perms", "9", "--repeat", "2",
            "--anosim",
        ]))
        .is_err());
    }

    #[test]
    fn pipeline_anosim_cross_check_goes_through_the_engine() {
        // The cross-check must honour the engine knobs (--backend et al.)
        // instead of silently running the legacy single-threaded oracle.
        let out = dispatch(&args(&[
            "pipeline", "--taxa", "64", "--samples", "20", "--groups", "2", "--n-perms", "39",
            "--anosim", "--backend", "native-batch", "--perm-block", "8",
        ]))
        .unwrap();
        assert!(out.contains("ANOSIM: R ="), "{out}");
        assert!(
            out.contains("cross-check statistic, backend=native-batch"),
            "cross-check names the engine backend: {out}"
        );
    }

    #[test]
    fn config_file_applies() {
        let dir = std::env::temp_dir().join("permanova_apu_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.toml");
        std::fs::write(
            &p,
            "[run]\nn_perms = 19\nalgo = \"brute\"\n[data]\nsource = \"synthetic\"\nn_dims = 24\nn_groups = 3\n",
        )
        .unwrap();
        let out = dispatch(&args(&["run", "--config", p.to_str().unwrap()])).unwrap();
        assert!(out.contains("perms=19"));
        assert!(out.contains("algo=brute"));
    }

    #[test]
    fn store_flags_override_config_file() {
        let dir = std::env::temp_dir().join("permanova_apu_cli_store_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.toml");
        std::fs::write(&p, "[store]\ndir = \"/from/config\"\ncapacity_bytes = 1024\n").unwrap();
        let a = args(&["serve", "--config", p.to_str().unwrap()]);
        let s = store_settings_from_args(&a).unwrap();
        assert_eq!(s.dir.as_deref(), Some("/from/config"));
        assert_eq!(s.capacity_bytes, 1024);
        assert!(s.enabled);

        let a = args(&[
            "serve", "--config", p.to_str().unwrap(), "--store-dir", "/from/flag",
            "--store-capacity-bytes", "2048",
        ]);
        let s = store_settings_from_args(&a).unwrap();
        assert_eq!(s.dir.as_deref(), Some("/from/flag"));
        assert_eq!(s.capacity_bytes, 2048);

        let a = args(&["serve", "--config", p.to_str().unwrap(), "--no-store"]);
        assert!(!store_settings_from_args(&a).unwrap().enabled);
        // Disabled or dir-less settings open no store.
        assert!(open_store_from_args(&a).unwrap().is_none());
        assert!(open_store_from_args(&args(&["serve"])).unwrap().is_none());
    }

    #[test]
    fn serve_batch_store_survives_process_restart() {
        let dir = std::env::temp_dir().join("permanova_apu_cli_serve_store_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let jobs = dir.join("jobs.jsonl");
        std::fs::write(
            &jobs,
            concat!(
                r#"{"id": "a", "n_perms": 19, "seed": 3, "data": {"source": "synthetic", "n_dims": 24, "n_groups": 2, "seed": 7}}"#,
                "\n",
            ),
        )
        .unwrap();
        let store = dir.join("store");
        let store = store.to_str().unwrap();

        // First invocation computes and persists...
        let cold = dispatch(&args(&[
            "serve", "--jobs", jobs.to_str().unwrap(), "--store-dir", store,
        ]))
        .unwrap();
        let first = crate::jsonio::Json::parse(cold.lines().next().unwrap()).unwrap();
        assert_eq!(first.req_str("cache").unwrap(), "miss");
        assert_eq!(first.req_str("store").unwrap(), "miss");

        // ...and a second invocation (fresh cache, fresh store handle — a
        // process restart in miniature) answers from disk, verbatim.
        let warm = dispatch(&args(&[
            "serve", "--jobs", jobs.to_str().unwrap(), "--store-dir", store,
        ]))
        .unwrap();
        let second = crate::jsonio::Json::parse(warm.lines().next().unwrap()).unwrap();
        assert_eq!(second.req_str("cache").unwrap(), "store");
        assert_eq!(second.req_str("store").unwrap(), "hit");
        assert_eq!(
            first.get("report").unwrap().to_string(),
            second.get("report").unwrap().to_string(),
            "a store hit returns the original serialized report bitwise"
        );

        // --no-store wins over --store-dir: back to a plain cold batch with
        // the pre-store response shape.
        let off = dispatch(&args(&[
            "serve", "--jobs", jobs.to_str().unwrap(), "--store-dir", store, "--no-store",
        ]))
        .unwrap();
        let third = crate::jsonio::Json::parse(off.lines().next().unwrap()).unwrap();
        assert_eq!(third.req_str("cache").unwrap(), "miss");
        assert!(third.get("store").is_none(), "{off}");
    }

    #[test]
    fn run_repeat_with_store_dir_hits_across_invocations() {
        let dir = std::env::temp_dir().join("permanova_apu_cli_run_store_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let store = dir.join("store");
        let base = [
            "run", "--n-dims", "24", "--n-groups", "2", "--n-perms", "19", "--repeat", "2",
            "--store-dir", store.to_str().unwrap(),
        ];
        let cold = dispatch(&args(&base)).unwrap();
        assert!(cold.contains("repeat x2 through the durable store"), "{cold}");
        assert!(cold.contains("1 hits / 1 misses / 1 puts"), "{cold}");
        // A second invocation answers every iteration from disk.
        let warm = dispatch(&args(&base)).unwrap();
        assert!(warm.contains("2 hits / 0 misses / 0 puts"), "{warm}");
        // Store flags on a one-shot run are rejected, not silently inert.
        for flag in [
            &["--store-dir", store.to_str().unwrap()][..],
            &["--store-capacity-bytes", "1024"][..],
            &["--no-store"][..],
        ] {
            let mut v =
                vec!["run", "--n-dims", "24", "--n-groups", "2", "--n-perms", "9"];
            v.extend_from_slice(flag);
            let e = dispatch(&args(&v)).unwrap_err().to_string();
            assert!(e.contains("--repeat"), "{e}");
        }
    }

    #[test]
    fn client_exits_nonzero_when_a_job_fails() {
        use crate::service::{Daemon, DaemonConfig};
        let daemon = Daemon::spawn(DaemonConfig {
            workers: 1,
            cache_capacity: 2,
            queue_depth: 4,
            ..DaemonConfig::default()
        })
        .unwrap();
        let addr = daemon.addr().to_string();

        let dir = std::env::temp_dir().join("permanova_apu_cli_client_fail_test");
        std::fs::create_dir_all(&dir).unwrap();
        let jobs = dir.join("jobs.jsonl");
        std::fs::write(
            &jobs,
            concat!(
                r#"{"v": 1, "id": "good", "request": {"n_perms": 9, "data": {"source": "synthetic", "n_dims": 24, "n_groups": 2, "seed": 7}}}"#,
                "\n",
                r#"{"v": 1, "id": "bad", "request": {"backend": "cuda", "n_perms": 9, "data": {"source": "synthetic", "n_dims": 24, "n_groups": 2, "seed": 7}}}"#,
                "\n",
            ),
        )
        .unwrap();

        // One failed job fails the invocation; the trailing --stats
        // response is excluded from the count.
        let e = dispatch(&args(&[
            "client", "--addr", &addr, "--jobs", jobs.to_str().unwrap(), "--stats",
        ]))
        .unwrap_err()
        .to_string();
        assert!(e.contains("1 of 2 jobs failed"), "{e}");

        let bye = dispatch(&args(&["client", "--addr", &addr, "--shutdown"])).unwrap();
        assert!(bye.contains("draining"), "{bye}");
        daemon.join().unwrap();
    }

    #[test]
    fn daemon_with_store_reports_store_stats() {
        use crate::service::{Daemon, DaemonConfig};
        let dir = std::env::temp_dir().join("permanova_apu_cli_daemon_store_test");
        let _ = std::fs::remove_dir_all(&dir);
        let daemon = Daemon::spawn(DaemonConfig {
            workers: 1,
            cache_capacity: 2,
            queue_depth: 4,
            store_dir: Some(dir.join("store")),
            ..DaemonConfig::default()
        })
        .unwrap();
        let addr = daemon.addr().to_string();
        let out = dispatch(&args(&["client", "--addr", &addr, "--stats"])).unwrap();
        let stats = crate::jsonio::Json::parse(out.lines().next().unwrap()).unwrap();
        assert!(stats.get("stats").unwrap().get("store").is_some(), "{out}");
        dispatch(&args(&["client", "--addr", &addr, "--shutdown"])).unwrap();
        let summary = daemon.join().unwrap();
        assert!(summary.store.is_some());
        assert!(summary.render().contains("store"), "{}", summary.render());
    }
}
