//! permanova-apu: the L3 leader binary.
//!
//! Thin shell around [`permanova_apu::cli`]: parse, dispatch, print.
//! All functionality lives in the library so it is testable and reusable
//! from the examples and benches.

use std::process::ExitCode;

fn main() -> ExitCode {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match permanova_apu::cli::Args::parse(&raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprint!("{}", permanova_apu::cli::usage());
            return ExitCode::from(2);
        }
    };
    match permanova_apu::cli::dispatch(&args) {
        Ok(out) => {
            print!("{out}");
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
