//! Stub PJRT runtime, compiled when the `pjrt` feature is off.
//!
//! The real client (`client.rs`) needs the `xla` crate (xla_extension
//! 0.5.1 plus its native toolchain), which hermetic build environments
//! don't have.  This stub keeps the exact same public surface — manifests
//! still load and validate — but reports the PJRT client as unavailable
//! instead of executing.  Every XLA test, bench and example already skips
//! when `artifacts/manifest.json` is absent, and the `xla` backend factory
//! surfaces the typed [`Error::Xla`] to the CLI.

use super::manifest::{ArtifactMeta, Manifest};
use crate::error::{Error, Result};
use crate::permanova::Grouping;

fn unavailable() -> Error {
    Error::Xla(
        "PJRT runtime not compiled in: add the `xla` crate dependency (see the \
         note in rust/Cargo.toml) and build with `--features pjrt` to execute \
         AOT artifacts"
            .into(),
    )
}

/// The runtime facade: loads the manifest, but has no PJRT client.
pub struct XlaRuntime {
    manifest: Manifest,
}

impl XlaRuntime {
    /// Load the manifest from `artifacts_dir`, then report the missing
    /// PJRT client.  (Manifest errors — missing/invalid files — surface
    /// first, exactly as with the real client.)
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let _manifest = Manifest::load(&artifacts_dir)?;
        Err(unavailable())
    }

    /// The loaded manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Sessions cannot be opened without a PJRT client.
    pub fn session(
        &self,
        _kernel: &str,
        _mat: &[f32],
        _n: usize,
        _grouping: &Grouping,
    ) -> Result<KernelSession<'_>> {
        Err(unavailable())
    }
}

/// One batch's outputs (same shape as the real client's).
#[derive(Clone, Debug)]
pub struct BatchOut {
    /// Pseudo-F per permutation row.
    pub f_stats: Vec<f64>,
    /// Raw s_W per permutation row.
    pub s_w: Vec<f32>,
}

/// Stub session: [`XlaRuntime::session`] always errors before one can be
/// constructed, so these methods exist only to satisfy the type surface.
pub struct KernelSession<'rt> {
    _rt: std::marker::PhantomData<&'rt ()>,
}

impl<'rt> KernelSession<'rt> {
    /// The artifact backing this session.
    pub fn meta(&self) -> &ArtifactMeta {
        unreachable!("stub KernelSession is never constructed")
    }

    /// Max permutation rows per execution.
    pub fn batch_capacity(&self) -> usize {
        unreachable!("stub KernelSession is never constructed")
    }

    /// Execute one batch.
    pub fn run_batch(&self, _groupings: &[u32], _rows: usize) -> Result<BatchOut> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn expect_err(r: Result<XlaRuntime>) -> Error {
        match r {
            Err(e) => e,
            Ok(_) => panic!("stub runtime must not open"),
        }
    }

    #[test]
    fn missing_dir_errors_with_path() {
        let e = expect_err(XlaRuntime::new("/no/such/dir"));
        assert!(e.to_string().contains("manifest.json"), "{e}");
    }

    #[test]
    fn valid_manifest_still_reports_unavailable_client() {
        let dir = std::env::temp_dir().join("permanova_apu_stub_rt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"interchange":"hlo-text","artifacts":[
                {"name":"matmul_n64_b16_k4","file":"matmul_n64_b16_k4.hlo.txt",
                 "kernel":"matmul","n_dims":64,"batch":16,"n_groups":4}]}"#,
        )
        .unwrap();
        let e = expect_err(XlaRuntime::new(&dir));
        assert!(e.to_string().contains("pjrt") || e.to_string().contains("PJRT"), "{e}");
    }
}
