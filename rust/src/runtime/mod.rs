//! XLA/PJRT runtime: the L3 side of the AOT bridge.
//!
//! `python/compile/aot.py` lowers the L2 PERMANOVA batch graph (with the L1
//! Pallas kernels inlined) to HLO text once at build time; this module
//! loads those artifacts, compiles them on the PJRT CPU client, and runs
//! them with device-resident inputs.  Python is never on the request path.

#[cfg(feature = "pjrt")]
mod client;
#[cfg(not(feature = "pjrt"))]
mod client_stub;
mod manifest;

#[cfg(feature = "pjrt")]
pub use client::{BatchOut, KernelSession, XlaRuntime};
#[cfg(not(feature = "pjrt"))]
pub use client_stub::{BatchOut, KernelSession, XlaRuntime};
pub use manifest::{ArtifactMeta, Manifest, SUPPORTED_VERSION};

/// Locate the artifacts directory for in-crate tests: honours
/// `PERMANOVA_APU_ARTIFACTS`, falling back to `<repo>/artifacts` relative
/// to the crate manifest.
pub fn artifacts_dir_for_tests() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("PERMANOVA_APU_ARTIFACTS") {
        return dir.into();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(crate::DEFAULT_ARTIFACTS_DIR)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dmat::DistanceMatrix;
    use crate::permanova::{
        fstat_from_sw, st_of, sw_brute_f64_dense, Grouping,
    };
    use crate::rng::PermutationPlan;

    fn runtime() -> Option<XlaRuntime> {
        let dir = artifacts_dir_for_tests();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping xla runtime test: no artifacts at {dir:?}");
            return None;
        }
        match XlaRuntime::new(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping xla runtime test: {e}");
                None
            }
        }
    }

    /// End-to-end parity: the XLA artifact must agree with the native Rust
    /// oracle on identical inputs — the core cross-layer correctness test.
    #[test]
    fn xla_matches_native_exact_shape() {
        let Some(rt) = runtime() else { return };
        let n = 64;
        let k = 4;
        let mat = DistanceMatrix::random_euclidean(n, 8, 77);
        let grouping = Grouping::balanced(n, k).unwrap();
        let plan = PermutationPlan::new(grouping.labels().to_vec(), 5, 16);
        let rows = plan.batch(0, 16);

        for kernel in ["bruteforce", "tiled", "matmul", "ref"] {
            let sess = rt.session(kernel, mat.data(), n, &grouping).unwrap();
            assert_eq!(sess.meta().n_dims, 64);
            let out = sess.run_batch(&rows, 16).unwrap();
            let s_t = st_of(&mat);
            for r in 0..16 {
                let want_sw = sw_brute_f64_dense(
                    mat.data(),
                    n,
                    &rows[r * n..(r + 1) * n],
                    grouping.inv_sizes(),
                );
                let got_sw = out.s_w[r] as f64;
                assert!(
                    (got_sw - want_sw).abs() / want_sw.max(1e-9) < 1e-4,
                    "{kernel} row {r}: sw {got_sw} vs {want_sw}"
                );
                let want_f = fstat_from_sw(want_sw, s_t, n, k);
                assert!(
                    (out.f_stats[r] - want_f).abs() / want_f.abs().max(1e-9) < 1e-3,
                    "{kernel} row {r}: f {} vs {want_f}",
                    out.f_stats[r]
                );
            }
        }
    }

    /// Padded path: a 50-object problem through the 64-lowered artifact.
    #[test]
    fn xla_padded_problem_matches_native() {
        let Some(rt) = runtime() else { return };
        let n = 50;
        let k = 3;
        let mat = DistanceMatrix::random_euclidean(n, 6, 123);
        let grouping = Grouping::balanced(n, k).unwrap();
        let plan = PermutationPlan::new(grouping.labels().to_vec(), 9, 8);
        let rows = plan.batch(0, 8);

        let sess = rt.session("matmul", mat.data(), n, &grouping).unwrap();
        assert_eq!(sess.meta().n_dims, 64, "best-fit rounds up");
        let out = sess.run_batch(&rows, 8).unwrap();
        let s_t = st_of(&mat);
        for r in 0..8 {
            let want_sw = sw_brute_f64_dense(
                mat.data(),
                n,
                &rows[r * n..(r + 1) * n],
                grouping.inv_sizes(),
            );
            assert!(
                ((out.s_w[r] as f64) - want_sw).abs() / want_sw.max(1e-9) < 1e-4,
                "row {r}"
            );
            let want_f = fstat_from_sw(want_sw, s_t, n, k);
            assert!(
                (out.f_stats[r] - want_f).abs() / want_f.abs().max(1e-9) < 1e-3,
                "row {r}: f {} vs {want_f}",
                out.f_stats[r]
            );
        }
    }

    #[test]
    fn session_rejects_bad_shapes() {
        let Some(rt) = runtime() else { return };
        let n = 64;
        let mat = DistanceMatrix::random_euclidean(n, 4, 1);
        let grouping = Grouping::balanced(n, 4).unwrap();
        // Wrong matrix buffer length.
        assert!(rt.session("matmul", &mat.data()[..10], n, &grouping).is_err());
        // Unknown kernel.
        assert!(rt.session("bogus", mat.data(), n, &grouping).is_err());
        // Too many groups for the artifact (k_art = 4 at n = 64).
        let g9 = Grouping::balanced(n, 9).unwrap();
        assert!(rt.session("matmul", mat.data(), n, &g9).is_err());
        // Batch overrun / zero rows.
        let sess = rt.session("matmul", mat.data(), n, &grouping).unwrap();
        let cap = sess.batch_capacity();
        let rows = vec![0u32; (cap + 1) * n];
        assert!(sess.run_batch(&rows, cap + 1).is_err());
        assert!(sess.run_batch(&[], 0).is_err());
    }

    /// Short batches (fewer rows than capacity) are padded internally and
    /// trimmed in the output.
    #[test]
    fn short_batches_supported() {
        let Some(rt) = runtime() else { return };
        let n = 64;
        let mat = DistanceMatrix::random_euclidean(n, 4, 5);
        let grouping = Grouping::balanced(n, 4).unwrap();
        let sess = rt.session("bruteforce", mat.data(), n, &grouping).unwrap();
        let plan = PermutationPlan::new(grouping.labels().to_vec(), 2, 4);
        let rows = plan.batch(0, 3);
        let out = sess.run_batch(&rows, 3).unwrap();
        assert_eq!(out.f_stats.len(), 3);
        assert_eq!(out.s_w.len(), 3);
    }
}
