//! PJRT runtime: load AOT artifacts, keep buffers device-resident, execute.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT) following the
//! reference wiring in /opt/xla-example: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute_b`.
//!
//! Design notes:
//! * HLO **text** interchange only — serialized jax>=0.5 protos carry
//!   64-bit instruction ids this XLA rejects (manifest enforces it).
//! * The distance matrix and `inv_group_sizes` are uploaded **once** per
//!   [`KernelSession`] and stay device-resident; per-batch traffic is just
//!   the `(batch, n)` grouping rows — the same "python never on the request
//!   path, matrix never re-staged" discipline the L3 hot loop needs.
//! * The PJRT wrappers are not `Send`; a session lives on one thread.  The
//!   coordinator gives the XLA backend a dedicated worker.
//! * Problems smaller than the lowered shape are padded (zero distances,
//!   label 0): padding contributes exactly 0 to s_W, and the true
//!   `n_eff` / `k_eff` are runtime scalar inputs to the artifact, so s_T's
//!   normalization and the F statistic's degrees of freedom stay exact.

use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{ArtifactMeta, Manifest};
use crate::error::{Error, Result};
use crate::permanova::Grouping;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// The runtime: one PJRT client + the artifact manifest.
pub struct XlaRuntime {
    client: PjRtClient,
    manifest: Manifest,
}

impl XlaRuntime {
    /// Create a CPU PJRT client and load the manifest from `artifacts_dir`.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = PjRtClient::cpu()?;
        Ok(XlaRuntime { client, manifest })
    }

    /// The loaded manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one artifact (text -> proto -> executable).
    pub fn compile(&self, meta: &ArtifactMeta) -> Result<PjRtLoadedExecutable> {
        let path = self.manifest.path_of(meta);
        let proto = HloModuleProto::from_text_file(&path)?;
        let comp = XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Open an execution session: pick the best-fitting artifact for
    /// `(kernel, n)`, compile it, and stage the matrix + weights on device.
    ///
    /// `mat` is the row-major n×n distance matrix; `grouping` supplies the
    /// label universe (k) and `inv_group_sizes`.
    pub fn session(
        &self,
        kernel: &str,
        mat: &[f32],
        n: usize,
        grouping: &Grouping,
    ) -> Result<KernelSession<'_>> {
        if mat.len() != n * n {
            return Err(Error::InvalidInput(format!(
                "matrix buffer {} != {n}x{n}",
                mat.len()
            )));
        }
        let meta = self
            .manifest
            .best_fit(kernel, n)
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "no artifact for kernel {kernel:?} with n_dims >= {n}; run `make artifacts` \
                     or add the shape to python/compile/aot.py CONFIGS"
                ))
            })?
            .clone();
        if grouping.k() > meta.n_groups {
            return Err(Error::Artifact(format!(
                "grouping has {} groups but artifact {} was lowered for {}",
                grouping.k(),
                meta.name,
                meta.n_groups
            )));
        }
        let exe = self.compile(&meta)?;

        // Stage the (padded) matrix.
        let np = meta.n_dims;
        let mat_buf = if np == n {
            self.client.buffer_from_host_buffer(mat, &[np, np], None)?
        } else {
            let mut padded = vec![0.0f32; np * np];
            for r in 0..n {
                padded[r * np..r * np + n].copy_from_slice(&mat[r * n..(r + 1) * n]);
            }
            self.client.buffer_from_host_buffer(&padded, &[np, np], None)?
        };

        // Stage inv_group_sizes, zero-padded to the artifact's k (empty
        // groups have no members; weight 0 keeps the matmul kernel's
        // 0 * w products finite).
        let mut igs = vec![0.0f32; meta.n_groups];
        igs[..grouping.k()].copy_from_slice(grouping.inv_sizes());
        let igs_buf = self.client.buffer_from_host_buffer(&igs, &[meta.n_groups], None)?;

        // The true problem size, as runtime scalars.
        let n_eff_buf = self
            .client
            .buffer_from_host_buffer(&[n as f32], &[], None)?;
        let k_eff_buf = self
            .client
            .buffer_from_host_buffer(&[grouping.k() as f32], &[], None)?;

        Ok(KernelSession {
            client: &self.client,
            exe,
            meta,
            mat_buf,
            igs_buf,
            n_eff_buf,
            k_eff_buf,
            n_true: n,
        })
    }
}

/// One batch's outputs.
#[derive(Clone, Debug)]
pub struct BatchOut {
    /// Pseudo-F per permutation row (computed in-graph with the true n, k).
    pub f_stats: Vec<f64>,
    /// Raw s_W per permutation row (exact — padding contributes zero).
    pub s_w: Vec<f32>,
}

/// A compiled kernel with device-resident matrix and weights.
pub struct KernelSession<'rt> {
    client: &'rt PjRtClient,
    exe: PjRtLoadedExecutable,
    meta: ArtifactMeta,
    mat_buf: PjRtBuffer,
    igs_buf: PjRtBuffer,
    n_eff_buf: PjRtBuffer,
    k_eff_buf: PjRtBuffer,
    n_true: usize,
}

impl<'rt> KernelSession<'rt> {
    /// The artifact backing this session.
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Max permutation rows per execution (the artifact's lowered batch).
    pub fn batch_capacity(&self) -> usize {
        self.meta.batch
    }

    /// Execute one batch of `rows` label rows (row-major `rows * n_true`).
    ///
    /// `rows` may be less than [`batch_capacity`](Self::batch_capacity);
    /// the remainder is filled with copies of row 0 and dropped from the
    /// output.
    pub fn run_batch(&self, groupings: &[u32], rows: usize) -> Result<BatchOut> {
        let n = self.n_true;
        let np = self.meta.n_dims;
        let b = self.meta.batch;
        if rows == 0 || rows > b {
            return Err(Error::InvalidInput(format!(
                "rows = {rows} out of range 1..={b}"
            )));
        }
        if groupings.len() != rows * n {
            return Err(Error::InvalidInput(format!(
                "groupings buffer {} != {rows}x{n}",
                groupings.len()
            )));
        }

        // Pack into the artifact's (b, np) i32 layout; pad columns with
        // label 0 (zero-distance padding objects) and rows with row 0.
        let mut grp = vec![0i32; b * np];
        for r in 0..b {
            let src_row = if r < rows { r } else { 0 };
            let src = &groupings[src_row * n..(src_row + 1) * n];
            let dst = &mut grp[r * np..r * np + n];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d = s as i32;
            }
        }
        let grp_buf = self.client.buffer_from_host_buffer(&grp, &[b, np], None)?;

        let outs = self.exe.execute_b(&[
            &self.mat_buf,
            &grp_buf,
            &self.igs_buf,
            &self.n_eff_buf,
            &self.k_eff_buf,
        ])?;
        let tuple = outs[0][0].to_literal_sync()?;
        let (f_lit, sw_lit) = tuple.to_tuple2()?;
        let f_raw = f_lit.to_vec::<f32>()?;
        let s_w_all = sw_lit.to_vec::<f32>()?;

        Ok(BatchOut {
            f_stats: f_raw[..rows].iter().map(|&f| f as f64).collect(),
            s_w: s_w_all[..rows].to_vec(),
        })
    }
}

