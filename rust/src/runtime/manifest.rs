//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every
//! lowered HLO module (kernel variant, shapes, dtypes, file).  The runtime
//! reads it once; artifact lookup is by `(kernel, n_dims)` with the batch
//! size coming along for the scheduler to honour.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::jsonio::Json;

/// Manifest schema version this runtime understands.
pub const SUPPORTED_VERSION: usize = 1;

/// One AOT-compiled artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    /// HLO text file, relative to the manifest's directory.
    pub file: String,
    /// Kernel variant: bruteforce | tiled | matmul | ref.
    pub kernel: String,
    /// Matrix edge the module was lowered for.
    pub n_dims: usize,
    /// Permutation rows per execution.
    pub batch: usize,
    /// Number of groups (one-hot width / F-statistic dof).
    pub n_groups: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    dir: PathBuf,
    artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let doc = Json::parse(text)?;
        let version = doc.req_usize("version")?;
        if version != SUPPORTED_VERSION {
            return Err(Error::Artifact(format!(
                "manifest version {version} unsupported (runtime supports {SUPPORTED_VERSION})"
            )));
        }
        let interchange = doc.req_str("interchange")?;
        if interchange != "hlo-text" {
            return Err(Error::Artifact(format!(
                "interchange {interchange:?} unsupported (xla_extension 0.5.1 requires hlo-text; \
                 serialized protos with 64-bit ids are rejected)"
            )));
        }
        let artifacts = doc
            .req_arr("artifacts")?
            .iter()
            .map(|a| {
                Ok(ArtifactMeta {
                    name: a.req_str("name")?.to_string(),
                    file: a.req_str("file")?.to_string(),
                    kernel: a.req_str("kernel")?.to_string(),
                    n_dims: a.req_usize("n_dims")?,
                    batch: a.req_usize("batch")?,
                    n_groups: a.req_usize("n_groups")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        if artifacts.is_empty() {
            return Err(Error::Artifact("manifest lists no artifacts".into()));
        }
        Ok(Manifest { dir, artifacts })
    }

    /// All artifacts.
    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.artifacts
    }

    /// Artifacts of one kernel variant.
    pub fn by_kernel(&self, kernel: &str) -> Vec<&ArtifactMeta> {
        self.artifacts.iter().filter(|a| a.kernel == kernel).collect()
    }

    /// Exact lookup.
    pub fn find(&self, kernel: &str, n_dims: usize) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.kernel == kernel && a.n_dims == n_dims)
    }

    /// The artifact to use for a problem of size `n_dims`: exact match, or
    /// the smallest lowered size that fits (inputs are padded up to it).
    pub fn best_fit(&self, kernel: &str, n_dims: usize) -> Option<&ArtifactMeta> {
        self.by_kernel(kernel)
            .into_iter()
            .filter(|a| a.n_dims >= n_dims)
            .min_by_key(|a| a.n_dims)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, a: &ArtifactMeta) -> PathBuf {
        self.dir.join(&a.file)
    }

    /// Verify every listed file exists and is non-empty.
    pub fn verify_files(&self) -> Result<()> {
        for a in &self.artifacts {
            let p = self.path_of(a);
            let md = std::fs::metadata(&p)
                .map_err(|e| Error::io(p.display().to_string(), e))?;
            if md.len() == 0 {
                return Err(Error::Artifact(format!("{} is empty", p.display())));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> String {
        r#"{
          "version": 1,
          "interchange": "hlo-text",
          "artifacts": [
            {"name": "matmul_n64_b16_k4", "file": "matmul_n64_b16_k4.hlo.txt",
             "kernel": "matmul", "n_dims": 64, "batch": 16, "n_groups": 4,
             "inputs": [], "outputs": []},
            {"name": "matmul_n256_b32_k8", "file": "matmul_n256_b32_k8.hlo.txt",
             "kernel": "matmul", "n_dims": 256, "batch": 32, "n_groups": 8,
             "inputs": [], "outputs": []},
            {"name": "tiled_n256_b32_k8", "file": "tiled_n256_b32_k8.hlo.txt",
             "kernel": "tiled", "n_dims": 256, "batch": 32, "n_groups": 8,
             "inputs": [], "outputs": []}
          ]
        }"#
        .to_string()
    }

    #[test]
    fn parse_and_query() {
        let m = Manifest::parse(&sample_json(), PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.artifacts().len(), 3);
        assert_eq!(m.by_kernel("matmul").len(), 2);
        let a = m.find("matmul", 64).unwrap();
        assert_eq!(a.batch, 16);
        assert!(m.find("matmul", 128).is_none());
        assert_eq!(m.path_of(a), PathBuf::from("/tmp/a/matmul_n64_b16_k4.hlo.txt"));
    }

    #[test]
    fn best_fit_rounds_up() {
        let m = Manifest::parse(&sample_json(), PathBuf::from(".")).unwrap();
        assert_eq!(m.best_fit("matmul", 64).unwrap().n_dims, 64);
        assert_eq!(m.best_fit("matmul", 65).unwrap().n_dims, 256);
        assert_eq!(m.best_fit("matmul", 100).unwrap().n_dims, 256);
        assert!(m.best_fit("matmul", 1000).is_none());
        assert!(m.best_fit("bogus", 64).is_none());
    }

    #[test]
    fn rejects_wrong_version_or_interchange() {
        let bad_v = sample_json().replace("\"version\": 1", "\"version\": 99");
        assert!(Manifest::parse(&bad_v, PathBuf::from(".")).is_err());
        let bad_i = sample_json().replace("hlo-text", "proto");
        assert!(Manifest::parse(&bad_i, PathBuf::from(".")).is_err());
        assert!(Manifest::parse(
            r#"{"version":1,"interchange":"hlo-text","artifacts":[]}"#,
            PathBuf::from(".")
        )
        .is_err());
    }

    #[test]
    fn loads_real_generated_manifest_if_present() {
        // When `make artifacts` has run, the real manifest must parse and
        // its files must verify.  Skips silently in a clean checkout.
        let dir = crate::runtime::artifacts_dir_for_tests();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts().is_empty());
            m.verify_files().unwrap();
            // The shapes aot.py promises.
            assert!(m.find("matmul", 64).is_some());
            assert!(m.find("bruteforce", 256).is_some());
        }
    }
}
