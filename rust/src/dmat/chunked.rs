//! Out-of-core packed triangle: the chunk-addressable file tier.
//!
//! PR 7 made every source stream into the resident packed buffer; this
//! module delivers the other half of the out-of-core item: a triangle that
//! lives in a **checksummed chunk file** and pages contiguous row ranges
//! through a hard `--max-resident-bytes` budget.  The design follows the
//! same access-pattern inversion that made `native-batch` the GPU-winning
//! kernel — amortize each expensive read (there: HBM; here: disk) across
//! every permutation lane before moving on — applied one level down the
//! storage hierarchy.
//!
//! * [`TriangleChunk`] — a contiguous packed row range `[r0, r1)` plus its
//!   row offsets, globally indexed so kernels address rows exactly as they
//!   address a resident [`CondensedMatrix`];
//! * [`FileTriangle`] — the on-disk triangle (`TRC1` format) with a greedy
//!   budget-respecting [`chunk_plan`](FileTriangle::chunk_plan) and paging
//!   counters (`chunks_paged`, `bytes_paged`) the service reports;
//! * [`TriangleWriter`] — the streaming producer ingest spills into (tmp +
//!   rename, per-block FNV-64 checksums accumulated as values arrive);
//! * [`TriangleStorage`] — the `Resident | FileBacked` seam every layer
//!   above (prelude, backends, cache, coordinator) now carries.
//!
//! ## `TRC1` file format
//!
//! Little-endian throughout, mirroring the store's segment conventions
//! (`store/spill.rs`: magic + sized header + payload, written to a tmp
//! path and atomically renamed) and hardened with the integrity check the
//! out-of-core tier actually needs — the file is re-read many times per
//! run, so every block is checksummed, not just validated once at ingest:
//!
//! ```text
//! [ b"TRC1" ][ u64 n ][ u64 block_values ]          // 20-byte header
//! [ n(n-1)/2 × f32 values, scipy pdist order ]
//! [ ceil(count / block_values) × u64 FNV-64 ]       // per-block checksums
//! ```
//!
//! Every file position is computable from `n`, so reads seek directly.
//! [`FileTriangle::load_chunk`] verifies the FNV-64 of every checksum
//! block it touches before handing values to a kernel; a flipped bit
//! anywhere in a paged range is a typed error, never a silently wrong
//! statistic.
//!
//! **Bitwise contract:** chunk boundaries fall between packed rows and
//! every consumer sweeps rows in ascending order per permutation lane with
//! carried accumulators, so the f32/f64 operation sequence per lane is
//! identical to a resident sweep — file-backed results are bit-equal to
//! resident results (pinned by `rust/tests/oocore_chunked.rs`).

use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::condensed::CondensedMatrix;
use crate::error::{Error, Result};
use crate::store::{fnv64_fold, FNV64_OFFSET};

/// Chunk-file magic.
pub const TRC_MAGIC: &[u8; 4] = b"TRC1";

/// Values per checksum block (256 KiB of f32s): small enough that a
/// corrupt block re-read costs little, large enough that the trailing
/// table stays negligible (8 bytes per 256 KiB ≈ 0.003%).
pub const TRC_BLOCK_VALUES: usize = 1 << 16;

const TRC_HEADER_BYTES: u64 = 20;

/// Packed values before row `r` of an `n`-object triangle:
/// `sum_{i<r} (n-1-i) = r·n − r(r+1)/2`.  `row_start(n, n)` is the total
/// value count `n(n-1)/2`.
#[inline]
pub fn row_start(n: usize, r: usize) -> usize {
    r * n - r * (r + 1) / 2
}

/// A contiguous packed row range `[r0, r1)` resident in memory.
///
/// Rows are addressed by their **global** index so kernel code written
/// against [`CondensedView::row`](super::CondensedView::row) ports by
/// swapping the receiver: `chunk.row(i)` for `r0 ≤ i < r1` is bitwise the
/// resident `tri.row(i)`.
#[derive(Clone, Debug)]
pub struct TriangleChunk {
    n: usize,
    r0: usize,
    r1: usize,
    values: Vec<f32>,
    /// Row `r0 + i` spans `offsets[i]..offsets[i+1]` (`r1 - r0 + 1` entries).
    offsets: Vec<usize>,
}

impl TriangleChunk {
    /// Build a chunk from the packed values of rows `[r0, r1)`.
    pub fn from_values(n: usize, r0: usize, r1: usize, values: Vec<f32>) -> Result<TriangleChunk> {
        let want = row_start(n, r1) - row_start(n, r0);
        if r0 > r1 || r1 > n || values.len() != want {
            return Err(Error::Config(format!(
                "triangle chunk rows [{r0},{r1}) of n = {n}: got {} values, want {want}",
                values.len()
            )));
        }
        let mut offsets = Vec::with_capacity(r1 - r0 + 1);
        let mut acc = 0usize;
        offsets.push(0);
        for i in r0..r1 {
            acc += n - 1 - i;
            offsets.push(acc);
        }
        Ok(TriangleChunk { n, r0, r1, values, offsets })
    }

    /// Number of objects of the full triangle this chunk belongs to.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// First (global) row in the chunk.
    #[inline]
    pub fn r0(&self) -> usize {
        self.r0
    }

    /// One past the last (global) row in the chunk.
    #[inline]
    pub fn r1(&self) -> usize {
        self.r1
    }

    /// Row `i`'s packed slice (`r0 ≤ i < r1`, global index): bitwise the
    /// resident `tri.row(i)`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(self.r0 <= i && i < self.r1, "row {i} outside [{},{})", self.r0, self.r1);
        let k = i - self.r0;
        &self.values[self.offsets[k]..self.offsets[k + 1]]
    }

    /// The chunk's packed values (rows `r0..r1` concatenated).
    #[inline]
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Resident bytes of this chunk's value buffer.
    pub fn nbytes(&self) -> usize {
        self.values.len() * std::mem::size_of::<f32>()
    }
}

/// A hook that re-materializes the `TRC1` file at `path` (an `n`-object
/// triangle) from the original dataset source, leaving a sealed file at
/// exactly `path`.  Installed by
/// [`load_storage`](crate::coordinator::load_storage) where the run
/// config — and therefore the source — is known.
pub type RebuildFn = Box<dyn Fn(&Path, usize) -> Result<()> + Send + Sync>;

/// The on-disk packed triangle: `TRC1` file + checksum table + budget.
///
/// Owns its file: dropping the last handle deletes it (chunk files are
/// per-run scratch, not durable artifacts — durable state lives in the
/// result store).
pub struct FileTriangle {
    path: PathBuf,
    n: usize,
    budget_bytes: u64,
    /// One FNV-64 per `TRC_BLOCK_VALUES`-value block (last block short).
    checksums: Vec<u64>,
    chunks_paged: AtomicU64,
    bytes_paged: AtomicU64,
    /// Scratch-read recovery: when a chunk read fails its checksum or IO,
    /// this hook rebuilds the file from the source before one retry.
    /// Held in a `Mutex` so concurrent readers serialize on a rebuild
    /// instead of racing to rewrite the same file.
    rebuild: Mutex<Option<RebuildFn>>,
    rebuilds: AtomicU64,
}

// Manual impl: the boxed rebuild hook has no `Debug` of its own.
impl std::fmt::Debug for FileTriangle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FileTriangle")
            .field("path", &self.path)
            .field("n", &self.n)
            .field("budget_bytes", &self.budget_bytes)
            .field("blocks", &self.checksums.len())
            .field("chunks_paged", &self.chunks_paged)
            .field("bytes_paged", &self.bytes_paged)
            .field("rebuilds", &self.rebuilds)
            .finish()
    }
}

impl FileTriangle {
    /// Open an existing `TRC1` file, validating magic, geometry and exact
    /// file length, and loading the (small) trailing checksum table.
    pub fn open(path: impl AsRef<Path>, budget_bytes: u64) -> Result<FileTriangle> {
        let p = path.as_ref();
        let mut f = File::open(p).map_err(|e| Error::io(p.display().to_string(), e))?;
        let mut head = [0u8; TRC_HEADER_BYTES as usize];
        f.read_exact(&mut head).map_err(|e| Error::io(p.display().to_string(), e))?;
        if &head[..4] != TRC_MAGIC {
            return Err(Error::parse("trc", p.display().to_string(), "bad magic"));
        }
        let n = u64::from_le_bytes(head[4..12].try_into().unwrap()) as usize;
        let block = u64::from_le_bytes(head[12..20].try_into().unwrap()) as usize;
        if n == 0 || n > 1 << 20 {
            let msg = format!("implausible n = {n}");
            return Err(Error::parse("trc", p.display().to_string(), msg));
        }
        if block != TRC_BLOCK_VALUES {
            let msg = format!("checksum block {block}, want {TRC_BLOCK_VALUES}");
            return Err(Error::parse("trc", p.display().to_string(), msg));
        }
        let count = row_start(n, n);
        let nblocks = count.div_ceil(TRC_BLOCK_VALUES);
        let want_len = TRC_HEADER_BYTES + (count * 4) as u64 + (nblocks * 8) as u64;
        let got_len = f
            .metadata()
            .map_err(|e| Error::io(p.display().to_string(), e))?
            .len();
        if got_len != want_len {
            let msg = format!("file is {got_len} bytes, want {want_len} for n = {n}");
            return Err(Error::parse("trc", p.display().to_string(), msg));
        }
        f.seek(SeekFrom::Start(TRC_HEADER_BYTES + (count * 4) as u64))
            .map_err(|e| Error::io(p.display().to_string(), e))?;
        let mut table = vec![0u8; nblocks * 8];
        f.read_exact(&mut table).map_err(|e| Error::io(p.display().to_string(), e))?;
        let checksums = table
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(FileTriangle {
            path: p.to_path_buf(),
            n,
            budget_bytes,
            checksums,
            chunks_paged: AtomicU64::new(0),
            bytes_paged: AtomicU64::new(0),
            rebuild: Mutex::new(None),
            rebuilds: AtomicU64::new(0),
        })
    }

    /// Install the scratch-read recovery hook (see [`RebuildFn`]).
    pub fn set_rebuild(&self, hook: RebuildFn) {
        *self.rebuild.lock().unwrap() = Some(hook);
    }

    /// Re-materializations performed after failed chunk reads.
    pub fn rebuilds(&self) -> u64 {
        self.rebuilds.load(Ordering::Relaxed)
    }

    /// Number of objects (matrix edge).
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Total packed values `n(n-1)/2`.
    #[inline]
    pub fn count(&self) -> usize {
        row_start(self.n, self.n)
    }

    /// The resident-bytes budget chunks are planned against.
    #[inline]
    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Path of the backing chunk file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Chunks paged in since open (each [`load_chunk`](Self::load_chunk)
    /// that touched the disk counts one).
    pub fn chunks_paged(&self) -> u64 {
        self.chunks_paged.load(Ordering::Relaxed)
    }

    /// Bytes read from disk since open (checksum-block granular).
    pub fn bytes_paged(&self) -> u64 {
        self.bytes_paged.load(Ordering::Relaxed)
    }

    /// Honest resident accounting for a file-backed triangle: at most one
    /// budget's worth of values is ever resident, plus the checksum table.
    pub fn resident_bytes(&self) -> usize {
        let packed = self.count() * 4;
        (self.budget_bytes as usize).min(packed) + self.checksums.len() * 8
    }

    /// Greedy chunk plan covering rows `[0, n)`: each range's packed bytes
    /// fit the budget, row counts are multiples of `align` (except the
    /// final range), and every range holds at least one `align` group even
    /// if that group alone exceeds the budget — the plan must always make
    /// progress.  `align > 1` exists for the tiled kernel, whose stripe
    /// loop must not straddle a chunk boundary.
    pub fn chunk_plan(&self, align: usize) -> Vec<(usize, usize)> {
        let align = align.max(1);
        let n = self.n;
        let budget_values = (self.budget_bytes / 4) as usize;
        let mut plan = Vec::new();
        let mut r0 = 0usize;
        while r0 < n {
            let mut r1 = (r0 + align).min(n);
            loop {
                let next = (r1 + align).min(n);
                if next == r1 {
                    break;
                }
                if row_start(n, next) - row_start(n, r0) > budget_values {
                    break;
                }
                r1 = next;
            }
            plan.push((r0, r1));
            r0 = r1;
        }
        plan
    }

    /// Page rows `[r0, r1)` in from disk, verifying the FNV-64 of every
    /// checksum block the range touches.  Reads are block-granular (the
    /// checksum unit), so `bytes_paged` counts what actually crossed the
    /// disk boundary, not just the values requested.
    ///
    /// Failure containment: a checksum or IO failure triggers **one**
    /// re-materialization of the file from the original source (when a
    /// [`RebuildFn`] is installed) followed by one retry; only a second
    /// failure surfaces, and its error says the rebuild was attempted.
    pub fn load_chunk(&self, r0: usize, r1: usize) -> Result<TriangleChunk> {
        let first = match self.read_chunk(r0, r1) {
            Ok(chunk) => return Ok(chunk),
            // Only data-path failures are recoverable by a rebuild;
            // a bad row range is the caller's bug and passes through.
            Err(e @ (Error::Io { .. } | Error::InvalidInput(_))) => e,
            Err(e) => return Err(e),
        };
        // Hold the hook lock across the rebuild + retry so concurrent
        // readers wait for one rewrite instead of racing their own.
        let guard = self.rebuild.lock().unwrap();
        let Some(hook) = guard.as_ref() else {
            return Err(first);
        };
        self.rebuilds.fetch_add(1, Ordering::Relaxed);
        eprintln!(
            "triangle chunk file {}: read failed ({first}); re-materializing from \
             the original source (one retry)",
            self.path.display()
        );
        if let Err(re) = hook(&self.path, self.n) {
            return Err(Error::InvalidInput(format!(
                "triangle chunk file {}: chunk read failed ({first}) and \
                 re-materialization from the source failed too ({re})",
                self.path.display()
            )));
        }
        self.read_chunk(r0, r1).map_err(|second| {
            Error::InvalidInput(format!(
                "triangle chunk file {}: chunk read failed even after \
                 re-materializing from the source ({second})",
                self.path.display()
            ))
        })
    }

    /// One raw attempt at paging rows `[r0, r1)` — no recovery.
    fn read_chunk(&self, r0: usize, r1: usize) -> Result<TriangleChunk> {
        let n = self.n;
        if r0 > r1 || r1 > n {
            return Err(Error::Config(format!("chunk rows [{r0},{r1}) out of range for n = {n}")));
        }
        let v0 = row_start(n, r0);
        let v1 = row_start(n, r1);
        if v0 == v1 {
            return TriangleChunk::from_values(n, r0, r1, Vec::new());
        }
        // Fault seam: `corrupt` forges the checksum-mismatch error a
        // flipped bit produces; `err` forges the IO error a failing disk
        // produces.  Each consult covers one read attempt, so `@<n>`
        // plans can fail the first attempt and let the retry succeed.
        match crate::inject::check("scratch.read") {
            Some(crate::inject::FaultKind::Corrupt) => {
                return Err(Error::InvalidInput(format!(
                    "triangle chunk file {}: checksum mismatch in block 0 \
                     (injected fault) — file corrupt, re-ingest the dataset",
                    self.path.display()
                )));
            }
            Some(crate::inject::FaultKind::Err) => {
                return Err(Error::io(
                    self.path.display().to_string(),
                    std::io::Error::other("injected fault: scratch.read:err"),
                ));
            }
            _ => {}
        }
        let count = self.count();
        let b0 = v0 / TRC_BLOCK_VALUES;
        let b1 = v1.div_ceil(TRC_BLOCK_VALUES);
        let lo = b0 * TRC_BLOCK_VALUES;
        let hi = (b1 * TRC_BLOCK_VALUES).min(count);
        let p = &self.path;
        let mut f = File::open(p).map_err(|e| Error::io(p.display().to_string(), e))?;
        f.seek(SeekFrom::Start(TRC_HEADER_BYTES + (lo * 4) as u64))
            .map_err(|e| Error::io(p.display().to_string(), e))?;
        let mut bytes = vec![0u8; (hi - lo) * 4];
        f.read_exact(&mut bytes).map_err(|e| Error::io(p.display().to_string(), e))?;
        for b in b0..b1 {
            let s = (b * TRC_BLOCK_VALUES - lo) * 4;
            let e = (((b + 1) * TRC_BLOCK_VALUES).min(count) - lo) * 4;
            let got = fnv64_fold(FNV64_OFFSET, &bytes[s..e]);
            if got != self.checksums[b] {
                return Err(Error::InvalidInput(format!(
                    "triangle chunk file {}: checksum mismatch in block {b} \
                     ({got:#018x} vs {:#018x}) — file corrupt, re-ingest the dataset",
                    p.display(),
                    self.checksums[b]
                )));
            }
        }
        let values: Vec<f32> = bytes[(v0 - lo) * 4..(v1 - lo) * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        self.chunks_paged.fetch_add(1, Ordering::Relaxed);
        self.bytes_paged.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        TriangleChunk::from_values(n, r0, r1, values)
    }
}

impl Drop for FileTriangle {
    fn drop(&mut self) {
        // Per-run scratch: best-effort cleanup, never fail a drop.
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Streaming `TRC1` producer: push values in scipy `pdist` order, finish
/// with the budget the resulting [`FileTriangle`] pages under.  Follows
/// the spill-segment discipline (`store/spill.rs`): write to `<path>.tmp`,
/// fsync, atomically rename — a crash mid-write never leaves a file that
/// [`FileTriangle::open`] would accept.
pub struct TriangleWriter {
    final_path: PathBuf,
    tmp_path: PathBuf,
    w: BufWriter<File>,
    n: usize,
    written: usize,
    checksums: Vec<u64>,
    block_fill: usize,
    hash: u64,
}

impl TriangleWriter {
    /// Start a `TRC1` file for an `n`-object triangle at `path`.
    pub fn create(path: impl AsRef<Path>, n: usize) -> Result<TriangleWriter> {
        let final_path = path.as_ref().to_path_buf();
        let tmp_path = final_path.with_extension("tmp");
        // Fault seam: fail spill-file creation before any byte lands, the
        // same clean failure a full scratch volume gives.
        if let Some(e) = crate::inject::io_error("scratch.write") {
            return Err(Error::io(tmp_path.display().to_string(), e));
        }
        let f = File::create(&tmp_path)
            .map_err(|e| Error::io(tmp_path.display().to_string(), e))?;
        let mut w = BufWriter::new(f);
        w.write_all(TRC_MAGIC)
            .and_then(|_| w.write_all(&(n as u64).to_le_bytes()))
            .and_then(|_| w.write_all(&(TRC_BLOCK_VALUES as u64).to_le_bytes()))
            .map_err(|e| Error::io(tmp_path.display().to_string(), e))?;
        Ok(TriangleWriter {
            final_path,
            tmp_path,
            w,
            n,
            written: 0,
            checksums: Vec::new(),
            block_fill: 0,
            hash: FNV64_OFFSET,
        })
    }

    /// Append the next packed value (scipy `pdist` order).
    pub fn push(&mut self, v: f32) -> Result<()> {
        let b = v.to_le_bytes();
        self.hash = fnv64_fold(self.hash, &b);
        self.w
            .write_all(&b)
            .map_err(|e| Error::io(self.tmp_path.display().to_string(), e))?;
        self.written += 1;
        self.block_fill += 1;
        if self.block_fill == TRC_BLOCK_VALUES {
            self.checksums.push(self.hash);
            self.hash = FNV64_OFFSET;
            self.block_fill = 0;
        }
        Ok(())
    }

    /// Append a run of packed values.
    pub fn push_all(&mut self, vals: &[f32]) -> Result<()> {
        for &v in vals {
            self.push(v)?;
        }
        Ok(())
    }

    /// Values pushed so far.
    pub fn written(&self) -> usize {
        self.written
    }

    /// Seal the file (checksum table, fsync, rename) and open it with the
    /// given paging budget.
    pub fn finish(self, budget_bytes: u64) -> Result<FileTriangle> {
        let path = self.final_path.clone();
        self.seal()?;
        FileTriangle::open(&path, budget_bytes)
    }

    /// Seal the file **without** opening it: the scratch-rebuild path
    /// rewrites a file that an existing [`FileTriangle`] handle already
    /// owns, and that handle's `Drop` must stay the only one deleting it.
    pub fn seal(mut self) -> Result<()> {
        let want = row_start(self.n, self.n);
        if self.written != want {
            return Err(Error::InvalidInput(format!(
                "triangle ended early: got {} of {want} distances for n = {}",
                self.written, self.n
            )));
        }
        if self.block_fill > 0 {
            self.checksums.push(self.hash);
        }
        for &c in &self.checksums {
            self.w
                .write_all(&c.to_le_bytes())
                .map_err(|e| Error::io(self.tmp_path.display().to_string(), e))?;
        }
        self.w
            .flush()
            .map_err(|e| Error::io(self.tmp_path.display().to_string(), e))?;
        self.w
            .get_ref()
            .sync_all()
            .map_err(|e| Error::io(self.tmp_path.display().to_string(), e))?;
        std::fs::rename(&self.tmp_path, &self.final_path)
            .map_err(|e| Error::io(self.final_path.display().to_string(), e))
    }
}

/// Unique scratch path for a chunk file (pid + process-wide sequence, in
/// the system temp dir).
pub fn scratch_triangle_path(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "permanova_trc_{tag}_{}_{seq}.trc",
        std::process::id()
    ))
}

/// Where a dataset's packed triangle lives: the seam every layer above the
/// kernels now carries.
///
/// `Resident` is the PR 5–8 world — the whole triangle in one
/// [`CondensedMatrix`], shared by `Arc`.  `FileBacked` is the out-of-core
/// tier: rows page through [`FileTriangle::load_chunk`] under a byte
/// budget.  Both are cheap to clone (Arc handles).
#[derive(Clone, Debug)]
pub enum TriangleStorage {
    /// Whole triangle resident in memory.
    Resident(Arc<CondensedMatrix>),
    /// Triangle paged from a checksummed chunk file under a byte budget.
    FileBacked(Arc<FileTriangle>),
}

impl TriangleStorage {
    /// Number of objects (matrix edge).
    pub fn n(&self) -> usize {
        match self {
            TriangleStorage::Resident(t) => t.n(),
            TriangleStorage::FileBacked(f) => f.n(),
        }
    }

    /// The resident triangle, if this storage is resident.
    pub fn as_resident(&self) -> Option<&Arc<CondensedMatrix>> {
        match self {
            TriangleStorage::Resident(t) => Some(t),
            TriangleStorage::FileBacked(_) => None,
        }
    }

    /// The file tier, if this storage is file-backed.
    pub fn as_file(&self) -> Option<&Arc<FileTriangle>> {
        match self {
            TriangleStorage::Resident(_) => None,
            TriangleStorage::FileBacked(f) => Some(f),
        }
    }

    /// True when rows page from disk.
    pub fn is_file_backed(&self) -> bool {
        matches!(self, TriangleStorage::FileBacked(_))
    }

    /// Honest resident accounting: full buffer + offsets when resident; at
    /// most one budget of values + the checksum table when file-backed.
    pub fn resident_bytes(&self) -> usize {
        match self {
            TriangleStorage::Resident(t) => t.resident_bytes(),
            TriangleStorage::FileBacked(f) => f.resident_bytes(),
        }
    }

    /// Paging counters `(chunks_paged, bytes_paged)`; `None` when resident.
    pub fn paging(&self) -> Option<(u64, u64)> {
        self.as_file().map(|f| (f.chunks_paged(), f.bytes_paged()))
    }
}

/// Write a resident triangle out as a chunk file (scratch path) and hand
/// back file-backed storage paging under `budget_bytes`.  Test and bench
/// helper: the canonical producer path is ingest spill
/// (`TriangleSink::with_budget`), which never materializes the resident
/// buffer at all.
pub fn file_backed_from(tri: &CondensedMatrix, budget_bytes: u64) -> Result<TriangleStorage> {
    let mut w = TriangleWriter::create(scratch_triangle_path("copy"), tri.n())?;
    w.push_all(tri.values())?;
    Ok(TriangleStorage::FileBacked(Arc::new(w.finish(budget_bytes)?)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dmat::random_euclidean_condensed;
    use crate::store::fnv64_bytes;

    fn file_of(tri: &CondensedMatrix, budget: u64) -> FileTriangle {
        let mut w = TriangleWriter::create(scratch_triangle_path("test"), tri.n()).unwrap();
        w.push_all(tri.values()).unwrap();
        w.finish(budget).unwrap()
    }

    #[test]
    fn row_start_matches_offsets() {
        for n in [1usize, 2, 3, 17, 64] {
            let mut acc = 0usize;
            for r in 0..n {
                assert_eq!(row_start(n, r), acc, "n={n} r={r}");
                acc += n - 1 - r;
            }
            assert_eq!(row_start(n, n), n * (n - 1) / 2, "n={n}");
        }
    }

    #[test]
    fn write_then_chunked_read_is_bitwise() {
        let tri = random_euclidean_condensed(61, 5, 9);
        let ft = file_of(&tri, 400); // 100 values per chunk: many chunks
        assert_eq!(ft.n(), 61);
        let plan = ft.chunk_plan(1);
        assert!(plan.len() >= 4, "budget forces paging: {plan:?}");
        let mut got: Vec<u32> = Vec::new();
        for &(r0, r1) in &plan {
            let chunk = ft.load_chunk(r0, r1).unwrap();
            for i in r0..r1 {
                assert_eq!(chunk.row(i), tri.row(i), "row {i}");
            }
            got.extend(chunk.values().iter().map(|v| v.to_bits()));
        }
        let want: Vec<u32> = tri.values().iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want);
        assert_eq!(ft.chunks_paged(), plan.len() as u64);
        assert!(ft.bytes_paged() >= (tri.values().len() * 4) as u64);
    }

    #[test]
    fn chunk_plan_covers_aligned_and_respects_budget() {
        let tri = random_euclidean_condensed(50, 4, 3);
        let ft = file_of(&tri, 1000); // 250 values per chunk
        for align in [1usize, 4, 8] {
            let plan = ft.chunk_plan(align);
            assert_eq!(plan.first().unwrap().0, 0);
            assert_eq!(plan.last().unwrap().1, 50);
            for w in plan.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            for (idx, &(r0, r1)) in plan.iter().enumerate() {
                if idx + 1 < plan.len() {
                    assert_eq!((r1 - r0) % align, 0, "align {align}: [{r0},{r1})");
                }
                let bytes = (row_start(50, r1) - row_start(50, r0)) * 4;
                // Within budget unless a single align group already overflows.
                assert!(
                    bytes <= 1000 || r1 - r0 <= align,
                    "align {align}: [{r0},{r1}) = {bytes} bytes"
                );
            }
        }
        // A huge budget yields a single chunk.
        let ft = file_of(&tri, u64::MAX);
        assert_eq!(ft.chunk_plan(1), vec![(0, 50)]);
    }

    #[test]
    fn checksum_table_matches_whole_block_fnv() {
        // Geometry sanity at a sub-block size: one short block.
        let tri = random_euclidean_condensed(33, 4, 5);
        let ft = file_of(&tri, u64::MAX);
        let bytes: Vec<u8> = tri.values().iter().flat_map(|v| v.to_le_bytes()).collect();
        assert_eq!(ft.checksums.len(), 1);
        assert_eq!(ft.checksums[0], fnv64_bytes(&bytes));
    }

    #[test]
    fn corrupt_value_is_a_checksum_error() {
        let tri = random_euclidean_condensed(40, 4, 11);
        let ft = file_of(&tri, 600);
        // Flip one payload byte in place.
        let path = ft.path().to_path_buf();
        let mut raw = std::fs::read(&path).unwrap();
        raw[TRC_HEADER_BYTES as usize + 41] ^= 0x40;
        std::fs::write(&path, raw).unwrap();
        let e = ft.load_chunk(0, ft.n()).unwrap_err().to_string();
        assert!(e.contains("checksum mismatch"), "{e}");
    }

    #[test]
    fn open_rejects_bad_magic_and_truncation() {
        let tri = random_euclidean_condensed(20, 3, 2);
        let ft = file_of(&tri, 1 << 20);
        let path = ft.path().to_path_buf();
        let raw = std::fs::read(&path).unwrap();

        let bad = scratch_triangle_path("badmagic");
        let mut b = raw.clone();
        b[0] = b'X';
        std::fs::write(&bad, &b).unwrap();
        let e = FileTriangle::open(&bad, 1024).unwrap_err().to_string();
        assert!(e.contains("bad magic"), "{e}");
        std::fs::remove_file(&bad).unwrap();

        let short = scratch_triangle_path("short");
        std::fs::write(&short, &raw[..raw.len() - 3]).unwrap();
        let e = FileTriangle::open(&short, 1024).unwrap_err().to_string();
        assert!(e.contains("bytes"), "{e}");
        std::fs::remove_file(&short).unwrap();
    }

    #[test]
    fn writer_rejects_early_finish() {
        let mut w = TriangleWriter::create(scratch_triangle_path("early"), 5).unwrap();
        w.push_all(&[1.0, 2.0, 3.0]).unwrap();
        let e = w.finish(1024).unwrap_err().to_string();
        assert!(e.contains("ended early"), "{e}");
    }

    #[test]
    fn drop_removes_the_backing_file() {
        let tri = random_euclidean_condensed(10, 3, 1);
        let ft = file_of(&tri, 1024);
        let path = ft.path().to_path_buf();
        assert!(path.exists());
        drop(ft);
        assert!(!path.exists());
    }

    #[test]
    fn storage_accessors_and_accounting() {
        let tri = random_euclidean_condensed(30, 4, 7);
        let resident = TriangleStorage::Resident(Arc::new(tri.clone()));
        assert_eq!(resident.n(), 30);
        assert!(!resident.is_file_backed());
        assert!(resident.as_resident().is_some());
        assert!(resident.paging().is_none());
        assert_eq!(resident.resident_bytes(), tri.resident_bytes());

        let fb = file_backed_from(&tri, 512).unwrap();
        assert_eq!(fb.n(), 30);
        assert!(fb.is_file_backed());
        assert!(fb.as_resident().is_none());
        assert_eq!(fb.paging(), Some((0, 0)));
        // Budget-capped values + checksum table, far below the full buffer.
        assert!(fb.resident_bytes() < tri.resident_bytes());
        let f = fb.as_file().unwrap();
        f.load_chunk(0, 30).unwrap();
        let (chunks, bytes) = fb.paging().unwrap();
        assert_eq!(chunks, 1);
        assert!(bytes >= (tri.values().len() * 4) as u64);
    }

    #[test]
    fn empty_range_loads_without_io() {
        let tri = random_euclidean_condensed(12, 3, 4);
        let ft = file_of(&tri, 1024);
        let c = ft.load_chunk(5, 5).unwrap();
        assert_eq!(c.values().len(), 0);
        assert_eq!(ft.chunks_paged(), 0);
    }
}
