//! Packed upper-triangle storage: the canonical kernel operand.
//!
//! Every permutation kernel in this crate — the PERMANOVA s_W
//! formulations, the batched SoA engine, the ANOSIM rank sweep — reads the
//! distance matrix's **strict upper triangle** in `(row, col > row)`
//! row-major order and nothing else.  Storing the full dense `n*n` matrix
//! therefore doubles the resident working set with bytes no kernel ever
//! touches: symmetric dead weight that evicts useful cache lines and
//! halves the largest problem that fits in LLC/HBM.  On the MI300A —
//! where CPU and GPU contend for the *same* HBM — footprint is bandwidth,
//! so the packed layout here is what the engine streams.
//!
//! * [`CondensedMatrix`] owns the packed `n*(n-1)/2` f32 buffer plus the
//!   per-row offsets (scipy `pdist` order: `d(0,1), d(0,2), ...,
//!   d(0,n-1), d(1,2), ...`), built once per dataset from a
//!   [`DistanceMatrix`];
//! * [`CondensedView`] is the borrowed, `Copy` view the kernels take.
//!
//! **Bitwise contract:** `view().row(i)` is exactly the slice
//! `dense_row_i[i+1..n]` — same values, same order — so a kernel ported
//! from the dense layout executes the identical f32/f64 operation sequence
//! and produces bit-identical statistics.  The packed-vs-dense conformance
//! suite pins this for every kernel, method and backend.

use super::DistanceMatrix;
use crate::error::{Error, Result};

/// Owned packed upper triangle: `n*(n-1)/2` f32 values + row offsets.
///
/// Row `i` (for `i < n-1`) holds `d(i, i+1), ..., d(i, n-1)` — the exact
/// slice the dense kernels read per row, at half the resident footprint.
#[derive(Clone, Debug, PartialEq)]
pub struct CondensedMatrix {
    n: usize,
    values: Vec<f32>,
    /// `offsets[i]..offsets[i+1]` bounds row `i` in `values` (n+1 entries).
    offsets: Vec<usize>,
}

/// Row offsets for an `n`-object packed triangle (`n + 1` entries; row `i`
/// spans `offsets[i]..offsets[i+1]`, length `n - 1 - i`).
fn row_offsets(n: usize) -> Vec<usize> {
    let mut offsets = Vec::with_capacity(n + 1);
    let mut acc = 0usize;
    offsets.push(0);
    for i in 0..n {
        acc += n - 1 - i;
        offsets.push(acc);
    }
    offsets
}

impl CondensedMatrix {
    /// Pack the strict upper triangle of a dense matrix (row-major scan —
    /// the values land in scipy `pdist` order).
    pub fn from_dense(mat: &DistanceMatrix) -> CondensedMatrix {
        let n = mat.n();
        let mut values = Vec::with_capacity(n * (n - 1) / 2);
        for i in 0..n {
            values.extend_from_slice(&mat.row(i)[i + 1..]);
        }
        CondensedMatrix { n, values, offsets: row_offsets(n) }
    }

    /// Wrap a condensed vector (scipy `pdist` order); checks the length.
    ///
    /// A mismatched length is a typed [`Error::Config`] here, at the
    /// construction boundary — not a panic later inside
    /// [`row`](Self::row) when an offset walks past the short buffer.
    pub fn from_values(n: usize, values: Vec<f32>) -> Result<CondensedMatrix> {
        let want = n * n.saturating_sub(1) / 2;
        if values.len() != want {
            return Err(Error::Config(format!(
                "condensed buffer has {} entries, want n(n-1)/2 = {want} for n = {n}",
                values.len()
            )));
        }
        Ok(CondensedMatrix { n, values, offsets: row_offsets(n) })
    }

    /// Mirror back into a dense matrix (exact: both triangles get the
    /// packed values, the diagonal is zero).
    pub fn to_dense(&self) -> DistanceMatrix {
        DistanceMatrix::from_condensed(self.n, &self.values)
            .expect("packed buffer length is maintained as an invariant")
    }

    /// Number of objects (matrix edge).
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// The packed buffer, in scipy `pdist` order (`n*(n-1)/2` values).
    #[inline]
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Row `i`'s packed slice: `d(i, i+1), ..., d(i, n-1)`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.values[self.offsets[i]..self.offsets[i + 1]]
    }

    /// Entry `(i, j)` for `i != j` (symmetric lookup; the diagonal is 0).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        if i == j {
            return 0.0;
        }
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        self.values[self.offsets[lo] + (hi - lo - 1)]
    }

    /// The borrowed view kernels take.
    #[inline]
    pub fn view(&self) -> CondensedView<'_> {
        CondensedView { n: self.n, values: &self.values, offsets: &self.offsets }
    }

    /// Bytes of the packed representation — the resident footprint the
    /// kernels actually stream (≤ ~0.5× the dense `n*n*4`).
    pub fn nbytes(&self) -> usize {
        self.values.len() * std::mem::size_of::<f32>()
    }

    /// Total resident bytes including the row-offset table: the honest
    /// memory-accounting number for a cached dataset that holds *only*
    /// this packed buffer (`n(n-1)/2 · 4` values + `(n+1) · 8` offsets).
    pub fn resident_bytes(&self) -> usize {
        self.nbytes() + self.offsets.len() * std::mem::size_of::<usize>()
    }
}

/// Borrowed packed-triangle view: what every f32 kernel sweeps.
#[derive(Clone, Copy, Debug)]
pub struct CondensedView<'a> {
    n: usize,
    values: &'a [f32],
    offsets: &'a [usize],
}

impl<'a> CondensedView<'a> {
    /// Number of objects (matrix edge).
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// The packed buffer (`n*(n-1)/2` values, scipy `pdist` order).
    #[inline]
    pub fn values(&self) -> &'a [f32] {
        self.values
    }

    /// Row `i`'s packed slice: `d(i, i+1), ..., d(i, n-1)` — bitwise the
    /// dense `row(i)[i+1..]`.
    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.values[self.offsets[i]..self.offsets[i + 1]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DistanceMatrix {
        let mut m = DistanceMatrix::zeros(4);
        m.set_sym(0, 1, 1.0);
        m.set_sym(0, 2, 2.0);
        m.set_sym(0, 3, 3.0);
        m.set_sym(1, 2, 1.5);
        m.set_sym(1, 3, 2.5);
        m.set_sym(2, 3, 0.5);
        m
    }

    #[test]
    fn packs_in_pdist_order() {
        let pm = CondensedMatrix::from_dense(&small());
        assert_eq!(pm.n(), 4);
        assert_eq!(pm.values(), &[1.0, 2.0, 3.0, 1.5, 2.5, 0.5]);
        assert_eq!(pm.values(), small().to_condensed().as_slice());
    }

    #[test]
    fn rows_match_dense_row_tails_bitwise() {
        for n in [3usize, 4, 7, 33, 64] {
            let m = DistanceMatrix::random_euclidean(n, 5, n as u64);
            let pm = CondensedMatrix::from_dense(&m);
            for i in 0..n {
                let dense_tail = &m.row(i)[i + 1..];
                assert_eq!(pm.row(i), dense_tail, "n={n} row {i}");
                assert_eq!(pm.view().row(i), dense_tail, "view n={n} row {i}");
            }
            assert_eq!(pm.row(n - 1).len(), 0, "last row has no columns");
        }
    }

    #[test]
    fn dense_roundtrip_is_exact() {
        for (n, seed) in [(3usize, 1u64), (20, 2), (45, 3)] {
            let m = DistanceMatrix::random_euclidean(n, 6, seed);
            let pm = CondensedMatrix::from_dense(&m);
            assert_eq!(pm.to_dense(), m, "n={n}");
        }
    }

    #[test]
    fn symmetric_get() {
        let pm = CondensedMatrix::from_dense(&small());
        assert_eq!(pm.get(1, 3), 2.5);
        assert_eq!(pm.get(3, 1), 2.5);
        assert_eq!(pm.get(2, 2), 0.0);
    }

    #[test]
    fn from_values_checks_length() {
        assert!(CondensedMatrix::from_values(4, vec![0.0; 6]).is_ok());
        // The bugfix pin: a bad length is a typed Config error at the
        // construction boundary, not a later panic in row().
        match CondensedMatrix::from_values(4, vec![0.0; 5]) {
            Err(Error::Config(m)) => assert!(m.contains("n(n-1)/2"), "{m}"),
            other => panic!("want Error::Config, got {other:?}"),
        }
    }

    #[test]
    fn resident_bytes_counts_values_plus_offsets() {
        for n in [2usize, 17, 64] {
            let pm = CondensedMatrix::from_dense(&DistanceMatrix::zeros(n));
            assert_eq!(pm.resident_bytes(), n * (n - 1) / 2 * 4 + (n + 1) * 8, "n={n}");
        }
    }

    #[test]
    fn footprint_is_at_most_half_dense() {
        for n in [3usize, 16, 101] {
            let m = DistanceMatrix::zeros(n);
            let pm = CondensedMatrix::from_dense(&m);
            assert_eq!(pm.nbytes(), n * (n - 1) / 2 * 4);
            assert!(pm.nbytes() * 2 <= m.nbytes(), "n={n}");
        }
    }

    #[test]
    fn tiny_edges_dont_panic() {
        let m1 = DistanceMatrix::zeros(1);
        let p1 = CondensedMatrix::from_dense(&m1);
        assert_eq!(p1.values().len(), 0);
        assert_eq!(p1.row(0).len(), 0);
        let m2 = DistanceMatrix::zeros(2);
        let p2 = CondensedMatrix::from_dense(&m2);
        assert_eq!(p2.values().len(), 1);
    }
}
