//! Streaming ingestion: every data source builds the packed triangle
//! directly — no dense `n*n` staging copy.
//!
//! PR 5 made [`CondensedMatrix`] the canonical kernel operand but the
//! loaders still materialized the full dense matrix first, so total
//! allocation peaked at ~1.5× the condensed size.  This module closes that
//! gap: the TSV/Pdm readers and the synthetic generator emit packed rows
//! straight into the `n(n-1)/2` buffer, and the PERMANOVA input contract
//! (finite, non-negative, zero diagonal, symmetric within `tol`) is
//! enforced **in the same streaming pass** by [`TriangleSink`] — a lower
//! entry `(r, c<r)` is compared against its mirror `(c, r)`, which was
//! already written when row `c` streamed through, so no dense cross-read
//! is ever needed.
//!
//! **Bitwise contract:** for any well-formed source, the streamed triangle
//! is bit-identical to `CondensedMatrix::from_dense` of the dense loader's
//! result — same values, same scipy `pdist` order.  The dense loaders
//! survive as test-only oracles; `rust/tests/ingest_streaming.rs` pins the
//! equivalence per source.

use std::io::{BufRead, BufReader, Read};
use std::path::Path;
use std::sync::Arc;

use super::chunked::{scratch_triangle_path, TriangleStorage, TriangleWriter};
use super::condensed::CondensedMatrix;
use super::PDM_MAGIC;
use crate::error::{Error, Result};
use crate::rng::Xoshiro256pp;

/// Packed index of the upper-triangle entry `(lo, hi)` (`lo < hi`) for an
/// `n`-object matrix: row `lo` starts at `lo*(n-1) - lo*(lo-1)/2`.
#[inline]
fn pack_index(n: usize, lo: usize, hi: usize) -> usize {
    debug_assert!(lo < hi && hi < n);
    lo * (n - 1) - lo * (lo - 1) / 2 + (hi - lo - 1)
}

/// Streaming builder + validator for the packed triangle.
///
/// Feed entries in row-major order (`r` ascending, `c` ascending within
/// each row; square sources feed all `n*n` entries, triangular generators
/// may feed only `c > r`).  Upper entries are stored; the diagonal and the
/// lower triangle are validated against the already-written upper entries
/// and discarded.  Every check the dense `DistanceMatrix::validate` ran as
/// a separate post-load pass happens here, per entry, as the bytes arrive:
///
/// * every entry must be finite (including the diagonal — the dense
///   validator's `|d| > tol` test silently passed a NaN diagonal; the
///   streaming pass closes that hole);
/// * diagonal entries must be 0 within `tol`;
/// * off-diagonal entries must be non-negative;
/// * a lower entry `(r, c)` must match its mirror `(c, r)` within `tol`.
///
/// Errors are [`Error::InvalidInput`] naming the offending `(row, col)`;
/// the loaders wrap them with the file path.
#[derive(Debug)]
pub struct TriangleSink {
    n: usize,
    tol: f32,
    values: Vec<f32>,
    /// Spill mode (out-of-core ingest): once the buffer would exceed this
    /// budget, values divert to a checksummed chunk file.  `None` keeps
    /// the PR 7 fully-resident behavior.
    budget_bytes: Option<u64>,
    /// Packed values already flushed to `writer` (the buffer holds the
    /// suffix `[flushed..)` of the packed order).
    flushed: usize,
    /// Lazily created on first flush, so an under-budget source never
    /// touches the disk.
    writer: Option<TriangleWriter>,
}

impl TriangleSink {
    /// A sink for an `n`-object matrix with symmetry/diagonal tolerance
    /// `tol`.
    pub fn new(n: usize, tol: f32) -> TriangleSink {
        TriangleSink {
            n,
            tol,
            values: Vec::with_capacity(n * n.saturating_sub(1) / 2),
            budget_bytes: None,
            flushed: 0,
            writer: None,
        }
    }

    /// A spill-capable sink: the resident buffer never exceeds
    /// `budget_bytes`; overflow streams to a scratch chunk file and
    /// [`finish_storage`](Self::finish_storage) hands back file-backed
    /// storage.  **Validation caveat** (documented honestly): a lower
    /// entry's mirror check only runs while its upper twin is still in
    /// the resident window — mirrors already flushed to disk are trusted.
    /// Upper-triangle-only producers (the synthetic generators) lose
    /// nothing; a square source with an asymmetry more than one budget
    /// behind the stream head is not detected here.
    pub fn with_budget(n: usize, tol: f32, budget_bytes: u64) -> TriangleSink {
        let mut s = TriangleSink::new(n, tol);
        s.values = Vec::new(); // don't pre-reserve the full triangle
        s.budget_bytes = Some(budget_bytes);
        s
    }

    /// Divert the buffered values to the chunk writer (spill mode only).
    fn flush_to_writer(&mut self) -> Result<()> {
        if self.writer.is_none() {
            self.writer = Some(TriangleWriter::create(
                scratch_triangle_path("ingest"),
                self.n,
            )?);
        }
        let w = self.writer.as_mut().expect("just created");
        w.push_all(&self.values)?;
        self.flushed += self.values.len();
        self.values.clear();
        Ok(())
    }

    /// Ingest entry `(r, c) = v`.  Upper entries are appended to the
    /// packed buffer; diagonal/lower entries are validated and dropped.
    pub fn entry(&mut self, r: usize, c: usize, v: f32) -> Result<()> {
        if !v.is_finite() {
            return Err(Error::InvalidInput(format!("non-finite distance at ({r},{c})")));
        }
        if r == c {
            if v.abs() > self.tol {
                return Err(Error::InvalidInput(format!(
                    "diagonal entry ({r},{r}) = {v}, want 0"
                )));
            }
            return Ok(());
        }
        if v < 0.0 {
            let (lo, hi) = if r < c { (r, c) } else { (c, r) };
            return Err(Error::InvalidInput(format!(
                "negative distance at ({lo},{hi}): {v}"
            )));
        }
        if c > r {
            // Row-major streaming invariant: this upper entry lands exactly
            // at the next packed slot.
            debug_assert_eq!(
                self.flushed + self.values.len(),
                pack_index(self.n, r, c)
            );
            self.values.push(v);
            if let Some(budget) = self.budget_bytes {
                if (self.values.len() * 4) as u64 > budget {
                    self.flush_to_writer()?;
                }
            }
        } else {
            // Mirror check: row `c` already streamed.  In spill mode the
            // twin may already be on disk; only the resident window is
            // checkable (see `with_budget`).
            let idx = pack_index(self.n, c, r);
            if idx >= self.flushed {
                let mirror = self.values[idx - self.flushed];
                if (v - mirror).abs() > self.tol {
                    return Err(Error::InvalidInput(format!(
                        "asymmetry at ({c},{r}): {mirror} vs {v} (tol {})",
                        self.tol
                    )));
                }
            }
        }
        Ok(())
    }

    /// True once any value has spilled to the chunk file.
    pub fn spilled(&self) -> bool {
        self.flushed > 0
    }

    /// Finish fully resident: every upper entry must have arrived.  Only
    /// valid for non-spilled sinks — spill-capable callers use
    /// [`finish_storage`](Self::finish_storage).
    pub fn finish(self) -> Result<CondensedMatrix> {
        if self.spilled() {
            return Err(Error::Config(
                "triangle spilled to disk during ingest; finish_storage() is \
                 the only valid completion for a budgeted sink"
                    .to_string(),
            ));
        }
        let want = self.n * self.n.saturating_sub(1) / 2;
        if self.values.len() != want {
            return Err(Error::InvalidInput(format!(
                "matrix ended early: got {} of {want} distances for n = {}",
                self.values.len(),
                self.n
            )));
        }
        CondensedMatrix::from_values(self.n, self.values)
    }

    /// Finish as [`TriangleStorage`]: resident when everything fit the
    /// budget (or no budget was set), file-backed when values spilled.
    pub fn finish_storage(mut self) -> Result<TriangleStorage> {
        if !self.spilled() {
            return Ok(TriangleStorage::Resident(Arc::new(self.finish()?)));
        }
        let want = self.n * self.n.saturating_sub(1) / 2;
        if self.flushed + self.values.len() != want {
            return Err(Error::InvalidInput(format!(
                "matrix ended early: got {} of {want} distances for n = {}",
                self.flushed + self.values.len(),
                self.n
            )));
        }
        self.flush_to_writer()?;
        let budget = self.budget_bytes.unwrap_or(0);
        let file = self.writer.expect("spilled sink has a writer").finish(budget)?;
        Ok(TriangleStorage::FileBacked(Arc::new(file)))
    }
}

/// Read a scikit-bio-style TSV straight into the packed triangle,
/// validating as it streams; returns the triangle and the sample ids.
///
/// Unlike the dense oracle reader (which zero-filled missing trailing
/// rows/columns), a ragged or truncated matrix is an error naming the
/// offending row.
pub fn read_tsv_condensed(
    path: impl AsRef<Path>,
    tol: f32,
) -> Result<(CondensedMatrix, Vec<String>)> {
    let (sink, ids) = read_tsv_sink(path, tol, None)?;
    Ok((sink.finish()?, ids))
}

/// TSV reader with a resident-bytes budget: same streaming loop as
/// [`read_tsv_condensed`], but an over-budget matrix spills to a chunk
/// file and comes back [`TriangleStorage::FileBacked`] instead of ever
/// materializing the full buffer.
pub fn read_tsv_storage(
    path: impl AsRef<Path>,
    tol: f32,
    budget_bytes: u64,
) -> Result<(TriangleStorage, Vec<String>)> {
    let (sink, ids) = read_tsv_sink(path, tol, Some(budget_bytes))?;
    Ok((sink.finish_storage()?, ids))
}

/// The one TSV streaming loop both public readers share: parse, feed the
/// sink, return it unfinished.
fn read_tsv_sink(
    path: impl AsRef<Path>,
    tol: f32,
    budget_bytes: Option<u64>,
) -> Result<(TriangleSink, Vec<String>)> {
    let p = path.as_ref();
    let f = std::fs::File::open(p).map_err(|e| Error::io(p.display().to_string(), e))?;
    let mut lines = BufReader::new(f).lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::parse("dmat-tsv", p.display().to_string(), "empty file"))?
        .map_err(|e| Error::io(p.display().to_string(), e))?;
    let ids: Vec<String> = header.split('\t').skip(1).map(|s| s.to_string()).collect();
    let n = ids.len();
    if n == 0 {
        return Err(Error::parse("dmat-tsv", p.display().to_string(), "no ids in header"));
    }
    let mut sink = match budget_bytes {
        Some(b) => TriangleSink::with_budget(n, tol, b),
        None => TriangleSink::new(n, tol),
    };
    let mut row = 0usize;
    for line in lines {
        let line = line.map_err(|e| Error::io(p.display().to_string(), e))?;
        if line.trim().is_empty() {
            continue;
        }
        if row >= n {
            return Err(Error::parse("dmat-tsv", p.display().to_string(), "too many rows"));
        }
        let mut fields = line.split('\t');
        let rid = fields.next().unwrap_or("");
        if rid != ids[row] {
            return Err(Error::parse(
                "dmat-tsv",
                format!("{} row {row}", p.display()),
                format!("row id {rid:?} != header id {:?}", ids[row]),
            ));
        }
        let mut cols = 0usize;
        for (j, tok) in fields.enumerate() {
            if j >= n {
                return Err(Error::parse(
                    "dmat-tsv",
                    format!("{} row {row}", p.display()),
                    "too many columns",
                ));
            }
            let v: f32 = tok.trim().parse().map_err(|e| {
                Error::parse(
                    "dmat-tsv",
                    format!("{} row {row} col {j}", p.display()),
                    format!("{e}"),
                )
            })?;
            sink.entry(row, j, v)?;
            cols += 1;
        }
        if cols != n {
            return Err(Error::parse(
                "dmat-tsv",
                format!("{} row {row}", p.display()),
                format!("ragged row: {cols} columns, want {n}"),
            ));
        }
        row += 1;
    }
    if row != n {
        return Err(Error::parse(
            "dmat-tsv",
            p.display().to_string(),
            format!("matrix ended early: {row} rows, want {n}"),
        ));
    }
    Ok((sink, ids))
}

/// Read the `PDM1` binary format straight into the packed triangle: one
/// `n*4`-byte row buffer at a time, validated as it streams — the dense
/// `n*n` staging allocation of the oracle reader never exists.
pub fn read_pdm_condensed(path: impl AsRef<Path>, tol: f32) -> Result<CondensedMatrix> {
    read_pdm_sink(path, tol, None)?.finish()
}

/// `PDM1` reader with a resident-bytes budget: over-budget matrices spill
/// to a chunk file and come back [`TriangleStorage::FileBacked`].
pub fn read_pdm_storage(
    path: impl AsRef<Path>,
    tol: f32,
    budget_bytes: u64,
) -> Result<TriangleStorage> {
    read_pdm_sink(path, tol, Some(budget_bytes))?.finish_storage()
}

/// The one `PDM1` streaming loop both public readers share.
fn read_pdm_sink(
    path: impl AsRef<Path>,
    tol: f32,
    budget_bytes: Option<u64>,
) -> Result<TriangleSink> {
    let p = path.as_ref();
    let f = std::fs::File::open(p).map_err(|e| Error::io(p.display().to_string(), e))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)
        .map_err(|e| Error::io(p.display().to_string(), e))?;
    if &magic != PDM_MAGIC {
        return Err(Error::parse("pdm", p.display().to_string(), "bad magic"));
    }
    let mut nb = [0u8; 8];
    r.read_exact(&mut nb)
        .map_err(|e| Error::io(p.display().to_string(), e))?;
    let n = u64::from_le_bytes(nb) as usize;
    if n == 0 || n > 1 << 20 {
        let msg = format!("implausible n = {n}");
        return Err(Error::parse("pdm", p.display().to_string(), msg));
    }
    let mut sink = match budget_bytes {
        Some(b) => TriangleSink::with_budget(n, tol, b),
        None => TriangleSink::new(n, tol),
    };
    let mut rowbuf = vec![0u8; n * 4];
    for i in 0..n {
        r.read_exact(&mut rowbuf).map_err(|e| {
            Error::io(format!("{} row {i}", p.display()), e)
        })?;
        for (j, c) in rowbuf.chunks_exact(4).enumerate() {
            sink.entry(i, j, f32::from_le_bytes([c[0], c[1], c[2], c[3]]))?;
        }
    }
    Ok(sink)
}

/// The random point cloud both synthetic generators share: `n` points in
/// `dim` dimensions, RNG consumed in exactly the order
/// `DistanceMatrix::random_euclidean` established.
fn euclidean_points(n: usize, dim: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256pp::new(seed);
    (0..n * dim)
        .map(|_| {
            let s: f32 = (0..4).map(|_| rng.next_f32()).sum::<f32>() - 2.0;
            s
        })
        .collect()
}

/// The exact per-pair f32 operation sequence of the dense generator.
#[inline]
fn pair_dist(pts: &[f32], dim: usize, i: usize, j: usize) -> f32 {
    let mut acc = 0.0f32;
    for d in 0..dim {
        let diff = pts[i * dim + d] - pts[j * dim + d];
        acc += diff * diff;
    }
    acc.sqrt()
}

/// Euclidean distances between `n` random points in `dim` dimensions,
/// generated straight into the packed triangle.  Consumes the RNG in
/// exactly the order `DistanceMatrix::random_euclidean` does and performs
/// the identical f32 operation sequence per pair, so the result is
/// bit-identical to packing the dense generator's output — without the
/// dense matrix ever existing.
pub fn random_euclidean_condensed(n: usize, dim: usize, seed: u64) -> CondensedMatrix {
    let pts = euclidean_points(n, dim, seed);
    let mut values = Vec::with_capacity(n * n.saturating_sub(1) / 2);
    let mut maxd = 0.0f32;
    for i in 0..n {
        for j in (i + 1)..n {
            let dist = pair_dist(&pts, dim, i, j);
            maxd = maxd.max(dist);
            values.push(dist);
        }
    }
    if maxd > 0.0 {
        for v in values.iter_mut() {
            *v /= maxd;
        }
    }
    CondensedMatrix::from_values(n, values)
        .expect("generator emits exactly n(n-1)/2 distances")
}

/// Budgeted synthetic generator: under-budget triangles stay resident
/// (identical to [`random_euclidean_condensed`]); over-budget triangles
/// stream to a chunk file in **two passes** over the pair loop — pass 1
/// finds the normalization max, pass 2 recomputes each distance and
/// writes `dist / maxd`.  Only the `n·dim` point cloud is ever resident.
/// Both passes run [`pair_dist`]'s exact f32 sequence on the same
/// operands and the final division matches the resident in-place
/// normalization, so the file's values are bit-identical to the resident
/// generator's.
pub fn random_euclidean_storage(
    n: usize,
    dim: usize,
    seed: u64,
    budget_bytes: u64,
) -> Result<TriangleStorage> {
    let packed_bytes = (n * n.saturating_sub(1) / 2 * 4) as u64;
    if budget_bytes == 0 || packed_bytes <= budget_bytes {
        return Ok(TriangleStorage::Resident(Arc::new(random_euclidean_condensed(
            n, dim, seed,
        ))));
    }
    let pts = euclidean_points(n, dim, seed);
    let mut maxd = 0.0f32;
    for i in 0..n {
        for j in (i + 1)..n {
            maxd = maxd.max(pair_dist(&pts, dim, i, j));
        }
    }
    let mut w = TriangleWriter::create(scratch_triangle_path("synth"), n)?;
    for i in 0..n {
        for j in (i + 1)..n {
            let dist = pair_dist(&pts, dim, i, j);
            w.push(if maxd > 0.0 { dist / maxd } else { dist })?;
        }
    }
    Ok(TriangleStorage::FileBacked(Arc::new(w.finish(budget_bytes)?)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dmat::DistanceMatrix;

    #[test]
    fn synthetic_streamed_equals_dense_then_pack_bitwise() {
        for (n, dim, seed) in [(2usize, 4, 7u64), (3, 16, 1), (17, 5, 9), (64, 16, 42)] {
            let dense = DistanceMatrix::random_euclidean(n, dim, seed);
            let oracle = CondensedMatrix::from_dense(&dense);
            let streamed = random_euclidean_condensed(n, dim, seed);
            assert_eq!(streamed.n(), n);
            let a: Vec<u32> = streamed.values().iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = oracle.values().iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "n={n} dim={dim} seed={seed}");
        }
    }

    #[test]
    fn sink_validates_per_entry() {
        let mut s = TriangleSink::new(3, 1e-6);
        s.entry(0, 0, 0.0).unwrap();
        s.entry(0, 1, 1.0).unwrap();
        s.entry(0, 2, 2.0).unwrap();
        s.entry(1, 0, 1.0).unwrap(); // mirror OK
        s.entry(1, 1, 0.0).unwrap();
        s.entry(1, 2, 0.5).unwrap();
        let e = s.entry(2, 0, 9.0).unwrap_err().to_string();
        assert!(e.contains("asymmetry at (0,2)"), "{e}");

        let mut s = TriangleSink::new(3, 1e-6);
        assert!(s.entry(0, 0, 0.25).unwrap_err().to_string().contains("diagonal"));
        assert!(s.entry(0, 1, f32::NAN).unwrap_err().to_string().contains("non-finite"));
        assert!(s.entry(0, 0, f32::NAN).unwrap_err().to_string().contains("non-finite"));
        assert!(s.entry(0, 1, -1.0).unwrap_err().to_string().contains("negative"));
    }

    #[test]
    fn sink_rejects_early_end() {
        let mut s = TriangleSink::new(3, 1e-6);
        s.entry(0, 1, 1.0).unwrap();
        let e = s.finish().unwrap_err().to_string();
        assert!(e.contains("ended early"), "{e}");
    }

    #[test]
    fn tsv_and_pdm_streamed_equal_the_oracles() {
        let dir = std::env::temp_dir().join("permanova_apu_test_ingest");
        std::fs::create_dir_all(&dir).unwrap();
        for n in [2usize, 3, 17, 64] {
            let dense = DistanceMatrix::random_euclidean(n, 6, n as u64);
            let oracle = CondensedMatrix::from_dense(&dense);

            let tsv = dir.join(format!("m{n}.tsv"));
            dense.write_tsv(&tsv, None).unwrap();
            let (streamed, ids) = read_tsv_condensed(&tsv, 1e-6).unwrap();
            assert_eq!(ids.len(), n);
            assert_eq!(streamed.values(), oracle.values(), "tsv n={n}");

            let pdm = dir.join(format!("m{n}.pdm"));
            dense.write_binary(&pdm).unwrap();
            let streamed = read_pdm_condensed(&pdm, 1e-6).unwrap();
            assert_eq!(streamed.values(), oracle.values(), "pdm n={n}");
        }
    }

    #[test]
    fn budgeted_loaders_spill_and_stay_bitwise() {
        let dir = std::env::temp_dir().join("permanova_apu_test_ingest_spill");
        std::fs::create_dir_all(&dir).unwrap();
        let n = 40usize;
        let dense = DistanceMatrix::random_euclidean(n, 6, 77);
        let oracle = CondensedMatrix::from_dense(&dense);
        let want: Vec<u32> = oracle.values().iter().map(|v| v.to_bits()).collect();
        let tiny = 256u64; // far below n(n-1)/2 * 4 = 3120
        let read_back = |s: &TriangleStorage| -> Vec<u32> {
            let f = s.as_file().expect("over-budget source is file-backed");
            f.load_chunk(0, f.n())
                .unwrap()
                .values()
                .iter()
                .map(|v| v.to_bits())
                .collect()
        };

        let tsv = dir.join("spill.tsv");
        dense.write_tsv(&tsv, None).unwrap();
        let (storage, ids) = read_tsv_storage(&tsv, 1e-6, tiny).unwrap();
        assert_eq!(ids.len(), n);
        assert_eq!(read_back(&storage), want, "tsv");

        let pdm = dir.join("spill.pdm");
        dense.write_binary(&pdm).unwrap();
        let storage = read_pdm_storage(&pdm, 1e-6, tiny).unwrap();
        assert_eq!(read_back(&storage), want, "pdm");

        let synth = random_euclidean_storage(n, 6, 77, tiny).unwrap();
        assert_eq!(read_back(&synth), want, "synthetic");
    }

    #[test]
    fn under_budget_loaders_stay_resident() {
        let dir = std::env::temp_dir().join("permanova_apu_test_ingest_spill");
        std::fs::create_dir_all(&dir).unwrap();
        let dense = DistanceMatrix::random_euclidean(12, 4, 5);
        let tsv = dir.join("resident.tsv");
        dense.write_tsv(&tsv, None).unwrap();
        let (storage, _) = read_tsv_storage(&tsv, 1e-6, 1 << 20).unwrap();
        assert!(!storage.is_file_backed());
        let synth = random_euclidean_storage(12, 4, 5, 1 << 20).unwrap();
        assert!(!synth.is_file_backed());
        // Budget 0 means unbounded for the synthetic generator.
        assert!(!random_euclidean_storage(12, 4, 5, 0).unwrap().is_file_backed());
    }

    #[test]
    fn spilled_sink_rejects_plain_finish_and_early_end() {
        let mut s = TriangleSink::with_budget(4, 1e-6, 4); // one value per flush
        s.entry(0, 1, 1.0).unwrap();
        s.entry(0, 2, 2.0).unwrap();
        assert!(s.spilled());
        let e = s.finish().unwrap_err().to_string();
        assert!(e.contains("finish_storage"), "{e}");

        let mut s = TriangleSink::with_budget(4, 1e-6, 4);
        s.entry(0, 1, 1.0).unwrap();
        s.entry(0, 2, 2.0).unwrap();
        let e = s.finish_storage().unwrap_err().to_string();
        assert!(e.contains("ended early"), "{e}");
    }

    #[test]
    fn spill_mirror_check_covers_the_resident_window() {
        // Asymmetry against a still-resident mirror is caught even in
        // spill mode.
        let mut s = TriangleSink::with_budget(3, 1e-6, 1 << 20);
        s.entry(0, 1, 1.0).unwrap();
        s.entry(0, 2, 2.0).unwrap();
        let e = s.entry(1, 0, 9.0).unwrap_err().to_string();
        assert!(e.contains("asymmetry"), "{e}");
    }

    #[test]
    fn ragged_and_empty_tsv_are_errors() {
        let dir = std::env::temp_dir().join("permanova_apu_test_ingest");
        std::fs::create_dir_all(&dir).unwrap();
        let ragged = dir.join("ragged.tsv");
        std::fs::write(&ragged, "\ta\tb\na\t0\t1\nb\t1\n").unwrap();
        let e = read_tsv_condensed(&ragged, 1e-6).unwrap_err().to_string();
        assert!(e.contains("ragged") || e.contains("row"), "{e}");

        let empty = dir.join("empty.tsv");
        std::fs::write(&empty, "").unwrap();
        let e = read_tsv_condensed(&empty, 1e-6).unwrap_err().to_string();
        assert!(e.contains("empty file"), "{e}");

        let short = dir.join("short.tsv");
        std::fs::write(&short, "\ta\tb\ta\t0\t1\n").unwrap();
        let e = read_tsv_condensed(&short, 1e-6).unwrap_err().to_string();
        assert!(e.contains("ended early"), "{e}");
    }
}
