//! Principal Coordinates Analysis (PCoA / classical MDS).
//!
//! The visualization step every PERMANOVA study pairs with its distance
//! matrix (skbio: `pcoa`), and the embedding PERMDISP needs: eigendecompose
//! the Gower-centered matrix
//!
//! ```text
//! B = -1/2 · J D² J,   J = I - 11ᵀ/n
//! ```
//!
//! and scale eigenvectors by √λ.  The eigensolver is a from-scratch cyclic
//! Jacobi rotation (the matrix is symmetric; n here is sample count, ≤ a
//! few thousand, where Jacobi's O(n³) with tiny constants is fine and its
//! unconditional numerical robustness beats a hand-rolled QR).

use super::DistanceMatrix;
use crate::error::{Error, Result};

/// A PCoA embedding.
#[derive(Clone, Debug)]
pub struct Pcoa {
    /// Number of objects.
    pub n: usize,
    /// Retained axes (columns), row-major `n x n_axes`.
    pub coords: Vec<f64>,
    pub n_axes: usize,
    /// Eigenvalues of the retained axes (descending, positive).
    pub eigenvalues: Vec<f64>,
    /// Fraction of total positive inertia explained per axis.
    pub proportion_explained: Vec<f64>,
}

impl Pcoa {
    /// Coordinate of object `i` on `axis`.
    #[inline]
    pub fn coord(&self, i: usize, axis: usize) -> f64 {
        self.coords[i * self.n_axes + axis]
    }

    /// Euclidean distance between objects in the embedding.
    pub fn embedded_distance(&self, i: usize, j: usize) -> f64 {
        (0..self.n_axes)
            .map(|a| {
                let d = self.coord(i, a) - self.coord(j, a);
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix (row-major n×n).
/// Returns (eigenvalues, eigenvectors as columns of a row-major n×n).
pub fn jacobi_eigh(a: &[f64], n: usize, max_sweeps: usize) -> (Vec<f64>, Vec<f64>) {
    let mut m = a.to_vec();
    jacobi_eigh_in_place(&mut m, n, max_sweeps)
}

/// [`jacobi_eigh`] rotating the caller's buffer **in place** (no matrix
/// copy; `a` is destroyed).  This is what [`pcoa`] uses so the whole
/// embedding runs on one n² scratch arena instead of allocating a fresh
/// copy for the solver — the PERMDISP prelude calls this on every dataset
/// load, so the saved n² f64 buffers are real memory on the service path.
pub fn jacobi_eigh_in_place(a: &mut [f64], n: usize, max_sweeps: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), n * n);
    let m = a;
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius norm — convergence test.
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m[p * n + q] * m[p * n + q];
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q.
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let eig: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    (eig, v)
}

/// Run PCoA, retaining at most `max_axes` positive-eigenvalue axes
/// (0 = all positive axes).
///
/// Memory: one n² f64 scratch arena serves D², its Gower-centered
/// transform *and* the eigensolver's working matrix (rotated in place);
/// the only other n² buffer is the eigenvector accumulator.  The seed
/// implementation allocated four separate n² temps (`d2`, `b`, the
/// solver's copy, `v`) per call — and PERMDISP preludes run this on every
/// dataset-cache miss, so the arena halves that path's peak temp memory.
/// The arithmetic per element is unchanged, so results are identical.
pub fn pcoa(mat: &DistanceMatrix, max_axes: usize) -> Result<Pcoa> {
    let n = mat.n();
    if n < 3 {
        return Err(Error::InvalidInput("PCoA needs at least 3 objects".into()));
    }
    // The arena: D² first ...
    let mut b = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let d = mat.get(i, j) as f64;
            b[i * n + j] = d * d;
        }
    }
    let row_means: Vec<f64> = (0..n)
        .map(|i| b[i * n..(i + 1) * n].iter().sum::<f64>() / n as f64)
        .collect();
    let grand = row_means.iter().sum::<f64>() / n as f64;
    // ... then Gower-centered B = -0.5 * J D² J, in place (each element
    // depends only on itself and the precomputed means).
    for i in 0..n {
        for j in 0..n {
            b[i * n + j] = -0.5 * (b[i * n + j] - row_means[i] - row_means[j] + grand);
        }
    }

    let (eig, vecs) = jacobi_eigh_in_place(&mut b, n, 60);
    // Sort axes by descending eigenvalue.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&x, &y| eig[y].partial_cmp(&eig[x]).unwrap());

    let pos_total: f64 = eig.iter().filter(|&&e| e > 0.0).sum();
    let tol = 1e-9 * pos_total.max(1e-30);
    let mut axes: Vec<usize> = order.into_iter().filter(|&i| eig[i] > tol).collect();
    if max_axes > 0 {
        axes.truncate(max_axes);
    }
    if axes.is_empty() {
        return Err(Error::InvalidInput("no positive eigenvalues (degenerate matrix)".into()));
    }

    let n_axes = axes.len();
    let mut coords = vec![0.0f64; n * n_axes];
    let mut eigenvalues = Vec::with_capacity(n_axes);
    let mut proportion = Vec::with_capacity(n_axes);
    for (a, &col) in axes.iter().enumerate() {
        let lambda = eig[col];
        eigenvalues.push(lambda);
        proportion.push(lambda / pos_total);
        let scale = lambda.sqrt();
        for i in 0..n {
            coords[i * n_axes + a] = vecs[i * n + col] * scale;
        }
    }
    Ok(Pcoa { n, coords, n_axes, eigenvalues, proportion_explained: proportion })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jacobi_known_eigensystem() {
        // [[2,1],[1,2]] -> eigenvalues {1, 3}.
        let (eig, vecs) = jacobi_eigh(&[2.0, 1.0, 1.0, 2.0], 2, 50);
        let mut e = eig.clone();
        e.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((e[0] - 1.0).abs() < 1e-10);
        assert!((e[1] - 3.0).abs() < 1e-10);
        // Eigenvector orthonormality.
        let dot = vecs[0] * vecs[1] + vecs[2] * vecs[3];
        assert!(dot.abs() < 1e-10);
    }

    #[test]
    fn in_place_solver_matches_the_copying_wrapper() {
        // Same rotations, same buffer arithmetic: identical outputs.
        let n = 8;
        let mut rng = crate::rng::Xoshiro256pp::new(7);
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in i..n {
                let x = rng.next_f64() - 0.5;
                a[i * n + j] = x;
                a[j * n + i] = x;
            }
        }
        let (eig_w, v_w) = jacobi_eigh(&a, n, 60);
        let mut scratch = a.clone();
        let (eig_p, v_p) = jacobi_eigh_in_place(&mut scratch, n, 60);
        assert_eq!(eig_w, eig_p);
        assert_eq!(v_w, v_p);
        assert_ne!(scratch, a, "in-place solver consumes its input");
    }

    #[test]
    fn jacobi_reconstructs_matrix() {
        // A = V diag(e) V^T for a random symmetric 6x6.
        let n = 6;
        let mut rng = crate::rng::Xoshiro256pp::new(3);
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in i..n {
                let x = rng.next_f64() - 0.5;
                a[i * n + j] = x;
                a[j * n + i] = x;
            }
        }
        let (eig, v) = jacobi_eigh(&a, n, 60);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += v[i * n + k] * eig[k] * v[j * n + k];
                }
                assert!((s - a[i * n + j]).abs() < 1e-8, "({i},{j})");
            }
        }
    }

    #[test]
    fn pcoa_recovers_euclidean_configuration() {
        // Distances from a genuine Euclidean configuration are exactly
        // embeddable: embedded distances == input distances.
        let mat = DistanceMatrix::random_euclidean(20, 3, 5);
        let p = pcoa(&mat, 0).unwrap();
        for i in 0..20 {
            for j in (i + 1)..20 {
                let d_in = mat.get(i, j) as f64;
                let d_emb = p.embedded_distance(i, j);
                assert!(
                    (d_in - d_emb).abs() < 1e-5,
                    "({i},{j}): {d_in} vs {d_emb}"
                );
            }
        }
        // 3-D points -> ~3 meaningful axes carry ~all inertia.
        let top3: f64 = p.proportion_explained.iter().take(3).sum();
        assert!(top3 > 0.999, "{:?}", p.proportion_explained);
    }

    #[test]
    fn pcoa_axes_ordered_and_normalized() {
        let mat = DistanceMatrix::random_euclidean(15, 6, 9);
        let p = pcoa(&mat, 4).unwrap();
        assert_eq!(p.n_axes, 4);
        for w in p.eigenvalues.windows(2) {
            assert!(w[0] >= w[1], "descending eigenvalues");
        }
        assert!(p.proportion_explained.iter().all(|&x| (0.0..=1.0).contains(&x)));
        // Axis coordinates are centered.
        for a in 0..p.n_axes {
            let mean: f64 = (0..p.n).map(|i| p.coord(i, a)).sum::<f64>() / p.n as f64;
            assert!(mean.abs() < 1e-8);
        }
    }

    #[test]
    fn pcoa_separates_planted_blocks() {
        let mat = DistanceMatrix::planted_blocks(24, 2, 0.1, 1.0, 3);
        let p = pcoa(&mat, 2).unwrap();
        // Axis 0 should separate the two groups almost perfectly.
        let mean0: f64 = (0..24).filter(|i| i % 2 == 0).map(|i| p.coord(i, 0)).sum::<f64>() / 12.0;
        let mean1: f64 = (0..24).filter(|i| i % 2 == 1).map(|i| p.coord(i, 0)).sum::<f64>() / 12.0;
        assert!((mean0 - mean1).abs() > 0.5, "axis 0 group means: {mean0} vs {mean1}");
    }

    #[test]
    fn rejects_tiny() {
        assert!(pcoa(&DistanceMatrix::zeros(3), 0).is_err(), "all-zero: no positive eigs");
    }
}
