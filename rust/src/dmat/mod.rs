//! Distance matrices: storage, validation, generation, IO.
//!
//! The paper's workload is a 25145² float32 UniFrac distance matrix.  This
//! module owns the square row-major [`DistanceMatrix`] — the I/O and PCoA
//! boundary representation — plus:
//!
//! * the packed upper-triangle [`CondensedMatrix`] / [`CondensedView`]
//!   ([`condensed`]), the **canonical kernel operand**: every permutation
//!   kernel sweeps the packed rows, at half the dense footprint;
//! * the out-of-core tier ([`chunked`]): [`TriangleStorage`] routes the
//!   triangle either to the resident buffer or to a checksummed chunk
//!   file paged under `--max-resident-bytes`, so `n` can exceed RAM;
//! * validation of the PERMANOVA input contract (square, symmetric, zero
//!   diagonal, non-negative, finite);
//! * conversion to/from *condensed* form (the upper-triangle vector scipy
//!   and scikit-bio use on the wire);
//! * a compact binary format (`.pdm`) and a TSV reader/writer for interop;
//! * synthetic generators used by tests, examples and benches;
//! * Principal Coordinates Analysis ([`pcoa`]) — the embedding step the
//!   PERMANOVA workflow pairs with its distance matrices.

pub mod chunked;
pub mod condensed;
pub mod ingest;
pub mod pcoa;

pub use chunked::{
    file_backed_from, scratch_triangle_path, FileTriangle, RebuildFn, TriangleChunk,
    TriangleStorage, TriangleWriter, TRC_BLOCK_VALUES, TRC_MAGIC,
};
pub use condensed::{CondensedMatrix, CondensedView};
pub use ingest::{
    random_euclidean_condensed, random_euclidean_storage, read_pdm_condensed,
    read_pdm_storage, read_tsv_condensed, read_tsv_storage, TriangleSink,
};
pub use pcoa::{jacobi_eigh, jacobi_eigh_in_place, pcoa, Pcoa};

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::rng::Xoshiro256pp;

/// Magic bytes of the binary distance-matrix format.
pub const PDM_MAGIC: &[u8; 4] = b"PDM1";

/// A square, row-major `f32` distance matrix.
///
/// Invariants (enforced by [`DistanceMatrix::validate`], relied on by the
/// kernels): `data.len() == n*n`, symmetric, zero diagonal, entries finite
/// and non-negative.
#[derive(Clone, Debug, PartialEq)]
pub struct DistanceMatrix {
    n: usize,
    data: Vec<f32>,
}

impl DistanceMatrix {
    /// An all-zero n×n matrix (valid: the trivial pseudo-metric).
    pub fn zeros(n: usize) -> Self {
        DistanceMatrix { n, data: vec![0.0; n * n] }
    }

    /// Wrap a row-major buffer; checks only the length (call
    /// [`validate`](Self::validate) for the full contract).
    pub fn from_vec(n: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != n * n {
            return Err(Error::InvalidInput(format!(
                "distance matrix buffer has {} entries, want {}x{}={}",
                data.len(),
                n,
                n,
                n * n
            )));
        }
        Ok(DistanceMatrix { n, data })
    }

    /// Build from a condensed upper-triangle vector (scipy `pdist` layout:
    /// d(0,1), d(0,2), ..., d(0,n-1), d(1,2), ...), mirroring into both
    /// triangles.
    pub fn from_condensed(n: usize, condensed: &[f32]) -> Result<Self> {
        let want = n * (n - 1) / 2;
        if condensed.len() != want {
            return Err(Error::InvalidInput(format!(
                "condensed vector has {} entries, want n(n-1)/2 = {want} for n = {n}",
                condensed.len()
            )));
        }
        let mut m = Self::zeros(n);
        let mut idx = 0;
        for i in 0..n {
            for j in (i + 1)..n {
                let d = condensed[idx];
                idx += 1;
                m.data[i * n + j] = d;
                m.data[j * n + i] = d;
            }
        }
        Ok(m)
    }

    /// The condensed upper-triangle vector (allocates `n(n-1)/2`).
    pub fn to_condensed(&self) -> Vec<f32> {
        let n = self.n;
        let mut out = Vec::with_capacity(n * (n - 1) / 2);
        for i in 0..n {
            out.extend_from_slice(&self.data[i * n + i + 1..(i + 1) * n]);
        }
        out
    }

    /// Number of objects (matrix edge).
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Row-major backing slice (length n²).
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major backing slice (length n²).  Callers are trusted to
    /// preserve the symmetry/zero-diagonal contract (or to call
    /// [`validate`](Self::validate) / [`symmetrize`](Self::symmetrize)
    /// afterwards).
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    /// Entry (i, j).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.n + j]
    }

    /// Set entry (i, j) AND its mirror (j, i).
    pub fn set_sym(&mut self, i: usize, j: usize, d: f32) {
        self.data[i * self.n + j] = d;
        self.data[j * self.n + i] = d;
    }

    /// Enforce the PERMANOVA input contract.
    ///
    /// `tol` is the absolute symmetry/diagonal tolerance (float32 UniFrac
    /// pipelines commonly carry ~1e-6 asymmetry from reduction order).
    pub fn validate(&self, tol: f32) -> Result<()> {
        let n = self.n;
        if n < 3 {
            return Err(Error::InvalidInput(format!(
                "need at least 3 objects for PERMANOVA, got {n}"
            )));
        }
        for i in 0..n {
            let dii = self.get(i, i);
            if dii.abs() > tol {
                return Err(Error::InvalidInput(format!(
                    "diagonal entry ({i},{i}) = {dii}, want 0"
                )));
            }
            for j in (i + 1)..n {
                let a = self.get(i, j);
                let b = self.get(j, i);
                if !a.is_finite() || !b.is_finite() {
                    return Err(Error::InvalidInput(format!(
                        "non-finite distance at ({i},{j})"
                    )));
                }
                if a < 0.0 || b < 0.0 {
                    return Err(Error::InvalidInput(format!(
                        "negative distance at ({i},{j}): {a}"
                    )));
                }
                if (a - b).abs() > tol {
                    return Err(Error::InvalidInput(format!(
                        "asymmetry at ({i},{j}): {a} vs {b} (tol {tol})"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Exactly symmetrize (average the two triangles) and zero the diagonal.
    pub fn symmetrize(&mut self) {
        let n = self.n;
        for i in 0..n {
            self.data[i * n + i] = 0.0;
            for j in (i + 1)..n {
                let avg = 0.5 * (self.data[i * n + j] + self.data[j * n + i]);
                self.data[i * n + j] = avg;
                self.data[j * n + i] = avg;
            }
        }
    }

    // ------------------------------------------------------------------
    // Generators
    // ------------------------------------------------------------------

    /// Euclidean distances between `n` random points in `dim` dimensions —
    /// a genuine metric, scaled so the max distance is ~1.
    pub fn random_euclidean(n: usize, dim: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::new(seed);
        let pts: Vec<f32> = (0..n * dim)
            .map(|_| {
                // Box-Muller-free approximate normal: sum of 4 uniforms.
                let s: f32 = (0..4).map(|_| rng.next_f32()).sum::<f32>() - 2.0;
                s
            })
            .collect();
        let mut m = Self::zeros(n);
        let mut maxd = 0.0f32;
        for i in 0..n {
            for j in (i + 1)..n {
                let mut acc = 0.0f32;
                for d in 0..dim {
                    let diff = pts[i * dim + d] - pts[j * dim + d];
                    acc += diff * diff;
                }
                let dist = acc.sqrt();
                maxd = maxd.max(dist);
                m.set_sym(i, j, dist);
            }
        }
        if maxd > 0.0 {
            for v in m.data.iter_mut() {
                *v /= maxd;
            }
        }
        m
    }

    /// A matrix with planted group structure: distances ~`within` inside
    /// each of `k` equal blocks, ~`between` across blocks (plus jitter).
    /// Used to test that PERMANOVA detects real effects.
    pub fn planted_blocks(n: usize, k: usize, within: f32, between: f32, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::new(seed);
        let mut m = Self::zeros(n);
        for i in 0..n {
            for j in (i + 1)..n {
                let same = (i % k) == (j % k);
                let base = if same { within } else { between };
                let jitter = 0.05 * base * (rng.next_f32() - 0.5);
                m.set_sym(i, j, (base + jitter).max(0.0));
            }
        }
        m
    }

    // ------------------------------------------------------------------
    // IO
    // ------------------------------------------------------------------

    /// Write the compact binary format: `PDM1 | n: u64 LE | n*n f32 LE`.
    pub fn write_binary(&self, path: impl AsRef<Path>) -> Result<()> {
        let p = path.as_ref();
        let f = std::fs::File::create(p).map_err(|e| Error::io(p.display().to_string(), e))?;
        let mut w = BufWriter::new(f);
        let mut run = || -> std::io::Result<()> {
            w.write_all(PDM_MAGIC)?;
            w.write_all(&(self.n as u64).to_le_bytes())?;
            for &v in &self.data {
                w.write_all(&v.to_le_bytes())?;
            }
            w.flush()
        };
        run().map_err(|e| Error::io(p.display().to_string(), e))
    }

    /// Read the binary format written by [`write_binary`](Self::write_binary).
    pub fn read_binary(path: impl AsRef<Path>) -> Result<Self> {
        let p = path.as_ref();
        let f = std::fs::File::open(p).map_err(|e| Error::io(p.display().to_string(), e))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)
            .map_err(|e| Error::io(p.display().to_string(), e))?;
        if &magic != PDM_MAGIC {
            return Err(Error::parse("pdm", p.display().to_string(), "bad magic"));
        }
        let mut nb = [0u8; 8];
        r.read_exact(&mut nb)
            .map_err(|e| Error::io(p.display().to_string(), e))?;
        let n = u64::from_le_bytes(nb) as usize;
        if n == 0 || n > 1 << 20 {
            let msg = format!("implausible n = {n}");
            return Err(Error::parse("pdm", p.display().to_string(), msg));
        }
        let mut bytes = vec![0u8; n * n * 4];
        r.read_exact(&mut bytes)
            .map_err(|e| Error::io(p.display().to_string(), e))?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Self::from_vec(n, data)
    }

    /// Write a scikit-bio-style TSV: header row of ids, then `id\td...`.
    pub fn write_tsv(&self, path: impl AsRef<Path>, ids: Option<&[String]>) -> Result<()> {
        let p = path.as_ref();
        let f = std::fs::File::create(p).map_err(|e| Error::io(p.display().to_string(), e))?;
        let mut w = BufWriter::new(f);
        let own_ids: Vec<String>;
        let ids = match ids {
            Some(ids) => ids,
            None => {
                own_ids = (0..self.n).map(|i| format!("s{i}")).collect();
                &own_ids
            }
        };
        let mut run = || -> std::io::Result<()> {
            for id in ids {
                write!(w, "\t{id}")?;
            }
            writeln!(w)?;
            for i in 0..self.n {
                write!(w, "{}", ids[i])?;
                for j in 0..self.n {
                    write!(w, "\t{}", self.get(i, j))?;
                }
                writeln!(w)?;
            }
            w.flush()
        };
        run().map_err(|e| Error::io(p.display().to_string(), e))
    }

    /// Read the TSV format written by [`write_tsv`](Self::write_tsv);
    /// returns the matrix and the sample ids.
    pub fn read_tsv(path: impl AsRef<Path>) -> Result<(Self, Vec<String>)> {
        let p = path.as_ref();
        let f = std::fs::File::open(p).map_err(|e| Error::io(p.display().to_string(), e))?;
        let mut lines = BufReader::new(f).lines();
        let header = lines
            .next()
            .ok_or_else(|| Error::parse("dmat-tsv", p.display().to_string(), "empty file"))?
            .map_err(|e| Error::io(p.display().to_string(), e))?;
        let ids: Vec<String> = header
            .split('\t')
            .skip(1)
            .map(|s| s.to_string())
            .collect();
        let n = ids.len();
        if n == 0 {
            return Err(Error::parse("dmat-tsv", p.display().to_string(), "no ids in header"));
        }
        let mut m = Self::zeros(n);
        for (i, line) in lines.enumerate() {
            let line = line.map_err(|e| Error::io(p.display().to_string(), e))?;
            if line.trim().is_empty() {
                continue;
            }
            if i >= n {
                return Err(Error::parse("dmat-tsv", p.display().to_string(), "too many rows"));
            }
            let mut fields = line.split('\t');
            let rid = fields.next().unwrap_or("");
            if rid != ids[i] {
                return Err(Error::parse(
                    "dmat-tsv",
                    format!("{} row {i}", p.display()),
                    format!("row id {rid:?} != header id {:?}", ids[i]),
                ));
            }
            for (j, tok) in fields.enumerate() {
                if j >= n {
                    return Err(Error::parse(
                        "dmat-tsv",
                        format!("{} row {i}", p.display()),
                        "too many columns",
                    ));
                }
                let v: f32 = tok.trim().parse().map_err(|e| {
                    Error::parse(
                        "dmat-tsv",
                        format!("{} row {i} col {j}", p.display()),
                        format!("{e}"),
                    )
                })?;
                m.data[i * n + j] = v;
            }
        }
        Ok((m, ids))
    }

    /// Bytes of the dense representation (the traffic unit the simulator
    /// reasons about).
    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// Test support: write an asymmetric 12-object `.pdm` (entry (0,1) ≠
/// (1,0) by 0.25 — beyond any sane tolerance) plus a matching 2-group
/// labels file under `dir`, returning `(matrix_path, labels_path)`.
/// Shared by the load-path validation tests in `coordinator`,
/// `service::cache` and `cli`.
#[cfg(test)]
pub(crate) fn write_asymmetric_pdm_fixture(dir: &std::path::Path) -> (String, String) {
    std::fs::create_dir_all(dir).unwrap();
    let mpath = dir.join("asym.pdm");
    let lpath = dir.join("labels.txt");
    let mut mat = DistanceMatrix::random_euclidean(12, 4, 3);
    mat.data_mut()[1] += 0.25; // (0,1) != (1,0)
    mat.write_binary(&mpath).unwrap();
    let labels: Vec<String> = (0..12).map(|i| format!("g{}", i % 2)).collect();
    std::fs::write(&lpath, labels.join("\n")).unwrap();
    (mpath.display().to_string(), lpath.display().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DistanceMatrix {
        let mut m = DistanceMatrix::zeros(4);
        m.set_sym(0, 1, 1.0);
        m.set_sym(0, 2, 2.0);
        m.set_sym(0, 3, 3.0);
        m.set_sym(1, 2, 1.5);
        m.set_sym(1, 3, 2.5);
        m.set_sym(2, 3, 0.5);
        m
    }

    #[test]
    fn from_vec_checks_len() {
        assert!(DistanceMatrix::from_vec(3, vec![0.0; 9]).is_ok());
        assert!(DistanceMatrix::from_vec(3, vec![0.0; 8]).is_err());
    }

    #[test]
    fn condensed_roundtrip() {
        let m = small();
        let c = m.to_condensed();
        assert_eq!(c.len(), 6);
        assert_eq!(c, vec![1.0, 2.0, 3.0, 1.5, 2.5, 0.5]);
        let m2 = DistanceMatrix::from_condensed(4, &c).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn condensed_wrong_len_rejected() {
        assert!(DistanceMatrix::from_condensed(4, &[0.0; 5]).is_err());
    }

    #[test]
    fn validate_accepts_good_matrix() {
        small().validate(1e-6).unwrap();
        DistanceMatrix::random_euclidean(20, 4, 1).validate(1e-5).unwrap();
    }

    #[test]
    fn validate_rejects_asymmetry_diag_negative_nan() {
        let mut m = small();
        m.data[1] = 9.0; // (0,1) != (1,0)
        assert!(m.validate(1e-6).is_err());

        let mut m = small();
        m.data[0] = 0.5; // diagonal
        assert!(m.validate(1e-6).is_err());

        let mut m = small();
        m.set_sym(0, 1, -1.0);
        assert!(m.validate(1e-6).is_err());

        let mut m = small();
        m.set_sym(0, 1, f32::NAN);
        assert!(m.validate(1e-6).is_err());

        assert!(DistanceMatrix::zeros(2).validate(1e-6).is_err(), "n < 3");
    }

    #[test]
    fn symmetrize_fixes_matrix() {
        let mut m = small();
        m.data[1] = 2.0; // (0,1) = 2, (1,0) = 1
        m.data[0] = 7.0; // diag
        m.symmetrize();
        m.validate(1e-6).unwrap();
        assert_eq!(m.get(0, 1), 1.5);
    }

    #[test]
    fn euclidean_is_metric_scaled() {
        let m = DistanceMatrix::random_euclidean(30, 8, 9);
        m.validate(1e-5).unwrap();
        let mx = m.data().iter().cloned().fold(0.0f32, f32::max);
        assert!((mx - 1.0).abs() < 1e-5);
        // Triangle inequality spot-check.
        for (i, j, k) in [(0, 1, 2), (3, 7, 11), (5, 20, 29)] {
            assert!(m.get(i, j) <= m.get(i, k) + m.get(k, j) + 1e-5);
        }
    }

    #[test]
    fn planted_blocks_have_structure() {
        let m = DistanceMatrix::planted_blocks(24, 3, 0.2, 1.0, 4);
        m.validate(1e-6).unwrap();
        assert!(m.get(0, 3) < 0.5, "same block (0,3 both ≡ 0 mod 3)");
        assert!(m.get(0, 1) > 0.5, "cross block");
    }

    #[test]
    fn binary_roundtrip() {
        let dir = std::env::temp_dir().join("permanova_apu_test_dmat");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.pdm");
        let m = DistanceMatrix::random_euclidean(17, 5, 3);
        m.write_binary(&p).unwrap();
        let m2 = DistanceMatrix::read_binary(&p).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn binary_bad_magic_rejected() {
        let dir = std::env::temp_dir().join("permanova_apu_test_dmat");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.pdm");
        std::fs::write(&p, b"NOPE\x00\x00\x00\x00\x00\x00\x00\x00").unwrap();
        assert!(DistanceMatrix::read_binary(&p).is_err());
    }

    #[test]
    fn tsv_roundtrip_with_ids() {
        let dir = std::env::temp_dir().join("permanova_apu_test_dmat");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.tsv");
        let m = small();
        let ids: Vec<String> = ["a", "b", "c", "d"].iter().map(|s| s.to_string()).collect();
        m.write_tsv(&p, Some(&ids)).unwrap();
        let (m2, ids2) = DistanceMatrix::read_tsv(&p).unwrap();
        assert_eq!(ids2, ids);
        for i in 0..4 {
            for j in 0..4 {
                assert!((m.get(i, j) - m2.get(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn nbytes_matches() {
        assert_eq!(small().nbytes(), 4 * 4 * 4);
    }
}
