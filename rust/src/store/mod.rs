//! The durable result store: a zero-dependency, crash-safe LSM cache
//! that lets warm state survive process restarts.
//!
//! The paper's workload is memory-bound end to end, and PR 4 identified
//! repeated analyses over shared datasets as the dominant service shape —
//! yet until this subsystem, every daemon restart re-paid the cold
//! memory-bound cost of each of them.  This module is the fix, stacked
//! in four layers:
//!
//! * [`Wal`] — append-only, length-prefixed + checksummed log; fsynced
//!   per put, truncated-tail tolerant on replay;
//! * [`MemTable`] — the sorted in-memory write buffer;
//! * [`SsTable`] — immutable sorted tables with a resident,
//!   binary-searchable key block, written via fsync + atomic rename;
//! * [`Lsm`] — the tree: flush on threshold, size-tiered compaction at
//!   [`MAX_TABLES`], whole-oldest-table eviction over the byte budget.
//!
//! [`ResultStore`] is the thread-safe facade the service layer holds: a
//! `key -> serialized AnalysisReport` cache whose **value is the exact
//! JSON the engine serialized** ([`crate::report`] serialization is
//! deterministic — sorted keys, shortest-roundtrip floats), so a store
//! hit returns the stored bytes verbatim.  The key
//! ([`crate::service::result_key`]) spans `dataset key × method × seed ×
//! perms × tol` and deliberately **excludes** the backend and scheduler
//! knobs: engine results are backend/shard/SMT-invariant (the
//! conformance suites pin this bitwise), so one backend's computation
//! answers every backend's request.
//!
//! [`SpillDir`] rides along: LRU-evicted packed triangles park on disk
//! and reload through the normal [`TriangleSink`](crate::dmat::TriangleSink)
//! validation instead of being re-streamed from their source.
//!
//! [`MAX_TABLES`]: lsm::MAX_TABLES

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::jsonio::Json;

mod lsm;
mod mem_table;
mod spill;
mod ss_table;
mod wal;

pub use lsm::{Lsm, LsmConfig, LsmStats, DEFAULT_FLUSH_BYTES, MAX_TABLES};
pub use mem_table::MemTable;
pub use spill::{SpillDir, SpillStats, SPILL_MAGIC};
pub use ss_table::{SsTable, SST_MAGIC};
pub use wal::Wal;

/// FNV-1a 64 offset basis — the running-hash start value for
/// [`fnv64_fold`].
pub const FNV64_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `bytes` into a running FNV-1a 64 hash.  Streaming producers (the
/// out-of-core chunk writer hashes values as they arrive, block by block)
/// carry `h` across calls; `fnv64_bytes` is the whole-buffer edition.
pub fn fnv64_fold(mut h: u64, bytes: &[u8]) -> u64 {
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a 64 over raw bytes — the checksum/filename hash every layer of
/// the store shares (the string edition lives in the service cache).
pub fn fnv64_bytes(bytes: &[u8]) -> u64 {
    fnv64_fold(FNV64_OFFSET, bytes)
}

/// Default on-disk budget for the result tables: generous for serialized
/// reports (a few KiB each) while bounding a long-lived daemon's disk
/// growth.
pub const DEFAULT_STORE_CAPACITY_BYTES: u64 = 256 << 20;

/// Where and how big — the knobs `--store-dir` / `--store-capacity-bytes`
/// (and the `[store]` config section) resolve to.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// Root directory: holds `wal.log`, `sst-*.sst` and `spill/`.
    pub dir: PathBuf,
    /// On-disk byte budget for the result tables (0 = unbounded).
    pub capacity_bytes: u64,
    /// Memtable flush threshold.
    pub flush_bytes: usize,
}

impl StoreConfig {
    /// Defaults for `dir`: the standard capacity + flush threshold.
    pub fn new(dir: impl Into<PathBuf>) -> StoreConfig {
        StoreConfig {
            dir: dir.into(),
            capacity_bytes: DEFAULT_STORE_CAPACITY_BYTES,
            flush_bytes: DEFAULT_FLUSH_BYTES,
        }
    }
}

/// A point-in-time snapshot of store effectiveness, surfaced by the
/// daemon `stats` op and the bench restart-warm axis.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Lookups answered from the store (no engine execution).
    pub hits: u64,
    /// Lookups that fell through to the engine.
    pub misses: u64,
    /// Results written.
    pub puts: u64,
    /// Immutable sorted tables on disk.
    pub segments: u64,
    /// Full-merge compactions this process lifetime.
    pub compactions: u64,
    /// Tables dropped (capacity evictions + corrupt sweeps).
    pub evicted_segments: u64,
    /// Entries buffered in the memtable.
    pub mem_entries: u64,
    /// Result bytes on disk (tables + WAL).
    pub disk_bytes: u64,
    /// Live WAL bytes (replay cost of a crash right now).
    pub wal_bytes: u64,
    /// Write attempts that failed with an IO error (see `degraded`).
    pub put_errors: u64,
    /// Whether repeated write failures flipped the store read-only:
    /// analyses keep running and `get` keeps serving, but nothing new
    /// persists until the process restarts against a healthy disk.
    pub degraded: bool,
    /// Spill-segment activity.
    pub spill: SpillStats,
}

/// Consecutive `put` failures before the store flips itself read-only.
/// One transient error is retried forever by later puts; a disk that
/// fails this many *in a row* is treated as gone for the rest of the
/// process lifetime.
pub const DEGRADE_AFTER: u64 = 3;

/// Advisory single-writer lock on a store directory: a `LOCK` file
/// holding the owner's pid, created with `create_new` (an atomic
/// exists-check + create on every platform).  Dropping the guard removes
/// the file.
#[derive(Debug)]
struct StoreLock {
    path: PathBuf,
}

impl StoreLock {
    /// Acquire the lock under `dir`, reclaiming a stale file left by a
    /// crashed holder (the WAL already makes crashes safe for *data*; the
    /// lock only has to keep two *live* writers apart).
    fn acquire(dir: &Path) -> Result<StoreLock> {
        std::fs::create_dir_all(dir)
            .map_err(|e| Error::io(dir.display().to_string(), e))?;
        let path = dir.join("LOCK");
        // One reclaim retry: a remove/create race with another starter
        // must not spin, and losing that race is a correct conflict.
        for attempt in 0..2 {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = writeln!(f, "{}", std::process::id());
                    let _ = f.sync_all();
                    return Ok(StoreLock { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let holder = std::fs::read_to_string(&path).unwrap_or_default();
                    let holder = holder.trim().to_string();
                    if attempt == 0 && lock_is_stale(&holder) {
                        let _ = std::fs::remove_file(&path);
                        continue;
                    }
                    return Err(Error::Config(format!(
                        "result store {} is already open by pid {holder} \
                         (lock file {}): a store directory has exactly one \
                         writer — stop the other process or point this one \
                         at a different --store-dir",
                        dir.display(),
                        path.display()
                    )));
                }
                Err(e) => return Err(Error::io(path.display().to_string(), e)),
            }
        }
        unreachable!("second attempt either locks or conflicts")
    }
}

/// A lock is stale when its recorded holder is provably dead: an
/// unparseable pid (torn write) or, where `/proc` exists, a pid with no
/// live process.  A live pid — including our own, which means this
/// process already opened the store — keeps the lock.
fn lock_is_stale(holder: &str) -> bool {
    match holder.parse::<u32>() {
        Err(_) => true,
        Ok(pid) => {
            pid != std::process::id()
                && Path::new("/proc").exists()
                && !Path::new(&format!("/proc/{pid}")).exists()
        }
    }
}

impl Drop for StoreLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Thread-safe facade over one [`Lsm`] tree + its [`SpillDir`] — the
/// handle [`DatasetCache`](crate::service::DatasetCache) carries and
/// every job executor consults.
#[derive(Debug)]
pub struct ResultStore {
    lsm: Mutex<Lsm>,
    spill: SpillDir,
    /// Single-writer guard: taken by `drain()` (graceful shutdown) so a
    /// successor can open the directory immediately; otherwise released
    /// on drop.
    lock: Mutex<Option<StoreLock>>,
    hits: AtomicU64,
    misses: AtomicU64,
    puts: AtomicU64,
    put_errors: AtomicU64,
    /// Consecutive put failures; reset by any success.
    put_fail_streak: AtomicU64,
    /// Latched by [`DEGRADE_AFTER`] consecutive put failures; never
    /// unlatched — a half-dead disk must not flap the store.
    degraded: AtomicBool,
}

impl ResultStore {
    /// Open (creating/replaying as needed) the store under `cfg.dir`.
    /// Fails with a typed [`Error::Config`] naming the holder when
    /// another live process already has the directory open.
    pub fn open(cfg: StoreConfig) -> Result<ResultStore> {
        let lock = StoreLock::acquire(&cfg.dir)?;
        let spill = SpillDir::open(cfg.dir.join("spill"))?;
        let lsm = Lsm::open(LsmConfig {
            dir: cfg.dir,
            capacity_bytes: cfg.capacity_bytes,
            flush_bytes: cfg.flush_bytes,
        })?;
        Ok(ResultStore {
            lsm: Mutex::new(lsm),
            spill,
            lock: Mutex::new(Some(lock)),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            puts: AtomicU64::new(0),
            put_errors: AtomicU64::new(0),
            put_fail_streak: AtomicU64::new(0),
            degraded: AtomicBool::new(false),
        })
    }

    /// The stored serialized report for `key`, if any.  IO trouble
    /// degrades to a miss — a flaky disk may cost recomputes, never an
    /// analysis failure.
    pub fn get(&self, key: &str) -> Option<Vec<u8>> {
        let got = self.lsm.lock().unwrap().get(key);
        match got {
            Ok(Some(v)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            Ok(None) | Err(_) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Durably record `key -> value` (WAL-fsynced before return).
    ///
    /// Failure containment: each IO failure is counted and returned to
    /// the caller (who treats persistence as best-effort), and
    /// [`DEGRADE_AFTER`] *consecutive* failures latch the store into a
    /// loud read-only `degraded` mode — later puts become no-ops instead
    /// of hammering a dead disk, while `get` keeps serving what already
    /// persisted.
    pub fn put(&self, key: &str, value: &[u8]) -> Result<()> {
        if self.degraded.load(Ordering::Relaxed) {
            return Ok(());
        }
        self.puts.fetch_add(1, Ordering::Relaxed);
        match self.lsm.lock().unwrap().put(key, value) {
            Ok(()) => {
                self.put_fail_streak.store(0, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => {
                self.put_errors.fetch_add(1, Ordering::Relaxed);
                let streak = self.put_fail_streak.fetch_add(1, Ordering::Relaxed) + 1;
                if streak >= DEGRADE_AFTER && !self.degraded.swap(true, Ordering::SeqCst)
                {
                    eprintln!(
                        "result store degraded to read-only after {streak} consecutive \
                         write failures (last: {e}); analyses continue, new results \
                         stop persisting until restart"
                    );
                }
                Err(e)
            }
        }
    }

    /// Whether repeated write failures latched the store read-only.
    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Graceful-shutdown hook: flush the memtable to a sorted table so
    /// the next boot replays nothing, and release the single-writer lock
    /// so a successor process can open the directory immediately.
    pub fn drain(&self) -> Result<()> {
        self.lsm.lock().unwrap().drain()?;
        self.lock.lock().unwrap().take();
        Ok(())
    }

    /// The spill directory for evicted packed triangles.
    pub fn spill_dir(&self) -> &SpillDir {
        &self.spill
    }

    /// Current counters.
    pub fn stats(&self) -> StoreStats {
        let inner = self.lsm.lock().unwrap().stats();
        StoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            puts: self.puts.load(Ordering::Relaxed),
            segments: inner.segments as u64,
            compactions: inner.compactions,
            evicted_segments: inner.evicted_segments,
            mem_entries: inner.mem_entries as u64,
            disk_bytes: inner.disk_bytes,
            wal_bytes: inner.wal_bytes,
            put_errors: self.put_errors.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
            spill: self.spill.stats(),
        }
    }

    /// The `stats` snapshot as JSON — the daemon `stats` op's `store`
    /// section.
    pub fn stats_json(&self) -> Json {
        let s = self.stats();
        Json::obj(vec![
            ("hits", Json::num(s.hits as f64)),
            ("misses", Json::num(s.misses as f64)),
            ("puts", Json::num(s.puts as f64)),
            ("segments", Json::num(s.segments as f64)),
            ("compactions", Json::num(s.compactions as f64)),
            ("evicted_segments", Json::num(s.evicted_segments as f64)),
            ("mem_entries", Json::num(s.mem_entries as f64)),
            ("disk_bytes", Json::num(s.disk_bytes as f64)),
            ("wal_bytes", Json::num(s.wal_bytes as f64)),
            ("put_errors", Json::num(s.put_errors as f64)),
            ("degraded", Json::Bool(s.degraded)),
            (
                "spill",
                Json::obj(vec![
                    ("spilled", Json::num(s.spill.spilled as f64)),
                    ("reloaded", Json::num(s.spill.reloaded as f64)),
                    ("segments", Json::num(s.spill.segments as f64)),
                    ("disk_bytes", Json::num(s.spill.disk_bytes as f64)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(case: &str) -> StoreConfig {
        let dir =
            std::env::temp_dir().join(format!("permanova_apu_store_facade_test_{case}"));
        let _ = std::fs::remove_dir_all(&dir);
        StoreConfig::new(dir)
    }

    #[test]
    fn get_put_counters_and_restart() {
        let cfg = tmp_store("counters");
        let store = ResultStore::open(cfg.clone()).unwrap();
        assert!(store.get("k").is_none());
        store.put("k", br#"{"f_obs":1.5}"#).unwrap();
        assert_eq!(store.get("k"), Some(br#"{"f_obs":1.5}"#.to_vec()));
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.puts), (1, 1, 1));
        assert!(s.wal_bytes > 0, "unflushed puts live in the WAL: {s:?}");
        drop(store);
        // Same dir, fresh process: the WAL replays the entry back.
        let store = ResultStore::open(cfg).unwrap();
        assert_eq!(store.get("k"), Some(br#"{"f_obs":1.5}"#.to_vec()));
        assert_eq!(store.stats().mem_entries, 1);
    }

    #[test]
    fn drain_flushes_to_a_segment() {
        let cfg = tmp_store("drain");
        let store = ResultStore::open(cfg.clone()).unwrap();
        store.put("k", b"v").unwrap();
        store.drain().unwrap();
        let s = store.stats();
        assert_eq!((s.segments, s.wal_bytes, s.mem_entries), (1, 0, 0), "{s:?}");
        drop(store);
        let store = ResultStore::open(cfg).unwrap();
        assert_eq!(store.get("k"), Some(b"v".to_vec()), "served from the table");
    }

    #[test]
    fn stats_json_shape() {
        let cfg = tmp_store("json");
        let store = ResultStore::open(cfg).unwrap();
        store.put("k", b"v").unwrap();
        store.get("k");
        let j = store.stats_json();
        for field in
            ["hits", "misses", "puts", "segments", "compactions", "disk_bytes", "wal_bytes"]
        {
            assert!(j.get(field).and_then(Json::as_u64).is_some(), "missing {field}");
        }
        assert!(j.get("spill").and_then(|s| s.get("segments")).is_some());
        assert_eq!(j.get("hits").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn fnv64_bytes_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv64_bytes(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64_bytes(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64_bytes(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fnv64_fold_composes_like_the_whole_buffer_hash() {
        let h = fnv64_fold(fnv64_fold(FNV64_OFFSET, b"foo"), b"bar");
        assert_eq!(h, fnv64_bytes(b"foobar"));
        assert_eq!(fnv64_fold(FNV64_OFFSET, b""), fnv64_bytes(b""));
    }

    #[test]
    fn second_open_names_the_live_holder() {
        let cfg = tmp_store("lock_conflict");
        let store = ResultStore::open(cfg.clone()).unwrap();
        let e = ResultStore::open(cfg.clone()).unwrap_err().to_string();
        let pid = std::process::id().to_string();
        assert!(e.contains("already open"), "{e}");
        assert!(e.contains(&pid), "names the holder pid: {e}");
        assert!(e.contains("LOCK"), "names the lock file: {e}");
        assert!(e.contains("--store-dir"), "names the remedy: {e}");
        drop(store);
        // Drop released the lock: the directory opens again.
        ResultStore::open(cfg).unwrap();
    }

    #[test]
    fn drain_releases_the_lock_before_drop() {
        let cfg = tmp_store("lock_drain");
        let store = ResultStore::open(cfg.clone()).unwrap();
        store.put("k", b"v").unwrap();
        store.drain().unwrap();
        // The first handle is still alive, but drained: a successor may
        // open the directory immediately (daemon handoff).
        let successor = ResultStore::open(cfg).unwrap();
        assert_eq!(successor.get("k"), Some(b"v".to_vec()));
        drop(store);
        // The drained handle's drop must not steal the successor's lock.
        assert!(successor.lock.lock().unwrap().is_some());
        let held = successor.lock.lock().unwrap().as_ref().unwrap().path.clone();
        assert!(held.exists(), "successor's lock file survives the old drop");
    }

    #[test]
    fn stale_lock_from_a_dead_pid_is_reclaimed() {
        let cfg = tmp_store("lock_stale");
        std::fs::create_dir_all(&cfg.dir).unwrap();
        // No live process has this pid (far beyond any default pid_max);
        // an unparseable holder is likewise stale.
        std::fs::write(cfg.dir.join("LOCK"), "999999999\n").unwrap();
        ResultStore::open(cfg.clone()).unwrap();
        let _ = std::fs::remove_dir_all(&cfg.dir);
        std::fs::create_dir_all(&cfg.dir).unwrap();
        std::fs::write(cfg.dir.join("LOCK"), "torn#write").unwrap();
        ResultStore::open(cfg).unwrap();
    }
}
