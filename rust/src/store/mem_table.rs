//! The in-memory write buffer of the LSM tree.
//!
//! A sorted `key -> value` map absorbing every [`put`](super::Lsm::put)
//! after it is WAL-durable.  Lookups hit it first (it always holds the
//! newest version of a key), and when its approximate footprint crosses
//! the flush threshold the whole map is [taken](MemTable::take) and
//! written out as one immutable sorted table — `BTreeMap` iteration order
//! *is* the table's key order, so the flush is a single sequential pass.

use std::collections::BTreeMap;

/// Fixed per-entry bookkeeping estimate (map node + two vec headers);
/// exact heap accounting isn't worth chasing — the threshold only decides
/// *when* to flush, never correctness.
const ENTRY_OVERHEAD: usize = 64;

/// The mutable sorted buffer between the WAL and the sorted tables.
#[derive(Debug, Default)]
pub struct MemTable {
    map: BTreeMap<String, Vec<u8>>,
    bytes: usize,
}

impl MemTable {
    /// An empty memtable.
    pub fn new() -> MemTable {
        MemTable::default()
    }

    /// Insert or overwrite `key`.  Last write wins, matching WAL replay
    /// order and the newest-table-first read path.
    pub fn insert(&mut self, key: String, value: Vec<u8>) {
        let key_bytes = key.len();
        let value_bytes = value.len();
        match self.map.insert(key, value) {
            // Replaced: key + overhead stay accounted; swap the value size.
            Some(old) => {
                self.bytes = self.bytes.saturating_sub(old.len()) + value_bytes;
            }
            None => self.bytes += key_bytes + value_bytes + ENTRY_OVERHEAD,
        }
    }

    /// The newest value for `key`, if buffered.
    pub fn get(&self, key: &str) -> Option<&[u8]> {
        self.map.get(key).map(Vec::as_slice)
    }

    /// Buffered entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Approximate heap footprint — the flush trigger.
    pub fn approx_bytes(&self) -> usize {
        self.bytes
    }

    /// Move the whole buffer out (for a flush), leaving the memtable
    /// empty.  The returned map iterates in key order — exactly the
    /// layout [`SsTable::write`](super::SsTable::write) wants.
    pub fn take(&mut self) -> BTreeMap<String, Vec<u8>> {
        self.bytes = 0;
        std::mem::take(&mut self.map)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn last_write_wins_and_bytes_track() {
        let mut m = MemTable::new();
        assert!(m.is_empty());
        m.insert("b".into(), vec![1, 2, 3]);
        m.insert("a".into(), vec![9]);
        assert_eq!(m.len(), 2);
        assert_eq!(m.get("b"), Some(&[1u8, 2, 3][..]));
        let before = m.approx_bytes();
        m.insert("b".into(), vec![7; 100]);
        assert_eq!(m.get("b"), Some(&[7u8; 100][..]), "overwrite keeps the newest");
        assert_eq!(m.len(), 2);
        assert!(m.approx_bytes() > before, "larger replacement grows the estimate");
    }

    #[test]
    fn take_drains_in_key_order() {
        let mut m = MemTable::new();
        m.insert("z".into(), b"3".to_vec());
        m.insert("a".into(), b"1".to_vec());
        m.insert("m".into(), b"2".to_vec());
        let drained = m.take();
        assert!(m.is_empty());
        assert_eq!(m.approx_bytes(), 0);
        let keys: Vec<&str> = drained.keys().map(String::as_str).collect();
        assert_eq!(keys, ["a", "m", "z"], "sorted — ready for a sequential table write");
    }

    #[test]
    fn shrinking_replacement_never_underflows() {
        let mut m = MemTable::new();
        m.insert("k".into(), vec![0; 1000]);
        m.insert("k".into(), Vec::new());
        assert!(m.approx_bytes() >= "k".len() + ENTRY_OVERHEAD);
    }
}
