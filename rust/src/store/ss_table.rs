//! Immutable sorted string tables — the durable level of the LSM tree.
//!
//! A table is written once (from a flushed memtable or a compaction
//! merge) and never mutated.  Layout:
//!
//! ```text
//! header : [b"SST1"] [u64 LE entry count] [u64 LE index offset]
//! data   : count × ( [u32 LE key_len] [key] [u32 LE value_len] [value] )
//! index  : count × ( [u32 LE key_len] [key] [u64 LE record offset] )
//! footer : [u64 LE fnv64(index bytes)]
//! ```
//!
//! The data block is keyed in ascending order (a `BTreeMap` flush is
//! already sorted); the index — the binary-searchable key block — is
//! loaded into memory at [`open`](SsTable::open) and checksummed, so a
//! [`get`](SsTable::get) is one in-memory binary search plus one seek +
//! read of exactly the requested record.  Writes go to a `.tmp` sibling
//! which is fsynced and atomically renamed into place: a crash mid-flush
//! leaves a stray `.tmp` (swept at [`Lsm::open`](super::Lsm::open)),
//! never a half-visible table.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{Error, Result};

use super::fnv64_bytes;

/// Table file magic.
pub const SST_MAGIC: &[u8; 4] = b"SST1";

/// magic + count + index offset.
const HEADER_BYTES: u64 = 20;

/// Sanity bound mirrored from the WAL: no single key/value above 1 GiB.
const MAX_FIELD_BYTES: u32 = 1 << 30;

/// One immutable on-disk sorted table with its resident key index.
#[derive(Debug)]
pub struct SsTable {
    path: PathBuf,
    file: Mutex<File>,
    /// `(key, absolute record offset)`, ascending by key.
    index: Vec<(String, u64)>,
    file_bytes: u64,
}

impl SsTable {
    /// Write `entries` (already key-sorted — `BTreeMap` iteration order)
    /// as a new table at `path`, atomically: build `.tmp`, fsync, rename.
    pub fn write(path: &Path, entries: &BTreeMap<String, Vec<u8>>) -> Result<()> {
        // Fault seam: fail the flush before the `.tmp` sibling exists, the
        // same clean failure an unwritable store directory gives.
        if let Some(e) = crate::inject::io_error("store.sst.write") {
            return Err(Error::io(path.display().to_string(), e));
        }
        let tmp = tmp_path(path);
        let ctx = || tmp.display().to_string();
        let file = File::create(&tmp).map_err(|e| Error::io(ctx(), e))?;
        let mut w = BufWriter::new(file);
        w.write_all(SST_MAGIC).map_err(|e| Error::io(ctx(), e))?;
        w.write_all(&(entries.len() as u64).to_le_bytes())
            .map_err(|e| Error::io(ctx(), e))?;
        // Index offset is patched in once the data block's size is known.
        w.write_all(&0u64.to_le_bytes()).map_err(|e| Error::io(ctx(), e))?;
        let mut offset = HEADER_BYTES;
        let mut index = Vec::with_capacity(entries.len() * 24);
        for (key, value) in entries {
            index.extend_from_slice(&(key.len() as u32).to_le_bytes());
            index.extend_from_slice(key.as_bytes());
            index.extend_from_slice(&offset.to_le_bytes());
            w.write_all(&(key.len() as u32).to_le_bytes())
                .map_err(|e| Error::io(ctx(), e))?;
            w.write_all(key.as_bytes()).map_err(|e| Error::io(ctx(), e))?;
            w.write_all(&(value.len() as u32).to_le_bytes())
                .map_err(|e| Error::io(ctx(), e))?;
            w.write_all(value).map_err(|e| Error::io(ctx(), e))?;
            offset += 8 + key.len() as u64 + value.len() as u64;
        }
        let index_offset = offset;
        w.write_all(&index).map_err(|e| Error::io(ctx(), e))?;
        w.write_all(&fnv64_bytes(&index).to_le_bytes())
            .map_err(|e| Error::io(ctx(), e))?;
        let mut file = w.into_inner().map_err(|e| Error::io(ctx(), e.into_error()))?;
        file.seek(SeekFrom::Start(12)).map_err(|e| Error::io(ctx(), e))?;
        file.write_all(&index_offset.to_le_bytes())
            .map_err(|e| Error::io(ctx(), e))?;
        file.sync_all().map_err(|e| Error::io(ctx(), e))?;
        drop(file);
        std::fs::rename(&tmp, path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        sync_parent_dir(path);
        Ok(())
    }

    /// Open a table: validate the header, load + checksum the key index.
    pub fn open(path: &Path) -> Result<SsTable> {
        let ctx = || path.display().to_string();
        let mut file = File::open(path).map_err(|e| Error::io(ctx(), e))?;
        let file_bytes = file.metadata().map_err(|e| Error::io(ctx(), e))?.len();
        let bad = |msg: &str| Error::parse("sst", path.display().to_string(), msg.to_string());
        if file_bytes < HEADER_BYTES + 8 {
            return Err(bad("file shorter than header + footer"));
        }
        let mut header = [0u8; HEADER_BYTES as usize];
        file.read_exact(&mut header).map_err(|e| Error::io(ctx(), e))?;
        if &header[..4] != SST_MAGIC {
            return Err(bad("bad magic"));
        }
        let count = u64::from_le_bytes(header[4..12].try_into().expect("8 bytes"));
        let index_offset = u64::from_le_bytes(header[12..20].try_into().expect("8 bytes"));
        if index_offset < HEADER_BYTES || index_offset > file_bytes - 8 {
            return Err(bad("index offset out of bounds"));
        }
        let index_bytes_len = (file_bytes - 8 - index_offset) as usize;
        file.seek(SeekFrom::Start(index_offset)).map_err(|e| Error::io(ctx(), e))?;
        let mut index_bytes = vec![0u8; index_bytes_len];
        file.read_exact(&mut index_bytes).map_err(|e| Error::io(ctx(), e))?;
        let mut footer = [0u8; 8];
        file.read_exact(&mut footer).map_err(|e| Error::io(ctx(), e))?;
        if fnv64_bytes(&index_bytes) != u64::from_le_bytes(footer) {
            return Err(bad("index checksum mismatch"));
        }
        let index = parse_index(&index_bytes, count, index_offset)
            .ok_or_else(|| bad("malformed index block"))?;
        Ok(SsTable { path: path.to_path_buf(), file: Mutex::new(file), index, file_bytes })
    }

    /// The value for `key`, read straight from disk via the resident
    /// index: one binary search, one seek, one record read.
    pub fn get(&self, key: &str) -> Result<Option<Vec<u8>>> {
        let Ok(slot) = self.index.binary_search_by(|(k, _)| k.as_str().cmp(key)) else {
            return Ok(None);
        };
        let offset = self.index[slot].1;
        let mut file = self.file.lock().unwrap();
        let (stored_key, value) = read_record(&mut file, offset, &self.path)?;
        if stored_key != key {
            // Index and data disagree — bitrot the index checksum missed.
            return Err(Error::parse(
                "sst",
                self.path.display().to_string(),
                format!("index points {key:?} at a record for {stored_key:?}"),
            ));
        }
        Ok(Some(value))
    }

    /// Every record in key order — the compaction read path.
    pub fn entries(&self) -> Result<Vec<(String, Vec<u8>)>> {
        let mut file = self.file.lock().unwrap();
        let mut out = Vec::with_capacity(self.index.len());
        for (_, offset) in &self.index {
            out.push(read_record(&mut file, *offset, &self.path)?);
        }
        Ok(out)
    }

    /// Number of records.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// Whether the table holds no records.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// On-disk size of the whole table file.
    pub fn file_bytes(&self) -> u64 {
        self.file_bytes
    }

    /// The table file's path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// `<path>.tmp` — the invisible sibling a table is built at.
pub fn tmp_path(path: &Path) -> PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".tmp");
    PathBuf::from(s)
}

/// Fsync the directory holding `path` so a rename survives power loss;
/// best-effort (not every platform lets you open a directory).
fn sync_parent_dir(path: &Path) {
    if let Some(parent) = path.parent() {
        if let Ok(d) = File::open(parent) {
            let _ = d.sync_all();
        }
    }
}

fn read_record(file: &mut File, offset: u64, path: &Path) -> Result<(String, Vec<u8>)> {
    let ctx = || path.display().to_string();
    let bad = |msg: &str| Error::parse("sst", path.display().to_string(), msg.to_string());
    file.seek(SeekFrom::Start(offset)).map_err(|e| Error::io(ctx(), e))?;
    let mut len4 = [0u8; 4];
    file.read_exact(&mut len4).map_err(|e| Error::io(ctx(), e))?;
    let klen = u32::from_le_bytes(len4);
    if klen > MAX_FIELD_BYTES {
        return Err(bad("implausible key length"));
    }
    let mut key = vec![0u8; klen as usize];
    file.read_exact(&mut key).map_err(|e| Error::io(ctx(), e))?;
    file.read_exact(&mut len4).map_err(|e| Error::io(ctx(), e))?;
    let vlen = u32::from_le_bytes(len4);
    if vlen > MAX_FIELD_BYTES {
        return Err(bad("implausible value length"));
    }
    let mut value = vec![0u8; vlen as usize];
    file.read_exact(&mut value).map_err(|e| Error::io(ctx(), e))?;
    let key = String::from_utf8(key).map_err(|_| bad("record key is not utf-8"))?;
    Ok((key, value))
}

/// Parse the index block: exactly `count` entries, keys strictly
/// ascending, offsets inside the data block.
fn parse_index(bytes: &[u8], count: u64, index_offset: u64) -> Option<Vec<(String, u64)>> {
    let mut index = Vec::with_capacity(count as usize);
    let mut pos = 0usize;
    for _ in 0..count {
        let klen =
            u32::from_le_bytes(bytes.get(pos..pos + 4)?.try_into().ok()?) as usize;
        let key = std::str::from_utf8(bytes.get(pos + 4..pos + 4 + klen)?).ok()?;
        let off_at = pos + 4 + klen;
        let offset = u64::from_le_bytes(bytes.get(off_at..off_at + 8)?.try_into().ok()?);
        if offset < HEADER_BYTES || offset >= index_offset {
            return None;
        }
        if let Some((last, _)) = index.last() {
            if key <= String::as_str(last) {
                return None; // unsorted or duplicate: not one of our tables
            }
        }
        index.push((key.to_string(), offset));
        pos = off_at + 8;
    }
    (pos == bytes.len()).then_some(index)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(case: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("permanova_apu_store_sst_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("{case}.sst"));
        let _ = std::fs::remove_file(&p);
        p
    }

    fn sample() -> BTreeMap<String, Vec<u8>> {
        let mut m = BTreeMap::new();
        m.insert("alpha".to_string(), b"one".to_vec());
        m.insert("beta".to_string(), Vec::new());
        m.insert("gamma".to_string(), vec![0xAB; 1024]);
        m
    }

    #[test]
    fn write_open_get_roundtrip() {
        let p = tmp("roundtrip");
        SsTable::write(&p, &sample()).unwrap();
        let t = SsTable::open(&p).unwrap();
        assert_eq!(t.len(), 3);
        assert!(!t.is_empty());
        assert_eq!(t.get("alpha").unwrap(), Some(b"one".to_vec()));
        assert_eq!(t.get("beta").unwrap(), Some(Vec::new()));
        assert_eq!(t.get("gamma").unwrap(), Some(vec![0xAB; 1024]));
        assert_eq!(t.get("delta").unwrap(), None, "absent key is a clean miss");
        assert_eq!(t.file_bytes(), std::fs::metadata(&p).unwrap().len());
        assert!(!tmp_path(&p).exists(), "the .tmp sibling was renamed away");
    }

    #[test]
    fn entries_iterate_in_key_order() {
        let p = tmp("entries");
        SsTable::write(&p, &sample()).unwrap();
        let t = SsTable::open(&p).unwrap();
        let got = t.entries().unwrap();
        let keys: Vec<&str> = got.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["alpha", "beta", "gamma"]);
        assert_eq!(got[2].1, vec![0xAB; 1024]);
    }

    #[test]
    fn corrupt_index_is_rejected_at_open() {
        let p = tmp("corrupt");
        SsTable::write(&p, &sample()).unwrap();
        let mut raw = std::fs::read(&p).unwrap();
        // Flip a byte in the index block (just before the 8-byte footer).
        let at = raw.len() - 12;
        raw[at] ^= 0xFF;
        std::fs::write(&p, &raw).unwrap();
        let e = SsTable::open(&p).unwrap_err().to_string();
        assert!(e.contains("checksum") || e.contains("malformed"), "{e}");
    }

    #[test]
    fn truncated_and_foreign_files_are_rejected() {
        let p = tmp("short");
        std::fs::write(&p, b"SST1short").unwrap();
        assert!(SsTable::open(&p).is_err());
        let p = tmp("foreign");
        std::fs::write(&p, vec![0u8; 256]).unwrap();
        let e = SsTable::open(&p).unwrap_err().to_string();
        assert!(e.contains("magic"), "{e}");
    }

    #[test]
    fn empty_table_roundtrips() {
        let p = tmp("empty");
        SsTable::write(&p, &BTreeMap::new()).unwrap();
        let t = SsTable::open(&p).unwrap();
        assert!(t.is_empty());
        assert_eq!(t.get("anything").unwrap(), None);
        assert!(t.entries().unwrap().is_empty());
    }
}
