//! The LSM tree: WAL → memtable → immutable sorted tables, with
//! size-tiered compaction and a byte-capacity eviction policy.
//!
//! Write path: [`put`](Lsm::put) appends + fsyncs the WAL record, then
//! inserts into the memtable; once the memtable crosses the flush
//! threshold it is written out as one immutable [`SsTable`] and the WAL
//! resets.  Read path: memtable first (always the newest version), then
//! tables newest-to-oldest — first hit wins, so later writes shadow
//! earlier ones without tombstones (the store is a cache; keys are never
//! deleted individually, only evicted wholesale).
//!
//! Compaction is size-tiered in the simplest shape that bounds read
//! amplification: when [`MAX_TABLES`] tables accumulate, all of them
//! merge (newest version of each key wins) into one table and the olds
//! are unlinked.  Capacity is a cache budget, not a quota: when the
//! on-disk footprint exceeds `capacity_bytes`, whole oldest tables are
//! dropped — for a result cache, losing the oldest entries only costs a
//! recompute, never correctness.
//!
//! Crash-safety: the WAL is fsynced per put (kill −9 loses at most the
//! record mid-write — see [`Wal`]); tables become visible only via an
//! fsync + atomic rename (a crash mid-flush leaves a `.tmp` stray that
//! [`open`](Lsm::open) sweeps); the WAL resets only *after* its records
//! are durable in a table.  Every boot state is therefore one of: record
//! in WAL, record in table, or record torn-and-dropped — never corrupt.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

use super::mem_table::MemTable;
use super::ss_table::SsTable;
use super::wal::Wal;

/// Default memtable flush threshold: serialized reports are a few KiB, so
/// this batches thousands of results per table while keeping WAL replay
/// (and therefore boot) cheap.
pub const DEFAULT_FLUSH_BYTES: usize = 4 << 20;

/// Tables that may accumulate before a full merge.  Reads check every
/// table on a miss, so this directly bounds read amplification.
pub const MAX_TABLES: usize = 4;

/// File name of the write-ahead log inside the store directory.
const WAL_FILE: &str = "wal.log";

/// Tuning for one [`Lsm`] tree.
#[derive(Clone, Debug)]
pub struct LsmConfig {
    /// Directory holding `wal.log` and `sst-*.sst` (created if absent).
    pub dir: PathBuf,
    /// On-disk byte budget; `0` = unbounded.  Enforced at table
    /// granularity by dropping the oldest tables.
    pub capacity_bytes: u64,
    /// Memtable size that triggers a flush to a sorted table.
    pub flush_bytes: usize,
}

/// Point-in-time counters for the `stats` op and the bench axis.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LsmStats {
    /// Entries buffered in the memtable (WAL-durable, not yet in a table).
    pub mem_entries: usize,
    /// Immutable sorted tables on disk.
    pub segments: usize,
    /// Entries across all tables (pre-dedup: shadowed versions count).
    pub table_entries: usize,
    /// Full-merge compactions performed this process lifetime.
    pub compactions: u64,
    /// Tables dropped: capacity evictions + corrupt segments swept at open.
    pub evicted_segments: u64,
    /// On-disk bytes: every table file + the live WAL.
    pub disk_bytes: u64,
    /// Bytes currently in the WAL (replay cost of a crash right now).
    pub wal_bytes: u64,
}

/// The log-structured merge tree.
#[derive(Debug)]
pub struct Lsm {
    cfg: LsmConfig,
    wal: Wal,
    mem: MemTable,
    /// Newest first — read order after the memtable.
    tables: Vec<SsTable>,
    next_seq: u64,
    compactions: u64,
    evicted_segments: u64,
}

impl Lsm {
    /// Open (creating if absent) the tree at `cfg.dir`: sweep `.tmp`
    /// strays, open every intact table newest-first (a corrupt table is
    /// unlinked and counted, never fatal — cache semantics), replay the
    /// WAL into the memtable.
    pub fn open(cfg: LsmConfig) -> Result<Lsm> {
        std::fs::create_dir_all(&cfg.dir)
            .map_err(|e| Error::io(cfg.dir.display().to_string(), e))?;
        let mut seqs: Vec<(u64, PathBuf)> = Vec::new();
        let mut evicted_segments = 0u64;
        let entries = std::fs::read_dir(&cfg.dir)
            .map_err(|e| Error::io(cfg.dir.display().to_string(), e))?;
        for entry in entries {
            let path = entry.map_err(|e| Error::io(cfg.dir.display().to_string(), e))?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            if name.ends_with(".tmp") {
                // A flush died mid-write before its rename; harmless.
                let _ = std::fs::remove_file(&path);
                continue;
            }
            if let Some(seq) = table_seq(name) {
                seqs.push((seq, path));
            }
        }
        // Newest (highest sequence) first — the read-priority order.
        seqs.sort_by(|a, b| b.0.cmp(&a.0));
        let next_seq = seqs.first().map(|(s, _)| s + 1).unwrap_or(0);
        let mut tables = Vec::with_capacity(seqs.len());
        for (_, path) in &seqs {
            match SsTable::open(path) {
                Ok(t) => tables.push(t),
                Err(_) => {
                    // Bitrot or a foreign file wearing our name: drop it
                    // rather than refuse to boot or serve bad bytes.
                    let _ = std::fs::remove_file(path);
                    evicted_segments += 1;
                }
            }
        }
        let (wal, replayed) = Wal::open(cfg.dir.join(WAL_FILE))?;
        let mut mem = MemTable::new();
        for (key, value) in replayed {
            mem.insert(key, value);
        }
        let mut lsm = Lsm {
            cfg,
            wal,
            mem,
            tables,
            next_seq,
            compactions: 0,
            evicted_segments,
        };
        // A crash can leave a replayed memtable already past the flush
        // threshold; flush now so the invariant holds from the start.
        if lsm.mem.approx_bytes() >= lsm.cfg.flush_bytes {
            lsm.flush()?;
        }
        Ok(lsm)
    }

    /// Newest value for `key`: memtable, then tables newest-to-oldest.
    pub fn get(&self, key: &str) -> Result<Option<Vec<u8>>> {
        if let Some(v) = self.mem.get(key) {
            return Ok(Some(v.to_vec()));
        }
        for table in &self.tables {
            if let Some(v) = table.get(key)? {
                return Ok(Some(v));
            }
        }
        Ok(None)
    }

    /// Durably record `key -> value`: WAL append + fsync, memtable
    /// insert, flush if the threshold tripped.
    pub fn put(&mut self, key: &str, value: &[u8]) -> Result<()> {
        self.wal.append(key, value)?;
        self.wal.sync()?;
        self.mem.insert(key.to_string(), value.to_vec());
        if self.mem.approx_bytes() >= self.cfg.flush_bytes {
            self.flush()?;
        }
        Ok(())
    }

    /// Write the memtable out as a new immutable table, reset the WAL,
    /// then compact / enforce the capacity budget.  No-op when empty.
    pub fn flush(&mut self) -> Result<()> {
        if self.mem.is_empty() {
            return Ok(());
        }
        let entries = self.mem.take();
        let path = self.table_path(self.next_seq);
        self.next_seq += 1;
        if let Err(e) = SsTable::write(&path, &entries) {
            // The records are still WAL-durable, but losing the taken
            // memtable copy would make them unreadable until the next
            // replay; put it back so gets keep serving and a later flush
            // can retry against a recovered disk.
            for (key, value) in entries {
                self.mem.insert(key, value);
            }
            return Err(e);
        }
        self.tables.insert(0, SsTable::open(&path)?);
        // Only now are the records durable outside the WAL.
        self.wal.reset()?;
        self.maybe_compact()?;
        self.enforce_capacity();
        Ok(())
    }

    /// Graceful-shutdown hook: flush whatever is buffered so the next
    /// boot replays nothing.  (Unflushed state would survive anyway — in
    /// the WAL — this just makes restart O(index load).)
    pub fn drain(&mut self) -> Result<()> {
        self.flush()
    }

    /// Current counters.
    pub fn stats(&self) -> LsmStats {
        LsmStats {
            mem_entries: self.mem.len(),
            segments: self.tables.len(),
            table_entries: self.tables.iter().map(SsTable::len).sum(),
            compactions: self.compactions,
            evicted_segments: self.evicted_segments,
            disk_bytes: self.disk_bytes(),
            wal_bytes: self.wal.bytes(),
        }
    }

    /// Table files + live WAL, in bytes.
    pub fn disk_bytes(&self) -> u64 {
        self.tables.iter().map(SsTable::file_bytes).sum::<u64>() + self.wal.bytes()
    }

    fn table_path(&self, seq: u64) -> PathBuf {
        self.cfg.dir.join(format!("sst-{seq:010}.sst"))
    }

    /// Full merge once [`MAX_TABLES`] accumulate: oldest-to-newest so the
    /// newest version of each key wins, one merged table replaces all.
    fn maybe_compact(&mut self) -> Result<()> {
        if self.tables.len() < MAX_TABLES {
            return Ok(());
        }
        let mut merged: BTreeMap<String, Vec<u8>> = BTreeMap::new();
        for table in self.tables.iter().rev() {
            for (key, value) in table.entries()? {
                merged.insert(key, value);
            }
        }
        let path = self.table_path(self.next_seq);
        self.next_seq += 1;
        SsTable::write(&path, &merged)?;
        let new = SsTable::open(&path)?;
        let old: Vec<PathBuf> =
            self.tables.iter().map(|t| t.path().to_path_buf()).collect();
        self.tables = vec![new];
        for p in old {
            let _ = std::fs::remove_file(p);
        }
        self.compactions += 1;
        Ok(())
    }

    /// Drop whole oldest tables while over the byte budget.  The single
    /// newest table always survives — capacity is enforced at table
    /// granularity, so one oversized table is tolerated rather than
    /// thrashing.
    fn enforce_capacity(&mut self) {
        if self.cfg.capacity_bytes == 0 {
            return;
        }
        while self.tables.len() > 1 && self.disk_bytes() > self.cfg.capacity_bytes {
            let victim = self.tables.pop().expect("len > 1 checked");
            let _ = std::fs::remove_file(victim.path());
            self.evicted_segments += 1;
        }
    }
}

/// Parse `sst-NNNNNNNNNN.sst` into its sequence number.
fn table_seq(name: &str) -> Option<u64> {
    name.strip_prefix("sst-")?.strip_suffix(".sst")?.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_tree(case: &str, capacity: u64, flush: usize) -> LsmConfig {
        let dir =
            std::env::temp_dir().join(format!("permanova_apu_store_lsm_test_{case}"));
        let _ = std::fs::remove_dir_all(&dir);
        LsmConfig { dir, capacity_bytes: capacity, flush_bytes: flush }
    }

    #[test]
    fn put_get_survive_reopen_via_wal() {
        let cfg = tmp_tree("wal_survive", 0, DEFAULT_FLUSH_BYTES);
        let mut lsm = Lsm::open(cfg.clone()).unwrap();
        lsm.put("k1", b"v1").unwrap();
        lsm.put("k2", b"v2").unwrap();
        lsm.put("k1", b"v1b").unwrap();
        assert_eq!(lsm.get("k1").unwrap(), Some(b"v1b".to_vec()), "last write wins");
        assert_eq!(lsm.stats().segments, 0, "nothing flushed yet");
        drop(lsm);
        let lsm = Lsm::open(cfg).unwrap();
        assert_eq!(lsm.get("k1").unwrap(), Some(b"v1b".to_vec()));
        assert_eq!(lsm.get("k2").unwrap(), Some(b"v2".to_vec()));
        assert_eq!(lsm.get("k3").unwrap(), None);
        assert_eq!(lsm.stats().mem_entries, 2, "replayed from the WAL");
    }

    #[test]
    fn flush_moves_entries_to_tables_and_resets_wal() {
        let cfg = tmp_tree("flush", 0, DEFAULT_FLUSH_BYTES);
        let mut lsm = Lsm::open(cfg.clone()).unwrap();
        lsm.put("a", b"1").unwrap();
        lsm.put("b", b"2").unwrap();
        lsm.flush().unwrap();
        let s = lsm.stats();
        assert_eq!((s.mem_entries, s.segments, s.wal_bytes), (0, 1, 0));
        assert_eq!(lsm.get("a").unwrap(), Some(b"1".to_vec()), "served from the table");
        drop(lsm);
        let lsm = Lsm::open(cfg).unwrap();
        assert_eq!(lsm.get("b").unwrap(), Some(b"2".to_vec()), "table survives reopen");
        assert_eq!(lsm.stats().mem_entries, 0, "WAL was empty — nothing replayed");
    }

    #[test]
    fn tiny_threshold_auto_flushes_and_newest_table_wins() {
        let cfg = tmp_tree("auto_flush", 0, 1);
        let mut lsm = Lsm::open(cfg).unwrap();
        lsm.put("k", b"old").unwrap(); // flushes immediately (threshold 1)
        lsm.put("k", b"new").unwrap(); // second table shadows the first
        let s = lsm.stats();
        assert!(s.segments >= 2, "each put flushed: {s:?}");
        assert_eq!(lsm.get("k").unwrap(), Some(b"new".to_vec()));
    }

    #[test]
    fn compaction_merges_tables_and_preserves_lookups() {
        let cfg = tmp_tree("compact", 0, 1);
        let mut lsm = Lsm::open(cfg.clone()).unwrap();
        for i in 0..MAX_TABLES {
            lsm.put(&format!("key-{i}"), format!("val-{i}").as_bytes()).unwrap();
        }
        let s = lsm.stats();
        assert_eq!(s.segments, 1, "MAX_TABLES flushes triggered one merge: {s:?}");
        assert_eq!(s.compactions, 1);
        assert_eq!(s.table_entries, MAX_TABLES);
        for i in 0..MAX_TABLES {
            assert_eq!(
                lsm.get(&format!("key-{i}")).unwrap(),
                Some(format!("val-{i}").into_bytes()),
                "lookup preserved across compaction"
            );
        }
        drop(lsm);
        let lsm = Lsm::open(cfg).unwrap();
        for i in 0..MAX_TABLES {
            assert!(lsm.get(&format!("key-{i}")).unwrap().is_some());
        }
    }

    #[test]
    fn capacity_drops_oldest_tables_only() {
        // Budget of ~one tiny table (~55 bytes each here): every flush
        // evicts the previous table.
        let cfg = tmp_tree("capacity", 60, 1);
        let mut lsm = Lsm::open(cfg).unwrap();
        lsm.put("old", b"x").unwrap();
        lsm.put("new", b"y").unwrap();
        let s = lsm.stats();
        assert_eq!(s.segments, 1, "{s:?}");
        assert!(s.evicted_segments >= 1);
        assert_eq!(lsm.get("new").unwrap(), Some(b"y".to_vec()), "newest survives");
        assert_eq!(lsm.get("old").unwrap(), None, "oldest evicted — only a recompute");
    }

    #[test]
    fn corrupt_table_is_swept_not_fatal() {
        let cfg = tmp_tree("sweep", 0, DEFAULT_FLUSH_BYTES);
        let mut lsm = Lsm::open(cfg.clone()).unwrap();
        lsm.put("keep", b"me").unwrap();
        lsm.flush().unwrap();
        lsm.put("also", b"keep").unwrap();
        drop(lsm);
        // A foreign file wearing a table name + a stray .tmp from a "crash".
        std::fs::write(cfg.dir.join("sst-9999999999.sst"), b"junk").unwrap();
        std::fs::write(cfg.dir.join("sst-0000000007.sst.tmp"), b"half a flush").unwrap();
        let lsm = Lsm::open(cfg.clone()).unwrap();
        assert_eq!(lsm.get("keep").unwrap(), Some(b"me".to_vec()));
        assert_eq!(lsm.get("also").unwrap(), Some(b"keep".to_vec()));
        let s = lsm.stats();
        assert_eq!(s.evicted_segments, 1, "the junk table was swept: {s:?}");
        assert!(!cfg.dir.join("sst-9999999999.sst").exists());
        assert!(!cfg.dir.join("sst-0000000007.sst.tmp").exists());
    }

    #[test]
    fn drain_then_reopen_replays_nothing() {
        let cfg = tmp_tree("drain", 0, DEFAULT_FLUSH_BYTES);
        let mut lsm = Lsm::open(cfg.clone()).unwrap();
        lsm.put("k", b"v").unwrap();
        lsm.drain().unwrap();
        assert_eq!(lsm.stats().wal_bytes, 0);
        drop(lsm);
        let lsm = Lsm::open(cfg).unwrap();
        assert_eq!(lsm.stats().mem_entries, 0);
        assert_eq!(lsm.get("k").unwrap(), Some(b"v".to_vec()));
    }
}
