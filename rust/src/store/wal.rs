//! Crash-safe append-only write-ahead log.
//!
//! Every [`ResultStore`](super::ResultStore) `put` is appended here and
//! fsynced **before** it lands in the memtable, so a process kill at any
//! instant loses at most the record being written.  Records are
//! length-prefixed and checksummed:
//!
//! ```text
//! record  := [u32 LE payload_len] [u64 LE fnv64(payload)] [payload]
//! payload := [u32 LE key_len] [key utf-8] [u32 LE value_len] [value]
//! ```
//!
//! Replay walks the file from the start and stops at the first record
//! that is short, fails its checksum, or decodes inconsistently — the
//! torn tail a crash mid-append leaves behind.  Everything before the
//! tear is intact by construction (records are appended in order and the
//! checksum covers the whole payload), so replay returns exactly the
//! fsynced prefix and [`Wal::open`] truncates the file back to it; the
//! next append continues from the last good byte.  A torn tail is
//! **expected** state, never an error.
//!
//! The log is bounded: [`Lsm::flush`](super::Lsm::flush) writes the
//! memtable to an immutable sorted table and then [`reset`](Wal::reset)s
//! the log, so replay cost is capped by the flush threshold, not by the
//! store's lifetime.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

use super::fnv64_bytes;

/// Upper bound on one record's payload — a corrupt length prefix must
/// not trigger a gigantic allocation during replay.
const MAX_PAYLOAD_BYTES: u32 = 1 << 30;

/// Record header: `u32` payload length + `u64` payload checksum.
const HEADER_BYTES: usize = 12;

/// The append-only log.  One per [`Lsm`](super::Lsm) tree; all writes go
/// through [`append`](Self::append) + [`sync`](Self::sync).
#[derive(Debug)]
pub struct Wal {
    path: PathBuf,
    file: File,
    bytes: u64,
}

impl Wal {
    /// Open (creating if absent) and replay the log at `path`.  Returns
    /// the log positioned for appending plus every intact record in write
    /// order; a torn tail is truncated away, not reported as an error.
    pub fn open(path: impl AsRef<Path>) -> Result<(Wal, Vec<(String, Vec<u8>)>)> {
        let path = path.as_ref().to_path_buf();
        let ctx = || path.display().to_string();
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&path)
            .map_err(|e| Error::io(ctx(), e))?;
        let mut raw = Vec::new();
        file.read_to_end(&mut raw).map_err(|e| Error::io(ctx(), e))?;
        let (entries, valid) = replay(&raw);
        if (valid as u64) < raw.len() as u64 {
            // Drop the torn tail so the next append starts on a record
            // boundary — re-appending over garbage would corrupt replay.
            file.set_len(valid as u64).map_err(|e| Error::io(ctx(), e))?;
            file.sync_data().map_err(|e| Error::io(ctx(), e))?;
        }
        file.seek(SeekFrom::Start(valid as u64)).map_err(|e| Error::io(ctx(), e))?;
        Ok((Wal { path, file, bytes: valid as u64 }, entries))
    }

    /// Append one `key -> value` record.  Durable only after
    /// [`sync`](Self::sync).
    pub fn append(&mut self, key: &str, value: &[u8]) -> Result<()> {
        // Fault seam: an injected error fails the append before any byte
        // lands, the same clean failure a full disk gives after fsync.
        if let Some(e) = crate::inject::io_error("store.wal.write") {
            return Err(Error::io(self.path.display().to_string(), e));
        }
        let payload = encode_payload(key, value)?;
        let mut rec = Vec::with_capacity(HEADER_BYTES + payload.len());
        rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        rec.extend_from_slice(&fnv64_bytes(&payload).to_le_bytes());
        rec.extend_from_slice(&payload);
        self.file
            .write_all(&rec)
            .map_err(|e| Error::io(self.path.display().to_string(), e))?;
        self.bytes += rec.len() as u64;
        Ok(())
    }

    /// Fsync appended records to stable storage — the durability point of
    /// the crash-safety contract.
    pub fn sync(&mut self) -> Result<()> {
        self.file
            .sync_data()
            .map_err(|e| Error::io(self.path.display().to_string(), e))
    }

    /// Truncate to empty after the memtable flushed to a sorted table —
    /// the records are durable there now, so replaying them again would
    /// only resurrect stale versions.
    pub fn reset(&mut self) -> Result<()> {
        let ctx = || self.path.display().to_string();
        self.file.set_len(0).map_err(|e| Error::io(ctx(), e))?;
        self.file.seek(SeekFrom::Start(0)).map_err(|e| Error::io(ctx(), e))?;
        self.file.sync_all().map_err(|e| Error::io(ctx(), e))?;
        self.bytes = 0;
        Ok(())
    }

    /// Bytes of intact records currently in the log.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// The log file's path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Serialize one record payload; rejects keys/values at or above the
/// sanity bound so the length prefix always round-trips.
fn encode_payload(key: &str, value: &[u8]) -> Result<Vec<u8>> {
    let total = 8usize + key.len() + value.len();
    if key.len() >= MAX_PAYLOAD_BYTES as usize || total >= MAX_PAYLOAD_BYTES as usize {
        return Err(Error::InvalidInput(format!(
            "wal record too large: {total} bytes (key {} + value {})",
            key.len(),
            value.len()
        )));
    }
    let mut p = Vec::with_capacity(total);
    p.extend_from_slice(&(key.len() as u32).to_le_bytes());
    p.extend_from_slice(key.as_bytes());
    p.extend_from_slice(&(value.len() as u32).to_le_bytes());
    p.extend_from_slice(value);
    Ok(p)
}

/// Decode one checksum-verified payload; `None` means the payload is
/// internally inconsistent (possible only via bitrot that collides the
/// checksum — vanishingly unlikely, but never worth a panic).
fn decode_payload(payload: &[u8]) -> Option<(String, Vec<u8>)> {
    let klen = u32::from_le_bytes(payload.get(0..4)?.try_into().ok()?) as usize;
    let key = payload.get(4..4 + klen)?;
    let vstart = 4 + klen;
    let vlen =
        u32::from_le_bytes(payload.get(vstart..vstart + 4)?.try_into().ok()?) as usize;
    let value = payload.get(vstart + 4..vstart + 4 + vlen)?;
    if vstart + 4 + vlen != payload.len() {
        return None;
    }
    Some((String::from_utf8(key.to_vec()).ok()?, value.to_vec()))
}

/// Walk `raw` record by record; returns the intact entries and the byte
/// offset where the intact prefix ends (== `raw.len()` iff no tear).
fn replay(raw: &[u8]) -> (Vec<(String, Vec<u8>)>, usize) {
    let mut entries = Vec::new();
    let mut pos = 0usize;
    while raw.len() - pos >= HEADER_BYTES {
        let len =
            u32::from_le_bytes(raw[pos..pos + 4].try_into().expect("4-byte slice"));
        if len > MAX_PAYLOAD_BYTES {
            break;
        }
        let len = len as usize;
        if raw.len() - pos - HEADER_BYTES < len {
            break; // torn: the payload never finished hitting disk
        }
        let want =
            u64::from_le_bytes(raw[pos + 4..pos + 12].try_into().expect("8-byte slice"));
        let payload = &raw[pos + HEADER_BYTES..pos + HEADER_BYTES + len];
        if fnv64_bytes(payload) != want {
            break; // torn: header landed, payload didn't (or bitrot)
        }
        let Some(entry) = decode_payload(payload) else {
            break;
        };
        entries.push(entry);
        pos += HEADER_BYTES + len;
    }
    (entries, pos)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(case: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("permanova_apu_store_wal_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("{case}.wal"));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn roundtrip_across_reopen() {
        let p = tmp("roundtrip");
        let (mut w, replayed) = Wal::open(&p).unwrap();
        assert!(replayed.is_empty());
        w.append("k1", b"v1").unwrap();
        w.append("k2", b"").unwrap();
        w.append("k1", b"v1-updated").unwrap();
        w.sync().unwrap();
        let bytes = w.bytes();
        drop(w);
        let (w2, replayed) = Wal::open(&p).unwrap();
        assert_eq!(w2.bytes(), bytes);
        assert_eq!(
            replayed,
            vec![
                ("k1".to_string(), b"v1".to_vec()),
                ("k2".to_string(), Vec::new()),
                ("k1".to_string(), b"v1-updated".to_vec()),
            ],
            "replay preserves write order (later duplicates win downstream)"
        );
    }

    #[test]
    fn torn_tail_is_truncated_not_fatal() {
        let p = tmp("torn");
        let (mut w, _) = Wal::open(&p).unwrap();
        w.append("good", b"payload").unwrap();
        w.sync().unwrap();
        let good_bytes = w.bytes();
        w.append("torn", b"never-synced-and-half-written").unwrap();
        drop(w);
        // Simulate the crash: chop the last record mid-payload.
        let raw = std::fs::read(&p).unwrap();
        std::fs::write(&p, &raw[..raw.len() - 5]).unwrap();
        let (w2, replayed) = Wal::open(&p).unwrap();
        assert_eq!(replayed.len(), 1, "only the fsynced record survives");
        assert_eq!(replayed[0].0, "good");
        assert_eq!(w2.bytes(), good_bytes, "tail truncated to the record boundary");
        assert_eq!(std::fs::metadata(&p).unwrap().len(), good_bytes);
    }

    #[test]
    fn checksum_tear_stops_replay() {
        let p = tmp("cksum");
        let (mut w, _) = Wal::open(&p).unwrap();
        w.append("a", b"first").unwrap();
        w.append("b", b"second").unwrap();
        w.sync().unwrap();
        drop(w);
        // Flip a byte inside the second record's payload.
        let mut raw = std::fs::read(&p).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 0xFF;
        std::fs::write(&p, &raw).unwrap();
        let (_, replayed) = Wal::open(&p).unwrap();
        assert_eq!(replayed.len(), 1);
        assert_eq!(replayed[0].0, "a", "replay stops at the corrupt record");
    }

    #[test]
    fn garbage_file_replays_empty() {
        let p = tmp("garbage");
        std::fs::write(&p, b"not a wal at all, definitely long enough to look like one").unwrap();
        let (w, replayed) = Wal::open(&p).unwrap();
        assert!(replayed.is_empty());
        assert_eq!(w.bytes(), 0, "whole file was a tear; truncated away");
    }

    #[test]
    fn reset_empties_the_log() {
        let p = tmp("reset");
        let (mut w, _) = Wal::open(&p).unwrap();
        w.append("k", b"v").unwrap();
        w.sync().unwrap();
        w.reset().unwrap();
        assert_eq!(w.bytes(), 0);
        w.append("after", b"reset").unwrap();
        w.sync().unwrap();
        drop(w);
        let (_, replayed) = Wal::open(&p).unwrap();
        assert_eq!(replayed, vec![("after".to_string(), b"reset".to_vec())]);
    }

    #[test]
    fn oversized_records_are_rejected_up_front() {
        let p = tmp("oversized");
        let (mut w, _) = Wal::open(&p).unwrap();
        let key = "k".repeat(MAX_PAYLOAD_BYTES as usize + 1);
        assert!(w.append(&key, b"v").is_err());
        assert_eq!(w.bytes(), 0, "nothing was written");
    }
}
