//! Spill segments: evicted packed triangles parked on disk.
//!
//! When the LRU [`DatasetCache`](crate::service::DatasetCache) evicts a
//! dataset, its packed `n(n-1)/2` buffer — the expensive, memory-bound
//! part of a load — is written here instead of being dropped outright.
//! A later miss on the same dataset key reloads the segment instead of
//! re-streaming (or re-generating) the source.
//!
//! Segment layout, modelled on the `PDM1` row format but packed-only:
//!
//! ```text
//! [b"SPL1"] [u32 LE key_len] [dataset key utf-8]
//! [u64 LE n] [u64 LE label count] [labels u32 LE ...]
//! [values f32 LE ...]            (n(n-1)/2 entries, scipy pdist order)
//! ```
//!
//! The full dataset key is stored (not just its hash, which names the
//! file) so a hash collision degrades to a clean miss.  Reloads are
//! **re-validated**: the values stream back through the same
//! [`TriangleSink`] every loader uses, so a corrupt or truncated segment
//! is rejected exactly like a corrupt source file — and the grouping is
//! rebuilt through [`Grouping::new`]'s own validation.  The reloaded
//! buffer is a fresh allocation (`Arc`-fresh) holding bit-identical
//! values — the equality the persistence suite pins.
//!
//! Spilling is best-effort by design: callers treat any error as "the
//! segment does not exist" and fall back to a full load.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::dmat::{CondensedMatrix, TriangleSink};
use crate::error::{Error, Result};
use crate::permanova::Grouping;

use super::fnv64_bytes;

/// Segment file magic.
pub const SPILL_MAGIC: &[u8; 4] = b"SPL1";

/// Implausibility bound shared with the `PDM1` reader.
const MAX_N: u64 = 1 << 20;

/// A directory of spill segments, one per dataset key.
#[derive(Debug)]
pub struct SpillDir {
    dir: PathBuf,
    spilled: AtomicU64,
    reloaded: AtomicU64,
}

/// Spill activity counters plus the current on-disk segment footprint.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpillStats {
    /// Evictions written out this process lifetime.
    pub spilled: u64,
    /// Segment reloads served this process lifetime.
    pub reloaded: u64,
    /// Segments currently on disk.
    pub segments: usize,
    /// Their total size in bytes.
    pub disk_bytes: u64,
}

impl SpillDir {
    /// Open (creating if absent) the segment directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<SpillDir> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .map_err(|e| Error::io(dir.display().to_string(), e))?;
        Ok(SpillDir { dir, spilled: AtomicU64::new(0), reloaded: AtomicU64::new(0) })
    }

    /// Write (or overwrite — the content is a pure function of the key)
    /// the segment for `key`, atomically via `.tmp` + rename.
    pub fn spill(&self, key: &str, tri: &CondensedMatrix, grouping: &Grouping) -> Result<()> {
        // Fault seam: spilling is best-effort by contract, so an injected
        // error here proves callers really do fall back to a full load.
        if let Some(e) = crate::inject::io_error("store.spill.write") {
            return Err(Error::io(self.segment_path(key).display().to_string(), e));
        }
        let path = self.segment_path(key);
        let tmp = super::ss_table::tmp_path(&path);
        let ctx = || tmp.display().to_string();
        let file = File::create(&tmp).map_err(|e| Error::io(ctx(), e))?;
        let mut w = BufWriter::new(file);
        w.write_all(SPILL_MAGIC).map_err(|e| Error::io(ctx(), e))?;
        w.write_all(&(key.len() as u32).to_le_bytes())
            .map_err(|e| Error::io(ctx(), e))?;
        w.write_all(key.as_bytes()).map_err(|e| Error::io(ctx(), e))?;
        w.write_all(&(tri.n() as u64).to_le_bytes())
            .map_err(|e| Error::io(ctx(), e))?;
        let labels = grouping.labels();
        w.write_all(&(labels.len() as u64).to_le_bytes())
            .map_err(|e| Error::io(ctx(), e))?;
        for label in labels {
            w.write_all(&label.to_le_bytes()).map_err(|e| Error::io(ctx(), e))?;
        }
        for v in tri.values() {
            w.write_all(&v.to_le_bytes()).map_err(|e| Error::io(ctx(), e))?;
        }
        let file = w.into_inner().map_err(|e| Error::io(ctx(), e.into_error()))?;
        file.sync_all().map_err(|e| Error::io(ctx(), e))?;
        drop(file);
        std::fs::rename(&tmp, &path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        self.spilled.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Reload the segment for `key`, if present: values re-validated
    /// through [`TriangleSink`], grouping through [`Grouping::new`].
    /// `Ok(None)` covers both "never spilled" and a key-hash collision.
    pub fn load(&self, key: &str) -> Result<Option<(CondensedMatrix, Grouping)>> {
        let path = self.segment_path(key);
        let file = match File::open(&path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(Error::io(path.display().to_string(), e)),
        };
        let ctx = || path.display().to_string();
        let bad = |msg: &str| Error::parse("spill", path.display().to_string(), msg.to_string());
        let mut r = BufReader::new(file);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic).map_err(|e| Error::io(ctx(), e))?;
        if &magic != SPILL_MAGIC {
            return Err(bad("bad magic"));
        }
        let mut len4 = [0u8; 4];
        r.read_exact(&mut len4).map_err(|e| Error::io(ctx(), e))?;
        let klen = u32::from_le_bytes(len4) as usize;
        if klen > 1 << 16 {
            return Err(bad("implausible key length"));
        }
        let mut kbytes = vec![0u8; klen];
        r.read_exact(&mut kbytes).map_err(|e| Error::io(ctx(), e))?;
        let stored_key = String::from_utf8(kbytes).map_err(|_| bad("key is not utf-8"))?;
        if stored_key != key {
            // FNV collision between dataset keys: treat as absent rather
            // than serve another dataset's triangle.
            return Ok(None);
        }
        let mut len8 = [0u8; 8];
        r.read_exact(&mut len8).map_err(|e| Error::io(ctx(), e))?;
        let n = u64::from_le_bytes(len8);
        if n == 0 || n > MAX_N {
            return Err(bad("implausible n"));
        }
        let n = n as usize;
        r.read_exact(&mut len8).map_err(|e| Error::io(ctx(), e))?;
        let n_labels = u64::from_le_bytes(len8) as usize;
        if n_labels != n {
            return Err(bad("label count != n"));
        }
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            r.read_exact(&mut len4).map_err(|e| Error::io(ctx(), e))?;
            labels.push(u32::from_le_bytes(len4));
        }
        // Stream the packed values back through the loaders' validator.
        // Upper-only feed: the diagonal / mirror tolerance never applies,
        // so the sink only enforces finite + non-negative — the checks a
        // packed buffer can still violate via corruption.
        let mut sink = TriangleSink::new(n, 0.0);
        let mut pos = 0usize;
        let mut buf = [0u8; 4];
        for row in 0..n {
            for col in row + 1..n {
                r.read_exact(&mut buf).map_err(|e| {
                    Error::io(format!("{} value {pos}", path.display()), e)
                })?;
                sink.entry(row, col, f32::from_le_bytes(buf))?;
                pos += 1;
            }
        }
        let tri = sink.finish()?;
        let grouping = Grouping::new(labels)?;
        self.reloaded.fetch_add(1, Ordering::Relaxed);
        Ok(Some((tri, grouping)))
    }

    /// Counters + a directory scan for the resident-segment footprint.
    pub fn stats(&self) -> SpillStats {
        let mut segments = 0usize;
        let mut disk_bytes = 0u64;
        if let Ok(entries) = std::fs::read_dir(&self.dir) {
            for entry in entries.flatten() {
                let is_seg = entry
                    .path()
                    .extension()
                    .map(|e| e == "seg")
                    .unwrap_or(false);
                if is_seg {
                    segments += 1;
                    disk_bytes += entry.metadata().map(|m| m.len()).unwrap_or(0);
                }
            }
        }
        SpillStats {
            spilled: self.spilled.load(Ordering::Relaxed),
            reloaded: self.reloaded.load(Ordering::Relaxed),
            segments,
            disk_bytes,
        }
    }

    /// The directory segments live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn segment_path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("spill-{:016x}.seg", fnv64_bytes(key.as_bytes())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dmat::ingest::random_euclidean_condensed;

    fn tmp(case: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("permanova_apu_store_spill_test_{case}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample(n: usize) -> (CondensedMatrix, Grouping) {
        let tri = random_euclidean_condensed(n, 6, 42);
        let labels: Vec<u32> = (0..n as u32).map(|i| i % 3).collect();
        (tri, Grouping::new(labels).unwrap())
    }

    #[test]
    fn spill_then_load_is_value_bitwise_equal() {
        let d = SpillDir::open(tmp("roundtrip")).unwrap();
        let (tri, grouping) = sample(17);
        d.spill("ds-key", &tri, &grouping).unwrap();
        let (back_tri, back_grouping) = d.load("ds-key").unwrap().expect("segment exists");
        let a: Vec<u32> = tri.values().iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = back_tri.values().iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "reload is value-bitwise-equal");
        assert_eq!(back_tri.n(), 17);
        assert_eq!(back_grouping.labels(), grouping.labels());
        let s = d.stats();
        assert_eq!((s.spilled, s.reloaded, s.segments), (1, 1, 1));
        assert!(s.disk_bytes > 0);
    }

    #[test]
    fn absent_key_is_a_clean_miss() {
        let d = SpillDir::open(tmp("absent")).unwrap();
        assert!(d.load("never-spilled").unwrap().is_none());
        assert_eq!(d.stats().reloaded, 0);
    }

    #[test]
    fn stored_key_mismatch_degrades_to_miss() {
        let d = SpillDir::open(tmp("collision")).unwrap();
        let (tri, grouping) = sample(9);
        d.spill("key-a", &tri, &grouping).unwrap();
        // Simulate an FNV collision: point key-b's file name at key-a's
        // segment content.
        std::fs::copy(d.segment_path("key-a"), d.segment_path("key-b")).unwrap();
        assert!(d.load("key-b").unwrap().is_none(), "stored key wins over file name");
    }

    #[test]
    fn corrupt_segments_are_errors_not_data() {
        let d = SpillDir::open(tmp("corrupt")).unwrap();
        let (tri, grouping) = sample(9);
        d.spill("k", &tri, &grouping).unwrap();
        let path = d.segment_path("k");
        // Truncate mid-values: the sink's "ended early" check fires.
        let raw = std::fs::read(&path).unwrap();
        std::fs::write(&path, &raw[..raw.len() - 6]).unwrap();
        assert!(d.load("k").is_err());
        // Inject a NaN value: the sink's finite check fires.
        let mut raw2 = raw.clone();
        let at = raw2.len() - 4;
        raw2[at..].copy_from_slice(&f32::NAN.to_le_bytes());
        std::fs::write(&path, &raw2).unwrap();
        let e = d.load("k").unwrap_err().to_string();
        assert!(e.contains("non-finite"), "{e}");
        // Foreign bytes: rejected at the magic.
        std::fs::write(&path, b"XXXXjunk").unwrap();
        assert!(d.load("k").is_err());
    }

    #[test]
    fn respill_overwrites_idempotently() {
        let d = SpillDir::open(tmp("respill")).unwrap();
        let (tri, grouping) = sample(9);
        d.spill("k", &tri, &grouping).unwrap();
        d.spill("k", &tri, &grouping).unwrap();
        let s = d.stats();
        assert_eq!((s.spilled, s.segments), (2, 1), "one file, counted per spill");
        assert!(d.load("k").unwrap().is_some());
    }
}
