//! Micro-benchmark harness: warmup, adaptive iteration, robust statistics —
//! plus the PERMANOVA backend sweep behind the `bench` CLI subcommand.
//!
//! The offline crate set has no criterion — and a benchmarking paper
//! deserves a first-class harness anyway.  The design follows STREAM's
//! methodology (the paper's own appendix): fixed warmup, best-and-median of
//! N timed repetitions, and robust spread (median absolute deviation) so a
//! noisy-neighbour run doesn't poison a comparison.
//!
//! ```no_run
//! use permanova_apu::bench::Bencher;
//! let mut b = Bencher::default();
//! let m = b.run("sum", || (0..1_000_000u64).sum::<u64>());
//! println!("{}", m.format_row());
//! ```
//!
//! The sweep half ([`SweepGrid`], [`run_sweep`], [`validate_bench_json`])
//! drives every registered backend over an n × permutations grid through
//! the unified engine and emits the repo's performance record,
//! `BENCH_PERMANOVA.json` (schema [`BENCH_SCHEMA`]) — the baseline every
//! later kernel/backend PR is measured against.

use std::time::{Duration, Instant};

use crate::config::{DataSource, RunConfig};
use crate::error::{Error, Result};
use crate::jsonio::Json;
use crate::permanova::Method;
use crate::report::Table;
use crate::request::AnalysisRequest;

/// Benchmark configuration.
#[derive(Clone, Debug)]
pub struct Bencher {
    /// Warmup repetitions (not timed).
    pub warmup: usize,
    /// Minimum timed repetitions.
    pub min_reps: usize,
    /// Maximum timed repetitions.
    pub max_reps: usize,
    /// Time budget per benchmark; reps stop early once exceeded (but never
    /// before `min_reps`).
    pub max_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: 2,
            min_reps: 5,
            max_reps: 50,
            max_time: Duration::from_secs(10),
        }
    }
}

impl Bencher {
    /// Quick preset for heavyweight end-to-end benches.
    pub fn heavy() -> Self {
        Bencher { warmup: 1, min_reps: 3, max_reps: 10, max_time: Duration::from_secs(30) }
    }

    /// Time `f` under this configuration.  The closure's return value is
    /// passed through `std::hint::black_box` so the computation cannot be
    /// optimized away.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.min_reps);
        let started = Instant::now();
        while times.len() < self.max_reps {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
            if times.len() >= self.min_reps && started.elapsed() > self.max_time {
                break;
            }
        }
        Measurement::from_times(name, times)
    }
}

/// Robust statistics of one benchmark.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Individual repetition times, seconds, in run order.
    pub times: Vec<f64>,
    pub best: f64,
    pub median: f64,
    pub mean: f64,
    /// Median absolute deviation (scaled by 1.4826 ≈ σ for normal data).
    pub mad: f64,
    pub worst: f64,
}

impl Measurement {
    /// Compute stats from raw times.
    pub fn from_times(name: &str, times: Vec<f64>) -> Measurement {
        assert!(!times.is_empty(), "no timings for {name}");
        let mut sorted = times.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let best = sorted[0];
        let worst = *sorted.last().unwrap();
        let median = percentile_sorted(&sorted, 50.0);
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = 1.4826 * percentile_sorted(&devs, 50.0);
        Measurement { name: name.to_string(), times, best, median, mean, mad, worst }
    }

    /// Bandwidth implied by moving `bytes` in the *best* time (STREAM's
    /// convention), in GB/s (10^9).
    pub fn best_rate_gbs(&self, bytes: usize) -> f64 {
        bytes as f64 / self.best / 1e9
    }

    /// Throughput at the median time, items per second.
    pub fn median_throughput(&self, items: usize) -> f64 {
        items as f64 / self.median
    }

    /// One formatted report row.
    pub fn format_row(&self) -> String {
        format!(
            "{:<36} best {:>10} median {:>10} ±{:>9} (n={})",
            self.name,
            format_secs(self.best),
            format_secs(self.median),
            format_secs(self.mad),
            self.times.len()
        )
    }
}

/// Percentile (0–100) of an ascending-sorted slice, linear interpolation.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Human-readable seconds (ns/µs/ms/s autoscale).
pub fn format_secs(t: f64) -> String {
    if t < 1e-6 {
        format!("{:.1}ns", t * 1e9)
    } else if t < 1e-3 {
        format!("{:.2}µs", t * 1e6)
    } else if t < 1.0 {
        format!("{:.2}ms", t * 1e3)
    } else {
        format!("{:.3}s", t)
    }
}

/// Speedup of `b` relative to `a` (how many times faster is b), by medians.
pub fn speedup(a: &Measurement, b: &Measurement) -> f64 {
    a.median / b.median
}

// ---------------------------------------------------------------------------
// The PERMANOVA backend sweep (the `bench` CLI subcommand's engine).
// ---------------------------------------------------------------------------

/// Schema identifier stamped into (and required from) `BENCH_PERMANOVA.json`.
/// v2 added the per-cell `method` field (the statistic axis of the sweep);
/// v3 added the top-level `throughput` section (service-layer jobs/sec,
/// cold vs warm dataset cache); v4 added the per-cell **memory-traffic
/// axis** (`bytes_per_perm`, `effective_gbs`, `packed_bytes` /
/// `dense_bytes` / `footprint_ratio`) — the packed-triangle layout's win,
/// measured instead of asserted; v5 added the top-level `latency` section
/// (open-loop p50/p99 request latency against an in-process TCP daemon,
/// swept over concurrent client counts); v6 added the per-cell
/// `resident_bytes` field — what the dense-free ingestion path actually
/// keeps resident (the packed values plus the row-offset table), which the
/// validator pins to exactly `packed_bytes + 8·(n+1)` so a footprint that
/// quietly re-grows a dense copy fails CI.  `dense_bytes` is since v6 the
/// **avoided** dense footprint, kept for the ratio axis.  v7 added the
/// top-level `restart_warm` section — the durable-store axis: identical
/// repeated jobs (same permutation seed) timed **cold** (no cache, no
/// store), **process-warm** (shared in-memory `DatasetCache`, still
/// recomputing every permutation sweep) and **disk-warm** (a fresh process
/// image answering every job from a pre-populated
/// [`ResultStore`](crate::store::ResultStore) without touching the engine);
/// the validator pins `store_hits == jobs` so a disk-warm pass that
/// quietly recomputes fails CI.  v8 added the top-level `oocore` section —
/// the residency-cap axis: the same PERMANOVA cell timed uncapped
/// (resident packed triangle) and under `--max-resident-bytes` at a
/// quarter of the packed triangle (spilled to a chunk file, swept
/// chunk-major), recording the capped run's paging counters and both
/// statistics as exact f64 bit patterns; the validator pins
/// `chunks_paged >= 1` and bitwise-equal `f_obs`/`p_value`, so a capped
/// sweep that either stops paging or drifts by one ULP fails CI.
pub const BENCH_SCHEMA: &str = "bench-permanova/v8";

/// Bytes each permutation streams through its statistic kernel: the
/// method's packed per-permutation operand plus the n-label row.
///
/// * PERMANOVA (and each pairwise sub-job): the packed f32 triangle,
///   `n(n-1)/2 · 4`;
/// * ANOSIM: the condensed f64 mid-ranks, `n(n-1)/2 · 8`;
/// * PERMDISP: the f64 distance-to-centroid vector, `n · 8`.
///
/// `n` is the problem the kernel actually sweeps (for pairwise cells, the
/// primary pair's sub-problem size).
pub fn bytes_per_perm(method: Method, n: usize) -> u64 {
    let n = n as u64;
    let pairs = n * n.saturating_sub(1) / 2;
    let labels = 4 * n;
    match method {
        Method::Permanova | Method::PairwisePermanova => pairs * 4 + labels,
        Method::Anosim => pairs * 8 + labels,
        Method::Permdisp => 8 * n + labels,
    }
}

/// The grid a benchmark sweep covers: backends × methods × n ×
/// permutation counts, plus the scheduling knobs shared by every cell.
#[derive(Clone, Debug)]
pub struct SweepGrid {
    /// Registry names to benchmark (validated against the registry).
    pub backends: Vec<String>,
    /// Methods to benchmark (`--methods permanova,anosim`); the default
    /// sweep pins PERMANOVA so the standing performance record keeps one
    /// statistic per cell family.
    pub methods: Vec<Method>,
    /// Matrix sizes (synthetic Euclidean data, one dataset per n).
    pub n_grid: Vec<usize>,
    /// Permutation counts.
    pub perm_grid: Vec<usize>,
    /// Groups in the synthetic grouping.
    pub n_groups: usize,
    /// Seed / threads / shard size / SMT / perm_block for every cell
    /// (data source, backend and n_perms are overwritten per cell).
    pub base: RunConfig,
    /// Timing policy for each cell.
    pub bencher: Bencher,
    /// Whether this was the CI smoke grid (recorded in the JSON).
    pub quick: bool,
    /// Jobs per throughput cell (the service-layer cold-vs-warm axis);
    /// 0 skips the throughput section entirely.  Also the per-client
    /// request count of the daemon latency axis.
    pub throughput_jobs: usize,
    /// Concurrent-client counts for the daemon latency axis (v5): each
    /// entry spawns an in-process TCP daemon and measures open-loop
    /// request latency under that many pipelined client connections.
    /// Empty skips the latency section entirely.
    pub latency_clients: Vec<usize>,
}

impl Default for SweepGrid {
    /// The standing grid: every native formulation plus the modelled GPU,
    /// at sizes where the access-pattern differences are visible but a
    /// full sweep still finishes in minutes.
    fn default() -> Self {
        SweepGrid {
            backends: default_bench_backends(),
            methods: vec![Method::Permanova],
            n_grid: vec![128, 256],
            perm_grid: vec![499],
            n_groups: 8,
            base: RunConfig::default(),
            // warmup 0: the sweep's pre-flight run doubles as the warmup.
            bencher: Bencher {
                warmup: 0,
                min_reps: 3,
                max_reps: 10,
                max_time: Duration::from_secs(5),
            },
            quick: false,
            throughput_jobs: 6,
            latency_clients: vec![1, 4],
        }
    }
}

impl SweepGrid {
    /// The CI smoke grid: same backend axis, toy sizes, minimal reps —
    /// fast enough to gate every push while still exercising the full
    /// sweep → JSON → validate pipeline.
    pub fn quick() -> Self {
        SweepGrid {
            n_grid: vec![48],
            perm_grid: vec![99],
            n_groups: 4,
            bencher: Bencher {
                warmup: 0,
                min_reps: 2,
                max_reps: 3,
                max_time: Duration::from_secs(1),
            },
            quick: true,
            throughput_jobs: 4,
            latency_clients: vec![2],
            ..Default::default()
        }
    }
}

/// The backend axis a default sweep covers (every distinct formulation the
/// paper compares: the three CPU kernels, the batched brute engine, the
/// modelled MI300A GPU).  `native` is omitted because it resolves to the
/// same tiled512 kernel as `native-tiled` — it would time an identical
/// cell twice; select it explicitly via `--backends` if wanted.
pub fn default_bench_backends() -> Vec<String> {
    ["native-brute", "native-tiled", "native-flat", "native-batch", "simulator-gpu"]
        .into_iter()
        .map(String::from)
        .collect()
}

/// One completed sweep: the machine-readable document, the rendered table,
/// and the cell count.
pub struct SweepOutput {
    pub json: Json,
    pub table: String,
    pub entries: usize,
}

/// Run the sweep: every cell goes through [`crate::backend::execute`] (the
/// same path the CLI's `run` takes), pre-flighted once for errors, then
/// timed under the grid's [`Bencher`].
pub fn run_sweep(grid: &SweepGrid) -> Result<SweepOutput> {
    let registry = crate::backend::Registry::with_defaults();
    if grid.backends.is_empty() {
        return Err(Error::Config("bench: empty backend list".into()));
    }
    for b in &grid.backends {
        if !registry.contains(b) {
            return Err(Error::UnknownBackend { name: b.clone(), known: registry.names() });
        }
    }
    if grid.methods.is_empty() {
        return Err(Error::Config("bench: empty method list".into()));
    }
    if grid.n_grid.is_empty() || grid.perm_grid.is_empty() {
        return Err(Error::Config("bench: empty n / n_perms grid".into()));
    }

    let mut entries = Vec::new();
    let cols = [
        "backend", "method", "kernel", "n", "perms", "block", "median", "best", "perms/s",
        "GB/s", "modelled",
    ];
    let mut table = Table::new(&cols);
    for &n in &grid.n_grid {
        let mut cell = grid.base.clone();
        cell.data = DataSource::Synthetic { n_dims: n, n_groups: grid.n_groups };
        // The streamed loader emits the packed triangle directly — the
        // only resident copy; every timed run below hands it through
        // `with_condensed` without any dense staging.
        let (tri, grouping) = crate::coordinator::load_data(&cell)?;
        for &n_perms in &grid.perm_grid {
            for backend in &grid.backends {
                for &method in &grid.methods {
                    let mut cfg = cell.clone();
                    cfg.backend = backend.clone();
                    cfg.n_perms = n_perms;
                    cfg.method = method;
                    cfg.validate()?;
                    // Pre-flight once so a misconfigured cell fails with a
                    // typed error instead of a panic inside the timing
                    // loop; this run is also the cell's warmup (grid
                    // warmup is 0) and the source of method/kernel/block
                    // provenance.
                    let report = AnalysisRequest::new(&cfg).with_condensed(&tri, &grouping).run()?;
                    let mut bencher = grid.bencher.clone();
                    let m = bencher
                        .run(&format!("{backend}/{}/n{n}/p{n_perms}", method.name()), || {
                            AnalysisRequest::new(&cfg)
                                .with_condensed(&tri, &grouping)
                                .run()
                                .expect("pre-flighted bench cell failed")
                        });
                    // Pairwise fans out one job per group pair; count the
                    // permutations actually evaluated, not the knob.
                    let total_perms = report.total_perms() as f64;
                    let perms_per_sec = total_perms / m.median;
                    // The v4 memory-traffic axis: bytes each permutation
                    // streams (the packed operand + label row, sized to the
                    // problem the kernel actually sweeps), the effective
                    // bandwidth that implies at the *best* time (STREAM's
                    // convention), and the dense→packed footprint ratio of
                    // the dataset the cell loaded.
                    let stream_n = report.primary().n;
                    let bpp = bytes_per_perm(method, stream_n);
                    let effective_gbs = bpp as f64 * total_perms / m.best / 1e9;
                    // v6: `dense_bytes` is the **avoided** footprint (no
                    // dense copy exists on any ingest path any more);
                    // `resident_bytes` is what the cell actually holds —
                    // the packed values plus the (n+1)-entry row-offset
                    // table — and matches `CondensedMatrix::resident_bytes`.
                    let dense_bytes = (n * n * 4) as u64;
                    let packed_bytes = (n * (n - 1) / 2 * 4) as u64;
                    let resident_bytes = tri.resident_bytes() as u64;
                    debug_assert_eq!(resident_bytes, packed_bytes + 8 * (n as u64 + 1));
                    let footprint_ratio = packed_bytes as f64 / dense_bytes as f64;
                    // Simulated backends model MI300A wall-clock alongside
                    // the exact numerics; 0.0 for real substrates.
                    let modelled_secs: f64 = report
                        .runs
                        .iter()
                        .flat_map(|r| r.per_device.iter())
                        .map(|d| d.simulated_secs)
                        .sum();
                    table.row(&[
                        backend.clone(),
                        method.name().to_string(),
                        report.kernel.clone(),
                        n.to_string(),
                        n_perms.to_string(),
                        if report.perm_block > 0 {
                            report.perm_block.to_string()
                        } else {
                            "-".to_string()
                        },
                        format_secs(m.median),
                        format_secs(m.best),
                        format!("{perms_per_sec:.0}"),
                        format!("{effective_gbs:.2}"),
                        if modelled_secs > 0.0 {
                            format_secs(modelled_secs)
                        } else {
                            "-".to_string()
                        },
                    ]);
                    entries.push(Json::obj(vec![
                        ("backend", Json::str(backend.clone())),
                        // The effective method axis of the cell (v2 field).
                        ("method", Json::str(method.name())),
                        ("kernel", Json::str(report.kernel.clone())),
                        ("n", Json::num(n as f64)),
                        ("k", Json::num(grid.n_groups as f64)),
                        ("n_perms", Json::num(n_perms as f64)),
                        ("perm_block", Json::num(report.perm_block as f64)),
                        ("threads", Json::num(cfg.threads as f64)),
                        ("shard_size", Json::num(cfg.shard_size as f64)),
                        ("smt_oversubscribe", Json::Bool(cfg.smt_oversubscribe)),
                        // String, not number: JSON numbers are f64 here and
                        // would silently round seeds above 2^53.
                        ("seed", Json::str(cfg.seed.to_string())),
                        ("reps", Json::num(m.times.len() as f64)),
                        ("best_secs", Json::num(m.best)),
                        ("median_secs", Json::num(m.median)),
                        ("mad_secs", Json::num(m.mad)),
                        ("perms_per_sec", Json::num(perms_per_sec)),
                        // v4 memory-traffic axis.
                        ("bytes_per_perm", Json::num(bpp as f64)),
                        ("effective_gbs", Json::num(effective_gbs)),
                        ("dense_bytes", Json::num(dense_bytes as f64)),
                        ("packed_bytes", Json::num(packed_bytes as f64)),
                        // v6: the packed-only residency of the loaded cell.
                        ("resident_bytes", Json::num(resident_bytes as f64)),
                        ("footprint_ratio", Json::num(footprint_ratio)),
                        ("modelled_secs", Json::num(modelled_secs)),
                        // Scheduled jobs in the cell (1, except pairwise =
                        // one per group pair).  f_obs/p_value below are the
                        // *primary* job's statistics — for pairwise that is
                        // the (0, 1) pair, and timings cover all jobs.
                        ("jobs", Json::num(report.runs.len() as f64)),
                        ("f_obs", Json::num(report.f_obs)),
                        ("p_value", Json::num(report.p_value)),
                    ]));
                }
            }
        }
    }
    let (throughput, throughput_table) = run_throughput_axis(grid)?;
    let (restart_warm, restart_table) = run_restart_axis(grid)?;
    let (oocore, oocore_table) = run_oocore_axis(grid)?;
    let (latency, latency_table) = run_latency_axis(grid)?;

    let entry_count = entries.len();
    let host_threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    let json = Json::obj(vec![
        ("schema", Json::str(BENCH_SCHEMA)),
        ("version", Json::str(crate::VERSION)),
        ("quick", Json::Bool(grid.quick)),
        ("host_threads", Json::num(host_threads as f64)),
        ("entries", Json::Arr(entries)),
        ("throughput", Json::Arr(throughput)),
        ("restart_warm", Json::Arr(restart_warm)),
        ("oocore", Json::Arr(oocore)),
        ("latency", Json::Arr(latency)),
    ]);
    let mut rendered = table.render();
    if !throughput_table.is_empty() {
        rendered.push('\n');
        rendered.push_str(&throughput_table);
    }
    if !restart_table.is_empty() {
        rendered.push('\n');
        rendered.push_str(&restart_table);
    }
    if !oocore_table.is_empty() {
        rendered.push('\n');
        rendered.push_str(&oocore_table);
    }
    if !latency_table.is_empty() {
        rendered.push('\n');
        rendered.push_str(&latency_table);
    }
    Ok(SweepOutput { json, table: rendered, entries: entry_count })
}

/// The service-layer throughput axis: for every backend × method, run a
/// repeated-dataset batch of [`SweepGrid::throughput_jobs`] jobs twice —
/// **cold** (cache capacity 0: every job reloads the dataset and rebuilds
/// its prelude) and **warm** (one shared [`DatasetCache`]: the first job
/// loads, the rest hit) — and record jobs/sec for both.  The jobs share
/// one dataset (`data_seed` pinned) but draw distinct permutation seeds,
/// the shape a shared-dataset service actually sees.  Both passes run
/// through the same shared scheduler pool, so the comparison isolates the
/// cache, not thread-spawn costs.
///
/// [`DatasetCache`]: crate::service::DatasetCache
fn run_throughput_axis(grid: &SweepGrid) -> Result<(Vec<Json>, String)> {
    use crate::service::{run_jobs, DatasetCache, JobRequest};

    if grid.throughput_jobs == 0 {
        return Ok((Vec::new(), String::new()));
    }
    if grid.throughput_jobs < 2 {
        return Err(Error::Config(
            "bench: --throughput-jobs needs >= 2 jobs to compare cold vs warm (0 disables)"
                .into(),
        ));
    }
    let jobs = grid.throughput_jobs;
    // One cell per backend × method at the grid's largest n (where the
    // dataset-load share is biggest) and smallest permutation count.
    let n = *grid.n_grid.iter().max().expect("validated non-empty");
    let n_perms = *grid.perm_grid.iter().min().expect("validated non-empty");

    let mut entries = Vec::new();
    let mut table =
        Table::new(&["backend", "method", "n", "perms", "jobs", "cold", "warm", "warm/cold"]);
    for backend in &grid.backends {
        for &method in &grid.methods {
            let mut cfg = grid.base.clone();
            cfg.data = DataSource::Synthetic { n_dims: n, n_groups: grid.n_groups };
            cfg.backend = backend.clone();
            cfg.method = method;
            cfg.n_perms = n_perms;
            // Pin the dataset, vary the permutation stream per job.
            cfg.data_seed = Some(cfg.seed);
            let requests: Vec<JobRequest> = (0..jobs)
                .map(|i| {
                    let mut job = cfg.clone();
                    job.seed = cfg.seed.wrapping_add(i as u64);
                    JobRequest::new(format!("{backend}-{}-{i}", method.name()), job)
                })
                .collect();

            let cold_cache = DatasetCache::new(0);
            let cold = run_jobs(&requests, &cold_cache, grid.base.threads);
            let warm_cache = DatasetCache::new(2);
            let warm = run_jobs(&requests, &warm_cache, grid.base.threads);
            for (label, batch) in [("cold", &cold), ("warm", &warm)] {
                if batch.summary.failed > 0 {
                    return Err(Error::Config(format!(
                        "throughput cell {backend}/{} ({label}): {} of {} jobs failed",
                        method.name(),
                        batch.summary.failed,
                        batch.summary.jobs
                    )));
                }
            }
            let warm_stats = warm_cache.stats();

            table.row(&[
                backend.clone(),
                method.name().to_string(),
                n.to_string(),
                n_perms.to_string(),
                jobs.to_string(),
                crate::report::format_rate(cold.summary.jobs_per_sec, "jobs"),
                crate::report::format_rate(warm.summary.jobs_per_sec, "jobs"),
                format!("{:.2}x", warm.summary.jobs_per_sec / cold.summary.jobs_per_sec),
            ]);
            entries.push(Json::obj(vec![
                ("backend", Json::str(backend.clone())),
                ("method", Json::str(method.name())),
                ("n", Json::num(n as f64)),
                ("k", Json::num(grid.n_groups as f64)),
                ("n_perms", Json::num(n_perms as f64)),
                ("jobs", Json::num(jobs as f64)),
                ("cold_secs", Json::num(cold.summary.elapsed_secs)),
                ("cold_jobs_per_sec", Json::num(cold.summary.jobs_per_sec)),
                ("warm_secs", Json::num(warm.summary.elapsed_secs)),
                ("warm_jobs_per_sec", Json::num(warm.summary.jobs_per_sec)),
                ("warm_hits", Json::num(warm_stats.hits as f64)),
                ("warm_misses", Json::num(warm_stats.misses as f64)),
            ]));
        }
    }
    let rendered = format!(
        "service throughput ({jobs} jobs/cell, repeated dataset, cold vs warm cache):\n{}",
        table.render()
    );
    Ok((entries, rendered))
}

/// Monotonic sequence for restart-axis store directories, so concurrent
/// sweeps inside one process (the test suite) never share a store.
static RESTART_DIR_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// The restart-warm axis (v7): what the durable store buys across a
/// process restart, measured instead of asserted.  For every backend ×
/// method, a batch of [`SweepGrid::throughput_jobs`] **identical** jobs
/// (same dataset, same permutation seed — the only shape the result store
/// can answer) runs at three temperatures:
///
/// * **cold** — capacity-0 cache, no store: every job reloads the dataset,
///   rebuilds its prelude and sweeps every permutation;
/// * **process-warm** — shared in-memory [`DatasetCache`]: loads and
///   preludes amortize, but every job still recomputes its permutation
///   sweep (results are not memoized in memory — that is the store's job);
/// * **disk-warm** — a *fresh* cache over a [`ResultStore`] pre-populated
///   by an untimed seeding batch and reopened from disk, modelling a
///   daemon restarted over the same `--store-dir`: every job returns the
///   previously serialized report without touching the engine.
///
/// The recorded `store_hits` must equal `jobs` (the validator pins it) —
/// a disk-warm pass that quietly recomputes is a bug, not a slow cell.
///
/// [`DatasetCache`]: crate::service::DatasetCache
/// [`ResultStore`]: crate::store::ResultStore
fn run_restart_axis(grid: &SweepGrid) -> Result<(Vec<Json>, String)> {
    use crate::service::{run_jobs, DatasetCache, JobRequest};
    use crate::store::{ResultStore, StoreConfig};
    use std::sync::Arc;

    if grid.throughput_jobs == 0 {
        // The store axis shares the throughput axis's job-count knob (and
        // its 0-disables contract): both measure service-layer batches.
        return Ok((Vec::new(), String::new()));
    }
    let jobs = grid.throughput_jobs;
    let n = *grid.n_grid.iter().max().expect("validated non-empty");
    let n_perms = *grid.perm_grid.iter().min().expect("validated non-empty");

    let mut entries = Vec::new();
    let mut table = Table::new(&[
        "backend", "method", "n", "perms", "jobs", "cold", "proc-warm", "disk-warm",
        "disk/cold",
    ]);
    for backend in &grid.backends {
        for &method in &grid.methods {
            let mut cfg = grid.base.clone();
            cfg.data = DataSource::Synthetic { n_dims: n, n_groups: grid.n_groups };
            cfg.backend = backend.clone();
            cfg.method = method;
            cfg.n_perms = n_perms;
            cfg.data_seed = Some(cfg.seed);
            // Identical jobs: the store key is (dataset, method, seed,
            // perms, tol), so only an exact repeat can hit.
            let requests: Vec<JobRequest> = (0..jobs)
                .map(|i| JobRequest::new(format!("restart-{backend}-{}-{i}", method.name()), cfg.clone()))
                .collect();
            let check = |label: &str, batch: &crate::service::BatchOutcome| -> Result<()> {
                if batch.summary.failed > 0 {
                    return Err(Error::Config(format!(
                        "restart cell {backend}/{} ({label}): {} of {} jobs failed",
                        method.name(),
                        batch.summary.failed,
                        batch.summary.jobs
                    )));
                }
                Ok(())
            };

            let seq = RESTART_DIR_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let dir = std::env::temp_dir()
                .join(format!("permanova_apu_bench_restart_{}_{seq}", std::process::id()));
            let _ = std::fs::remove_dir_all(&dir);

            // Cold: nothing amortizes.
            let cold_cache = DatasetCache::new(0);
            let cold = run_jobs(&requests, &cold_cache, grid.base.threads);
            check("cold", &cold)?;
            // Process-warm: the in-memory tier only.
            let warm_cache = DatasetCache::new(2);
            let process_warm = run_jobs(&requests, &warm_cache, grid.base.threads);
            check("process-warm", &process_warm)?;
            // Seed the store (untimed — this is the pre-restart process),
            // drain it to a sorted table, then drop every handle: the
            // disk-warm pass below must reopen purely from disk.
            let store = Arc::new(ResultStore::open(StoreConfig::new(&dir))?);
            let seed_cache = DatasetCache::with_store(2, Arc::clone(&store));
            let seeding = run_jobs(&requests, &seed_cache, grid.base.threads);
            check("seeding", &seeding)?;
            store.drain()?;
            let puts = store.stats().puts;
            drop(seed_cache);
            drop(store);
            // Disk-warm: a restarted process answering from the store.
            let store = Arc::new(ResultStore::open(StoreConfig::new(&dir))?);
            let disk_cache = DatasetCache::with_store(2, Arc::clone(&store));
            let disk_warm = run_jobs(&requests, &disk_cache, grid.base.threads);
            check("disk-warm", &disk_warm)?;
            let store_hits = store.stats().hits;
            if store_hits != jobs as u64 {
                return Err(Error::Config(format!(
                    "restart cell {backend}/{}: disk-warm pass hit the store {store_hits} of \
                     {jobs} times — the durable tier is not answering identical jobs",
                    method.name()
                )));
            }
            let _ = std::fs::remove_dir_all(&dir);

            table.row(&[
                backend.clone(),
                method.name().to_string(),
                n.to_string(),
                n_perms.to_string(),
                jobs.to_string(),
                crate::report::format_rate(cold.summary.jobs_per_sec, "jobs"),
                crate::report::format_rate(process_warm.summary.jobs_per_sec, "jobs"),
                crate::report::format_rate(disk_warm.summary.jobs_per_sec, "jobs"),
                format!("{:.2}x", disk_warm.summary.jobs_per_sec / cold.summary.jobs_per_sec),
            ]);
            entries.push(Json::obj(vec![
                ("backend", Json::str(backend.clone())),
                ("method", Json::str(method.name())),
                ("n", Json::num(n as f64)),
                ("k", Json::num(grid.n_groups as f64)),
                ("n_perms", Json::num(n_perms as f64)),
                ("jobs", Json::num(jobs as f64)),
                ("cold_secs", Json::num(cold.summary.elapsed_secs)),
                ("cold_jobs_per_sec", Json::num(cold.summary.jobs_per_sec)),
                ("process_warm_secs", Json::num(process_warm.summary.elapsed_secs)),
                (
                    "process_warm_jobs_per_sec",
                    Json::num(process_warm.summary.jobs_per_sec),
                ),
                ("disk_warm_secs", Json::num(disk_warm.summary.elapsed_secs)),
                ("disk_warm_jobs_per_sec", Json::num(disk_warm.summary.jobs_per_sec)),
                ("store_hits", Json::num(store_hits as f64)),
                ("store_puts", Json::num(puts as f64)),
            ]));
        }
    }
    let rendered = format!(
        "restart warmth ({jobs} identical jobs/cell: no cache vs in-memory cache vs reopened \
         store):\n{}",
        table.render()
    );
    Ok((entries, rendered))
}

/// The out-of-core axis (v8): the same PERMANOVA cell timed **uncapped**
/// (resident packed triangle) and **capped** (`--max-resident-bytes` at a
/// quarter of the packed triangle, so the dataset spills to a chunk file
/// at ingest and every sweep pages it back chunk-major), one cell per
/// backend at the grid's largest n and smallest permutation count.
///
/// Each cell records the capped run's paging counters and both runs'
/// statistics — the latter as exact f64 **bit patterns** (strings, the
/// `seed` idiom: JSON numbers are f64-via-decimal here and must not
/// arbitrate a bitwise claim).  The axis's defining invariant is that
/// capped ≡ uncapped bit for bit: a chunked sweep that drifts by one ULP
/// is a broken kernel, not noise, and the cell (and validator) fail
/// rather than record it.  PERMANOVA only: ANOSIM/PERMDISP honestly
/// refuse file-backed datasets (their kernels rank/eigendecompose the
/// whole triangle), so a capped cell for them has nothing to time;
/// backends whose engines cannot sweep chunks (the AOT XLA runtime) are
/// skipped, not failed.
fn run_oocore_axis(grid: &SweepGrid) -> Result<(Vec<Json>, String)> {
    let n = *grid.n_grid.iter().max().expect("validated non-empty");
    let n_perms = *grid.perm_grid.iter().min().expect("validated non-empty");
    let packed_bytes = (n * (n - 1) / 2 * 4) as u64;
    // A quarter of the triangle: small enough that every sweep pages
    // several chunks, large enough that chunk-load overhead stays visible
    // rather than dominant.  Floor keeps toy grids above one f32 row.
    let cap = (packed_bytes / 4).max(256);

    let mut entries = Vec::new();
    let mut table = Table::new(&[
        "backend", "n", "perms", "cap", "chunks", "paged", "resident", "capped", "capped/resident",
    ]);
    for backend in &grid.backends {
        let mut cfg = grid.base.clone();
        cfg.data = DataSource::Synthetic { n_dims: n, n_groups: grid.n_groups };
        cfg.backend = backend.clone();
        cfg.method = Method::Permanova;
        cfg.n_perms = n_perms;
        cfg.max_resident_bytes = 0;
        cfg.validate()?;
        let mut capped_cfg = cfg.clone();
        capped_cfg.max_resident_bytes = cap;

        // Pre-flight both modes (doubling as warmup); these reports are
        // the cells' statistic/paging provenance.
        let resident = AnalysisRequest::new(&cfg).run()?;
        let capped = match AnalysisRequest::new(&capped_cfg).run() {
            Ok(report) => report,
            // An engine that cannot sweep chunks declines with a typed
            // config error naming the knob; that is a skip, not a failure.
            Err(Error::Config(msg)) if msg.contains("--max-resident-bytes") => continue,
            Err(e) => return Err(e),
        };
        let oo = capped.oocore.as_ref().ok_or_else(|| {
            Error::Config(format!(
                "oocore cell {backend}: capped run (--max-resident-bytes {cap}) reported no \
                 paging section"
            ))
        })?;
        if capped.f_obs.to_bits() != resident.f_obs.to_bits()
            || capped.p_value.to_bits() != resident.p_value.to_bits()
        {
            return Err(Error::Config(format!(
                "oocore cell {backend}: capped run diverged from resident run (f_obs {} vs {}, \
                 p {} vs {}) — the chunked sweep must be bitwise identical",
                capped.f_obs, resident.f_obs, capped.p_value, resident.p_value
            )));
        }

        let mut bencher = grid.bencher.clone();
        let resident_m = bencher.run(&format!("oocore/{backend}/resident"), || {
            AnalysisRequest::new(&cfg).run().expect("pre-flighted oocore cell failed")
        });
        let mut bencher = grid.bencher.clone();
        let capped_m = bencher.run(&format!("oocore/{backend}/capped"), || {
            AnalysisRequest::new(&capped_cfg).run().expect("pre-flighted oocore cell failed")
        });

        table.row(&[
            backend.clone(),
            n.to_string(),
            n_perms.to_string(),
            cap.to_string(),
            oo.chunks_paged.to_string(),
            crate::report::format_bytes(oo.bytes_paged),
            format_secs(resident_m.median),
            format_secs(capped_m.median),
            format!("{:.2}x", capped_m.median / resident_m.median),
        ]);
        entries.push(Json::obj(vec![
            ("backend", Json::str(backend.clone())),
            ("method", Json::str(Method::Permanova.name())),
            ("n", Json::num(n as f64)),
            ("k", Json::num(grid.n_groups as f64)),
            ("n_perms", Json::num(n_perms as f64)),
            ("packed_bytes", Json::num(packed_bytes as f64)),
            ("resident_cap", Json::num(cap as f64)),
            ("chunks_paged", Json::num(oo.chunks_paged as f64)),
            ("bytes_paged", Json::num(oo.bytes_paged as f64)),
            ("resident_secs", Json::num(resident_m.median)),
            ("capped_secs", Json::num(capped_m.median)),
            ("f_obs", Json::num(resident.f_obs)),
            ("p_value", Json::num(resident.p_value)),
            // Bitwise provenance: u64 bit patterns as strings (`seed`
            // idiom) — the validator compares these, not decimal floats.
            ("f_obs_bits", Json::str(resident.f_obs.to_bits().to_string())),
            ("capped_f_obs_bits", Json::str(capped.f_obs.to_bits().to_string())),
            ("p_value_bits", Json::str(resident.p_value.to_bits().to_string())),
            ("capped_p_value_bits", Json::str(capped.p_value.to_bits().to_string())),
        ]));
    }
    if entries.is_empty() {
        return Ok((entries, String::new()));
    }
    let rendered = format!(
        "out-of-core (same cell resident vs --max-resident-bytes {cap}, bitwise-pinned):\n{}",
        table.render()
    );
    Ok((entries, rendered))
}

/// The daemon latency axis (v5): for every client count `C` in
/// [`SweepGrid::latency_clients`], spawn an in-process TCP daemon
/// (loopback, OS-picked port) and open `C` concurrent connections, each
/// pipelining [`SweepGrid::throughput_jobs`] run requests **open-loop**
/// (every frame written up front, then responses read back) — so a
/// response's latency includes its queueing delay behind the other
/// clients, which is exactly the service-level number a shared daemon
/// owes its callers.  Reported per cell: p50/p99/mean response latency
/// (connection-side wall clock) and aggregate responses/sec.
///
/// All requests share one pinned dataset with distinct permutation
/// seeds (the shared-service shape), at the grid's smallest n and
/// permutation count: the axis measures admission, scheduling and wire
/// overhead under concurrency — the kernel-speed axes are `entries`.
fn run_latency_axis(grid: &SweepGrid) -> Result<(Vec<Json>, String)> {
    use crate::service::{envelope_v1, wire, Daemon, DaemonConfig};
    use std::io::{BufReader, BufWriter, Write};
    use std::net::TcpStream;

    if grid.latency_clients.is_empty() {
        return Ok((Vec::new(), String::new()));
    }
    let per_client = grid.throughput_jobs.max(2);
    let n = *grid.n_grid.iter().min().expect("validated non-empty");
    let n_perms = *grid.perm_grid.iter().min().expect("validated non-empty");
    let backend = grid.backends.first().expect("validated non-empty");
    let method = *grid.methods.first().expect("validated non-empty");

    let mut entries = Vec::new();
    let mut table = Table::new(&[
        "clients", "reqs", "n", "perms", "p50", "p99", "mean", "resp/s", "shed",
    ]);
    for &clients in &grid.latency_clients {
        if clients == 0 {
            return Err(Error::Config(
                "bench: --latency-clients entries must be >= 1 (use 0 alone to disable)".into(),
            ));
        }
        let daemon = Daemon::spawn(DaemonConfig {
            workers: grid.base.threads,
            cache_capacity: 4,
            ..DaemonConfig::default()
        })?;
        let addr = daemon.addr();
        // One request body per (client, slot): shared dataset (pinned
        // data seed), distinct permutation seeds.
        let build_requests = |client: usize| -> Vec<String> {
            (0..per_client)
                .map(|slot| {
                    let seed = grid.base.seed.wrapping_add((client * per_client + slot) as u64);
                    let payload = Json::obj(vec![
                        ("method", Json::str(method.name())),
                        ("backend", Json::str(backend.clone())),
                        ("n_perms", Json::num(n_perms as f64)),
                        ("seed", Json::str(seed.to_string())),
                        (
                            "data",
                            Json::obj(vec![
                                ("source", Json::str("synthetic")),
                                ("n_dims", Json::num(n as f64)),
                                ("n_groups", Json::num(grid.n_groups as f64)),
                                ("seed", Json::str(grid.base.seed.to_string())),
                            ]),
                        ),
                    ]);
                    envelope_v1(Some(&format!("lat-{client}-{slot}")), payload).to_string()
                })
                .collect()
        };
        // Each client thread: connect, write all frames (open loop),
        // then timestamp every response against its connection start.
        let t_cell = Instant::now();
        let outcomes: Vec<Result<(Vec<f64>, usize)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..clients)
                .map(|client| {
                    let requests = build_requests(client);
                    scope.spawn(move || -> Result<(Vec<f64>, usize)> {
                        let io_err = |e| Error::io(addr.to_string(), e);
                        let stream = TcpStream::connect(addr).map_err(io_err)?;
                        let read_half = stream.try_clone().map_err(io_err)?;
                        let mut reader = BufReader::new(read_half);
                        let mut writer = BufWriter::new(stream);
                        let t0 = Instant::now();
                        for request in &requests {
                            wire::write_frame(&mut writer, request).map_err(io_err)?;
                        }
                        writer.flush().map_err(io_err)?;
                        let mut latencies = Vec::with_capacity(requests.len());
                        let mut shed = 0usize;
                        for _ in &requests {
                            let payload = wire::read_frame(&mut reader)?.ok_or_else(|| {
                                Error::Coordinator("daemon closed mid-latency-cell".into())
                            })?;
                            let elapsed = t0.elapsed().as_secs_f64();
                            let doc = Json::parse(&payload)?;
                            if doc.get("retry_after").is_some() {
                                shed += 1;
                            } else if doc.opt_bool("ok")? == Some(true) {
                                latencies.push(elapsed);
                            } else {
                                return Err(Error::Config(format!(
                                    "latency cell response failed: {payload}"
                                )));
                            }
                        }
                        Ok((latencies, shed))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| panic!("latency client panicked")))
                .collect()
        });
        let wall_secs = t_cell.elapsed().as_secs_f64();
        daemon.shutdown();
        let summary = daemon.join()?;
        let mut latencies = Vec::new();
        let mut shed = 0usize;
        for outcome in outcomes {
            let (mut l, s) = outcome?;
            latencies.append(&mut l);
            shed += s;
        }
        if latencies.is_empty() {
            return Err(Error::Config(format!(
                "latency cell with {clients} clients completed no requests"
            )));
        }
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = percentile_sorted(&latencies, 50.0);
        let p99 = percentile_sorted(&latencies, 99.0);
        let mean = latencies.iter().sum::<f64>() / latencies.len() as f64;
        let total = clients * per_client;
        let rps = latencies.len() as f64 / wall_secs;
        table.row(&[
            clients.to_string(),
            total.to_string(),
            n.to_string(),
            n_perms.to_string(),
            format_secs(p50),
            format_secs(p99),
            format_secs(mean),
            format!("{rps:.1}"),
            shed.to_string(),
        ]);
        entries.push(Json::obj(vec![
            ("clients", Json::num(clients as f64)),
            ("requests_per_client", Json::num(per_client as f64)),
            ("total_requests", Json::num(total as f64)),
            ("completed", Json::num(latencies.len() as f64)),
            ("shed", Json::num(shed as f64)),
            ("backend", Json::str(backend.clone())),
            ("method", Json::str(method.name())),
            ("n", Json::num(n as f64)),
            ("n_perms", Json::num(n_perms as f64)),
            ("p50_ms", Json::num(p50 * 1e3)),
            ("p99_ms", Json::num(p99 * 1e3)),
            ("mean_ms", Json::num(mean * 1e3)),
            ("wall_secs", Json::num(wall_secs)),
            ("responses_per_sec", Json::num(rps)),
            ("daemon_connections", Json::num(summary.connections as f64)),
        ]));
    }
    let rendered = format!(
        "daemon latency (open-loop, {per_client} pipelined requests/client):\n{}",
        table.render()
    );
    Ok((entries, rendered))
}

fn bench_field_err(ctx: &str, msg: impl Into<String>) -> Error {
    Error::Config(format!("bench json {ctx}: {}", msg.into()))
}

/// Validate a `BENCH_PERMANOVA.json` document against [`BENCH_SCHEMA`]:
/// required fields, known backend and method names, finite/positive
/// timings, p-values in `(0, 1]`.  Returns the entry count.  This is what
/// CI's bench smoke job runs (`bench --check`), so a malformed artifact
/// fails the build.
pub fn validate_bench_json(doc: &Json) -> Result<usize> {
    let schema = doc.req_str("schema")?;
    if schema != BENCH_SCHEMA {
        return Err(bench_field_err(
            "schema",
            format!("got {schema:?}, expected {BENCH_SCHEMA:?}"),
        ));
    }
    doc.req_str("version")?;
    if doc.req_usize("host_threads")? == 0 {
        return Err(bench_field_err("host_threads", "must be >= 1"));
    }
    if !matches!(doc.get("quick"), Some(Json::Bool(_))) {
        return Err(bench_field_err("quick", "missing/not a boolean"));
    }
    let entries = doc.req_arr("entries")?;
    if entries.is_empty() {
        return Err(bench_field_err("entries", "must be non-empty"));
    }
    let registry = crate::backend::Registry::with_defaults();
    for (i, e) in entries.iter().enumerate() {
        let ctx = format!("entry {i}");
        let backend = e.req_str("backend")?;
        if !registry.contains(backend) {
            return Err(bench_field_err(&ctx, format!("unknown backend {backend:?}")));
        }
        let method = e
            .req_str("method")
            .map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        if Method::parse(method).is_none() {
            return Err(bench_field_err(&ctx, format!("unknown method {method:?}")));
        }
        e.req_str("kernel")?;
        if e.req_usize("n")? == 0 || e.req_usize("n_perms")? == 0 {
            return Err(bench_field_err(&ctx, "n and n_perms must be >= 1"));
        }
        for key in ["k", "perm_block", "threads", "shard_size"] {
            e.req_usize(key)
                .map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        }
        let seed = e
            .req_str("seed")
            .map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        if seed.parse::<u64>().is_err() {
            return Err(bench_field_err(&ctx, format!("seed {seed:?} is not a u64")));
        }
        let reps = e
            .req_usize("reps")
            .map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        if reps == 0 {
            return Err(bench_field_err(&ctx, "reps must be >= 1"));
        }
        let jobs = e
            .req_usize("jobs")
            .map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        if jobs == 0 {
            return Err(bench_field_err(&ctx, "jobs must be >= 1"));
        }
        if !matches!(e.get("smt_oversubscribe"), Some(Json::Bool(_))) {
            return Err(bench_field_err(&ctx, "smt_oversubscribe missing/not a boolean"));
        }
        let num = |key: &str| -> Result<f64> {
            let v = e
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| bench_field_err(&ctx, format!("{key} missing/not a number")))?;
            if !v.is_finite() {
                return Err(bench_field_err(&ctx, format!("{key} must be finite, got {v}")));
            }
            Ok(v)
        };
        let best = num("best_secs")?;
        let median = num("median_secs")?;
        num("mad_secs")?;
        let pps = num("perms_per_sec")?;
        num("f_obs")?;
        let p = num("p_value")?;
        let modelled = num("modelled_secs")?;
        // v4: the memory-traffic axis must be present and self-consistent
        // — in particular the packed footprint must actually be ≤ half the
        // dense footprint (the acceptance bar of the layout change).
        let bpp = e
            .req_usize("bytes_per_perm")
            .map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        if bpp == 0 {
            return Err(bench_field_err(&ctx, "bytes_per_perm must be >= 1"));
        }
        let gbs = num("effective_gbs")?;
        if gbs <= 0.0 {
            return Err(bench_field_err(&ctx, format!("effective_gbs must be > 0, got {gbs}")));
        }
        let dense = e
            .req_usize("dense_bytes")
            .map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        let packed = e
            .req_usize("packed_bytes")
            .map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        if packed == 0 || packed * 2 > dense {
            return Err(bench_field_err(
                &ctx,
                format!("packed_bytes {packed} must be in [1, dense_bytes/2 = {}]", dense / 2),
            ));
        }
        let ratio = num("footprint_ratio")?;
        if !(ratio > 0.0 && ratio <= 0.5) {
            return Err(bench_field_err(
                &ctx,
                format!("footprint_ratio must be in (0, 0.5], got {ratio}"),
            ));
        }
        if (ratio - packed as f64 / dense as f64).abs() > 1e-9 {
            return Err(bench_field_err(
                &ctx,
                format!("footprint_ratio {ratio} != packed_bytes/dense_bytes"),
            ));
        }
        // v6: the resident footprint must be *exactly* the packed values
        // plus the (n+1)-entry offset table — a cell whose residency still
        // includes a dense copy (or any other hidden buffer) fails here.
        let resident = e
            .req_usize("resident_bytes")
            .map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        let n_cell = e.req_usize("n")?;
        let want_resident = packed + 8 * (n_cell + 1);
        if resident != want_resident {
            return Err(bench_field_err(
                &ctx,
                format!(
                    "resident_bytes {resident} != packed_bytes + offsets = {want_resident} \
                     (a dense copy has crept back into the resident footprint?)"
                ),
            ));
        }
        if modelled < 0.0 {
            return Err(bench_field_err(
                &ctx,
                format!("modelled_secs must be >= 0, got {modelled}"),
            ));
        }
        if best <= 0.0 || median < best {
            return Err(bench_field_err(
                &ctx,
                format!("timings must satisfy 0 < best <= median (best {best}, median {median})"),
            ));
        }
        if pps <= 0.0 {
            return Err(bench_field_err(&ctx, format!("perms_per_sec must be > 0, got {pps}")));
        }
        if !(p > 0.0 && p <= 1.0) {
            return Err(bench_field_err(&ctx, format!("p_value must be in (0, 1], got {p}")));
        }
    }

    // v3: the service-layer throughput section.  The array itself is
    // required (it is how CI notices the axis silently disappearing); it
    // may be empty only when the sweep was run with throughput_jobs = 0.
    let throughput = doc
        .get("throughput")
        .and_then(Json::as_arr)
        .ok_or_else(|| bench_field_err("throughput", "missing/not an array"))?;
    for (i, e) in throughput.iter().enumerate() {
        let ctx = format!("throughput {i}");
        let backend = e.req_str("backend").map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        if !registry.contains(backend) {
            return Err(bench_field_err(&ctx, format!("unknown backend {backend:?}")));
        }
        let method = e.req_str("method").map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        if Method::parse(method).is_none() {
            return Err(bench_field_err(&ctx, format!("unknown method {method:?}")));
        }
        let req = |key: &str| -> Result<usize> {
            e.req_usize(key).map_err(|err| bench_field_err(&ctx, err.to_string()))
        };
        if req("n")? == 0 || req("n_perms")? == 0 {
            return Err(bench_field_err(&ctx, "n and n_perms must be >= 1"));
        }
        req("k")?;
        let jobs = req("jobs")?;
        if jobs < 2 {
            return Err(bench_field_err(&ctx, "a throughput cell needs >= 2 jobs"));
        }
        let num = |key: &str| -> Result<f64> {
            let v = e
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| bench_field_err(&ctx, format!("{key} missing/not a number")))?;
            if !v.is_finite() {
                return Err(bench_field_err(&ctx, format!("{key} must be finite, got {v}")));
            }
            Ok(v)
        };
        for key in ["cold_secs", "warm_secs"] {
            if num(key)? <= 0.0 {
                return Err(bench_field_err(&ctx, format!("{key} must be > 0")));
            }
        }
        for key in ["cold_jobs_per_sec", "warm_jobs_per_sec"] {
            if num(key)? <= 0.0 {
                return Err(bench_field_err(&ctx, format!("{key} must be > 0")));
            }
        }
        let hits = req("warm_hits")?;
        let misses = req("warm_misses")?;
        if hits + misses != jobs {
            return Err(bench_field_err(
                &ctx,
                format!("warm_hits {hits} + warm_misses {misses} != jobs {jobs}"),
            ));
        }
        if misses == 0 {
            return Err(bench_field_err(&ctx, "a cold-started warm pass must miss at least once"));
        }
    }

    // v7: the restart-warm section.  Required as an array (CI notices the
    // axis silently disappearing); may be empty only when the sweep ran
    // with throughput_jobs = 0 (the shared batch-axis disable).
    let restart = doc
        .get("restart_warm")
        .and_then(Json::as_arr)
        .ok_or_else(|| bench_field_err("restart_warm", "missing/not an array"))?;
    for (i, e) in restart.iter().enumerate() {
        let ctx = format!("restart_warm {i}");
        let backend = e.req_str("backend").map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        if !registry.contains(backend) {
            return Err(bench_field_err(&ctx, format!("unknown backend {backend:?}")));
        }
        let method = e.req_str("method").map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        if Method::parse(method).is_none() {
            return Err(bench_field_err(&ctx, format!("unknown method {method:?}")));
        }
        let req = |key: &str| -> Result<usize> {
            e.req_usize(key).map_err(|err| bench_field_err(&ctx, err.to_string()))
        };
        if req("n")? == 0 || req("n_perms")? == 0 {
            return Err(bench_field_err(&ctx, "n and n_perms must be >= 1"));
        }
        req("k")?;
        let jobs = req("jobs")?;
        if jobs < 2 {
            return Err(bench_field_err(&ctx, "a restart cell needs >= 2 jobs"));
        }
        let num = |key: &str| -> Result<f64> {
            let v = e
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| bench_field_err(&ctx, format!("{key} missing/not a number")))?;
            if !v.is_finite() {
                return Err(bench_field_err(&ctx, format!("{key} must be finite, got {v}")));
            }
            Ok(v)
        };
        for key in ["cold_secs", "process_warm_secs", "disk_warm_secs"] {
            if num(key)? <= 0.0 {
                return Err(bench_field_err(&ctx, format!("{key} must be > 0")));
            }
        }
        for key in ["cold_jobs_per_sec", "process_warm_jobs_per_sec", "disk_warm_jobs_per_sec"] {
            if num(key)? <= 0.0 {
                return Err(bench_field_err(&ctx, format!("{key} must be > 0")));
            }
        }
        // The axis's defining invariant: every disk-warm job answered from
        // the store.  A cell that recomputed is invalid, not just slow.
        let hits = req("store_hits")?;
        if hits != jobs {
            return Err(bench_field_err(
                &ctx,
                format!("store_hits {hits} != jobs {jobs} (disk-warm pass recomputed)"),
            ));
        }
        if req("store_puts")? == 0 {
            return Err(bench_field_err(&ctx, "store_puts must be >= 1 (seeding pass wrote nothing)"));
        }
    }

    // v8: the out-of-core section.  Required as an array (CI notices the
    // axis silently disappearing); may be empty only when every backend in
    // the grid declined the residency cap (an all-XLA sweep).  The two
    // pinned invariants are the tentpole's acceptance bar: the capped run
    // actually paged, and its statistics are **bitwise** the resident
    // run's — compared as u64 bit-pattern strings, never decimal floats.
    let oocore = doc
        .get("oocore")
        .and_then(Json::as_arr)
        .ok_or_else(|| bench_field_err("oocore", "missing/not an array"))?;
    for (i, e) in oocore.iter().enumerate() {
        let ctx = format!("oocore {i}");
        let backend = e.req_str("backend").map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        if !registry.contains(backend) {
            return Err(bench_field_err(&ctx, format!("unknown backend {backend:?}")));
        }
        let method = e.req_str("method").map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        if Method::parse(method).is_none() {
            return Err(bench_field_err(&ctx, format!("unknown method {method:?}")));
        }
        let req = |key: &str| -> Result<usize> {
            e.req_usize(key).map_err(|err| bench_field_err(&ctx, err.to_string()))
        };
        if req("n")? == 0 || req("n_perms")? == 0 {
            return Err(bench_field_err(&ctx, "n and n_perms must be >= 1"));
        }
        req("k")?;
        let packed = req("packed_bytes")?;
        let cap = req("resident_cap")?;
        if cap == 0 || cap >= packed {
            return Err(bench_field_err(
                &ctx,
                format!("resident_cap {cap} must be in [1, packed_bytes {packed}) — a cap the \
                         triangle fits under measures nothing"),
            ));
        }
        if req("chunks_paged")? == 0 {
            return Err(bench_field_err(&ctx, "chunks_paged must be >= 1 (capped run never paged)"));
        }
        if req("bytes_paged")? == 0 {
            return Err(bench_field_err(&ctx, "bytes_paged must be >= 1"));
        }
        let num = |key: &str| -> Result<f64> {
            let v = e
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| bench_field_err(&ctx, format!("{key} missing/not a number")))?;
            if !v.is_finite() {
                return Err(bench_field_err(&ctx, format!("{key} must be finite, got {v}")));
            }
            Ok(v)
        };
        for key in ["resident_secs", "capped_secs"] {
            if num(key)? <= 0.0 {
                return Err(bench_field_err(&ctx, format!("{key} must be > 0")));
            }
        }
        num("f_obs")?;
        let p = num("p_value")?;
        if !(p > 0.0 && p <= 1.0) {
            return Err(bench_field_err(&ctx, format!("p_value must be in (0, 1], got {p}")));
        }
        let bits = |key: &str| -> Result<u64> {
            let s = e.req_str(key).map_err(|err| bench_field_err(&ctx, err.to_string()))?;
            s.parse::<u64>()
                .map_err(|_| bench_field_err(&ctx, format!("{key} {s:?} is not a u64 bit pattern")))
        };
        if bits("f_obs_bits")? != bits("capped_f_obs_bits")? {
            return Err(bench_field_err(
                &ctx,
                "capped f_obs differs from the resident run bitwise — the chunked sweep broke \
                 the determinism contract",
            ));
        }
        if bits("p_value_bits")? != bits("capped_p_value_bits")? {
            return Err(bench_field_err(
                &ctx,
                "capped p_value differs from the resident run bitwise — the chunked sweep broke \
                 the determinism contract",
            ));
        }
    }

    // v5: the daemon latency section.  Required as an array (CI notices
    // the axis silently disappearing); may be empty only when the sweep
    // ran with the axis disabled (`latency_clients` empty).
    let latency = doc
        .get("latency")
        .and_then(Json::as_arr)
        .ok_or_else(|| bench_field_err("latency", "missing/not an array"))?;
    for (i, e) in latency.iter().enumerate() {
        let ctx = format!("latency {i}");
        let backend = e.req_str("backend").map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        if !registry.contains(backend) {
            return Err(bench_field_err(&ctx, format!("unknown backend {backend:?}")));
        }
        let method = e.req_str("method").map_err(|err| bench_field_err(&ctx, err.to_string()))?;
        if Method::parse(method).is_none() {
            return Err(bench_field_err(&ctx, format!("unknown method {method:?}")));
        }
        let req = |key: &str| -> Result<usize> {
            e.req_usize(key).map_err(|err| bench_field_err(&ctx, err.to_string()))
        };
        let clients = req("clients")?;
        let per_client = req("requests_per_client")?;
        if clients == 0 || per_client == 0 {
            return Err(bench_field_err(&ctx, "clients and requests_per_client must be >= 1"));
        }
        let total = req("total_requests")?;
        if total != clients * per_client {
            return Err(bench_field_err(
                &ctx,
                format!("total_requests {total} != clients x requests_per_client"),
            ));
        }
        let completed = req("completed")?;
        let shed = req("shed")?;
        if completed == 0 {
            return Err(bench_field_err(&ctx, "completed must be >= 1"));
        }
        if completed + shed != total {
            return Err(bench_field_err(
                &ctx,
                format!("completed {completed} + shed {shed} != total_requests {total}"),
            ));
        }
        if req("n")? == 0 || req("n_perms")? == 0 {
            return Err(bench_field_err(&ctx, "n and n_perms must be >= 1"));
        }
        let num = |key: &str| -> Result<f64> {
            let v = e
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| bench_field_err(&ctx, format!("{key} missing/not a number")))?;
            if !v.is_finite() {
                return Err(bench_field_err(&ctx, format!("{key} must be finite, got {v}")));
            }
            Ok(v)
        };
        let p50 = num("p50_ms")?;
        let p99 = num("p99_ms")?;
        let mean = num("mean_ms")?;
        if !(p50 > 0.0 && p50 <= p99) {
            return Err(bench_field_err(
                &ctx,
                format!("percentiles must satisfy 0 < p50 <= p99 (p50 {p50}, p99 {p99})"),
            ));
        }
        if mean <= 0.0 {
            return Err(bench_field_err(&ctx, format!("mean_ms must be > 0, got {mean}")));
        }
        if num("wall_secs")? <= 0.0 {
            return Err(bench_field_err(&ctx, "wall_secs must be > 0"));
        }
        if num("responses_per_sec")? <= 0.0 {
            return Err(bench_field_err(&ctx, "responses_per_sec must be > 0"));
        }
    }
    Ok(entries.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_known_vector() {
        let m = Measurement::from_times("x", vec![3.0, 1.0, 2.0, 4.0, 100.0]);
        assert_eq!(m.best, 1.0);
        assert_eq!(m.worst, 100.0);
        assert_eq!(m.median, 3.0);
        assert!((m.mean - 22.0).abs() < 1e-12);
        // MAD robust to the outlier: devs {2,1,0,1,97} → median 1 → 1.4826
        assert!((m.mad - 1.4826).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 4.0);
        assert!((percentile_sorted(&v, 50.0) - 2.5).abs() < 1e-12);
        assert_eq!(percentile_sorted(&[7.0], 50.0), 7.0);
    }

    #[test]
    fn run_executes_and_counts() {
        let mut calls = 0usize;
        let mut b =
            Bencher { warmup: 1, min_reps: 3, max_reps: 3, max_time: Duration::from_secs(5) };
        let m = b.run("count", || {
            calls += 1;
            calls
        });
        assert_eq!(calls, 4); // 1 warmup + 3 timed
        assert_eq!(m.times.len(), 3);
        assert!(m.best > 0.0);
        assert!(m.best <= m.median && m.median <= m.worst);
    }

    #[test]
    fn max_time_stops_early() {
        let mut b = Bencher {
            warmup: 0,
            min_reps: 2,
            max_reps: 1000,
            max_time: Duration::from_millis(50),
        };
        let m = b.run("sleepy", || std::thread::sleep(Duration::from_millis(20)));
        assert!(m.times.len() < 1000, "stopped early: {}", m.times.len());
        assert!(m.times.len() >= 2);
    }

    #[test]
    fn rates_and_formatting() {
        let m = Measurement::from_times("bw", vec![0.5]);
        assert!((m.best_rate_gbs(1_000_000_000) - 2.0).abs() < 1e-9);
        assert!((m.median_throughput(100) - 200.0).abs() < 1e-9);
        assert!(format_secs(2.5e-9).ends_with("ns"));
        assert!(format_secs(2.5e-6).ends_with("µs"));
        assert!(format_secs(2.5e-3).ends_with("ms"));
        assert!(format_secs(2.5).ends_with('s'));
        assert!(m.format_row().contains("bw"));
    }

    #[test]
    fn speedup_direction() {
        let slow = Measurement::from_times("slow", vec![2.0]);
        let fast = Measurement::from_times("fast", vec![0.5]);
        assert!((speedup(&slow, &fast) - 4.0).abs() < 1e-12);
    }

    /// A minimal, fast grid for sweep tests: two backends, one tiny cell
    /// each, a single timed repetition.
    fn tiny_grid() -> SweepGrid {
        SweepGrid {
            backends: vec!["native-brute".into(), "native-batch".into()],
            n_grid: vec![24],
            perm_grid: vec![9],
            n_groups: 2,
            bencher: Bencher {
                warmup: 0,
                min_reps: 1,
                max_reps: 1,
                max_time: Duration::from_secs(1),
            },
            quick: true,
            throughput_jobs: 2,
            // Most sweep tests exercise the kernel/throughput axes; the
            // latency axis (which spawns a daemon) opts in explicitly.
            latency_clients: vec![],
            ..Default::default()
        }
    }

    #[test]
    fn sweep_emits_schema_valid_json() {
        let out = run_sweep(&tiny_grid()).unwrap();
        assert_eq!(out.entries, 2);
        assert!(out.table.contains("native-batch"));
        assert!(out.table.contains("service throughput"), "{}", out.table);
        assert_eq!(validate_bench_json(&out.json).unwrap(), 2);
        // Round-trips through the serializer.
        let parsed = Json::parse(&out.json.to_string_pretty()).unwrap();
        assert_eq!(validate_bench_json(&parsed).unwrap(), 2);
        // The batch entry records the block width actually used: the
        // default 64 clamped to this grid's 10 permutations.
        let entries = parsed.req_arr("entries").unwrap();
        let batch = entries
            .iter()
            .find(|e| e.req_str("backend").unwrap() == "native-batch")
            .unwrap();
        assert_eq!(batch.req_usize("perm_block").unwrap(), 10);
        assert_eq!(batch.req_str("kernel").unwrap(), "brute-block");
    }

    #[test]
    fn sweep_covers_the_method_axis() {
        let mut g = tiny_grid();
        g.methods = vec![Method::Permanova, Method::Anosim, Method::Permdisp];
        let out = run_sweep(&g).unwrap();
        assert_eq!(out.entries, 6, "2 backends x 3 methods");
        assert_eq!(validate_bench_json(&out.json).unwrap(), 6);
        for e in out.json.req_arr("entries").unwrap() {
            assert_eq!(e.req_usize("jobs").unwrap(), 1, "single-job methods");
        }
        let entries = out.json.req_arr("entries").unwrap();
        let kernel_of = |method: &str, backend: &str| {
            entries
                .iter()
                .find(|e| {
                    e.req_str("method").unwrap() == method
                        && e.req_str("backend").unwrap() == backend
                })
                .unwrap()
                .req_str("kernel")
                .unwrap()
                .to_string()
        };
        // The method axis is recorded with the kernel actually evaluated.
        assert_eq!(kernel_of("permanova", "native-brute"), "brute");
        assert_eq!(kernel_of("permanova", "native-batch"), "brute-block");
        assert_eq!(kernel_of("anosim", "native-batch"), "rank-r");
        assert_eq!(kernel_of("permdisp", "native-brute"), "centroid-anova");
    }

    #[test]
    fn traffic_axis_records_the_packed_stream() {
        // Pinned arithmetic: n = 24 → pairs = 276.
        assert_eq!(bytes_per_perm(Method::Permanova, 24), 276 * 4 + 96);
        assert_eq!(bytes_per_perm(Method::PairwisePermanova, 24), 276 * 4 + 96);
        assert_eq!(bytes_per_perm(Method::Anosim, 24), 276 * 8 + 96);
        assert_eq!(bytes_per_perm(Method::Permdisp, 24), 24 * 8 + 96);

        let mut g = tiny_grid();
        g.methods = vec![Method::Permanova, Method::Anosim, Method::Permdisp];
        let out = run_sweep(&g).unwrap();
        for e in out.json.req_arr("entries").unwrap() {
            let method = Method::parse(e.req_str("method").unwrap()).unwrap();
            assert_eq!(
                e.req_usize("bytes_per_perm").unwrap() as u64,
                bytes_per_perm(method, 24),
                "{method:?}"
            );
            let ratio = e.get("footprint_ratio").unwrap().as_f64().unwrap();
            assert!((ratio - 23.0 / 48.0).abs() < 1e-12, "(n-1)/2n for n=24, got {ratio}");
            assert_eq!(e.req_usize("dense_bytes").unwrap(), 24 * 24 * 4);
            assert_eq!(e.req_usize("packed_bytes").unwrap(), 276 * 4);
            // v6: packed values + 25-entry offset table — and nothing else.
            assert_eq!(e.req_usize("resident_bytes").unwrap(), 276 * 4 + 8 * 25);
            assert!(e.get("effective_gbs").unwrap().as_f64().unwrap() > 0.0);
        }
        assert!(out.table.contains("GB/s"), "{}", out.table);
    }

    #[test]
    fn pairwise_traffic_uses_the_subproblem_size() {
        let mut g = tiny_grid();
        g.backends = vec!["native-brute".into()];
        g.methods = vec![Method::PairwisePermanova];
        g.n_groups = 3;
        let out = run_sweep(&g).unwrap();
        let e = &out.json.req_arr("entries").unwrap()[0];
        // 24 objects in 3 balanced groups → each pair sweeps n = 16.
        assert_eq!(e.req_usize("bytes_per_perm").unwrap() as u64,
            bytes_per_perm(Method::PairwisePermanova, 16));
        // ... while the footprint ratio describes the loaded dataset (n = 24).
        assert_eq!(e.req_usize("dense_bytes").unwrap(), 24 * 24 * 4);
    }

    #[test]
    fn pairwise_cells_record_their_job_fanout() {
        let mut g = tiny_grid();
        g.backends = vec!["native-brute".into()];
        g.methods = vec![Method::PairwisePermanova];
        g.n_groups = 3;
        let out = run_sweep(&g).unwrap();
        assert_eq!(validate_bench_json(&out.json).unwrap(), 1);
        let e = &out.json.req_arr("entries").unwrap()[0];
        assert_eq!(e.req_str("method").unwrap(), "pairwise");
        assert_eq!(e.req_usize("jobs").unwrap(), 3, "3 groups -> 3 pair jobs");
    }

    #[test]
    fn throughput_axis_records_cold_and_warm_cache() {
        let mut g = tiny_grid();
        g.backends = vec!["native-brute".into()];
        g.throughput_jobs = 3;
        let out = run_sweep(&g).unwrap();
        let cells = out.json.req_arr("throughput").unwrap();
        assert_eq!(cells.len(), 1, "one cell per backend x method");
        let c = &cells[0];
        assert_eq!(c.req_str("backend").unwrap(), "native-brute");
        assert_eq!(c.req_str("method").unwrap(), "permanova");
        assert_eq!(c.req_usize("jobs").unwrap(), 3);
        assert_eq!(c.req_usize("warm_misses").unwrap(), 1, "first warm job loads");
        assert_eq!(c.req_usize("warm_hits").unwrap(), 2, "the rest hit");
        assert!(c.get("cold_jobs_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(c.get("warm_jobs_per_sec").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn warm_cache_outruns_cold_on_a_load_dominated_cell() {
        // The acceptance cell: with a prelude-heavy method (PERMDISP runs a
        // PCoA eigendecomposition per dataset load) and almost no
        // permutation work, the warm pass skips nearly everything the cold
        // pass repeats — jobs/sec must come out strictly higher.
        let mut g = tiny_grid();
        g.backends = vec!["native-brute".into()];
        g.methods = vec![Method::Permdisp];
        g.n_grid = vec![120];
        g.perm_grid = vec![3];
        g.throughput_jobs = 5;
        let out = run_sweep(&g).unwrap();
        let c = &out.json.req_arr("throughput").unwrap()[0];
        let cold = c.get("cold_jobs_per_sec").unwrap().as_f64().unwrap();
        let warm = c.get("warm_jobs_per_sec").unwrap().as_f64().unwrap();
        assert!(
            warm > cold,
            "warm cache must outrun cold on a repeated-dataset batch: warm {warm} vs cold {cold}"
        );
    }

    #[test]
    fn throughput_axis_can_be_disabled() {
        let mut g = tiny_grid();
        g.throughput_jobs = 0;
        let out = run_sweep(&g).unwrap();
        assert!(out.json.req_arr("throughput").unwrap().is_empty());
        assert!(!out.table.contains("service throughput"));
        // The restart axis shares the disable knob (both are batch axes).
        assert!(out.json.req_arr("restart_warm").unwrap().is_empty());
        assert!(!out.table.contains("restart warmth"));
        // An empty section still validates (the key must exist).
        assert_eq!(validate_bench_json(&out.json).unwrap(), 2);
        // ... but 1 job cannot compare cold vs warm: rejected, not clamped.
        g.throughput_jobs = 1;
        assert!(run_sweep(&g).is_err());
    }

    #[test]
    fn restart_axis_records_three_temperatures() {
        let mut g = tiny_grid();
        g.backends = vec!["native-brute".into()];
        g.throughput_jobs = 3;
        let out = run_sweep(&g).unwrap();
        assert!(out.table.contains("restart warmth"), "{}", out.table);
        let cells = out.json.req_arr("restart_warm").unwrap();
        assert_eq!(cells.len(), 1, "one cell per backend x method");
        let c = &cells[0];
        assert_eq!(c.req_str("backend").unwrap(), "native-brute");
        assert_eq!(c.req_str("method").unwrap(), "permanova");
        assert_eq!(c.req_usize("jobs").unwrap(), 3);
        // Every disk-warm job answered from the reopened store; the
        // seeding batch put exactly one entry (3 identical jobs → 1 miss).
        assert_eq!(c.req_usize("store_hits").unwrap(), 3);
        assert_eq!(c.req_usize("store_puts").unwrap(), 1);
        for key in ["cold_jobs_per_sec", "process_warm_jobs_per_sec", "disk_warm_jobs_per_sec"] {
            assert!(c.get(key).unwrap().as_f64().unwrap() > 0.0, "{key}");
        }
        assert_eq!(validate_bench_json(&out.json).unwrap(), 1);
    }

    #[test]
    fn oocore_axis_pins_bitwise_parity_while_paging() {
        let mut g = tiny_grid();
        g.backends = vec!["native-brute".into(), "native-batch".into()];
        let out = run_sweep(&g).unwrap();
        assert!(out.table.contains("out-of-core"), "{}", out.table);
        let cells = out.json.req_arr("oocore").unwrap();
        assert_eq!(cells.len(), 2, "one cell per backend");
        for c in cells {
            let backend = c.req_str("backend").unwrap();
            assert_eq!(c.req_str("method").unwrap(), "permanova");
            // n = 24 → packed 1104 bytes; quarter-cap floored to 256.
            assert_eq!(c.req_usize("packed_bytes").unwrap(), 1104);
            assert_eq!(c.req_usize("resident_cap").unwrap(), 276, "{backend}");
            assert!(c.req_usize("chunks_paged").unwrap() >= 1, "{backend}");
            assert!(c.req_usize("bytes_paged").unwrap() >= 1, "{backend}");
            // The defining invariant, recorded as bit patterns.
            assert_eq!(
                c.req_str("f_obs_bits").unwrap(),
                c.req_str("capped_f_obs_bits").unwrap(),
                "{backend}"
            );
            assert_eq!(
                c.req_str("p_value_bits").unwrap(),
                c.req_str("capped_p_value_bits").unwrap(),
                "{backend}"
            );
        }
        assert_eq!(validate_bench_json(&out.json).unwrap(), 2);
    }

    #[test]
    fn validator_rejects_broken_oocore_cells() {
        let good = run_sweep(&tiny_grid()).unwrap().json;
        // Missing section (v8 requires the key).
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            m.remove("oocore");
        }
        let e = validate_bench_json(&bad).unwrap_err().to_string();
        assert!(e.contains("oocore"), "{e}");
        // A capped run that never paged is a cap the validator rejects.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            let mut cells = m.get("oocore").unwrap().as_arr().unwrap().to_vec();
            if let Json::Obj(c) = &mut cells[0] {
                c.insert("chunks_paged".into(), Json::num(0));
            }
            m.insert("oocore".into(), Json::Arr(cells));
        }
        let e = validate_bench_json(&bad).unwrap_err().to_string();
        assert!(e.contains("chunks_paged"), "{e}");
        // A cap the triangle fits under measures nothing.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            let mut cells = m.get("oocore").unwrap().as_arr().unwrap().to_vec();
            if let Json::Obj(c) = &mut cells[0] {
                c.insert("resident_cap".into(), Json::num(1e9));
            }
            m.insert("oocore".into(), Json::Arr(cells));
        }
        let e = validate_bench_json(&bad).unwrap_err().to_string();
        assert!(e.contains("resident_cap"), "{e}");
        // One flipped statistic bit fails the document.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            let mut cells = m.get("oocore").unwrap().as_arr().unwrap().to_vec();
            if let Json::Obj(c) = &mut cells[0] {
                let bits: u64 = c.get("f_obs_bits").unwrap().as_str().unwrap().parse().unwrap();
                c.insert("capped_f_obs_bits".into(), Json::str((bits ^ 1).to_string()));
            }
            m.insert("oocore".into(), Json::Arr(cells));
        }
        let e = validate_bench_json(&bad).unwrap_err().to_string();
        assert!(e.contains("bitwise"), "{e}");
    }

    #[test]
    fn disk_warm_outruns_cold_on_a_load_dominated_cell() {
        // The acceptance cell for the durable store: a PCoA-heavy method
        // (PERMDISP eigendecomposes per dataset load) over a repeated
        // dataset.  The disk-warm pass skips the load *and* the sweep —
        // jobs/sec must come out strictly higher than cold.
        let mut g = tiny_grid();
        g.backends = vec!["native-brute".into()];
        g.methods = vec![Method::Permdisp];
        g.n_grid = vec![120];
        g.perm_grid = vec![3];
        g.throughput_jobs = 5;
        let out = run_sweep(&g).unwrap();
        let c = &out.json.req_arr("restart_warm").unwrap()[0];
        let cold = c.get("cold_jobs_per_sec").unwrap().as_f64().unwrap();
        let disk = c.get("disk_warm_jobs_per_sec").unwrap().as_f64().unwrap();
        assert!(
            disk > cold,
            "a reopened store must outrun cold recomputation: disk-warm {disk} vs cold {cold}"
        );
    }

    #[test]
    fn sweep_rejects_bad_grids() {
        let mut g = tiny_grid();
        g.backends = vec!["warp-drive".into()];
        assert!(run_sweep(&g).is_err());
        let mut g = tiny_grid();
        g.backends.clear();
        assert!(run_sweep(&g).is_err());
        let mut g = tiny_grid();
        g.n_grid.clear();
        assert!(run_sweep(&g).is_err());
        let mut g = tiny_grid();
        g.methods.clear();
        assert!(run_sweep(&g).is_err());
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        let good = run_sweep(&tiny_grid()).unwrap().json;
        // Wrong schema tag.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            m.insert("schema".into(), Json::str("bench-permanova/v999"));
        }
        assert!(validate_bench_json(&bad).is_err());
        // Empty entries.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            m.insert("entries".into(), Json::Arr(vec![]));
        }
        assert!(validate_bench_json(&bad).is_err());
        // Entry with an out-of-range p-value.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            let mut entries = m.get("entries").unwrap().as_arr().unwrap().to_vec();
            if let Json::Obj(e) = &mut entries[0] {
                e.insert("p_value".into(), Json::num(1.5));
            }
            m.insert("entries".into(), Json::Arr(entries));
        }
        assert!(validate_bench_json(&bad).is_err());
        // Entry with an unknown backend.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            let mut entries = m.get("entries").unwrap().as_arr().unwrap().to_vec();
            if let Json::Obj(e) = &mut entries[0] {
                e.insert("backend".into(), Json::str("warp-drive"));
            }
            m.insert("entries".into(), Json::Arr(entries));
        }
        assert!(validate_bench_json(&bad).is_err());
        // Entry with an unknown (or missing) method: v2 requires it.
        for method in [Some("kruskal"), None] {
            let mut bad = good.clone();
            if let Json::Obj(m) = &mut bad {
                let mut entries = m.get("entries").unwrap().as_arr().unwrap().to_vec();
                if let Json::Obj(e) = &mut entries[0] {
                    match method {
                        Some(v) => {
                            e.insert("method".into(), Json::str(v));
                        }
                        None => {
                            e.remove("method");
                        }
                    }
                }
                m.insert("entries".into(), Json::Arr(entries));
            }
            assert!(validate_bench_json(&bad).is_err(), "{method:?}");
        }
        // Entry missing the v4/v6 traffic fields.
        for key in [
            "bytes_per_perm",
            "effective_gbs",
            "footprint_ratio",
            "packed_bytes",
            "resident_bytes",
        ] {
            let mut bad = good.clone();
            if let Json::Obj(m) = &mut bad {
                let mut entries = m.get("entries").unwrap().as_arr().unwrap().to_vec();
                if let Json::Obj(e) = &mut entries[0] {
                    e.remove(key);
                }
                m.insert("entries".into(), Json::Arr(entries));
            }
            assert!(validate_bench_json(&bad).is_err(), "missing {key} accepted");
        }
        // A footprint ratio above 0.5 (packed not actually packed) fails.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            let mut entries = m.get("entries").unwrap().as_arr().unwrap().to_vec();
            if let Json::Obj(e) = &mut entries[0] {
                e.insert("footprint_ratio".into(), Json::num(0.9));
            }
            m.insert("entries".into(), Json::Arr(entries));
        }
        assert!(validate_bench_json(&bad).is_err());
        // A residency that still includes the dense copy fails (v6): the
        // validator pins resident_bytes to exactly packed + offsets.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            let mut entries = m.get("entries").unwrap().as_arr().unwrap().to_vec();
            if let Json::Obj(e) = &mut entries[0] {
                let packed = e.get("packed_bytes").unwrap().as_f64().unwrap();
                let dense = e.get("dense_bytes").unwrap().as_f64().unwrap();
                e.insert("resident_bytes".into(), Json::num(packed + dense + 8.0 * 25.0));
            }
            m.insert("entries".into(), Json::Arr(entries));
        }
        let e = validate_bench_json(&bad).unwrap_err().to_string();
        assert!(e.contains("resident_bytes"), "{e}");
        // Missing throughput section (v3 requires the key).
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            m.remove("throughput");
        }
        assert!(validate_bench_json(&bad).is_err());
        // Throughput cell whose hit/miss counters don't add up.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            let mut cells = m.get("throughput").unwrap().as_arr().unwrap().to_vec();
            if let Json::Obj(c) = &mut cells[0] {
                c.insert("warm_hits".into(), Json::num(99));
            }
            m.insert("throughput".into(), Json::Arr(cells));
        }
        assert!(validate_bench_json(&bad).is_err());
        // Throughput cell with a non-positive rate.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            let mut cells = m.get("throughput").unwrap().as_arr().unwrap().to_vec();
            if let Json::Obj(c) = &mut cells[0] {
                c.insert("warm_jobs_per_sec".into(), Json::num(0));
            }
            m.insert("throughput".into(), Json::Arr(cells));
        }
        assert!(validate_bench_json(&bad).is_err());
        // Not an object at all.
        assert!(validate_bench_json(&Json::Arr(vec![])).is_err());
        // Missing latency section (v5 requires the key).
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            m.remove("latency");
        }
        assert!(validate_bench_json(&bad).is_err());
        // Missing restart_warm section (v7 requires the key).
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            m.remove("restart_warm");
        }
        let e = validate_bench_json(&bad).unwrap_err().to_string();
        assert!(e.contains("restart_warm"), "{e}");
        // A disk-warm pass that recomputed (store_hits != jobs) fails.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            let mut cells = m.get("restart_warm").unwrap().as_arr().unwrap().to_vec();
            if let Json::Obj(c) = &mut cells[0] {
                c.insert("store_hits".into(), Json::num(0));
            }
            m.insert("restart_warm".into(), Json::Arr(cells));
        }
        let e = validate_bench_json(&bad).unwrap_err().to_string();
        assert!(e.contains("store_hits"), "{e}");
        // A seeding pass that wrote nothing fails.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            let mut cells = m.get("restart_warm").unwrap().as_arr().unwrap().to_vec();
            if let Json::Obj(c) = &mut cells[0] {
                c.insert("store_puts".into(), Json::num(0));
            }
            m.insert("restart_warm".into(), Json::Arr(cells));
        }
        let e = validate_bench_json(&bad).unwrap_err().to_string();
        assert!(e.contains("store_puts"), "{e}");
    }

    #[test]
    fn latency_axis_measures_open_loop_percentiles() {
        let mut g = tiny_grid();
        g.backends = vec!["native-brute".into()];
        g.latency_clients = vec![1, 2];
        let out = run_sweep(&g).unwrap();
        assert_eq!(validate_bench_json(&out.json).unwrap(), 1);
        assert!(out.table.contains("daemon latency"), "{}", out.table);
        let cells = out.json.req_arr("latency").unwrap();
        assert_eq!(cells.len(), 2, "one cell per client count");
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.req_usize("clients").unwrap(), i + 1);
            assert_eq!(c.req_usize("requests_per_client").unwrap(), 2);
            let total = c.req_usize("total_requests").unwrap();
            assert_eq!(total, (i + 1) * 2);
            assert_eq!(
                c.req_usize("completed").unwrap() + c.req_usize("shed").unwrap(),
                total
            );
            let p50 = c.get("p50_ms").unwrap().as_f64().unwrap();
            let p99 = c.get("p99_ms").unwrap().as_f64().unwrap();
            assert!(p50 > 0.0 && p50 <= p99, "p50 {p50} p99 {p99}");
            assert!(c.get("responses_per_sec").unwrap().as_f64().unwrap() > 0.0);
        }

        // Disabled axis: the key survives, empty, and still validates.
        g.latency_clients = vec![];
        let out = run_sweep(&g).unwrap();
        assert!(out.json.req_arr("latency").unwrap().is_empty());
        assert!(!out.table.contains("daemon latency"));
        assert_eq!(validate_bench_json(&out.json).unwrap(), 1);

        // A zero client count is rejected, not clamped.
        g.latency_clients = vec![0];
        assert!(run_sweep(&g).is_err());

        // Validator: inconsistent percentiles fail.
        g.latency_clients = vec![1];
        let good = run_sweep(&g).unwrap().json;
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            let mut cells = m.get("latency").unwrap().as_arr().unwrap().to_vec();
            if let Json::Obj(c) = &mut cells[0] {
                c.insert("p50_ms".into(), Json::num(1e9));
            }
            m.insert("latency".into(), Json::Arr(cells));
        }
        assert!(validate_bench_json(&bad).is_err(), "p50 > p99 accepted");
    }
}
