//! Micro-benchmark harness: warmup, adaptive iteration, robust statistics.
//!
//! The offline crate set has no criterion — and a benchmarking paper
//! deserves a first-class harness anyway.  The design follows STREAM's
//! methodology (the paper's own appendix): fixed warmup, best-and-median of
//! N timed repetitions, and robust spread (median absolute deviation) so a
//! noisy-neighbour run doesn't poison a comparison.
//!
//! ```no_run
//! use permanova_apu::bench::Bencher;
//! let mut b = Bencher::default();
//! let m = b.run("sum", || (0..1_000_000u64).sum::<u64>());
//! println!("{}", m.format_row());
//! ```

use std::time::{Duration, Instant};

/// Benchmark configuration.
#[derive(Clone, Debug)]
pub struct Bencher {
    /// Warmup repetitions (not timed).
    pub warmup: usize,
    /// Minimum timed repetitions.
    pub min_reps: usize,
    /// Maximum timed repetitions.
    pub max_reps: usize,
    /// Time budget per benchmark; reps stop early once exceeded (but never
    /// before `min_reps`).
    pub max_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: 2,
            min_reps: 5,
            max_reps: 50,
            max_time: Duration::from_secs(10),
        }
    }
}

impl Bencher {
    /// Quick preset for heavyweight end-to-end benches.
    pub fn heavy() -> Self {
        Bencher { warmup: 1, min_reps: 3, max_reps: 10, max_time: Duration::from_secs(30) }
    }

    /// Time `f` under this configuration.  The closure's return value is
    /// passed through `std::hint::black_box` so the computation cannot be
    /// optimized away.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.min_reps);
        let started = Instant::now();
        while times.len() < self.max_reps {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
            if times.len() >= self.min_reps && started.elapsed() > self.max_time {
                break;
            }
        }
        Measurement::from_times(name, times)
    }
}

/// Robust statistics of one benchmark.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Individual repetition times, seconds, in run order.
    pub times: Vec<f64>,
    pub best: f64,
    pub median: f64,
    pub mean: f64,
    /// Median absolute deviation (scaled by 1.4826 ≈ σ for normal data).
    pub mad: f64,
    pub worst: f64,
}

impl Measurement {
    /// Compute stats from raw times.
    pub fn from_times(name: &str, times: Vec<f64>) -> Measurement {
        assert!(!times.is_empty(), "no timings for {name}");
        let mut sorted = times.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let best = sorted[0];
        let worst = *sorted.last().unwrap();
        let median = percentile_sorted(&sorted, 50.0);
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = 1.4826 * percentile_sorted(&devs, 50.0);
        Measurement { name: name.to_string(), times, best, median, mean, mad, worst }
    }

    /// Bandwidth implied by moving `bytes` in the *best* time (STREAM's
    /// convention), in GB/s (10^9).
    pub fn best_rate_gbs(&self, bytes: usize) -> f64 {
        bytes as f64 / self.best / 1e9
    }

    /// Throughput at the median time, items per second.
    pub fn median_throughput(&self, items: usize) -> f64 {
        items as f64 / self.median
    }

    /// One formatted report row.
    pub fn format_row(&self) -> String {
        format!(
            "{:<36} best {:>10} median {:>10} ±{:>9} (n={})",
            self.name,
            format_secs(self.best),
            format_secs(self.median),
            format_secs(self.mad),
            self.times.len()
        )
    }
}

/// Percentile (0–100) of an ascending-sorted slice, linear interpolation.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Human-readable seconds (ns/µs/ms/s autoscale).
pub fn format_secs(t: f64) -> String {
    if t < 1e-6 {
        format!("{:.1}ns", t * 1e9)
    } else if t < 1e-3 {
        format!("{:.2}µs", t * 1e6)
    } else if t < 1.0 {
        format!("{:.2}ms", t * 1e3)
    } else {
        format!("{:.3}s", t)
    }
}

/// Speedup of `b` relative to `a` (how many times faster is b), by medians.
pub fn speedup(a: &Measurement, b: &Measurement) -> f64 {
    a.median / b.median
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_known_vector() {
        let m = Measurement::from_times("x", vec![3.0, 1.0, 2.0, 4.0, 100.0]);
        assert_eq!(m.best, 1.0);
        assert_eq!(m.worst, 100.0);
        assert_eq!(m.median, 3.0);
        assert!((m.mean - 22.0).abs() < 1e-12);
        // MAD robust to the outlier: devs {2,1,0,1,97} → median 1 → 1.4826
        assert!((m.mad - 1.4826).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 4.0);
        assert!((percentile_sorted(&v, 50.0) - 2.5).abs() < 1e-12);
        assert_eq!(percentile_sorted(&[7.0], 50.0), 7.0);
    }

    #[test]
    fn run_executes_and_counts() {
        let mut calls = 0usize;
        let mut b =
            Bencher { warmup: 1, min_reps: 3, max_reps: 3, max_time: Duration::from_secs(5) };
        let m = b.run("count", || {
            calls += 1;
            calls
        });
        assert_eq!(calls, 4); // 1 warmup + 3 timed
        assert_eq!(m.times.len(), 3);
        assert!(m.best > 0.0);
        assert!(m.best <= m.median && m.median <= m.worst);
    }

    #[test]
    fn max_time_stops_early() {
        let mut b = Bencher {
            warmup: 0,
            min_reps: 2,
            max_reps: 1000,
            max_time: Duration::from_millis(50),
        };
        let m = b.run("sleepy", || std::thread::sleep(Duration::from_millis(20)));
        assert!(m.times.len() < 1000, "stopped early: {}", m.times.len());
        assert!(m.times.len() >= 2);
    }

    #[test]
    fn rates_and_formatting() {
        let m = Measurement::from_times("bw", vec![0.5]);
        assert!((m.best_rate_gbs(1_000_000_000) - 2.0).abs() < 1e-9);
        assert!((m.median_throughput(100) - 200.0).abs() < 1e-9);
        assert!(format_secs(2.5e-9).ends_with("ns"));
        assert!(format_secs(2.5e-6).ends_with("µs"));
        assert!(format_secs(2.5e-3).ends_with("ms"));
        assert!(format_secs(2.5).ends_with('s'));
        assert!(m.format_row().contains("bw"));
    }

    #[test]
    fn speedup_direction() {
        let slow = Measurement::from_times("slow", vec![2.0]);
        let fast = Measurement::from_times("fast", vec![0.5]);
        assert!((speedup(&slow, &fast) - 4.0).abs() < 1e-12);
    }
}
