//! Run reports and rendering: the structured result of a PERMANOVA run,
//! plus tables, horizontal bar charts and markdown fragments.
//!
//! Everything the CLI, examples and benches print goes through here so the
//! output of `cargo bench` lines up with what EXPERIMENTS.md records.
//! [`RunReport`] always records **which backend** produced it — the
//! provenance every cross-substrate comparison in this repo leans on.

use std::fmt::Write as _;

use crate::jsonio::Json;

/// Per-device (or per-backend) utilization after a run.
#[derive(Clone, Debug)]
pub struct DeviceStats {
    pub device: String,
    pub batches: usize,
    pub perms: usize,
    pub busy_secs: f64,
    /// Sum of modelled MI300A seconds (simulated devices only).
    pub simulated_secs: f64,
}

/// Aggregated output of a PERMANOVA run (backend engine or coordinator).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub f_obs: f64,
    pub p_value: f64,
    pub n_perms: usize,
    pub n: usize,
    pub k: usize,
    pub s_t: f64,
    pub elapsed_secs: f64,
    /// Registry name of the backend that produced this report
    /// (`"coordinated"` for heterogeneous multi-device runs).
    pub backend: String,
    /// Kernel formulation the backend actually evaluated
    /// ([`Caps::kernel`](crate::backend::Caps) — `"mixed"` for
    /// heterogeneous runs), rendered and serialized as `algo`.
    pub kernel: String,
    /// Permutations per matrix sweep **actually used** (the configured
    /// width clamped to the permutation count), when the producing backend
    /// is block-batched (`native-batch`); 0 for one-permutation-per-sweep
    /// backends.
    pub perm_block: usize,
    pub per_device: Vec<DeviceStats>,
    /// The permuted F distribution (observed excluded), in plan order.
    pub f_perms: Vec<f64>,
}

impl RunReport {
    /// Human-readable report block (the CLI's `run` output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "PERMANOVA  n={} k={} perms={} backend={} algo={}{}\n",
            self.n,
            self.k,
            self.n_perms,
            self.backend,
            self.kernel,
            if self.perm_block > 0 {
                format!(" block={}", self.perm_block)
            } else {
                String::new()
            }
        ));
        out.push_str(&format!(
            "  pseudo-F = {:.6}\n  p-value  = {:.6}\n  s_T      = {:.6}\n  wall     = {:.3}s\n",
            self.f_obs, self.p_value, self.s_t, self.elapsed_secs
        ));
        let mut t = Table::new(&["device", "batches", "perms", "busy s", "modelled s"]);
        for d in &self.per_device {
            t.row(&[
                d.device.clone(),
                d.batches.to_string(),
                d.perms.to_string(),
                format!("{:.3}", d.busy_secs),
                if d.simulated_secs > 0.0 {
                    format!("{:.3}", d.simulated_secs)
                } else {
                    "-".to_string()
                },
            ]);
        }
        out.push_str(&t.render());
        out
    }

    /// Machine-readable report (consumed by scripts / CI trend tracking).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::str(crate::VERSION)),
            ("backend", Json::str(self.backend.clone())),
            ("algo", Json::str(self.kernel.clone())),
            ("n", Json::num(self.n as f64)),
            ("k", Json::num(self.k as f64)),
            ("n_perms", Json::num(self.n_perms as f64)),
            ("perm_block", Json::num(self.perm_block as f64)),
            ("f_obs", Json::num(self.f_obs)),
            ("p_value", Json::num(self.p_value)),
            ("s_t", Json::num(self.s_t)),
            ("elapsed_secs", Json::num(self.elapsed_secs)),
            (
                "devices",
                Json::Arr(
                    self.per_device
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("device", Json::str(d.device.clone())),
                                ("batches", Json::num(d.batches as f64)),
                                ("perms", Json::num(d.perms as f64)),
                                ("busy_secs", Json::num(d.busy_secs)),
                                ("simulated_secs", Json::num(d.simulated_secs)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// A simple aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience for &str cells.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as aligned plain text (first column left, rest right).
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                if i == 0 {
                    let _ = write!(out, "{:<width$}", c, width = w[i]);
                } else {
                    let _ = write!(out, "{:>width$}", c, width = w[i]);
                }
            }
            out.push('\n');
        };
        fmt_row(&self.headers, &mut out);
        let total: usize = w.iter().sum::<usize>() + 2 * (w.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }

    /// Render as a GitHub-flavoured markdown table (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        out
    }
}

/// Horizontal bar chart (the paper's Figure 1 format: label, value, bar;
/// lower is better, bars scaled to the max).
pub fn bar_chart(title: &str, items: &[(String, f64)], unit: &str, width: usize) -> String {
    let max = items.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max);
    let label_w = items.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for (label, v) in items {
        let bar = if max > 0.0 {
            (((v / max) * width as f64).round() as usize).max(1)
        } else {
            1
        };
        let _ = writeln!(
            out,
            "{:<label_w$} {:>9.2}{} |{}",
            label,
            v,
            unit,
            "#".repeat(bar)
        );
    }
    out
}

/// Format a byte count with binary units.
pub fn format_bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["name", "value"]);
        t.row_str(&["alpha", "1"]).row_str(&["b", "22222"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[1].starts_with('-'));
        // Right-aligned value column: both data lines same width.
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_wrong_arity() {
        Table::new(&["a", "b"]).row_str(&["only one"]);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(&["x", "y"]);
        t.row_str(&["1", "2"]);
        let md = t.render_markdown();
        assert!(md.starts_with("| x | y |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn bar_chart_scaling() {
        let items = vec![("slow".to_string(), 10.0), ("fast".to_string(), 2.5)];
        let s = bar_chart("t", &items, "s", 40);
        let slow_bar = s.lines().find(|l| l.starts_with("slow")).unwrap();
        let fast_bar = s.lines().find(|l| l.starts_with("fast")).unwrap();
        let count = |l: &str| l.chars().filter(|&c| c == '#').count();
        assert_eq!(count(slow_bar), 40);
        assert_eq!(count(fast_bar), 10);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(format_bytes(512), "512 B");
        assert_eq!(format_bytes(2048), "2.00 KiB");
        assert_eq!(format_bytes(5_057_000_000_000), "4.60 TiB");
    }

    fn sample_report() -> RunReport {
        RunReport {
            f_obs: 2.5,
            p_value: 0.01,
            n_perms: 99,
            n: 40,
            k: 4,
            s_t: 10.0,
            elapsed_secs: 0.5,
            backend: "native-tiled".into(),
            kernel: "tiled512".into(),
            perm_block: 0,
            per_device: vec![DeviceStats {
                device: "native-tiled".into(),
                batches: 1,
                perms: 100,
                busy_secs: 0.4,
                simulated_secs: 0.0,
            }],
            f_perms: vec![1.0; 99],
        }
    }

    #[test]
    fn run_report_render_records_backend() {
        let s = sample_report().render();
        assert!(s.contains("backend=native-tiled"));
        assert!(s.contains("algo=tiled512"));
        assert!(s.contains("pseudo-F"));
        // perm_block = 0: no block annotation for non-batched backends.
        assert!(!s.contains("block="));
    }

    #[test]
    fn run_report_render_shows_perm_block_when_batched() {
        let mut r = sample_report();
        r.backend = "native-batch".into();
        r.kernel = "brute-block".into();
        r.perm_block = 64;
        let s = r.render();
        assert!(s.contains("backend=native-batch"));
        assert!(s.contains("algo=brute-block"), "{s}");
        assert!(s.contains("block=64"), "{s}");
    }

    #[test]
    fn run_report_json_roundtrips() {
        let doc = sample_report().to_json();
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.req_str("backend").unwrap(), "native-tiled");
        assert_eq!(parsed.req_usize("n_perms").unwrap(), 99);
        assert_eq!(parsed.req_usize("perm_block").unwrap(), 0);
        assert_eq!(parsed.req_arr("devices").unwrap().len(), 1);
    }
}
