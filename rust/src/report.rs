//! Run reports and rendering: the structured results of permutation-test
//! runs, plus tables, horizontal bar charts and markdown fragments.
//!
//! Everything the CLI, examples and benches print goes through here so the
//! output of `cargo bench` lines up with what EXPERIMENTS.md records.
//! [`RunReport`] always records **which backend** produced it and **which
//! method** it evaluated — the provenance every cross-substrate comparison
//! in this repo leans on.  [`AnalysisReport`] is the method-tagged
//! aggregate `backend::execute` returns: one run for the single-statistic
//! methods, one run per group pair for pairwise PERMANOVA.
//!
//! Serialization stability contract: `AnalysisReport::to_json(...)
//! .to_string()` is the **value stored** by the durable
//! [`ResultStore`](crate::store::ResultStore) — a store hit returns those
//! bytes verbatim, and the persistence suite asserts bitwise equality
//! across process restarts.  Keep `to_json` deterministic: field set and
//! values must be pure functions of the run (no wall-clock reads beyond
//! the existing `elapsed_secs`/`busy_secs` measurements captured during
//! execution, no map iteration with unstable order — [`Json::obj`] sorts
//! keys, which is what makes the round-trip byte-stable).

use std::fmt::Write as _;

use crate::jsonio::Json;
use crate::permanova::Method;

/// Per-device (or per-backend) utilization after a run.
#[derive(Clone, Debug)]
pub struct DeviceStats {
    pub device: String,
    pub batches: usize,
    pub perms: usize,
    pub busy_secs: f64,
    /// Sum of modelled MI300A seconds (simulated devices only).
    pub simulated_secs: f64,
}

/// Out-of-core paging activity of one run over a **file-backed** triangle
/// (absent for resident runs — uncapped reports serialize byte-identically
/// to before the out-of-core tier existed).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OocoreStats {
    /// The residency budget the run paged under (`--max-resident-bytes`).
    pub resident_cap: u64,
    /// Chunks read from disk during this run (prelude + permutation sweep).
    pub chunks_paged: u64,
    /// Bytes read from disk during this run.
    pub bytes_paged: u64,
}

/// Aggregated output of one permutation-test run (backend engine or
/// coordinator).  `f_obs` / `f_perms` hold the run's *method statistic* —
/// pseudo-F for PERMANOVA, R for ANOSIM, ANOVA F for PERMDISP (the field
/// names predate the statistic-generic engine and are kept for
/// machine-readable compatibility).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub f_obs: f64,
    pub p_value: f64,
    pub n_perms: usize,
    pub n: usize,
    pub k: usize,
    pub s_t: f64,
    pub elapsed_secs: f64,
    /// Name of the method evaluated ([`Method::name`]; pairwise fan-out
    /// runs record `"permanova"` — the per-pair job's method).
    pub method: String,
    /// Registry name of the backend that produced this report
    /// (`"coordinated"` for heterogeneous multi-device runs).
    pub backend: String,
    /// Kernel formulation the backend actually evaluated
    /// ([`Caps::kernel`](crate::backend::Caps) — `"mixed"` for
    /// heterogeneous runs), rendered and serialized as `algo`.
    pub kernel: String,
    /// Permutations per matrix sweep **actually used** (the configured
    /// width clamped to the permutation count), when the producing backend
    /// is block-batched (`native-batch`); 0 for one-permutation-per-sweep
    /// backends.
    pub perm_block: usize,
    pub per_device: Vec<DeviceStats>,
    /// Paging activity when the run swept a file-backed triangle under a
    /// residency budget (`None` for resident runs — and absent from the
    /// JSON, keeping uncapped serialization byte-stable).
    pub oocore: Option<OocoreStats>,
    /// The permuted F distribution (observed excluded), in plan order.
    pub f_perms: Vec<f64>,
}

impl RunReport {
    /// The parsed method tag (None if a foreign producer wrote an unknown
    /// name — rendering then falls back to generic labels).
    fn method_tag(&self) -> Option<Method> {
        Method::parse(&self.method)
    }

    /// Human-readable report block (the CLI's `run` output).
    pub fn render(&self) -> String {
        let title = self.method_tag().map_or("PERMANOVA", |m| m.title());
        let stat = self.method_tag().map_or("statistic", |m| m.statistic_label());
        let mut out = String::new();
        out.push_str(&format!(
            "{title}  n={} k={} perms={} backend={} algo={}{}\n",
            self.n,
            self.k,
            self.n_perms,
            self.backend,
            self.kernel,
            if self.perm_block > 0 {
                format!(" block={}", self.perm_block)
            } else {
                String::new()
            }
        ));
        out.push_str(&format!(
            "  {stat:<8} = {:.6}\n  p-value  = {:.6}\n",
            self.f_obs, self.p_value
        ));
        // s_T is a pseudo-F decomposition diagnostic; it does not exist
        // for the rank / dispersion statistics.
        if self.method_tag() != Some(Method::Anosim) && self.method_tag() != Some(Method::Permdisp)
        {
            out.push_str(&format!("  s_T      = {:.6}\n", self.s_t));
        }
        out.push_str(&format!("  wall     = {:.3}s\n", self.elapsed_secs));
        if let Some(oo) = &self.oocore {
            out.push_str(&format!(
                "  paging   = {} chunks, {} read (cap {})\n",
                oo.chunks_paged,
                format_bytes(oo.bytes_paged),
                format_bytes(oo.resident_cap),
            ));
        }
        let mut t = Table::new(&["device", "batches", "perms", "busy s", "modelled s"]);
        for d in &self.per_device {
            t.row(&[
                d.device.clone(),
                d.batches.to_string(),
                d.perms.to_string(),
                format!("{:.3}", d.busy_secs),
                if d.simulated_secs > 0.0 {
                    format!("{:.3}", d.simulated_secs)
                } else {
                    "-".to_string()
                },
            ]);
        }
        out.push_str(&t.render());
        out
    }

    /// Machine-readable report (consumed by scripts / CI trend tracking).
    /// The `oocore` section appears only for file-backed runs, so uncapped
    /// reports keep their exact byte shape (the store contract).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("version", Json::str(crate::VERSION)),
            ("method", Json::str(self.method.clone())),
            ("backend", Json::str(self.backend.clone())),
            ("algo", Json::str(self.kernel.clone())),
            ("n", Json::num(self.n as f64)),
            ("k", Json::num(self.k as f64)),
            ("n_perms", Json::num(self.n_perms as f64)),
            ("perm_block", Json::num(self.perm_block as f64)),
            ("f_obs", Json::num(self.f_obs)),
            ("p_value", Json::num(self.p_value)),
            ("s_t", Json::num(self.s_t)),
            ("elapsed_secs", Json::num(self.elapsed_secs)),
            (
                "devices",
                Json::Arr(
                    self.per_device
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("device", Json::str(d.device.clone())),
                                ("batches", Json::num(d.batches as f64)),
                                ("perms", Json::num(d.perms as f64)),
                                ("busy_secs", Json::num(d.busy_secs)),
                                ("simulated_secs", Json::num(d.simulated_secs)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if let Some(oo) = &self.oocore {
            fields.push((
                "oocore",
                Json::obj(vec![
                    ("resident_cap", Json::num(oo.resident_cap as f64)),
                    ("chunks_paged", Json::num(oo.chunks_paged as f64)),
                    ("bytes_paged", Json::num(oo.bytes_paged as f64)),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

/// One pair's identity + multiple-comparison adjustment inside a pairwise
/// PERMANOVA fan-out, parallel to [`AnalysisReport::runs`].
#[derive(Clone, Debug)]
pub struct PairSummary {
    pub group_a: u32,
    pub group_b: u32,
    /// Objects in the pair's sub-problem.
    pub n: usize,
    /// Bonferroni-adjusted p (capped at 1).
    pub p_adjusted: f64,
}

/// The method-tagged result of `backend::execute`: which [`Method`] ran,
/// and one [`RunReport`] per scheduled job — exactly one for PERMANOVA /
/// ANOSIM / PERMDISP, one per group pair for pairwise PERMANOVA.
///
/// Dereferences to the primary run (`runs[0]`), so single-run consumers
/// keep reading `report.f_obs`, `report.p_value`, `report.backend`, ...
/// without unwrapping; pairwise consumers walk `runs` / `pairs`.
#[derive(Clone, Debug)]
pub struct AnalysisReport {
    pub method: Method,
    /// Objects / groups of the *full* problem (pairwise runs record their
    /// sub-problem sizes in their own reports).
    pub n: usize,
    pub k: usize,
    /// One report per scheduled job, never empty.
    pub runs: Vec<RunReport>,
    /// Pair identities + Bonferroni adjustments, parallel to `runs`
    /// (pairwise PERMANOVA only; empty otherwise).
    pub pairs: Vec<PairSummary>,
    /// Mean distance-to-centroid per group (PERMDISP only; empty otherwise).
    pub group_dispersions: Vec<f64>,
}

impl std::ops::Deref for AnalysisReport {
    type Target = RunReport;

    /// The primary run.  Deliberate non-smart-pointer `Deref`: an
    /// `AnalysisReport` *is* its primary `RunReport` plus method metadata,
    /// and every pre-existing consumer reads primary-run fields.
    fn deref(&self) -> &RunReport {
        &self.runs[0]
    }
}

impl AnalysisReport {
    /// The primary run: the single run for one-statistic methods, the
    /// first pair's run for pairwise.
    pub fn primary(&self) -> &RunReport {
        &self.runs[0]
    }

    /// Total permutations evaluated across every scheduled job (including
    /// each job's observed labelling — what throughput metrics count).
    pub fn total_perms(&self) -> usize {
        self.runs.iter().map(|r| r.n_perms + 1).sum()
    }

    /// Human-readable report (the CLI's `run` output for every method).
    pub fn render(&self) -> String {
        match self.method {
            Method::PairwisePermanova => {
                let r0 = self.primary();
                let mut out = format!(
                    "{}  n={} k={} perms={} backend={} algo={} comparisons={}\n",
                    self.method.title(),
                    self.n,
                    self.k,
                    r0.n_perms,
                    r0.backend,
                    r0.kernel,
                    self.pairs.len()
                );
                let mut t = Table::new(&["pair", "n", "pseudo-F", "p", "p (Bonferroni)"]);
                for (pair, run) in self.pairs.iter().zip(&self.runs) {
                    t.row(&[
                        format!("{} vs {}", pair.group_a, pair.group_b),
                        pair.n.to_string(),
                        format!("{:.4}", run.f_obs),
                        format!("{:.4}", run.p_value),
                        format!("{:.4}", pair.p_adjusted),
                    ]);
                }
                out.push_str(&t.render());
                out
            }
            _ => {
                let mut out = self.primary().render();
                if !self.group_dispersions.is_empty() {
                    out.push_str(&format!(
                        "  dispersions: {}\n",
                        self.group_dispersions
                            .iter()
                            .map(|d| format!("{d:.4}"))
                            .collect::<Vec<_>>()
                            .join(", ")
                    ));
                }
                out
            }
        }
    }

    /// Machine-readable report.  Single-run methods keep the exact
    /// [`RunReport::to_json`] shape (plus `group_dispersions` for
    /// PERMDISP); pairwise emits one entry per pair under `pairs`.
    pub fn to_json(&self) -> Json {
        match self.method {
            Method::PairwisePermanova => {
                let r0 = self.primary();
                Json::obj(vec![
                    ("version", Json::str(crate::VERSION)),
                    ("method", Json::str(self.method.name())),
                    ("backend", Json::str(r0.backend.clone())),
                    ("n", Json::num(self.n as f64)),
                    ("k", Json::num(self.k as f64)),
                    ("n_perms", Json::num(r0.n_perms as f64)),
                    ("n_comparisons", Json::num(self.pairs.len() as f64)),
                    (
                        "pairs",
                        Json::Arr(
                            self.pairs
                                .iter()
                                .zip(&self.runs)
                                .map(|(pair, run)| {
                                    Json::obj(vec![
                                        ("group_a", Json::num(pair.group_a as f64)),
                                        ("group_b", Json::num(pair.group_b as f64)),
                                        ("n", Json::num(pair.n as f64)),
                                        ("f_obs", Json::num(run.f_obs)),
                                        ("p_value", Json::num(run.p_value)),
                                        ("p_adjusted", Json::num(pair.p_adjusted)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            }
            _ => {
                let mut doc = self.primary().to_json();
                if !self.group_dispersions.is_empty() {
                    if let Json::Obj(m) = &mut doc {
                        m.insert(
                            "group_dispersions".into(),
                            Json::Arr(
                                self.group_dispersions.iter().map(|&d| Json::num(d)).collect(),
                            ),
                        );
                    }
                }
                doc
            }
        }
    }
}

/// A simple aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience for &str cells.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as aligned plain text (first column left, rest right).
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                if i == 0 {
                    let _ = write!(out, "{:<width$}", c, width = w[i]);
                } else {
                    let _ = write!(out, "{:>width$}", c, width = w[i]);
                }
            }
            out.push('\n');
        };
        fmt_row(&self.headers, &mut out);
        let total: usize = w.iter().sum::<usize>() + 2 * w.len().saturating_sub(1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }

    /// Render as a GitHub-flavoured markdown table (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        out
    }
}

/// Horizontal bar chart (the paper's Figure 1 format: label, value, bar;
/// lower is better, bars scaled to the max).
pub fn bar_chart(title: &str, items: &[(String, f64)], unit: &str, width: usize) -> String {
    let max = items.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max);
    let label_w = items.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for (label, v) in items {
        let bar = if max > 0.0 {
            (((v / max) * width as f64).round() as usize).max(1)
        } else {
            1
        };
        let _ = writeln!(
            out,
            "{:<label_w$} {:>9.2}{} |{}",
            label,
            v,
            unit,
            "#".repeat(bar)
        );
    }
    out
}

/// Format a per-second rate with a unit word, autoscaled through k/M
/// (`format_rate(19.25, "jobs")` → `"19.2 jobs/s"`).  Shared by the batch
/// summary and the bench throughput table so rates render identically
/// everywhere.
pub fn format_rate(per_sec: f64, what: &str) -> String {
    if per_sec >= 1e6 {
        format!("{:.2}M {what}/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2}k {what}/s", per_sec / 1e3)
    } else if per_sec >= 10.0 {
        format!("{per_sec:.1} {what}/s")
    } else {
        format!("{per_sec:.3} {what}/s")
    }
}

/// Format a byte count with binary units.
pub fn format_bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["name", "value"]);
        t.row_str(&["alpha", "1"]).row_str(&["b", "22222"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[1].starts_with('-'));
        // Right-aligned value column: both data lines same width.
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_wrong_arity() {
        Table::new(&["a", "b"]).row_str(&["only one"]);
    }

    #[test]
    fn empty_table_renders_without_underflow() {
        // Regression: zero headers used to underflow the separator width.
        let s = Table::new(&[]).render();
        assert!(s.ends_with('\n'));
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(&["x", "y"]);
        t.row_str(&["1", "2"]);
        let md = t.render_markdown();
        assert!(md.starts_with("| x | y |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn bar_chart_scaling() {
        let items = vec![("slow".to_string(), 10.0), ("fast".to_string(), 2.5)];
        let s = bar_chart("t", &items, "s", 40);
        let slow_bar = s.lines().find(|l| l.starts_with("slow")).unwrap();
        let fast_bar = s.lines().find(|l| l.starts_with("fast")).unwrap();
        let count = |l: &str| l.chars().filter(|&c| c == '#').count();
        assert_eq!(count(slow_bar), 40);
        assert_eq!(count(fast_bar), 10);
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(format_rate(19.25, "jobs"), "19.2 jobs/s");
        assert_eq!(format_rate(0.5, "jobs"), "0.500 jobs/s");
        assert_eq!(format_rate(1_500.0, "perms"), "1.50k perms/s");
        assert_eq!(format_rate(2_000_000.0, "perms"), "2.00M perms/s");
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(format_bytes(512), "512 B");
        assert_eq!(format_bytes(2048), "2.00 KiB");
        assert_eq!(format_bytes(5_057_000_000_000), "4.60 TiB");
    }

    fn sample_report() -> RunReport {
        RunReport {
            f_obs: 2.5,
            p_value: 0.01,
            n_perms: 99,
            n: 40,
            k: 4,
            s_t: 10.0,
            elapsed_secs: 0.5,
            method: "permanova".into(),
            backend: "native-tiled".into(),
            kernel: "tiled512".into(),
            perm_block: 0,
            per_device: vec![DeviceStats {
                device: "native-tiled".into(),
                batches: 1,
                perms: 100,
                busy_secs: 0.4,
                simulated_secs: 0.0,
            }],
            oocore: None,
            f_perms: vec![1.0; 99],
        }
    }

    #[test]
    fn oocore_section_appears_only_for_file_backed_runs() {
        let resident = sample_report();
        let doc = resident.to_json().to_string();
        assert!(!doc.contains("oocore"), "uncapped reports keep their byte shape: {doc}");
        assert!(!resident.render().contains("paging"));

        let mut capped = sample_report();
        capped.oocore =
            Some(OocoreStats { resident_cap: 4096, chunks_paged: 7, bytes_paged: 12000 });
        let parsed = Json::parse(&capped.to_json().to_string()).unwrap();
        let oo = parsed.get("oocore").expect("capped reports carry the oocore section");
        assert_eq!(oo.req_usize("resident_cap").unwrap(), 4096);
        assert_eq!(oo.req_usize("chunks_paged").unwrap(), 7);
        assert_eq!(oo.req_usize("bytes_paged").unwrap(), 12000);
        let s = capped.render();
        assert!(s.contains("paging   = 7 chunks"), "{s}");
        assert!(s.contains("cap 4.00 KiB"), "{s}");
    }

    #[test]
    fn run_report_render_records_backend() {
        let s = sample_report().render();
        assert!(s.starts_with("PERMANOVA"));
        assert!(s.contains("backend=native-tiled"));
        assert!(s.contains("algo=tiled512"));
        assert!(s.contains("pseudo-F"));
        assert!(s.contains("s_T"));
        // perm_block = 0: no block annotation for non-batched backends.
        assert!(!s.contains("block="));
    }

    #[test]
    fn run_report_render_is_method_aware() {
        let mut r = sample_report();
        r.method = "anosim".into();
        r.kernel = "rank-r".into();
        let s = r.render();
        assert!(s.starts_with("ANOSIM"), "{s}");
        assert!(s.contains("R        = 2.500000"), "{s}");
        assert!(!s.contains("s_T"), "rank statistic has no s_T: {s}");

        r.method = "permdisp".into();
        let s = r.render();
        assert!(s.starts_with("PERMDISP"), "{s}");
        assert!(s.contains("F        = 2.500000"), "{s}");
    }

    fn pairwise_analysis() -> AnalysisReport {
        let mut a = sample_report();
        a.n = 20;
        let mut b = sample_report();
        b.n = 20;
        b.f_obs = 0.5;
        b.p_value = 0.8;
        AnalysisReport {
            method: Method::PairwisePermanova,
            n: 30,
            k: 3,
            runs: vec![a, b],
            pairs: vec![
                PairSummary { group_a: 0, group_b: 1, n: 20, p_adjusted: 0.03 },
                PairSummary { group_a: 0, group_b: 2, n: 20, p_adjusted: 1.0 },
            ],
            group_dispersions: vec![],
        }
    }

    #[test]
    fn analysis_report_derefs_to_primary_run() {
        let single = AnalysisReport {
            method: Method::Permanova,
            n: 40,
            k: 4,
            runs: vec![sample_report()],
            pairs: vec![],
            group_dispersions: vec![],
        };
        assert_eq!(single.f_obs, 2.5);
        assert_eq!(single.backend, "native-tiled");
        assert_eq!(single.total_perms(), 100);
        assert!(single.render().contains("pseudo-F"));
        // Single-method JSON keeps the RunReport shape.
        assert_eq!(single.to_json(), sample_report().to_json());
    }

    #[test]
    fn analysis_report_renders_pairwise_table() {
        let r = pairwise_analysis();
        assert_eq!(r.total_perms(), 200);
        let s = r.render();
        assert!(s.starts_with("PAIRWISE-PERMANOVA"), "{s}");
        assert!(s.contains("comparisons=2"), "{s}");
        assert!(s.contains("0 vs 1"), "{s}");
        assert!(s.contains("0 vs 2"), "{s}");
        let doc = r.to_json();
        let parsed = Json::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(parsed.req_str("method").unwrap(), "pairwise");
        assert_eq!(parsed.req_usize("n_comparisons").unwrap(), 2);
        assert_eq!(parsed.req_arr("pairs").unwrap().len(), 2);
    }

    #[test]
    fn analysis_report_appends_dispersions() {
        let mut r = sample_report();
        r.method = "permdisp".into();
        let a = AnalysisReport {
            method: Method::Permdisp,
            n: 40,
            k: 4,
            runs: vec![r],
            pairs: vec![],
            group_dispersions: vec![0.25, 0.5],
        };
        assert!(a.render().contains("dispersions: 0.2500, 0.5000"));
        let parsed = Json::parse(&a.to_json().to_string()).unwrap();
        assert_eq!(parsed.req_arr("group_dispersions").unwrap().len(), 2);
        assert_eq!(parsed.req_str("method").unwrap(), "permdisp");
    }

    #[test]
    fn run_report_render_shows_perm_block_when_batched() {
        let mut r = sample_report();
        r.backend = "native-batch".into();
        r.kernel = "brute-block".into();
        r.perm_block = 64;
        let s = r.render();
        assert!(s.contains("backend=native-batch"));
        assert!(s.contains("algo=brute-block"), "{s}");
        assert!(s.contains("block=64"), "{s}");
    }

    #[test]
    fn run_report_json_roundtrips() {
        let doc = sample_report().to_json();
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.req_str("backend").unwrap(), "native-tiled");
        assert_eq!(parsed.req_usize("n_perms").unwrap(), 99);
        assert_eq!(parsed.req_usize("perm_block").unwrap(), 0);
        assert_eq!(parsed.req_arr("devices").unwrap().len(), 1);
    }
}
