//! Report rendering: tables, horizontal bar charts, markdown fragments.
//!
//! Everything the CLI, examples and benches print goes through here so the
//! output of `cargo bench` lines up with what EXPERIMENTS.md records.

use std::fmt::Write as _;

/// A simple aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience for &str cells.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as aligned plain text (first column left, rest right).
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                if i == 0 {
                    let _ = write!(out, "{:<width$}", c, width = w[i]);
                } else {
                    let _ = write!(out, "{:>width$}", c, width = w[i]);
                }
            }
            out.push('\n');
        };
        fmt_row(&self.headers, &mut out);
        let total: usize = w.iter().sum::<usize>() + 2 * (w.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }

    /// Render as a GitHub-flavoured markdown table (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        out
    }
}

/// Horizontal bar chart (the paper's Figure 1 format: label, value, bar;
/// lower is better, bars scaled to the max).
pub fn bar_chart(title: &str, items: &[(String, f64)], unit: &str, width: usize) -> String {
    let max = items.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max);
    let label_w = items.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for (label, v) in items {
        let bar = if max > 0.0 {
            (((v / max) * width as f64).round() as usize).max(1)
        } else {
            1
        };
        let _ = writeln!(
            out,
            "{:<label_w$} {:>9.2}{} |{}",
            label,
            v,
            unit,
            "#".repeat(bar)
        );
    }
    out
}

/// Format a byte count with binary units.
pub fn format_bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["name", "value"]);
        t.row_str(&["alpha", "1"]).row_str(&["b", "22222"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[1].starts_with('-'));
        // Right-aligned value column: both data lines same width.
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_wrong_arity() {
        Table::new(&["a", "b"]).row_str(&["only one"]);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(&["x", "y"]);
        t.row_str(&["1", "2"]);
        let md = t.render_markdown();
        assert!(md.starts_with("| x | y |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn bar_chart_scaling() {
        let items = vec![("slow".to_string(), 10.0), ("fast".to_string(), 2.5)];
        let s = bar_chart("t", &items, "s", 40);
        let slow_bar = s.lines().find(|l| l.starts_with("slow")).unwrap();
        let fast_bar = s.lines().find(|l| l.starts_with("fast")).unwrap();
        let count = |l: &str| l.chars().filter(|&c| c == '#').count();
        assert_eq!(count(slow_bar), 40);
        assert_eq!(count(fast_bar), 10);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(format_bytes(512), "512 B");
        assert_eq!(format_bytes(2048), "2.00 KiB");
        assert_eq!(format_bytes(5_057_000_000_000), "4.60 TiB");
    }
}
