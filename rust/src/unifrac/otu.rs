//! OTU (feature) tables: which taxa are present in which samples.
//!
//! The minimal BIOM-equivalent the UniFrac computation needs: a dense
//! presence/absence matrix over (feature, sample), with ids on both axes.
//! Counts are kept (u32) so weighted metrics can be added later; unweighted
//! UniFrac only consumes presence.

use crate::error::{Error, Result};

/// A feature-by-sample count table.
#[derive(Clone, Debug)]
pub struct OtuTable {
    feature_ids: Vec<String>,
    sample_ids: Vec<String>,
    /// Row-major `n_features x n_samples` counts.
    counts: Vec<u32>,
}

impl OtuTable {
    /// Build from parts; validates dimensions and id uniqueness.
    pub fn new(
        feature_ids: Vec<String>,
        sample_ids: Vec<String>,
        counts: Vec<u32>,
    ) -> Result<Self> {
        if counts.len() != feature_ids.len() * sample_ids.len() {
            return Err(Error::InvalidInput(format!(
                "counts has {} entries, want {} features x {} samples",
                counts.len(),
                feature_ids.len(),
                sample_ids.len()
            )));
        }
        for ids in [&feature_ids, &sample_ids] {
            let mut seen = std::collections::HashSet::new();
            for id in ids {
                if !seen.insert(id) {
                    return Err(Error::InvalidInput(format!("duplicate id {id:?}")));
                }
            }
        }
        Ok(OtuTable { feature_ids, sample_ids, counts })
    }

    /// All-zero table.
    pub fn zeros(feature_ids: Vec<String>, sample_ids: Vec<String>) -> Result<Self> {
        let len = feature_ids.len() * sample_ids.len();
        Self::new(feature_ids, sample_ids, vec![0; len])
    }

    pub fn n_features(&self) -> usize {
        self.feature_ids.len()
    }

    pub fn n_samples(&self) -> usize {
        self.sample_ids.len()
    }

    pub fn feature_ids(&self) -> &[String] {
        &self.feature_ids
    }

    pub fn sample_ids(&self) -> &[String] {
        &self.sample_ids
    }

    /// Count of feature `f` in sample `s`.
    #[inline]
    pub fn count(&self, f: usize, s: usize) -> u32 {
        self.counts[f * self.sample_ids.len() + s]
    }

    /// Set count of feature `f` in sample `s`.
    pub fn set_count(&mut self, f: usize, s: usize, c: u32) {
        self.counts[f * self.sample_ids.len() + s] = c;
    }

    /// Presence of feature `f` in sample `s`.
    #[inline]
    pub fn present(&self, f: usize, s: usize) -> bool {
        self.count(f, s) > 0
    }

    /// Number of features present in sample `s` (its richness).
    pub fn sample_richness(&self, s: usize) -> usize {
        (0..self.n_features()).filter(|&f| self.present(f, s)).count()
    }

    /// Total observations in the table.
    pub fn total(&self) -> u64 {
        self.counts.iter().map(|&c| c as u64).sum()
    }

    /// Drop features absent from every sample; returns how many were
    /// removed.  (Real pipelines do this before UniFrac — empty features
    /// carry no signal but cost tree traversal.)
    pub fn drop_empty_features(&mut self) -> usize {
        let ns = self.n_samples();
        let keep: Vec<usize> = (0..self.n_features())
            .filter(|&f| (0..ns).any(|s| self.present(f, s)))
            .collect();
        let dropped = self.n_features() - keep.len();
        if dropped > 0 {
            let mut new_counts = Vec::with_capacity(keep.len() * ns);
            let mut new_ids = Vec::with_capacity(keep.len());
            for &f in &keep {
                new_counts.extend_from_slice(&self.counts[f * ns..(f + 1) * ns]);
                new_ids.push(self.feature_ids[f].clone());
            }
            self.counts = new_counts;
            self.feature_ids = new_ids;
        }
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(prefix: &str, n: usize) -> Vec<String> {
        (0..n).map(|i| format!("{prefix}{i}")).collect()
    }

    #[test]
    fn construction_and_access() {
        let mut t = OtuTable::zeros(ids("f", 3), ids("s", 2)).unwrap();
        t.set_count(0, 0, 5);
        t.set_count(2, 1, 1);
        assert_eq!(t.count(0, 0), 5);
        assert!(t.present(0, 0));
        assert!(!t.present(0, 1));
        assert_eq!(t.sample_richness(0), 1);
        assert_eq!(t.sample_richness(1), 1);
        assert_eq!(t.total(), 6);
    }

    #[test]
    fn rejects_bad_shapes_and_dup_ids() {
        assert!(OtuTable::new(ids("f", 2), ids("s", 2), vec![0; 3]).is_err());
        let mut dup = ids("f", 2);
        dup[1] = "f0".into();
        assert!(OtuTable::new(dup, ids("s", 1), vec![0; 2]).is_err());
    }

    #[test]
    fn drop_empty_features() {
        let mut t = OtuTable::new(
            ids("f", 3),
            ids("s", 2),
            vec![
                1, 0, // f0 present in s0
                0, 0, // f1 empty
                0, 2, // f2 present in s1
            ],
        )
        .unwrap();
        assert_eq!(t.drop_empty_features(), 1);
        assert_eq!(t.n_features(), 2);
        assert_eq!(t.feature_ids(), &["f0".to_string(), "f2".to_string()]);
        assert!(t.present(1, 1));
        assert_eq!(t.drop_empty_features(), 0, "idempotent");
    }
}
