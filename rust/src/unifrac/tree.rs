//! Phylogenetic trees: the structure UniFrac integrates over.
//!
//! Flat arena representation (parent/children index vectors) with a cached
//! postorder — the traversal order presence propagation needs.  Branch
//! lengths live on the child end of each edge, Newick-style.

use crate::error::{Error, Result};

/// Sentinel parent index of the root.
pub const NO_PARENT: usize = usize::MAX;

/// A rooted phylogenetic tree with branch lengths.
#[derive(Clone, Debug)]
pub struct PhyloTree {
    /// Parent index per node; `NO_PARENT` for the root.
    parent: Vec<usize>,
    /// Branch length from node to its parent (0.0 for the root).
    length: Vec<f32>,
    /// Node name; empty for unnamed internals.
    name: Vec<String>,
    /// Children indices per node.
    children: Vec<Vec<usize>>,
    root: usize,
    /// Cached postorder (children before parents).
    postorder: Vec<usize>,
}

impl PhyloTree {
    /// Build from parallel arrays.  `parent[root] == NO_PARENT` for exactly
    /// one node; children lists are derived; postorder is computed.
    pub fn new(parent: Vec<usize>, length: Vec<f32>, name: Vec<String>) -> Result<Self> {
        let n = parent.len();
        if n == 0 {
            return Err(Error::InvalidInput("empty tree".into()));
        }
        if length.len() != n || name.len() != n {
            return Err(Error::InvalidInput("tree array length mismatch".into()));
        }
        let mut root = None;
        let mut children = vec![Vec::new(); n];
        for (i, &p) in parent.iter().enumerate() {
            if p == NO_PARENT {
                if root.replace(i).is_some() {
                    return Err(Error::InvalidInput("multiple roots".into()));
                }
            } else {
                if p >= n {
                    return Err(Error::InvalidInput(format!("node {i}: parent {p} out of range")));
                }
                children[p].push(i);
            }
        }
        let root = root.ok_or_else(|| Error::InvalidInput("no root".into()))?;

        // Iterative postorder; also validates connectivity / acyclicity.
        let mut postorder = Vec::with_capacity(n);
        let mut stack = vec![(root, 0usize)];
        let mut visited = vec![false; n];
        while let Some((node, ci)) = stack.pop() {
            if ci < children[node].len() {
                stack.push((node, ci + 1));
                let ch = children[node][ci];
                if visited[ch] {
                    return Err(Error::InvalidInput("cycle in tree".into()));
                }
                visited[ch] = true;
                stack.push((ch, 0));
            } else {
                postorder.push(node);
            }
        }
        if postorder.len() != n {
            return Err(Error::InvalidInput(format!(
                "tree is disconnected: reached {} of {n} nodes",
                postorder.len()
            )));
        }
        Ok(PhyloTree { parent, length, name, children, root, postorder })
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.parent.len()
    }

    /// True if the tree has no nodes (never constructible — kept for API
    /// completeness).
    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Root index.
    pub fn root(&self) -> usize {
        self.root
    }

    /// Parent index (NO_PARENT for root).
    pub fn parent(&self, i: usize) -> usize {
        self.parent[i]
    }

    /// Branch length above node `i`.
    pub fn length(&self, i: usize) -> f32 {
        self.length[i]
    }

    /// Node name ("" if unnamed).
    pub fn name(&self, i: usize) -> &str {
        &self.name[i]
    }

    /// Children of node `i`.
    pub fn children(&self, i: usize) -> &[usize] {
        &self.children[i]
    }

    /// Nodes in postorder (children before parents).
    pub fn postorder(&self) -> &[usize] {
        &self.postorder
    }

    /// True if `i` is a leaf.
    pub fn is_leaf(&self, i: usize) -> bool {
        self.children[i].is_empty()
    }

    /// Indices of all leaves, in postorder.
    pub fn leaves(&self) -> Vec<usize> {
        self.postorder.iter().copied().filter(|&i| self.is_leaf(i)).collect()
    }

    /// Total branch length (sum over non-root edges).
    pub fn total_length(&self) -> f64 {
        (0..self.len())
            .filter(|&i| self.parent[i] != NO_PARENT)
            .map(|i| self.length[i] as f64)
            .sum()
    }

    /// Look up a leaf by name.
    pub fn leaf_by_name(&self, name: &str) -> Option<usize> {
        (0..self.len()).find(|&i| self.is_leaf(i) && self.name[i] == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ((A:1,B:2)I:0.5,C:3)R  — 5 nodes.
    pub(crate) fn small_tree() -> PhyloTree {
        //          R(4)
        //        /      \
        //      I(2):0.5  C(3):3
        //     /   \
        //  A(0):1  B(1):2
        PhyloTree::new(
            vec![2, 2, 4, 4, NO_PARENT],
            vec![1.0, 2.0, 0.5, 3.0, 0.0],
            vec!["A".into(), "B".into(), "I".into(), "C".into(), "R".into()],
        )
        .unwrap()
    }

    #[test]
    fn structure_queries() {
        let t = small_tree();
        assert_eq!(t.len(), 5);
        assert_eq!(t.root(), 4);
        assert!(t.is_leaf(0));
        assert!(!t.is_leaf(2));
        assert_eq!(t.children(4), &[2, 3]);
        assert_eq!(t.leaves(), vec![0, 1, 3]);
        assert_eq!(t.leaf_by_name("B"), Some(1));
        assert_eq!(t.leaf_by_name("I"), None, "internal nodes are not leaves");
        assert!((t.total_length() - 6.5).abs() < 1e-9);
    }

    #[test]
    fn postorder_children_first() {
        let t = small_tree();
        let pos: Vec<usize> = {
            let mut pos = vec![0; t.len()];
            for (ord, &n) in t.postorder().iter().enumerate() {
                pos[n] = ord;
            }
            pos
        };
        for i in 0..t.len() {
            if t.parent(i) != NO_PARENT {
                assert!(pos[i] < pos[t.parent(i)], "child {i} after parent");
            }
        }
    }

    #[test]
    fn rejects_malformed() {
        // no root
        assert!(PhyloTree::new(vec![1, 0], vec![0.0; 2], vec!["".into(); 2]).is_err());
        // two roots
        assert!(PhyloTree::new(
            vec![NO_PARENT, NO_PARENT],
            vec![0.0; 2],
            vec!["".into(); 2]
        )
        .is_err());
        // parent out of range
        assert!(PhyloTree::new(vec![NO_PARENT, 9], vec![0.0; 2], vec!["".into(); 2]).is_err());
        // length mismatch
        assert!(PhyloTree::new(vec![NO_PARENT], vec![], vec!["".into()]).is_err());
        // empty
        assert!(PhyloTree::new(vec![], vec![], vec![]).is_err());
    }
}
