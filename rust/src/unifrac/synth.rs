//! Synthetic microbiome data: EMP-shaped trees and communities.
//!
//! The paper's input (Earth Microbiome Project, Unweighted UniFrac, 25145
//! samples) is not redistributable; this generator produces workloads with
//! the same *statistical structure* at any size:
//!
//! * a random coalescent-style phylogeny (exponential branch lengths — the
//!   shape real 16S trees have);
//! * `k` environments, each preferring an overlapping pool of taxa (soil vs
//!   gut vs ocean communities share some clades, diverge in others);
//! * samples drawn per environment with per-taxon presence probabilities
//!   high inside the preferred pool and low outside.
//!
//! PERMANOVA over the resulting UniFrac matrix shows exactly the behaviour
//! the paper's users exploit: significant group effects for environment
//! labels, null for shuffled labels.  The generator is fully seeded.

use super::otu::OtuTable;
use super::tree::{PhyloTree, NO_PARENT};
use crate::error::Result;
use crate::permanova::Grouping;
use crate::rng::Xoshiro256pp;

/// Parameters of the synthetic community.
#[derive(Clone, Debug)]
pub struct SynthParams {
    /// Number of taxa (tree leaves).
    pub n_taxa: usize,
    /// Number of samples.
    pub n_samples: usize,
    /// Number of environments (PERMANOVA groups).
    pub n_envs: usize,
    /// Probability a pool taxon is present in a sample of its environment.
    pub p_in: f64,
    /// Probability a non-pool taxon is present ("contamination"/cosmopolitan).
    pub p_out: f64,
    /// Fraction of taxa in each environment's preferred pool.
    pub pool_frac: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for SynthParams {
    fn default() -> Self {
        SynthParams {
            n_taxa: 256,
            n_samples: 64,
            n_envs: 4,
            p_in: 0.7,
            p_out: 0.05,
            pool_frac: 0.35,
            seed: 42,
        }
    }
}

/// A generated dataset: tree + table + true environment labels.
#[derive(Clone, Debug)]
pub struct SynthDataset {
    pub tree: PhyloTree,
    pub table: OtuTable,
    pub grouping: Grouping,
    /// Environment name per sample (metadata-style).
    pub env_names: Vec<String>,
}

/// Random coalescent-style binary tree over `n_taxa` named leaves.
///
/// Repeatedly merges two random lineages under a new internal node with
/// exponential branch lengths — the standard neutral-model shape.
pub fn random_tree(n_taxa: usize, seed: u64) -> Result<PhyloTree> {
    assert!(n_taxa >= 2, "need at least two taxa");
    let mut rng = Xoshiro256pp::new(seed);
    let total = 2 * n_taxa - 1;
    let mut parent = vec![NO_PARENT; total];
    let mut length = vec![0.0f32; total];
    let mut name = vec![String::new(); total];
    for (i, nm) in name.iter_mut().enumerate().take(n_taxa) {
        *nm = format!("t{i}");
    }
    // Active lineage set starts as the leaves.
    let mut active: Vec<usize> = (0..n_taxa).collect();
    let mut next = n_taxa;
    while active.len() > 1 {
        // Pick two distinct random lineages to coalesce.
        let a_ix = rng.gen_range(active.len() as u32) as usize;
        let a = active.swap_remove(a_ix);
        let b_ix = rng.gen_range(active.len() as u32) as usize;
        let b = active.swap_remove(b_ix);
        parent[a] = next;
        parent[b] = next;
        // Exponential(1) lengths scaled down as the tree deepens (older
        // branches are longer — coalescent shape).
        let depth_scale = 1.0 + (active.len() as f64).ln().max(0.0);
        length[a] = (exp_sample(&mut rng) / depth_scale) as f32 + 1e-4;
        length[b] = (exp_sample(&mut rng) / depth_scale) as f32 + 1e-4;
        active.push(next);
        next += 1;
    }
    PhyloTree::new(parent, length, name)
}

fn exp_sample(rng: &mut Xoshiro256pp) -> f64 {
    -(1.0 - rng.next_f64()).ln()
}

/// Generate a full dataset (tree, presence table, labels).
pub fn generate(params: &SynthParams) -> Result<SynthDataset> {
    let p = params;
    let tree = random_tree(p.n_taxa, p.seed)?;
    let mut rng = Xoshiro256pp::new(p.seed ^ 0xC0FFEE);

    // Environment pools: contiguous leaf-id blocks with overlap, so pools
    // are phylogenetically clustered (as real environments are).
    let pool_size = ((p.n_taxa as f64) * p.pool_frac).max(1.0) as usize;
    let pools: Vec<Vec<usize>> = (0..p.n_envs)
        .map(|e| {
            let start = (e * p.n_taxa) / p.n_envs;
            (0..pool_size).map(|i| (start + i) % p.n_taxa).collect()
        })
        .collect();

    let feature_ids: Vec<String> = (0..p.n_taxa).map(|i| format!("t{i}")).collect();
    let sample_ids: Vec<String> = (0..p.n_samples).map(|i| format!("s{i}")).collect();
    let mut table = OtuTable::zeros(feature_ids, sample_ids)?;

    let mut labels = Vec::with_capacity(p.n_samples);
    let mut env_names = Vec::with_capacity(p.n_samples);
    for s in 0..p.n_samples {
        let env = s % p.n_envs;
        labels.push(env as u32);
        env_names.push(format!("env{env}"));
        let mut in_pool = vec![false; p.n_taxa];
        for &t in &pools[env] {
            in_pool[t] = true;
        }
        for t in 0..p.n_taxa {
            let prob = if in_pool[t] { p.p_in } else { p.p_out };
            if (rng.next_f64()) < prob {
                // Log-series-ish counts: mostly small, occasionally large.
                let c = 1 + (rng.next_f64().powi(3) * 50.0) as u32;
                table.set_count(t, s, c);
            }
        }
    }
    // Guarantee no empty samples (re-roll singletons into pool taxa).
    for s in 0..p.n_samples {
        if table.sample_richness(s) == 0 {
            let env = s % p.n_envs;
            table.set_count(pools[env][0], s, 1);
        }
    }
    let grouping = Grouping::new(labels)?;
    Ok(SynthDataset { tree, table, grouping, env_names })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::unifrac::compute::unweighted_unifrac;

    #[test]
    fn random_tree_shape() {
        let t = random_tree(50, 1).unwrap();
        assert_eq!(t.len(), 99);
        assert_eq!(t.leaves().len(), 50);
        assert!(t.total_length() > 0.0);
        // Every leaf is named t<i>, internals unnamed.
        for &l in &t.leaves() {
            assert!(t.name(l).starts_with('t'));
        }
    }

    #[test]
    fn random_tree_deterministic() {
        let a = random_tree(20, 7).unwrap();
        let b = random_tree(20, 7).unwrap();
        assert_eq!(a.len(), b.len());
        assert!((a.total_length() - b.total_length()).abs() < 1e-9);
        let c = random_tree(20, 8).unwrap();
        assert!((a.total_length() - c.total_length()).abs() > 1e-12);
    }

    #[test]
    fn generate_valid_dataset() {
        let d = generate(&SynthParams {
            n_taxa: 64,
            n_samples: 24,
            n_envs: 3,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(d.table.n_samples(), 24);
        assert_eq!(d.grouping.n(), 24);
        assert_eq!(d.grouping.k(), 3);
        for s in 0..24 {
            assert!(d.table.sample_richness(s) > 0, "sample {s} empty");
        }
    }

    #[test]
    fn environments_are_separable_under_unifrac() {
        // The whole point of the generator: within-env UniFrac distance
        // must be clearly below cross-env distance, on average.
        let d = generate(&SynthParams {
            n_taxa: 128,
            n_samples: 30,
            n_envs: 3,
            seed: 5,
            ..Default::default()
        })
        .unwrap();
        let m = unweighted_unifrac(&d.tree, &d.table, 2).unwrap();
        let labels = d.grouping.labels();
        let (mut win, mut wn, mut cross, mut cn) = (0.0f64, 0, 0.0f64, 0);
        for i in 0..30 {
            for j in (i + 1)..30 {
                if labels[i] == labels[j] {
                    win += m.get(i, j) as f64;
                    wn += 1;
                } else {
                    cross += m.get(i, j) as f64;
                    cn += 1;
                }
            }
        }
        let win = win / wn as f64;
        let cross = cross / cn as f64;
        assert!(
            cross > win * 1.15,
            "within {win:.4} vs cross {cross:.4} — no structure"
        );
    }
}
