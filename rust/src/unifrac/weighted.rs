//! Weighted (normalized) UniFrac — the abundance-aware sibling metric.
//!
//! The paper's input is *Unweighted* UniFrac, but unifrac-binaries (the
//! system the paper's kernel lives in) ships both, and downstream users
//! expect both.  Normalized Weighted UniFrac (Lozupone 2007):
//!
//! ```text
//! d(i,j) = Σ_b L_b · |u_bi − u_bj|  /  Σ_b L_b · (u_bi + u_bj)
//! ```
//!
//! where `u_bi` is the fraction of sample i's total counts that sit under
//! branch b (proportional abundances propagated leaf → root).  Unlike the
//! presence masks of the unweighted metric, the propagated quantity is a
//! dense f64 per (branch, sample), so the hot loop is a streaming
//! |a−b| / (a+b) accumulation over branches — still embarrassingly
//! parallel over sample pairs.

use super::otu::OtuTable;
use super::tree::{PhyloTree, NO_PARENT};
use crate::dmat::DistanceMatrix;
use crate::error::{Error, Result};

/// Weighted-normalized UniFrac distance matrix.
///
/// `threads` = 0 uses all available cores.  Errors on samples with zero
/// total counts (their proportions are undefined) and on observed features
/// missing from the tree.
pub fn weighted_unifrac(
    tree: &PhyloTree,
    table: &OtuTable,
    threads: usize,
) -> Result<DistanceMatrix> {
    let s = table.n_samples();
    if s < 2 {
        return Err(Error::InvalidInput("need at least 2 samples".into()));
    }
    // Feature -> leaf map (same contract as unweighted).
    let mut by_name = std::collections::HashMap::new();
    for &l in &tree.leaves() {
        by_name.insert(tree.name(l).to_string(), l);
    }
    let mut leaf_of_feature = Vec::with_capacity(table.n_features());
    for (f, id) in table.feature_ids().iter().enumerate() {
        match by_name.get(id) {
            Some(&l) => leaf_of_feature.push(Some(l)),
            None => {
                if (0..s).any(|x| table.present(f, x)) {
                    return Err(Error::InvalidInput(format!(
                        "feature {id:?} has observations but no leaf in the tree"
                    )));
                }
                leaf_of_feature.push(None);
            }
        }
    }

    // Sample totals for normalization.
    let mut totals = vec![0.0f64; s];
    for f in 0..table.n_features() {
        for (x, t) in totals.iter_mut().enumerate() {
            *t += table.count(f, x) as f64;
        }
    }
    if let Some(x) = totals.iter().position(|&t| t == 0.0) {
        return Err(Error::InvalidInput(format!(
            "sample {:?} has zero total count",
            table.sample_ids()[x]
        )));
    }

    // Propagate proportional abundance leaf -> root.
    // abund[node * s + sample], f64 (node count can be ~2 * taxa).
    let nn = tree.len();
    let mut abund = vec![0.0f64; nn * s];
    for (f, leaf) in leaf_of_feature.iter().enumerate() {
        if let Some(leaf) = *leaf {
            let row = &mut abund[leaf * s..(leaf + 1) * s];
            for (x, r) in row.iter_mut().enumerate() {
                let c = table.count(f, x);
                if c > 0 {
                    *r += c as f64 / totals[x];
                }
            }
        }
    }
    for &node in tree.postorder() {
        let p = tree.parent(node);
        if p == NO_PARENT {
            continue;
        }
        // Rows `node` and `p` are disjoint (a tree has no self-parents).
        let base = abund.as_mut_ptr();
        unsafe {
            let src = std::slice::from_raw_parts(base.add(node * s), s);
            let dst = std::slice::from_raw_parts_mut(base.add(p * s), s);
            for (d, v) in dst.iter_mut().zip(src) {
                *d += *v;
            }
        }
    }

    // Branch list with lengths.
    let branches: Vec<(usize, f64)> = (0..nn)
        .filter(|&i| tree.parent(i) != NO_PARENT && tree.length(i) != 0.0)
        .map(|i| (i, tree.length(i) as f64))
        .collect();

    let threads = crate::permanova::resolve_threads(threads).min(s.max(1));
    let mut mat = DistanceMatrix::zeros(s);
    let mat_ptr = SendPtr(mat.data_mut().as_mut_ptr());
    let cursor = std::sync::atomic::AtomicUsize::new(0);
    let abund = &abund;
    let branches = &branches;

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mat_ptr = &mat_ptr;
                loop {
                    let i = cursor.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= s {
                        break;
                    }
                    for j in (i + 1)..s {
                        let mut num = 0.0f64;
                        let mut den = 0.0f64;
                        for &(b, len) in branches {
                            let ua = abund[b * s + i];
                            let ub = abund[b * s + j];
                            num += len * (ua - ub).abs();
                            den += len * (ua + ub);
                        }
                        let d = if den > 0.0 { (num / den) as f32 } else { 0.0 };
                        // SAFETY: row i is owned by exactly one thread.
                        unsafe {
                            *mat_ptr.0.add(i * s + j) = d;
                            *mat_ptr.0.add(j * s + i) = d;
                        }
                    }
                }
            });
        }
    });

    Ok(mat)
}

struct SendPtr(*mut f32);
unsafe impl Sync for SendPtr {}
unsafe impl Send for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::unifrac::newick;

    fn fixture() -> (PhyloTree, OtuTable) {
        let tree = newick::parse("((A:1,B:1)I:1,(C:1,D:1)J:1)R;").unwrap();
        let features = vec!["A".to_string(), "B".into(), "C".into(), "D".into()];
        let samples: Vec<String> = (0..3).map(|i| format!("s{i}")).collect();
        // s0 = {A: 4}, s1 = {B: 4}, s2 = {A: 2, B: 2}
        #[rustfmt::skip]
        let counts = vec![
            4, 0, 2, // A
            0, 4, 2, // B
            0, 0, 0, // C
            0, 0, 0, // D
        ];
        (tree, OtuTable::new(features, samples, counts).unwrap())
    }

    #[test]
    fn hand_computed() {
        let (tree, table) = fixture();
        let m = weighted_unifrac(&tree, &table, 1).unwrap();
        // s0 vs s1: u(A)=1 vs 0, u(B)=0 vs 1, u(I)=1 vs 1.
        // num = 1·|1-0| + 1·|0-1| + 1·|1-1| = 2; den = 1+1+2 = 4 -> 0.5
        assert!((m.get(0, 1) - 0.5).abs() < 1e-6, "{}", m.get(0, 1));
        // s0 vs s2: A: |1-0.5|=0.5, B: |0-0.5|=0.5, I: |1-1|=0
        // num = 1.0; den = 1.5 + 0.5 + 2 = 4 -> 0.25
        assert!((m.get(0, 2) - 0.25).abs() < 1e-6, "{}", m.get(0, 2));
        m.validate(1e-6).unwrap();
    }

    #[test]
    fn identical_abundances_zero_distance() {
        let (tree, table) = fixture();
        let m = weighted_unifrac(&tree, &table, 1).unwrap();
        assert_eq!(m.get(0, 0), 0.0);
        // Scale invariance: proportions, not raw counts, matter.
        let features = vec!["A".to_string(), "B".into(), "C".into(), "D".into()];
        let samples = vec!["x".to_string(), "y".into()];
        let t2 = OtuTable::new(features, samples, vec![1, 100, 1, 100, 0, 0, 0, 0]).unwrap();
        let m2 = weighted_unifrac(&tree, &t2, 1).unwrap();
        assert!(m2.get(0, 1) < 1e-9, "same proportions -> 0, got {}", m2.get(0, 1));
    }

    #[test]
    fn disjoint_clades_distance_one() {
        let tree = newick::parse("((A:1,B:1)I:1,(C:1,D:1)J:1)R;").unwrap();
        let features = vec!["A".to_string(), "C".into()];
        let samples = vec!["x".to_string(), "y".into()];
        let table = OtuTable::new(features, samples, vec![3, 0, 0, 5]).unwrap();
        let m = weighted_unifrac(&tree, &table, 1).unwrap();
        assert!((m.get(0, 1) - 1.0).abs() < 1e-9, "{}", m.get(0, 1));
    }

    #[test]
    fn weighted_differs_from_unweighted_on_abundance_shift() {
        // Same presence everywhere, different abundances: unweighted says
        // 0, weighted says > 0.
        let tree = newick::parse("((A:1,B:1)I:1,C:2)R;").unwrap();
        let features = vec!["A".to_string(), "B".into(), "C".into()];
        let samples = vec!["x".to_string(), "y".into()];
        let table = OtuTable::new(features, samples, vec![9, 1, 1, 1, 1, 9]).unwrap();
        let uw = super::super::unweighted_unifrac(&tree, &table, 1).unwrap();
        let w = weighted_unifrac(&tree, &table, 1).unwrap();
        assert_eq!(uw.get(0, 1), 0.0, "same presence");
        assert!(w.get(0, 1) > 0.2, "abundance shift: {}", w.get(0, 1));
    }

    #[test]
    fn zero_count_sample_rejected() {
        let tree = newick::parse("(A:1,B:1);").unwrap();
        let table = OtuTable::new(
            vec!["A".to_string(), "B".into()],
            vec!["x".to_string(), "y".into()],
            vec![1, 0, 1, 0],
        )
        .unwrap();
        assert!(weighted_unifrac(&tree, &table, 1).is_err());
    }

    #[test]
    fn threads_deterministic_and_metric() {
        let ds = crate::unifrac::generate(&crate::unifrac::SynthParams {
            n_taxa: 96,
            n_samples: 40,
            n_envs: 3,
            seed: 9,
            ..Default::default()
        })
        .unwrap();
        let m1 = weighted_unifrac(&ds.tree, &ds.table, 1).unwrap();
        let m4 = weighted_unifrac(&ds.tree, &ds.table, 4).unwrap();
        assert_eq!(m1, m4);
        m1.validate(1e-6).unwrap();
        for v in m1.data() {
            assert!((0.0..=1.0 + 1e-6).contains(v));
        }
    }
}
