//! Newick format: parser and writer for phylogenetic trees.
//!
//! Supports the subset real microbiome pipelines emit: nested groups,
//! node labels (quoted or bare), branch lengths (`:1.5e-3`), and the
//! trailing semicolon.  Comments in square brackets are skipped.

use super::tree::{PhyloTree, NO_PARENT};
use crate::error::{Error, Result};

/// Parse a Newick document into a [`PhyloTree`].
pub fn parse(text: &str) -> Result<PhyloTree> {
    let mut p = NewickParser { b: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let mut parent = Vec::new();
    let mut length = Vec::new();
    let mut name = Vec::new();
    let root = p.node(&mut parent, &mut length, &mut name, NO_PARENT)?;
    debug_assert_eq!(root + 1, parent.len());
    p.skip_ws();
    if p.peek() == Some(b';') {
        p.pos += 1;
    }
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing content after tree"));
    }
    PhyloTree::new(parent, length, name)
}

/// Serialize a tree to Newick (children in stored order, lengths always
/// written, names written when non-empty).
pub fn write(tree: &PhyloTree) -> String {
    let mut out = String::new();
    write_node(tree, tree.root(), &mut out);
    out.push(';');
    out
}

fn write_node(tree: &PhyloTree, node: usize, out: &mut String) {
    let kids = tree.children(node);
    if !kids.is_empty() {
        out.push('(');
        for (i, &c) in kids.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            write_node(tree, c, out);
        }
        out.push(')');
    }
    let nm = tree.name(node);
    if !nm.is_empty() {
        if nm.chars().any(|c| " (),:;'[]".contains(c)) {
            out.push('\'');
            out.push_str(&nm.replace('\'', "''"));
            out.push('\'');
        } else {
            out.push_str(nm);
        }
    }
    if tree.parent(node) != NO_PARENT {
        out.push(':');
        out.push_str(&format!("{}", tree.length(node)));
    }
}

struct NewickParser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> NewickParser<'a> {
    fn err(&self, msg: impl Into<String>) -> Error {
        Error::parse("newick", format!("byte {}", self.pos), msg)
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        loop {
            while self
                .peek()
                .map(|c| c.is_ascii_whitespace())
                .unwrap_or(false)
            {
                self.pos += 1;
            }
            // Newick comments: [...] (non-nesting)
            if self.peek() == Some(b'[') {
                while let Some(c) = self.peek() {
                    self.pos += 1;
                    if c == b']' {
                        break;
                    }
                }
            } else {
                break;
            }
        }
    }

    /// Parse one node (subtree); append to the arrays; return its index.
    fn node(
        &mut self,
        parent: &mut Vec<usize>,
        length: &mut Vec<f32>,
        name: &mut Vec<String>,
        _parent_hint: usize,
    ) -> Result<usize> {
        self.skip_ws();
        let mut child_indices = Vec::new();
        if self.peek() == Some(b'(') {
            self.pos += 1;
            loop {
                let c = self.node(parent, length, name, NO_PARENT)?;
                child_indices.push(c);
                self.skip_ws();
                match self.peek() {
                    Some(b',') => {
                        self.pos += 1;
                    }
                    Some(b')') => {
                        self.pos += 1;
                        break;
                    }
                    _ => return Err(self.err("expected ',' or ')'")),
                }
            }
        }
        self.skip_ws();
        let nm = self.label()?;
        self.skip_ws();
        let len = if self.peek() == Some(b':') {
            self.pos += 1;
            self.number()?
        } else {
            0.0
        };
        let idx = parent.len();
        parent.push(NO_PARENT); // patched by caller if we're a child
        length.push(len);
        name.push(nm);
        for c in child_indices {
            parent[c] = idx;
        }
        Ok(idx)
    }

    fn label(&mut self) -> Result<String> {
        self.skip_ws();
        if self.peek() == Some(b'\'') {
            // Quoted label; '' is an escaped quote.
            self.pos += 1;
            let mut s = String::new();
            loop {
                match self.peek() {
                    None => return Err(self.err("unterminated quoted label")),
                    Some(b'\'') => {
                        self.pos += 1;
                        if self.peek() == Some(b'\'') {
                            s.push('\'');
                            self.pos += 1;
                        } else {
                            return Ok(s);
                        }
                    }
                    Some(c) => {
                        s.push(c as char);
                        self.pos += 1;
                    }
                }
            }
        }
        let start = self.pos;
        while let Some(c) = self.peek() {
            if b"(),:;[]".contains(&c) || c.is_ascii_whitespace() {
                break;
            }
            self.pos += 1;
        }
        Ok(std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err("invalid utf-8 in label"))?
            .to_string())
    }

    fn number(&mut self) -> Result<f32> {
        self.skip_ws();
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'-' | b'+' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse()
            .map_err(|e| self.err(format!("bad branch length {text:?}: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_simple() {
        let t = parse("((A:1,B:2)I:0.5,C:3)R;").unwrap();
        assert_eq!(t.len(), 5);
        let a = t.leaf_by_name("A").unwrap();
        assert_eq!(t.length(a), 1.0);
        let i = t.parent(a);
        assert_eq!(t.name(i), "I");
        assert_eq!(t.length(i), 0.5);
        assert_eq!(t.name(t.root()), "R");
        assert_eq!(t.leaves().len(), 3);
    }

    #[test]
    fn parse_unnamed_and_lengthless() {
        let t = parse("((A,B),(C,D));").unwrap();
        assert_eq!(t.leaves().len(), 4);
        assert_eq!(t.total_length(), 0.0);
    }

    #[test]
    fn parse_scientific_lengths_and_comments() {
        let t = parse("[emp tree](A:1.5e-3,B:2E2)root:0;").unwrap();
        let a = t.leaf_by_name("A").unwrap();
        assert!((t.length(a) - 0.0015).abs() < 1e-9);
        let b = t.leaf_by_name("B").unwrap();
        assert_eq!(t.length(b), 200.0);
    }

    #[test]
    fn parse_quoted_labels() {
        let t = parse("('taxon one':1,'o''brien':2);").unwrap();
        assert!(t.leaf_by_name("taxon one").is_some());
        assert!(t.leaf_by_name("o'brien").is_some());
    }

    #[test]
    fn roundtrip() {
        let src = "((A:1,B:2)I:0.5,(C:3,D:0.25)J:1.5)R;";
        let t = parse(src).unwrap();
        let out = write(&t);
        let t2 = parse(&out).unwrap();
        assert_eq!(t.len(), t2.len());
        assert_eq!(t.leaves().len(), t2.leaves().len());
        assert!((t.total_length() - t2.total_length()).abs() < 1e-9);
        // Same leaf name set
        let mut n1: Vec<&str> = t.leaves().iter().map(|&l| t.name(l)).collect();
        let mut n2: Vec<&str> = t2.leaves().iter().map(|&l| t2.name(l)).collect();
        n1.sort_unstable();
        n2.sort_unstable();
        assert_eq!(n1, n2);
    }

    #[test]
    fn roundtrip_quoted() {
        let t = parse("('a b':1,c:2);").unwrap();
        let t2 = parse(&write(&t)).unwrap();
        assert!(t2.leaf_by_name("a b").is_some());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("((A,B;").is_err());
        assert!(parse("(A:x);").is_err());
        assert!(parse("(A,B)); extra").is_err());
        assert!(parse("('unterminated);").is_err());
    }

    #[test]
    fn single_leaf() {
        let t = parse("A;").unwrap();
        assert_eq!(t.len(), 1);
        assert!(t.is_leaf(t.root()));
        assert_eq!(t.name(t.root()), "A");
    }
}
