//! Unweighted UniFrac: the distance metric behind the paper's input matrix.
//!
//! The paper's 25145² matrix is Unweighted UniFrac of the Earth Microbiome
//! Project, computed by the same author's unifrac-binaries.  UniFrac(i, j) =
//! (branch length unique to i or j) / (branch length covered by i or j):
//!
//! ```text
//! d(i,j) = Σ_b L_b·[p_bi ⊕ p_bj]  /  Σ_b L_b·[p_bi ∨ p_bj]
//! ```
//!
//! where `p_bi` is "any leaf under branch b is present in sample i",
//! computed by one postorder sweep (presence propagates leaf → root).
//!
//! The inner pairwise accumulation is *stripe-based*, as in the author's
//! optimized implementations: samples are packed into 64-bit masks, branches
//! are walked once per 64-sample stripe pair, and the XOR/OR popcount-style
//! update is branch-free.  Multi-threaded over row stripes.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::otu::OtuTable;
use super::tree::{PhyloTree, NO_PARENT};
use crate::dmat::DistanceMatrix;
use crate::error::{Error, Result};

/// Per-branch presence masks for one 64-sample stripe.
struct StripeMasks {
    /// `masks[node]` bit `s` = presence of (stripe_base + s) under `node`.
    masks: Vec<u64>,
}

/// Compute per-node presence masks for samples `[base, base+width)`.
fn presence_masks(
    tree: &PhyloTree,
    table: &OtuTable,
    leaf_of_feature: &[Option<usize>],
    base: usize,
    width: usize,
) -> StripeMasks {
    let mut masks = vec![0u64; tree.len()];
    // Seed leaves from the table.
    for (f, leaf) in leaf_of_feature.iter().enumerate() {
        if let Some(leaf) = *leaf {
            let mut m = 0u64;
            for s in 0..width {
                if table.present(f, base + s) {
                    m |= 1 << s;
                }
            }
            masks[leaf] |= m;
        }
    }
    // Propagate up in postorder.
    for &node in tree.postorder() {
        let p = tree.parent(node);
        if p != NO_PARENT {
            let m = masks[node];
            masks[p] |= m;
        }
    }
    StripeMasks { masks }
}

/// Map table features to tree leaves by id; errors if any feature with
/// observations has no matching leaf (silent drops hide real bugs).
fn map_features(tree: &PhyloTree, table: &OtuTable) -> Result<Vec<Option<usize>>> {
    let mut by_name = std::collections::HashMap::new();
    for &l in &tree.leaves() {
        by_name.insert(tree.name(l).to_string(), l);
    }
    let ns = table.n_samples();
    table
        .feature_ids()
        .iter()
        .enumerate()
        .map(|(f, id)| match by_name.get(id) {
            Some(&l) => Ok(Some(l)),
            None => {
                let observed = (0..ns).any(|s| table.present(f, s));
                if observed {
                    Err(Error::InvalidInput(format!(
                        "feature {id:?} has observations but no leaf in the tree"
                    )))
                } else {
                    Ok(None)
                }
            }
        })
        .collect()
}

/// Unweighted UniFrac distance matrix over the table's samples.
///
/// `threads` = 0 uses all available cores.
///
/// Uses the shared-length decomposition (perf pass — see EXPERIMENTS.md
/// §Perf): with `A_i = Σ_b L_b·p_bi` (branch length covering sample i,
/// one pass) and `C_ij = Σ_b L_b·p_bi·p_bj` (branch length covering both),
///
/// ```text
/// unique(i,j) = A_i + A_j − 2·C_ij        (covered by exactly one)
/// total(i,j)  = A_i + A_j −   C_ij        (covered by at least one)
/// d(i,j)      = unique / total
/// ```
///
/// so the per-branch stripe-pair update only touches the *set* bits of the
/// two presence masks (`popcount(mi)·popcount(mj)` adds instead of a dense
/// 64×64 double update) — ~6x faster on EMP-like (~30% presence) tables.
pub fn unweighted_unifrac(
    tree: &PhyloTree,
    table: &OtuTable,
    threads: usize,
) -> Result<DistanceMatrix> {
    let s = table.n_samples();
    if s < 2 {
        return Err(Error::InvalidInput("need at least 2 samples".into()));
    }
    let leaf_of_feature = map_features(tree, table)?;

    // Per-stripe presence masks (stripe = 64 samples).
    let n_stripes = s.div_ceil(64);
    let stripes: Vec<StripeMasks> = (0..n_stripes)
        .map(|si| {
            let base = si * 64;
            let width = (s - base).min(64);
            presence_masks(tree, table, &leaf_of_feature, base, width)
        })
        .collect();

    // Branches with nonzero length (root excluded).
    let branches: Vec<(usize, f32)> = (0..tree.len())
        .filter(|&i| tree.parent(i) != NO_PARENT && tree.length(i) != 0.0)
        .map(|i| (i, tree.length(i)))
        .collect();

    // A_i: branch length covering each sample (one pass over branches).
    let mut covered = vec![0.0f64; s];
    for &(b, len) in &branches {
        let len = len as f64;
        for (si, stripe) in stripes.iter().enumerate() {
            let mut m = stripe.masks[b];
            while m != 0 {
                let bit = m.trailing_zeros() as usize;
                covered[si * 64 + bit] += len;
                m &= m - 1;
            }
        }
    }

    let threads = crate::permanova::resolve_threads(threads).min(n_stripes.max(1));
    let mut mat = DistanceMatrix::zeros(s);
    let mat_ptr = MatPtr(mat.data_mut().as_mut_ptr());
    let cursor = AtomicUsize::new(0);
    let covered = &covered;

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mat_ptr = &mat_ptr;
                // C_ij accumulator for one 64x64 stripe pair.
                let mut shared = vec![0.0f64; 64 * 64];
                loop {
                    let si = cursor.fetch_add(1, Ordering::Relaxed);
                    if si >= n_stripes {
                        break;
                    }
                    let base_i = si * 64;
                    let w_i = (s - base_i).min(64);
                    for sj in si..n_stripes {
                        let base_j = sj * 64;
                        let w_j = (s - base_j).min(64);
                        shared[..64 * 64].fill(0.0);
                        // Branches covering every sample of both stripes
                        // (root-adjacent: the dense worst case) shift C by
                        // a constant — fold them into one scalar.
                        let full_i = if w_i == 64 { u64::MAX } else { (1u64 << w_i) - 1 };
                        let full_j = if w_j == 64 { u64::MAX } else { (1u64 << w_j) - 1 };
                        let mut dense_all = 0.0f64;
                        for &(b, len) in &branches {
                            let mi = stripes[si].masks[b];
                            let mj = stripes[sj].masks[b];
                            if mi == 0 || mj == 0 {
                                continue; // no pair covered by this branch
                            }
                            let len = len as f64;
                            if mi == full_i && mj == full_j {
                                dense_all += len;
                                continue;
                            }
                            // Only set bits contribute to C.
                            let mut ma = mi;
                            while ma != 0 {
                                let a = ma.trailing_zeros() as usize;
                                ma &= ma - 1;
                                let row = &mut shared[a * 64..a * 64 + 64];
                                let mut mc = mj;
                                while mc != 0 {
                                    let c = mc.trailing_zeros() as usize;
                                    mc &= mc - 1;
                                    row[c] += len;
                                }
                            }
                        }
                        // d = (A_i + A_j - 2C) / (A_i + A_j - C); upper
                        // triangle only, mirrored below.
                        for a in 0..w_i {
                            let gi = base_i + a;
                            let ai = covered[gi];
                            for c in 0..w_j {
                                let gj = base_j + c;
                                if gj <= gi {
                                    continue;
                                }
                                let cij = shared[a * 64 + c] + dense_all;
                                let tot = ai + covered[gj] - cij;
                                let d = if tot > 0.0 {
                                    ((tot - cij) / tot) as f32
                                } else {
                                    0.0
                                };
                                // SAFETY: (gi, gj) pairs are unique across
                                // stripe-pair iterations; each thread owns
                                // disjoint si rows.
                                unsafe {
                                    *mat_ptr.0.add(gi * s + gj) = d;
                                    *mat_ptr.0.add(gj * s + gi) = d;
                                }
                            }
                        }
                    }
                }
            });
        }
    });

    Ok(mat)
}

struct MatPtr(*mut f32);
unsafe impl Sync for MatPtr {}
unsafe impl Send for MatPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::unifrac::newick;

    /// Tree: ((A:1,B:1)I:1,(C:1,D:1)J:1)R;  (all unit branches)
    fn fixture() -> (PhyloTree, OtuTable) {
        let tree = newick::parse("((A:1,B:1)I:1,(C:1,D:1)J:1)R;").unwrap();
        // samples: s0={A}, s1={B}, s2={A,B}, s3={C}, s4={A,B,C,D}
        let features = vec!["A".to_string(), "B".into(), "C".into(), "D".into()];
        let samples: Vec<String> = (0..5).map(|i| format!("s{i}")).collect();
        #[rustfmt::skip]
        let counts = vec![
            // s0 s1 s2 s3 s4
            1, 0, 1, 0, 1, // A
            0, 1, 1, 0, 1, // B
            0, 0, 0, 1, 1, // C
            0, 0, 0, 0, 1, // D
        ];
        (tree, OtuTable::new(features, samples, counts).unwrap())
    }

    #[test]
    fn hand_computed_distances() {
        let (tree, table) = fixture();
        let m = unweighted_unifrac(&tree, &table, 1).unwrap();
        // s0={A}: covers A(1), I(1). s1={B}: covers B(1), I(1).
        // unique = A+B = 2; total = A+B+I = 3 → 2/3
        assert!((m.get(0, 1) - 2.0 / 3.0).abs() < 1e-6, "{}", m.get(0, 1));
        // s0={A} vs s2={A,B}: unique = B(1); total = A+B+I = 3 → 1/3
        assert!((m.get(0, 2) - 1.0 / 3.0).abs() < 1e-6);
        // s0={A} vs s3={C}: unique = A+I+C+J = 4; total same = 4 → 1
        assert!((m.get(0, 3) - 1.0).abs() < 1e-6);
        // s2={A,B} vs s4=all: unique = C+D+J = 3; total = 6 → 1/2
        assert!((m.get(2, 4) - 0.5).abs() < 1e-6);
        m.validate(1e-6).unwrap();
    }

    #[test]
    fn identical_samples_distance_zero() {
        let tree = newick::parse("((A:1,B:1):0.5,C:2);").unwrap();
        let features = vec!["A".to_string(), "B".into(), "C".into()];
        let samples = vec!["x".to_string(), "y".into(), "z".into()];
        let counts = vec![
            3, 3, 0, // A in x,y
            1, 1, 0, // B in x,y
            0, 0, 2, // C in z
        ];
        let table = OtuTable::new(features, samples, counts).unwrap();
        let m = unweighted_unifrac(&tree, &table, 1).unwrap();
        assert_eq!(m.get(0, 1), 0.0, "identical presence -> 0");
        assert!((m.get(0, 2) - 1.0).abs() < 1e-6, "disjoint clades -> 1");
    }

    #[test]
    fn unifrac_is_presence_only() {
        // Counts 1 vs 1000 must not change unweighted UniFrac.
        let tree = newick::parse("((A:1,B:1):1,C:1);").unwrap();
        let f = vec!["A".to_string(), "B".into(), "C".into()];
        let s = vec!["u".to_string(), "v".into()];
        let t1 = OtuTable::new(f.clone(), s.clone(), vec![1, 0, 1, 1, 0, 1]).unwrap();
        let t2 = OtuTable::new(f, s, vec![900, 0, 7, 1000, 0, 3]).unwrap();
        let m1 = unweighted_unifrac(&tree, &t1, 1).unwrap();
        let m2 = unweighted_unifrac(&tree, &t2, 1).unwrap();
        assert_eq!(m1.get(0, 1), m2.get(0, 1));
    }

    #[test]
    fn threads_do_not_change_result() {
        let (tree, table) = fixture();
        let m1 = unweighted_unifrac(&tree, &table, 1).unwrap();
        let m4 = unweighted_unifrac(&tree, &table, 4).unwrap();
        assert_eq!(m1, m4);
    }

    #[test]
    fn many_samples_cross_stripe() {
        // >64 samples forces multi-stripe pairs; compare one value against
        // the single-stripe hand formula by duplicating sample contents.
        let tree = newick::parse("((A:1,B:1)I:1,(C:1,D:1)J:1)R;").unwrap();
        let features = vec!["A".to_string(), "B".into(), "C".into(), "D".into()];
        let ns = 70;
        let samples: Vec<String> = (0..ns).map(|i| format!("s{i}")).collect();
        let mut counts = vec![0u32; 4 * ns];
        for s in 0..ns {
            // Even samples = {A}; odd = {C}
            if s % 2 == 0 {
                counts[s] = 1; // A row
            } else {
                counts[2 * ns + s] = 1; // C row
            }
        }
        let table = OtuTable::new(features, samples, counts).unwrap();
        let m = unweighted_unifrac(&tree, &table, 2).unwrap();
        // {A} vs {A} = 0; {A} vs {C} = 1 (disjoint clades incl. internals)
        assert_eq!(m.get(0, 2), 0.0);
        assert_eq!(m.get(0, 68), 0.0, "cross-stripe same content");
        assert!((m.get(0, 1) - 1.0).abs() < 1e-6);
        assert!((m.get(1, 69) - 0.0).abs() < 1e-6, "cross-stripe {{C}} vs {{C}}");
        assert!((m.get(0, 69) - 1.0).abs() < 1e-6, "cross-stripe disjoint");
        m.validate(1e-6).unwrap();
    }

    #[test]
    fn observed_feature_missing_from_tree_errors() {
        let tree = newick::parse("(A:1,B:1);").unwrap();
        let table = OtuTable::new(
            vec!["A".to_string(), "X".into()],
            vec!["s0".to_string(), "s1".into()],
            vec![1, 0, 0, 1],
        )
        .unwrap();
        assert!(unweighted_unifrac(&tree, &table, 1).is_err());
    }

    #[test]
    fn unobserved_missing_feature_tolerated() {
        let tree = newick::parse("(A:1,B:1);").unwrap();
        let table = OtuTable::new(
            vec!["A".to_string(), "B".into(), "ghost".into()],
            vec!["s0".to_string(), "s1".into()],
            vec![1, 0, 0, 1, 0, 0],
        )
        .unwrap();
        unweighted_unifrac(&tree, &table, 1).unwrap();
    }
}
