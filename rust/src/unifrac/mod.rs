//! UniFrac substrate: trees, OTU tables, the metric, synthetic data.
//!
//! The paper's input matrix is Unweighted UniFrac over the Earth Microbiome
//! Project.  This module is the from-scratch substrate that produces
//! equivalent inputs: a Newick parser ([`newick`]), phylogenetic trees
//! ([`PhyloTree`]), feature tables ([`OtuTable`]), the stripe-based
//! Unweighted UniFrac computation ([`unweighted_unifrac`]) and a seeded
//! EMP-shaped synthetic community generator ([`synth`]).

pub mod newick;
mod otu;
pub mod synth;
mod tree;

mod compute;
mod weighted;

pub use compute::unweighted_unifrac;
pub use otu::OtuTable;
pub use synth::{generate, random_tree, SynthDataset, SynthParams};
pub use tree::{PhyloTree, NO_PARENT};
pub use weighted::weighted_unifrac;
