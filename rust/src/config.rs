//! Run configuration: a TOML-subset parser and the typed run config.
//!
//! The offline crate set has no serde/toml, so we parse the subset real
//! configs use: `[section]` headers, `key = value` with string / integer /
//! float / boolean / flat-array values, `#` comments.  The typed layer
//! ([`RunConfig`]) provides defaults and validation; the CLI applies
//! overrides on top.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::permanova::{Method, SwAlgorithm};

/// A parsed TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: `(section, key) -> value`; top-level keys use the
/// empty section name.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    entries: BTreeMap<(String, String), TomlValue>,
}

impl TomlDoc {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let ctx = || format!("line {}", ln + 1);
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| Error::parse("toml", ctx(), "unterminated section header"))?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(Error::parse("toml", ctx(), "empty section name"));
                }
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| Error::parse("toml", ctx(), "expected key = value"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(Error::parse("toml", ctx(), "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|m| Error::parse("toml", ctx(), m))?;
            doc.entries.insert((section.clone(), key.to_string()), value);
        }
        Ok(doc)
    }

    /// Load and parse a file.
    pub fn load(path: impl AsRef<Path>) -> Result<TomlDoc> {
        let p = path.as_ref();
        let text =
            std::fs::read_to_string(p).map_err(|e| Error::io(p.display().to_string(), e))?;
        Self::parse(&text)
    }

    /// Look up a value.
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    /// Typed getters with defaults.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(TomlValue::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(TomlValue::as_int).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(TomlValue::as_bool).unwrap_or(default)
    }

    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(TomlValue::as_float).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> std::result::Result<TomlValue, String> {
    let t = text.trim();
    if t.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = t.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if t == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if t == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = t.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("unrecognized value {t:?}"))
}

/// Split a flat array body on commas (no nested arrays in our subset, but
/// strings may contain commas).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

/// Where the distance matrix comes from.
#[derive(Clone, Debug, PartialEq)]
pub enum DataSource {
    /// Synthetic Euclidean matrix of the given size.
    Synthetic { n_dims: usize, n_groups: usize },
    /// UniFrac over a generated community (the E2E pipeline).
    SyntheticUnifrac { n_taxa: usize, n_samples: usize, n_groups: usize },
    /// Binary `.pdm` file (labels via `labels_path` TSV, one label/line).
    Pdm { path: String, labels_path: String },
    /// scikit-bio-style TSV.
    Tsv { path: String, labels_path: String },
}

/// Fully-resolved run configuration.
///
/// `backend` is a **name**, resolved against the name-keyed registry in
/// [`crate::backend`] (`native`, `native-brute`, `native-tiled`,
/// `native-flat`, `native-batch`, `simulator`, `simulator-gpu`, `xla`,
/// ...) — an open set, so new backends plug in without touching the
/// config layer.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub data: DataSource,
    pub n_perms: usize,
    pub seed: u64,
    /// Seed for *generating* synthetic data sources (`[data] seed` /
    /// `--data-seed`); `None` couples it to [`seed`](Self::seed) (the
    /// pre-service behaviour).  Decoupling lets a job batch draw distinct
    /// permutation streams over the **same** dataset — the shape the
    /// `DatasetCache` amortizes.
    pub data_seed: Option<u64>,
    /// Which permutation test to run (`[run] method` / `--method`):
    /// `permanova` (default), `anosim`, `permdisp`, `pairwise`.  Every
    /// method routes through the same backend engine.
    pub method: Method,
    pub algo: SwAlgorithm,
    /// Worker threads / slots for the shard scheduler (0 = all available).
    pub threads: usize,
    /// Registry name of the execution backend.
    pub backend: String,
    pub artifacts_dir: String,
    /// XLA kernel variant to prefer (bruteforce | tiled | matmul | ref).
    pub xla_kernel: String,
    /// Simulated-backend SMT toggle (the Figure 1 CPU ablation axis).
    pub smt: bool,
    /// Permutations per scheduler shard (0 = automatic).
    pub shard_size: usize,
    /// Shard-scheduler SMT-style oversubscription: 2 OS threads per worker
    /// slot.  Mirrors the paper's "same cores, 1 vs 2 threads per core"
    /// ablation when `threads` is pinned to a physical-core count.
    pub smt_oversubscribe: bool,
    /// Permutations per matrix sweep for the batched brute engine
    /// (`native-batch`); 0 = the paper-informed default block width.
    pub perm_block: usize,
    /// Absolute symmetry/diagonal tolerance for validating **file-sourced**
    /// distance matrices on load (`[data] tol` / `--data-tol` / JSON
    /// `data.tol`).  Float32 UniFrac pipelines commonly carry ~1e-6
    /// asymmetry from reduction order; anything beyond this tolerance is
    /// rejected with a config error instead of being silently analyzed.
    /// Synthetic sources are valid by construction and skip the check.
    pub data_tol: f32,
    /// Hard cap on the bytes of distance-matrix triangle kept resident
    /// (`[run] max_resident_bytes` / `--max-resident-bytes`; 0 =
    /// unbounded, the default).  A dataset whose packed triangle
    /// (`n(n-1)/2 × 4` bytes) exceeds the cap is spilled to a scratch
    /// file at ingest and analyzed chunk-major: each kernel sweeps one
    /// budget-sized row-chunk at a time, so `n` can exceed RAM.  Results
    /// are bitwise identical to the uncapped run on every backend.
    pub max_resident_bytes: u64,
}

/// Default [`RunConfig::data_tol`]: loose enough for f32 pipeline noise,
/// tight enough to catch genuinely asymmetric or corrupted input.
pub const DEFAULT_DATA_TOL: f32 = 1e-4;

/// The `[store]` config section: where (and whether) the durable result
/// store lives.  CLI flags win over the file: `--store-dir` /
/// `--store-capacity-bytes` override `dir` / `capacity_bytes`, and
/// `--no-store` forces `enabled = false`.  The store is always opt-in —
/// no `dir` means no store, and every code path then behaves exactly as
/// it did before the store existed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StoreSettings {
    /// Store root directory (`[store] dir`); `None` disables the store.
    pub dir: Option<String>,
    /// On-disk byte budget (`[store] capacity_bytes`; 0 = unbounded).
    pub capacity_bytes: u64,
    /// Master switch (`[store] enabled`, default true).
    pub enabled: bool,
}

impl Default for StoreSettings {
    fn default() -> Self {
        StoreSettings {
            dir: None,
            capacity_bytes: crate::store::DEFAULT_STORE_CAPACITY_BYTES,
            enabled: true,
        }
    }
}

impl StoreSettings {
    /// Read the `[store]` section (absent keys get defaults).
    pub fn from_toml(doc: &TomlDoc) -> Result<StoreSettings> {
        let d = StoreSettings::default();
        let dir = doc.str_or("store", "dir", "");
        let capacity = doc.int_or("store", "capacity_bytes", d.capacity_bytes as i64);
        if capacity < 0 {
            return Err(Error::Config(format!(
                "store.capacity_bytes must be >= 0, got {capacity}"
            )));
        }
        Ok(StoreSettings {
            dir: if dir.is_empty() { None } else { Some(dir) },
            capacity_bytes: capacity as u64,
            enabled: doc.bool_or("store", "enabled", true),
        })
    }
}

/// The `[fault]` config section: an optional deterministic
/// fault-injection plan for chaos drills (see
/// [`FaultPlan`](crate::inject::FaultPlan) for the spec grammar).
/// `--fault-plan SPEC` overrides the file.  Absent — the default, and
/// the only sane production state — no plan is armed and every
/// injection seam costs one relaxed atomic load.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct FaultSettings {
    /// Comma-separated fault directives (`[fault] plan`); `None` = off.
    pub plan: Option<String>,
}

impl FaultSettings {
    /// Read the `[fault]` section (absent section or key = disabled).
    /// The spec itself is validated where it is armed, so a config file
    /// with a bad plan fails loudly at startup, not at first consult.
    pub fn from_toml(doc: &TomlDoc) -> Result<FaultSettings> {
        let plan = doc.str_or("fault", "plan", "");
        Ok(FaultSettings { plan: if plan.is_empty() { None } else { Some(plan) } })
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            data: DataSource::Synthetic { n_dims: 256, n_groups: 8 },
            n_perms: 999,
            seed: 0x5EED_CAFE,
            data_seed: None,
            method: Method::Permanova,
            algo: SwAlgorithm::Tiled { tile: crate::permanova::DEFAULT_TILE },
            threads: 0,
            backend: "native".to_string(),
            artifacts_dir: crate::DEFAULT_ARTIFACTS_DIR.to_string(),
            xla_kernel: "matmul".to_string(),
            smt: true,
            shard_size: 0,
            smt_oversubscribe: false,
            perm_block: 0,
            data_tol: DEFAULT_DATA_TOL,
            max_resident_bytes: 0,
        }
    }
}

impl RunConfig {
    /// Build from a parsed TOML document (missing keys get defaults).
    pub fn from_toml(doc: &TomlDoc) -> Result<RunConfig> {
        let d = RunConfig::default();
        let source = doc.str_or("data", "source", "synthetic");
        let data = match source.as_str() {
            "synthetic" => DataSource::Synthetic {
                n_dims: doc.int_or("data", "n_dims", 256) as usize,
                n_groups: doc.int_or("data", "n_groups", 8) as usize,
            },
            "unifrac" => DataSource::SyntheticUnifrac {
                n_taxa: doc.int_or("data", "n_taxa", 256) as usize,
                n_samples: doc.int_or("data", "n_samples", 64) as usize,
                n_groups: doc.int_or("data", "n_groups", 4) as usize,
            },
            "pdm" => DataSource::Pdm {
                path: doc.str_or("data", "path", ""),
                labels_path: doc.str_or("data", "labels", ""),
            },
            "tsv" => DataSource::Tsv {
                path: doc.str_or("data", "path", ""),
                labels_path: doc.str_or("data", "labels", ""),
            },
            other => {
                return Err(Error::Config(format!("unknown data.source {other:?}")))
            }
        };
        let algo_s = doc.str_or("run", "algo", &d.algo.name());
        let algo = SwAlgorithm::parse(&algo_s)
            .ok_or_else(|| Error::Config(format!("unknown run.algo {algo_s:?}")))?;
        let method_s = doc.str_or("run", "method", d.method.name());
        let method = Method::parse(&method_s)
            .ok_or_else(|| Error::Config(format!("unknown run.method {method_s:?}")))?;
        let max_resident = doc.int_or("run", "max_resident_bytes", d.max_resident_bytes as i64);
        if max_resident < 0 {
            return Err(Error::Config(format!(
                "run.max_resident_bytes must be >= 0 (0 = unbounded), got {max_resident}"
            )));
        }
        let cfg = RunConfig {
            data,
            n_perms: doc.int_or("run", "n_perms", d.n_perms as i64) as usize,
            seed: doc.int_or("run", "seed", d.seed as i64) as u64,
            data_seed: doc.get("data", "seed").and_then(TomlValue::as_int).map(|i| i as u64),
            method,
            algo,
            threads: doc.int_or("run", "threads", 0) as usize,
            backend: doc.str_or("run", "backend", &d.backend),
            artifacts_dir: doc.str_or("xla", "artifacts_dir", &d.artifacts_dir),
            xla_kernel: doc.str_or("xla", "kernel", &d.xla_kernel),
            smt: doc.bool_or("simulate", "smt", true),
            shard_size: doc.int_or("run", "shard_size", 0) as usize,
            smt_oversubscribe: doc.bool_or("run", "smt_oversubscribe", false),
            perm_block: doc.int_or("run", "perm_block", 0) as usize,
            data_tol: doc.float_or("data", "tol", d.data_tol as f64) as f32,
            max_resident_bytes: max_resident as u64,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Build from a JSON object — the `serve` subcommand's JSONL request
    /// format (one request per line).  Missing keys take the same defaults
    /// as [`from_toml`](Self::from_toml); present-but-mistyped keys are
    /// errors.  `seed` may be a number (< 2^53) or a decimal string (full
    /// u64 range).
    ///
    /// ```json
    /// {"method": "anosim", "backend": "native-batch", "n_perms": 499,
    ///  "seed": 7, "data": {"source": "synthetic", "n_dims": 128, "n_groups": 4}}
    /// ```
    pub fn from_json(doc: &crate::jsonio::Json) -> Result<RunConfig> {
        Self::from_json_at(doc, "")
    }

    /// [`from_json`](Self::from_json) with a field-path prefix: every
    /// error names the exact offending field as seen from the document
    /// root (`request.data.n_dims`, not a bare `n_dims`), which is what
    /// the v1 request envelope parser
    /// ([`crate::service::parse_envelope`]) reports for the job payload
    /// nested under its `"request"` key.  The empty prefix is the legacy
    /// v0 top-level job shape.
    pub fn from_json_at(doc: &crate::jsonio::Json, prefix: &str) -> Result<RunConfig> {
        use crate::jsonio::Json;
        // Unknown keys are rejected, not ignored: a misspelled or
        // misplaced field (e.g. top-level "data_seed" instead of
        // data.seed) must fail loudly rather than silently take a
        // default and compute something else.
        const TOP_KEYS: [&str; 15] = [
            "id", "method", "backend", "algo", "n_perms", "seed", "threads", "shard_size",
            "smt", "smt_oversubscribe", "perm_block", "artifacts_dir", "xla_kernel", "data",
            "max_resident_bytes",
        ];
        const DATA_KEYS: [&str; 9] = [
            "source", "n_dims", "n_groups", "n_taxa", "n_samples", "path", "labels", "seed",
            "tol",
        ];
        let Json::Obj(map) = doc else {
            return Err(Error::Config(if prefix.is_empty() {
                "job request must be a JSON object".into()
            } else {
                format!("field {prefix:?} must be a JSON object")
            }));
        };
        for key in map.keys() {
            if !TOP_KEYS.contains(&key.as_str()) {
                return Err(Error::Config(format!(
                    "unknown field {:?} (known: {})",
                    field_path(prefix, key),
                    TOP_KEYS.join(", ")
                )));
            }
        }
        let top = FieldsAt { doc, path: prefix.to_string() };
        let data_path = field_path(prefix, "data");
        let d = RunConfig::default();
        let (data, data_seed, data_tol) = match doc.get("data") {
            None => (d.data.clone(), None, d.data_tol),
            Some(o @ Json::Obj(dm)) => {
                for key in dm.keys() {
                    if !DATA_KEYS.contains(&key.as_str()) {
                        return Err(Error::Config(format!(
                            "unknown field {:?} (known: {})",
                            field_path(&data_path, key),
                            DATA_KEYS.join(", ")
                        )));
                    }
                }
                let f = FieldsAt { doc: o, path: data_path.clone() };
                let source = f.opt_str("source")?.unwrap_or("synthetic").to_string();
                let data = match source.as_str() {
                    "synthetic" => DataSource::Synthetic {
                        n_dims: f.opt_usize("n_dims")?.unwrap_or(256),
                        n_groups: f.opt_usize("n_groups")?.unwrap_or(8),
                    },
                    "unifrac" => DataSource::SyntheticUnifrac {
                        n_taxa: f.opt_usize("n_taxa")?.unwrap_or(256),
                        n_samples: f.opt_usize("n_samples")?.unwrap_or(64),
                        n_groups: f.opt_usize("n_groups")?.unwrap_or(4),
                    },
                    "pdm" => DataSource::Pdm {
                        path: f.opt_str("path")?.unwrap_or("").to_string(),
                        labels_path: f.opt_str("labels")?.unwrap_or("").to_string(),
                    },
                    "tsv" => DataSource::Tsv {
                        path: f.opt_str("path")?.unwrap_or("").to_string(),
                        labels_path: f.opt_str("labels")?.unwrap_or("").to_string(),
                    },
                    other => {
                        return Err(Error::Config(format!(
                            "unknown {} {other:?}",
                            field_path(&data_path, "source")
                        )))
                    }
                };
                let data_seed = f.opt_u64("seed")?;
                let data_tol = match o.get("tol") {
                    None => d.data_tol,
                    Some(v) => v.as_f64().ok_or_else(|| f.bad("tol", "a number"))? as f32,
                };
                (data, data_seed, data_tol)
            }
            Some(_) => {
                return Err(Error::Config(format!(
                    "field {data_path:?} must be a JSON object"
                )))
            }
        };
        let method = match top.opt_str("method")? {
            None => d.method,
            Some(s) => Method::parse(s).ok_or_else(|| {
                Error::Config(format!("field {:?}: unknown method {s:?}", top.name("method")))
            })?,
        };
        let algo = match top.opt_str("algo")? {
            None => d.algo,
            Some(s) => SwAlgorithm::parse(s).ok_or_else(|| {
                Error::Config(format!("field {:?}: unknown algo {s:?}", top.name("algo")))
            })?,
        };
        let cfg = RunConfig {
            data,
            n_perms: top.opt_usize("n_perms")?.unwrap_or(d.n_perms),
            seed: top.opt_u64("seed")?.unwrap_or(d.seed),
            data_seed,
            method,
            algo,
            threads: top.opt_usize("threads")?.unwrap_or(d.threads),
            backend: top.opt_str("backend")?.unwrap_or(&d.backend).to_string(),
            artifacts_dir: top.opt_str("artifacts_dir")?.unwrap_or(&d.artifacts_dir).to_string(),
            xla_kernel: top.opt_str("xla_kernel")?.unwrap_or(&d.xla_kernel).to_string(),
            smt: top.opt_bool("smt")?.unwrap_or(d.smt),
            shard_size: top.opt_usize("shard_size")?.unwrap_or(d.shard_size),
            smt_oversubscribe: top
                .opt_bool("smt_oversubscribe")?
                .unwrap_or(d.smt_oversubscribe),
            perm_block: top.opt_usize("perm_block")?.unwrap_or(d.perm_block),
            data_tol,
            max_resident_bytes: top
                .opt_u64("max_resident_bytes")?
                .unwrap_or(d.max_resident_bytes),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The seed synthetic data sources are generated from: `data_seed`
    /// when set, else the run seed (the pre-service coupling).
    pub fn effective_data_seed(&self) -> u64 {
        self.data_seed.unwrap_or(self.seed)
    }

    /// The shard-scheduler spec this config resolves to.
    pub fn shard_spec(&self) -> crate::backend::ShardSpec {
        crate::backend::ShardSpec {
            shard_size: self.shard_size,
            workers: self.threads,
            smt: self.smt_oversubscribe,
        }
    }

    /// Sanity-check cross-field constraints.
    pub fn validate(&self) -> Result<()> {
        if self.n_perms == 0 {
            return Err(Error::Config("n_perms must be >= 1".into()));
        }
        if !self.data_tol.is_finite() || self.data_tol < 0.0 {
            return Err(Error::Config(format!(
                "data.tol must be a finite non-negative number, got {}",
                self.data_tol
            )));
        }
        let registry = crate::backend::Registry::with_defaults();
        if !registry.contains(&self.backend) {
            return Err(Error::UnknownBackend {
                name: self.backend.clone(),
                known: registry.names(),
            });
        }
        match &self.data {
            DataSource::Synthetic { n_dims, n_groups } => {
                if *n_groups < 2 || n_dims <= n_groups {
                    return Err(Error::Config(format!(
                        "need 2 <= n_groups < n_dims (got k={n_groups}, n={n_dims})"
                    )));
                }
            }
            DataSource::SyntheticUnifrac { n_samples, n_groups, .. } => {
                if *n_groups < 2 || n_samples <= n_groups {
                    return Err(Error::Config("need 2 <= n_groups < n_samples".into()));
                }
            }
            DataSource::Pdm { path, labels_path } | DataSource::Tsv { path, labels_path } => {
                if path.is_empty() || labels_path.is_empty() {
                    return Err(Error::Config("file sources need data.path and data.labels".into()));
                }
            }
        }
        Ok(())
    }
}

/// Join a field-path prefix with a field name: `("request", "data")` →
/// `"request.data"`.  The empty prefix names the field alone — the legacy
/// v0 top-level job shape.
fn field_path(prefix: &str, field: &str) -> String {
    if prefix.is_empty() {
        field.to_string()
    } else {
        format!("{prefix}.{field}")
    }
}

/// Typed optional-field accessors that name the **full field path** in
/// errors: `Ok(None)` when the key is absent, `Err` naming
/// `prefix.field` when it is present with the wrong type — so a mistyped
/// field nested inside a request envelope fails loudly with its exact
/// location instead of a bare key name.
struct FieldsAt<'a> {
    doc: &'a crate::jsonio::Json,
    path: String,
}

impl<'a> FieldsAt<'a> {
    fn name(&self, field: &str) -> String {
        field_path(&self.path, field)
    }

    fn bad(&self, field: &str, want: &str) -> Error {
        Error::Config(format!("field {:?} must be {want}", self.name(field)))
    }

    fn opt_str(&self, field: &str) -> Result<Option<&'a str>> {
        match self.doc.get(field) {
            None => Ok(None),
            Some(v) => v.as_str().map(Some).ok_or_else(|| self.bad(field, "a string")),
        }
    }

    fn opt_usize(&self, field: &str) -> Result<Option<usize>> {
        match self.doc.get(field) {
            None => Ok(None),
            Some(v) => v
                .as_usize()
                .map(Some)
                .ok_or_else(|| self.bad(field, "a non-negative integer")),
        }
    }

    /// u64 as a JSON number (< 2^53) or a decimal string (full range).
    fn opt_u64(&self, field: &str) -> Result<Option<u64>> {
        match self.doc.get(field) {
            None => Ok(None),
            Some(crate::jsonio::Json::Str(s)) => s
                .parse::<u64>()
                .map(Some)
                .map_err(|_| self.bad(field, "a u64 (number or decimal string)")),
            Some(v) => v
                .as_u64()
                .map(Some)
                .ok_or_else(|| self.bad(field, "a u64 (number or decimal string)")),
        }
    }

    fn opt_bool(&self, field: &str) -> Result<Option<bool>> {
        match self.doc.get(field) {
            None => Ok(None),
            Some(v) => v.as_bool().map(Some).ok_or_else(|| self.bad(field, "a boolean")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_document() {
        let doc = TomlDoc::parse(
            r#"
            # top comment
            title = "example"   # trailing comment
            [run]
            n_perms = 3999
            seed = 42
            algo = "tiled512"
            smt = true
            ratio = 0.5
            tags = ["a", "b,c", 3]
            [data]
            source = "synthetic"
            n_dims = 25145
            "#,
        )
        .unwrap();
        assert_eq!(doc.str_or("", "title", ""), "example");
        assert_eq!(doc.int_or("run", "n_perms", 0), 3999);
        assert!(doc.bool_or("run", "smt", false));
        assert_eq!(doc.float_or("run", "ratio", 0.0), 0.5);
        let arr = doc.get("run", "tags").unwrap();
        match arr {
            TomlValue::Array(items) => {
                assert_eq!(items[1], TomlValue::Str("b,c".into()));
                assert_eq!(items[2], TomlValue::Int(3));
            }
            _ => panic!("not an array"),
        }
        assert_eq!(doc.int_or("data", "n_dims", 0), 25145);
    }

    #[test]
    fn parse_errors_carry_line() {
        for (bad, frag) in [
            ("[unterminated", "line 1"),
            ("keyonly", "line 1"),
            ("x = ", "line 1"),
            ("a = \"open", "line 1"),
            ("[]", "line 1"),
        ] {
            let e = TomlDoc::parse(bad).unwrap_err().to_string();
            assert!(e.contains(frag), "{bad:?} -> {e}");
        }
    }

    #[test]
    fn run_config_from_toml_and_defaults() {
        let doc = TomlDoc::parse(
            r#"
            [run]
            n_perms = 199
            algo = "brute"
            backend = "native"
            [data]
            source = "unifrac"
            n_taxa = 128
            n_samples = 32
            n_groups = 4
            "#,
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.n_perms, 199);
        assert_eq!(cfg.algo, SwAlgorithm::Brute);
        assert_eq!(
            cfg.data,
            DataSource::SyntheticUnifrac { n_taxa: 128, n_samples: 32, n_groups: 4 }
        );
        // Defaults fill the rest.
        assert_eq!(cfg.backend, "native");
        assert_eq!(cfg.artifacts_dir, "artifacts");
        assert_eq!(cfg.shard_size, 0);
        assert!(!cfg.smt_oversubscribe);
        assert_eq!(cfg.perm_block, 0);
    }

    #[test]
    fn store_settings_from_toml() {
        let d = StoreSettings::from_toml(&TomlDoc::parse("").unwrap()).unwrap();
        assert_eq!(d, StoreSettings::default());
        assert!(d.dir.is_none(), "no dir = store disabled");
        let s = StoreSettings::from_toml(
            &TomlDoc::parse(
                "[store]\ndir = \"/var/lib/permanova/store\"\ncapacity_bytes = 1048576\nenabled = true\n",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(s.dir.as_deref(), Some("/var/lib/permanova/store"));
        assert_eq!(s.capacity_bytes, 1_048_576);
        assert!(s.enabled);
        let off = StoreSettings::from_toml(
            &TomlDoc::parse("[store]\ndir = \"x\"\nenabled = false\n").unwrap(),
        )
        .unwrap();
        assert!(!off.enabled);
        assert!(StoreSettings::from_toml(
            &TomlDoc::parse("[store]\ncapacity_bytes = -1\n").unwrap()
        )
        .is_err());
        // A [store] section in a run config file must not break RunConfig
        // parsing (sections are independent).
        let both = TomlDoc::parse("[run]\nn_perms = 99\n[store]\ndir = \"s\"\n").unwrap();
        assert_eq!(RunConfig::from_toml(&both).unwrap().n_perms, 99);
        assert_eq!(StoreSettings::from_toml(&both).unwrap().dir.as_deref(), Some("s"));
    }

    #[test]
    fn method_parses_and_defaults_to_permanova() {
        let cfg = RunConfig::from_toml(&TomlDoc::parse("").unwrap()).unwrap();
        assert_eq!(cfg.method, Method::Permanova);
        for (text, want) in [
            ("[run]\nmethod = \"anosim\"\n", Method::Anosim),
            ("[run]\nmethod = \"permdisp\"\n", Method::Permdisp),
            ("[run]\nmethod = \"pairwise\"\n", Method::PairwisePermanova),
            ("[run]\nmethod = \"pairwise-permanova\"\n", Method::PairwisePermanova),
        ] {
            let cfg = RunConfig::from_toml(&TomlDoc::parse(text).unwrap()).unwrap();
            assert_eq!(cfg.method, want, "{text}");
        }
        let bad = TomlDoc::parse("[run]\nmethod = \"kruskal\"\n").unwrap();
        let e = RunConfig::from_toml(&bad).unwrap_err().to_string();
        assert!(e.contains("kruskal"), "{e}");
    }

    #[test]
    fn perm_block_parses_and_selects_batch_backend() {
        let doc = TomlDoc::parse(
            "[run]\nbackend = \"native-batch\"\nperm_block = 16\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.backend, "native-batch");
        assert_eq!(cfg.perm_block, 16);
    }

    #[test]
    fn run_config_from_json_requests() {
        use crate::jsonio::Json;
        let doc = Json::parse(
            r#"{"method": "anosim", "backend": "native-batch", "n_perms": 49,
                "seed": 7, "perm_block": 16,
                "data": {"source": "synthetic", "n_dims": 48, "n_groups": 4}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.method, Method::Anosim);
        assert_eq!(cfg.backend, "native-batch");
        assert_eq!(cfg.n_perms, 49);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.perm_block, 16);
        assert_eq!(cfg.data, DataSource::Synthetic { n_dims: 48, n_groups: 4 });

        // Defaults fill everything absent; an empty object is a valid job.
        let cfg = RunConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.n_perms, RunConfig::default().n_perms);
        assert_eq!(cfg.backend, "native");

        // String seeds carry the full u64 range.
        let doc = Json::parse(r#"{"seed": "18446744073709551615"}"#).unwrap();
        assert_eq!(RunConfig::from_json(&doc).unwrap().seed, u64::MAX);

        // Unknown names, mistyped fields and invalid shapes are errors.
        for bad in [
            r#"{"method": "kruskal"}"#,
            r#"{"backend": "cuda"}"#,
            r#"{"algo": "quantum"}"#,
            r#"{"n_perms": 0}"#,
            r#"{"n_perms": "many"}"#,
            r#"{"data": {"source": "hdf5"}}"#,
            r#"{"data": {"source": "pdm"}}"#,
            r#"{"data": []}"#,
            r#"[1, 2]"#,
            // Unknown keys fail loudly instead of silently defaulting —
            // data_seed's correct spelling is nested data.seed.
            r#"{"data_seed": 7}"#,
            r#"{"n_perm": 99}"#,
            r#"{"data": {"n_dim": 48}}"#,
        ] {
            let doc = Json::parse(bad).unwrap();
            assert!(RunConfig::from_json(&doc).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn from_json_at_names_full_field_paths() {
        use crate::jsonio::Json;
        let at = |text: &str| {
            RunConfig::from_json_at(&Json::parse(text).unwrap(), "request")
                .unwrap_err()
                .to_string()
        };
        assert!(at(r#"{"n_perm": 9}"#).contains("\"request.n_perm\""));
        assert!(at(r#"{"n_perms": "many"}"#).contains("\"request.n_perms\""));
        assert!(at(r#"{"data": {"n_dim": 48}}"#).contains("\"request.data.n_dim\""));
        assert!(at(r#"{"data": {"tol": "loose"}}"#).contains("\"request.data.tol\""));
        assert!(at(r#"{"data": []}"#).contains("\"request.data\""));
        assert!(at(r#"{"data": {"source": "hdf5"}}"#).contains("request.data.source"));
        assert!(at(r#"{"method": 7}"#).contains("\"request.method\""));
        // The legacy prefixless spelling names bare dotted fields.
        let e = RunConfig::from_json(&Json::parse(r#"{"data": {"n_dim": 48}}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(e.contains("\"data.n_dim\""), "{e}");
        // Non-object payloads under a prefix name the prefix itself.
        let e = RunConfig::from_json_at(&Json::parse("[1]").unwrap(), "request")
            .unwrap_err()
            .to_string();
        assert!(e.contains("\"request\""), "{e}");
    }

    #[test]
    fn run_config_rejects_bad_values() {
        for bad in [
            "[run]\nalgo = \"nope\"",
            "[run]\nbackend = \"cuda\"",
            "[data]\nsource = \"hdf5\"",
            "[run]\nn_perms = 0",
            "[data]\nsource = \"pdm\"",
            "[data]\nsource = \"synthetic\"\nn_dims = 4\nn_groups = 8",
            "[data]\ntol = -0.5",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(RunConfig::from_toml(&doc).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn data_tol_knob_parses_and_defaults() {
        let cfg = RunConfig::from_toml(&TomlDoc::parse("").unwrap()).unwrap();
        assert_eq!(cfg.data_tol, DEFAULT_DATA_TOL);
        let doc = TomlDoc::parse("[data]\ntol = 0.01\n").unwrap();
        assert!((RunConfig::from_toml(&doc).unwrap().data_tol - 0.01).abs() < 1e-9);
        // JSON jobs: nested data.tol, numbers only, negatives rejected.
        use crate::jsonio::Json;
        let doc = Json::parse(r#"{"data": {"source": "synthetic", "tol": 0.02}}"#).unwrap();
        assert!((RunConfig::from_json(&doc).unwrap().data_tol - 0.02).abs() < 1e-7);
        for bad in [r#"{"data": {"tol": "loose"}}"#, r#"{"data": {"tol": -1}}"#] {
            let doc = Json::parse(bad).unwrap();
            assert!(RunConfig::from_json(&doc).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn max_resident_bytes_knob_parses_and_defaults() {
        use crate::jsonio::Json;
        // Default: unbounded (0) — the pre-out-of-core behaviour.
        let cfg = RunConfig::from_toml(&TomlDoc::parse("").unwrap()).unwrap();
        assert_eq!(cfg.max_resident_bytes, 0);
        let doc = TomlDoc::parse("[run]\nmax_resident_bytes = 4096\n").unwrap();
        assert_eq!(RunConfig::from_toml(&doc).unwrap().max_resident_bytes, 4096);
        let bad = TomlDoc::parse("[run]\nmax_resident_bytes = -1\n").unwrap();
        let e = RunConfig::from_toml(&bad).unwrap_err().to_string();
        assert!(e.contains("max_resident_bytes"), "{e}");
        // JSON jobs: top-level key, number or decimal string.
        let doc = Json::parse(r#"{"max_resident_bytes": 8192}"#).unwrap();
        assert_eq!(RunConfig::from_json(&doc).unwrap().max_resident_bytes, 8192);
        let doc = Json::parse(r#"{"max_resident_bytes": "18446744073709551615"}"#).unwrap();
        assert_eq!(RunConfig::from_json(&doc).unwrap().max_resident_bytes, u64::MAX);
        let doc = Json::parse(r#"{"max_resident_bytes": "lots"}"#).unwrap();
        assert!(RunConfig::from_json(&doc).is_err());
    }

    #[test]
    fn backend_names_resolve_through_registry() {
        for name in ["native", "native-tiled", "native-batch", "simulator", "simulated", "xla"] {
            let cfg = RunConfig { backend: name.to_string(), ..Default::default() };
            cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        let bad = RunConfig { backend: "tpu".to_string(), ..Default::default() };
        let e = bad.validate().unwrap_err().to_string();
        assert!(e.contains("tpu") && e.contains("native-tiled"), "{e}");
    }

    #[test]
    fn shard_knobs_flow_into_spec() {
        let doc = TomlDoc::parse(
            "[run]\nthreads = 6\nshard_size = 128\nsmt_oversubscribe = true\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        let spec = cfg.shard_spec();
        assert_eq!(spec.workers, 6);
        assert_eq!(spec.shard_size, 128);
        assert!(spec.smt);
        assert_eq!(spec.threads(), 12, "SMT oversubscription doubles the slots");
    }

    #[test]
    fn default_config_validates() {
        RunConfig::default().validate().unwrap();
    }
}
