//! Crate-wide error type.
//!
//! One hand-rolled enum covering every layer: data validation, IO, parsing
//! (JSON/TOML/Newick), the XLA runtime, backend selection and coordinator
//! scheduling.  The `Display`/`Error` impls are written out by hand (no
//! `thiserror`) so the crate builds with zero dependencies in hermetic
//! environments.  Library code returns [`Result`]; only `main` formats for
//! humans.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes of the library.
#[derive(Debug)]
pub enum Error {
    /// Input data failed validation (asymmetric matrix, empty group, ...).
    InvalidInput(String),

    /// A configuration file or CLI flag is malformed.
    Config(String),

    /// Underlying IO failure, annotated with the path involved.
    Io { path: String, source: std::io::Error },

    /// A structured text format failed to parse (JSON, TOML subset, Newick,
    /// distance-matrix TSV...).  `what` names the format.
    Parse { what: &'static str, context: String, message: String },

    /// artifacts/manifest.json doesn't describe what the runtime needs.
    Artifact(String),

    /// The XLA/PJRT layer failed (compile, transfer, execute, or the
    /// runtime was compiled out entirely).
    Xla(String),

    /// No backend with the requested name is registered.
    UnknownBackend { name: String, known: Vec<String> },

    /// Coordinator-level failure (a worker died, a channel closed early...).
    Coordinator(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidInput(m) => write!(f, "invalid input: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io { path, source } => write!(f, "io error on {path}: {source}"),
            Error::Parse { what, context, message } => {
                write!(f, "{what} parse error at {context}: {message}")
            }
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla runtime error: {m}"),
            Error::UnknownBackend { name, known } => {
                if let Some(s) = nearest_name(name, known) {
                    write!(
                        f,
                        "unknown backend {name:?} (did you mean {s:?}? known: {})",
                        known.join(", ")
                    )
                } else {
                    write!(f, "unknown backend {name:?} (known: {})", known.join(", "))
                }
            }
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
        }
    }
}

/// Levenshtein edit distance (small inputs only: backend names).
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// The closest known name to a typo, if it is plausibly a typo at all:
/// within 3 edits and less than half the input's length.  `native-batched`
/// suggests `native-batch`; an unrelated name like `cuda` suggests nothing.
fn nearest_name<'a>(name: &str, known: &'a [String]) -> Option<&'a str> {
    let (best, dist) = known
        .iter()
        .map(|k| (k.as_str(), levenshtein(name, k)))
        .min_by_key(|&(_, d)| d)?;
    (dist <= 3 && 2 * dist < name.chars().count()).then_some(best)
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    /// Convenience for IO errors carrying their path.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }

    /// Convenience for parse errors.
    pub fn parse(
        what: &'static str,
        context: impl Into<String>,
        message: impl Into<String>,
    ) -> Self {
        Error::Parse { what, context: context.into(), message: message.into() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::parse("json", "line 3", "unexpected token");
        let s = e.to_string();
        assert!(s.contains("json"));
        assert!(s.contains("line 3"));
        assert!(s.contains("unexpected token"));
    }

    #[test]
    fn io_error_carries_path() {
        let e = Error::io("/nope/file", std::io::Error::from(std::io::ErrorKind::NotFound));
        assert!(e.to_string().contains("/nope/file"));
    }

    #[test]
    fn unknown_backend_lists_known() {
        let e = Error::UnknownBackend {
            name: "cuda".into(),
            known: vec!["native".into(), "simulator".into()],
        };
        let s = e.to_string();
        assert!(s.contains("cuda"));
        assert!(s.contains("native"));
        assert!(s.contains("simulator"));
        // Nothing resembles "cuda": no speculative suggestion.
        assert!(!s.contains("did you mean"), "{s}");
    }

    #[test]
    fn unknown_backend_suggests_the_nearest_name() {
        let known: Vec<String> =
            ["native", "native-batch", "native-brute", "simulator", "xla"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let e = Error::UnknownBackend { name: "native-batched".into(), known: known.clone() };
        let s = e.to_string();
        assert!(s.contains("did you mean \"native-batch\"?"), "{s}");
        let e = Error::UnknownBackend { name: "simulater".into(), known };
        assert!(e.to_string().contains("did you mean \"simulator\"?"), "{}", e);
    }

    #[test]
    fn levenshtein_reference_values() {
        assert_eq!(levenshtein("", ""), 0);
        assert_eq!(levenshtein("abc", "abc"), 0);
        assert_eq!(levenshtein("abc", ""), 3);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
        assert_eq!(levenshtein("native-batched", "native-batch"), 2);
    }

    #[test]
    fn source_chain() {
        use std::error::Error as _;
        let e = Error::io("/x", std::io::Error::from(std::io::ErrorKind::NotFound));
        assert!(e.source().is_some());
        assert!(Error::Config("x".into()).source().is_none());
    }
}
