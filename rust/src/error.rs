//! Crate-wide error type.
//!
//! One `thiserror` enum covering every layer: data validation, IO, parsing
//! (JSON/TOML/Newick), the XLA runtime, and coordinator scheduling.  Library
//! code returns [`Result`]; only `main` formats for humans.

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes of the library.
#[derive(Error, Debug)]
pub enum Error {
    /// Input data failed validation (asymmetric matrix, empty group, ...).
    #[error("invalid input: {0}")]
    InvalidInput(String),

    /// A configuration file or CLI flag is malformed.
    #[error("config error: {0}")]
    Config(String),

    /// Underlying IO failure, annotated with the path involved.
    #[error("io error on {path}: {source}")]
    Io {
        path: String,
        #[source]
        source: std::io::Error,
    },

    /// A structured text format failed to parse (JSON, TOML subset, Newick,
    /// distance-matrix TSV...).  `what` names the format.
    #[error("{what} parse error at {context}: {message}")]
    Parse {
        what: &'static str,
        context: String,
        message: String,
    },

    /// artifacts/manifest.json doesn't describe what the runtime needs.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// The XLA/PJRT layer failed (compile, transfer, execute).
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// Coordinator-level failure (a worker died, a channel closed early...).
    #[error("coordinator error: {0}")]
    Coordinator(String),
}

impl Error {
    /// Convenience for IO errors carrying their path.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }

    /// Convenience for parse errors.
    pub fn parse(
        what: &'static str,
        context: impl Into<String>,
        message: impl Into<String>,
    ) -> Self {
        Error::Parse { what, context: context.into(), message: message.into() }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::parse("json", "line 3", "unexpected token");
        let s = e.to_string();
        assert!(s.contains("json"));
        assert!(s.contains("line 3"));
        assert!(s.contains("unexpected token"));
    }

    #[test]
    fn io_error_carries_path() {
        let e = Error::io("/nope/file", std::io::Error::from(std::io::ErrorKind::NotFound));
        assert!(e.to_string().contains("/nope/file"));
    }
}
