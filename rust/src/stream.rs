//! STREAM: the memory-bandwidth benchmark from the paper's Appendix A2.
//!
//! The paper calibrates its CPU-vs-GPU comparison with STREAM (McCalpin) and
//! a GPU-offload variant (STREAM-OMPGPU): ~0.2 TB/s from the 24 CPU cores
//! vs ~3.0 TB/s from the GPU CUs of the *same* HBM stack.  This module
//! reimplements the four kernels (Copy/Scale/Add/Triad) with the reference
//! methodology — N repetitions, best-time rates, validation pass — both to
//! measure the *host* we actually run on (calibrating the simulator's CPU
//! side) and to regenerate the A2 tables.
//!
//! Multi-threaded with static partitioning, matching `omp parallel for
//! schedule(static)` in the original.  The worker pool is the crate-wide
//! [`with_static_pool`] (persistent workers + barrier sync, so the timed
//! region excludes thread spawn — as OpenMP's does).

use std::time::Instant;

use crate::backend::shard::with_static_pool;
use crate::permanova::resolve_threads;

/// The four STREAM kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamKernel {
    /// c = a
    Copy,
    /// b = s*c
    Scale,
    /// c = a + b
    Add,
    /// a = b + s*c
    Triad,
}

impl StreamKernel {
    /// All four, in STREAM's canonical order.
    pub const ALL: [StreamKernel; 4] =
        [StreamKernel::Copy, StreamKernel::Scale, StreamKernel::Add, StreamKernel::Triad];

    /// Kernel name as STREAM prints it.
    pub fn name(&self) -> &'static str {
        match self {
            StreamKernel::Copy => "Copy",
            StreamKernel::Scale => "Scale",
            StreamKernel::Add => "Add",
            StreamKernel::Triad => "Triad",
        }
    }

    /// Bytes moved per element (STREAM counting: loads + stores of f64).
    pub fn bytes_per_elem(&self) -> usize {
        match self {
            StreamKernel::Copy | StreamKernel::Scale => 16,
            StreamKernel::Add | StreamKernel::Triad => 24,
        }
    }
}

/// Result of one kernel's timing sweep.
#[derive(Clone, Debug)]
pub struct StreamResult {
    pub kernel: StreamKernel,
    /// Best rate over the timed repetitions, MB/s (10^6, STREAM convention).
    pub best_rate_mbs: f64,
    pub avg_time: f64,
    pub min_time: f64,
    pub max_time: f64,
}

/// Full run output: the four kernels plus validation status.
#[derive(Clone, Debug)]
pub struct StreamReport {
    pub array_len: usize,
    pub threads: usize,
    pub reps: usize,
    pub results: Vec<StreamResult>,
    pub validated: bool,
    /// Max relative validation error across the three arrays.
    pub max_rel_err: f64,
}

impl StreamReport {
    /// Rate for one kernel (panics if absent — it never is).
    pub fn rate(&self, k: StreamKernel) -> f64 {
        self.results.iter().find(|r| r.kernel == k).unwrap().best_rate_mbs
    }

    /// Render the classic STREAM table.
    pub fn format_table(&self) -> String {
        let mut out = String::new();
        out.push_str("Function    Best Rate MB/s  Avg time     Min time     Max time\n");
        for r in &self.results {
            out.push_str(&format!(
                "{:<12}{:>14.1}  {:>9.6}    {:>9.6}    {:>9.6}\n",
                format!("{}:", r.kernel.name()),
                r.best_rate_mbs,
                r.avg_time,
                r.min_time,
                r.max_time
            ));
        }
        out
    }
}

/// Run STREAM: `len` f64 elements per array, `reps` timed repetitions
/// (first excluded, as in the reference), `threads` workers (0 = all).
pub fn run_stream(len: usize, reps: usize, threads: usize) -> StreamReport {
    assert!(reps >= 2, "need >= 2 reps (first is discarded)");
    let threads = resolve_threads(threads);
    let scalar = 3.0f64;

    let mut a = vec![1.0f64; len];
    let mut b = vec![2.0f64; len];
    let mut c = vec![0.0f64; len];

    let mut times = vec![vec![0.0f64; reps]; 4];

    let (pa, pb, pc) = (SendPtr(a.as_mut_ptr()), SendPtr(b.as_mut_ptr()), SendPtr(c.as_mut_ptr()));
    // One STREAM kernel sweep over a worker's static partition [lo, hi).
    let kernel = |w: usize, lo: usize, hi: usize| {
        // SAFETY: disjoint [lo, hi) slices per worker; the main thread does
        // not touch the arrays while a job is in flight.
        unsafe {
            let a = std::slice::from_raw_parts_mut(pa.0.add(lo), hi - lo);
            let b = std::slice::from_raw_parts_mut(pb.0.add(lo), hi - lo);
            let c = std::slice::from_raw_parts_mut(pc.0.add(lo), hi - lo);
            match w {
                0 => {
                    for i in 0..a.len() {
                        c[i] = a[i];
                    }
                }
                1 => {
                    for i in 0..a.len() {
                        b[i] = scalar * c[i];
                    }
                }
                2 => {
                    for i in 0..a.len() {
                        c[i] = a[i] + b[i];
                    }
                }
                _ => {
                    for i in 0..a.len() {
                        a[i] = b[i] + scalar * c[i];
                    }
                }
            }
        }
    };

    with_static_pool(threads, len, &kernel, |pool| {
        for rep in 0..reps {
            for (ki, _k) in StreamKernel::ALL.iter().enumerate() {
                let t0 = Instant::now();
                pool.run(ki);
                times[ki][rep] = t0.elapsed().as_secs_f64();
            }
        }
    });

    // Validation, as in stream.c: replay the recurrence on scalars.
    let (mut va, mut vb, mut vc) = (1.0f64, 2.0f64, 0.0f64);
    for _ in 0..reps {
        vc = va;
        vb = scalar * vc;
        vc = va + vb;
        va = vb + scalar * vc;
    }
    let err = |got: &[f64], want: f64| -> f64 {
        got.iter().map(|&x| ((x - want) / want).abs()).fold(0.0, f64::max)
    };
    let max_rel_err = err(&a, va).max(err(&b, vb)).max(err(&c, vc));
    let validated = max_rel_err < 1e-13 * len as f64;

    let results = StreamKernel::ALL
        .iter()
        .enumerate()
        .map(|(ki, &kernel)| {
            let timed = &times[ki][1..]; // first iteration excluded
            let min_time = timed.iter().cloned().fold(f64::INFINITY, f64::min);
            let max_time = timed.iter().cloned().fold(0.0, f64::max);
            let avg_time = timed.iter().sum::<f64>() / timed.len() as f64;
            let bytes = kernel.bytes_per_elem() * len;
            StreamResult {
                kernel,
                best_rate_mbs: bytes as f64 / min_time / 1e6,
                avg_time,
                min_time,
                max_time,
            }
        })
        .collect();

    StreamReport { array_len: len, threads, reps, results, validated, max_rel_err }
}

struct SendPtr(*mut f64);
unsafe impl Sync for SendPtr {}
unsafe impl Send for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_metadata() {
        assert_eq!(StreamKernel::Copy.bytes_per_elem(), 16);
        assert_eq!(StreamKernel::Triad.bytes_per_elem(), 24);
        assert_eq!(StreamKernel::ALL.len(), 4);
        assert_eq!(StreamKernel::Add.name(), "Add");
    }

    #[test]
    fn small_run_validates() {
        let r = run_stream(100_000, 3, 2);
        assert!(r.validated, "rel err {}", r.max_rel_err);
        assert_eq!(r.results.len(), 4);
        for res in &r.results {
            assert!(res.best_rate_mbs > 0.0);
            assert!(res.min_time <= res.avg_time && res.avg_time <= res.max_time + 1e-12);
        }
    }

    #[test]
    fn single_thread_validates() {
        let r = run_stream(50_000, 2, 1);
        assert!(r.validated);
    }

    #[test]
    fn odd_len_and_threads() {
        // len not divisible by threads exercises the partition edges.
        let r = run_stream(100_001, 2, 3);
        assert!(r.validated, "rel err {}", r.max_rel_err);
    }

    #[test]
    fn table_formatting() {
        let r = run_stream(10_000, 2, 1);
        let t = r.format_table();
        for name in ["Copy:", "Scale:", "Add:", "Triad:"] {
            assert!(t.contains(name), "{t}");
        }
    }

    #[test]
    fn rate_lookup() {
        let r = run_stream(10_000, 2, 1);
        assert_eq!(r.rate(StreamKernel::Copy), r.results[0].best_rate_mbs);
    }
}
