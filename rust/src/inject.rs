//! Deterministic fault injection: named seams, a parsed plan, exact counters.
//!
//! The failure surfaces this crate grew in PRs 6–9 — the TCP daemon, the
//! crash-safe LSM result store, the TRC1 spill scratch — all fail through
//! the operating system, which makes their error paths hard to reach from
//! a test and impossible to reach *deterministically*.  This module is the
//! one seam that fixes that: production code consults a named **injection
//! point** (a dotted string like `store.wal.write`) at the top of each
//! fallible IO or execution path, and an installed [`FaultPlan`] decides
//! whether that particular consult fails, and how.
//!
//! # Plan grammar
//!
//! A plan is a comma-separated list of directives, each
//! `<point>:<kind>@<trigger>`:
//!
//! | trigger          | meaning                                              |
//! |------------------|------------------------------------------------------|
//! | `@<n>`           | fire on the n-th consult of the point (1-based)      |
//! | `@id=<job-id>`   | fire on every consult carrying that job id           |
//! | `@p=<rate>/<seed>` | seeded xorshift64: fire with probability `rate`    |
//!
//! Kinds: `err` (an injected `io::Error`), `corrupt` (data-integrity
//! failure, e.g. a TRC1 checksum mismatch), `drop` (discard a connection),
//! `panic` (unwind inside the executor), `stall` (hold the seam long
//! enough to trip a deadline).  Examples:
//!
//! ```text
//! store.wal.write:err@3
//! scratch.read:corrupt@2,wire.accept:drop@1
//! job.exec:panic@id=j7
//! store.sst.write:err@p=0.5/42
//! ```
//!
//! # Cost when disarmed
//!
//! Every seam starts with one relaxed [`AtomicBool`] load; with no plan
//! installed that is the *entire* cost, so fault-free production runs are
//! unchanged.  Arming is process-global ([`install`]/[`clear`]) because
//! faults must reach seams buried under the daemon's worker threads where
//! no handle can be threaded through.
//!
//! # Counters
//!
//! The plan counts, per point, how many times it was consulted (`hits`)
//! and how many times it fired (`fired`).  Tests assert these reconcile
//! exactly — an injection campaign that silently never reached its seam is
//! a test bug, not a pass.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};

/// What happens at a seam when a directive fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The seam reports an injected `std::io::Error`.
    Err,
    /// The seam behaves as if the bytes it read failed integrity checks.
    Corrupt,
    /// The seam discards the unit of work (e.g. an accepted connection).
    Drop,
    /// The seam panics, exercising unwind containment.
    Panic,
    /// The seam stalls long enough to trip the surrounding deadline.
    Stall,
}

impl FaultKind {
    fn parse(s: &str) -> Option<FaultKind> {
        match s {
            "err" => Some(FaultKind::Err),
            "corrupt" => Some(FaultKind::Corrupt),
            "drop" => Some(FaultKind::Drop),
            "panic" => Some(FaultKind::Panic),
            "stall" => Some(FaultKind::Stall),
            _ => None,
        }
    }

    /// The spec-grammar name of the kind.
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Err => "err",
            FaultKind::Corrupt => "corrupt",
            FaultKind::Drop => "drop",
            FaultKind::Panic => "panic",
            FaultKind::Stall => "stall",
        }
    }
}

#[derive(Debug)]
enum Trigger {
    /// Fire on the n-th consult of the point (1-based, exactly once).
    Nth(u64),
    /// Fire on every consult that carries this job id.
    Id(String),
    /// Fire with probability `rate`; the xorshift64 state advances once
    /// per consult so a fixed seed replays the identical fault sequence.
    Prob { rate: f64, state: Mutex<u64> },
}

#[derive(Debug)]
struct Rule {
    point: String,
    kind: FaultKind,
    trigger: Trigger,
}

#[derive(Debug, Default, Clone, Copy)]
struct PointCount {
    hits: u64,
    fired: u64,
}

/// A parsed fault campaign: which seams fail, when, and how — plus exact
/// per-point consult/fire counters.
#[derive(Debug, Default)]
pub struct FaultPlan {
    rules: Vec<Rule>,
    counts: Mutex<BTreeMap<String, PointCount>>,
}

pub(crate) fn xorshift64(mut x: u64) -> u64 {
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    x
}

impl FaultPlan {
    /// Parse a comma-separated spec string (see the module docs for the
    /// grammar).  Every malformed directive is an [`Error::Config`] that
    /// quotes the directive and restates the grammar.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut rules = Vec::new();
        for raw in spec.split(',') {
            let d = raw.trim();
            if d.is_empty() {
                continue;
            }
            let bad = |why: &str| {
                Error::Config(format!(
                    "fault-plan directive {d:?}: {why} (grammar: <point>:<kind>@<n> | \
                     <point>:<kind>@id=<job-id> | <point>:<kind>@p=<rate>/<seed>; kinds: \
                     err, corrupt, drop, panic, stall)"
                ))
            };
            let (point, rest) = d.split_once(':').ok_or_else(|| bad("missing ':'"))?;
            if point.is_empty() {
                return Err(bad("empty point name"));
            }
            let (kind_s, trig_s) = rest.split_once('@').ok_or_else(|| bad("missing '@'"))?;
            let kind = FaultKind::parse(kind_s)
                .ok_or_else(|| bad(&format!("unknown kind {kind_s:?}")))?;
            let trigger = if let Some(id) = trig_s.strip_prefix("id=") {
                if id.is_empty() {
                    return Err(bad("empty job id"));
                }
                Trigger::Id(id.to_string())
            } else if let Some(p) = trig_s.strip_prefix("p=") {
                let (rate_s, seed_s) = p
                    .split_once('/')
                    .ok_or_else(|| bad("probabilistic trigger needs p=<rate>/<seed>"))?;
                let rate: f64 =
                    rate_s.parse().map_err(|_| bad("rate is not a number"))?;
                if !(0.0..=1.0).contains(&rate) {
                    return Err(bad("rate must be in [0, 1]"));
                }
                let seed: u64 =
                    seed_s.parse().map_err(|_| bad("seed is not an unsigned integer"))?;
                Trigger::Prob { rate, state: Mutex::new(seed.max(1)) }
            } else {
                let n: u64 = trig_s
                    .parse()
                    .map_err(|_| bad("nth trigger is not a positive integer"))?;
                if n == 0 {
                    return Err(bad("nth trigger is 1-based; @0 would never fire"));
                }
                Trigger::Nth(n)
            };
            rules.push(Rule { point: point.to_string(), kind, trigger });
        }
        if rules.is_empty() {
            return Err(Error::Config(
                "fault-plan is empty: expected comma-separated <point>:<kind>@<trigger> \
                 directives"
                    .into(),
            ));
        }
        Ok(FaultPlan { rules, counts: Mutex::new(BTreeMap::new()) })
    }

    /// Record one consult of `point` (carrying `id` when the caller has
    /// one) and return the kind of the first rule that fires, if any.
    fn consult(&self, point: &str, id: Option<&str>) -> Option<FaultKind> {
        let mut counts = self.counts.lock().unwrap();
        let entry = counts.entry(point.to_string()).or_default();
        entry.hits += 1;
        let hit = entry.hits;
        let mut fired = None;
        for rule in self.rules.iter().filter(|r| r.point == point) {
            let fires = match &rule.trigger {
                Trigger::Nth(n) => hit == *n,
                Trigger::Id(want) => id == Some(want.as_str()),
                Trigger::Prob { rate, state } => {
                    let mut s = state.lock().unwrap();
                    *s = xorshift64(*s);
                    // Top 53 bits → uniform in [0, 1), the standard trick.
                    ((*s >> 11) as f64 / (1u64 << 53) as f64) < *rate
                }
            };
            if fires {
                fired = Some(rule.kind);
                break;
            }
        }
        if fired.is_some() {
            entry.fired += 1;
        }
        fired
    }

    fn snapshot(&self) -> Vec<(String, u64, u64)> {
        self.counts
            .lock()
            .unwrap()
            .iter()
            .map(|(k, c)| (k.clone(), c.hits, c.fired))
            .collect()
    }
}

static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);

/// Install `plan` process-wide; every seam consults it until [`clear`].
pub fn install(plan: FaultPlan) {
    *PLAN.lock().unwrap() = Some(Arc::new(plan));
    ARMED.store(true, Ordering::SeqCst);
}

/// Disarm and drop the installed plan (no-op when none is installed).
pub fn clear() {
    ARMED.store(false, Ordering::SeqCst);
    *PLAN.lock().unwrap() = None;
}

/// Whether a plan is installed.  One relaxed load — this is the entire
/// per-seam cost of the module in fault-free runs.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

fn plan() -> Option<Arc<FaultPlan>> {
    if !armed() {
        return None;
    }
    PLAN.lock().unwrap().clone()
}

/// Consult `point` with no job id; `None` means proceed normally.
pub fn check(point: &str) -> Option<FaultKind> {
    plan()?.consult(point, None)
}

/// Consult `point` on behalf of job `id` (for `@id=` triggers).
pub fn check_id(point: &str, id: &str) -> Option<FaultKind> {
    plan()?.consult(point, Some(id))
}

/// IO-seam helper: consult `point` and, if an `err` directive fires,
/// return the injected `std::io::Error` for the caller to wrap in its
/// usual path-bearing error.  Non-`err` kinds at an IO-only seam are
/// ignored (the seam cannot express them).
pub fn io_error(point: &str) -> Option<std::io::Error> {
    match check(point) {
        Some(FaultKind::Err) => Some(std::io::Error::other(format!(
            "injected fault: {point}:err"
        ))),
        _ => None,
    }
}

/// Executor-seam helper: panic if a `panic` directive fires for this job.
pub fn panic_if_injected(point: &str, id: &str) {
    if let Some(FaultKind::Panic) = check_id(point, id) {
        panic!("injected fault: {point}:panic for job {id:?}");
    }
}

/// Per-point `(point, hits, fired)` counters of the installed plan, in
/// point order; empty when disarmed.  Tests use this to assert a
/// campaign actually reached its seams.
pub fn counters() -> Vec<(String, u64, u64)> {
    match plan() {
        Some(p) => p.snapshot(),
        None => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The plan registry is process-global and the harness runs tests on
    /// concurrent threads, so every test that installs a plan serializes
    /// on this guard (and survives a poisoned lock from a failed peer).
    static GUARD: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        GUARD.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn parse_rejects_malformed_directives() {
        for spec in [
            "",
            " , ,",
            "store.wal.write",
            "store.wal.write:err",
            ":err@1",
            "store.wal.write:@1",
            "store.wal.write:explode@1",
            "store.wal.write:err@0",
            "store.wal.write:err@three",
            "job.exec:panic@id=",
            "store.sst.write:err@p=0.5",
            "store.sst.write:err@p=1.5/42",
            "store.sst.write:err@p=half/42",
            "store.sst.write:err@p=0.5/soon",
        ] {
            let err = FaultPlan::parse(spec).unwrap_err();
            let msg = err.to_string();
            assert!(
                msg.contains("fault-plan"),
                "error for {spec:?} names the knob: {msg}"
            );
        }
        // Malformed directives quote themselves and restate the grammar.
        let msg = FaultPlan::parse("a:err@0").unwrap_err().to_string();
        assert!(msg.contains("\"a:err@0\""), "{msg}");
        assert!(msg.contains("grammar"), "{msg}");
    }

    #[test]
    fn nth_trigger_fires_exactly_once() {
        let plan = FaultPlan::parse("p.x:err@3").unwrap();
        let fired: Vec<bool> =
            (0..6).map(|_| plan.consult("p.x", None).is_some()).collect();
        assert_eq!(fired, [false, false, true, false, false, false]);
        assert_eq!(plan.snapshot(), [("p.x".to_string(), 6, 1)]);
    }

    #[test]
    fn points_count_independently() {
        let plan = FaultPlan::parse("a.b:err@1,c.d:corrupt@2").unwrap();
        assert_eq!(plan.consult("a.b", None), Some(FaultKind::Err));
        assert_eq!(plan.consult("c.d", None), None);
        assert_eq!(plan.consult("c.d", None), Some(FaultKind::Corrupt));
        assert_eq!(plan.consult("unwired.point", None), None);
        assert_eq!(
            plan.snapshot(),
            [
                ("a.b".to_string(), 1, 1),
                ("c.d".to_string(), 2, 1),
                ("unwired.point".to_string(), 1, 0),
            ]
        );
    }

    #[test]
    fn id_trigger_matches_only_its_job() {
        let plan = FaultPlan::parse("job.exec:panic@id=j7").unwrap();
        assert_eq!(plan.consult("job.exec", Some("j1")), None);
        assert_eq!(plan.consult("job.exec", Some("j7")), Some(FaultKind::Panic));
        assert_eq!(plan.consult("job.exec", None), None);
        // Every consult of the id fires — the trigger is per-consult.
        assert_eq!(plan.consult("job.exec", Some("j7")), Some(FaultKind::Panic));
    }

    #[test]
    fn probabilistic_trigger_is_seed_deterministic() {
        let run = |seed: u64| -> Vec<bool> {
            let plan =
                FaultPlan::parse(&format!("p.q:err@p=0.5/{seed}")).unwrap();
            (0..32).map(|_| plan.consult("p.q", None).is_some()).collect()
        };
        assert_eq!(run(42), run(42), "same seed, same fault sequence");
        assert_ne!(run(42), run(43), "different seed, different sequence");
        let fired = run(42).iter().filter(|&&f| f).count();
        assert!((4..=28).contains(&fired), "rate 0.5 over 32: got {fired}");
        // Degenerate rates are exact, not approximate.
        let never = FaultPlan::parse("p.q:err@p=0/1").unwrap();
        assert!((0..64).all(|_| never.consult("p.q", None).is_none()));
        let always = FaultPlan::parse("p.q:err@p=1/1").unwrap();
        assert!((0..64).all(|_| always.consult("p.q", None).is_some()));
    }

    #[test]
    fn global_install_arms_and_clear_disarms() {
        let _g = lock();
        clear();
        assert!(!armed());
        assert_eq!(check("inject.test.point"), None);
        install(FaultPlan::parse("inject.test.point:err@1").unwrap());
        assert!(armed());
        let e = io_error("inject.test.point").expect("first consult fires");
        assert!(e.to_string().contains("injected fault: inject.test.point:err"));
        assert!(io_error("inject.test.point").is_none(), "@1 fires once");
        assert_eq!(
            counters(),
            [("inject.test.point".to_string(), 2, 1)]
        );
        clear();
        assert!(!armed());
        assert!(counters().is_empty());
    }

    #[test]
    fn panic_helper_unwinds_only_for_its_job() {
        let _g = lock();
        clear();
        install(FaultPlan::parse("inject.test.exec:panic@id=j7").unwrap());
        panic_if_injected("inject.test.exec", "j1"); // must not panic
        let caught = std::panic::catch_unwind(|| {
            panic_if_injected("inject.test.exec", "j7");
        });
        let payload = caught.unwrap_err();
        let msg = payload.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains("injected fault"), "{msg}");
        assert!(msg.contains("j7"), "{msg}");
        clear();
    }
}
