//! The statistic-generic seam of the execution engine: [`Method`] names
//! *which* permutation test a run performs, [`StatKernel`] owns that
//! method's precomputation (its *prelude*) and per-permutation statistic.
//!
//! The paper's CPU-vs-GPU result is an access-pattern result about the
//! permute-relabel-reduce loop, not about PERMANOVA's pseudo-F
//! specifically — ANOSIM and PERMDISP run the *same* loop over the same
//! distance matrix with a different reduction.  This module is the seam
//! that lets the `Backend` engine evaluate any of them through the same
//! shard × block × SMT scheduler:
//!
//! * [`Method`] — the method axis (`--method permanova|anosim|permdisp|
//!   pairwise`), threaded through `RunConfig`, the bench sweep and every
//!   report;
//! * [`StatKernel`] — one prepared instance per run.  The variant carries
//!   the method's prelude (PERMANOVA: `s_T` plus the **packed triangle**
//!   the f32 kernels sweep; ANOSIM: the condensed mid-ranks; PERMDISP:
//!   the PCoA distance-to-centroid vector), replacing the
//!   permanova-specific `s_t` that `BatchPlan` used to hard-wire;
//! * [`eval_plan_range`] / [`eval_plan_range_blocked`] — the generic
//!   scalar and block-batched evaluation loops backends delegate to for
//!   every method that has no specialized fast path.
//!
//! PERMANOVA keeps its f32 kernel formulations (the paper's algorithms):
//! backends match on [`StatKernel::Permanova`] and run their existing
//! `sw_*` machinery; the generic `eval_labels` for that variant is the f64
//! brute-force oracle, used by tests and wrappers only.
//!
//! **Bitwise contract:** for a given method, every generic evaluation path
//! executes the identical f64 operation sequence per permutation, so all
//! backends (and all shard / worker / SMT / block settings) produce
//! bit-identical statistics — the conformance suite pins each method
//! against its legacy standalone oracle function.

use std::sync::Arc;

use super::anosim::{r_statistic, r_statistic_block, rank_condensed};
use super::grouping::Grouping;
use super::kernels::sw_brute_f64;
use super::permdisp::{anova_f, dispersion_prelude};
use super::stats::{fstat_from_sw, st_of_condensed, st_rows};
use crate::backend::shard::{for_each_block, ShardSpec};
use crate::dmat::{CondensedMatrix, DistanceMatrix, TriangleStorage};
use crate::error::{Error, Result};
use crate::rng::PermutationPlan;

/// Which permutation test a run performs — the method axis of the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// PERMANOVA (Anderson 2001): pseudo-F over the distance matrix.
    Permanova,
    /// ANOSIM (Clarke 1993): rank-based R over the same matrix.
    Anosim,
    /// PERMDISP (Anderson 2006): ANOVA F over PCoA distances-to-centroid.
    Permdisp,
    /// Post-hoc all-pairs PERMANOVA, one scheduled job per group pair
    /// (Bonferroni-adjusted).
    PairwisePermanova,
}

impl Method {
    /// Every method, in CLI/report order.
    pub const ALL: [Method; 4] =
        [Method::Permanova, Method::Anosim, Method::Permdisp, Method::PairwisePermanova];

    /// Stable identifier used in configs, flags, bench cells and reports.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Permanova => "permanova",
            Method::Anosim => "anosim",
            Method::Permdisp => "permdisp",
            Method::PairwisePermanova => "pairwise",
        }
    }

    /// Parse the identifier format produced by [`name`](Self::name)
    /// (plus the long spelling `pairwise-permanova`).
    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "permanova" => Some(Method::Permanova),
            "anosim" => Some(Method::Anosim),
            "permdisp" => Some(Method::Permdisp),
            "pairwise" | "pairwise-permanova" => Some(Method::PairwisePermanova),
            _ => None,
        }
    }

    /// Display label of the method's test statistic.
    pub fn statistic_label(&self) -> &'static str {
        match self {
            Method::Permanova | Method::PairwisePermanova => "pseudo-F",
            Method::Anosim => "R",
            Method::Permdisp => "F",
        }
    }

    /// Report/render title (`PERMANOVA`, `ANOSIM`, ...).
    pub fn title(&self) -> &'static str {
        match self {
            Method::Permanova => "PERMANOVA",
            Method::Anosim => "ANOSIM",
            Method::Permdisp => "PERMDISP",
            Method::PairwisePermanova => "PAIRWISE-PERMANOVA",
        }
    }
}

/// PERMANOVA prelude: the permutation-invariant total sum of squares plus
/// the triangle **storage** the f32 kernels sweep — resident (the packed
/// buffer) or file-backed (the out-of-core tier, swept chunk by chunk).
#[derive(Clone, Debug)]
pub struct PermanovaStat {
    /// `s_T = Σ_{i<j} d²_ij / n`.
    pub s_t: f64,
    /// Objects in the matrix the prelude was computed from (reuse check).
    pub n: usize,
    /// Where the packed triangle lives.  Shared (`Arc` inside) so the
    /// service cache builds it once per dataset and every job's backend
    /// streams the same buffer — or pages the same file.
    pub storage: TriangleStorage,
}

impl PermanovaStat {
    /// The resident packed triangle.  Backends that can only sweep a
    /// resident buffer call this after routing file-backed storage to the
    /// chunked kernels (or to a loud `Error::Config`); reaching it with a
    /// file-backed prelude is an engine routing bug.
    pub fn packed(&self) -> &Arc<CondensedMatrix> {
        self.storage.as_resident().expect(
            "resident triangle requested from a file-backed PERMANOVA prelude; \
             file-backed runs route through the chunked kernels",
        )
    }
}

/// ANOSIM prelude: condensed mid-ranks of the distances (computed once —
/// they depend only on the matrix, never on the labelling).
#[derive(Clone, Debug)]
pub struct AnosimStat {
    /// Mid-ranks of the condensed upper triangle, in (i, j) row-major order.
    pub ranks: Vec<f64>,
}

/// PERMDISP prelude: each object's PCoA distance to its group centroid.
#[derive(Clone, Debug)]
pub struct PermdispStat {
    /// Distance-to-centroid per object (the values the ANOVA F permutes over).
    pub dists: Vec<f64>,
    /// Group count of the observed labelling.
    pub k: usize,
    /// Mean distance-to-centroid per group (the dispersions under test).
    pub group_dispersions: Vec<f64>,
}

/// A prepared per-run statistic: the method's prelude plus its
/// per-permutation evaluation.  Built once by [`prepare`](Self::prepare)
/// and shared read-only with the backend via `BatchPlan::stat`.
///
/// **Prelude reuse is bitwise-neutral:** a prelude depends only on the
/// (matrix, grouping) problem, never on the permutation plan, seed, backend
/// or scheduling knobs — so the service layer's `DatasetCache` memoizes one
/// prepared kernel per method per dataset and hands the *same values* to
/// every job.  Reusing a prelude therefore cannot perturb a single bit of
/// any statistic; [`check_problem`](Self::check_problem) guards against
/// handing a kernel to a *different* problem than it was prepared for.
#[derive(Clone, Debug)]
pub enum StatKernel {
    Permanova(PermanovaStat),
    Anosim(AnosimStat),
    Permdisp(PermdispStat),
}

impl StatKernel {
    /// Run the method's precomputation for one (matrix, grouping) problem.
    ///
    /// Packs the triangle itself; callers that already hold a per-dataset
    /// packed buffer (the service cache) use
    /// [`prepare_shared`](Self::prepare_shared) to avoid re-packing.
    ///
    /// [`Method::PairwisePermanova`] has no single kernel — the engine fans
    /// it out into one PERMANOVA job per group pair *above* this seam — so
    /// requesting it here is an input error.
    pub fn prepare(
        method: Method,
        mat: &DistanceMatrix,
        grouping: &Grouping,
    ) -> Result<StatKernel> {
        Self::prepare_shared(method, mat, grouping, None)
    }

    /// Run the method's precomputation straight from the **packed
    /// triangle** — the dense-free path every production caller uses (the
    /// coordinator streams sources into a [`CondensedMatrix`]; no dense
    /// copy exists to prepare from).  Bitwise-equal to
    /// [`prepare`](Self::prepare) on the corresponding dense matrix: the
    /// PERMANOVA and ANOSIM preludes already consume condensed values, and
    /// PERMDISP — whose PCoA is the one dense boundary left — stages a
    /// transient `to_dense()` mirror for its prelude and drops it before
    /// returning, so nothing dense is retained.
    pub fn prepare_packed(
        method: Method,
        tri: &Arc<CondensedMatrix>,
        grouping: &Grouping,
    ) -> Result<StatKernel> {
        if grouping.n() != tri.n() {
            return Err(Error::InvalidInput(format!(
                "grouping n = {} vs matrix n = {}",
                grouping.n(),
                tri.n()
            )));
        }
        match method {
            Method::Permanova => Ok(StatKernel::Permanova(PermanovaStat {
                s_t: st_of_condensed(tri),
                n: tri.n(),
                storage: TriangleStorage::Resident(Arc::clone(tri)),
            })),
            Method::Anosim => {
                Ok(StatKernel::Anosim(AnosimStat { ranks: rank_condensed(tri.values()) }))
            }
            Method::Permdisp => {
                let mat = tri.to_dense();
                let (dists, group_dispersions) = dispersion_prelude(&mat, grouping)?;
                Ok(StatKernel::Permdisp(PermdispStat {
                    dists,
                    k: grouping.k(),
                    group_dispersions,
                }))
            }
            Method::PairwisePermanova => Err(Error::InvalidInput(
                "pairwise PERMANOVA is a fan-out of per-pair PERMANOVA jobs; \
                 prepare a Permanova kernel per pair instead"
                    .into(),
            )),
        }
    }

    /// Run the method's precomputation from **triangle storage** — the
    /// out-of-core-aware production entry.  Resident storage delegates to
    /// [`prepare_packed`](Self::prepare_packed) (bit-for-bit the classic
    /// prelude).  File-backed storage supports PERMANOVA only: its `s_T`
    /// pass streams the paged chunks through [`st_rows`] in ascending row
    /// order — the exact f64 op sequence of [`st_of_condensed`], so the
    /// prelude is **bitwise identical** to a resident preparation of the
    /// same triangle.  Methods whose prelude fundamentally needs the whole
    /// triangle at once fail loudly, naming the budget knob:
    ///
    /// * ANOSIM — its global mid-rank sort orders all `n(n-1)/2` distances
    ///   against each other;
    /// * PERMDISP — its PCoA eigendecomposition works on the dense matrix.
    pub fn prepare_storage(
        method: Method,
        storage: &TriangleStorage,
        grouping: &Grouping,
    ) -> Result<StatKernel> {
        let file = match storage {
            TriangleStorage::Resident(tri) => {
                return Self::prepare_packed(method, tri, grouping)
            }
            TriangleStorage::FileBacked(f) => f,
        };
        if grouping.n() != file.n() {
            return Err(Error::InvalidInput(format!(
                "grouping n = {} vs matrix n = {}",
                grouping.n(),
                file.n()
            )));
        }
        let packed_bytes = file.count() * 4;
        match method {
            Method::Permanova => {
                let mut acc = 0.0f64;
                for (r0, r1) in file.chunk_plan(1) {
                    let chunk = file.load_chunk(r0, r1)?;
                    st_rows(&chunk, r0, r1, &mut acc);
                }
                Ok(StatKernel::Permanova(PermanovaStat {
                    s_t: acc / file.n() as f64,
                    n: file.n(),
                    storage: storage.clone(),
                }))
            }
            Method::Anosim => Err(Error::Config(format!(
                "ANOSIM's global rank sort needs the whole triangle resident, but the \
                 dataset is file-backed under --max-resident-bytes; raise the budget to \
                 at least {packed_bytes} bytes (or drop the cap) to run this method"
            ))),
            Method::Permdisp => Err(Error::Config(format!(
                "PERMDISP's PCoA eigendecomposition needs the dense matrix resident, but \
                 the dataset is file-backed under --max-resident-bytes; raise the budget \
                 to at least {packed_bytes} bytes (or drop the cap) to run this method"
            ))),
            Method::PairwisePermanova => Err(Error::Config(format!(
                "pairwise PERMANOVA extracts per-pair sub-triangles from the resident \
                 buffer, but the dataset is file-backed under --max-resident-bytes; \
                 raise the budget to at least {packed_bytes} bytes (or drop the cap) to \
                 run this method"
            ))),
        }
    }

    /// [`prepare`](Self::prepare) with an optionally **pre-packed**
    /// triangle.  Kept as the dense-side seam for tests and wrappers that
    /// start from a [`DistanceMatrix`]; production code prepares through
    /// [`prepare_packed`](Self::prepare_packed).  Sharing is
    /// bitwise-neutral: the packed values are exactly what
    /// `CondensedMatrix::from_dense(mat)` would produce (checked against
    /// the matrix edge).
    pub fn prepare_shared(
        method: Method,
        mat: &DistanceMatrix,
        grouping: &Grouping,
        packed: Option<Arc<CondensedMatrix>>,
    ) -> Result<StatKernel> {
        if grouping.n() != mat.n() {
            return Err(Error::InvalidInput(format!(
                "grouping n = {} vs matrix n = {}",
                grouping.n(),
                mat.n()
            )));
        }
        if let Some(p) = &packed {
            if p.n() != mat.n() {
                return Err(Error::InvalidInput(format!(
                    "packed triangle has n = {}, matrix has n = {}",
                    p.n(),
                    mat.n()
                )));
            }
        }
        match method {
            Method::Permanova => {
                let packed = packed.unwrap_or_else(|| Arc::new(CondensedMatrix::from_dense(mat)));
                Ok(StatKernel::Permanova(PermanovaStat {
                    s_t: st_of_condensed(&packed),
                    n: mat.n(),
                    storage: TriangleStorage::Resident(packed),
                }))
            }
            // The rank prelude consumes the packed values directly (they
            // are already in condensed order); the ranks then *are* the
            // packed per-permutation operand, so nothing else is retained.
            Method::Anosim => Ok(StatKernel::Anosim(AnosimStat {
                ranks: match &packed {
                    Some(p) => rank_condensed(p.values()),
                    None => rank_condensed(&mat.to_condensed()),
                },
            })),
            // PERMDISP's per-permutation operand is the O(n) distance-to-
            // centroid vector; its prelude needs the dense matrix (PCoA is
            // the dense boundary) and nothing packed.
            Method::Permdisp => {
                let (dists, group_dispersions) = dispersion_prelude(mat, grouping)?;
                Ok(StatKernel::Permdisp(PermdispStat {
                    dists,
                    k: grouping.k(),
                    group_dispersions,
                }))
            }
            Method::PairwisePermanova => Err(Error::InvalidInput(
                "pairwise PERMANOVA is a fan-out of per-pair PERMANOVA jobs; \
                 prepare a Permanova kernel per pair instead"
                    .into(),
            )),
        }
    }

    /// Verify this kernel was prepared for the given problem shape: the
    /// cheap guard the engine runs before reusing a cached prelude.  It
    /// checks everything derivable from the prelude (object count, and the
    /// group count for PERMDISP) against the problem's edge `n` — a
    /// size-matched but *content*-different matrix is the caller's
    /// contract to avoid (the `DatasetCache` keys on the data source, so a
    /// cached prelude always belongs to its dataset).
    pub fn check_problem(&self, n: usize, grouping: &Grouping) -> Result<()> {
        let prepared_n = match self {
            StatKernel::Permanova(p) => p.n,
            // ranks.len() = n(n-1)/2 uniquely determines n (round, don't
            // truncate: sqrt may land an ulp below the exact odd integer).
            StatKernel::Anosim(a) => {
                ((1.0 + (1.0 + 8.0 * a.ranks.len() as f64).sqrt()) / 2.0).round() as usize
            }
            StatKernel::Permdisp(p) => p.dists.len(),
        };
        if prepared_n != n {
            return Err(Error::InvalidInput(format!(
                "prelude prepared for n = {prepared_n}, problem has n = {n}"
            )));
        }
        if let StatKernel::Permdisp(p) = self {
            if p.k != grouping.k() {
                return Err(Error::InvalidInput(format!(
                    "PERMDISP prelude prepared for k = {}, grouping has k = {}",
                    p.k,
                    grouping.k()
                )));
            }
        }
        Ok(())
    }

    /// The method this kernel evaluates.
    pub fn method(&self) -> Method {
        match self {
            StatKernel::Permanova(_) => Method::Permanova,
            StatKernel::Anosim(_) => Method::Anosim,
            StatKernel::Permdisp(_) => Method::Permdisp,
        }
    }

    /// Kernel identifier recorded in reports for the *generic* evaluation
    /// paths (PERMANOVA backends record their own f32 formulation instead).
    pub fn kernel_label(&self) -> &'static str {
        match self {
            StatKernel::Permanova(_) => "brute-f64",
            StatKernel::Anosim(_) => "rank-r",
            StatKernel::Permdisp(_) => "centroid-anova",
        }
    }

    /// The PERMANOVA total sum of squares (0 for other methods — a
    /// diagnostic that only exists for the pseudo-F decomposition).
    pub fn s_t(&self) -> f64 {
        match self {
            StatKernel::Permanova(p) => p.s_t,
            _ => 0.0,
        }
    }

    /// The PERMDISP per-group mean dispersions (empty for other methods).
    pub fn group_dispersions(&self) -> &[f64] {
        match self {
            StatKernel::Permdisp(p) => &p.group_dispersions,
            _ => &[],
        }
    }

    /// The **resident** packed triangle this kernel streams per
    /// permutation, when the method has an n² f32 stream (PERMANOVA) and
    /// the triangle is in memory.  ANOSIM's packed operand is its f64 rank
    /// vector, PERMDISP's is the O(n) distance vector, and a file-backed
    /// PERMANOVA prelude has no resident buffer — all of those return
    /// `None`.
    pub fn packed(&self) -> Option<&Arc<CondensedMatrix>> {
        match self {
            StatKernel::Permanova(p) => p.storage.as_resident(),
            _ => None,
        }
    }

    /// The triangle storage behind this kernel (PERMANOVA only — the
    /// methods whose hot loop streams the n² triangle).
    pub fn storage(&self) -> Option<&TriangleStorage> {
        match self {
            StatKernel::Permanova(p) => Some(&p.storage),
            _ => None,
        }
    }

    /// Evaluate the statistic for one labelling (the generic f64 path).
    /// Matrix-free: every prelude already carries its packed operand, and
    /// the problem edge `n` is `labels.len()`.
    ///
    /// For [`StatKernel::Permanova`] this is the f64 brute-force *oracle*
    /// (`sw_brute_f64`), not the f32 production kernels — backends keep
    /// their formulation-specific fast paths for that variant and only
    /// tests/wrappers call this one.
    pub fn eval_labels(&self, grouping: &Grouping, labels: &[u32]) -> f64 {
        match self {
            StatKernel::Permanova(p) => {
                let sw = sw_brute_f64(p.packed().view(), labels, grouping.inv_sizes());
                fstat_from_sw(sw, p.s_t, p.n, grouping.k())
            }
            StatKernel::Anosim(a) => r_statistic(&a.ranks, labels.len(), labels),
            StatKernel::Permdisp(p) => anova_f(&p.dists, labels, p.k),
        }
    }
}

/// Evaluate a permutation-plan range `[start, start + count)` through the
/// shard scheduler: each worker owns a scratch label row and streams
/// through its shards, calling [`StatKernel::eval_labels`] per index.
///
/// This is the scalar one-permutation-per-step loop every backend uses for
/// methods without a specialized path; results are independent of the
/// shard spec (the scheduler's determinism contract).  Matrix-free: the
/// prelude carries the packed operand, the grouping carries `n`.
pub fn eval_plan_range(
    kernel: &StatKernel,
    grouping: &Grouping,
    plan: &PermutationPlan,
    start: usize,
    count: usize,
    spec: &ShardSpec,
) -> Vec<f64> {
    let n = grouping.n();
    assert_eq!(plan.n(), n, "plan/grouping size mismatch");
    let mut out = vec![0.0f64; count];
    crate::backend::shard::run_sharded_with(
        spec,
        &mut out,
        || vec![0u32; n],
        |row, lo, slice| {
            for (i, o) in slice.iter_mut().enumerate() {
                plan.fill(start + lo + i, row);
                *o = kernel.eval_labels(grouping, row);
            }
        },
    );
    out
}

/// Evaluate a plan range with the **block-batched** schedule: workers walk
/// their shards in `perm_block`-wide blocks (the batched brute engine's
/// walk), amortizing prelude reads across the block's lanes where the
/// method allows it.
///
/// * [`StatKernel::Anosim`] uses the SoA rank-sweep kernel
///   (`r_statistic_block`): each condensed rank is read **once** per
///   block and applied to all lanes — the same access-pattern win as
///   `sw_brute_block`, because ANOSIM's hot loop streams the same n²/2
///   triangle.
/// * Other variants evaluate each lane with the scalar statistic (the
///   PERMDISP prelude is an O(n) vector; there is no n² stream to
///   amortize).
///
/// Every lane executes the scalar path's exact f64 operation sequence, so
/// blocked evaluation is **bitwise identical** to [`eval_plan_range`] at
/// any block width, shard size, worker count and SMT setting.
pub fn eval_plan_range_blocked(
    kernel: &StatKernel,
    grouping: &Grouping,
    plan: &PermutationPlan,
    start: usize,
    count: usize,
    perm_block: usize,
    spec: &ShardSpec,
) -> Vec<f64> {
    let n = grouping.n();
    assert_eq!(plan.n(), n, "plan/grouping size mismatch");
    let block = super::batch::resolve_perm_block(perm_block).min(count.max(1));
    let spec = spec.aligned_to_block(count, block);
    let mut out = vec![0.0f64; count];
    crate::backend::shard::run_sharded_with(
        &spec,
        &mut out,
        // Per-worker scratch: one label row + one SoA block buffer (only
        // the ANOSIM rank-sweep arm consumes the latter; the per-lane
        // scalar arm pays nothing for it).
        || {
            let soa = match kernel {
                StatKernel::Anosim(_) => vec![0u32; n * block],
                _ => Vec::new(),
            };
            (vec![0u32; n], soa)
        },
        |scratch, lo, slice| {
            let (row, soa) = scratch;
            for_each_block(0, slice.len(), block, |off, b| {
                let dst = &mut slice[off..off + b];
                match kernel {
                    StatKernel::Anosim(a) => {
                        let soa = &mut soa[..n * b];
                        for j in 0..b {
                            plan.fill(start + lo + off + j, row);
                            for i in 0..n {
                                soa[i * b + j] = row[i];
                            }
                        }
                        r_statistic_block(&a.ranks, n, soa, b, dst);
                    }
                    _ => {
                        for (j, o) in dst.iter_mut().enumerate() {
                            plan.fill(start + lo + off + j, row);
                            *o = kernel.eval_labels(grouping, row);
                        }
                    }
                }
            });
        },
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::permanova::{anosim, permdisp};

    fn fixture(n: usize, k: usize, seed: u64) -> (DistanceMatrix, Grouping) {
        (DistanceMatrix::random_euclidean(n, 6, seed), Grouping::balanced(n, k).unwrap())
    }

    #[test]
    fn method_name_parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m), "{m:?}");
        }
        assert_eq!(Method::parse("pairwise-permanova"), Some(Method::PairwisePermanova));
        assert_eq!(Method::parse("PERMANOVA"), None);
        assert_eq!(Method::parse("bogus"), None);
        assert_eq!(Method::parse(""), None);
    }

    #[test]
    fn statistic_labels() {
        assert_eq!(Method::Permanova.statistic_label(), "pseudo-F");
        assert_eq!(Method::Anosim.statistic_label(), "R");
        assert_eq!(Method::Permdisp.statistic_label(), "F");
        assert_eq!(Method::Permanova.title(), "PERMANOVA");
        assert_eq!(Method::PairwisePermanova.title(), "PAIRWISE-PERMANOVA");
    }

    #[test]
    fn prepare_builds_the_right_prelude() {
        let (mat, grouping) = fixture(24, 3, 5);
        match StatKernel::prepare(Method::Permanova, &mat, &grouping).unwrap() {
            StatKernel::Permanova(p) => assert!(p.s_t > 0.0),
            other => panic!("{other:?}"),
        }
        match StatKernel::prepare(Method::Anosim, &mat, &grouping).unwrap() {
            StatKernel::Anosim(a) => assert_eq!(a.ranks.len(), 24 * 23 / 2),
            other => panic!("{other:?}"),
        }
        match StatKernel::prepare(Method::Permdisp, &mat, &grouping).unwrap() {
            StatKernel::Permdisp(p) => {
                assert_eq!(p.dists.len(), 24);
                assert_eq!(p.k, 3);
                assert_eq!(p.group_dispersions.len(), 3);
            }
            other => panic!("{other:?}"),
        }
        assert!(StatKernel::prepare(Method::PairwisePermanova, &mat, &grouping).is_err());
        let g_bad = Grouping::balanced(30, 3).unwrap();
        assert!(StatKernel::prepare(Method::Anosim, &mat, &g_bad).is_err());
    }

    #[test]
    fn prepare_shared_reuses_the_packed_buffer_bitwise() {
        let (mat, grouping) = fixture(24, 3, 5);
        let packed = Arc::new(CondensedMatrix::from_dense(&mat));
        // Shared-packed preludes carry the same values as self-packed ones.
        for method in [Method::Permanova, Method::Anosim] {
            let cold = StatKernel::prepare(method, &mat, &grouping).unwrap();
            let shared =
                StatKernel::prepare_shared(method, &mat, &grouping, Some(Arc::clone(&packed)))
                    .unwrap();
            match (&cold, &shared) {
                (StatKernel::Permanova(a), StatKernel::Permanova(b)) => {
                    assert_eq!(a.s_t.to_bits(), b.s_t.to_bits());
                    assert_eq!(a.packed().values(), b.packed().values());
                    // The shared buffer is referenced, not copied.
                    assert!(Arc::ptr_eq(b.packed(), &packed));
                }
                (StatKernel::Anosim(a), StatKernel::Anosim(b)) => {
                    assert_eq!(a.ranks, b.ranks);
                }
                other => panic!("{other:?}"),
            }
        }
        // A packed buffer for a different problem size is rejected.
        let (other_mat, other_grouping) = fixture(30, 3, 5);
        assert!(StatKernel::prepare_shared(
            Method::Permanova,
            &other_mat,
            &other_grouping,
            Some(packed)
        )
        .is_err());
        // The accessor exposes the triangle only for the f32-stream method.
        let p = StatKernel::prepare(Method::Permanova, &mat, &grouping).unwrap();
        assert_eq!(p.packed().unwrap().n(), 24);
        let a = StatKernel::prepare(Method::Anosim, &mat, &grouping).unwrap();
        assert!(a.packed().is_none());
    }

    #[test]
    fn prepare_packed_matches_dense_prepare_bitwise() {
        // The dense-free production path produces the exact prelude the
        // dense oracle path would — per method, bit for bit.
        let (mat, grouping) = fixture(24, 3, 5);
        let tri = Arc::new(CondensedMatrix::from_dense(&mat));
        for method in [Method::Permanova, Method::Anosim, Method::Permdisp] {
            let dense = StatKernel::prepare(method, &mat, &grouping).unwrap();
            let packed = StatKernel::prepare_packed(method, &tri, &grouping).unwrap();
            match (&dense, &packed) {
                (StatKernel::Permanova(a), StatKernel::Permanova(b)) => {
                    assert_eq!(a.s_t.to_bits(), b.s_t.to_bits());
                    assert_eq!(a.packed().values(), b.packed().values());
                    assert!(Arc::ptr_eq(b.packed(), &tri), "must reference, not re-pack");
                }
                (StatKernel::Anosim(a), StatKernel::Anosim(b)) => assert_eq!(a.ranks, b.ranks),
                (StatKernel::Permdisp(a), StatKernel::Permdisp(b)) => {
                    assert_eq!(a.dists, b.dists);
                    assert_eq!(a.group_dispersions, b.group_dispersions);
                    assert_eq!(a.k, b.k);
                }
                other => panic!("{other:?}"),
            }
        }
        assert!(StatKernel::prepare_packed(Method::PairwisePermanova, &tri, &grouping).is_err());
        let g_bad = Grouping::balanced(30, 3).unwrap();
        assert!(StatKernel::prepare_packed(Method::Permanova, &tri, &g_bad).is_err());
    }

    #[test]
    fn prepare_storage_file_backed_matches_resident_bitwise() {
        let (mat, grouping) = fixture(31, 3, 6);
        let tri = Arc::new(CondensedMatrix::from_dense(&mat));
        // A 300-byte cap over 31·30/2 f32 values forces many chunks.
        let file = crate::dmat::file_backed_from(&tri, 300).unwrap();
        let resident = StatKernel::prepare_packed(Method::Permanova, &tri, &grouping).unwrap();
        let paged =
            StatKernel::prepare_storage(Method::Permanova, &file, &grouping).unwrap();
        match (&resident, &paged) {
            (StatKernel::Permanova(a), StatKernel::Permanova(b)) => {
                assert_eq!(a.s_t.to_bits(), b.s_t.to_bits(), "chunked s_T must match bits");
                assert_eq!(a.n, b.n);
                assert!(b.storage.is_file_backed());
            }
            other => panic!("{other:?}"),
        }
        // The file-backed prelude exposes storage but no resident triangle.
        assert!(paged.packed().is_none());
        assert!(paged.storage().unwrap().is_file_backed());
        // Resident storage routes through prepare_packed unchanged.
        let via_storage = StatKernel::prepare_storage(
            Method::Permanova,
            &TriangleStorage::Resident(Arc::clone(&tri)),
            &grouping,
        )
        .unwrap();
        match &via_storage {
            StatKernel::Permanova(p) => assert!(Arc::ptr_eq(p.packed(), &tri)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn prepare_storage_rejects_whole_triangle_methods_when_file_backed() {
        let (mat, grouping) = fixture(20, 2, 7);
        let tri = Arc::new(CondensedMatrix::from_dense(&mat));
        let file = crate::dmat::file_backed_from(&tri, 128).unwrap();
        for method in [Method::Anosim, Method::Permdisp, Method::PairwisePermanova] {
            let err = StatKernel::prepare_storage(method, &file, &grouping).unwrap_err();
            let msg = err.to_string();
            assert!(
                matches!(err, Error::Config(_)),
                "{method:?}: expected Error::Config, got {err:?}"
            );
            assert!(
                msg.contains("--max-resident-bytes"),
                "{method:?}: message must name the budget knob: {msg}"
            );
        }
        // Size mismatch stays an input error, not a config error.
        let g_bad = Grouping::balanced(30, 3).unwrap();
        assert!(matches!(
            StatKernel::prepare_storage(Method::Permanova, &file, &g_bad),
            Err(Error::InvalidInput(_))
        ));
    }

    #[test]
    fn check_problem_guards_prelude_reuse() {
        let (mat, grouping) = fixture(24, 3, 5);
        let (other_mat, other_grouping) = fixture(30, 3, 5);
        for method in [Method::Permanova, Method::Anosim, Method::Permdisp] {
            let kernel = StatKernel::prepare(method, &mat, &grouping).unwrap();
            kernel.check_problem(mat.n(), &grouping).unwrap();
            assert!(
                kernel.check_problem(other_mat.n(), &other_grouping).is_err(),
                "{method:?}: prelude for n=24 must not serve n=30"
            );
        }
        // PERMDISP additionally pins the group count.
        let kernel = StatKernel::prepare(Method::Permdisp, &mat, &grouping).unwrap();
        let g2 = Grouping::balanced(24, 2).unwrap();
        assert!(kernel.check_problem(mat.n(), &g2).is_err(), "k=3 prelude must not serve k=2");
    }

    #[test]
    fn eval_matches_the_legacy_oracles() {
        // The kernel's per-permutation statistic is the *same* f64 code the
        // legacy free functions run, so the full distributions match exactly.
        let (mat, grouping) = fixture(30, 3, 9);
        let plan = PermutationPlan::new(grouping.labels().to_vec(), 41, 20);
        let mut row = vec![0u32; 30];

        let a = StatKernel::prepare(Method::Anosim, &mat, &grouping).unwrap();
        let legacy = anosim(&mat, &grouping, 19, 41).unwrap();
        plan.fill(0, &mut row);
        assert_eq!(a.eval_labels(&grouping, &row), legacy.r_obs);

        let d = StatKernel::prepare(Method::Permdisp, &mat, &grouping).unwrap();
        let legacy = permdisp(&mat, &grouping, 19, 41).unwrap();
        assert_eq!(d.eval_labels(&grouping, &row), legacy.f_obs);
        match &d {
            StatKernel::Permdisp(p) => {
                assert_eq!(p.group_dispersions, legacy.group_dispersions)
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn plan_range_is_shard_invariant() {
        let (mat, grouping) = fixture(26, 2, 3);
        let plan = PermutationPlan::new(grouping.labels().to_vec(), 7, 40);
        for method in [Method::Permanova, Method::Anosim, Method::Permdisp] {
            let kernel = StatKernel::prepare(method, &mat, &grouping).unwrap();
            let base =
                eval_plan_range(&kernel, &grouping, &plan, 0, 40, &ShardSpec::with_workers(1));
            for spec in [
                ShardSpec::with_workers(3),
                ShardSpec { shard_size: 7, workers: 2, smt: true },
                ShardSpec::default(),
            ] {
                let got = eval_plan_range(&kernel, &grouping, &plan, 0, 40, &spec);
                assert_eq!(base, got, "{method:?} {spec:?}");
            }
        }
    }

    #[test]
    fn blocked_is_bitwise_identical_to_scalar_for_every_method() {
        let (mat, grouping) = fixture(28, 4, 13);
        let plan = PermutationPlan::new(grouping.labels().to_vec(), 17, 50);
        for method in [Method::Permanova, Method::Anosim, Method::Permdisp] {
            let kernel = StatKernel::prepare(method, &mat, &grouping).unwrap();
            let want =
                eval_plan_range(&kernel, &grouping, &plan, 0, 50, &ShardSpec::with_workers(1));
            for block in [1usize, 3, 8, 64] {
                for spec in [
                    ShardSpec::with_workers(1),
                    ShardSpec { shard_size: 7, workers: 3, smt: false },
                    ShardSpec { shard_size: 16, workers: 2, smt: true },
                ] {
                    let got =
                        eval_plan_range_blocked(&kernel, &grouping, &plan, 0, 50, block, &spec);
                    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "{method:?} block={block} {spec:?} perm {i}: {g} vs {w}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn blocked_sub_ranges_line_up() {
        let (mat, grouping) = fixture(22, 2, 8);
        let plan = PermutationPlan::new(grouping.labels().to_vec(), 29, 40);
        let kernel = StatKernel::prepare(Method::Anosim, &mat, &grouping).unwrap();
        let spec = ShardSpec::with_workers(2);
        let full = eval_plan_range_blocked(&kernel, &grouping, &plan, 0, 40, 8, &spec);
        let head = eval_plan_range_blocked(&kernel, &grouping, &plan, 0, 13, 8, &spec);
        let tail = eval_plan_range_blocked(&kernel, &grouping, &plan, 13, 27, 8, &spec);
        assert_eq!(&full[..13], &head[..]);
        assert_eq!(&full[13..], &tail[..]);
    }
}
