//! PERMANOVA — Permutational Multivariate Analysis of Variance.
//!
//! The paper's subject system: a non-parametric test of whether groups of
//! objects differ, driven by a distance matrix and assessed by permuting
//! group labels (Anderson 2001).  This module owns:
//!
//! * [`Grouping`] — validated categorical factor with `inv_group_sizes`;
//! * the three kernel formulations of the hot loop (paper Algorithms 1–3):
//!   [`sw_brute_one`], [`sw_tiled_one`], [`sw_flat_one`], selected via
//!   [`SwAlgorithm`];
//! * batched multi-threaded execution ([`sw_batch`], [`sw_plan_range`]) —
//!   the `permanova_f_stat_sW_T` analog;
//! * the batched brute engine ([`sw_brute_block`],
//!   [`sw_plan_range_blocked`]) — one matrix sweep amortized over a SoA
//!   block of permutations, the paper's GPU-winning access pattern;
//! * the full statistic ([`permanova`], [`st_of`], [`fstat_from_sw`],
//!   [`pvalue`]);
//! * the surrounding workflow: post-hoc [`pairwise_permanova`]
//!   (Bonferroni), rank-based [`anosim`] (Clarke 1993), and dispersion
//!   homogeneity [`permdisp`] (Anderson 2006, via PCoA).

mod anosim;
mod batch;
mod grouping;
mod kernels;
mod pairwise;
mod permdisp;
mod stats;

pub use anosim::{anosim, AnosimResult};
pub use permdisp::{permdisp, PermdispResult};
pub use batch::{
    resolve_perm_block, resolve_threads, sw_batch, sw_permutations, sw_plan_range,
    sw_plan_range_blocked,
};
pub use grouping::Grouping;
pub use kernels::{
    sw_brute_block, sw_brute_f64, sw_brute_one, sw_flat_one, sw_of, sw_one, sw_tiled_one,
    SwAlgorithm, DEFAULT_PERM_BLOCK, DEFAULT_TILE,
};
pub use pairwise::{pairwise_permanova, PairwiseEntry, PairwiseResult};
pub use stats::{fstat_from_sw, permanova, pvalue, st_of, PermanovaOpts, PermanovaResult};
