//! PERMANOVA — Permutational Multivariate Analysis of Variance.
//!
//! The paper's subject system: a non-parametric test of whether groups of
//! objects differ, driven by a distance matrix and assessed by permuting
//! group labels (Anderson 2001).  This module owns:
//!
//! * [`Grouping`] — validated categorical factor with `inv_group_sizes`;
//! * the three kernel formulations of the hot loop (paper Algorithms 1–3):
//!   [`sw_brute_one`], [`sw_tiled_one`], [`sw_flat_one`], selected via
//!   [`SwAlgorithm`] — all sweeping the **packed upper triangle**
//!   (`dmat::CondensedView`, half the dense footprint), with the dense
//!   seeds kept as `*_dense` conformance oracles;
//! * batched multi-threaded execution ([`sw_batch`], [`sw_plan_range`]) —
//!   the `permanova_f_stat_sW_T` analog;
//! * the batched brute engine ([`sw_brute_block`],
//!   [`sw_plan_range_blocked`]) — one matrix sweep amortized over a SoA
//!   block of permutations, the paper's GPU-winning access pattern;
//! * the out-of-core chunk seam ([`PackedRows`], the `*_rows` kernels,
//!   [`sw_plan_range_chunked`], [`sw_plan_range_blocked_chunked`]) — the
//!   same kernels sweeping paged row chunks with carried per-lane
//!   accumulators, bitwise identical to the resident sweeps;
//! * the full statistic ([`permanova`], [`st_of`], [`fstat_from_sw`],
//!   [`pvalue`]);
//! * the statistic-generic seam of the execution engine ([`Method`],
//!   [`StatKernel`], [`eval_plan_range`], [`eval_plan_range_blocked`]) —
//!   what lets every backend evaluate ANOSIM and PERMDISP through the same
//!   shard × block × SMT scheduler as PERMANOVA;
//! * the surrounding workflow: post-hoc [`pairwise_permanova`]
//!   (Bonferroni), rank-based [`anosim`] (Clarke 1993), and dispersion
//!   homogeneity [`permdisp`] (Anderson 2006, via PCoA) — each kept as a
//!   thin single-threaded wrapper over the same per-method statistic code,
//!   which makes them the engine's f64 conformance oracles.

mod anosim;
mod batch;
mod grouping;
mod kernels;
mod method;
mod pairwise;
mod permdisp;
mod stats;

pub use anosim::{anosim, AnosimResult};
pub use permdisp::{permdisp, PermdispResult};
pub use batch::{
    resolve_perm_block, resolve_threads, sw_batch, sw_permutations, sw_plan_range,
    sw_plan_range_blocked, sw_plan_range_blocked_chunked, sw_plan_range_chunked,
};
pub use grouping::Grouping;
pub use kernels::{
    chunk_align, sw_brute_block, sw_brute_block_dense, sw_brute_block_rows, sw_brute_f64,
    sw_brute_f64_dense, sw_brute_one, sw_brute_one_dense, sw_brute_rows, sw_flat_one,
    sw_flat_one_dense, sw_flat_rows, sw_of, sw_one, sw_one_dense, sw_rows, sw_tiled_one,
    sw_tiled_one_dense, sw_tiled_rows, PackedRows, SwAlgorithm, DEFAULT_PERM_BLOCK,
    DEFAULT_TILE,
};
pub use method::{
    eval_plan_range, eval_plan_range_blocked, AnosimStat, Method, PermanovaStat, PermdispStat,
    StatKernel,
};
pub use pairwise::{
    pairwise_permanova, pairwise_seed, pairwise_subproblem, pairwise_subproblem_condensed,
    PairwiseEntry, PairwiseResult,
};
pub use stats::{
    fstat_from_sw, permanova, pvalue, st_of, st_of_condensed, st_rows, PermanovaOpts,
    PermanovaResult,
};
