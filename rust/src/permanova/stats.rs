//! The full PERMANOVA statistic: s_T, pseudo-F, permutation p-value.
//!
//! The paper benchmarks only the s_W hot loop ("the other steps add minimal
//! overhead"); a production library still needs them, so here they are —
//! skbio-compatible semantics throughout:
//!
//! * `s_T = Σ_{i<j} d²_ij / n`
//! * `s_W = Σ_{i<j, same group} d²_ij / |group|`
//! * `s_A = s_T − s_W`,  `F = (s_A/(k−1)) / (s_W/(n−k))`
//! * `p = (1 + #{F_perm ≥ F_obs}) / (1 + P)`

use std::time::Instant;

use super::batch::{resolve_threads, sw_plan_range};
use super::grouping::Grouping;
use super::kernels::{PackedRows, SwAlgorithm, DEFAULT_TILE};
use crate::dmat::{CondensedMatrix, DistanceMatrix};
use crate::error::{Error, Result};
use crate::rng::PermutationPlan;

/// Total sum of squares `s_T` (f64 accumulation; permutation-invariant).
pub fn st_of(mat: &DistanceMatrix) -> f64 {
    let n = mat.n();
    let mut acc = 0.0f64;
    for i in 0..n {
        let row = mat.row(i);
        let mut local = 0.0f64;
        for &v in &row[i + 1..] {
            local += (v as f64) * (v as f64);
        }
        acc += local;
    }
    acc / n as f64
}

/// [`st_of`] over the packed triangle.  A packed row is bitwise the dense
/// row's `[i+1..n]` tail and the per-row accumulation order is identical,
/// so the two functions return the same bits — which keeps every recorded
/// `s_t` (reports, goldens) stable across the layout change.
pub fn st_of_condensed(tri: &CondensedMatrix) -> f64 {
    let mut acc = 0.0f64;
    st_rows(&tri.view(), 0, tri.n(), &mut acc);
    acc / tri.n() as f64
}

/// The s_T sum over rows `[r0, r1)` of any packed row source, into a
/// caller-carried accumulator (**undivided** — the caller divides by `n`
/// after covering `[0, n)`).  Per-row f64 locals summed in ascending row
/// order, exactly as [`st_of_condensed`] always did, so a sequence of
/// ascending contiguous ranges reproduces its bits — this is how the
/// out-of-core prelude computes `s_t` one paged chunk at a time.
pub fn st_rows<S: PackedRows>(src: &S, r0: usize, r1: usize, acc: &mut f64) {
    for i in r0..r1 {
        let mut local = 0.0f64;
        for &v in src.row(i) {
            local += (v as f64) * (v as f64);
        }
        *acc += local;
    }
}

/// Pseudo-F from a partial statistic.
#[inline]
pub fn fstat_from_sw(s_w: f64, s_t: f64, n: usize, k: usize) -> f64 {
    let s_a = s_t - s_w;
    (s_a / (k as f64 - 1.0)) / (s_w / (n as f64 - k as f64))
}

/// Permutation p-value, skbio semantics (observed value participates).
pub fn pvalue(f_obs: f64, f_perms: &[f64]) -> f64 {
    let ge = f_perms.iter().filter(|&&f| f >= f_obs).count();
    (1.0 + ge as f64) / (1.0 + f_perms.len() as f64)
}

/// Options for a PERMANOVA run.
#[derive(Clone, Debug)]
pub struct PermanovaOpts {
    /// Which s_W kernel formulation to use.
    pub algo: SwAlgorithm,
    /// Worker threads (0 = all available).
    pub threads: usize,
    /// RNG seed for the permutation plan.
    pub seed: u64,
    /// Retain the permuted F distribution in the result.
    pub keep_f_perms: bool,
}

impl Default for PermanovaOpts {
    fn default() -> Self {
        PermanovaOpts {
            algo: SwAlgorithm::Tiled { tile: DEFAULT_TILE },
            threads: 0,
            seed: 0x5EED_CAFE,
            keep_f_perms: false,
        }
    }
}

/// Result of a PERMANOVA run.
#[derive(Clone, Debug)]
pub struct PermanovaResult {
    /// Observed pseudo-F.
    pub f_obs: f64,
    /// Permutation p-value.
    pub p_value: f64,
    /// Number of label permutations tested (excluding the observed).
    pub n_perms: usize,
    /// Objects / groups of the test.
    pub n: usize,
    pub k: usize,
    /// Total sum of squares (diagnostic).
    pub s_t: f64,
    /// Observed partial statistic (diagnostic).
    pub s_w_obs: f64,
    /// Kernel used.
    pub algo: String,
    /// Threads used.
    pub threads: usize,
    /// Wall time of the permutation sweep.
    pub elapsed_secs: f64,
    /// The permuted F distribution, if requested.
    pub f_perms: Option<Vec<f64>>,
}

/// Run the complete PERMANOVA test.
///
/// `n_perms` is the number of *random* permutations (999, 3999, ... by
/// convention 10^x − 1 so that (1+P) is round); the observed labelling is
/// index 0 of the plan and is not double-counted.
pub fn permanova(
    mat: &DistanceMatrix,
    grouping: &Grouping,
    n_perms: usize,
    opts: &PermanovaOpts,
) -> Result<PermanovaResult> {
    if grouping.n() != mat.n() {
        return Err(Error::InvalidInput(format!(
            "grouping has {} objects, matrix has {}",
            grouping.n(),
            mat.n()
        )));
    }
    if n_perms == 0 {
        return Err(Error::InvalidInput("n_perms must be >= 1".into()));
    }
    let n = mat.n();
    let k = grouping.k();
    let threads = resolve_threads(opts.threads);
    let start = Instant::now();

    // Pack once; the permutation sweep streams the triangle, not the
    // dense matrix (half the bytes per permutation).
    let tri = CondensedMatrix::from_dense(mat);
    let plan = PermutationPlan::new(grouping.labels().to_vec(), opts.seed, n_perms + 1);
    let s_w_all =
        sw_plan_range(&tri, &plan, 0, n_perms + 1, grouping.inv_sizes(), opts.algo, threads);

    let s_t = st_of_condensed(&tri);
    let f_all: Vec<f64> = s_w_all
        .iter()
        .map(|&sw| fstat_from_sw(sw as f64, s_t, n, k))
        .collect();
    let f_obs = f_all[0];
    let f_perms = &f_all[1..];
    let p_value = pvalue(f_obs, f_perms);

    Ok(PermanovaResult {
        f_obs,
        p_value,
        n_perms,
        n,
        k,
        s_t,
        s_w_obs: s_w_all[0] as f64,
        algo: opts.algo.name(),
        threads,
        elapsed_secs: start.elapsed().as_secs_f64(),
        f_perms: if opts.keep_f_perms { Some(f_perms.to_vec()) } else { None },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn st_hand_computed() {
        // d(0,1)=1, d(0,2)=2, d(1,2)=2; n=3 → s_T = (1+4+4)/3 = 3
        let mut m = DistanceMatrix::zeros(3);
        m.set_sym(0, 1, 1.0);
        m.set_sym(0, 2, 2.0);
        m.set_sym(1, 2, 2.0);
        assert!((st_of(&m) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn st_condensed_is_bitwise_identical_to_dense() {
        for (n, seed) in [(3usize, 1u64), (17, 2), (64, 3), (97, 4)] {
            let m = DistanceMatrix::random_euclidean(n, 6, seed);
            let tri = CondensedMatrix::from_dense(&m);
            assert_eq!(st_of(&m).to_bits(), st_of_condensed(&tri).to_bits(), "n={n}");
        }
    }

    #[test]
    fn st_rows_chunked_is_bitwise_identical_to_whole() {
        for (n, seed) in [(3usize, 5u64), (17, 6), (64, 7)] {
            let m = DistanceMatrix::random_euclidean(n, 6, seed);
            let tri = CondensedMatrix::from_dense(&m);
            let want = st_of_condensed(&tri);
            for step in [1usize, 4, 11, n] {
                let mut acc = 0.0f64;
                let mut r0 = 0usize;
                while r0 < n {
                    let r1 = (r0 + step).min(n);
                    st_rows(&tri.view(), r0, r1, &mut acc);
                    r0 = r1;
                }
                let got = acc / n as f64;
                assert_eq!(got.to_bits(), want.to_bits(), "n={n} step={step}");
            }
        }
    }

    #[test]
    fn fstat_identity() {
        // s_t=10, s_w=4, n=10, k=3: F = (6/2)/(4/7) = 5.25
        assert!((fstat_from_sw(4.0, 10.0, 10, 3) - 5.25).abs() < 1e-12);
    }

    #[test]
    fn pvalue_edges() {
        let perms = vec![1.0, 2.0, 3.0, 4.0];
        assert!((pvalue(5.0, &perms) - 0.2).abs() < 1e-12); // above all: 1/5
        assert!((pvalue(0.0, &perms) - 1.0).abs() < 1e-12); // below all
        assert!((pvalue(3.0, &perms) - 0.6).abs() < 1e-12); // ties count (>=)
    }

    #[test]
    fn planted_structure_detected() {
        let n = 60;
        let k = 3;
        let mat = DistanceMatrix::planted_blocks(n, k, 0.1, 1.0, 7);
        let labels: Vec<u32> = (0..n).map(|i| (i % k) as u32).collect();
        let grouping = Grouping::new(labels).unwrap();
        let res = permanova(&mat, &grouping, 199, &PermanovaOpts::default()).unwrap();
        assert!(res.f_obs > 10.0, "F = {}", res.f_obs);
        assert!((res.p_value - 1.0 / 200.0).abs() < 1e-9, "p = {}", res.p_value);
    }

    #[test]
    fn null_data_gives_large_p() {
        let n = 50;
        let mat = DistanceMatrix::random_euclidean(n, 8, 21);
        let grouping = Grouping::balanced(n, 5).unwrap();
        let res = permanova(&mat, &grouping, 499, &PermanovaOpts::default()).unwrap();
        assert!(res.p_value > 0.01, "p = {}", res.p_value);
    }

    #[test]
    fn result_is_seed_deterministic_and_algo_invariant() {
        let mat = DistanceMatrix::random_euclidean(40, 6, 2);
        let grouping = Grouping::balanced(40, 4).unwrap();
        let mk = |algo, seed| {
            permanova(
                &mat,
                &grouping,
                99,
                &PermanovaOpts { algo, seed, threads: 2, keep_f_perms: true },
            )
            .unwrap()
        };
        let a = mk(SwAlgorithm::Brute, 5);
        let b = mk(SwAlgorithm::Brute, 5);
        assert_eq!(a.p_value, b.p_value);
        assert_eq!(a.f_perms, b.f_perms);
        // Different algorithm, same seed: same permutations, near-same stats.
        let c = mk(SwAlgorithm::Tiled { tile: 8 }, 5);
        assert!((a.f_obs - c.f_obs).abs() / a.f_obs < 1e-4);
        assert_eq!(a.p_value, c.p_value);
        // Different seed: different permutation draw.
        let d = mk(SwAlgorithm::Brute, 6);
        assert_ne!(a.f_perms, d.f_perms);
    }

    #[test]
    fn input_validation() {
        let mat = DistanceMatrix::random_euclidean(10, 4, 1);
        let g12 = Grouping::balanced(12, 3).unwrap();
        assert!(permanova(&mat, &g12, 99, &PermanovaOpts::default()).is_err());
        let g10 = Grouping::balanced(10, 2).unwrap();
        assert!(permanova(&mat, &g10, 0, &PermanovaOpts::default()).is_err());
    }

    #[test]
    fn keep_f_perms_length() {
        let mat = DistanceMatrix::random_euclidean(16, 4, 3);
        let grouping = Grouping::balanced(16, 2).unwrap();
        let res = permanova(
            &mat,
            &grouping,
            49,
            &PermanovaOpts { keep_f_perms: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!(res.f_perms.as_ref().unwrap().len(), 49);
        assert_eq!(res.n_perms, 49);
    }
}
