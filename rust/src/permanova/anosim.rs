//! ANOSIM — Analysis of Similarities (Clarke 1993).
//!
//! The rank-based companion test scikit-bio ships next to PERMANOVA, and a
//! natural consistency check for it: the same permutation machinery over a
//! different statistic.
//!
//! ```text
//! R = (r̄_between − r̄_within) / (M / 2),   M = n(n−1)/2
//! ```
//!
//! where `r̄` are mean ranks of the corresponding distances (mid-ranks on
//! ties).  R ∈ [−1, 1]; R ≫ 0 means within-group distances are
//! systematically smaller.  Significance by label permutation, identical
//! plan machinery as PERMANOVA — ranks are computed **once** (they depend
//! only on the distances), so each permutation costs O(M) like the paper's
//! s_W kernels.
//!
//! The statistic itself lives in [`r_statistic`] (scalar) and
//! [`r_statistic_block`] (the SoA block variant the batched backend uses);
//! the engine reaches both through `StatKernel::Anosim`, and the
//! [`anosim`] free function below is the thin single-threaded wrapper that
//! doubles as the conformance suite's f64 oracle.
//!
//! Layout note: ANOSIM's per-permutation operand was **packed all along**
//! — the mid-rank vector is the condensed upper triangle in the same
//! `(i, j > i)` order as `dmat::CondensedMatrix`, and since PR 5 the
//! prelude builds it straight from the dataset's shared packed buffer
//! (same values, bit-identical ranks).

use super::grouping::Grouping;
use super::method::{Method, StatKernel};
use super::stats::pvalue;
use crate::dmat::DistanceMatrix;
use crate::error::{Error, Result};
use crate::rng::PermutationPlan;

/// Result of an ANOSIM run.
#[derive(Clone, Debug)]
pub struct AnosimResult {
    /// Observed R statistic.
    pub r_obs: f64,
    pub p_value: f64,
    pub n_perms: usize,
    pub n: usize,
    pub k: usize,
}

/// Mid-ranks of the condensed distance vector (1-based, ties averaged).
pub(crate) fn rank_condensed(condensed: &[f32]) -> Vec<f64> {
    let m = condensed.len();
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| condensed[a].partial_cmp(&condensed[b]).unwrap());
    let mut ranks = vec![0.0f64; m];
    let mut i = 0;
    while i < m {
        let mut j = i;
        while j + 1 < m && condensed[order[j + 1]] == condensed[order[i]] {
            j += 1;
        }
        // mid-rank for the tie run [i, j]
        let mid = (i + j) as f64 / 2.0 + 1.0;
        for &o in &order[i..=j] {
            ranks[o] = mid;
        }
        i = j + 1;
    }
    ranks
}

/// R statistic for one labelling over precomputed condensed ranks.
pub(crate) fn r_statistic(ranks: &[f64], n: usize, labels: &[u32]) -> f64 {
    let mut sum_within = 0.0f64;
    let mut cnt_within = 0usize;
    let mut sum_between = 0.0f64;
    let mut idx = 0usize;
    for i in 0..n {
        let gi = labels[i];
        for j in (i + 1)..n {
            let r = ranks[idx];
            idx += 1;
            if labels[j] == gi {
                sum_within += r;
                cnt_within += 1;
            } else {
                sum_between += r;
            }
        }
    }
    let m = ranks.len();
    let cnt_between = m - cnt_within;
    if cnt_within == 0 || cnt_between == 0 {
        return 0.0; // degenerate labelling (can't happen through Grouping)
    }
    let mean_w = sum_within / cnt_within as f64;
    let mean_b = sum_between / cnt_between as f64;
    (mean_b - mean_w) / (m as f64 / 2.0)
}

/// R statistics for a structure-of-arrays *block* of labellings: one sweep
/// over the condensed ranks evaluates all `block` lanes — the batched
/// engine's one-sweep-many-permutations access pattern applied to ANOSIM's
/// hot loop (ranks are the streamed n²/2 operand here, exactly as d² is
/// for PERMANOVA).
///
/// `labels` is position-major SoA: `labels[i * block + j]` is the label of
/// object `i` under lane `j`; `out` (length `block`) receives each lane's R.
///
/// **Bitwise contract:** per lane, the (i, j) visit order and the f64
/// operation sequence are exactly [`r_statistic`]'s, so every lane is
/// bit-identical to the scalar statistic at any block width.
pub(crate) fn r_statistic_block(
    ranks: &[f64],
    n: usize,
    labels: &[u32],
    block: usize,
    out: &mut [f64],
) {
    debug_assert_eq!(labels.len(), n * block);
    debug_assert_eq!(out.len(), block);
    let mut sum_within = vec![0.0f64; block];
    let mut cnt_within = vec![0usize; block];
    let mut sum_between = vec![0.0f64; block];
    let mut idx = 0usize;
    for i in 0..n {
        let row_groups = &labels[i * block..(i + 1) * block];
        for j in (i + 1)..n {
            let r = ranks[idx];
            idx += 1;
            let col_groups = &labels[j * block..(j + 1) * block];
            for lane in 0..block {
                if col_groups[lane] == row_groups[lane] {
                    sum_within[lane] += r;
                    cnt_within[lane] += 1;
                } else {
                    sum_between[lane] += r;
                }
            }
        }
    }
    let m = ranks.len();
    for lane in 0..block {
        let cnt_between = m - cnt_within[lane];
        out[lane] = if cnt_within[lane] == 0 || cnt_between == 0 {
            0.0 // degenerate labelling (can't happen through Grouping)
        } else {
            let mean_w = sum_within[lane] / cnt_within[lane] as f64;
            let mean_b = sum_between[lane] / cnt_between as f64;
            (mean_b - mean_w) / (m as f64 / 2.0)
        };
    }
}

/// Run ANOSIM with `n_perms` label permutations.
///
/// Thin wrapper over the `StatKernel::Anosim` seam (single-threaded, one
/// permutation per step): the engine's backends evaluate the *same* f64
/// statistic, which is what makes this function the conformance suite's
/// oracle — engine runs must match it exactly.
pub fn anosim(
    mat: &DistanceMatrix,
    grouping: &Grouping,
    n_perms: usize,
    seed: u64,
) -> Result<AnosimResult> {
    if n_perms == 0 {
        return Err(Error::InvalidInput("n_perms must be >= 1".into()));
    }
    let kernel = StatKernel::prepare(Method::Anosim, mat, grouping)?;
    let n = mat.n();
    let plan = PermutationPlan::new(grouping.labels().to_vec(), seed, n_perms + 1);
    let mut row = vec![0u32; n];
    let mut r_all = Vec::with_capacity(n_perms + 1);
    for i in 0..n_perms + 1 {
        plan.fill(i, &mut row);
        r_all.push(kernel.eval_labels(grouping, &row));
    }
    let r_obs = r_all[0];
    Ok(AnosimResult {
        r_obs,
        p_value: pvalue(r_obs, &r_all[1..]),
        n_perms,
        n,
        k: grouping.k(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_with_ties() {
        let r = rank_condensed(&[0.5, 0.1, 0.5, 0.9]);
        // sorted: 0.1(rank 1), 0.5, 0.5 (mid 2.5), 0.9 (rank 4)
        assert_eq!(r, vec![2.5, 1.0, 2.5, 4.0]);
    }

    #[test]
    fn block_statistic_is_bitwise_identical_to_scalar_per_lane() {
        let n = 18;
        let mat = DistanceMatrix::random_euclidean(n, 5, 21);
        let ranks = rank_condensed(&mat.to_condensed());
        let grouping = Grouping::balanced(n, 3).unwrap();
        let base = grouping.labels();
        for block in [1usize, 2, 5, 8] {
            // Lanes: rotations of the observed labelling.
            let mut aos = Vec::with_capacity(block * n);
            for r in 0..block {
                for i in 0..n {
                    aos.push(base[(i + r) % n]);
                }
            }
            let mut soa = vec![0u32; block * n];
            for r in 0..block {
                for i in 0..n {
                    soa[i * block + r] = aos[r * n + i];
                }
            }
            let mut out = vec![0.0f64; block];
            r_statistic_block(&ranks, n, &soa, block, &mut out);
            for r in 0..block {
                let want = r_statistic(&ranks, n, &aos[r * n..(r + 1) * n]);
                assert_eq!(
                    out[r].to_bits(),
                    want.to_bits(),
                    "block={block} lane {r}: {} vs {want}",
                    out[r]
                );
            }
        }
    }

    #[test]
    fn perfectly_separated_r_is_one() {
        // All within distances < all between distances -> R = 1.
        let n = 12;
        let mut mat = DistanceMatrix::zeros(n);
        for i in 0..n {
            for j in (i + 1)..n {
                let same = (i % 2) == (j % 2);
                let d = if same {
                    0.1 + 0.001 * (i + j) as f32
                } else {
                    5.0 + 0.001 * (i * j) as f32
                };
                mat.set_sym(i, j, d);
            }
        }
        let grouping = Grouping::new((0..n).map(|i| (i % 2) as u32).collect()).unwrap();
        let res = anosim(&mat, &grouping, 199, 3).unwrap();
        assert!((res.r_obs - 1.0).abs() < 1e-9, "R = {}", res.r_obs);
        assert!((res.p_value - 1.0 / 200.0).abs() < 1e-9);
    }

    #[test]
    fn null_data_r_near_zero() {
        let mat = DistanceMatrix::random_euclidean(40, 8, 7);
        let grouping = Grouping::balanced(40, 4).unwrap();
        let res = anosim(&mat, &grouping, 199, 5).unwrap();
        assert!(res.r_obs.abs() < 0.25, "R = {}", res.r_obs);
        assert!(res.p_value > 0.05, "p = {}", res.p_value);
    }

    #[test]
    fn agrees_with_permanova_verdict() {
        // Strong structure: both tests fire; exchangeable data: neither.
        let strong = DistanceMatrix::planted_blocks(36, 3, 0.1, 1.0, 2);
        let grouping = Grouping::balanced(36, 3).unwrap();
        let a = anosim(&strong, &grouping, 99, 1).unwrap();
        let p = super::super::stats::permanova(
            &strong,
            &grouping,
            99,
            &super::super::stats::PermanovaOpts::default(),
        )
        .unwrap();
        assert!(a.p_value <= 0.05 && p.p_value <= 0.05);
        assert!(a.r_obs > 0.5);
    }

    #[test]
    fn r_bounded() {
        for seed in 0..6u64 {
            let mat = DistanceMatrix::random_euclidean(20, 4, seed);
            let grouping = Grouping::balanced(20, 2 + (seed as usize % 3)).unwrap();
            let res = anosim(&mat, &grouping, 49, seed).unwrap();
            assert!((-1.0..=1.0).contains(&res.r_obs), "R = {}", res.r_obs);
            assert!(res.p_value > 0.0 && res.p_value <= 1.0);
        }
    }

    #[test]
    fn input_validation() {
        let mat = DistanceMatrix::random_euclidean(10, 4, 1);
        let g12 = Grouping::balanced(12, 3).unwrap();
        assert!(anosim(&mat, &g12, 9, 1).is_err());
        let g10 = Grouping::balanced(10, 2).unwrap();
        assert!(anosim(&mat, &g10, 0, 1).is_err());
    }

    #[test]
    fn deterministic_in_seed() {
        let mat = DistanceMatrix::random_euclidean(24, 6, 3);
        let grouping = Grouping::balanced(24, 3).unwrap();
        let a = anosim(&mat, &grouping, 99, 11).unwrap();
        let b = anosim(&mat, &grouping, 99, 11).unwrap();
        assert_eq!(a.p_value, b.p_value);
        assert_eq!(a.r_obs, b.r_obs);
        let c = anosim(&mat, &grouping, 99, 12).unwrap();
        assert_eq!(a.r_obs, c.r_obs, "observed statistic is seed-free");
    }
}
