//! Group labellings: the categorical factor PERMANOVA tests.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A validated assignment of `n` objects to `k` groups.
///
/// Carries the derived quantities every kernel needs: per-group counts and
/// `inv_group_sizes` (the `1/|group|` weights of the paper's inner loop).
/// Group sizes are invariant under label permutation, so one `Grouping`
/// serves an entire permutation test.
#[derive(Clone, Debug, PartialEq)]
pub struct Grouping {
    labels: Vec<u32>,
    counts: Vec<u32>,
    inv_sizes: Vec<f32>,
}

impl Grouping {
    /// Validate and wrap a label vector.  Labels must be `0..k` dense (every
    /// group non-empty), with `k >= 2` and `n > k` (the F statistic needs
    /// both degrees of freedom positive).
    pub fn new(labels: Vec<u32>) -> Result<Self> {
        let n = labels.len();
        let k = match labels.iter().max() {
            Some(&m) => m as usize + 1,
            None => return Err(Error::InvalidInput("empty grouping".into())),
        };
        if k < 2 {
            return Err(Error::InvalidInput(
                "PERMANOVA needs at least 2 groups".into(),
            ));
        }
        if n <= k {
            return Err(Error::InvalidInput(format!(
                "need n > k for the F statistic (n = {n}, k = {k})"
            )));
        }
        let mut counts = vec![0u32; k];
        for &l in &labels {
            counts[l as usize] += 1;
        }
        if let Some(g) = counts.iter().position(|&c| c == 0) {
            return Err(Error::InvalidInput(format!(
                "group {g} is empty (labels must be dense 0..k)"
            )));
        }
        let inv_sizes = counts.iter().map(|&c| 1.0 / c as f32).collect();
        Ok(Grouping { labels, counts, inv_sizes })
    }

    /// Balanced assignment: object `i` gets label `i % k`.
    pub fn balanced(n: usize, k: usize) -> Result<Self> {
        Self::new((0..n).map(|i| (i % k) as u32).collect())
    }

    /// Build from arbitrary category values (e.g. metadata strings),
    /// mapping them to dense labels in first-seen-sorted order.  Returns the
    /// grouping and the category -> label mapping.
    pub fn from_categories<S: AsRef<str>>(cats: &[S]) -> Result<(Self, BTreeMap<String, u32>)> {
        let mut m2 = BTreeMap::new();
        for c in cats {
            let next = m2.len() as u32;
            m2.entry(c.as_ref().to_string()).or_insert(next);
        }
        // BTreeMap iteration is sorted by category; reassign dense ids in
        // sorted order so the mapping is stable regardless of input order.
        for (i, (_, v)) in m2.iter_mut().enumerate() {
            *v = i as u32;
        }
        let labels = cats
            .iter()
            .map(|c| *m2.get(c.as_ref()).expect("just inserted"))
            .collect();
        Ok((Self::new(labels)?, m2))
    }

    /// Number of objects.
    #[inline]
    pub fn n(&self) -> usize {
        self.labels.len()
    }

    /// Number of groups.
    #[inline]
    pub fn k(&self) -> usize {
        self.counts.len()
    }

    /// The dense label vector.
    #[inline]
    pub fn labels(&self) -> &[u32] {
        &self.labels
    }

    /// Objects per group.
    #[inline]
    pub fn counts(&self) -> &[u32] {
        &self.counts
    }

    /// `1 / |group|` weights (the paper's `inv_group_sizes`).
    #[inline]
    pub fn inv_sizes(&self) -> &[f32] {
        &self.inv_sizes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_counts() {
        let g = Grouping::balanced(10, 3).unwrap();
        assert_eq!(g.n(), 10);
        assert_eq!(g.k(), 3);
        assert_eq!(g.counts(), &[4, 3, 3]);
        assert!((g.inv_sizes()[0] - 0.25).abs() < 1e-7);
    }

    #[test]
    fn rejects_degenerate() {
        assert!(Grouping::new(vec![]).is_err());
        assert!(Grouping::new(vec![0, 0, 0, 0]).is_err(), "k = 1");
        assert!(Grouping::new(vec![0, 1]).is_err(), "n <= k");
        assert!(Grouping::new(vec![0, 2, 2, 0]).is_err(), "group 1 empty");
    }

    #[test]
    fn from_categories_stable_sorted_mapping() {
        let cats = ["gut", "soil", "gut", "ocean", "soil", "gut"];
        let (g, map) = Grouping::from_categories(&cats).unwrap();
        // Sorted order: gut=0, ocean=1, soil=2
        assert_eq!(map["gut"], 0);
        assert_eq!(map["ocean"], 1);
        assert_eq!(map["soil"], 2);
        assert_eq!(g.labels(), &[0, 2, 0, 1, 2, 0]);
        assert_eq!(g.counts(), &[3, 1, 2]);
    }

    #[test]
    fn from_categories_order_independent() {
        let (a, _) = Grouping::from_categories(&["x", "y", "x", "z"]).unwrap();
        let (b, _) = Grouping::from_categories(&["z", "y", "x", "x"]).unwrap();
        // Same category multiset, different order: same k and count multiset.
        assert_eq!(a.k(), b.k());
        let mut ca = a.counts().to_vec();
        let mut cb = b.counts().to_vec();
        ca.sort_unstable();
        cb.sort_unstable();
        assert_eq!(ca, cb);
    }
}
