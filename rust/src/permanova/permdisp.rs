//! PERMDISP — homogeneity of multivariate dispersions (Anderson 2006).
//!
//! PERMANOVA's required companion check: a significant PERMANOVA can mean
//! different *locations* or different *spreads*; PERMDISP isolates the
//! spread.  Following vegan's `betadisper + permutest` (and skbio's
//! `permdisp`): embed the distance matrix with PCoA, measure each object's
//! Euclidean distance to its group centroid, then permutation-test the
//! ANOVA F statistic over those distances.
//!
//! The expensive embedding lives in [`dispersion_prelude`] (run once per
//! problem, the `StatKernel::Permdisp` prelude); [`anova_f`] is the O(n)
//! per-permutation statistic.  The [`permdisp`] free function below is the
//! thin single-threaded wrapper that doubles as the conformance suite's
//! f64 oracle.
//!
//! Layout note: PERMDISP's per-permutation operand is the O(n)
//! distance-to-centroid vector — there is no n² stream to pack.  Its
//! prelude is the one engine path that legitimately reads the **dense**
//! matrix (PCoA Gower-centers the full n²), which is why `dmat::pcoa`
//! sits on the dense side of the packed-layout boundary (and why its
//! scratch arena matters: it runs on every dataset-cache miss).

use super::grouping::Grouping;
use super::method::{Method, StatKernel};
use super::stats::pvalue;
use crate::dmat::{pcoa, DistanceMatrix};
use crate::error::{Error, Result};
use crate::rng::PermutationPlan;

/// Result of a PERMDISP run.
#[derive(Clone, Debug)]
pub struct PermdispResult {
    /// Observed ANOVA F over distances-to-centroid.
    pub f_obs: f64,
    pub p_value: f64,
    pub n_perms: usize,
    pub n: usize,
    pub k: usize,
    /// Mean distance-to-centroid per group (the dispersions under test).
    pub group_dispersions: Vec<f64>,
}

/// ANOVA F over `values` grouped by `labels` (k groups, all non-empty).
pub(crate) fn anova_f(values: &[f64], labels: &[u32], k: usize) -> f64 {
    let n = values.len();
    let mut sums = vec![0.0f64; k];
    let mut counts = vec![0usize; k];
    for (&v, &g) in values.iter().zip(labels) {
        sums[g as usize] += v;
        counts[g as usize] += 1;
    }
    let grand = values.iter().sum::<f64>() / n as f64;
    let mut ss_between = 0.0f64;
    for g in 0..k {
        let mean_g = sums[g] / counts[g] as f64;
        ss_between += counts[g] as f64 * (mean_g - grand) * (mean_g - grand);
    }
    let mut ss_within = 0.0f64;
    for (&v, &g) in values.iter().zip(labels) {
        let mean_g = sums[g as usize] / counts[g as usize] as f64;
        ss_within += (v - mean_g) * (v - mean_g);
    }
    if ss_within <= 0.0 {
        return f64::INFINITY;
    }
    (ss_between / (k as f64 - 1.0)) / (ss_within / (n as f64 - k as f64))
}

/// The PERMDISP prelude: embed the matrix with PCoA and return each
/// object's distance to its group centroid plus the per-group mean
/// dispersions.  This is the expensive, permutation-invariant half of the
/// test, shared between the engine's `StatKernel::Permdisp` and the
/// [`permdisp`] oracle.
pub(crate) fn dispersion_prelude(
    mat: &DistanceMatrix,
    grouping: &Grouping,
) -> Result<(Vec<f64>, Vec<f64>)> {
    let k = grouping.k();
    let labels = grouping.labels();

    // Embed and compute distance of every object to its group centroid.
    let emb = pcoa(mat, 0)?;
    let na = emb.n_axes;
    let mut centroids = vec![0.0f64; k * na];
    for (i, &g) in labels.iter().enumerate() {
        for a in 0..na {
            centroids[g as usize * na + a] += emb.coord(i, a);
        }
    }
    for g in 0..k {
        let c = grouping.counts()[g] as f64;
        for a in 0..na {
            centroids[g * na + a] /= c;
        }
    }
    let dists: Vec<f64> = labels
        .iter()
        .enumerate()
        .map(|(i, &g)| {
            (0..na)
                .map(|a| {
                    let d = emb.coord(i, a) - centroids[g as usize * na + a];
                    d * d
                })
                .sum::<f64>()
                .sqrt()
        })
        .collect();

    let group_dispersions: Vec<f64> = (0..k)
        .map(|g| {
            let (s, c) = labels
                .iter()
                .zip(&dists)
                .filter(|(&l, _)| l as usize == g)
                .fold((0.0, 0usize), |(s, c), (_, &d)| (s + d, c + 1));
            s / c as f64
        })
        .collect();
    Ok((dists, group_dispersions))
}

/// Run PERMDISP with `n_perms` label permutations.
///
/// Thin wrapper over the `StatKernel::Permdisp` seam (single-threaded,
/// one permutation per step): the engine's backends evaluate the *same*
/// f64 statistic over the *same* prelude, which is what makes this
/// function the conformance suite's oracle.
pub fn permdisp(
    mat: &DistanceMatrix,
    grouping: &Grouping,
    n_perms: usize,
    seed: u64,
) -> Result<PermdispResult> {
    if n_perms == 0 {
        return Err(Error::InvalidInput("n_perms must be >= 1".into()));
    }
    let kernel = StatKernel::prepare(Method::Permdisp, mat, grouping)?;
    let group_dispersions = kernel.group_dispersions().to_vec();
    let n = mat.n();
    let k = grouping.k();

    // Permutation test: shuffle which group each distance belongs to
    // (vegan's permutest on the betadisper residuals).
    let plan = PermutationPlan::new(grouping.labels().to_vec(), seed, n_perms + 1);
    let mut row = vec![0u32; n];
    let mut f_all = Vec::with_capacity(n_perms + 1);
    for i in 0..n_perms + 1 {
        plan.fill(i, &mut row);
        f_all.push(kernel.eval_labels(grouping, &row));
    }
    let f_obs = f_all[0];
    Ok(PermdispResult {
        f_obs,
        p_value: pvalue(f_obs, &f_all[1..]),
        n_perms,
        n,
        k,
        group_dispersions,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    /// Two groups with equal spread but different location.
    fn location_only() -> (DistanceMatrix, Grouping) {
        let n = 40;
        let mut rng = Xoshiro256pp::new(8);
        // Points on a line: group 0 near 0, group 1 near 10, same jitter.
        let pts: Vec<f64> = (0..n)
            .map(|i| if i % 2 == 0 { 0.0 } else { 10.0 } + rng.next_f64())
            .collect();
        let mut mat = DistanceMatrix::zeros(n);
        for i in 0..n {
            for j in (i + 1)..n {
                mat.set_sym(i, j, (pts[i] - pts[j]).abs() as f32);
            }
        }
        (mat, Grouping::new((0..n).map(|i| (i % 2) as u32).collect()).unwrap())
    }

    /// Two groups, same center, very different spread.
    fn dispersion_only() -> (DistanceMatrix, Grouping) {
        let n = 40;
        let mut rng = Xoshiro256pp::new(9);
        let pts: Vec<f64> = (0..n)
            .map(|i| {
                let spread = if i % 2 == 0 { 0.1 } else { 5.0 };
                (rng.next_f64() - 0.5) * 2.0 * spread
            })
            .collect();
        let mut mat = DistanceMatrix::zeros(n);
        for i in 0..n {
            for j in (i + 1)..n {
                mat.set_sym(i, j, (pts[i] - pts[j]).abs() as f32);
            }
        }
        (mat, Grouping::new((0..n).map(|i| (i % 2) as u32).collect()).unwrap())
    }

    #[test]
    fn location_shift_is_not_dispersion() {
        let (mat, grouping) = location_only();
        let r = permdisp(&mat, &grouping, 199, 3).unwrap();
        assert!(r.p_value > 0.05, "equal spreads must pass: p = {}", r.p_value);
        // ... while PERMANOVA on the same data fires (it's a location test).
        let p = super::super::stats::permanova(
            &mat,
            &grouping,
            199,
            &super::super::stats::PermanovaOpts::default(),
        )
        .unwrap();
        assert!(p.p_value <= 0.01);
    }

    #[test]
    fn dispersion_difference_detected() {
        let (mat, grouping) = dispersion_only();
        let r = permdisp(&mat, &grouping, 199, 4).unwrap();
        assert!(r.p_value <= 0.01, "different spreads must fail: p = {}", r.p_value);
        assert!(r.group_dispersions[1] > 5.0 * r.group_dispersions[0]);
    }

    #[test]
    fn anova_f_hand_case() {
        // groups: {1, 2} mean 1.5, {5, 6} mean 5.5; grand 3.5
        // ss_between = 2*(2)^2 * 2 = 16; ss_within = 4*0.25 = 1
        // F = (16/1)/(1/2) = 32
        let f = anova_f(&[1.0, 2.0, 5.0, 6.0], &[0, 0, 1, 1], 2);
        assert!((f - 32.0).abs() < 1e-10, "{f}");
    }

    #[test]
    fn deterministic_and_validated() {
        let (mat, grouping) = dispersion_only();
        let a = permdisp(&mat, &grouping, 99, 7).unwrap();
        let b = permdisp(&mat, &grouping, 99, 7).unwrap();
        assert_eq!(a.p_value, b.p_value);
        assert_eq!(a.group_dispersions, b.group_dispersions);

        let g_bad = Grouping::balanced(99, 3).unwrap();
        assert!(permdisp(&mat, &g_bad, 9, 1).is_err());
        assert!(permdisp(&mat, &grouping, 0, 1).is_err());
    }
}
