//! The paper's three `permanova_f_stat_sW` kernel formulations, in Rust —
//! sweeping the **packed upper triangle** ([`CondensedView`]).
//!
//! These are line-for-line ports of the paper's Algorithms 1–3 (modulo Rust
//! idiom), kept deliberately close to the C++ so the measured CPU-side
//! comparisons mean what the paper's did:
//!
//! * [`sw_brute_one`] — Algorithm 1, the original brute force: row-major
//!   scan of the strict upper triangle with the `grouping[col] == group_idx`
//!   branch in the inner loop.
//! * [`sw_tiled_one`] — Algorithm 2, the CPU cache-tiled variant with the
//!   hand-split `TILE` loops and the hoisted `inv_group_sizes` access
//!   (multiply once per row-stripe, not once per element).
//! * [`sw_flat_one`] — Algorithm 3's *formulation* (branch → predicated
//!   multiply, the shape GPU and SIMD compilers want), which is what the
//!   OpenMP target region compiles down to on the GPU.  On the CPU this is
//!   the autovectorizable variant.
//!
//! **Memory layout.**  Since PR 5 the production kernels take a
//! [`CondensedView`] — the packed `n*(n-1)/2` triangle — instead of the
//! dense `n*n` buffer.  The kernels only ever read `(row, col > row)` in
//! row-major order, and a packed row *is* the dense row's `[row+1..n]`
//! tail, so the f32 operation sequence is unchanged: every packed kernel
//! is **bitwise identical** to its dense seed, at half the streamed
//! footprint (the paper's memory-bound loop moves half the bytes per
//! permutation).  The dense seeds are kept as `*_dense` oracles, pinned
//! against the packed kernels by the packed-layout conformance suite.
//!
//! All variants return identical values up to f32 reduction order; the
//! brute kernel is also provided with an f64 accumulator ([`sw_brute_f64`])
//! as the in-crate oracle.

use super::grouping::Grouping;
use crate::dmat::{CondensedMatrix, CondensedView, DistanceMatrix, TriangleChunk};

/// Anything that can hand a kernel packed row `i` of an `n`-object
/// triangle: the resident [`CondensedView`] or an out-of-core
/// [`TriangleChunk`] (which only answers for its own `[r0, r1)` range).
///
/// This is the seam the chunk-major refactor hangs on: every `*_rows`
/// kernel below sweeps an arbitrary row range of any row source with a
/// **caller-carried accumulator**, and the classic whole-triangle kernels
/// are now single full-range calls — so a sequence of chunk-range calls
/// with ascending, contiguous ranges executes the *identical* f32/f64
/// operation sequence per permutation lane as one resident sweep.  That
/// is the entire bitwise argument for out-of-core results, and
/// `rust/tests/oocore_chunked.rs` pins it per backend.
pub trait PackedRows {
    /// Number of objects (matrix edge) of the full triangle.
    fn n(&self) -> usize;
    /// Row `i`'s packed slice `d(i, i+1), ..., d(i, n-1)`.
    fn row(&self, i: usize) -> &[f32];
}

impl PackedRows for CondensedView<'_> {
    #[inline]
    fn n(&self) -> usize {
        CondensedView::n(self)
    }

    #[inline]
    fn row(&self, i: usize) -> &[f32] {
        CondensedView::row(self, i)
    }
}

impl PackedRows for TriangleChunk {
    #[inline]
    fn n(&self) -> usize {
        TriangleChunk::n(self)
    }

    #[inline]
    fn row(&self, i: usize) -> &[f32] {
        TriangleChunk::row(self, i)
    }
}

/// Which s_W kernel to run — the paper's algorithm axis of Figure 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwAlgorithm {
    /// Algorithm 1: original brute force (branchy inner loop).
    Brute,
    /// Algorithm 2: CPU cache-tiled, with the paper's TILE constant.
    Tiled { tile: usize },
    /// Algorithm 3's formulation: predicated/branchless (GPU/SIMD shape).
    Flat,
}

impl SwAlgorithm {
    /// Stable identifier used in configs, manifests and reports.
    pub fn name(&self) -> String {
        match self {
            SwAlgorithm::Brute => "brute".to_string(),
            SwAlgorithm::Tiled { tile } => format!("tiled{tile}"),
            SwAlgorithm::Flat => "flat".to_string(),
        }
    }

    /// Parse the identifier format produced by [`name`](Self::name); bare
    /// `"tiled"` uses the paper-informed default tile.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "brute" => Some(SwAlgorithm::Brute),
            "flat" => Some(SwAlgorithm::Flat),
            "tiled" => Some(SwAlgorithm::Tiled { tile: DEFAULT_TILE }),
            _ => s
                .strip_prefix("tiled")
                .and_then(|t| t.parse().ok())
                .filter(|&t| t > 0)
                .map(|tile| SwAlgorithm::Tiled { tile }),
        }
    }
}

/// Default TILE: 512 columns × 4 B ≈ 2 KiB of `grouping` per stripe plus a
/// 512-wide row segment of the matrix — comfortably L1-resident, matching
/// the regime the paper tuned for on Zen 4.
pub const DEFAULT_TILE: usize = 512;

/// Default permutation-block width for the batched brute engine: 64 lanes
/// × 4 B = 256 B of labels per matrix element touched — a full GPU
/// wavefront's worth of work per d² read, and on the CPU enough lanes to
/// push the kernel from matrix-bandwidth-bound to compute-bound, which is
/// the regime where the paper's MI300A GPU measurement lives.
pub const DEFAULT_PERM_BLOCK: usize = 64;

/// Algorithm 1 — original brute force, f32 accumulation (paper-faithful),
/// sweeping the packed triangle.
///
/// `tri` is the packed upper triangle, `grouping` one label row,
/// `inv_group_sizes` the 1/|group| weights.
pub fn sw_brute_one(tri: CondensedView<'_>, grouping: &[u32], inv_group_sizes: &[f32]) -> f32 {
    let mut s_w = 0.0f32;
    sw_brute_rows(&tri, 0, tri.n(), grouping, inv_group_sizes, &mut s_w);
    s_w
}

/// Algorithm 1 over rows `[r0, r1)` of any packed row source, accumulating
/// into a caller-carried `s_w`.  Covering `[0, n)` in ascending contiguous
/// ranges reproduces [`sw_brute_one`]'s exact f32 operation sequence.
pub fn sw_brute_rows<S: PackedRows>(
    src: &S,
    r0: usize,
    r1: usize,
    grouping: &[u32],
    inv_group_sizes: &[f32],
    s_w: &mut f32,
) {
    let n = src.n();
    debug_assert_eq!(grouping.len(), n);
    for row in r0..r1.min(n.saturating_sub(1)) {
        // no columns in last row
        let group_idx = grouping[row];
        let w = inv_group_sizes[group_idx as usize];
        let tri_row = src.row(row);
        for (off, &val) in tri_row.iter().enumerate() {
            // diagonal is never stored; col = row + 1 + off
            if grouping[row + 1 + off] == group_idx {
                *s_w += val * val * w;
            }
        }
    }
}

/// Algorithm 1, batched: one sweep over the packed triangle evaluates a
/// structure-of-arrays *block* of `block` permutations at once.
///
/// This is the access pattern that wins on the paper's MI300A GPU cores:
/// instead of re-streaming the triangle once per permutation (the CPU
/// formulations above), each `d[i][j]` is read and squared **once** and the
/// cost is amortized across all `block` label assignments — the label
/// blocks are the streamed operand, and they are tiny.
///
/// `labels` is position-major SoA: `labels[i * block + j]` is the label of
/// object `i` under block lane `j`.  `out` (length `block`) accumulates
/// each lane's s_W and must be zeroed by the caller.
///
/// **Bitwise contract:** per lane, the (row, col) visit order and the f32
/// operation sequence (`(d·d)·w`, then add) are exactly [`sw_brute_one`]'s,
/// so every lane is bitwise identical to running the single-permutation
/// brute kernel on that labelling — at *any* block width.  The conformance
/// tests pin this.
pub fn sw_brute_block(
    tri: CondensedView<'_>,
    labels: &[u32],
    block: usize,
    inv_group_sizes: &[f32],
    out: &mut [f32],
) {
    sw_brute_block_rows(&tri, 0, tri.n(), labels, block, inv_group_sizes, out);
}

/// The SoA block engine over rows `[r0, r1)` of any packed row source.
/// `out` carries each lane's partial s_W across calls (the caller zeroes
/// it once, before the first range) — covering `[0, n)` in ascending
/// contiguous ranges reproduces [`sw_brute_block`]'s exact per-lane f32
/// operation sequence, which is itself [`sw_brute_one`]'s.
#[allow(clippy::too_many_arguments)]
pub fn sw_brute_block_rows<S: PackedRows>(
    src: &S,
    r0: usize,
    r1: usize,
    labels: &[u32],
    block: usize,
    inv_group_sizes: &[f32],
    out: &mut [f32],
) {
    let n = src.n();
    debug_assert_eq!(labels.len(), n * block);
    debug_assert_eq!(out.len(), block);
    for row in r0..r1.min(n.saturating_sub(1)) {
        // no columns in last row
        let row_groups = &labels[row * block..(row + 1) * block];
        let tri_row = src.row(row);
        for (off, &val) in tri_row.iter().enumerate() {
            let col = row + 1 + off; // diagonal is never stored
            let v2 = val * val;
            let col_groups = &labels[col * block..(col + 1) * block];
            for j in 0..block {
                let g = row_groups[j];
                if col_groups[j] == g {
                    out[j] += v2 * inv_group_sizes[g as usize];
                }
            }
        }
    }
}

/// Algorithm 1 with an f64 accumulator — the in-crate numerical oracle,
/// over the packed triangle.
pub fn sw_brute_f64(tri: CondensedView<'_>, grouping: &[u32], inv_group_sizes: &[f32]) -> f64 {
    let n = tri.n();
    let mut s_w = 0.0f64;
    for row in 0..n.saturating_sub(1) {
        let group_idx = grouping[row];
        let w = inv_group_sizes[group_idx as usize] as f64;
        let tri_row = tri.row(row);
        let mut local = 0.0f64;
        for (off, &val) in tri_row.iter().enumerate() {
            if grouping[row + 1 + off] == group_idx {
                let val = val as f64;
                local += val * val;
            }
        }
        s_w += local * w;
    }
    s_w
}

/// Algorithm 2 — the paper's hand-tiled CPU variant, on packed rows.
///
/// Faithfully reproduces the published loop structure: `TILE`-stepped
/// `trow`/`tcol` outer loops (note `tcol` starts at `trow + 1`, so column
/// tiles are *unaligned* — exactly as published), per-row `local_s_W`
/// accumulation, and the `inv_group_sizes` multiply hoisted to once per
/// (row, tile) — the access-reuse discovery the paper describes.  A tile's
/// column window `[min_col, max_col)` of dense row `row` is the packed
/// row's `[min_col-row-1, max_col-row-1)` — same values, same order.
pub fn sw_tiled_one(
    tri: CondensedView<'_>,
    grouping: &[u32],
    inv_group_sizes: &[f32],
    tile: usize,
) -> f32 {
    let mut s_w = 0.0f32;
    sw_tiled_rows(&tri, 0, tri.n(), grouping, inv_group_sizes, tile, &mut s_w);
    s_w
}

/// Algorithm 2 over rows `[r0, r1)` of any packed row source.  **`r0`
/// must be a multiple of `tile`**: the published loop walks `tile`-row
/// stripes from row 0, so chunk boundaries must fall between stripes for
/// the chunked sweep to replay the exact stripe sequence (the chunk
/// planner aligns to `tile` for this kernel).  `r1` is a stripe boundary
/// or `n`.
#[allow(clippy::too_many_arguments)]
pub fn sw_tiled_rows<S: PackedRows>(
    src: &S,
    r0: usize,
    r1: usize,
    grouping: &[u32],
    inv_group_sizes: &[f32],
    tile: usize,
    s_w: &mut f32,
) {
    debug_assert!(tile > 0);
    debug_assert_eq!(r0 % tile, 0, "chunk start must align to the stripe size");
    let n = src.n();
    let mut trow = r0;
    while trow < r1 && trow + 1 < n {
        // no columns in last row
        let mut tcol = trow + 1;
        while tcol < n {
            // diagonal is never stored
            let row_end = (trow + tile).min(n - 1);
            for row in trow..row_end {
                let min_col = tcol.max(row + 1);
                let max_col = (tcol + tile).min(n);
                if min_col >= max_col {
                    continue;
                }
                let tri_row = src.row(row);
                let group_idx = grouping[row];
                // The paper's inner loop, with the branch if-converted and
                // eight-lane re-associated so it runs as SIMD FMAs (same
                // optimization the paper's compilers apply at -O3).
                let cols = &grouping[min_col..max_col];
                let vals = &tri_row[min_col - row - 1..max_col - row - 1];
                let local_s_w = masked_sum_sq(vals, cols, group_idx);
                *s_w += local_s_w * inv_group_sizes[group_idx as usize];
            }
            tcol += tile;
        }
        trow += tile;
    }
}

/// Algorithm 3's formulation — branch replaced by a predicated multiply,
/// on packed rows.
///
/// This is the shape the GPU compiler gives the paper's `collapse(2)
/// reduction` region.  On the CPU, rustc cannot vectorize a strict-order
/// f32 reduction, so the row sum is split into eight explicit accumulator
/// lanes (`masked_sum_sq`) — semantically a fixed re-association, which
/// LLVM then turns into masked SIMD FMAs.  (Perf pass: 0.59 -> ~2.6
/// Gelem/s on the dev host; see EXPERIMENTS.md §Perf.)
pub fn sw_flat_one(tri: CondensedView<'_>, grouping: &[u32], inv_group_sizes: &[f32]) -> f32 {
    let mut s_w = 0.0f32;
    sw_flat_rows(&tri, 0, tri.n(), grouping, inv_group_sizes, &mut s_w);
    s_w
}

/// Algorithm 3's formulation over rows `[r0, r1)` of any packed row
/// source, accumulating into a caller-carried `s_w`.
pub fn sw_flat_rows<S: PackedRows>(
    src: &S,
    r0: usize,
    r1: usize,
    grouping: &[u32],
    inv_group_sizes: &[f32],
    s_w: &mut f32,
) {
    let n = src.n();
    for row in r0..r1.min(n.saturating_sub(1)) {
        let group_idx = grouping[row];
        let w = inv_group_sizes[group_idx as usize];
        let gs = &grouping[(row + 1)..n];
        let vs = src.row(row);
        *s_w += masked_sum_sq(vs, gs, group_idx) * w;
    }
}

/// Eight-lane masked sum of squares: `Σ (g == group) · v²` with a fixed
/// lane re-association that unlocks SIMD.  Shared by the flat and tiled
/// kernels' inner loops (packed and dense alike — which is half of why
/// the two layouts are bitwise identical).
#[inline]
fn masked_sum_sq(vs: &[f32], gs: &[u32], group_idx: u32) -> f32 {
    debug_assert_eq!(vs.len(), gs.len());
    const LANES: usize = 8;
    let mut acc = [0.0f32; LANES];
    let chunks = vs.len() / LANES;
    for c in 0..chunks {
        let v = &vs[c * LANES..(c + 1) * LANES];
        let g = &gs[c * LANES..(c + 1) * LANES];
        for l in 0..LANES {
            let m = (g[l] == group_idx) as u32 as f32;
            acc[l] += m * v[l] * v[l];
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * LANES..vs.len() {
        let m = (gs[i] == group_idx) as u32 as f32;
        tail += m * vs[i] * vs[i];
    }
    acc.iter().sum::<f32>() + tail
}

/// Dispatch one permutation through the chosen algorithm (packed operand).
#[inline]
pub fn sw_one(
    algo: SwAlgorithm,
    tri: CondensedView<'_>,
    grouping: &[u32],
    inv_group_sizes: &[f32],
) -> f32 {
    match algo {
        SwAlgorithm::Brute => sw_brute_one(tri, grouping, inv_group_sizes),
        SwAlgorithm::Tiled { tile } => sw_tiled_one(tri, grouping, inv_group_sizes, tile),
        SwAlgorithm::Flat => sw_flat_one(tri, grouping, inv_group_sizes),
    }
}

/// Dispatch a row range through the chosen algorithm with a carried
/// accumulator — the chunk-major edition of [`sw_one`].  The tiled
/// variant requires `r0` to be a stripe multiple (see [`sw_tiled_rows`]).
#[allow(clippy::too_many_arguments)]
pub fn sw_rows<S: PackedRows>(
    algo: SwAlgorithm,
    src: &S,
    r0: usize,
    r1: usize,
    grouping: &[u32],
    inv_group_sizes: &[f32],
    s_w: &mut f32,
) {
    match algo {
        SwAlgorithm::Brute => sw_brute_rows(src, r0, r1, grouping, inv_group_sizes, s_w),
        SwAlgorithm::Tiled { tile } => {
            sw_tiled_rows(src, r0, r1, grouping, inv_group_sizes, tile, s_w)
        }
        SwAlgorithm::Flat => sw_flat_rows(src, r0, r1, grouping, inv_group_sizes, s_w),
    }
}

/// The row alignment a chunk plan must honor for `algo`'s chunked sweep
/// to replay the resident op sequence: the stripe size for the tiled
/// kernel, 1 (any row boundary) otherwise.
pub fn chunk_align(algo: SwAlgorithm) -> usize {
    match algo {
        SwAlgorithm::Tiled { tile } => tile,
        SwAlgorithm::Brute | SwAlgorithm::Flat => 1,
    }
}

/// Convenience wrapper for matrix + grouping types (packs the triangle —
/// use a prebuilt [`CondensedMatrix`] when calling in a loop).
pub fn sw_of(algo: SwAlgorithm, mat: &DistanceMatrix, grouping: &Grouping) -> f32 {
    let tri = CondensedMatrix::from_dense(mat);
    sw_one(algo, tri.view(), grouping.labels(), grouping.inv_sizes())
}

// ---------------------------------------------------------------------------
// Dense seed kernels — the pre-packed-layout implementations, kept verbatim
// as the conformance oracles the packed kernels are pinned against (and for
// callers that hold only a dense buffer, e.g. the XLA artifact checks).
// ---------------------------------------------------------------------------

/// Dense seed of [`sw_brute_one`]: Algorithm 1 over the row-major `n*n`
/// buffer.  Bitwise-identical to the packed kernel by construction.
pub fn sw_brute_one_dense(
    mat: &[f32],
    n: usize,
    grouping: &[u32],
    inv_group_sizes: &[f32],
) -> f32 {
    debug_assert_eq!(mat.len(), n * n);
    debug_assert_eq!(grouping.len(), n);
    let mut s_w = 0.0f32;
    for row in 0..n.saturating_sub(1) {
        let group_idx = grouping[row];
        let w = inv_group_sizes[group_idx as usize];
        let mat_row = &mat[row * n..(row + 1) * n];
        for col in (row + 1)..n {
            if grouping[col] == group_idx {
                let val = mat_row[col];
                s_w += val * val * w;
            }
        }
    }
    s_w
}

/// Dense seed of [`sw_brute_f64`] (the f64 oracle over a dense buffer).
pub fn sw_brute_f64_dense(
    mat: &[f32],
    n: usize,
    grouping: &[u32],
    inv_group_sizes: &[f32],
) -> f64 {
    let mut s_w = 0.0f64;
    for row in 0..n.saturating_sub(1) {
        let group_idx = grouping[row];
        let w = inv_group_sizes[group_idx as usize] as f64;
        let mat_row = &mat[row * n..(row + 1) * n];
        let mut local = 0.0f64;
        for col in (row + 1)..n {
            if grouping[col] == group_idx {
                let val = mat_row[col] as f64;
                local += val * val;
            }
        }
        s_w += local * w;
    }
    s_w
}

/// Dense seed of [`sw_tiled_one`].
pub fn sw_tiled_one_dense(
    mat: &[f32],
    n: usize,
    grouping: &[u32],
    inv_group_sizes: &[f32],
    tile: usize,
) -> f32 {
    debug_assert!(tile > 0);
    let mut s_w = 0.0f32;
    let mut trow = 0usize;
    while trow + 1 < n {
        let mut tcol = trow + 1;
        while tcol < n {
            let row_end = (trow + tile).min(n - 1);
            for row in trow..row_end {
                let min_col = tcol.max(row + 1);
                let max_col = (tcol + tile).min(n);
                if min_col >= max_col {
                    continue;
                }
                let mat_row = &mat[row * n..(row + 1) * n];
                let group_idx = grouping[row];
                let cols = &grouping[min_col..max_col];
                let local_s_w = masked_sum_sq(&mat_row[min_col..max_col], cols, group_idx);
                s_w += local_s_w * inv_group_sizes[group_idx as usize];
            }
            tcol += tile;
        }
        trow += tile;
    }
    s_w
}

/// Dense seed of [`sw_flat_one`].
pub fn sw_flat_one_dense(
    mat: &[f32],
    n: usize,
    grouping: &[u32],
    inv_group_sizes: &[f32],
) -> f32 {
    let mut s_w = 0.0f32;
    for row in 0..n.saturating_sub(1) {
        let group_idx = grouping[row];
        let w = inv_group_sizes[group_idx as usize];
        let mat_row = &mat[row * n..(row + 1) * n];
        let gs = &grouping[(row + 1)..n];
        let vs = &mat_row[(row + 1)..n];
        s_w += masked_sum_sq(vs, gs, group_idx) * w;
    }
    s_w
}

/// Dense seed of [`sw_brute_block`] (SoA block over a dense buffer).
pub fn sw_brute_block_dense(
    mat: &[f32],
    n: usize,
    labels: &[u32],
    block: usize,
    inv_group_sizes: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(mat.len(), n * n);
    debug_assert_eq!(labels.len(), n * block);
    debug_assert_eq!(out.len(), block);
    for row in 0..n.saturating_sub(1) {
        let row_groups = &labels[row * block..(row + 1) * block];
        let mat_row = &mat[row * n..(row + 1) * n];
        for col in (row + 1)..n {
            let val = mat_row[col];
            let v2 = val * val;
            let col_groups = &labels[col * block..(col + 1) * block];
            for j in 0..block {
                let g = row_groups[j];
                if col_groups[j] == g {
                    out[j] += v2 * inv_group_sizes[g as usize];
                }
            }
        }
    }
}

/// Dense dispatch (seed oracle of [`sw_one`]).
#[inline]
pub fn sw_one_dense(
    algo: SwAlgorithm,
    mat: &[f32],
    n: usize,
    grouping: &[u32],
    inv_group_sizes: &[f32],
) -> f32 {
    match algo {
        SwAlgorithm::Brute => sw_brute_one_dense(mat, n, grouping, inv_group_sizes),
        SwAlgorithm::Tiled { tile } => {
            sw_tiled_one_dense(mat, n, grouping, inv_group_sizes, tile)
        }
        SwAlgorithm::Flat => sw_flat_one_dense(mat, n, grouping, inv_group_sizes),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dmat::DistanceMatrix;
    use crate::rng::Xoshiro256pp;

    fn hand_case() -> (DistanceMatrix, Vec<u32>, Vec<f32>) {
        // Same pinned case as the python oracle test:
        // groups {0,1},{2,3}; d(0,1)=1, d(2,3)=2, cross=9 → s_W = 2.5
        let mut m = DistanceMatrix::zeros(4);
        m.set_sym(0, 1, 1.0);
        m.set_sym(2, 3, 2.0);
        for i in 0..2 {
            for j in 2..4 {
                m.set_sym(i, j, 9.0);
            }
        }
        (m, vec![0, 0, 1, 1], vec![0.5, 0.5])
    }

    #[test]
    fn hand_computed_value_all_algorithms() {
        let (m, g, inv) = hand_case();
        let tri = CondensedMatrix::from_dense(&m);
        for algo in [
            SwAlgorithm::Brute,
            SwAlgorithm::Flat,
            SwAlgorithm::Tiled { tile: 1 },
            SwAlgorithm::Tiled { tile: 2 },
            SwAlgorithm::Tiled { tile: 3 },
            SwAlgorithm::Tiled { tile: 64 },
        ] {
            let got = sw_one(algo, tri.view(), &g, &inv);
            assert!((got - 2.5).abs() < 1e-6, "{algo:?} -> {got}");
        }
        assert!((sw_brute_f64(tri.view(), &g, &inv) - 2.5).abs() < 1e-12);
        assert!((sw_brute_f64_dense(m.data(), 4, &g, &inv) - 2.5).abs() < 1e-12);
    }

    fn random_case(n: usize, k: usize, seed: u64) -> (DistanceMatrix, Vec<u32>, Vec<f32>) {
        let m = DistanceMatrix::random_euclidean(n, 6, seed);
        let mut rng = Xoshiro256pp::new(seed ^ 0xABCD);
        let mut labels: Vec<u32> = (0..n).map(|i| (i % k) as u32).collect();
        crate::rng::shuffle(&mut rng, &mut labels);
        let mut counts = vec![0u32; k];
        for &l in &labels {
            counts[l as usize] += 1;
        }
        let inv = counts.iter().map(|&c| 1.0 / c as f32).collect();
        (m, labels, inv)
    }

    #[test]
    fn algorithms_agree_on_random_inputs() {
        let cases = [(7usize, 2usize, 1u64), (32, 4, 2), (65, 3, 3), (128, 8, 4), (200, 5, 5)];
        for (n, k, seed) in cases {
            let (m, g, inv) = random_case(n, k, seed);
            let tri = CondensedMatrix::from_dense(&m);
            let oracle = sw_brute_f64(tri.view(), &g, &inv);
            for algo in [
                SwAlgorithm::Brute,
                SwAlgorithm::Flat,
                SwAlgorithm::Tiled { tile: 16 },
                SwAlgorithm::Tiled { tile: 37 }, // deliberately awkward tile
                SwAlgorithm::Tiled { tile: 512 },
            ] {
                let got = sw_one(algo, tri.view(), &g, &inv) as f64;
                let rel = (got - oracle).abs() / oracle.max(1e-12);
                assert!(rel < 5e-5, "{algo:?} n={n}: got {got}, oracle {oracle}");
            }
        }
    }

    #[test]
    fn packed_kernels_are_bitwise_identical_to_dense_seeds() {
        // The tentpole contract: every formulation, packed vs dense, bit
        // for bit — including awkward tiles and the f64 oracle.
        let cases = [(7usize, 2usize, 11u64), (32, 4, 12), (65, 3, 13), (96, 5, 14)];
        for (n, k, seed) in cases {
            let (m, g, inv) = random_case(n, k, seed);
            let tri = CondensedMatrix::from_dense(&m);
            for algo in [
                SwAlgorithm::Brute,
                SwAlgorithm::Flat,
                SwAlgorithm::Tiled { tile: 1 },
                SwAlgorithm::Tiled { tile: 37 },
                SwAlgorithm::Tiled { tile: 512 },
            ] {
                let packed = sw_one(algo, tri.view(), &g, &inv);
                let dense = sw_one_dense(algo, m.data(), n, &g, &inv);
                assert_eq!(
                    packed.to_bits(),
                    dense.to_bits(),
                    "{algo:?} n={n}: packed {packed} vs dense {dense}"
                );
            }
            let packed = sw_brute_f64(tri.view(), &g, &inv);
            let dense = sw_brute_f64_dense(m.data(), n, &g, &inv);
            assert_eq!(packed.to_bits(), dense.to_bits(), "f64 oracle n={n}");
        }
    }

    #[test]
    fn tile_size_is_semantics_invariant() {
        let (m, g, inv) = random_case(97, 4, 9);
        let tri = CondensedMatrix::from_dense(&m);
        let want = sw_tiled_one(tri.view(), &g, &inv, 512);
        for tile in [1, 2, 3, 5, 8, 13, 31, 96, 97, 100, 4096] {
            let got = sw_tiled_one(tri.view(), &g, &inv, tile);
            assert!(
                (got - want).abs() / want.max(1e-9) < 5e-5,
                "tile {tile}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn zero_matrix_gives_zero() {
        let n = 24;
        let g: Vec<u32> = (0..n as u32).map(|i| i % 3).collect();
        let inv = vec![1.0 / 8.0; 3];
        let tri = CondensedMatrix::from_dense(&DistanceMatrix::zeros(n));
        for algo in [SwAlgorithm::Brute, SwAlgorithm::Flat, SwAlgorithm::Tiled { tile: 8 }] {
            assert_eq!(sw_one(algo, tri.view(), &g, &inv), 0.0);
        }
    }

    #[test]
    fn tiny_inputs_dont_panic() {
        // n = 1 has no pairs at all; n = 2 has exactly one.
        let g1 = vec![0u32];
        let inv = vec![1.0f32, 1.0];
        let t1 = CondensedMatrix::from_dense(&DistanceMatrix::zeros(1));
        assert_eq!(sw_brute_one(t1.view(), &g1, &inv), 0.0);
        assert_eq!(sw_flat_one(t1.view(), &g1, &inv), 0.0);
        assert_eq!(sw_tiled_one(t1.view(), &g1, &inv, 4), 0.0);

        let mut m2 = DistanceMatrix::zeros(2);
        m2.set_sym(0, 1, 3.0);
        let t2 = CondensedMatrix::from_dense(&m2);
        let g2 = vec![0u32, 0];
        let inv2 = vec![0.5f32];
        for algo in [SwAlgorithm::Brute, SwAlgorithm::Flat, SwAlgorithm::Tiled { tile: 4 }] {
            let got = sw_one(algo, t2.view(), &g2, &inv2);
            assert!((got - 4.5).abs() < 1e-6); // 3^2 * 0.5
        }
    }

    /// Pack `rows` label rows (row-major, as `PermutationPlan::batch`
    /// emits) into the position-major SoA layout `sw_brute_block` takes.
    fn to_soa(rows_aos: &[u32], rows: usize, n: usize) -> Vec<u32> {
        let mut soa = vec![0u32; rows * n];
        for r in 0..rows {
            for i in 0..n {
                soa[i * rows + r] = rows_aos[r * n + i];
            }
        }
        soa
    }

    #[test]
    fn block_kernel_is_bitwise_identical_to_brute_per_lane() {
        for (n, k, seed) in [(7usize, 2usize, 1u64), (32, 4, 2), (65, 3, 3), (96, 5, 4)] {
            let (m, g, inv) = random_case(n, k, seed);
            let tri = CondensedMatrix::from_dense(&m);
            // Lanes: the observed labelling plus rotations of it.
            for block in [1usize, 2, 5, 8, 64] {
                let mut aos = Vec::with_capacity(block * n);
                for r in 0..block {
                    for i in 0..n {
                        aos.push(g[(i + r) % n]);
                    }
                }
                let soa = to_soa(&aos, block, n);
                let mut out = vec![0.0f32; block];
                sw_brute_block(tri.view(), &soa, block, &inv, &mut out);
                let mut out_dense = vec![0.0f32; block];
                sw_brute_block_dense(m.data(), n, &soa, block, &inv, &mut out_dense);
                for r in 0..block {
                    let want = sw_brute_one(tri.view(), &aos[r * n..(r + 1) * n], &inv);
                    assert_eq!(
                        out[r].to_bits(),
                        want.to_bits(),
                        "n={n} block={block} lane {r}: {} vs {want}",
                        out[r]
                    );
                    assert_eq!(
                        out[r].to_bits(),
                        out_dense[r].to_bits(),
                        "n={n} block={block} lane {r}: packed vs dense seed"
                    );
                }
            }
        }
    }

    #[test]
    fn block_kernel_tiny_inputs_dont_panic() {
        // n = 1: no pairs; n = 2: one pair per lane.
        let inv = vec![1.0f32, 1.0];
        let mut out = vec![0.0f32; 3];
        let t1 = CondensedMatrix::from_dense(&DistanceMatrix::zeros(1));
        sw_brute_block(t1.view(), &[0, 0, 0], 3, &inv, &mut out);
        assert_eq!(out, vec![0.0, 0.0, 0.0]);

        let mut m2 = DistanceMatrix::zeros(2);
        m2.set_sym(0, 1, 3.0);
        let t2 = CondensedMatrix::from_dense(&m2);
        // Two lanes: same group (pair counts) vs different groups (no pair).
        let soa = [0u32, 0, 0, 1]; // labels[i*2 + j]: obj0 = {0,0}, obj1 = {0,1}
        let inv2 = vec![0.5f32, 1.0];
        let mut out2 = vec![0.0f32; 2];
        sw_brute_block(t2.view(), &soa, 2, &inv2, &mut out2);
        assert!((out2[0] - 4.5).abs() < 1e-6); // 3² · 0.5
        assert_eq!(out2[1], 0.0);
    }

    #[test]
    fn chunked_row_sweeps_are_bitwise_identical_to_whole_sweeps() {
        // The out-of-core contract at kernel level: splitting the row
        // range at any boundary (stripe-aligned for tiled) and carrying
        // the accumulator reproduces the whole sweep bit for bit.
        for (n, k, seed) in [(7usize, 2usize, 21u64), (33, 3, 22), (96, 5, 23)] {
            let (m, g, inv) = random_case(n, k, seed);
            let tri = CondensedMatrix::from_dense(&m);
            let v = tri.view();
            for algo in [
                SwAlgorithm::Brute,
                SwAlgorithm::Flat,
                SwAlgorithm::Tiled { tile: 8 },
                SwAlgorithm::Tiled { tile: 512 },
            ] {
                let want = sw_one(algo, v, &g, &inv);
                let align = chunk_align(algo);
                for step in [1usize, 3, 10, n] {
                    let step = step.div_ceil(align) * align;
                    let mut acc = 0.0f32;
                    let mut r0 = 0usize;
                    while r0 < n {
                        let r1 = (r0 + step).min(n);
                        sw_rows(algo, &v, r0, r1, &g, &inv, &mut acc);
                        r0 = r1;
                    }
                    assert_eq!(
                        acc.to_bits(),
                        want.to_bits(),
                        "{algo:?} n={n} step={step}: {acc} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn chunked_sweep_over_triangle_chunks_matches_resident() {
        // Same contract, but the row source is actual TriangleChunk
        // pieces instead of the resident view.
        use crate::dmat::TriangleChunk;
        let (m, g, inv) = random_case(41, 4, 31);
        let tri = CondensedMatrix::from_dense(&m);
        let n = 41usize;
        for algo in [SwAlgorithm::Brute, SwAlgorithm::Flat, SwAlgorithm::Tiled { tile: 8 }] {
            let want = sw_one(algo, tri.view(), &g, &inv);
            let align = chunk_align(algo);
            let step = 8usize.div_ceil(align) * align;
            let mut acc = 0.0f32;
            let mut r0 = 0usize;
            while r0 < n {
                let r1 = (r0 + step).min(n);
                let mut vals = Vec::new();
                for i in r0..r1 {
                    vals.extend_from_slice(tri.row(i));
                }
                let chunk = TriangleChunk::from_values(n, r0, r1, vals).unwrap();
                sw_rows(algo, &chunk, r0, r1, &g, &inv, &mut acc);
                r0 = r1;
            }
            assert_eq!(acc.to_bits(), want.to_bits(), "{algo:?}");
        }
    }

    #[test]
    fn block_kernel_chunked_rows_match_whole_sweep_per_lane() {
        let (m, g, inv) = random_case(40, 4, 33);
        let tri = CondensedMatrix::from_dense(&m);
        let n = 40usize;
        let block = 5usize;
        let mut aos = Vec::with_capacity(block * n);
        for r in 0..block {
            for i in 0..n {
                aos.push(g[(i + r) % n]);
            }
        }
        let soa = to_soa(&aos, block, n);
        let mut whole = vec![0.0f32; block];
        sw_brute_block(tri.view(), &soa, block, &inv, &mut whole);
        let mut chunked = vec![0.0f32; block]; // zeroed once, carried across ranges
        for (r0, r1) in [(0usize, 7usize), (7, 16), (16, 40)] {
            sw_brute_block_rows(&tri.view(), r0, r1, &soa, block, &inv, &mut chunked);
        }
        for j in 0..block {
            assert_eq!(chunked[j].to_bits(), whole[j].to_bits(), "lane {j}");
        }
    }

    #[test]
    fn name_parse_roundtrip() {
        for algo in [
            SwAlgorithm::Brute,
            SwAlgorithm::Flat,
            SwAlgorithm::Tiled { tile: 128 },
            SwAlgorithm::Tiled { tile: 512 },
        ] {
            assert_eq!(SwAlgorithm::parse(&algo.name()), Some(algo));
        }
        assert_eq!(
            SwAlgorithm::parse("tiled"),
            Some(SwAlgorithm::Tiled { tile: DEFAULT_TILE })
        );
        assert_eq!(SwAlgorithm::parse("tiled0"), None);
        assert_eq!(SwAlgorithm::parse("bogus"), None);
    }
}
