//! Post-hoc pairwise PERMANOVA.
//!
//! A significant k-group PERMANOVA says "some groups differ" — the standard
//! follow-up microbiome studies run is all-pairs PERMANOVA on the sub-matrix
//! of each group pair, with a Bonferroni correction for the k(k−1)/2 tests.
//! (scikit-bio leaves this to the user; unifrac-binaries users script it —
//! so it belongs in the library.)
//!
//! The building blocks are public: [`pairwise_subproblem`] extracts one
//! pair's sub-matrix + 2-group labelling and [`pairwise_seed`] derives the
//! pair's independent RNG seed.  `backend::execute` fans
//! `Method::PairwisePermanova` out as one scheduled engine job per pair
//! using exactly these helpers, so the [`pairwise_permanova`] free
//! function below (which runs each pair through the legacy `permanova`
//! path) is the conformance suite's oracle for that method.

use super::grouping::Grouping;
use super::stats::{permanova, PermanovaOpts};
use crate::dmat::{CondensedMatrix, DistanceMatrix};
use crate::error::Result;

/// One pair's test result.
#[derive(Clone, Debug)]
pub struct PairwiseEntry {
    pub group_a: u32,
    pub group_b: u32,
    /// Objects in the pair's sub-problem.
    pub n: usize,
    pub f_obs: f64,
    pub p_value: f64,
    /// Bonferroni-adjusted p (capped at 1).
    pub p_adjusted: f64,
}

/// Result of the all-pairs sweep.
#[derive(Clone, Debug)]
pub struct PairwiseResult {
    pub entries: Vec<PairwiseEntry>,
    pub n_comparisons: usize,
}

/// Deterministic, order-independent seed for the `(a, b)` pair's
/// permutation plan, derived from the run seed and the pair identity.
/// Shared by the legacy sweep and the engine's pairwise fan-out so the two
/// paths draw identical permutation streams.
pub fn pairwise_seed(seed: u64, a: u32, b: u32) -> u64 {
    seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(((a as u64) << 32) | b as u64)
}

/// Extract the sub-matrix and 2-group labelling for groups `(a, b)`
/// (label 0 = group `a`, label 1 = group `b`).
pub fn pairwise_subproblem(
    mat: &DistanceMatrix,
    grouping: &Grouping,
    a: u32,
    b: u32,
) -> Result<(DistanceMatrix, Grouping)> {
    let idx: Vec<usize> = grouping
        .labels()
        .iter()
        .enumerate()
        .filter(|(_, &g)| g == a || g == b)
        .map(|(i, _)| i)
        .collect();
    let m = idx.len();
    let mut sub = DistanceMatrix::zeros(m);
    for (r, &i) in idx.iter().enumerate() {
        for (c, &j) in idx.iter().enumerate() {
            sub.data_mut()[r * m + c] = mat.get(i, j);
        }
    }
    let labels: Vec<u32> = idx
        .iter()
        .map(|&i| (grouping.labels()[i] == b) as u32)
        .collect();
    Ok((sub, Grouping::new(labels)?))
}

/// [`pairwise_subproblem`] straight from the packed triangle: extract the
/// pair's sub-triangle without materializing either the parent or the
/// child as a dense matrix.  Bitwise-identical to packing the dense
/// extractor's output — both copy the same f32 entries in the same
/// `(row, col > row)` order — which the engine's dense-free pairwise
/// fan-out relies on.
pub fn pairwise_subproblem_condensed(
    tri: &CondensedMatrix,
    grouping: &Grouping,
    a: u32,
    b: u32,
) -> Result<(CondensedMatrix, Grouping)> {
    let idx: Vec<usize> = grouping
        .labels()
        .iter()
        .enumerate()
        .filter(|(_, &g)| g == a || g == b)
        .map(|(i, _)| i)
        .collect();
    let m = idx.len();
    let mut values = Vec::with_capacity(m * m.saturating_sub(1) / 2);
    for r in 0..m {
        for c in (r + 1)..m {
            values.push(tri.get(idx[r], idx[c]));
        }
    }
    let sub = CondensedMatrix::from_values(m, values)
        .expect("sub-triangle is built with exactly m(m-1)/2 entries");
    let labels: Vec<u32> = idx
        .iter()
        .map(|&i| (grouping.labels()[i] == b) as u32)
        .collect();
    Ok((sub, Grouping::new(labels)?))
}

/// Run PERMANOVA for every group pair; p-values Bonferroni-adjusted.
///
/// Each pair uses an independent seed derived from `opts.seed` and the
/// pair identity, so results are reproducible and order-independent.
pub fn pairwise_permanova(
    mat: &DistanceMatrix,
    grouping: &Grouping,
    n_perms: usize,
    opts: &PermanovaOpts,
) -> Result<PairwiseResult> {
    let k = grouping.k() as u32;
    let n_comparisons = (k as usize) * (k as usize - 1) / 2;
    let mut entries = Vec::with_capacity(n_comparisons);
    for a in 0..k {
        for b in (a + 1)..k {
            let (sub, sub_grouping) = pairwise_subproblem(mat, grouping, a, b)?;
            let pair_opts =
                PermanovaOpts { seed: pairwise_seed(opts.seed, a, b), ..opts.clone() };
            let res = permanova(&sub, &sub_grouping, n_perms, &pair_opts)?;
            entries.push(PairwiseEntry {
                group_a: a,
                group_b: b,
                n: sub.n(),
                f_obs: res.f_obs,
                p_value: res.p_value,
                p_adjusted: (res.p_value * n_comparisons as f64).min(1.0),
            });
        }
    }
    Ok(PairwiseResult { entries, n_comparisons })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::permanova::SwAlgorithm;

    /// Three groups: 0 and 1 are identical clouds, 2 is far away.
    fn fixture() -> (DistanceMatrix, Grouping) {
        let n = 45;
        let k = 3;
        let mut mat = DistanceMatrix::zeros(n);
        let mut rng = crate::rng::Xoshiro256pp::new(6);
        for i in 0..n {
            for j in (i + 1)..n {
                let gi = i % k;
                let gj = j % k;
                // groups {0,1} near each other; group 2 distant.
                let base = if (gi == 2) != (gj == 2) { 1.0 } else { 0.2 };
                let jitter = 0.02 * rng.next_f32();
                mat.set_sym(i, j, base + jitter);
            }
        }
        (mat, Grouping::balanced(n, k).unwrap())
    }

    #[test]
    fn detects_only_the_real_pair_differences() {
        let (mat, grouping) = fixture();
        let opts = PermanovaOpts { algo: SwAlgorithm::Flat, ..Default::default() };
        let r = pairwise_permanova(&mat, &grouping, 199, &opts).unwrap();
        assert_eq!(r.n_comparisons, 3);
        assert_eq!(r.entries.len(), 3);
        for e in &r.entries {
            let involves_2 = e.group_a == 2 || e.group_b == 2;
            if involves_2 {
                let (a, b) = (e.group_a, e.group_b);
                assert!(e.p_adjusted <= 0.05, "pair ({a}, {b}): p_adj {}", e.p_adjusted);
            } else {
                // Null pair: must not survive the Bonferroni-corrected
                // threshold (a fixed dataset can land anywhere in the
                // null distribution, so don't over-assert the raw p).
                assert!(e.p_adjusted > 0.05, "pair (0,1) should be null: p_adj {}", e.p_adjusted);
            }
            assert!(e.p_adjusted >= e.p_value);
            assert_eq!(e.n, 30, "two balanced groups of 15");
        }
    }

    #[test]
    fn adjustment_caps_at_one() {
        let (mat, grouping) = fixture();
        let r = pairwise_permanova(&mat, &grouping, 19, &PermanovaOpts::default()).unwrap();
        for e in &r.entries {
            assert!(e.p_adjusted <= 1.0);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let (mat, grouping) = fixture();
        let opts = PermanovaOpts { seed: 9, ..Default::default() };
        let a = pairwise_permanova(&mat, &grouping, 49, &opts).unwrap();
        let b = pairwise_permanova(&mat, &grouping, 49, &opts).unwrap();
        for (x, y) in a.entries.iter().zip(&b.entries) {
            assert_eq!(x.p_value, y.p_value);
            assert_eq!(x.f_obs, y.f_obs);
        }
    }

    #[test]
    fn subproblem_extraction() {
        let (mat, grouping) = fixture();
        let (sub, sg) = pairwise_subproblem(&mat, &grouping, 0, 2).unwrap();
        assert_eq!(sub.n(), 30);
        assert_eq!(sg.k(), 2);
        sub.validate(1e-6).unwrap();
        // Distances survive extraction: check one known pair.
        // Objects 0 (g0) and 2 (g2) are sub-indices 0 and 1.
        assert_eq!(sub.get(0, 1), mat.get(0, 2));
    }

    #[test]
    fn condensed_subproblem_matches_dense_extraction_bitwise() {
        let (mat, grouping) = fixture();
        let tri = CondensedMatrix::from_dense(&mat);
        for (a, b) in [(0u32, 1u32), (0, 2), (1, 2)] {
            let (dense_sub, dense_g) = pairwise_subproblem(&mat, &grouping, a, b).unwrap();
            let (packed_sub, packed_g) =
                pairwise_subproblem_condensed(&tri, &grouping, a, b).unwrap();
            assert_eq!(packed_sub.n(), dense_sub.n(), "pair ({a}, {b})");
            assert_eq!(packed_g.labels(), dense_g.labels());
            let packed_of_dense = CondensedMatrix::from_dense(&dense_sub);
            let lhs: Vec<u32> = packed_sub.values().iter().map(|v| v.to_bits()).collect();
            let rhs: Vec<u32> = packed_of_dense.values().iter().map(|v| v.to_bits()).collect();
            assert_eq!(lhs, rhs, "pair ({a}, {b}) sub-triangle must be bitwise identical");
        }
    }
}
