//! Batched, multi-threaded s_W / F-stat computation over permutations.
//!
//! This is the Rust analog of the paper's `permanova_f_stat_sW_T`:
//! `#pragma omp parallel for` over permutations, each thread running the
//! single-permutation kernel.  The permutation axis is embarrassingly
//! parallel and the matrix is shared read-only — exactly the regime the
//! paper measures.
//!
//! Threading is delegated to the crate-wide sharded scheduler
//! ([`crate::backend::shard`]); thread count is explicit (the SMT study of
//! Figure 1 is "same cores, 1 vs 2 threads per core"), defaulting to
//! available parallelism.

use super::grouping::Grouping;
use super::kernels::{sw_one, SwAlgorithm};
use crate::backend::shard::{run_sharded, run_sharded_with, ShardSpec};
use crate::dmat::DistanceMatrix;
use crate::rng::PermutationPlan;

/// Resolve a thread-count request (0 = all available).
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Compute s_W for `rows` pre-materialized label rows (row-major
/// `rows * n`), using `threads` OS threads via the shard scheduler.
pub fn sw_batch(
    mat: &DistanceMatrix,
    groupings: &[u32],
    rows: usize,
    inv_group_sizes: &[f32],
    algo: SwAlgorithm,
    threads: usize,
) -> Vec<f32> {
    let n = mat.n();
    assert_eq!(groupings.len(), rows * n, "groupings buffer shape");
    let mut out = vec![0.0f32; rows];
    let spec = ShardSpec::with_workers(resolve_threads(threads));
    run_sharded(&spec, &mut out, |start, slice| {
        for (i, o) in slice.iter_mut().enumerate() {
            let r = start + i;
            *o = sw_one(algo, mat.data(), n, &groupings[r * n..(r + 1) * n], inv_group_sizes);
        }
    });
    out
}

/// Compute s_W for a permutation-plan range without materializing all label
/// rows up front: each worker owns a scratch row and streams through its
/// shards.  This is the memory-lean path the coordinator uses for large
/// permutation counts.
pub fn sw_plan_range(
    mat: &DistanceMatrix,
    plan: &PermutationPlan,
    start: usize,
    count: usize,
    inv_group_sizes: &[f32],
    algo: SwAlgorithm,
    threads: usize,
) -> Vec<f32> {
    let n = mat.n();
    assert_eq!(plan.n(), n, "plan/matrix size mismatch");
    let mut out = vec![0.0f32; count];
    let spec = ShardSpec::with_workers(resolve_threads(threads));
    run_sharded_with(
        &spec,
        &mut out,
        || vec![0u32; n],
        |row, lo, slice| {
            for (i, o) in slice.iter_mut().enumerate() {
                plan.fill(start + lo + i, row);
                *o = sw_one(algo, mat.data(), n, row, inv_group_sizes);
            }
        },
    );
    out
}

/// Convenience: batch s_W for a grouping's permutation plan `[0, count)`.
pub fn sw_permutations(
    mat: &DistanceMatrix,
    grouping: &Grouping,
    seed: u64,
    count: usize,
    algo: SwAlgorithm,
    threads: usize,
) -> Vec<f32> {
    let plan = PermutationPlan::new(grouping.labels().to_vec(), seed, count);
    sw_plan_range(mat, &plan, 0, count, grouping.inv_sizes(), algo, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::permanova::kernels::sw_brute_f64;

    fn setup(n: usize, k: usize) -> (DistanceMatrix, Grouping) {
        let mat = DistanceMatrix::random_euclidean(n, 8, 11);
        let grouping = Grouping::balanced(n, k).unwrap();
        (mat, grouping)
    }

    #[test]
    fn batch_matches_single_threaded_oracle() {
        let (mat, grouping) = setup(48, 4);
        let plan = PermutationPlan::new(grouping.labels().to_vec(), 5, 33);
        let rows = plan.batch(0, 33);
        let got = sw_batch(&mat, &rows, 33, grouping.inv_sizes(), SwAlgorithm::Flat, 4);
        for r in 0..33 {
            let want = sw_brute_f64(
                mat.data(),
                48,
                &rows[r * 48..(r + 1) * 48],
                grouping.inv_sizes(),
            );
            assert!(
                ((got[r] as f64) - want).abs() / want.max(1e-12) < 5e-5,
                "row {r}"
            );
        }
    }

    #[test]
    fn plan_range_equals_materialized_batch() {
        let (mat, grouping) = setup(32, 3);
        let plan = PermutationPlan::new(grouping.labels().to_vec(), 77, 64);
        let rows = plan.batch(10, 20);
        let a = sw_batch(&mat, &rows, 20, grouping.inv_sizes(), SwAlgorithm::Brute, 3);
        let b = sw_plan_range(&mat, &plan, 10, 20, grouping.inv_sizes(), SwAlgorithm::Brute, 3);
        assert_eq!(a, b);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let (mat, grouping) = setup(40, 5);
        let base = sw_permutations(&mat, &grouping, 3, 41, SwAlgorithm::Tiled { tile: 16 }, 1);
        for threads in [2, 3, 8] {
            let got =
                sw_permutations(&mat, &grouping, 3, 41, SwAlgorithm::Tiled { tile: 16 }, threads);
            assert_eq!(base, got, "threads = {threads}");
        }
    }

    #[test]
    fn index_zero_is_observed_statistic() {
        let (mat, grouping) = setup(36, 4);
        let got = sw_permutations(&mat, &grouping, 9, 8, SwAlgorithm::Flat, 2);
        let direct = super::super::kernels::sw_of(SwAlgorithm::Flat, &mat, &grouping);
        assert!((got[0] - direct).abs() < 1e-6);
    }

    #[test]
    fn empty_and_single_row_edges() {
        let (mat, grouping) = setup(16, 2);
        let plan = PermutationPlan::new(grouping.labels().to_vec(), 1, 4);
        assert!(sw_plan_range(&mat, &plan, 0, 0, grouping.inv_sizes(), SwAlgorithm::Flat, 4)
            .is_empty());
        let one = sw_plan_range(&mat, &plan, 2, 1, grouping.inv_sizes(), SwAlgorithm::Flat, 4);
        assert_eq!(one.len(), 1);
    }

    #[test]
    fn resolve_threads_semantics() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(7), 7);
    }
}
